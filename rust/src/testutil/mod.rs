//! Small property-testing helpers (the proptest crate is not in the offline
//! vendor set, so tests use seeded-random sweeps with shrink-free reporting).

use crate::rng::{Pcg64, Rng64};

/// Run `f` against `iters` seeded RNGs; panics with the failing seed so the
/// case is reproducible (`prop_check` + the seed = a regression test).
pub fn prop_check(name: &str, iters: u64, mut f: impl FnMut(&mut Pcg64)) {
    for seed in 0..iters {
        let mut rng = Pcg64::seed_from_u64(0xBAD5EED ^ seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property {name:?} failed at seed {seed}: {msg}");
        }
    }
}

/// Assert two f64 slices are elementwise close.
pub fn assert_close(got: &[f64], want: &[f64], tol: f64, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol,
            "{ctx}[{i}]: got {g}, want {w} (tol {tol})"
        );
    }
}

/// Random f64 vector in [-scale, scale].
pub fn rand_vec(rng: &mut Pcg64, n: usize, scale: f64) -> Vec<f64> {
    (0..n).map(|_| (rng.f64_unit() * 2.0 - 1.0) * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_check_passes_quiet() {
        prop_check("trivial", 5, |rng| {
            assert!(rng.f64_unit() < 1.0);
        });
    }

    #[test]
    fn prop_check_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            prop_check("fails", 3, |_| panic!("boom"));
        });
        let msg = format!("{:?}", r.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("seed 0"), "{msg}");
    }

    #[test]
    fn assert_close_tolerates() {
        assert_close(&[1.0, 2.0], &[1.0005, 2.0], 1e-3, "ok");
    }
}
