//! Unix-domain-socket backend for co-located parties
//! (`TrainConfig::transport = Uds`, `spnn train --transport uds`).
//!
//! Same [`wire`](super::wire) framing and I/O-thread layout as the TCP
//! loopback mesh, but over `std::os::unix::net::UnixStream` socketpairs:
//! no ports, no listeners, no TCP/IP stack — the kernel moves the bytes
//! through a local pipe-like channel, which is both the cheapest real
//! IPC for parties sharing a host and a second, independent proof that
//! the protocols only depend on the [`Channel`](super::Channel) contract.
//! Weights are bit-identical to the netsim and TCP backends (asserted by
//! the `*_transports_are_transcript_equal` tests and
//! `rust/tests/decentralized.rs`).
//!
//! The mesh is strictly in-process (socketpairs have no address to
//! rendezvous on); multi-process deployments use TCP, where the session
//! handshake and the resilient relink layer live.

use std::net::Shutdown;
use std::os::unix::net::UnixStream;
use std::sync::Arc;

use super::tcp::{assemble_mesh, Duplex};
use crate::netsim::{LinkSpec, NetPort, NetStats};
use crate::{Error, Result};

impl Duplex for UnixStream {
    fn try_clone_stream(&self) -> std::io::Result<Self> {
        self.try_clone()
    }

    fn shutdown_write(&self) {
        let _ = UnixStream::shutdown(self, Shutdown::Write);
    }

    fn clear_read_timeout(&self) -> std::io::Result<()> {
        self.set_read_timeout(None)
    }

    fn set_nodelay_opt(&self) {
        // no Nagle on unix sockets — nothing to disable
    }
}

/// Full mesh over Unix-domain socketpairs: one `UnixStream::pair()` per
/// party pair, shared sender-side stats — the co-located-parties
/// counterpart of [`super::tcp::loopback_mesh`], assembled by the same
/// shared loop.
pub fn pair_mesh(names: &[&str], spec: LinkSpec) -> Result<(Vec<NetPort>, Arc<NetStats>)> {
    assemble_mesh(names, spec, |i, j| {
        UnixStream::pair().map_err(|e| Error::Net(format!("socketpair {i}<->{j}: {e}")))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{Payload, Phase};
    use std::time::Duration;

    #[test]
    fn uds_pair_reorders_tags_and_accounts_bytes() {
        let (mut ports, stats) = pair_mesh(&["A", "B"], LinkSpec::lan()).unwrap();
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        let h = std::thread::spawn(move || {
            a.send_tagged(1, 5, Payload::U64s(vec![5, 5])).unwrap();
            a.send_tagged(1, 6, Payload::F32s(vec![6.5])).unwrap();
            // keep the port alive until B confirms
            a.recv_tagged(1, 99).unwrap().into_u64s().unwrap()
        });
        b.set_recv_timeout(Duration::from_secs(20));
        assert_eq!(b.recv_tagged(0, 6).unwrap().into_f32s().unwrap(), vec![6.5]);
        assert_eq!(b.recv_tagged(0, 5).unwrap().into_u64s().unwrap(), vec![5, 5]);
        b.send_tagged(0, 99, Payload::U64s(vec![1])).unwrap();
        assert_eq!(h.join().unwrap(), vec![1]);
        let want = Payload::U64s(vec![5, 5]).total_bytes()
            + Payload::F32s(vec![6.5]).total_bytes();
        assert_eq!(stats.bytes_sent_by(0, Phase::Online), want);
    }

    #[test]
    fn uds_dropped_peer_surfaces_as_disconnect() {
        let (mut ports, _) = pair_mesh(&["A", "B"], LinkSpec::lan()).unwrap();
        let b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        drop(b);
        a.set_recv_timeout(Duration::from_secs(5));
        let err = a.recv(1).unwrap_err();
        assert!(format!("{err}").contains("disconnected"), "{err}");
    }

    #[test]
    fn uds_three_party_mesh_routes_all_pairs() {
        let (ports, _) = pair_mesh(&["A", "B", "C"], LinkSpec::lan()).unwrap();
        let mut it = ports.into_iter();
        let mut a = it.next().unwrap();
        let mut b = it.next().unwrap();
        let mut c = it.next().unwrap();
        let hb = std::thread::spawn(move || {
            let v = b.recv_u64s(0).unwrap();
            b.send(2, Payload::U64s(vec![v[0] + 1])).unwrap();
            b.recv_u64s(2).unwrap()
        });
        let hc = std::thread::spawn(move || {
            let v = c.recv_u64s(1).unwrap();
            c.send(0, Payload::U64s(vec![v[0] + 1])).unwrap();
            c.send(1, Payload::U64s(vec![99])).unwrap();
        });
        a.send(1, Payload::U64s(vec![10])).unwrap();
        assert_eq!(a.recv_u64s(2).unwrap(), vec![12]);
        assert_eq!(hb.join().unwrap(), vec![99]);
        hc.join().unwrap();
    }
}
