//! Resilient socket links: mid-training reconnect/resume for the
//! multi-process TCP backend.
//!
//! A plain socket link dies with its `TcpStream`: one RST mid-epoch and
//! the whole training run is lost. This module wraps each peer connection
//! of a multi-process deployment in a **journaled link**:
//!
//! * every data frame carries a per-link sequence number
//!   ([`wire::encode_frame`]); the sender keeps each frame in a journal
//!   until the peer acknowledges it — acks piggyback on reverse-direction
//!   data frames, and an idle-tick [`wire::FT_ACK`] frame covers
//!   one-directional phases so the journal stays bounded;
//! * when the connection drops, the link's fixed **dialer** side re-dials
//!   the peer's listener and the two sides exchange
//!   `spnn-relink v1 id=… token=… last=…` / `spnn-relink-ok last=…`
//!   control frames naming the highest sequence number each has
//!   delivered; both sides prune their journals to that point and replay
//!   the rest over the fresh socket;
//! * the receiver drops frames it has already delivered (replay
//!   duplicates) and insists on gap-free sequence numbers, so the stream
//!   the protocol observes is **exactly once, in order** — which is what
//!   keeps the trained weights bit-identical through a reconnect;
//! * an orderly shutdown sends a goodbye marker ([`wire::FT_BYE`]), so a
//!   clean peer exit is distinguishable from a dropped link and never
//!   triggers a reconnect storm;
//! * with a journal directory configured ([`RelinkOpts::journal_dir`],
//!   derived from `--checkpoint-dir`), the unacked tail and both
//!   delivery watermarks also spill to an append-only, checksummed file
//!   per link, so even a killed **process** can be relaunched and rejoin
//!   through the same `spnn-relink` exchange: the restored watermarks
//!   dedupe the peer's replay, the restored tail replays to the peer,
//!   and sequence numbering continues where it left off — exactly-once
//!   delivery holds across the crash.
//!
//! Deadlock freedom: no thread ever blocks in a socket write while
//! holding the link lock. The writer journals under the lock but writes
//! through a cached clone of the socket outside it, and journal replay
//! after a reconnect runs on a dedicated worker thread while the link's
//! reader keeps draining inbound frames — so bidirectional bulk traffic
//! (and simultaneous two-sided recovery) cannot wedge on full kernel
//! buffers.
//!
//! Dialer/acceptor roles are fixed by the session topology: every party
//! re-dials the coordinator's rendezvous listener, and within the peer
//! mesh the higher-id party re-dials the lower-id party's listener
//! (mirroring the original bring-up). The acceptor keeps its listener
//! open for the lifetime of the session behind a small accept hub that
//! routes `spnn-relink` connections to the right link.
//!
//! Chaos hook: a link set can be told to sever one connection after N
//! sent frames (`spnn party --chaos-kill N` / `spnn launch --chaos
//! ROLE:N`), which is how the reconnect path stays honest in CI — see
//! the chaos tests here and in `rust/tests/decentralized.rs`.

use std::collections::{HashMap, VecDeque};
use std::fs;
use std::io::{Seek as _, SeekFrom, Write as _};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::tcp::connect_retry;
use super::wire;
use crate::netsim::{LinkSpec, Msg, NetPort, NetStats, PartyId, Payload, Phase, NO_TAG};
use crate::protocols::common::Fnv;
use crate::{Error, Result};

/// Per-step deadline for the relink control exchange on a fresh socket.
const RELINK_STEP_TIMEOUT: Duration = Duration::from_secs(10);

/// Writer idle tick: after this long with nothing to send, flush a
/// standalone ack so an idle reverse direction still prunes the peer's
/// journal.
const ACK_IDLE_TICK: Duration = Duration::from_millis(100);

/// Frames cloned out of the journal per locked batch during a replay
/// (bounds lock hold time while the reader is busy).
const REPLAY_CHUNK: usize = 16;

/// Default window in which a dropped connection must be re-established
/// before the link gives up and surfaces a disconnect error.
pub const RECONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// How a broken connection gets a replacement socket.
#[derive(Clone, Debug)]
pub(crate) enum Redial {
    /// This side re-dials the peer's listener at the given address.
    Dial(String),
    /// The peer re-dials us; our accept hub installs the new socket.
    Accept,
}

/// Knobs for a resilient link set.
pub(crate) struct RelinkOpts {
    /// Session token relink connections must present.
    pub(crate) token: u64,
    /// Reconnect window per outage.
    pub(crate) reconnect_timeout: Duration,
    /// Chaos: sever the first link that has sent this many data frames.
    pub(crate) chaos_kill_after: Option<u64>,
    /// Durable journal directory: when set, each link spills its unacked
    /// tail and delivery watermarks to `<dir>/link-<me>-<peer>.jnl` so a
    /// killed-and-relaunched process can rejoin the session with
    /// exactly-once delivery (see [`Durable`]).
    pub(crate) journal_dir: Option<String>,
}

impl Default for RelinkOpts {
    fn default() -> Self {
        RelinkOpts {
            token: 0,
            reconnect_timeout: RECONNECT_TIMEOUT,
            chaos_kill_after: None,
            journal_dir: None,
        }
    }
}

// ---------------------------------------------------------------------------
// Durable journal (crash-restartable links)
// ---------------------------------------------------------------------------

/// Magic + format tag at the head of a durable link-journal file,
/// followed by the session token (8 bytes LE).
const JNL_MAGIC: &[u8; 8] = b"SPNNJNL1";
/// Record kinds: a journaled data frame, the peer-ack watermark (our
/// frames the peer confirmed), and the delivery watermark (peer frames
/// we handed to the protocol).
const JREC_FRAME: u8 = 1;
const JREC_ACKED: u8 = 2;
const JREC_DELIVERED: u8 = 3;
/// Compact the file once this many bytes were appended since the last
/// rewrite (dead records accumulate as watermarks advance).
const JNL_COMPACT_BYTES: u64 = 1 << 20;

/// Append-only spill of one link's unacked tail and delivery watermarks.
///
/// Every journaled frame and every watermark advance is appended as a
/// checksummed record, so a killed process relaunched with the same
/// journal directory rebuilds the exact link state: the unacked frames
/// to replay, the next sequence number to assign, and the highest peer
/// frame already delivered (which dedupes the peer's replay after the
/// `spnn-relink` exchange). A torn tail record — the mark of a crash
/// mid-append — is truncated away on restore; a file written under a
/// different session token belongs to a different run and is reset.
struct Durable {
    path: PathBuf,
    file: fs::File,
    /// Bytes appended since the last compaction (growth bound).
    appended: u64,
}

/// Link state rebuilt from a durable journal on relaunch.
struct Restored {
    journal: VecDeque<(u64, Vec<u8>)>,
    next_seq: u64,
    delivered: u64,
    acked: u64,
}

impl Default for Restored {
    fn default() -> Self {
        Restored { journal: VecDeque::new(), next_seq: 1, delivered: 0, acked: 0 }
    }
}

/// Encode one journal record: kind byte, payload, FNV-1a 64 over both.
fn jnl_record(kind: u8, payload: &[u8]) -> Vec<u8> {
    let mut rec = Vec::with_capacity(1 + payload.len() + 8);
    rec.push(kind);
    rec.extend_from_slice(payload);
    let mut f = Fnv::new();
    f.add_bytes(&rec);
    rec.extend_from_slice(&f.0.to_le_bytes());
    rec
}

fn jnl_frame_record(seq: u64, frame: &[u8]) -> Vec<u8> {
    let mut p = Vec::with_capacity(12 + frame.len());
    p.extend_from_slice(&seq.to_le_bytes());
    p.extend_from_slice(&(frame.len() as u32).to_le_bytes());
    p.extend_from_slice(frame);
    jnl_record(JREC_FRAME, &p)
}

/// Total byte length of the record at the head of `rest` (checksum
/// included), or `None` when it is short or of unknown kind.
fn jnl_record_len(rest: &[u8]) -> Option<usize> {
    match *rest.first()? {
        JREC_FRAME => {
            if rest.len() < 13 {
                return None;
            }
            let len = u32::from_le_bytes(rest[9..13].try_into().unwrap()) as usize;
            let total = 13 + len + 8;
            (rest.len() >= total).then_some(total)
        }
        JREC_ACKED | JREC_DELIVERED => (rest.len() >= 17).then_some(17),
        _ => None,
    }
}

/// Parse a journal image, returning the restored link state plus the
/// number of leading bytes that form valid records. A return of 0 means
/// "start fresh": the header is missing or corrupt, or the file was
/// written under a different session token.
fn parse_journal(buf: &[u8], token: u64) -> (Restored, usize) {
    let mut r = Restored::default();
    if buf.len() < 16
        || &buf[..8] != JNL_MAGIC
        || u64::from_le_bytes(buf[8..16].try_into().unwrap()) != token
    {
        return (r, 0);
    }
    let mut frames: Vec<(u64, Vec<u8>)> = Vec::new();
    let mut pos = 16usize;
    loop {
        let Some(total) = jnl_record_len(&buf[pos..]) else { break };
        let rec = &buf[pos..pos + total];
        let body = &rec[..total - 8];
        let mut f = Fnv::new();
        f.add_bytes(body);
        if u64::from_le_bytes(rec[total - 8..].try_into().unwrap()) != f.0 {
            break; // torn or corrupt record: the valid prefix ends here
        }
        let v = u64::from_le_bytes(body[1..9].try_into().unwrap());
        match body[0] {
            JREC_FRAME => frames.push((v, body[13..].to_vec())),
            JREC_ACKED => r.acked = r.acked.max(v),
            _ => r.delivered = r.delivered.max(v),
        }
        pos += total;
    }
    // every sent frame is either still unacked (tail) or covered by the
    // ack watermark, so the highest seq seen fixes the next to assign
    frames.retain(|(s, _)| *s > r.acked);
    r.next_seq = frames.last().map_or(0, |(s, _)| *s).max(r.acked) + 1;
    r.journal = frames.into();
    (r, pos)
}

impl Durable {
    /// Open (and restore from) the journal for one link, creating or
    /// resetting the file as needed. The returned handle is positioned
    /// for appends past the valid prefix.
    fn open(dir: &str, me: PartyId, peer: PartyId, token: u64) -> Result<(Durable, Restored)> {
        fs::create_dir_all(dir)
            .map_err(|e| Error::Net(format!("relink journal dir {dir:?}: {e}")))?;
        let path = Path::new(dir).join(format!("link-{me}-{peer}.jnl"));
        let buf = fs::read(&path).unwrap_or_default();
        let (restored, valid) = parse_journal(&buf, token);
        let io = |e: std::io::Error| Error::Net(format!("relink journal {path:?}: {e}"));
        let mut file = fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&path)
            .map_err(io)?;
        if valid == 0 {
            // fresh file, stale token, or corrupt header: start over
            file.set_len(0).map_err(io)?;
            let mut hdr = Vec::with_capacity(16);
            hdr.extend_from_slice(JNL_MAGIC);
            hdr.extend_from_slice(&token.to_le_bytes());
            file.write_all(&hdr).map_err(io)?;
        } else {
            if valid < buf.len() {
                eprintln!(
                    "spnn-relink: journal {path:?}: dropping torn tail ({valid} of {} \
                     bytes valid)",
                    buf.len()
                );
                file.set_len(valid as u64).map_err(io)?;
            }
            file.seek(SeekFrom::End(0)).map_err(io)?;
        }
        Ok((Durable { path, file, appended: 0 }, restored))
    }

    /// Append one pre-encoded record. Failures degrade the link to
    /// in-memory journaling only (a later relaunch recovers less, live
    /// delivery is unaffected).
    fn append(&mut self, rec: &[u8]) {
        if self.file.write_all(rec).is_err() {
            eprintln!(
                "spnn-relink: journal {:?}: append failed; crash durability degraded",
                self.path
            );
        }
        self.appended += rec.len() as u64;
    }

    fn frame(&mut self, seq: u64, frame: &[u8]) {
        self.append(&jnl_frame_record(seq, frame));
    }

    fn watermark(&mut self, kind: u8, v: u64) {
        self.append(&jnl_record(kind, &v.to_le_bytes()));
    }
}

/// Rewrite the durable file down to the live state — the unacked tail
/// plus both watermarks — once enough dead bytes accumulated. Failures
/// leave the append-only file in place (it just keeps growing).
fn jnl_compact(g: &mut Inner, token: u64) {
    if !matches!(&g.durable, Some(d) if d.appended >= JNL_COMPACT_BYTES) {
        return;
    }
    let mut buf = Vec::with_capacity(1024);
    buf.extend_from_slice(JNL_MAGIC);
    buf.extend_from_slice(&token.to_le_bytes());
    for (s, f) in &g.journal {
        buf.extend_from_slice(&jnl_frame_record(*s, f));
    }
    buf.extend_from_slice(&jnl_record(JREC_ACKED, &g.acked.to_le_bytes()));
    buf.extend_from_slice(&jnl_record(JREC_DELIVERED, &g.delivered.to_le_bytes()));
    let d = g.durable.as_mut().expect("checked above");
    let tmp = d.path.with_extension("jnl.tmp");
    // write through a handle we keep: after the rename it IS the live
    // file, so appends never land in a renamed-over inode
    let mut nf = match fs::OpenOptions::new().create(true).write(true).truncate(true).open(&tmp)
    {
        Ok(f) => f,
        Err(_) => return,
    };
    if nf.write_all(&buf).is_err() || fs::rename(&tmp, &d.path).is_err() {
        return;
    }
    d.file = nf;
    d.appended = 0;
}

/// Mutable link state shared by the reader, writer, replay-worker and
/// hub threads.
struct Inner {
    /// Current socket; `None` while the link is down.
    stream: Option<TcpStream>,
    /// Bumped on every socket install (stale-handle detection).
    epoch: u64,
    /// Sent-but-unacked frames, encoded, contiguous by sequence number.
    journal: VecDeque<(u64, Vec<u8>)>,
    /// Next sequence number to assign (data frames start at 1).
    next_seq: u64,
    /// Highest in-order sequence number delivered from the peer.
    delivered: u64,
    /// Highest own sequence number the peer has acknowledged.
    acked: u64,
    /// Highest `delivered` value we have sent to the peer (piggybacked
    /// or standalone) — drives the idle-tick ack.
    last_ack_sent: u64,
    /// Peer sent its goodbye marker: EOF is clean, stop reconnecting.
    peer_bye: bool,
    /// Our side shut down (port dropped / outbox closed).
    closed: bool,
    /// Our goodbye went out (exactly once).
    bye_sent: bool,
    /// Epoch of the replay worker currently owning the write side
    /// (`None` = the writer thread owns it).
    replaying: Option<u64>,
    /// Data frames written on this link (chaos trigger).
    frames_sent: u64,
    /// Durable spill of the journal and watermarks (crash-restart
    /// support); `None` when journaling is memory-only.
    durable: Option<Durable>,
    /// Chaos: this endpoint was "killed" — stop all recovery, send no
    /// goodbye, leave the durable journal as the only trace.
    killed: bool,
}

/// One resilient link's shared state.
struct Shared {
    me: PartyId,
    peer: PartyId,
    token: u64,
    reconnect_timeout: Duration,
    chaos_after: Option<u64>,
    /// Set once the chaos kill fired anywhere in the link set.
    chaos_fired: Arc<AtomicBool>,
    inner: Mutex<Inner>,
    cv: Condvar,
}

fn prune_journal(g: &mut Inner, ack: u64) {
    if ack > g.acked {
        g.acked = ack;
        if let Some(d) = g.durable.as_mut() {
            d.watermark(JREC_ACKED, ack);
        }
    }
    while g.journal.front().is_some_and(|(s, _)| *s <= g.acked) {
        g.journal.pop_front();
    }
}

fn drop_stream(g: &mut Inner) {
    if let Some(s) = g.stream.take() {
        let _ = s.shutdown(Shutdown::Both);
    }
}

fn ctl_msg(from: PartyId, text: String) -> Msg {
    Msg { from, tag: NO_TAG, payload: Payload::Control(text), depart: 0.0, phase: Phase::Offline }
}

/// Point `cache` at the link's current socket; `None` while the link is
/// down or a replay worker owns the write side.
fn refresh_cache(g: &Inner, cache: &mut Option<(TcpStream, u64)>) {
    if g.replaying.is_some() {
        *cache = None;
        return;
    }
    match g.stream.as_ref() {
        Some(s) => {
            if cache.as_ref().map(|c| c.1) != Some(g.epoch) {
                *cache = s.try_clone().ok().map(|c| (c, g.epoch));
            }
        }
        None => *cache = None,
    }
}

/// Write one frame through the cached handle **without holding the link
/// lock** (the frame is already journaled, so a failure just marks the
/// link down and lets the reconnect path replay it). Returns true on a
/// completed write.
fn write_unlocked(sh: &Shared, cache: &mut Option<(TcpStream, u64)>, frame: &[u8]) -> bool {
    let Some((s, ep)) = cache.as_ref() else { return false };
    let mut w: &TcpStream = s;
    if std::io::Write::write_all(&mut w, frame).is_ok() {
        return true;
    }
    let mut g = sh.inner.lock().unwrap();
    if g.epoch == *ep {
        drop_stream(&mut g);
    }
    *cache = None;
    false
}

/// Probe-write the goodbye on the current socket (one small frame; safe
/// under the lock). Marks the stream down on failure so the caller can
/// fall back to a reconnect.
fn send_bye_locked(g: &mut Inner) -> bool {
    let Some(s) = g.stream.as_ref() else { return false };
    let bye = wire::encode_bye(g.next_seq - 1, g.delivered);
    let mut w: &TcpStream = s;
    if std::io::Write::write_all(&mut w, &bye).is_ok() {
        g.bye_sent = true;
        let _ = s.shutdown(Shutdown::Write);
        true
    } else {
        drop_stream(g);
        false
    }
}

/// Block (bounded by the reconnect window) until no replay worker owns
/// the link's write side.
fn wait_replay<'a>(
    sh: &'a Shared,
    mut g: std::sync::MutexGuard<'a, Inner>,
) -> std::sync::MutexGuard<'a, Inner> {
    let deadline = Instant::now() + sh.reconnect_timeout;
    while g.replaying.is_some() && Instant::now() < deadline {
        let (g2, _) = sh.cv.wait_timeout(g, Duration::from_millis(50)).unwrap();
        g = g2;
    }
    g
}

fn maybe_chaos(sh: &Shared, g: &mut Inner) {
    if let Some(n) = sh.chaos_after {
        if g.frames_sent == n && !sh.chaos_fired.swap(true, Ordering::SeqCst) {
            eprintln!(
                "spnn-relink: CHAOS severing link {} -> {} after {n} data frames",
                sh.me, sh.peer
            );
            if let Some(s) = g.stream.as_ref() {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Socket install + background journal replay
// ---------------------------------------------------------------------------

/// Install a fresh socket (lock held) and hand the write side to a
/// replay worker: the journal tail streams to the peer on its own
/// thread while this link's reader resumes immediately, so neither side
/// of a two-way recovery ever stops draining its inbound direction.
fn install_and_replay(sh: &Arc<Shared>, g: &mut Inner, stream: TcpStream) -> bool {
    let wr = match stream.try_clone() {
        Ok(c) => c,
        Err(_) => return false,
    };
    g.stream = Some(stream);
    g.epoch += 1;
    let epoch = g.epoch;
    g.replaying = Some(epoch);
    let sh2 = sh.clone();
    let spawned = std::thread::Builder::new()
        .name(format!("spnn-replay-{}-{}", sh.me, sh.peer))
        .spawn(move || replay_worker(sh2, wr, epoch));
    if spawned.is_err() {
        g.replaying = None;
        drop_stream(g);
        return false;
    }
    sh.cv.notify_all();
    true
}

/// Stream the unacked journal (and anything appended mid-replay) to the
/// peer in sequence order, in small locked batches, then hand the write
/// side back to the writer thread. Sends the goodbye itself when the
/// link closed while the replay was in flight.
fn replay_worker(sh: Arc<Shared>, stream: TcpStream, epoch: u64) {
    let mut last_seq = 0u64;
    let mut replayed = 0usize;
    loop {
        let batch = {
            let mut g = sh.inner.lock().unwrap();
            if g.epoch != epoch || g.replaying != Some(epoch) {
                if g.replaying == Some(epoch) {
                    g.replaying = None;
                }
                sh.cv.notify_all();
                return; // superseded by a newer socket
            }
            let delivered = g.delivered;
            let mut batch: Vec<Vec<u8>> = Vec::new();
            for (s, f) in g.journal.iter_mut() {
                if *s <= last_seq {
                    continue;
                }
                if batch.len() == REPLAY_CHUNK {
                    break;
                }
                wire::patch_ack(f, delivered);
                last_seq = *s;
                batch.push(f.clone());
            }
            if batch.is_empty() {
                // drained: atomically hand the write side back (and say
                // goodbye if the link closed while we were replaying)
                g.replaying = None;
                g.last_ack_sent = g.last_ack_sent.max(delivered);
                if g.closed && !g.bye_sent {
                    send_bye_locked(&mut g);
                }
                sh.cv.notify_all();
                if replayed > 0 {
                    eprintln!(
                        "spnn-relink: party {} replayed {replayed} frame(s) to peer {}",
                        sh.me, sh.peer
                    );
                }
                return;
            }
            batch
        };
        for f in &batch {
            let mut w: &TcpStream = &stream;
            if std::io::Write::write_all(&mut w, f).is_err() {
                let mut g = sh.inner.lock().unwrap();
                if g.epoch == epoch {
                    drop_stream(&mut g);
                }
                if g.replaying == Some(epoch) {
                    g.replaying = None;
                }
                sh.cv.notify_all();
                return; // the next reconnect replays from the journal
            }
        }
        replayed += batch.len();
    }
}

/// Dialer-side recovery, run with the link lock held: re-dial, exchange
/// `spnn-relink`, prune the journal and kick off the background replay.
/// Returns false when the reconnect window elapsed.
fn reconnect_locked(sh: &Arc<Shared>, g: &mut Inner, addr: &str) -> bool {
    let _sp = crate::obs::span("transport_relink_seconds");
    let deadline = Instant::now() + sh.reconnect_timeout;
    loop {
        if g.killed {
            return false;
        }
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            eprintln!(
                "spnn-relink: party {} gave up re-dialing {} (peer {}) after {:?}",
                sh.me, addr, sh.peer, sh.reconnect_timeout
            );
            return false;
        }
        let stream = match connect_retry(addr, remaining) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("spnn-relink: party {} could not re-dial peer {}: {e}", sh.me, sh.peer);
                return false;
            }
        };
        stream.set_nodelay(true).ok();
        if stream.set_read_timeout(Some(RELINK_STEP_TIMEOUT)).is_err() {
            continue;
        }
        let hello = ctl_msg(
            sh.me,
            format!("spnn-relink v1 id={} token={} last={}", sh.me, sh.token, g.delivered),
        );
        let mut w: &TcpStream = &stream;
        if wire::write_msg(&mut w, &hello).is_err() {
            continue;
        }
        let mut r: &TcpStream = &stream;
        let reply = match wire::read_msg(&mut r) {
            Ok(Some(m)) => match m.payload.into_control() {
                Ok(t) => t,
                Err(_) => continue,
            },
            _ => continue,
        };
        let Some(rest) = reply.strip_prefix("spnn-relink-ok last=") else {
            eprintln!(
                "spnn-relink: party {} relink to peer {} rejected: {reply:?}",
                sh.me, sh.peer
            );
            std::thread::sleep(Duration::from_millis(50));
            continue;
        };
        let Ok(peer_last) = rest.trim().parse::<u64>() else { continue };
        prune_journal(g, peer_last);
        if stream.set_read_timeout(None).is_err() {
            continue;
        }
        if !install_and_replay(sh, g, stream) {
            continue;
        }
        eprintln!(
            "spnn-relink: party {} re-established link to peer {} ({} unacked frame(s) \
             to replay)",
            sh.me,
            sh.peer,
            g.journal.len()
        );
        crate::obs::counter_add("transport_relinks_total", 1);
        return true;
    }
}

// ---------------------------------------------------------------------------
// Writer / reader threads
// ---------------------------------------------------------------------------

fn writer_loop(sh: Arc<Shared>, out_rx: mpsc::Receiver<Msg>, redial: Redial) {
    // cached clone of the current socket, tagged with its epoch; writes
    // happen through it OUTSIDE the link lock (see module docs)
    let mut cache: Option<(TcpStream, u64)> = None;
    loop {
        match out_rx.recv_timeout(ACK_IDLE_TICK) {
            Ok(msg) => {
                let (frame, ack) = {
                    let mut g = sh.inner.lock().unwrap();
                    let seq = g.next_seq;
                    g.next_seq += 1;
                    let ack = g.delivered;
                    let frame = wire::encode_frame(&msg, seq, ack);
                    g.journal.push_back((seq, frame.clone()));
                    if let Some(d) = g.durable.as_mut() {
                        d.frame(seq, &frame);
                    }
                    jnl_compact(&mut g, sh.token);
                    refresh_cache(&g, &mut cache);
                    (frame, ack)
                };
                if write_unlocked(&sh, &mut cache, &frame) {
                    let mut g = sh.inner.lock().unwrap();
                    g.last_ack_sent = g.last_ack_sent.max(ack);
                    g.frames_sent += 1;
                    maybe_chaos(&sh, &mut g);
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // idle reverse direction: flush a standalone ack so the
                // peer's journal stays bounded on one-way traffic phases
                let frame = {
                    let mut g = sh.inner.lock().unwrap();
                    if g.killed {
                        return;
                    }
                    refresh_cache(&g, &mut cache);
                    if cache.is_some() && g.delivered > g.last_ack_sent {
                        g.last_ack_sent = g.delivered;
                        Some(wire::encode_ack(g.delivered))
                    } else {
                        None
                    }
                };
                if let Some(f) = frame {
                    write_unlocked(&sh, &mut cache, &f);
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    // outbox closed: the port is gone. Let an in-flight replay finish
    // (it says goodbye itself when it drains), otherwise say goodbye —
    // the bye write doubles as a liveness probe, and the dialing side
    // runs one reconnect cycle so an unacked tail is not silently
    // swallowed by a dead link.
    let mut g = sh.inner.lock().unwrap();
    if g.killed {
        return;
    }
    g.closed = true;
    g = wait_replay(&sh, g);
    if !g.bye_sent && !send_bye_locked(&mut g) && !g.journal.is_empty() && !g.peer_bye {
        if let Redial::Dial(addr) = &redial {
            if reconnect_locked(&sh, &mut g, addr) {
                g = wait_replay(&sh, g); // worker sends the bye on drain
                if !g.bye_sent {
                    send_bye_locked(&mut g);
                }
            }
        }
    }
    sh.cv.notify_all();
}

fn reader_loop(sh: Arc<Shared>, inbox_tx: mpsc::Sender<Msg>, redial: Redial) {
    'outer: loop {
        // acquire a handle on the current socket, reconnecting (dialer)
        // or waiting for the hub (acceptor) when the link is down
        let (mut rd, my_epoch) = {
            let mut g = sh.inner.lock().unwrap();
            loop {
                if g.closed || g.peer_bye || g.killed {
                    return;
                }
                if let Some(s) = g.stream.as_ref() {
                    match s.try_clone() {
                        Ok(c) => break (c, g.epoch),
                        Err(_) => {
                            drop_stream(&mut g);
                            continue;
                        }
                    }
                }
                match &redial {
                    Redial::Dial(addr) => {
                        if !reconnect_locked(&sh, &mut g, addr) {
                            return; // inbox drops -> port reports disconnect
                        }
                    }
                    Redial::Accept => {
                        let deadline = Instant::now() + sh.reconnect_timeout;
                        while g.stream.is_none() && !g.closed && !g.peer_bye && !g.killed {
                            let now = Instant::now();
                            if now >= deadline {
                                eprintln!(
                                    "spnn-relink: party {} gave up waiting for peer {} \
                                     to re-dial after {:?}",
                                    sh.me, sh.peer, sh.reconnect_timeout
                                );
                                return;
                            }
                            let (g2, _) = sh.cv.wait_timeout(g, deadline - now).unwrap();
                            g = g2;
                        }
                    }
                }
            }
        };
        loop {
            match wire::read_frame(&mut rd) {
                Ok(Some(f)) => {
                    let mut g = sh.inner.lock().unwrap();
                    prune_journal(&mut g, f.ack);
                    match f.msg {
                        None if f.ftype == wire::FT_ACK => continue,
                        None => {
                            // goodbye: peer is done; any later EOF is clean
                            g.peer_bye = true;
                            sh.cv.notify_all();
                            return;
                        }
                        Some(msg) => {
                            if msg.from != sh.peer {
                                eprintln!(
                                    "spnn-relink: party {}: frame from {} on the link to \
                                     peer {} — dropping link",
                                    sh.me, msg.from, sh.peer
                                );
                                return;
                            }
                            if f.seq <= g.delivered {
                                continue; // replay duplicate
                            }
                            if f.seq != g.delivered + 1 {
                                eprintln!(
                                    "spnn-relink: party {}: sequence gap from peer {} \
                                     (got {}, expected {}) — dropping link",
                                    sh.me,
                                    sh.peer,
                                    f.seq,
                                    g.delivered + 1
                                );
                                return;
                            }
                            g.delivered = f.seq;
                            if let Some(d) = g.durable.as_mut() {
                                d.watermark(JREC_DELIVERED, f.seq);
                            }
                            jnl_compact(&mut g, sh.token);
                            drop(g);
                            if inbox_tx.send(msg).is_err() {
                                let mut g = sh.inner.lock().unwrap();
                                g.closed = true;
                                sh.cv.notify_all();
                                return;
                            }
                        }
                    }
                }
                Ok(None) | Err(_) => {
                    // EOF without a goodbye, or a torn frame: link dropped
                    let mut g = sh.inner.lock().unwrap();
                    if g.closed || g.peer_bye || g.killed {
                        return;
                    }
                    if g.epoch == my_epoch {
                        drop_stream(&mut g);
                    }
                    continue 'outer;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Accept hub (acceptor-side listener for the session lifetime)
// ---------------------------------------------------------------------------

/// Handle to the background accept loop that serves `spnn-relink`
/// connections on the acceptor's listener.
pub(crate) struct Hub {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Hub {
    /// Stop the accept loop and join its thread.
    pub(crate) fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Hub {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_relink(stream: TcpStream, links: &[(PartyId, Arc<Shared>)], me: PartyId, token: u64) {
    stream.set_nodelay(true).ok();
    if stream.set_read_timeout(Some(RELINK_STEP_TIMEOUT)).is_err() {
        return;
    }
    let reject = |s: &TcpStream, why: String| {
        eprintln!("spnn-relink: party {me}: dropping stray connection ({why})");
        let mut w: &TcpStream = s;
        let _ = wire::write_msg(&mut w, &ctl_msg(me, format!("spnn-err {why}")));
    };
    let mut r: &TcpStream = &stream;
    let text = match wire::read_msg(&mut r) {
        Ok(Some(m)) => match m.payload.into_control() {
            Ok(t) => t,
            Err(_) => return reject(&stream, "relink hello is not a control frame".into()),
        },
        _ => return,
    };
    let Some(rest) = text.strip_prefix("spnn-relink v1 ") else {
        return reject(&stream, format!("expected relink hello, got {text:?}"));
    };
    let field = |key: &str| -> Option<u64> {
        rest.split_whitespace()
            .find_map(|w| w.strip_prefix(key).and_then(|r| r.strip_prefix('=')))
            .and_then(|v| v.parse().ok())
    };
    let (Some(pid), Some(ptoken), Some(peer_last)) =
        (field("id"), field("token"), field("last"))
    else {
        return reject(&stream, format!("malformed relink hello {text:?}"));
    };
    if ptoken != token {
        return reject(&stream, "wrong session token".into());
    }
    let Some((_, sh)) = links.iter().find(|(p, _)| *p as u64 == pid) else {
        return reject(&stream, format!("no acceptor-side link for peer {pid}"));
    };
    let mut g = sh.inner.lock().unwrap();
    if g.peer_bye {
        return reject(&stream, "peer already said goodbye on this link".into());
    }
    // kick the old socket (wakes our reader if it is still blocked on it)
    drop_stream(&mut g);
    let mut w: &TcpStream = &stream;
    let ok = ctl_msg(me, format!("spnn-relink-ok last={}", g.delivered));
    if wire::write_msg(&mut w, &ok).is_err() {
        return;
    }
    prune_journal(&mut g, peer_last);
    if stream.set_read_timeout(None).is_err() {
        return;
    }
    // the replay worker streams the tail (and, if we already shut down,
    // the goodbye the peer never received) while our reader — woken by
    // the install — resumes draining inbound frames
    if install_and_replay(sh, &mut g, stream) {
        eprintln!(
            "spnn-relink: party {me} re-accepted link from peer {pid} ({} unacked \
             frame(s) to replay)",
            g.journal.len()
        );
    }
}

fn spawn_hub(
    listener: TcpListener,
    links: Vec<(PartyId, Arc<Shared>)>,
    me: PartyId,
    token: u64,
) -> Result<Hub> {
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let links = Arc::new(links);
    listener
        .set_nonblocking(true)
        .map_err(|e| Error::Net(format!("hub set_nonblocking: {e}")))?;
    let handle = std::thread::Builder::new()
        .name(format!("spnn-hub-{me}"))
        .spawn(move || loop {
            if stop2.load(Ordering::SeqCst) {
                return;
            }
            match listener.accept() {
                Ok((s, _)) => {
                    if s.set_nonblocking(false).is_ok() {
                        // one detached thread per connection: a stray or
                        // stalled client blocking in its 10 s handshake
                        // read must never starve a genuine re-dial (the
                        // listener may be on a routable address)
                        let links = links.clone();
                        let _ = std::thread::Builder::new()
                            .name(format!("spnn-relink-accept-{me}"))
                            .spawn(move || handle_relink(s, &links, me, token));
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(_) => std::thread::sleep(Duration::from_millis(25)),
            }
        })
        .map_err(Error::Io)?;
    Ok(Hub { stop, handle: Some(handle) })
}

// ---------------------------------------------------------------------------
// Link-set assembly
// ---------------------------------------------------------------------------

/// The thread handles and shared state behind one party's resilient
/// links (owned by `super::tcp::TcpPort`).
pub(crate) struct LinkSet {
    pub(crate) writers: Vec<JoinHandle<()>>,
    pub(crate) hub: Option<Hub>,
    shareds: Vec<(PartyId, Arc<Shared>)>,
}

impl LinkSet {
    /// Chaos/ops hook: sever every live connection of this party once
    /// (simulates a network cut; the links re-establish themselves).
    pub(crate) fn sever_all(&self) {
        for (_, sh) in &self.shareds {
            let g = sh.inner.lock().unwrap();
            if let Some(s) = g.stream.as_ref() {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }

    /// Chaos hook: simulate a process kill. Every connection drops with
    /// no goodbye, all recovery stops, and the durable journal (when
    /// configured) is left as the only trace — a relaunched endpoint
    /// restores from it and rejoins.
    pub(crate) fn kill_all(&self) {
        for (_, sh) in &self.shareds {
            let mut g = sh.inner.lock().unwrap();
            g.killed = true;
            drop_stream(&mut g);
            sh.cv.notify_all();
        }
    }
}

/// Build a `NetPort` whose peer connections are resilient links:
/// `streams[p]` is the established socket to party `p`, `redials[p]`
/// names the recovery role for that link, and `listener` (required when
/// any link is [`Redial::Accept`]) stays open behind the accept hub.
///
/// A link with a redial role but **no** initial socket starts down and
/// recovers through the normal relink path — this is how a relaunched
/// process rejoins after a crash, with its journal restored from
/// [`RelinkOpts::journal_dir`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn resilient_port(
    me: PartyId,
    names: &[&str],
    streams: Vec<Option<TcpStream>>,
    redials: Vec<Option<Redial>>,
    listener: Option<TcpListener>,
    opts: RelinkOpts,
    spec: LinkSpec,
    stats: Arc<NetStats>,
) -> Result<(NetPort, LinkSet)> {
    assert_eq!(streams.len(), redials.len());
    let chaos_fired = Arc::new(AtomicBool::new(false));
    let mut txs: HashMap<PartyId, mpsc::Sender<Msg>> = HashMap::new();
    let mut rxs: HashMap<PartyId, mpsc::Receiver<Msg>> = HashMap::new();
    let mut writers = Vec::new();
    let mut shareds: Vec<(PartyId, Arc<Shared>)> = Vec::new();
    let mut acceptors: Vec<(PartyId, Arc<Shared>)> = Vec::new();
    for (peer, (slot, redial)) in streams.into_iter().zip(redials).enumerate() {
        let Some(redial) = redial else {
            if slot.is_some() {
                return Err(Error::Net(format!(
                    "party {me}: no redial role for the link to peer {peer}"
                )));
            }
            continue;
        };
        if let Some(stream) = &slot {
            stream.set_nodelay(true).map_err(|e| Error::Net(format!("set_nodelay: {e}")))?;
            // the handshake may have left a read timeout installed; the
            // reader must block indefinitely (deadlock detection lives in
            // the port)
            stream
                .set_read_timeout(None)
                .map_err(|e| Error::Net(format!("clear read timeout: {e}")))?;
        }
        let (durable, restored) = match opts.journal_dir.as_deref() {
            Some(dir) => {
                let (d, r) = Durable::open(dir, me, peer, opts.token)?;
                (Some(d), r)
            }
            None => (None, Restored::default()),
        };
        let live = slot.is_some();
        let sh = Arc::new(Shared {
            me,
            peer,
            token: opts.token,
            reconnect_timeout: opts.reconnect_timeout,
            chaos_after: opts.chaos_kill_after,
            chaos_fired: chaos_fired.clone(),
            inner: Mutex::new(Inner {
                stream: slot,
                epoch: if live { 1 } else { 0 },
                journal: restored.journal,
                next_seq: restored.next_seq,
                delivered: restored.delivered,
                acked: restored.acked,
                last_ack_sent: restored.delivered,
                peer_bye: false,
                closed: false,
                bye_sent: false,
                replaying: None,
                frames_sent: 0,
                durable,
                killed: false,
            }),
            cv: Condvar::new(),
        });
        if matches!(redial, Redial::Accept) {
            acceptors.push((peer, sh.clone()));
        }
        let (inbox_tx, inbox_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel::<Msg>();
        let wh = std::thread::Builder::new()
            .name(format!("spnn-tx-{me}-{peer}"))
            .spawn({
                let sh = sh.clone();
                let redial = redial.clone();
                move || writer_loop(sh, out_rx, redial)
            })
            .map_err(Error::Io)?;
        // reader detaches; it exits on goodbye, close, or reconnect give-up
        let _detached = std::thread::Builder::new()
            .name(format!("spnn-rx-{me}-{peer}"))
            .spawn({
                let sh = sh.clone();
                move || reader_loop(sh, inbox_tx, redial)
            })
            .map_err(Error::Io)?;
        txs.insert(peer, out_tx);
        rxs.insert(peer, inbox_rx);
        writers.push(wh);
        shareds.push((peer, sh));
    }
    let hub = match listener {
        Some(l) if !acceptors.is_empty() => Some(spawn_hub(l, acceptors, me, opts.token)?),
        _ => None,
    };
    let port = NetPort::new(me, names[me], spec, txs, rxs, stats);
    Ok((port, LinkSet { writers, hub, shareds }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Payload;

    /// Two resilient endpoints over a real socket: A (id 0) accepts
    /// relinks on its listener, B (id 1) re-dials. Also returns the hub
    /// listener's address for stray-connection probes.
    fn pair(
        chaos_b: Option<u64>,
        timeout: Duration,
    ) -> (NetPort, LinkSet, NetPort, LinkSet, String) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let sb = TcpStream::connect(&addr).unwrap();
        let (sa, _) = listener.accept().unwrap();
        let stats_a = Arc::new(NetStats::new(&["A", "B"]));
        let stats_b = Arc::new(NetStats::new(&["A", "B"]));
        let (pa, la) = resilient_port(
            0,
            &["A", "B"],
            vec![None, Some(sa)],
            vec![None, Some(Redial::Accept)],
            Some(listener),
            RelinkOpts { token: 99, reconnect_timeout: timeout, ..Default::default() },
            LinkSpec::lan(),
            stats_a,
        )
        .unwrap();
        let (pb, lb) = resilient_port(
            1,
            &["A", "B"],
            vec![Some(sb), None],
            vec![Some(Redial::Dial(addr.clone())), None],
            None,
            RelinkOpts {
                token: 99,
                reconnect_timeout: timeout,
                chaos_kill_after: chaos_b,
                journal_dir: None,
            },
            LinkSpec::lan(),
            stats_b,
        )
        .unwrap();
        (pa, la, pb, lb, addr)
    }

    fn drain_n(port: &mut NetPort, from: PartyId, n: u64, label: &str) {
        for want in 0..n {
            let got = port.recv_u64s(from).unwrap_or_else(|e| panic!("{label} at {want}: {e}"));
            assert_eq!(got, vec![want], "{label}: out of order or lost");
        }
    }

    #[test]
    fn severed_links_replay_and_deliver_exactly_once_in_order() {
        let (mut pa, la, mut pb, lb, _addr) = pair(None, Duration::from_secs(20));
        pa.set_recv_timeout(Duration::from_secs(30));
        pb.set_recv_timeout(Duration::from_secs(30));
        // B -> A with two cuts initiated from either side of the wire
        let hb = std::thread::spawn(move || {
            for i in 0..120u64 {
                pb.send(0, Payload::U64s(vec![i])).unwrap();
                if i == 40 {
                    lb.sever_all(); // cut from the dialer side
                }
                if i == 80 {
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
            // A -> B leg afterwards, over whatever socket is now live
            for i in 0..40u64 {
                let got = pb.recv_u64s(0).unwrap();
                assert_eq!(got, vec![i]);
            }
            (pb, lb)
        });
        std::thread::sleep(Duration::from_millis(30));
        la.sever_all(); // cut from the acceptor side while B is sending
        drain_n(&mut pa, 1, 120, "A<-B");
        for i in 0..40u64 {
            pa.send(1, Payload::U64s(vec![i])).unwrap();
        }
        let (_pb, _lb) = hb.join().unwrap();
    }

    #[test]
    fn goodbye_shutdown_is_clean_and_final() {
        let (mut pa, _la, mut pb, lb, _addr) = pair(None, Duration::from_millis(600));
        pa.set_recv_timeout(Duration::from_secs(10));
        for i in 0..5u64 {
            pb.send(0, Payload::U64s(vec![i])).unwrap();
        }
        // orderly shutdown: outboxes close, writers say goodbye
        drop(pb);
        for wh in lb.writers {
            wh.join().unwrap();
        }
        drain_n(&mut pa, 1, 5, "A<-B");
        // after the goodbye the link must NOT reconnect: the next receive
        // reports a disconnect instead of hanging for the timeout window
        let err = pa.recv(1).unwrap_err();
        assert!(format!("{err}").contains("disconnected"), "{err}");
    }

    #[test]
    fn idle_links_prune_their_journal_via_standalone_acks() {
        // one-directional traffic: B streams, A never sends a data frame
        // back, so only the idle-tick FT_ACK can shrink B's journal
        let (mut pa, _la, mut pb, lb, _addr) = pair(None, Duration::from_secs(20));
        pa.set_recv_timeout(Duration::from_secs(10));
        for i in 0..50u64 {
            pb.send(0, Payload::U64s(vec![i])).unwrap();
        }
        drain_n(&mut pa, 1, 50, "A<-B");
        // a few idle ticks later the journal must be (close to) empty
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let len = lb.shareds[0].1.inner.lock().unwrap().journal.len();
            if len == 0 {
                break;
            }
            assert!(Instant::now() < deadline, "journal never pruned ({len} frames left)");
            std::thread::sleep(Duration::from_millis(50));
        }
        drop(pb);
    }

    #[test]
    fn acceptor_gives_up_when_nobody_redials() {
        // B is a bare socket that dies without a goodbye and never relinks
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let sb = TcpStream::connect(&addr).unwrap();
        let (sa, _) = listener.accept().unwrap();
        let stats = Arc::new(NetStats::new(&["A", "B"]));
        let (mut pa, _la) = resilient_port(
            0,
            &["A", "B"],
            vec![None, Some(sa)],
            vec![None, Some(Redial::Accept)],
            Some(listener),
            RelinkOpts {
                token: 1,
                reconnect_timeout: Duration::from_millis(300),
                ..Default::default()
            },
            LinkSpec::lan(),
            stats,
        )
        .unwrap();
        drop(sb); // FIN with no goodbye marker = dropped link
        pa.set_recv_timeout(Duration::from_secs(10));
        let t0 = Instant::now();
        let err = pa.recv(1).unwrap_err();
        assert!(format!("{err}").contains("disconnected"), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(8), "gave up too slowly");
    }

    #[test]
    fn chaos_kill_fires_once_and_recovers() {
        let (mut pa, _la, mut pb, lb, _addr) = pair(Some(10), Duration::from_secs(20));
        pa.set_recv_timeout(Duration::from_secs(30));
        pb.set_recv_timeout(Duration::from_secs(30));
        let hb = std::thread::spawn(move || {
            for i in 0..40u64 {
                pb.send(0, Payload::U64s(vec![i])).unwrap();
            }
            pb.recv_u64s(0).unwrap();
            (pb, lb)
        });
        drain_n(&mut pa, 1, 40, "A<-B under chaos");
        pa.send(1, Payload::U64s(vec![7])).unwrap();
        let (_pb, lb) = hb.join().unwrap();
        assert!(
            lb.shareds[0].1.chaos_fired.load(Ordering::SeqCst),
            "chaos kill never triggered"
        );
    }

    #[test]
    fn hub_rejects_stray_and_wrong_token_connections() {
        let (mut pa, _la, mut pb, _lb, addr) = pair(None, Duration::from_secs(20));
        pa.set_recv_timeout(Duration::from_secs(20));
        // wrong session token: named rejection
        let s = TcpStream::connect(&addr).unwrap();
        let mut w: &TcpStream = &s;
        wire::write_msg(&mut w, &ctl_msg(1, "spnn-relink v1 id=1 token=7 last=0".into()))
            .unwrap();
        let mut r: &TcpStream = &s;
        let reply = wire::read_msg(&mut r).unwrap().unwrap().payload.into_control().unwrap();
        assert!(reply.contains("spnn-err") && reply.contains("token"), "{reply}");
        // complete garbage: rejected without disturbing the session
        let s = TcpStream::connect(&addr).unwrap();
        let mut w: &TcpStream = &s;
        wire::write_msg(&mut w, &ctl_msg(9, "GET / HTTP/1.1".into())).unwrap();
        let mut r: &TcpStream = &s;
        let reply = wire::read_msg(&mut r).unwrap().unwrap().payload.into_control().unwrap();
        assert!(reply.contains("spnn-err"), "{reply}");
        // regular traffic keeps flowing around the strays
        pb.send(0, Payload::U64s(vec![1])).unwrap();
        assert_eq!(pa.recv_u64s(1).unwrap(), vec![1]);
    }

    fn jnl_test_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("spnn-jnl-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    /// Satellite of the crash-restart story: a killed endpoint (no
    /// goodbye, threads dead, only the on-disk journal surviving) is
    /// relaunched from that journal and rejoins the same session —
    /// outage-window frames replay to it exactly once, and its own
    /// sequence numbering continues where the dead process stopped.
    #[test]
    fn killed_endpoint_restores_journal_and_rejoins_exactly_once() {
        let dir = jnl_test_dir("kill");
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let sb = TcpStream::connect(&addr).unwrap();
        let (sa, _) = listener.accept().unwrap();
        let jopts = || RelinkOpts {
            token: 5,
            reconnect_timeout: Duration::from_secs(20),
            chaos_kill_after: None,
            journal_dir: Some(dir.to_string_lossy().into_owned()),
        };
        let (mut pa, _la) = resilient_port(
            0,
            &["A", "B"],
            vec![None, Some(sa)],
            vec![None, Some(Redial::Accept)],
            Some(listener),
            RelinkOpts {
                token: 5,
                reconnect_timeout: Duration::from_secs(20),
                ..Default::default()
            },
            LinkSpec::lan(),
            Arc::new(NetStats::new(&["A", "B"])),
        )
        .unwrap();
        let (mut pb, lb) = resilient_port(
            1,
            &["A", "B"],
            vec![Some(sb), None],
            vec![Some(Redial::Dial(addr.clone())), None],
            None,
            jopts(),
            LinkSpec::lan(),
            Arc::new(NetStats::new(&["A", "B"])),
        )
        .unwrap();
        pa.set_recv_timeout(Duration::from_secs(30));
        pb.set_recv_timeout(Duration::from_secs(30));

        // settle two-way traffic so the journal holds real watermarks
        for i in 0..50u64 {
            pb.send(0, Payload::U64s(vec![i])).unwrap();
        }
        drain_n(&mut pa, 1, 50, "A<-B before kill");
        for i in 0..20u64 {
            pa.send(1, Payload::U64s(vec![i])).unwrap();
        }
        drain_n(&mut pb, 0, 20, "B<-A before kill");

        // kill B: no goodbye, no recovery — only the journal remains
        lb.kill_all();
        drop(pb);
        for wh in lb.writers {
            wh.join().unwrap();
        }

        // A keeps sending into the outage; its journal holds the tail
        for i in 20..30u64 {
            pa.send(1, Payload::U64s(vec![i])).unwrap();
        }

        // relaunch B from the journal: no initial socket — the dial-side
        // reader re-establishes the link with the restored watermarks
        let (mut pb2, _lb2) = resilient_port(
            1,
            &["A", "B"],
            vec![None, None],
            vec![Some(Redial::Dial(addr)), None],
            None,
            jopts(),
            LinkSpec::lan(),
            Arc::new(NetStats::new(&["A", "B"])),
        )
        .unwrap();
        pb2.set_recv_timeout(Duration::from_secs(30));

        // the outage-window frames arrive exactly once, in order; the
        // pre-kill frames (delivered watermark 20) must NOT reappear
        for want in 20..30u64 {
            assert_eq!(pb2.recv_u64s(0).unwrap(), vec![want], "lost/duplicated at {want}");
        }
        // and the relaunched sender continues its sequence seamlessly
        // (a next_seq reset to 1 would be dropped by A as duplicates)
        for i in 50..70u64 {
            pb2.send(0, Payload::U64s(vec![i])).unwrap();
        }
        for want in 50..70u64 {
            assert_eq!(pa.recv_u64s(1).unwrap(), vec![want]);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn journal_restore_truncates_torn_tails_and_discards_stale_tokens() {
        let dir = jnl_test_dir("torn");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("link-1-0.jnl");
        // a valid journal image: two frames, watermarks, then a record
        // torn mid-append by a crash
        let mut buf = Vec::new();
        buf.extend_from_slice(JNL_MAGIC);
        buf.extend_from_slice(&7u64.to_le_bytes());
        buf.extend_from_slice(&jnl_frame_record(1, b"alpha"));
        buf.extend_from_slice(&jnl_frame_record(2, b"beta"));
        buf.extend_from_slice(&jnl_record(JREC_ACKED, &1u64.to_le_bytes()));
        buf.extend_from_slice(&jnl_record(JREC_DELIVERED, &9u64.to_le_bytes()));
        let valid_len = buf.len();
        buf.push(JREC_FRAME);
        buf.extend_from_slice(&[3, 0, 0]);
        std::fs::write(&path, &buf).unwrap();

        let (_d, r) = Durable::open(dir.to_str().unwrap(), 1, 0, 7).unwrap();
        assert_eq!((r.acked, r.delivered, r.next_seq), (1, 9, 3));
        let tail: Vec<u64> = r.journal.iter().map(|(s, _)| *s).collect();
        assert_eq!(tail, vec![2], "acked frames must not be replayed");
        assert_eq!(r.journal[0].1, b"beta");
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            valid_len as u64,
            "torn tail not truncated"
        );

        // a different session token means a different run: start fresh
        let (_d, r) = Durable::open(dir.to_str().unwrap(), 1, 0, 8).unwrap();
        assert_eq!((r.next_seq, r.delivered, r.acked, r.journal.len()), (1, 0, 0, 0));
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 16, "stale journal kept");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn journal_compaction_rewrites_to_live_state() {
        let dir = jnl_test_dir("compact");
        let (d, _r) = Durable::open(dir.to_str().unwrap(), 0, 1, 3).unwrap();
        let mut g = Inner {
            stream: None,
            epoch: 0,
            journal: VecDeque::new(),
            next_seq: 1,
            delivered: 0,
            acked: 0,
            last_ack_sent: 0,
            peer_bye: false,
            closed: false,
            bye_sent: false,
            replaying: None,
            frames_sent: 0,
            durable: Some(d),
            killed: false,
        };
        // one acked frame, one live one, a delivery — then force a rewrite
        g.journal.push_back((1, b"one".to_vec()));
        g.next_seq = 2;
        if let Some(d) = g.durable.as_mut() {
            d.frame(1, b"one");
        }
        prune_journal(&mut g, 1);
        g.delivered = 4;
        g.journal.push_back((2, b"two".to_vec()));
        g.next_seq = 3;
        if let Some(d) = g.durable.as_mut() {
            d.watermark(JREC_DELIVERED, 4);
            d.frame(2, b"two");
            d.appended = JNL_COMPACT_BYTES; // force the size trigger
        }
        jnl_compact(&mut g, 3);
        let path = dir.join("link-0-1.jnl");
        let len = std::fs::metadata(&path).unwrap().len();
        assert!(len < 128, "compaction did not shrink the file ({len} bytes)");
        // a restore from the compacted file reproduces the live state
        let (_d, r) = Durable::open(dir.to_str().unwrap(), 0, 1, 3).unwrap();
        assert_eq!((r.next_seq, r.delivered, r.acked), (3, 4, 1));
        assert_eq!(r.journal.len(), 1);
        assert_eq!(r.journal[0], (2, b"two".to_vec()));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
