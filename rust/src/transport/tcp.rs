//! Real-socket backend: `std::net::TcpStream` carrying the [`wire`]
//! framing, presented to the protocols through the same session engine
//! ([`NetPort`]) the simulator uses.
//!
//! Layering: every peer connection gets one **reader thread** (decodes
//! frames into the port's per-peer `mpsc` inbox — exactly where the
//! simulator's in-process channel would deliver) and one **writer thread**
//! (drains an unbounded outbox queue into the socket). Sends therefore
//! never block the protocol thread — the same non-blocking-send semantics
//! as netsim — which rules out the classic both-sides-blocked-in-`write`
//! TCP deadlock regardless of message size vs kernel buffer size.
//!
//! Two link flavors share this layout:
//!
//! * **simple links** (`spawn_io`, used by the in-process
//!   [`loopback_mesh`] and the UDS pair mesh in [`super::uds`]): the
//!   socket *is* the link — a drop kills the run. Shutdown is
//!   flush-safe: dropping the port closes the outbox queues, the writers
//!   drain whatever is queued, send a FIN and exit; the peer's reader
//!   sees a clean EOF at a frame boundary.
//! * **resilient links** ([`super::relink`], used by the multi-process
//!   runner behind [`TcpPort`]): every data frame is journaled and
//!   sequence-numbered, a dropped `TcpStream` is re-dialed and the
//!   unacked tail replayed, so training survives mid-epoch connection
//!   kills bit-identically.
//!
//! [`TcpPort::shutdown`] joins the writer threads so a process can exit
//! without racing its own final flush.

use std::collections::HashMap;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::relink::LinkSet;
use super::wire;
use super::Channel;
use crate::netsim::{LinkSpec, Msg, NetPort, NetStats, PartyId, Payload, Phase};
use crate::{Error, Result};

/// How long `connect_retry` keeps retrying a refused connection —
/// covers peers whose listener is not bound yet (process startup races).
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// The stream operations the simple-link I/O threads need, so one
/// implementation serves both `TcpStream` and `UnixStream`.
pub(crate) trait Duplex: std::io::Read + std::io::Write + Send + Sized + 'static {
    /// Second handle on the same socket (reader half).
    fn try_clone_stream(&self) -> std::io::Result<Self>;
    /// Half-close the write direction (FIN after the final flush).
    fn shutdown_write(&self);
    /// Remove any read timeout a handshake may have left installed.
    fn clear_read_timeout(&self) -> std::io::Result<()>;
    /// Disable Nagle where the transport has it (no-op otherwise).
    fn set_nodelay_opt(&self);
}

impl Duplex for TcpStream {
    fn try_clone_stream(&self) -> std::io::Result<Self> {
        self.try_clone()
    }

    fn shutdown_write(&self) {
        let _ = TcpStream::shutdown(self, Shutdown::Write);
    }

    fn clear_read_timeout(&self) -> std::io::Result<()> {
        self.set_read_timeout(None)
    }

    fn set_nodelay_opt(&self) {
        let _ = self.set_nodelay(true);
    }
}

/// Wire up one duplex peer connection as a **simple link**: a reader
/// thread feeding `inbox_tx` and a writer thread draining the returned
/// outbox sender. Returns the outbox sender (to place in the port's tx
/// map) and the writer's join handle (join it to guarantee the final
/// flush).
pub(crate) fn spawn_io<S: Duplex>(
    stream: S,
    me: PartyId,
    peer: PartyId,
    inbox_tx: mpsc::Sender<Msg>,
) -> Result<(mpsc::Sender<Msg>, JoinHandle<()>)> {
    stream.set_nodelay_opt();
    // the handshake may have left a read timeout installed; the reader
    // thread must block indefinitely (deadlock detection lives in the port)
    stream
        .clear_read_timeout()
        .map_err(|e| Error::Net(format!("clear read timeout: {e}")))?;
    let mut rd = stream.try_clone_stream().map_err(|e| Error::Net(format!("clone stream: {e}")))?;
    let mut wr = stream;

    let reader = move || loop {
        match wire::read_msg(&mut rd) {
            Ok(Some(msg)) => {
                if msg.from != peer {
                    eprintln!(
                        "spnn-tcp: party {me}: frame from {} on the link to peer {peer} — \
                         dropping connection",
                        msg.from
                    );
                    break;
                }
                if inbox_tx.send(msg).is_err() {
                    break; // port dropped — nobody is listening anymore
                }
            }
            Ok(None) => break, // clean FIN from the peer
            Err(_) => break,   // reset/short read: surfaced as a port disconnect
        }
    };
    // reader detaches; it exits on EOF or port drop
    let _detached = std::thread::Builder::new()
        .name(format!("spnn-rx-{me}-{peer}"))
        .spawn(reader)
        .map_err(Error::Io)?;

    let (out_tx, out_rx) = mpsc::channel::<Msg>();
    let writer = move || {
        while let Ok(msg) = out_rx.recv() {
            if wire::write_msg(&mut wr, &msg).is_err() {
                break;
            }
        }
        wr.shutdown_write();
    };
    let wh = std::thread::Builder::new()
        .name(format!("spnn-tx-{me}-{peer}"))
        .spawn(writer)
        .map_err(Error::Io)?;
    Ok((out_tx, wh))
}

/// A socket-backed party endpoint: the shared session engine over
/// resilient TCP links ([`super::relink`]), plus the I/O-thread
/// lifecycle. The real-socket [`Channel`] backend the multi-process
/// runner deploys.
pub struct TcpPort {
    port: Option<NetPort>,
    links: Option<LinkSet>,
    stats: Arc<NetStats>,
}

impl TcpPort {
    pub(crate) fn new(port: NetPort, links: LinkSet, stats: Arc<NetStats>) -> Self {
        TcpPort { port: Some(port), links: Some(links), stats }
    }

    /// This process's sender-side traffic counters.
    pub fn stats(&self) -> &Arc<NetStats> {
        &self.stats
    }

    /// Chaos/ops hook: sever every live peer connection once (simulating
    /// a network cut). The resilient links re-establish themselves and
    /// replay unacked traffic; training continues bit-identically.
    pub fn sever_links(&self) {
        if let Some(links) = &self.links {
            links.sever_all();
        }
    }

    fn port(&mut self) -> &mut NetPort {
        self.port.as_mut().expect("TcpPort used after shutdown")
    }

    /// Flush-and-close: drop the outbox queues (writers drain every queued
    /// frame, say goodbye, FIN, exit), join the writers so queued messages
    /// are on the wire before the caller proceeds to exit, then stop the
    /// relink accept hub.
    pub fn shutdown(mut self) {
        let _sp = crate::obs::span("transport_flush_seconds");
        self.port.take(); // drops the tx map -> writers drain + goodbye
        if let Some(mut links) = self.links.take() {
            for wh in links.writers.drain(..) {
                let _ = wh.join();
            }
            if let Some(mut hub) = links.hub.take() {
                hub.shutdown();
            }
        }
    }
}

impl Channel for TcpPort {
    fn id(&self) -> PartyId {
        self.port.as_ref().expect("TcpPort used after shutdown").id
    }

    fn name(&self) -> &str {
        &self.port.as_ref().expect("TcpPort used after shutdown").name
    }

    fn spec(&self) -> LinkSpec {
        self.port.as_ref().expect("TcpPort used after shutdown").spec()
    }

    fn now(&mut self) -> f64 {
        self.port().now()
    }

    fn advance(&mut self, dt: f64) {
        self.port().advance(dt)
    }

    fn reset_clock(&mut self) {
        self.port().reset_clock()
    }

    fn set_stage(&mut self, stage: &'static str) {
        self.port().set_stage(stage)
    }

    fn set_recv_timeout(&mut self, d: Duration) {
        self.port().set_recv_timeout(d)
    }

    fn send_tagged_phase(
        &mut self,
        to: PartyId,
        tag: u64,
        payload: Payload,
        phase: Phase,
    ) -> Result<()> {
        self.port().send_tagged_phase(to, tag, payload, phase)
    }

    fn recv_any_tag(&mut self, from: PartyId) -> Result<(u64, Payload)> {
        self.port().recv_any_tag(from)
    }

    fn recv_tagged(&mut self, from: PartyId, tag: u64) -> Result<Payload> {
        self.port().recv_tagged(from, tag)
    }

    fn try_recv_tagged(&mut self, from: PartyId, tag: u64) -> Result<Option<Payload>> {
        self.port().try_recv_tagged(from, tag)
    }
}

/// Full mesh over loopback TCP: one listener per party (ephemeral ports),
/// one socket pair per party pair, shared sender-side stats — a drop-in
/// replacement for [`crate::netsim::full_mesh`] that pushes every message
/// through real kernel sockets and the wire codec.
///
/// This is the `TrainConfig::transport = Tcp` backend: the transcript-
/// parity tests run the trainers on it to prove the wire layer is
/// bit-exact against the simulator. Links are **simple** (not resilient):
/// all parties live in one process, so a socket can only die with the
/// process itself.
pub fn loopback_mesh(names: &[&str], spec: LinkSpec) -> Result<(Vec<NetPort>, Arc<NetStats>)> {
    let n = names.len();
    let mut listeners = Vec::with_capacity(n);
    for _ in 0..n {
        listeners
            .push(TcpListener::bind("127.0.0.1:0").map_err(|e| Error::Net(format!("bind: {e}")))?);
    }
    let addrs: Vec<std::net::SocketAddr> = listeners
        .iter()
        .map(|l| l.local_addr())
        .collect::<std::io::Result<_>>()
        .map_err(|e| Error::Net(format!("local_addr: {e}")))?;
    assemble_mesh(names, spec, |i, j| {
        // j dials i; the kernel backlog completes the connection, so a
        // sequential connect-then-accept cannot deadlock
        let sj = TcpStream::connect(addrs[i])
            .map_err(|e| Error::Net(format!("connect {i}<-{j}: {e}")))?;
        let (si, _) = listeners[i]
            .accept()
            .map_err(|e| Error::Net(format!("accept {i}<-{j}: {e}")))?;
        Ok((si, sj))
    })
}

/// Shared mesh-assembly loop for the simple-link backends: for every
/// party pair `(i, j)` with `i < j`, `connect(i, j)` yields the
/// connected `(i-side, j-side)` stream pair, and each side gets its
/// reader/writer threads and per-peer channels.
pub(crate) fn assemble_mesh<S: Duplex>(
    names: &[&str],
    spec: LinkSpec,
    mut connect: impl FnMut(usize, usize) -> Result<(S, S)>,
) -> Result<(Vec<NetPort>, Arc<NetStats>)> {
    let n = names.len();
    let stats = Arc::new(NetStats::new(names));
    // per-party channel maps under construction
    let mut txs: Vec<HashMap<PartyId, mpsc::Sender<Msg>>> =
        (0..n).map(|_| HashMap::new()).collect();
    let mut rxs: Vec<HashMap<PartyId, mpsc::Receiver<Msg>>> =
        (0..n).map(|_| HashMap::new()).collect();

    for i in 0..n {
        for j in (i + 1)..n {
            let (si, sj) = connect(i, j)?;
            let (inbox_tx_i, inbox_rx_i) = mpsc::channel();
            let (out_tx_i, _wh_i) = spawn_io(si, i, j, inbox_tx_i)?;
            txs[i].insert(j, out_tx_i);
            rxs[i].insert(j, inbox_rx_i);
            let (inbox_tx_j, inbox_rx_j) = mpsc::channel();
            let (out_tx_j, _wh_j) = spawn_io(sj, j, i, inbox_tx_j)?;
            txs[j].insert(i, out_tx_j);
            rxs[j].insert(i, inbox_rx_j);
        }
    }
    let ports = txs
        .into_iter()
        .zip(rxs)
        .enumerate()
        .map(|(id, (tx, rx))| NetPort::new(id, names[id], spec, tx, rx, stats.clone()))
        .collect();
    Ok((ports, stats))
}

/// `TcpStream::connect` with retry/backoff until `timeout` — rendezvous
/// peers may not have bound their listener yet, and a re-dialed peer may
/// take a moment to notice its side of an outage.
pub(crate) fn connect_retry(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let deadline = std::time::Instant::now() + timeout;
    let mut wait = Duration::from_millis(20);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if std::time::Instant::now() + wait >= deadline {
                    return Err(Error::Net(format!("connect {addr}: {e} (gave up retrying)")));
                }
                std::thread::sleep(wait);
                wait = (wait * 2).min(Duration::from_millis(500));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_pair_reorders_tags_like_netsim() {
        let (mut ports, stats) = loopback_mesh(&["A", "B"], LinkSpec::lan()).unwrap();
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        let h = std::thread::spawn(move || {
            a.send_tagged(1, 5, Payload::U64s(vec![5, 5])).unwrap();
            a.send_tagged(1, 6, Payload::F32s(vec![6.5])).unwrap();
            a.send_tagged(1, 7, Payload::Control("seven".into())).unwrap();
            // keep the port alive until B confirms, then reply
            let done = b_ack(&mut a);
            a.send(1, Payload::Seed([9; 32])).unwrap();
            done
        });
        fn b_ack(a: &mut NetPort) -> u64 {
            a.recv_tagged(1, 99).unwrap().into_u64s().unwrap()[0]
        }
        b.set_recv_timeout(Duration::from_secs(20));
        // consume out of order across a real socket
        assert_eq!(b.recv_tagged(0, 7).unwrap().into_control().unwrap(), "seven");
        assert_eq!(b.recv_tagged(0, 6).unwrap().into_f32s().unwrap(), vec![6.5]);
        assert_eq!(b.recv_tagged(0, 5).unwrap().into_u64s().unwrap(), vec![5, 5]);
        b.send_tagged(0, 99, Payload::U64s(vec![1])).unwrap();
        assert_eq!(b.recv(0).unwrap().into_seed().unwrap(), [9; 32]);
        assert_eq!(h.join().unwrap(), 1);
        // sender-side byte accounting matches the payload model
        let want = Payload::U64s(vec![5, 5]).total_bytes()
            + Payload::F32s(vec![6.5]).total_bytes()
            + Payload::Control("seven".into()).total_bytes()
            + Payload::Seed([9; 32]).total_bytes();
        assert_eq!(stats.bytes_sent_by(0, Phase::Online), want);
    }

    #[test]
    fn dropped_peer_surfaces_as_disconnect_not_hang() {
        let (mut ports, _) = loopback_mesh(&["A", "B"], LinkSpec::lan()).unwrap();
        let b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        drop(b); // FIN both directions
        a.set_recv_timeout(Duration::from_secs(5));
        let err = a.recv(1).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("disconnected"), "{msg}");
    }

    #[test]
    fn mid_frame_close_is_a_short_read() {
        // raw socket: write half a frame, then close
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            use std::io::Write;
            let mut s = TcpStream::connect(addr).unwrap();
            let msg = Msg {
                from: 0,
                tag: 1,
                payload: Payload::U64s(vec![1, 2, 3]),
                depart: 0.0,
                phase: Phase::Online,
            };
            let frame = wire::encode_msg(&msg);
            s.write_all(&frame[..frame.len() / 2]).unwrap();
            // drop: FIN mid-frame
        });
        let (mut s, _) = listener.accept().unwrap();
        h.join().unwrap();
        let err = wire::read_msg(&mut s).unwrap_err();
        assert!(format!("{err}").contains("short read"), "{err}");
    }

    #[test]
    fn connect_retry_waits_for_late_listener() {
        // bind, learn the port, close, rebind after a delay — the dialer
        // must ride out the refused window
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        drop(listener);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            let l = TcpListener::bind(addr).unwrap();
            let _ = l.accept();
        });
        let got = connect_retry(&addr.to_string(), Duration::from_secs(10));
        // the exact port may be racily taken by another process; only
        // assert we did not give up instantly when it worked
        if got.is_ok() {
            h.join().unwrap();
        } else {
            let _ = h.join();
        }
    }

    #[test]
    fn three_party_loopback_mesh_routes_all_pairs() {
        let (ports, _) = loopback_mesh(&["A", "B", "C"], LinkSpec::lan()).unwrap();
        let mut it = ports.into_iter();
        let mut a = it.next().unwrap();
        let mut b = it.next().unwrap();
        let mut c = it.next().unwrap();
        let hb = std::thread::spawn(move || {
            let v = b.recv_u64s(0).unwrap();
            b.send(2, Payload::U64s(vec![v[0] + 1])).unwrap();
            b.recv_u64s(2).unwrap()
        });
        let hc = std::thread::spawn(move || {
            let v = c.recv_u64s(1).unwrap();
            c.send(0, Payload::U64s(vec![v[0] + 1])).unwrap();
            c.send(1, Payload::U64s(vec![99])).unwrap();
        });
        a.send(1, Payload::U64s(vec![10])).unwrap();
        assert_eq!(a.recv_u64s(2).unwrap(), vec![12]);
        assert_eq!(hb.join().unwrap(), vec![99]);
        hc.join().unwrap();
    }
}
