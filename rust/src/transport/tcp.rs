//! Real-socket backend: `std::net::TcpStream` carrying the [`wire`]
//! framing, presented to the protocols through the same session engine
//! ([`NetPort`]) the simulator uses.
//!
//! Layering: every peer connection gets one **reader thread** (decodes
//! frames into the port's per-peer `mpsc` inbox — exactly where the
//! simulator's in-process channel would deliver) and one **writer thread**
//! (drains an unbounded outbox queue into the socket). Sends therefore
//! never block the protocol thread — the same non-blocking-send semantics
//! as netsim — which rules out the classic both-sides-blocked-in-`write`
//! TCP deadlock regardless of message size vs kernel buffer size.
//!
//! Shutdown is flush-safe: dropping the port closes the outbox queues, the
//! writers drain whatever is queued, send a FIN (`shutdown(Write)`) and
//! exit; the peer's reader sees a clean EOF at a frame boundary. A party
//! that still expects traffic from a departed peer gets the port's
//! descriptive disconnect error instead of a hang. [`TcpPort::shutdown`]
//! additionally joins the writer threads so a process can exit without
//! racing its own final flush.

use std::collections::HashMap;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::wire;
use super::Channel;
use crate::netsim::{LinkSpec, Msg, NetPort, NetStats, PartyId, Payload, Phase};
use crate::{Error, Result};

/// How long [`connect_retry`] keeps retrying a refused connection —
/// covers peers whose listener is not bound yet (process startup races).
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// Wire up one duplex peer connection: a reader thread feeding `inbox_tx`
/// and a writer thread draining the returned outbox sender. Returns the
/// outbox sender (to place in the port's tx map) and the writer's join
/// handle (join it to guarantee the final flush).
pub(crate) fn spawn_io(
    stream: TcpStream,
    me: PartyId,
    peer: PartyId,
    inbox_tx: mpsc::Sender<Msg>,
) -> Result<(mpsc::Sender<Msg>, JoinHandle<()>)> {
    stream.set_nodelay(true).map_err(|e| Error::Net(format!("set_nodelay: {e}")))?;
    // the handshake may have left a read timeout installed; the reader
    // thread must block indefinitely (deadlock detection lives in the port)
    stream
        .set_read_timeout(None)
        .map_err(|e| Error::Net(format!("clear read timeout: {e}")))?;
    let mut rd = stream.try_clone().map_err(|e| Error::Net(format!("clone stream: {e}")))?;
    let mut wr = stream;

    let reader = move || loop {
        match wire::read_msg(&mut rd) {
            Ok(Some(msg)) => {
                if msg.from != peer {
                    eprintln!(
                        "spnn-tcp: party {me}: frame from {} on the link to peer {peer} — \
                         dropping connection",
                        msg.from
                    );
                    break;
                }
                if inbox_tx.send(msg).is_err() {
                    break; // port dropped — nobody is listening anymore
                }
            }
            Ok(None) => break, // clean FIN from the peer
            Err(_) => break,   // reset/short read: surfaced as a port disconnect
        }
    };
    // reader detaches; it exits on EOF or port drop
    let _detached = std::thread::Builder::new()
        .name(format!("spnn-rx-{me}-{peer}"))
        .spawn(reader)
        .map_err(Error::Io)?;

    let (out_tx, out_rx) = mpsc::channel::<Msg>();
    let writer = move || {
        while let Ok(msg) = out_rx.recv() {
            if wire::write_msg(&mut wr, &msg).is_err() {
                break;
            }
        }
        let _ = wr.shutdown(Shutdown::Write);
    };
    let wh = std::thread::Builder::new()
        .name(format!("spnn-tx-{me}-{peer}"))
        .spawn(writer)
        .map_err(Error::Io)?;
    Ok((out_tx, wh))
}

/// Build a [`NetPort`] (plus writer handles) from one established stream
/// per peer (`streams[p]` = connection to party `p`, `None` for self and
/// absent parties).
pub(crate) fn port_from_streams(
    me: PartyId,
    names: &[&str],
    streams: Vec<Option<TcpStream>>,
    spec: LinkSpec,
    stats: Arc<NetStats>,
) -> Result<(NetPort, Vec<JoinHandle<()>>)> {
    let mut txs: HashMap<PartyId, mpsc::Sender<Msg>> = HashMap::new();
    let mut rxs: HashMap<PartyId, mpsc::Receiver<Msg>> = HashMap::new();
    let mut writers = Vec::new();
    for (peer, slot) in streams.into_iter().enumerate() {
        let Some(stream) = slot else { continue };
        let (inbox_tx, inbox_rx) = mpsc::channel();
        let (out_tx, wh) = spawn_io(stream, me, peer, inbox_tx)?;
        txs.insert(peer, out_tx);
        rxs.insert(peer, inbox_rx);
        writers.push(wh);
    }
    Ok((NetPort::new(me, names[me], spec, txs, rxs, stats), writers))
}

/// A socket-backed party endpoint: the shared session engine over TCP
/// connections, plus the I/O-thread lifecycle. The second [`Channel`]
/// backend next to the simulator's [`NetPort`].
pub struct TcpPort {
    port: Option<NetPort>,
    writers: Vec<JoinHandle<()>>,
    stats: Arc<NetStats>,
}

impl TcpPort {
    pub(crate) fn new(port: NetPort, writers: Vec<JoinHandle<()>>, stats: Arc<NetStats>) -> Self {
        TcpPort { port: Some(port), writers, stats }
    }

    /// This process's sender-side traffic counters.
    pub fn stats(&self) -> &Arc<NetStats> {
        &self.stats
    }

    fn port(&mut self) -> &mut NetPort {
        self.port.as_mut().expect("TcpPort used after shutdown")
    }

    /// Flush-and-close: drop the outbox queues (writers drain every queued
    /// frame, FIN, exit) and join the writers, so queued messages are on
    /// the wire before the caller proceeds to exit.
    pub fn shutdown(mut self) {
        self.port.take(); // drops the tx map -> writers drain + FIN
        for wh in self.writers.drain(..) {
            let _ = wh.join();
        }
    }
}

impl Channel for TcpPort {
    fn id(&self) -> PartyId {
        self.port.as_ref().expect("TcpPort used after shutdown").id
    }

    fn name(&self) -> &str {
        &self.port.as_ref().expect("TcpPort used after shutdown").name
    }

    fn spec(&self) -> LinkSpec {
        self.port.as_ref().expect("TcpPort used after shutdown").spec()
    }

    fn now(&mut self) -> f64 {
        self.port().now()
    }

    fn advance(&mut self, dt: f64) {
        self.port().advance(dt)
    }

    fn reset_clock(&mut self) {
        self.port().reset_clock()
    }

    fn set_stage(&mut self, stage: &'static str) {
        self.port().set_stage(stage)
    }

    fn set_recv_timeout(&mut self, d: Duration) {
        self.port().set_recv_timeout(d)
    }

    fn send_tagged_phase(
        &mut self,
        to: PartyId,
        tag: u64,
        payload: Payload,
        phase: Phase,
    ) -> Result<()> {
        self.port().send_tagged_phase(to, tag, payload, phase)
    }

    fn recv_any_tag(&mut self, from: PartyId) -> Result<(u64, Payload)> {
        self.port().recv_any_tag(from)
    }

    fn recv_tagged(&mut self, from: PartyId, tag: u64) -> Result<Payload> {
        self.port().recv_tagged(from, tag)
    }

    fn try_recv_tagged(&mut self, from: PartyId, tag: u64) -> Result<Option<Payload>> {
        self.port().try_recv_tagged(from, tag)
    }
}

/// Full mesh over loopback TCP: one listener per party (ephemeral ports),
/// one socket pair per party pair, shared sender-side stats — a drop-in
/// replacement for [`crate::netsim::full_mesh`] that pushes every message
/// through real kernel sockets and the wire codec.
///
/// This is the `TrainConfig::transport = Tcp` backend: the transcript-
/// parity tests run the trainers on it to prove the wire layer is
/// bit-exact against the simulator.
pub fn loopback_mesh(names: &[&str], spec: LinkSpec) -> Result<(Vec<NetPort>, Arc<NetStats>)> {
    let n = names.len();
    let stats = Arc::new(NetStats::new(names));
    let mut listeners = Vec::with_capacity(n);
    for _ in 0..n {
        listeners
            .push(TcpListener::bind("127.0.0.1:0").map_err(|e| Error::Net(format!("bind: {e}")))?);
    }
    let addrs: Vec<std::net::SocketAddr> = listeners
        .iter()
        .map(|l| l.local_addr())
        .collect::<std::io::Result<_>>()
        .map_err(|e| Error::Net(format!("local_addr: {e}")))?;

    // per-party channel maps under construction
    let mut txs: Vec<HashMap<PartyId, mpsc::Sender<Msg>>> =
        (0..n).map(|_| HashMap::new()).collect();
    let mut rxs: Vec<HashMap<PartyId, mpsc::Receiver<Msg>>> =
        (0..n).map(|_| HashMap::new()).collect();

    for i in 0..n {
        for j in (i + 1)..n {
            // j dials i; the kernel backlog completes the connection, so a
            // sequential connect-then-accept cannot deadlock
            let sj = TcpStream::connect(addrs[i])
                .map_err(|e| Error::Net(format!("connect {i}<-{j}: {e}")))?;
            let (si, _) = listeners[i]
                .accept()
                .map_err(|e| Error::Net(format!("accept {i}<-{j}: {e}")))?;
            let (inbox_tx_i, inbox_rx_i) = mpsc::channel();
            let (out_tx_i, _wh_i) = spawn_io(si, i, j, inbox_tx_i)?;
            txs[i].insert(j, out_tx_i);
            rxs[i].insert(j, inbox_rx_i);
            let (inbox_tx_j, inbox_rx_j) = mpsc::channel();
            let (out_tx_j, _wh_j) = spawn_io(sj, j, i, inbox_tx_j)?;
            txs[j].insert(i, out_tx_j);
            rxs[j].insert(i, inbox_rx_j);
        }
    }
    let ports = txs
        .into_iter()
        .zip(rxs)
        .enumerate()
        .map(|(id, (tx, rx))| NetPort::new(id, names[id], spec, tx, rx, stats.clone()))
        .collect();
    Ok((ports, stats))
}

/// `TcpStream::connect` with retry/backoff until `timeout` — rendezvous
/// peers may not have bound their listener yet.
pub(crate) fn connect_retry(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let deadline = std::time::Instant::now() + timeout;
    let mut wait = Duration::from_millis(20);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if std::time::Instant::now() + wait >= deadline {
                    return Err(Error::Net(format!("connect {addr}: {e} (gave up retrying)")));
                }
                std::thread::sleep(wait);
                wait = (wait * 2).min(Duration::from_millis(500));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_pair_reorders_tags_like_netsim() {
        let (mut ports, stats) = loopback_mesh(&["A", "B"], LinkSpec::lan()).unwrap();
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        let h = std::thread::spawn(move || {
            a.send_tagged(1, 5, Payload::U64s(vec![5, 5])).unwrap();
            a.send_tagged(1, 6, Payload::F32s(vec![6.5])).unwrap();
            a.send_tagged(1, 7, Payload::Control("seven".into())).unwrap();
            // keep the port alive until B confirms, then reply
            let done = b_ack(&mut a);
            a.send(1, Payload::Seed([9; 32])).unwrap();
            done
        });
        fn b_ack(a: &mut NetPort) -> u64 {
            a.recv_tagged(1, 99).unwrap().into_u64s().unwrap()[0]
        }
        b.set_recv_timeout(Duration::from_secs(20));
        // consume out of order across a real socket
        assert_eq!(b.recv_tagged(0, 7).unwrap().into_control().unwrap(), "seven");
        assert_eq!(b.recv_tagged(0, 6).unwrap().into_f32s().unwrap(), vec![6.5]);
        assert_eq!(b.recv_tagged(0, 5).unwrap().into_u64s().unwrap(), vec![5, 5]);
        b.send_tagged(0, 99, Payload::U64s(vec![1])).unwrap();
        assert_eq!(b.recv(0).unwrap().into_seed().unwrap(), [9; 32]);
        assert_eq!(h.join().unwrap(), 1);
        // sender-side byte accounting matches the payload model
        let want = Payload::U64s(vec![5, 5]).total_bytes()
            + Payload::F32s(vec![6.5]).total_bytes()
            + Payload::Control("seven".into()).total_bytes()
            + Payload::Seed([9; 32]).total_bytes();
        assert_eq!(stats.bytes_sent_by(0, Phase::Online), want);
    }

    #[test]
    fn dropped_peer_surfaces_as_disconnect_not_hang() {
        let (mut ports, _) = loopback_mesh(&["A", "B"], LinkSpec::lan()).unwrap();
        let b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        drop(b); // FIN both directions
        a.set_recv_timeout(Duration::from_secs(5));
        let err = a.recv(1).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("disconnected"), "{msg}");
    }

    #[test]
    fn mid_frame_close_is_a_short_read() {
        // raw socket: write half a frame, then close
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            use std::io::Write;
            let mut s = TcpStream::connect(addr).unwrap();
            let msg = Msg {
                from: 0,
                tag: 1,
                payload: Payload::U64s(vec![1, 2, 3]),
                depart: 0.0,
                phase: Phase::Online,
            };
            let frame = wire::encode_msg(&msg);
            s.write_all(&frame[..frame.len() / 2]).unwrap();
            // drop: FIN mid-frame
        });
        let (mut s, _) = listener.accept().unwrap();
        h.join().unwrap();
        let err = wire::read_msg(&mut s).unwrap_err();
        assert!(format!("{err}").contains("short read"), "{err}");
    }

    #[test]
    fn connect_retry_waits_for_late_listener() {
        // bind, learn the port, close, rebind after a delay — the dialer
        // must ride out the refused window
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        drop(listener);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            let l = TcpListener::bind(addr).unwrap();
            let _ = l.accept();
        });
        let got = connect_retry(&addr.to_string(), Duration::from_secs(10));
        // the exact port may be racily taken by another process; only
        // assert we did not give up instantly when it worked
        if got.is_ok() {
            h.join().unwrap();
        } else {
            let _ = h.join();
        }
    }

    #[test]
    fn three_party_loopback_mesh_routes_all_pairs() {
        let (ports, _) = loopback_mesh(&["A", "B", "C"], LinkSpec::lan()).unwrap();
        let mut it = ports.into_iter();
        let mut a = it.next().unwrap();
        let mut b = it.next().unwrap();
        let mut c = it.next().unwrap();
        let hb = std::thread::spawn(move || {
            let v = b.recv_u64s(0).unwrap();
            b.send(2, Payload::U64s(vec![v[0] + 1])).unwrap();
            b.recv_u64s(2).unwrap()
        });
        let hc = std::thread::spawn(move || {
            let v = c.recv_u64s(1).unwrap();
            c.send(0, Payload::U64s(vec![v[0] + 1])).unwrap();
            c.send(1, Payload::U64s(vec![99])).unwrap();
        });
        a.send(1, Payload::U64s(vec![10])).unwrap();
        assert_eq!(a.recv_u64s(2).unwrap(), vec![12]);
        assert_eq!(hb.join().unwrap(), vec![99]);
        hc.join().unwrap();
    }
}
