//! Length-prefixed wire framing for the socket backends (TCP and UDS).
//!
//! The netsim backend moves [`Payload`]s by ownership and only *accounts*
//! their wire size; this module is the real serialization those byte
//! counts model. One frame per [`Msg`]:
//!
//! ```text
//! [len: u32 LE] [ftype: u8] [seq: u64] [ack: u64]
//!               [from: u32] [tag: u64] [depart: f64 bits] [phase: u8]
//!               [kind: u8] [payload body...]
//! ```
//!
//! The first envelope row is the **resilient-link header** added for
//! mid-training reconnect ([`super::relink`]): `seq` numbers every data
//! frame on a link (1, 2, 3, … — `0` marks pre-session handshake
//! traffic, which is never journaled), `ack` piggybacks the highest
//! sequence number the sender has delivered from its peer (journal
//! pruning), and `ftype` distinguishes payload-carrying [`FT_DATA`]
//! frames from the [`FT_BYE`] goodbye marker that makes an orderly
//! shutdown distinguishable from a dropped connection, and from the
//! standalone [`FT_ACK`] frames that keep journals bounded when the
//! reverse direction is idle.
//!
//! Every variable-length field carries an explicit element count, so a
//! truncated frame is always detected (`truncated frame` / `short read`
//! errors) instead of being misparsed. Floats travel as raw IEEE-754 bit
//! patterns — `decode(encode(m))` is bit-exact, which is what makes a
//! socket run train the same weights as a netsim run.
//!
//! The sender's virtual-clock departure stamp (`depart`) rides the frame,
//! so the receiving port can model simulated arrival time across real
//! sockets exactly as the simulator does in-process.

use std::io::{Read, Write};

use crate::netsim::{Msg, Payload, Phase};
use crate::{Error, Result};

/// Hard cap on one frame's body (defense against corrupt length prefixes).
pub const FRAME_MAX: usize = 1 << 30;

/// Frame type: an ordinary payload-carrying message.
pub const FT_DATA: u8 = 0;
/// Frame type: goodbye marker — the sender is done and the following EOF
/// is an orderly shutdown, not a dropped link (see [`super::relink`]).
pub const FT_BYE: u8 = 1;
/// Frame type: standalone acknowledgment — carries only the `ack` field,
/// so a link whose reverse direction is idle still prunes its peer's
/// send journal (see [`super::relink`]).
pub const FT_ACK: u8 = 2;

/// Byte offset of the `ack` field within a whole frame (length prefix
/// included) — lets the reconnect journal patch a stored frame's ack
/// just before (re)transmission instead of re-encoding the payload.
pub(crate) const ACK_OFFSET: usize = 4 + 1 + 8;

fn err(msg: impl Into<String>) -> Error {
    Error::Net(msg.into())
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new() -> Self {
        // reserve the length prefix slot up front
        Enc { buf: vec![0u8; 4] }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    fn u64s(&mut self, v: &[u64]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.u64(x);
        }
    }

    fn finish(mut self) -> Vec<u8> {
        let body = (self.buf.len() - 4) as u32;
        self.buf[..4].copy_from_slice(&body.to_le_bytes());
        self.buf
    }
}

const KIND_U64S: u8 = 0;
const KIND_F32S: u8 = 1;
const KIND_F64S: u8 = 2;
const KIND_CIPHER: u8 = 3;
const KIND_CIPHER_BLOCK: u8 = 4;
const KIND_SEED: u8 = 5;
const KIND_BITS: u8 = 6;
const KIND_CONTROL: u8 = 7;
const KIND_INFER_REQ: u8 = 8;
const KIND_INFER_RESP: u8 = 9;

/// Serialize one data frame (length prefix included) with explicit
/// resilient-link sequence and ack numbers.
pub fn encode_frame(msg: &Msg, seq: u64, ack: u64) -> Vec<u8> {
    let mut e = Enc::new();
    e.u8(FT_DATA);
    e.u64(seq);
    e.u64(ack);
    e.u32(msg.from as u32);
    e.u64(msg.tag);
    e.u64(msg.depart.to_bits());
    e.u8(match msg.phase {
        Phase::Online => 0,
        Phase::Offline => 1,
    });
    match &msg.payload {
        Payload::U64s(v) => {
            e.u8(KIND_U64S);
            e.u64s(v);
        }
        Payload::F32s(v) => {
            e.u8(KIND_F32S);
            e.u32(v.len() as u32);
            for &x in v {
                e.bytes(&x.to_bits().to_le_bytes());
            }
        }
        Payload::F64s(v) => {
            e.u8(KIND_F64S);
            e.u32(v.len() as u32);
            for &x in v {
                e.u64(x.to_bits());
            }
        }
        Payload::Cipher(items) => {
            e.u8(KIND_CIPHER);
            e.u32(items.len() as u32);
            for item in items {
                e.u32(item.len() as u32);
                e.bytes(item);
            }
        }
        Payload::CipherBlock { data, ct_bytes, count } => {
            e.u8(KIND_CIPHER_BLOCK);
            e.u32(*ct_bytes as u32);
            e.u32(*count as u32);
            e.u32(data.len() as u32);
            e.bytes(data);
        }
        Payload::Seed(s) => {
            e.u8(KIND_SEED);
            e.bytes(s);
        }
        Payload::Bits(v) => {
            e.u8(KIND_BITS);
            e.u64s(v);
        }
        Payload::Control(s) => {
            e.u8(KIND_CONTROL);
            e.u32(s.len() as u32);
            e.bytes(s.as_bytes());
        }
        Payload::InferReq(v) => {
            e.u8(KIND_INFER_REQ);
            e.u32(v.len() as u32);
            for &x in v {
                e.u32(x);
            }
        }
        Payload::InferResp(v) => {
            e.u8(KIND_INFER_RESP);
            e.u32(v.len() as u32);
            for &x in v {
                e.bytes(&x.to_bits().to_le_bytes());
            }
        }
    }
    e.finish()
}

/// Serialize one message as an unjournaled frame (`seq = ack = 0`) —
/// the form all pre-session handshake traffic uses.
pub fn encode_msg(msg: &Msg) -> Vec<u8> {
    encode_frame(msg, 0, 0)
}

/// Serialize a goodbye marker: `seq` is the highest sequence number the
/// sender assigned, `ack` the highest it delivered.
pub fn encode_bye(seq: u64, ack: u64) -> Vec<u8> {
    let mut e = Enc::new();
    e.u8(FT_BYE);
    e.u64(seq);
    e.u64(ack);
    e.finish()
}

/// Serialize a standalone acknowledgment (`seq` is unused and 0).
pub fn encode_ack(ack: u64) -> Vec<u8> {
    let mut e = Enc::new();
    e.u8(FT_ACK);
    e.u64(0);
    e.u64(ack);
    e.finish()
}

/// Patch the ack field of an already-encoded frame in place (see
/// [`ACK_OFFSET`]).
pub(crate) fn patch_ack(frame: &mut [u8], ack: u64) {
    frame[ACK_OFFSET..ACK_OFFSET + 8].copy_from_slice(&ack.to_le_bytes());
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// A decoded frame: the resilient-link envelope plus the message
/// (`None` for the payload-less [`FT_BYE`] / [`FT_ACK`] frames).
#[derive(Debug)]
pub struct Frame {
    /// Frame type ([`FT_DATA`] / [`FT_BYE`] / [`FT_ACK`]).
    pub ftype: u8,
    /// Link sequence number (0 = unjournaled handshake-era frame).
    pub seq: u64,
    /// Highest peer sequence number the sender had delivered.
    pub ack: u64,
    /// The carried message; `None` for goodbye and ack frames.
    pub msg: Option<Msg>,
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(err(format!(
                "truncated frame: wanted {n} bytes at offset {}, body is {} bytes",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    /// Element count that must still fit in the remaining body — rejects
    /// absurd counts from corrupt frames before allocating.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem_bytes) > self.buf.len() - self.pos {
            return Err(err(format!(
                "truncated frame: {n} element(s) of {elem_bytes} byte(s) exceed \
                 the {} remaining body byte(s)",
                self.buf.len() - self.pos
            )));
        }
        Ok(n)
    }

    fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.count(8)?;
        (0..n).map(|_| self.u64()).collect()
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(err(format!(
                "trailing garbage: frame body is {} bytes but decoding consumed {}",
                self.buf.len(),
                self.pos
            )));
        }
        Ok(())
    }
}

/// Decode one frame *body* (the bytes after the length prefix).
pub fn decode_frame(body: &[u8]) -> Result<Frame> {
    let mut d = Dec { buf: body, pos: 0 };
    let ftype = d.u8()?;
    let seq = d.u64()?;
    let ack = d.u64()?;
    match ftype {
        FT_BYE | FT_ACK => {
            d.done()?;
            Ok(Frame { ftype, seq, ack, msg: None })
        }
        FT_DATA => {
            let from = d.u32()? as usize;
            let tag = d.u64()?;
            let depart = f64::from_bits(d.u64()?);
            let phase = match d.u8()? {
                0 => Phase::Online,
                1 => Phase::Offline,
                other => return Err(err(format!("bad phase byte {other}"))),
            };
            let kind = d.u8()?;
            let payload = match kind {
                KIND_U64S => Payload::U64s(d.u64s()?),
                KIND_F32S => {
                    let n = d.count(4)?;
                    let mut v = Vec::with_capacity(n);
                    for _ in 0..n {
                        let b = d.take(4)?;
                        v.push(f32::from_bits(u32::from_le_bytes([b[0], b[1], b[2], b[3]])));
                    }
                    Payload::F32s(v)
                }
                KIND_F64S => {
                    let n = d.count(8)?;
                    let mut v = Vec::with_capacity(n);
                    for _ in 0..n {
                        v.push(f64::from_bits(d.u64()?));
                    }
                    Payload::F64s(v)
                }
                KIND_CIPHER => {
                    let n = d.count(4)?;
                    let mut items = Vec::with_capacity(n);
                    for _ in 0..n {
                        let len = d.count(1)?;
                        items.push(d.take(len)?.to_vec());
                    }
                    Payload::Cipher(items)
                }
                KIND_CIPHER_BLOCK => {
                    let ct_bytes = d.u32()? as usize;
                    let count = d.u32()? as usize;
                    let len = d.count(1)?;
                    Payload::CipherBlock { data: d.take(len)?.to_vec(), ct_bytes, count }
                }
                KIND_SEED => {
                    let mut s = [0u8; 32];
                    s.copy_from_slice(d.take(32)?);
                    Payload::Seed(s)
                }
                KIND_BITS => Payload::Bits(d.u64s()?),
                KIND_CONTROL => {
                    let len = d.count(1)?;
                    let s = String::from_utf8(d.take(len)?.to_vec())
                        .map_err(|_| err("control payload is not utf-8"))?;
                    Payload::Control(s)
                }
                KIND_INFER_REQ => {
                    let n = d.count(4)?;
                    (0..n).map(|_| d.u32()).collect::<Result<Vec<u32>>>().map(Payload::InferReq)?
                }
                KIND_INFER_RESP => {
                    let n = d.count(4)?;
                    let mut v = Vec::with_capacity(n);
                    for _ in 0..n {
                        let b = d.take(4)?;
                        v.push(f32::from_bits(u32::from_le_bytes([b[0], b[1], b[2], b[3]])));
                    }
                    Payload::InferResp(v)
                }
                other => return Err(err(format!("unknown payload kind {other}"))),
            };
            d.done()?;
            Ok(Frame { ftype, seq, ack, msg: Some(Msg { from, tag, payload, depart, phase }) })
        }
        other => Err(err(format!("unknown frame type {other}"))),
    }
}

/// Decode one frame body that must carry a message (handshake traffic —
/// a goodbye marker here is a protocol violation).
pub fn decode_msg(body: &[u8]) -> Result<Msg> {
    decode_frame(body)?
        .msg
        .ok_or_else(|| err("unexpected goodbye frame where a message was required"))
}

// ---------------------------------------------------------------------------
// Stream I/O
// ---------------------------------------------------------------------------

/// Write one message as a single framed chunk (unjournaled, `seq = 0`).
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> std::io::Result<()> {
    w.write_all(&encode_msg(msg))
}

/// Read the next frame (envelope included). Returns `Ok(None)` on a clean
/// EOF at a frame boundary; EOF *inside* a frame is a short-read error,
/// as is a length prefix beyond [`FRAME_MAX`].
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>> {
    let mut len_b = [0u8; 4];
    match read_full(r, &mut len_b)? {
        ReadOutcome::CleanEof => return Ok(None),
        ReadOutcome::Short(got) => {
            return Err(err(format!(
                "short read: connection closed {got}/4 bytes into a frame header"
            )))
        }
        ReadOutcome::Full => {}
    }
    let len = u32::from_le_bytes(len_b) as usize;
    if len > FRAME_MAX {
        return Err(err(format!("frame length {len} exceeds cap {FRAME_MAX}")));
    }
    let mut body = vec![0u8; len];
    match read_full(r, &mut body)? {
        ReadOutcome::Full => decode_frame(&body).map(Some),
        ReadOutcome::CleanEof | ReadOutcome::Short(_) => Err(err(format!(
            "short read: connection closed inside a {len}-byte frame body"
        ))),
    }
}

/// Read the next message, treating a clean EOF and the payload-less
/// frame types as end-of-stream (`Ok(None)`). The handshake and the
/// simple (non-resilient) loopback links use this — neither ever
/// receives ack frames; resilient links read the envelope through
/// [`read_frame`] instead.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Option<Msg>> {
    match read_frame(r)? {
        None => Ok(None),
        Some(Frame { msg, .. }) => Ok(msg),
    }
}

enum ReadOutcome {
    Full,
    CleanEof,
    Short(usize),
}

/// `read_exact` that distinguishes EOF-before-any-byte from EOF-mid-buffer.
fn read_full<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<ReadOutcome> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Ok(if got == 0 {
                    ReadOutcome::CleanEof
                } else {
                    ReadOutcome::Short(got)
                })
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(err(format!("socket read failed: {e}"))),
        }
    }
    Ok(ReadOutcome::Full)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::NO_TAG;
    use crate::rng::{Pcg64, Rng64};
    use crate::testutil::prop_check;

    // body offsets after the length prefix: ftype(1) seq(8) ack(8)
    const MSG_AT: usize = 17;
    // then from(4) tag(8) depart(8) -> phase, kind, first count
    const PHASE_AT: usize = MSG_AT + 20;
    const KIND_AT: usize = PHASE_AT + 1;
    const COUNT_AT: usize = KIND_AT + 1;

    fn roundtrip(msg: &Msg) -> Msg {
        let frame = encode_msg(msg);
        let body_len = u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]]) as usize;
        assert_eq!(body_len + 4, frame.len(), "length prefix disagrees with frame");
        decode_msg(&frame[4..]).expect("decode")
    }

    fn assert_msg_eq(a: &Msg, b: &Msg) {
        assert_eq!(a.from, b.from);
        assert_eq!(a.tag, b.tag);
        assert_eq!(a.depart.to_bits(), b.depart.to_bits());
        assert_eq!(a.phase, b.phase);
        match (&a.payload, &b.payload) {
            (Payload::U64s(x), Payload::U64s(y)) => assert_eq!(x, y),
            (Payload::F32s(x), Payload::F32s(y)) => {
                assert_eq!(x.len(), y.len());
                for (u, v) in x.iter().zip(y) {
                    assert_eq!(u.to_bits(), v.to_bits());
                }
            }
            (Payload::F64s(x), Payload::F64s(y)) => {
                assert_eq!(x.len(), y.len());
                for (u, v) in x.iter().zip(y) {
                    assert_eq!(u.to_bits(), v.to_bits());
                }
            }
            (Payload::Cipher(x), Payload::Cipher(y)) => assert_eq!(x, y),
            (
                Payload::CipherBlock { data: d1, ct_bytes: c1, count: n1 },
                Payload::CipherBlock { data: d2, ct_bytes: c2, count: n2 },
            ) => {
                assert_eq!(d1, d2);
                assert_eq!(c1, c2);
                assert_eq!(n1, n2);
            }
            (Payload::Seed(x), Payload::Seed(y)) => assert_eq!(x, y),
            (Payload::Bits(x), Payload::Bits(y)) => assert_eq!(x, y),
            (Payload::Control(x), Payload::Control(y)) => assert_eq!(x, y),
            (Payload::InferReq(x), Payload::InferReq(y)) => assert_eq!(x, y),
            (Payload::InferResp(x), Payload::InferResp(y)) => {
                assert_eq!(x.len(), y.len());
                for (u, v) in x.iter().zip(y) {
                    assert_eq!(u.to_bits(), v.to_bits());
                }
            }
            (x, y) => panic!("variant changed: {} vs {}", x.kind(), y.kind()),
        }
    }

    fn random_payload(rng: &mut Pcg64) -> Payload {
        let n = (rng.next_u64() % 17) as usize;
        match rng.next_u64() % 10 {
            0 => Payload::U64s((0..n).map(|_| rng.next_u64()).collect()),
            1 => Payload::F32s(
                (0..n).map(|_| f32::from_bits(rng.next_u64() as u32 & 0x7f7f_ffff)).collect(),
            ),
            2 => Payload::F64s((0..n).map(|_| (rng.next_u64() as f64) / 7.0).collect()),
            3 => Payload::Cipher(
                (0..n)
                    .map(|_| {
                        let l = (rng.next_u64() % 40) as usize;
                        (0..l).map(|_| rng.next_u64() as u8).collect()
                    })
                    .collect(),
            ),
            4 => {
                let ct_bytes = 1 + (rng.next_u64() % 33) as usize;
                Payload::CipherBlock {
                    data: (0..n * ct_bytes).map(|_| rng.next_u64() as u8).collect(),
                    ct_bytes,
                    count: n,
                }
            }
            5 => {
                let mut s = [0u8; 32];
                for b in s.iter_mut() {
                    *b = rng.next_u64() as u8;
                }
                Payload::Seed(s)
            }
            6 => Payload::Bits((0..n).map(|_| rng.next_u64()).collect()),
            7 => Payload::InferReq((0..n).map(|_| rng.next_u64() as u32).collect()),
            8 => Payload::InferResp(
                (0..n).map(|_| f32::from_bits(rng.next_u64() as u32 & 0x7f7f_ffff)).collect(),
            ),
            _ => Payload::Control(format!("ctl:{}", rng.next_u64())),
        }
    }

    #[test]
    fn every_payload_variant_roundtrips() {
        // property: encode/decode is the identity on every variant, for
        // random contents, tags (incl. NO_TAG), phases, depart stamps and
        // seq/ack envelopes
        prop_check("wire_roundtrip", 300, |rng| {
            let msg = Msg {
                from: (rng.next_u64() % 7) as usize,
                tag: if rng.next_u64() % 4 == 0 { NO_TAG } else { rng.next_u64() },
                payload: random_payload(rng),
                depart: (rng.next_u64() as f64) / 1e6,
                phase: if rng.next_u64() % 2 == 0 { Phase::Online } else { Phase::Offline },
            };
            let (seq, ack) = (rng.next_u64(), rng.next_u64());
            let frame = encode_frame(&msg, seq, ack);
            let f = decode_frame(&frame[4..]).expect("decode");
            assert_eq!(f.seq, seq);
            assert_eq!(f.ack, ack);
            assert_msg_eq(&msg, f.msg.as_ref().expect("data frame"));
        });
    }

    #[test]
    fn empty_collections_roundtrip() {
        for payload in [
            Payload::U64s(vec![]),
            Payload::F32s(vec![]),
            Payload::F64s(vec![]),
            Payload::Cipher(vec![]),
            Payload::CipherBlock { data: vec![], ct_bytes: 0, count: 0 },
            Payload::Bits(vec![]),
            Payload::Control(String::new()),
            Payload::InferReq(vec![]),
            Payload::InferResp(vec![]),
        ] {
            let msg = Msg { from: 0, tag: 1, payload, depart: 0.0, phase: Phase::Online };
            assert_msg_eq(&msg, &roundtrip(&msg));
        }
    }

    #[test]
    fn float_bit_patterns_survive_exactly() {
        let msg = Msg {
            from: 2,
            tag: 9,
            payload: Payload::F64s(vec![-0.0, f64::MIN_POSITIVE, 1.0 + f64::EPSILON, 3e300]),
            depart: f64::MAX,
            phase: Phase::Online,
        };
        assert_msg_eq(&msg, &roundtrip(&msg));
    }

    #[test]
    fn bye_frames_roundtrip_and_patch_ack_works() {
        let frame = encode_bye(41, 7);
        let f = decode_frame(&frame[4..]).unwrap();
        assert_eq!((f.ftype, f.seq, f.ack), (FT_BYE, 41, 7));
        assert!(f.msg.is_none());
        let frame = encode_ack(19);
        let f = decode_frame(&frame[4..]).unwrap();
        assert_eq!((f.ftype, f.seq, f.ack), (FT_ACK, 0, 19));
        assert!(f.msg.is_none());
        // a bye where a message is required is a protocol violation
        assert!(decode_msg(&frame[4..]).is_err());
        // patch_ack rewrites only the ack field, on any frame type
        let msg = Msg {
            from: 1,
            tag: 3,
            payload: Payload::U64s(vec![9]),
            depart: 0.25,
            phase: Phase::Online,
        };
        let mut frame = encode_frame(&msg, 17, 0);
        patch_ack(&mut frame, 0xdead_beef);
        let f = decode_frame(&frame[4..]).unwrap();
        assert_eq!(f.seq, 17);
        assert_eq!(f.ack, 0xdead_beef);
        assert_msg_eq(&msg, f.msg.as_ref().unwrap());
    }

    #[test]
    fn every_truncation_of_a_frame_errors_cleanly() {
        // property: decoding any strict prefix of a valid body must fail
        // (explicit element counts make truncation always detectable), and
        // must never panic
        prop_check("wire_truncation", 60, |rng| {
            let msg = Msg {
                from: 1,
                tag: rng.next_u64(),
                payload: random_payload(rng),
                depart: 0.5,
                phase: Phase::Online,
            };
            let frame = encode_frame(&msg, rng.next_u64(), rng.next_u64());
            let body = &frame[4..];
            for cut in 0..body.len() {
                assert!(
                    decode_frame(&body[..cut]).is_err(),
                    "truncation to {cut}/{} bytes decoded successfully",
                    body.len()
                );
            }
            assert!(decode_frame(body).is_ok());
        });
        // goodbye / ack frames too
        for frame in [encode_bye(3, 4), encode_ack(9)] {
            for cut in 0..frame.len() - 4 {
                assert!(decode_frame(&frame[4..4 + cut]).is_err());
            }
        }
    }

    #[test]
    fn corrupt_frames_are_rejected() {
        assert!(decode_frame(&[]).is_err());
        let msg = Msg {
            from: 0,
            tag: 0,
            payload: Payload::U64s(vec![1]),
            depart: 0.0,
            phase: Phase::Online,
        };
        let frame = encode_msg(&msg);
        // bad frame type byte
        let mut bad = frame[4..].to_vec();
        bad[0] = 77;
        assert!(decode_frame(&bad).is_err());
        // bad phase byte
        let mut bad = frame[4..].to_vec();
        bad[PHASE_AT] = 9;
        assert!(decode_frame(&bad).is_err());
        // bad kind byte
        let mut bad = frame[4..].to_vec();
        bad[KIND_AT] = 200;
        assert!(decode_frame(&bad).is_err());
        // absurd element count must not allocate or succeed
        let mut bad = frame[4..].to_vec();
        bad[COUNT_AT..COUNT_AT + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_frame(&bad).is_err());
        // trailing garbage after a valid message
        let mut bad = frame[4..].to_vec();
        bad.push(0);
        assert!(decode_frame(&bad).is_err());
        // trailing garbage after a goodbye
        let mut bad = encode_bye(0, 0)[4..].to_vec();
        bad.push(0);
        assert!(decode_frame(&bad).is_err());
    }

    #[test]
    fn stream_io_roundtrips_and_reports_eof() {
        let msgs: Vec<Msg> = (0..3)
            .map(|i| Msg {
                from: i,
                tag: i as u64,
                payload: Payload::U64s(vec![i as u64; i + 1]),
                depart: i as f64,
                phase: Phase::Online,
            })
            .collect();
        let mut buf = Vec::new();
        for m in &msgs {
            write_msg(&mut buf, m).unwrap();
        }
        let mut r = &buf[..];
        for m in &msgs {
            let got = read_msg(&mut r).unwrap().expect("message");
            assert_msg_eq(m, &got);
        }
        // clean EOF at the frame boundary
        assert!(read_msg(&mut r).unwrap().is_none());
        // EOF inside the header and inside the body are short reads
        let mut short = &buf[..2];
        assert!(read_msg(&mut short).is_err());
        let mut short = &buf[..10];
        let e = read_msg(&mut short).unwrap_err();
        assert!(format!("{e}").contains("short read"), "{e}");
        // oversized length prefix is rejected before allocation
        let huge = (FRAME_MAX as u32 + 1).to_le_bytes();
        let mut r = &huge[..];
        assert!(read_msg(&mut r).is_err());
        // goodbye / ack markers read as end-of-stream through read_msg
        // but as explicit frames through read_frame
        for (frame, ftype) in [(encode_bye(9, 2), FT_BYE), (encode_ack(2), FT_ACK)] {
            let mut r = &frame[..];
            assert!(read_msg(&mut r).unwrap().is_none());
            let mut r = &frame[..];
            let f = read_frame(&mut r).unwrap().unwrap();
            assert_eq!((f.ftype, f.ack), (ftype, 2));
            assert!(f.msg.is_none());
        }
    }

    #[test]
    fn encoded_size_tracks_accounted_wire_bytes() {
        // the frame is within a small constant of the netsim accounting
        // (the simulator's HEADER_BYTES models exactly this envelope)
        let payload = Payload::U64s(vec![7; 100]);
        let accounted = payload.total_bytes();
        let msg = Msg { from: 0, tag: 3, payload, depart: 1.0, phase: Phase::Online };
        let frame = encode_frame(&msg, 1, 1);
        let diff = (frame.len() as i64 - accounted as i64).abs();
        assert!(diff <= 16, "frame {} vs accounted {accounted}", frame.len());
    }
}
