//! Pluggable party transport: the [`Channel`] trait every protocol talks
//! through, with two interchangeable backends.
//!
//! The paper's system runs "in a decentralized setting" (§6): coordinator,
//! server, dealer and data holders live on separate machines. This module
//! is the boundary that makes that real without forking the protocol code:
//!
//! * [`Channel`] captures the full port surface the protocols use — tagged
//!   sends (`send_tagged`), per-peer FIFO-per-tag out-of-order receives
//!   (`recv_tagged` / `recv_any_tag` backed by reorder buffers), the
//!   non-blocking `try_recv_tagged` poll, the Lamport-style virtual clock,
//!   protocol-stage labels, rich timeout diagnostics and exact wire-byte
//!   accounting.
//! * Backend (a): the **netsim** simulator ([`crate::netsim`]) — the seed
//!   behavior, in-process channels plus a modeled wire.
//! * Backend (b): **TCP** ([`tcp`]) — real `std::net::TcpStream` sockets
//!   carrying the length-prefixed [`wire`] encoding of every
//!   [`Payload`](crate::netsim::Payload), either as an in-process loopback
//!   mesh (`TrainConfig::transport = Tcp`) or as a genuinely multi-process
//!   deployment rendezvoused by the [`session`] handshake and driven by
//!   the [`runner`] (`spnn party` / `spnn launch`).
//!
//! * Backend (c): **UDS** ([`uds`], unix only) — Unix-domain socketpairs
//!   for co-located parties, same framing, no TCP/IP stack
//!   (`--transport uds`).
//!
//! All backends share one session engine (`netsim::NetPort`: reorder
//! buffers, virtual clock, stats, deadlock diagnostics); they differ only
//! in what carries the messages — in-process `mpsc` channels vs socket
//! reader/writer threads. Because the sender's virtual-clock departure
//! stamp travels inside the wire frame, the simulated-time model works
//! identically across backends, and the trained weights are bit-identical
//! (asserted by the `*_transports_are_transcript_equal` tests).
//!
//! Multi-process hardening lives in two further modules: [`auth`]
//! (pre-shared-key mutual authentication of the rendezvous, hand-rolled
//! SHA-256/HMAC) and [`relink`] (journaled resilient links — a dropped
//! `TcpStream` is re-dialed and the unacked tail replayed, so training
//! survives mid-epoch connection kills bit-identically).

#![warn(missing_docs)]

pub mod auth;
pub mod relink;
pub mod runner;
pub mod session;
pub mod tcp;
#[cfg(unix)]
pub mod uds;
pub mod wire;

use std::time::Duration;

use crate::netsim::{LinkSpec, NetPort, PartyId, Payload, Phase};
use crate::Result;

pub use crate::config::TransportKind;

/// The full port surface of a decentralized party, as consumed by every
/// protocol role (object-safe: role closures are boxed over
/// `&mut dyn Channel` so one role body runs unchanged on any backend).
pub trait Channel: Send {
    /// This party's id within the deployment.
    fn id(&self) -> PartyId;

    /// This party's display name (diagnostics).
    fn name(&self) -> &str;

    /// Link characteristics used for the virtual-clock wire model.
    fn spec(&self) -> LinkSpec;

    /// Current virtual time (compute + modeled wire delays so far).
    fn now(&mut self) -> f64;

    /// Manually advance the virtual clock (extrapolated compute sections).
    fn advance(&mut self, dt: f64);

    /// Reset the clock (e.g. between timed epochs).
    fn reset_clock(&mut self);

    /// Label the current protocol stage (traffic breakdown + diagnostics).
    fn set_stage(&mut self, stage: &'static str);

    /// Deadlock-detection timeout for blocking receives.
    fn set_recv_timeout(&mut self, d: Duration);

    /// Send with explicit tag and phase (the primitive all sends reduce to).
    fn send_tagged_phase(
        &mut self,
        to: PartyId,
        tag: u64,
        payload: Payload,
        phase: Phase,
    ) -> Result<()>;

    /// Blocking receive of the next message from `from` regardless of tag
    /// (buffered messages first, in arrival order), returning the tag.
    fn recv_any_tag(&mut self, from: PartyId) -> Result<(u64, Payload)>;

    /// Blocking receive of the next `tag` message from `from`; messages
    /// with other tags arriving first are parked in the per-peer reorder
    /// buffer (FIFO within each tag).
    fn recv_tagged(&mut self, from: PartyId, tag: u64) -> Result<Payload>;

    /// Non-blocking [`Self::recv_tagged`]: `None` when nothing matching is
    /// available yet.
    fn try_recv_tagged(&mut self, from: PartyId, tag: u64) -> Result<Option<Payload>>;

    // --- provided conveniences (the seed NetPort surface) ---

    /// Send `payload` to party `to` (online phase, untagged).
    fn send(&mut self, to: PartyId, payload: Payload) -> Result<()> {
        self.send_tagged_phase(to, crate::netsim::NO_TAG, payload, Phase::Online)
    }

    /// Send with explicit phase tag.
    fn send_phase(&mut self, to: PartyId, payload: Payload, phase: Phase) -> Result<()> {
        self.send_tagged_phase(to, crate::netsim::NO_TAG, payload, phase)
    }

    /// Send tagged with a batch / stream id (online phase).
    fn send_tagged(&mut self, to: PartyId, tag: u64, payload: Payload) -> Result<()> {
        self.send_tagged_phase(to, tag, payload, Phase::Online)
    }

    /// Blocking receive of the next message from `from` regardless of tag.
    fn recv(&mut self, from: PartyId) -> Result<Payload> {
        self.recv_any_tag(from).map(|(_, p)| p)
    }

    /// Receive and assert the u64 variant (the most common case).
    fn recv_u64s(&mut self, from: PartyId) -> Result<Vec<u64>> {
        self.recv(from)?.into_u64s()
    }

    /// Receive and assert the f32 variant.
    fn recv_f32s(&mut self, from: PartyId) -> Result<Vec<f32>> {
        self.recv(from)?.into_f32s()
    }
}

impl Channel for NetPort {
    fn id(&self) -> PartyId {
        self.id
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn spec(&self) -> LinkSpec {
        NetPort::spec(self)
    }

    fn now(&mut self) -> f64 {
        NetPort::now(self)
    }

    fn advance(&mut self, dt: f64) {
        NetPort::advance(self, dt)
    }

    fn reset_clock(&mut self) {
        NetPort::reset_clock(self)
    }

    fn set_stage(&mut self, stage: &'static str) {
        NetPort::set_stage(self, stage)
    }

    fn set_recv_timeout(&mut self, d: Duration) {
        NetPort::set_recv_timeout(self, d)
    }

    fn send_tagged_phase(
        &mut self,
        to: PartyId,
        tag: u64,
        payload: Payload,
        phase: Phase,
    ) -> Result<()> {
        NetPort::send_tagged_phase(self, to, tag, payload, phase)
    }

    fn recv_any_tag(&mut self, from: PartyId) -> Result<(u64, Payload)> {
        NetPort::recv_any_tag(self, from)
    }

    fn recv_tagged(&mut self, from: PartyId, tag: u64) -> Result<Payload> {
        NetPort::recv_tagged(self, from, tag)
    }

    fn try_recv_tagged(&mut self, from: PartyId, tag: u64) -> Result<Option<Payload>> {
        NetPort::try_recv_tagged(self, from, tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::full_mesh;

    // exercise the whole surface through the trait object, the way the
    // protocol roles see it
    fn ping(ch: &mut dyn Channel, peer: PartyId) -> Result<Vec<u64>> {
        ch.set_stage("ping");
        ch.send_tagged(peer, 7, Payload::U64s(vec![1, 2]))?;
        ch.recv_tagged(peer, 7)?.into_u64s()
    }

    #[test]
    fn netport_implements_the_channel_surface() {
        let (mut ports, _) = full_mesh(&["A", "B"], LinkSpec::lan());
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        let h = std::thread::spawn(move || {
            let ch: &mut dyn Channel = &mut b;
            let got = ch.recv_tagged(0, 7).unwrap().into_u64s().unwrap();
            ch.send_tagged(0, 7, Payload::U64s(got.clone())).unwrap();
            got
        });
        let echoed = ping(&mut a, 1).unwrap();
        assert_eq!(echoed, vec![1, 2]);
        assert_eq!(h.join().unwrap(), vec![1, 2]);
        let ch: &mut dyn Channel = &mut a;
        assert_eq!(ch.id(), 0);
        assert_eq!(ch.name(), "A");
        assert!(ch.now() >= 0.0);
        ch.advance(1.0);
        assert!(ch.now() >= 1.0);
        ch.reset_clock();
        assert!(ch.now() < 1.0);
    }
}
