//! Multi-process deployment runner: the engine behind `spnn launch` and
//! `spnn party`.
//!
//! * [`run_party`] — one worker process: join the session (presenting
//!   the PSK when the deployment is authenticated), rebuild the
//!   deployment locally from the broadcast config (datasets re-synthesize
//!   deterministically from the seed — private inputs never travel), run
//!   this party's role body over a [`TcpPort`] backed by resilient
//!   relink-capable connections, ship the [`PartyOut`](crate::parties::PartyOut) back to the
//!   coordinator, flush and exit.
//! * [`run_launch`] — the coordinator process: host the rendezvous
//!   (optionally spawning the other roles as child OS processes of the
//!   same binary), run the coordinator role, collect every worker's
//!   `PartyOut` over the wire, and assemble the final [`TrainReport`]
//!   through the trainer's `finish` step — producing the same
//!   `weight_digest` an in-process run reports (asserted by the
//!   decentralized smoke test, including a run with a connection killed
//!   mid-epoch).
//!
//! Traffic accounting: each process counts the bytes *it* sends (the same
//! sender-side accounting netsim uses) and reports them — totals as
//! metrics, the per-stage rows verbatim — in its `PartyOut`; the
//! coordinator sums the totals and merges the stage rows
//! ([`crate::netsim::merge_stage_rows`]) into the whole-mesh Table-3b
//! breakdown, so `spnn launch` prints the same per-stage traffic table a
//! netsim run does. Virtual time still works — departure stamps ride the
//! wire frames — so reports carry both sim-time and wall-clock numbers.

use std::net::TcpListener;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::auth::Psk;
use super::relink::{self, Redial, RelinkOpts};
use super::session::{self, SessionSpec};
use super::tcp::TcpPort;
use crate::netsim::{merge_stage_rows, NetStats, Phase};
use crate::parties::{self, Deployment, NetSummary};
use crate::protocols::{self, TrainReport};
use crate::serve::{Request, ServeQueue};
use crate::{Error, Result};

/// Whole-session rendezvous deadline (covers process spawn + handshake).
pub const SESSION_TIMEOUT: Duration = Duration::from_secs(120);

fn trainer_for(spec: &SessionSpec) -> Result<Box<dyn protocols::Trainer>> {
    protocols::by_name(&spec.protocol)
        .ok_or_else(|| Error::Config(format!("unknown protocol {:?}", spec.protocol)))
}

/// Trainer + deployment + the pieces `finish` needs later, so the
/// (potentially large) synthetic dataset is derived exactly once.
struct Prepared {
    trainer: Box<dyn protocols::Trainer>,
    dep: Deployment,
    cfg: &'static crate::config::ModelConfig,
    test: crate::data::Dataset,
}

/// Build the (train or serve) deployment for this process. `queue` feeds
/// the coordinator's serve role when `spec.serve` is set; worker processes
/// pass [`ServeQueue::detached`] (their coordinator closure never runs).
fn build_deployment(spec: &SessionSpec, queue: ServeQueue) -> Result<Prepared> {
    let trainer = trainer_for(spec)?;
    let (cfg, train, test) = spec.datasets()?;
    crate::exec::set_default_threads(spec.tc.exec_threads);
    let dep = match &spec.serve {
        Some(opts) => trainer
            .serve_deployment(cfg, &spec.tc, &train, &test, spec.holders, opts, queue)?,
        None => trainer.deployment(cfg, &spec.tc, &train, &test, spec.holders)?,
    };
    Ok(Prepared { trainer, dep, cfg, test })
}

/// Per-party sender-side byte totals, attached to the shipped `PartyOut`.
fn traffic_metrics(stats: &NetStats, id: usize) -> Vec<(String, f64)> {
    vec![
        ("online_bytes_sent".into(), stats.bytes_sent_by(id, Phase::Online) as f64),
        ("offline_bytes_sent".into(), stats.bytes_sent_by(id, Phase::Offline) as f64),
    ]
}

/// Run one worker party: `spnn party --role <role> --connect <addr>`,
/// plus `--psk-file` for authenticated sessions, `--chaos-kill N`
/// (sever one connection after N sent frames) for reconnect drills, and
/// `--checkpoint-dir DIR` to persist / warm-load this role's parameter
/// blocks. The dir is process-local by design (it holds this party's
/// private shares), so it never rides the config broadcast — only the
/// `warm_start` bit does.
pub fn run_party(
    connect: &str,
    role: &str,
    bind_host: &str,
    psk: Option<&Psk>,
    chaos_kill_after: Option<u64>,
    ckpt_dir: Option<&str>,
    ckpt_keep: Option<usize>,
) -> Result<()> {
    let mut sess = session::join(connect, role, bind_host, SESSION_TIMEOUT, psk)?;
    sess.spec.tc.checkpoint_dir = ckpt_dir.map(|s| s.to_string());
    // like the dir, the rotation depth is a process-local retention policy
    sess.spec.tc.checkpoint_keep = ckpt_keep;
    let Prepared { dep, .. } = build_deployment(&sess.spec, ServeQueue::detached())?;
    if dep.names.len() != sess.n {
        return Err(Error::Protocol(format!(
            "topology mismatch: local deployment has {} parties, session has {}",
            dep.names.len(),
            sess.n
        )));
    }
    if dep.names.get(sess.id).map(|s| s.as_str()) != Some(role) {
        return Err(Error::Protocol(format!(
            "topology mismatch: session assigned id {} but local role table says {:?}",
            sess.id,
            dep.names.get(sess.id)
        )));
    }
    eprintln!(
        "spnn party: joined as {role} (party {}/{}) for {} on {}",
        sess.id,
        sess.n,
        sess.spec.protocol,
        sess.spec.dataset
    );
    let name_refs: Vec<&str> = dep.names.iter().map(|s| s.as_str()).collect();
    let stats = Arc::new(NetStats::new(&name_refs));
    // link recovery roles mirror the bring-up topology: we re-dial the
    // coordinator and lower-id peers; higher-id peers re-dial us through
    // the kept listener
    let mut redials: Vec<Option<Redial>> = vec![None; sess.n];
    for p in 0..sess.n {
        if p == sess.id {
            continue;
        }
        redials[p] = Some(if p == 0 {
            Redial::Dial(sess.coordinator_addr.clone())
        } else if p < sess.id {
            Redial::Dial(sess.peer_addrs[p].clone().ok_or_else(|| {
                Error::Protocol(format!("roster missing the re-dial address of party {p}"))
            })?)
        } else {
            Redial::Accept
        });
    }
    let opts = RelinkOpts {
        token: sess.token,
        reconnect_timeout: relink::RECONNECT_TIMEOUT,
        chaos_kill_after,
        // a checkpointed party also journals its links durably, so a
        // kill between checkpoint and shutdown stays recoverable
        journal_dir: sess.spec.tc.checkpoint_dir.as_ref().map(|d| format!("{d}/journal")),
    };
    let (port, links) = relink::resilient_port(
        sess.id,
        &name_refs,
        sess.streams,
        redials,
        Some(sess.listener),
        opts,
        sess.spec.link(),
        stats.clone(),
    )?;
    let mut port = TcpPort::new(port, links, stats.clone());

    let f = dep
        .fns
        .into_iter()
        .nth(sess.id)
        .ok_or_else(|| Error::Protocol("role body missing".into()))?;
    let mut out = f(&mut port)?;
    out.metrics.extend(traffic_metrics(&stats, sess.id));
    out.stages = stats.stage_rows();
    out.timings = crate::obs::registry().export();
    parties::send_party_out(&mut port, 0, &out)?;
    port.shutdown(); // join writers: the PartyOut is flushed before exit
    eprintln!("spnn party: {role} done (sim {:.2}s)", out.sim_time);
    Ok(())
}

/// Options for [`run_launch`].
pub struct LaunchOpts {
    /// Rendezvous bind address (`127.0.0.1:0` = ephemeral loopback).
    pub listen: String,
    /// Spawn the worker roles as child processes of this binary. When
    /// false, the launcher prints the `spnn party` command lines and waits
    /// for manual joins (multi-terminal / multi-host mode).
    pub spawn: bool,
    /// Chaos drill: spawn the named role with `--chaos-kill N` so it
    /// severs one of its connections after N sent frames mid-training
    /// (spawn mode only).
    pub chaos: Option<(String, u64)>,
}

/// Kill-on-drop guard so a failed rendezvous never leaves orphan workers.
struct ChildGuard(Vec<(String, Child)>);

impl ChildGuard {
    fn wait_all(&mut self) -> Result<()> {
        for (role, child) in self.0.drain(..) {
            let status = child.wait_with_output().map_err(Error::Io)?;
            if !status.status.success() {
                return Err(Error::Protocol(format!(
                    "party process {role} exited with {:?}",
                    status.status.code()
                )));
            }
        }
        Ok(())
    }
}

impl Drop for ChildGuard {
    fn drop(&mut self) {
        for (_, child) in self.0.iter_mut() {
            let _ = child.kill();
        }
        for (_, mut child) in self.0.drain(..) {
            let _ = child.wait();
        }
    }
}

/// Host a full decentralized run: rendezvous + coordinator role + result
/// collection + report assembly. The PSK (if any) comes from
/// `spec.tc.psk_file` and is loaded by each process independently.
pub fn run_launch(spec: &SessionSpec, opts: &LaunchOpts) -> Result<TrainReport> {
    let listener = TcpListener::bind(&opts.listen)
        .map_err(|e| Error::Net(format!("bind {}: {e}", opts.listen)))?;
    run_launch_on(listener, spec, opts)
}

/// [`run_launch`] on an already-bound rendezvous listener (lets callers
/// learn the ephemeral port before the workers need it).
pub fn run_launch_on(
    listener: TcpListener,
    spec: &SessionSpec,
    opts: &LaunchOpts,
) -> Result<TrainReport> {
    if spec.serve.is_some() {
        return Err(Error::Config(
            "serve sessions need a request queue — launch them through run_serve"
                .into(),
        ));
    }
    launch_on(listener, spec, opts, ServeQueue::detached())
}

/// Host a decentralized **serve** session (`spnn serve --launch`): like
/// [`run_launch`], but after training the workers stay resident and the
/// coordinator answers inference requests drained from `queue` (fed by the
/// TCP front door or any in-process producer). Returns when every queue
/// sender is dropped, with the same report a train-only run assembles.
pub fn run_serve(
    spec: &SessionSpec,
    opts: &LaunchOpts,
    queue: std::sync::mpsc::Receiver<Request>,
) -> Result<TrainReport> {
    let listener = TcpListener::bind(&opts.listen)
        .map_err(|e| Error::Net(format!("bind {}: {e}", opts.listen)))?;
    run_serve_on(listener, spec, opts, queue)
}

/// [`run_serve`] on an already-bound rendezvous listener.
pub fn run_serve_on(
    listener: TcpListener,
    spec: &SessionSpec,
    opts: &LaunchOpts,
    queue: std::sync::mpsc::Receiver<Request>,
) -> Result<TrainReport> {
    if spec.serve.is_none() {
        return Err(Error::Config(
            "run_serve needs spec.serve set (the workers must build serve \
             deployments too)"
                .into(),
        ));
    }
    launch_on(listener, spec, opts, ServeQueue::new(queue))
}

/// The shared launch engine behind [`run_launch_on`] / [`run_serve_on`].
fn launch_on(
    listener: TcpListener,
    spec: &SessionSpec,
    opts: &LaunchOpts,
    queue: ServeQueue,
) -> Result<TrainReport> {
    let wall = Instant::now();
    let psk = match &spec.tc.psk_file {
        Some(path) => Some(Psk::from_file(std::path::Path::new(path))?),
        None => None,
    };
    let Prepared { trainer, dep, cfg, test } = build_deployment(spec, queue)?;
    let n = dep.names.len();
    let addr = listener.local_addr().map_err(Error::Io)?.to_string();
    if let Some((role, _)) = &opts.chaos {
        if !opts.spawn {
            return Err(Error::Config(
                "--chaos only works in spawn mode (it rides the spawned command line); \
                 for manual joins pass --chaos-kill N to the party itself"
                    .into(),
            ));
        }
        if !dep.names[1..].iter().any(|r| r == role) {
            return Err(Error::Config(format!(
                "--chaos names unknown role {role:?} (worker roles: {:?})",
                &dep.names[1..]
            )));
        }
    }

    let mut guard = ChildGuard(Vec::new());
    if opts.spawn {
        let exe = std::env::current_exe().map_err(Error::Io)?;
        for role in &dep.names[1..] {
            let mut cmd = Command::new(&exe);
            cmd.args(["party", "--role", role.as_str(), "--connect", addr.as_str()]);
            if let Some(path) = &spec.tc.psk_file {
                cmd.args(["--psk-file", path.as_str()]);
            }
            // spawned children share this host's checkpoint dir; each
            // writes/reads only its own <role>.ckpt inside it
            if let Some(dir) = &spec.tc.checkpoint_dir {
                cmd.args(["--checkpoint-dir", dir.as_str()]);
            }
            if let Some(keep) = spec.tc.checkpoint_keep {
                cmd.arg("--checkpoint-keep").arg(keep.to_string());
            }
            if let Some((chaos_role, n_frames)) = &opts.chaos {
                if chaos_role == role {
                    cmd.args(["--chaos-kill", &n_frames.to_string()]);
                }
            }
            let child = cmd
                .stdin(Stdio::null())
                .stdout(Stdio::null()) // keep the report stream clean
                .stderr(Stdio::inherit())
                .spawn()
                .map_err(Error::Io)?;
            guard.0.push((role.clone(), child));
        }
        eprintln!("spnn launch: spawned {} party processes, rendezvous on {addr}", n - 1);
    } else {
        let psk_arg = match &spec.tc.psk_file {
            Some(path) => format!(" --psk-file {path}"),
            None => String::new(),
        };
        eprintln!("spnn launch: waiting for {} manual joins; run in other terminals:", n - 1);
        for role in &dep.names[1..] {
            eprintln!("  spnn party --role {role} --connect {addr}{psk_arg}");
        }
    }

    let hosted = session::host(&listener, spec, &dep.names, SESSION_TIMEOUT, psk.as_ref())?;
    let name_refs: Vec<&str> = dep.names.iter().map(|s| s.as_str()).collect();
    let stats = Arc::new(NetStats::new(&name_refs));
    // the coordinator accepts relinks from every party on the rendezvous
    // listener it already owns
    let redials: Vec<Option<Redial>> = (0..n)
        .map(|p| if p == 0 { None } else { Some(Redial::Accept) })
        .collect();
    let relink_opts = RelinkOpts {
        token: hosted.token,
        reconnect_timeout: relink::RECONNECT_TIMEOUT,
        chaos_kill_after: None,
        journal_dir: spec.tc.checkpoint_dir.as_ref().map(|d| format!("{d}/journal")),
    };
    let (port, links) = relink::resilient_port(
        0,
        &name_refs,
        hosted.streams,
        redials,
        Some(listener),
        relink_opts,
        spec.link(),
        stats.clone(),
    )?;
    let mut port = TcpPort::new(port, links, stats.clone());

    let mut fns = dep.fns;
    let f0 = fns.remove(0);
    let mut outs = vec![f0(&mut port)?];
    for id in 1..n {
        let out = parties::recv_party_out(&mut port, id)?;
        // fold worker timings into the local registry so the launcher's
        // "time by stage" table covers the whole mesh, as with stage rows
        crate::obs::registry().absorb(&out.timings);
        outs.push(out);
    }
    port.shutdown();
    guard.wait_all()?;

    // whole-mesh totals = own sends + every worker's reported sends;
    // stage rows merge the same way, so the Table-3b breakdown is
    // complete even though every process only sees its own links
    let mut online = stats.bytes_phase(Phase::Online);
    let mut offline = stats.bytes_phase(Phase::Offline);
    for out in &outs[1..] {
        online += out.metric("online_bytes_sent").unwrap_or(0.0) as usize;
        offline += out.metric("offline_bytes_sent").unwrap_or(0.0) as usize;
    }
    let stages = merge_stage_rows(
        std::iter::once(stats.stage_rows()).chain(outs[1..].iter().map(|o| o.stages.clone())),
    );
    let net = NetSummary { online_bytes: online, offline_bytes: offline, stages };
    trainer.finish(cfg, &spec.tc, &test, &outs, net, wall.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    fn spec(proto: &str) -> SessionSpec {
        SessionSpec {
            protocol: proto.into(),
            dataset: "fraud".into(),
            rows: 320,
            holders: 2,
            mbps: 100.0,
            tc: TrainConfig { epochs: 1, batch: 128, ..Default::default() },
            serve: None,
        }
    }

    fn netsim_digest(s: &SessionSpec) -> (u64, Vec<f64>) {
        use crate::netsim::LinkSpec;
        use crate::protocols::Trainer;
        let (cfg, train, test) = s.datasets().unwrap();
        let mut tc = s.tc.clone();
        tc.transport = crate::config::TransportKind::Netsim;
        let local = crate::protocols::secureml::SecureMl
            .train(cfg, &tc, LinkSpec::from_mbps(s.mbps), &train, &test, 2)
            .unwrap();
        (local.weight_digest, local.train_losses.clone())
    }

    /// In-process version of the multi-process flow: the launcher hosts
    /// with `spawn: false` while threads play the worker processes via
    /// `run_party` against the same rendezvous — exercising the entire
    /// session + runner + result-collection path without forking.
    #[test]
    fn launch_and_parties_in_threads_match_netsim_digest() {
        let mut s = spec("secureml"); // artifact-free protocol, runs anywhere
        s.tc.lr_override = Some(0.05);
        // bind the rendezvous first so the "workers" know its port
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = LaunchOpts { listen: addr.clone(), spawn: false, chaos: None };

        let roles = ["party0", "dealer", "party1"];
        let mut workers = Vec::new();
        for role in roles {
            let addr = addr.clone();
            workers.push(std::thread::spawn(move || {
                run_party(&addr, role, "127.0.0.1", None, None, None, None)
            }));
        }
        let rep = run_launch_on(listener, &s, &opts).unwrap();
        for w in workers {
            w.join().unwrap().unwrap();
        }
        assert_ne!(rep.weight_digest, 0);
        assert!(rep.online_bytes > 0, "worker traffic not aggregated");
        // the per-stage breakdown now covers the whole mesh, not just the
        // coordinator's own links: worker-side stages must appear
        assert!(!rep.stages.is_empty(), "stage rows not aggregated");
        let stage_bytes: u64 = rep.stages.iter().map(|r| r.bytes).sum();
        assert_eq!(
            stage_bytes as usize,
            rep.online_bytes + rep.offline_bytes,
            "merged stage rows disagree with the aggregated totals"
        );

        // the same config through the ordinary in-process netsim path
        // must produce the identical model
        let (digest, losses) = netsim_digest(&s);
        assert_eq!(
            rep.weight_digest, digest,
            "distributed run diverged from the in-process run"
        );
        assert_eq!(rep.train_losses, losses);
    }

    /// The reconnect drill: one worker severs its sockets mid-training
    /// (chaos kill); the resilient links re-dial and replay, and the
    /// trained weights stay bit-identical to the in-process run.
    #[test]
    fn launch_survives_a_connection_killed_mid_training() {
        let mut s = spec("secureml");
        s.tc.lr_override = Some(0.05);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = LaunchOpts { listen: addr.clone(), spawn: false, chaos: None };
        let mut workers = Vec::new();
        for (role, chaos) in [("party0", Some(25u64)), ("dealer", None), ("party1", None)] {
            let addr = addr.clone();
            workers.push(std::thread::spawn(move || {
                run_party(&addr, role, "127.0.0.1", None, chaos, None, None)
            }));
        }
        let rep = run_launch_on(listener, &s, &opts).unwrap();
        for w in workers {
            w.join().unwrap().unwrap();
        }
        let (digest, _) = netsim_digest(&s);
        assert_eq!(
            rep.weight_digest, digest,
            "training diverged after a mid-run connection kill + replay"
        );
    }

    /// A wrong key on one party aborts the whole launch with a
    /// diagnostic naming the offending role (acceptance criterion).
    #[test]
    fn launch_aborts_on_wrong_psk_naming_the_role() {
        let dir = std::env::temp_dir();
        let good = dir.join(format!("spnn-psk-good-{}", std::process::id()));
        let bad = dir.join(format!("spnn-psk-bad-{}", std::process::id()));
        std::fs::write(&good, "the real key\n").unwrap();
        std::fs::write(&bad, "an impostor key\n").unwrap();
        let mut s = spec("secureml");
        s.tc.psk_file = Some(good.to_string_lossy().into_owned());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = LaunchOpts { listen: addr.clone(), spawn: false, chaos: None };
        let good_psk = Psk::from_file(&good).unwrap();
        let bad_psk = Psk::from_file(&bad).unwrap();
        let mut workers = Vec::new();
        for (role, key) in
            [("party0", good_psk.clone()), ("dealer", bad_psk), ("party1", good_psk)]
        {
            let addr = addr.clone();
            workers.push(std::thread::spawn(move || {
                run_party(&addr, role, "127.0.0.1", Some(&key), None, None, None)
            }));
        }
        let err = run_launch_on(listener, &s, &opts).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("PSK authentication"), "{msg}");
        assert!(msg.contains("dealer"), "diagnostic must name the role: {msg}");
        // the workers all fail one way or another once the host aborts
        for w in workers {
            assert!(w.join().unwrap().is_err());
        }
        let _ = std::fs::remove_file(&good);
        let _ = std::fs::remove_file(&bad);
    }

    /// Serve-mode launch, in-thread: the coordinator hosts a serve session
    /// (`spec.serve` rides the config broadcast, so the thread "processes"
    /// build serve deployments from it), a client scores rows through the
    /// queue mid-session, and the answers are bit-identical to an
    /// in-process netsim serve of the same config.
    #[test]
    fn serve_launch_in_threads_scores_like_netsim() {
        use crate::serve::{request_scores, ServeOpts};
        let mut s = spec("spnn-ss");
        s.tc.lr_override = Some(0.05);
        s.serve = Some(ServeOpts { coalesce: 16, depth: 2, ..Default::default() });
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = LaunchOpts { listen: addr.clone(), spawn: false, chaos: None };

        let mut workers = Vec::new();
        for role in ["server", "dealer", "holder0", "holder1"] {
            let addr = addr.clone();
            workers.push(std::thread::spawn(move || {
                run_party(&addr, role, "127.0.0.1", None, None, None, None)
            }));
        }
        let (tx, rx) = std::sync::mpsc::channel();
        let rows: Vec<u32> = (0..21).collect(); // ragged through coalesce 16
        let client = std::thread::spawn({
            let rows = rows.clone();
            move || {
                let scores = request_scores(&tx, &rows);
                // dropping tx ends the session
                scores
            }
        });
        let rep = run_serve_on(listener, &s, &opts, rx).unwrap();
        let scores = client.join().unwrap().unwrap();
        for w in workers {
            w.join().unwrap().unwrap();
        }
        assert_eq!(scores.len(), rows.len());
        assert_ne!(rep.weight_digest, 0);

        // reference: the identical config served fully in-process (netsim)
        let (cfg, train, test) = s.datasets().unwrap();
        let mut tc = s.tc.clone();
        tc.transport = crate::config::TransportKind::Netsim;
        let h = crate::serve::serve(
            crate::protocols::by_name("spnn-ss").unwrap(),
            cfg,
            &tc,
            crate::netsim::LinkSpec::from_mbps(s.mbps),
            &train,
            &test,
            2,
            s.serve.as_ref().unwrap(),
        )
        .unwrap();
        let want = h.infer(&rows).unwrap();
        let ref_rep = h.shutdown().unwrap();
        assert_eq!(rep.weight_digest, ref_rep.weight_digest);
        for (i, (got, w)) in scores.iter().zip(&want).enumerate() {
            assert_eq!(
                got.to_bits(),
                w.to_bits(),
                "row {i}: multi-process serve diverged from netsim"
            );
        }
    }

    #[test]
    fn unknown_protocol_is_rejected_before_binding() {
        let s = spec("quantum-ml");
        let opts = LaunchOpts { listen: "127.0.0.1:0".into(), spawn: false, chaos: None };
        assert!(run_launch(&s, &opts).is_err());
    }

    #[test]
    fn chaos_role_must_exist() {
        let s = spec("secureml");
        let opts = LaunchOpts {
            listen: "127.0.0.1:0".into(),
            spawn: true,
            chaos: Some(("astronaut".into(), 5)),
        };
        let err = run_launch(&s, &opts).unwrap_err();
        assert!(format!("{err}").contains("astronaut"), "{err}");
    }

    #[test]
    fn chaos_is_rejected_in_no_spawn_mode() {
        // silently ignoring the drill would let an operator believe the
        // reconnect path was exercised when it never was
        let s = spec("secureml");
        let opts = LaunchOpts {
            listen: "127.0.0.1:0".into(),
            spawn: false,
            chaos: Some(("dealer".into(), 5)),
        };
        let err = run_launch(&s, &opts).unwrap_err();
        assert!(format!("{err}").contains("spawn mode"), "{err}");
    }
}
