//! Multi-process deployment runner: the engine behind `spnn launch` and
//! `spnn party`.
//!
//! * [`run_party`] — one worker process: join the session, rebuild the
//!   deployment locally from the broadcast config (datasets re-synthesize
//!   deterministically from the seed — private inputs never travel), run
//!   this party's role body over a [`TcpPort`], ship the [`PartyOut`]
//!   back to the coordinator, flush and exit.
//! * [`run_launch`] — the coordinator process: host the rendezvous
//!   (optionally spawning the other roles as child OS processes of the
//!   same binary), run the coordinator role, collect every worker's
//!   `PartyOut` over the wire, and assemble the final [`TrainReport`]
//!   through the trainer's `finish` step — producing the same
//!   `weight_digest` an in-process run reports (asserted by the
//!   decentralized smoke test).
//!
//! Traffic accounting: each process counts the bytes *it* sends (the same
//! sender-side accounting netsim uses) and reports them as metrics in its
//! `PartyOut`; the coordinator sums them into whole-mesh totals. Virtual
//! time still works — departure stamps ride the wire frames — so reports
//! carry both sim-time and wall-clock numbers.

use std::net::TcpListener;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::session::{self, SessionSpec};
use super::tcp::{port_from_streams, TcpPort};
use crate::netsim::{NetStats, Phase};
use crate::parties::{self, Deployment, NetSummary};
use crate::protocols::{self, TrainReport};
use crate::{Error, Result};

/// Whole-session rendezvous deadline (covers process spawn + handshake).
pub const SESSION_TIMEOUT: Duration = Duration::from_secs(120);

fn trainer_for(spec: &SessionSpec) -> Result<Box<dyn protocols::Trainer>> {
    protocols::by_name(&spec.protocol)
        .ok_or_else(|| Error::Config(format!("unknown protocol {:?}", spec.protocol)))
}

/// Trainer + deployment + the pieces `finish` needs later, so the
/// (potentially large) synthetic dataset is derived exactly once.
struct Prepared {
    trainer: Box<dyn protocols::Trainer>,
    dep: Deployment,
    cfg: &'static crate::config::ModelConfig,
    test: crate::data::Dataset,
}

fn build_deployment(spec: &SessionSpec) -> Result<Prepared> {
    let trainer = trainer_for(spec)?;
    let (cfg, train, test) = spec.datasets()?;
    crate::exec::set_default_threads(spec.tc.exec_threads);
    let dep = trainer.deployment(cfg, &spec.tc, &train, &test, spec.holders)?;
    Ok(Prepared { trainer, dep, cfg, test })
}

/// Per-party sender-side byte totals, attached to the shipped `PartyOut`.
fn traffic_metrics(stats: &NetStats, id: usize) -> Vec<(String, f64)> {
    vec![
        ("online_bytes_sent".into(), stats.bytes_sent_by(id, Phase::Online) as f64),
        ("offline_bytes_sent".into(), stats.bytes_sent_by(id, Phase::Offline) as f64),
    ]
}

/// Run one worker party: `spnn party --role <role> --connect <addr>`.
pub fn run_party(connect: &str, role: &str, bind_host: &str) -> Result<()> {
    let sess = session::join(connect, role, bind_host, SESSION_TIMEOUT)?;
    let Prepared { dep, .. } = build_deployment(&sess.spec)?;
    if dep.names.len() != sess.n {
        return Err(Error::Protocol(format!(
            "topology mismatch: local deployment has {} parties, session has {}",
            dep.names.len(),
            sess.n
        )));
    }
    if dep.names.get(sess.id).map(|s| s.as_str()) != Some(role) {
        return Err(Error::Protocol(format!(
            "topology mismatch: session assigned id {} but local role table says {:?}",
            sess.id,
            dep.names.get(sess.id)
        )));
    }
    eprintln!(
        "spnn party: joined as {role} (party {}/{}) for {} on {}",
        sess.id,
        sess.n,
        sess.spec.protocol,
        sess.spec.dataset
    );
    let name_refs: Vec<&str> = dep.names.iter().map(|s| s.as_str()).collect();
    let stats = Arc::new(NetStats::new(&name_refs));
    let (port, writers) =
        port_from_streams(sess.id, &name_refs, sess.streams, sess.spec.link(), stats.clone())?;
    let mut port = TcpPort::new(port, writers, stats.clone());

    let f = dep
        .fns
        .into_iter()
        .nth(sess.id)
        .ok_or_else(|| Error::Protocol("role body missing".into()))?;
    let mut out = f(&mut port)?;
    out.metrics.extend(traffic_metrics(&stats, sess.id));
    parties::send_party_out(&mut port, 0, &out)?;
    port.shutdown(); // join writers: the PartyOut is flushed before exit
    eprintln!("spnn party: {role} done (sim {:.2}s)", out.sim_time);
    Ok(())
}

/// Options for [`run_launch`].
pub struct LaunchOpts {
    /// Rendezvous bind address (`127.0.0.1:0` = ephemeral loopback).
    pub listen: String,
    /// Spawn the worker roles as child processes of this binary. When
    /// false, the launcher prints the `spnn party` command lines and waits
    /// for manual joins (multi-terminal / multi-host mode).
    pub spawn: bool,
}

/// Kill-on-drop guard so a failed rendezvous never leaves orphan workers.
struct ChildGuard(Vec<(String, Child)>);

impl ChildGuard {
    fn wait_all(&mut self) -> Result<()> {
        for (role, child) in self.0.drain(..) {
            let status = child.wait_with_output().map_err(Error::Io)?;
            if !status.status.success() {
                return Err(Error::Protocol(format!(
                    "party process {role} exited with {:?}",
                    status.status.code()
                )));
            }
        }
        Ok(())
    }
}

impl Drop for ChildGuard {
    fn drop(&mut self) {
        for (_, child) in self.0.iter_mut() {
            let _ = child.kill();
        }
        for (_, mut child) in self.0.drain(..) {
            let _ = child.wait();
        }
    }
}

/// Host a full decentralized run: rendezvous + coordinator role + result
/// collection + report assembly.
pub fn run_launch(spec: &SessionSpec, opts: &LaunchOpts) -> Result<TrainReport> {
    let listener = TcpListener::bind(&opts.listen)
        .map_err(|e| Error::Net(format!("bind {}: {e}", opts.listen)))?;
    run_launch_on(listener, spec, opts)
}

/// [`run_launch`] on an already-bound rendezvous listener (lets callers
/// learn the ephemeral port before the workers need it).
pub fn run_launch_on(
    listener: TcpListener,
    spec: &SessionSpec,
    opts: &LaunchOpts,
) -> Result<TrainReport> {
    let wall = Instant::now();
    let Prepared { trainer, dep, cfg, test } = build_deployment(spec)?;
    let n = dep.names.len();
    let addr = listener.local_addr().map_err(Error::Io)?.to_string();

    let mut guard = ChildGuard(Vec::new());
    if opts.spawn {
        let exe = std::env::current_exe().map_err(Error::Io)?;
        for role in &dep.names[1..] {
            let child = Command::new(&exe)
                .args(["party", "--role", role.as_str(), "--connect", addr.as_str()])
                .stdin(Stdio::null())
                .stdout(Stdio::null()) // keep the report stream clean
                .stderr(Stdio::inherit())
                .spawn()
                .map_err(Error::Io)?;
            guard.0.push((role.clone(), child));
        }
        eprintln!("spnn launch: spawned {} party processes, rendezvous on {addr}", n - 1);
    } else {
        eprintln!("spnn launch: waiting for {} manual joins; run in other terminals:", n - 1);
        for role in &dep.names[1..] {
            eprintln!("  spnn party --role {role} --connect {addr}");
        }
    }

    let hosted = session::host(&listener, spec, &dep.names, SESSION_TIMEOUT)?;
    let name_refs: Vec<&str> = dep.names.iter().map(|s| s.as_str()).collect();
    let stats = Arc::new(NetStats::new(&name_refs));
    let (port, writers) =
        port_from_streams(0, &name_refs, hosted.streams, spec.link(), stats.clone())?;
    let mut port = TcpPort::new(port, writers, stats.clone());

    let mut fns = dep.fns;
    let f0 = fns.remove(0);
    let mut outs = vec![f0(&mut port)?];
    for id in 1..n {
        outs.push(parties::recv_party_out(&mut port, id)?);
    }
    port.shutdown();
    guard.wait_all()?;

    // whole-mesh totals = own sends + every worker's reported sends
    let mut online = stats.bytes_phase(Phase::Online);
    let mut offline = stats.bytes_phase(Phase::Offline);
    for out in &outs[1..] {
        online += out.metric("online_bytes_sent").unwrap_or(0.0) as usize;
        offline += out.metric("offline_bytes_sent").unwrap_or(0.0) as usize;
    }
    let net =
        NetSummary { online_bytes: online, offline_bytes: offline, stages: stats.stage_rows() };
    trainer.finish(cfg, &spec.tc, &test, &outs, net, wall.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    fn spec(proto: &str) -> SessionSpec {
        SessionSpec {
            protocol: proto.into(),
            dataset: "fraud".into(),
            rows: 320,
            holders: 2,
            mbps: 100.0,
            tc: TrainConfig { epochs: 1, batch: 128, ..Default::default() },
        }
    }

    /// In-process version of the multi-process flow: the launcher hosts
    /// with `spawn: false` while threads play the worker processes via
    /// `run_party` against the same rendezvous — exercising the entire
    /// session + runner + result-collection path without forking.
    #[test]
    fn launch_and_parties_in_threads_match_netsim_digest() {
        let mut s = spec("secureml"); // artifact-free protocol, runs anywhere
        s.tc.lr_override = Some(0.05);
        // bind the rendezvous first so the "workers" know its port
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = LaunchOpts { listen: addr.clone(), spawn: false };

        let roles = ["party0", "dealer", "party1"];
        let mut workers = Vec::new();
        for role in roles {
            let addr = addr.clone();
            workers.push(std::thread::spawn(move || run_party(&addr, role, "127.0.0.1")));
        }
        let rep = run_launch_on(listener, &s, &opts).unwrap();
        for w in workers {
            w.join().unwrap().unwrap();
        }
        assert_ne!(rep.weight_digest, 0);
        assert!(rep.online_bytes > 0, "worker traffic not aggregated");

        // the same config through the ordinary in-process netsim path
        // must produce the identical model
        use crate::netsim::LinkSpec;
        use crate::protocols::Trainer;
        let (cfg, train, test) = s.datasets().unwrap();
        let mut tc = s.tc.clone();
        tc.transport = crate::config::TransportKind::Netsim;
        let local = crate::protocols::secureml::SecureMl
            .train(cfg, &tc, LinkSpec::from_mbps(s.mbps), &train, &test, 2)
            .unwrap();
        assert_eq!(
            rep.weight_digest, local.weight_digest,
            "distributed run diverged from the in-process run"
        );
        assert_eq!(rep.train_losses, local.train_losses);
    }

    #[test]
    fn unknown_protocol_is_rejected_before_binding() {
        let s = spec("quantum-ml");
        let opts = LaunchOpts { listen: "127.0.0.1:0".into(), spawn: false };
        assert!(run_launch(&s, &opts).is_err());
    }
}
