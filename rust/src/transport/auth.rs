//! Pre-shared-key authentication for the session rendezvous: a
//! hand-rolled SHA-256 / HMAC-SHA-256 (FIPS 180-4 / RFC 2104, zero
//! dependencies like the rest of the [`bignum`](crate::bignum)-style
//! crypto substrate) plus the challenge/response proofs the handshake
//! exchanges.
//!
//! # Threat model
//!
//! The PR-3 session token is a *consistency* check: it keeps a stray
//! client of a different session from wiring into the mesh, but anyone
//! who can reach the rendezvous port can claim a role. With a PSK
//! (`spnn launch --psk-file` / `spnn party --psk-file`) the rendezvous
//! becomes mutually authenticated:
//!
//! * the party's `hello` carries a fresh nonce `Na`;
//! * the coordinator answers with its own nonce `Nb` **and a proof**
//!   `HMAC(psk, "spnn-auth-host" ‖ Na ‖ Nb ‖ role)` — so a party with the
//!   key never talks to an impostor coordinator;
//! * the party answers `HMAC(psk, "spnn-auth-party" ‖ Na ‖ Nb ‖ role)` —
//!   so the coordinator aborts the whole session (naming the role) when
//!   any joiner holds a wrong or missing key;
//! * the peer-mesh session token is re-derived as an HMAC of the config
//!   wire string under the PSK, so direct party-to-party connections are
//!   tied to the key as well.
//!
//! The nonces make the proofs non-replayable across sessions. What the
//! PSK does **not** provide is confidentiality or integrity of the
//! subsequent traffic (no TLS in a zero-dependency build): run the mesh
//! on a trusted network or through an external tunnel — see
//! `docs/DEPLOYMENT.md`.

use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::{Error, Result};

// ---------------------------------------------------------------------------
// SHA-256 (FIPS 180-4)
// ---------------------------------------------------------------------------

const SHA256_K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

const SHA256_H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// Incremental SHA-256 hasher (streaming `update` + `finalize`).
pub struct Sha256 {
    state: [u32; 8],
    /// Partial block awaiting 64 accumulated bytes.
    buf: [u8; 64],
    buf_len: usize,
    /// Total message length so far, in bytes.
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    /// Fresh hasher in the FIPS 180-4 initial state.
    pub fn new() -> Self {
        Sha256 { state: SHA256_H0, buf: [0u8; 64], buf_len: 0, total: 0 }
    }

    fn compress(state: &mut [u32; 8], block: &[u8]) {
        debug_assert_eq!(block.len(), 64);
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = *state;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(SHA256_K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        state[0] = state[0].wrapping_add(a);
        state[1] = state[1].wrapping_add(b);
        state[2] = state[2].wrapping_add(c);
        state[3] = state[3].wrapping_add(d);
        state[4] = state[4].wrapping_add(e);
        state[5] = state[5].wrapping_add(f);
        state[6] = state[6].wrapping_add(g);
        state[7] = state[7].wrapping_add(h);
    }

    /// Absorb `data` (callable any number of times, any chunking).
    pub fn update(&mut self, data: &[u8]) {
        self.total = self.total.wrapping_add(data.len() as u64);
        let mut rest = data;
        if self.buf_len > 0 {
            let want = 64 - self.buf_len;
            let take = want.min(rest.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&rest[..take]);
            self.buf_len += take;
            rest = &rest[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                Self::compress(&mut self.state, &block);
                self.buf_len = 0;
            }
        }
        let mut chunks = rest.chunks_exact(64);
        for block in &mut chunks {
            Self::compress(&mut self.state, block);
        }
        let tail = chunks.remainder();
        self.buf[..tail.len()].copy_from_slice(tail);
        self.buf_len = tail.len();
    }

    /// Apply the FIPS padding and return the 32-byte digest.
    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total.wrapping_mul(8);
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0x00]);
        }
        // length update must not re-count the pad: write the block directly
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        Self::compress(&mut self.state, &block);
        let mut out = [0u8; 32];
        for (i, word) in self.state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }
}

/// One-shot SHA-256.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize()
}

/// HMAC-SHA-256 (RFC 2104): keys longer than the 64-byte block are
/// hashed first, shorter ones zero-padded.
pub fn hmac_sha256(key: &[u8], msg: &[u8]) -> [u8; 32] {
    let mut k = [0u8; 64];
    if key.len() > 64 {
        k[..32].copy_from_slice(&sha256(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut ipad = [0u8; 64];
    let mut opad = [0u8; 64];
    for ((ib, ob), &kb) in ipad.iter_mut().zip(opad.iter_mut()).zip(k.iter()) {
        *ib = kb ^ 0x36;
        *ob = kb ^ 0x5c;
    }
    let mut inner = Sha256::new();
    inner.update(&ipad);
    inner.update(msg);
    let inner = inner.finalize();
    let mut outer = Sha256::new();
    outer.update(&opad);
    outer.update(&inner);
    outer.finalize()
}

/// Lowercase hex encoding.
pub fn to_hex(bytes: &[u8]) -> String {
    const DIGITS: &[u8; 16] = b"0123456789abcdef";
    let mut s = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(DIGITS[(b >> 4) as usize] as char);
        s.push(DIGITS[(b & 0xf) as usize] as char);
    }
    s
}

/// Decode lowercase/uppercase hex (even length required).
pub fn from_hex(s: &str) -> Result<Vec<u8>> {
    if s.len() % 2 != 0 {
        return Err(Error::Protocol(format!("odd-length hex string ({} chars)", s.len())));
    }
    let nib = |c: u8| -> Result<u8> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => Err(Error::Protocol(format!("bad hex digit {:?}", c as char))),
        }
    };
    let b = s.as_bytes();
    (0..s.len() / 2).map(|i| Ok((nib(b[2 * i])? << 4) | nib(b[2 * i + 1])?)).collect()
}

/// Constant-time byte-slice equality (no early exit on mismatch).
pub fn ct_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut acc = 0u8;
    for (x, y) in a.iter().zip(b) {
        acc |= x ^ y;
    }
    acc == 0
}

/// Fresh 16-byte handshake nonce: unique, not secret (nonces travel in
/// the clear; only the HMAC proofs depend on the key). Mixes wall time,
/// the process id and a process-local counter through SHA-256.
pub fn fresh_nonce() -> [u8; 16] {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let mut h = Sha256::new();
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    h.update(&t.to_le_bytes());
    h.update(&std::process::id().to_le_bytes());
    h.update(&COUNTER.fetch_add(1, Ordering::Relaxed).to_le_bytes());
    let d = h.finalize();
    let mut out = [0u8; 16];
    out.copy_from_slice(&d[..16]);
    out
}

// ---------------------------------------------------------------------------
// Pre-shared key
// ---------------------------------------------------------------------------

/// A loaded pre-shared key. `Debug` prints a redacted placeholder so the
/// secret can never leak through diagnostics.
#[derive(Clone, PartialEq, Eq)]
pub struct Psk(Vec<u8>);

impl fmt::Debug for Psk {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Psk(<{} bytes, redacted>)", self.0.len())
    }
}

/// Domain-separation label for the coordinator-side handshake proof.
const CTX_HOST: &str = "spnn-auth-host";
/// Domain-separation label for the party-side handshake proof.
const CTX_PARTY: &str = "spnn-auth-party";

impl Psk {
    /// Wrap raw key bytes (tests; operators use [`Psk::from_file`]).
    pub fn from_bytes(bytes: &[u8]) -> Psk {
        Psk(bytes.to_vec())
    }

    /// Load the key from a file, trimming trailing ASCII whitespace (so
    /// `echo secret > key` and binary key files both work). Empty files
    /// are rejected.
    pub fn from_file(path: &Path) -> Result<Psk> {
        let mut bytes = std::fs::read(path)
            .map_err(|e| Error::Config(format!("psk file {}: {e}", path.display())))?;
        while bytes.last().is_some_and(|b| b.is_ascii_whitespace()) {
            bytes.pop();
        }
        if bytes.is_empty() {
            return Err(Error::Config(format!(
                "psk file {} is empty after trimming whitespace",
                path.display()
            )));
        }
        Ok(Psk(bytes))
    }

    fn proof(&self, ctx: &str, nonce_a: &[u8], nonce_b: &[u8], role: &str) -> [u8; 32] {
        // unambiguous framing: fixed label, length-prefixed fields
        let cap = ctx.len() + nonce_a.len() + nonce_b.len() + role.len() + 16;
        let mut msg = Vec::with_capacity(cap);
        msg.extend_from_slice(ctx.as_bytes());
        for field in [nonce_a, nonce_b, role.as_bytes()] {
            msg.extend_from_slice(&(field.len() as u32).to_le_bytes());
            msg.extend_from_slice(field);
        }
        hmac_sha256(&self.0, &msg)
    }

    /// Coordinator-side proof over both nonces and the claimed role (hex).
    pub fn host_proof(&self, nonce_a: &[u8], nonce_b: &[u8], role: &str) -> String {
        to_hex(&self.proof(CTX_HOST, nonce_a, nonce_b, role))
    }

    /// Party-side proof over both nonces and the claimed role (hex).
    pub fn party_proof(&self, nonce_a: &[u8], nonce_b: &[u8], role: &str) -> String {
        to_hex(&self.proof(CTX_PARTY, nonce_a, nonce_b, role))
    }

    /// Verify a hex proof in constant time.
    pub fn verify_host(&self, proof_hex: &str, nonce_a: &[u8], nonce_b: &[u8], role: &str) -> bool {
        match from_hex(proof_hex) {
            Ok(p) => ct_eq(&p, &self.proof(CTX_HOST, nonce_a, nonce_b, role)),
            Err(_) => false,
        }
    }

    /// Verify a hex proof in constant time.
    pub fn verify_party(
        &self,
        proof_hex: &str,
        nonce_a: &[u8],
        nonce_b: &[u8],
        role: &str,
    ) -> bool {
        match from_hex(proof_hex) {
            Ok(p) => ct_eq(&p, &self.proof(CTX_PARTY, nonce_a, nonce_b, role)),
            Err(_) => false,
        }
    }

    /// Keyed session token for the peer mesh: replaces the unauthenticated
    /// config-digest token when a PSK is in force, so party-to-party
    /// connections also require the key.
    pub fn mesh_token(&self, cfg_wire: &str, rendezvous: &str) -> u64 {
        let mut msg = Vec::with_capacity(cfg_wire.len() + rendezvous.len() + 16);
        msg.extend_from_slice(b"spnn-mesh-token");
        for field in [cfg_wire.as_bytes(), rendezvous.as_bytes()] {
            msg.extend_from_slice(&(field.len() as u32).to_le_bytes());
            msg.extend_from_slice(field);
        }
        let d = hmac_sha256(&self.0, &msg);
        u64::from_le_bytes([d[0], d[1], d[2], d[3], d[4], d[5], d[6], d[7]])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sha256_fips_vectors() {
        assert_eq!(
            to_hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            to_hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            to_hex(&sha256(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq")),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn sha256_streaming_matches_one_shot_at_any_chunking() {
        // includes lengths that straddle the 55/56/64-byte padding edges
        let data: Vec<u8> = (0u32..300).map(|i| (i * 7 + 3) as u8).collect();
        for len in [0, 1, 55, 56, 57, 63, 64, 65, 127, 128, 129, 300] {
            let one = sha256(&data[..len]);
            for chunk in [1, 3, 7, 64, 300] {
                let mut h = Sha256::new();
                for c in data[..len].chunks(chunk) {
                    h.update(c);
                }
                assert_eq!(h.finalize(), one, "len {len} chunk {chunk}");
            }
        }
    }

    #[test]
    fn hmac_rfc4231_vectors() {
        // case 1
        assert_eq!(
            to_hex(&hmac_sha256(&[0x0b; 20], b"Hi There")),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
        // case 2
        assert_eq!(
            to_hex(&hmac_sha256(b"Jefe", b"what do ya want for nothing?")),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
        // long key (> block size) takes the hashed-key path
        let long_key = [0xaa; 131];
        let got = hmac_sha256(&long_key, b"Test Using Larger Than Block-Size Key - Hash Key First");
        assert_eq!(
            to_hex(&got),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn hex_roundtrip_and_errors() {
        let bytes = [0x00, 0x7f, 0x80, 0xff, 0x3c];
        assert_eq!(from_hex(&to_hex(&bytes)).unwrap(), bytes);
        assert_eq!(from_hex("0AfF").unwrap(), vec![0x0a, 0xff]);
        assert!(from_hex("abc").is_err());
        assert!(from_hex("zz").is_err());
    }

    #[test]
    fn ct_eq_semantics() {
        assert!(ct_eq(b"abc", b"abc"));
        assert!(!ct_eq(b"abc", b"abd"));
        assert!(!ct_eq(b"abc", b"ab"));
        assert!(ct_eq(b"", b""));
    }

    #[test]
    fn nonces_are_unique() {
        let mut seen = std::collections::HashSet::new();
        for _ in 0..64 {
            assert!(seen.insert(fresh_nonce()));
        }
    }

    #[test]
    fn proofs_verify_and_bind_every_field() {
        let k = Psk::from_bytes(b"correct horse battery staple");
        let (na, nb) = (fresh_nonce(), fresh_nonce());
        let hp = k.host_proof(&na, &nb, "server");
        let pp = k.party_proof(&na, &nb, "server");
        assert_ne!(hp, pp, "host/party proofs must be domain-separated");
        assert!(k.verify_host(&hp, &na, &nb, "server"));
        assert!(k.verify_party(&pp, &na, &nb, "server"));
        // any changed field invalidates
        assert!(!k.verify_host(&hp, &nb, &na, "server"));
        assert!(!k.verify_host(&hp, &na, &nb, "dealer"));
        assert!(!k.verify_party(&hp, &na, &nb, "server"), "proof contexts must not cross");
        let other = Psk::from_bytes(b"wrong key");
        assert!(!other.verify_host(&hp, &na, &nb, "server"));
        // garbage proofs are rejected, not panicked on
        assert!(!k.verify_host("not hex", &na, &nb, "server"));
    }

    #[test]
    fn mesh_token_depends_on_key_config_and_address() {
        let a = Psk::from_bytes(b"alpha");
        let b = Psk::from_bytes(b"beta");
        let t = a.mesh_token("cfg v1", "127.0.0.1:7000");
        assert_ne!(t, b.mesh_token("cfg v1", "127.0.0.1:7000"));
        assert_ne!(t, a.mesh_token("cfg v2", "127.0.0.1:7000"));
        assert_ne!(t, a.mesh_token("cfg v1", "127.0.0.1:7001"));
        assert_eq!(t, a.mesh_token("cfg v1", "127.0.0.1:7000"));
    }

    #[test]
    fn psk_file_loads_trimmed_and_rejects_empty() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("spnn-psk-test-{}", std::process::id()));
        std::fs::write(&path, "sekrit\n").unwrap();
        let k = Psk::from_file(&path).unwrap();
        assert_eq!(k, Psk::from_bytes(b"sekrit"));
        // Debug must never print the key material
        let dbg = format!("{k:?}");
        assert!(!dbg.contains("sekrit"), "{dbg}");
        std::fs::write(&path, "  \n\n").unwrap();
        assert!(Psk::from_file(&path).is_err());
        let _ = std::fs::remove_file(&path);
        assert!(Psk::from_file(Path::new("/nonexistent/psk")).is_err());
    }
}
