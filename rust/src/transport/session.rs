//! Session rendezvous for multi-process deployments: role claim,
//! optional PSK challenge/response authentication, config + seed
//! exchange, full-mesh bring-up and a topology check, all over the same
//! [`wire`] framing the training traffic uses.
//!
//! ```text
//! party                within the rendezvous           coordinator (host)
//! -----                ---------------------           ------------------
//! connect ------------------------------------------>  accept
//! "spnn-hello v1 role=<role> nonce=<Na>" ----------->  claim role -> id
//! [PSK only] <------------- "spnn-auth v1 nonce=<Nb> proof=<HMAC(host)>"
//! [PSK only] verify host proof
//! [PSK only] "spnn-auth-proof v1 proof=<HMAC(party)>" -> verify or ABORT
//! <----------- "spnn-welcome v1 id=.. n=.. token=.. cfg=<config string>"
//! bind peer listener
//! "spnn-listen <addr>" ----------------------------->  collect all
//! <--------------------------- "spnn-roster 1@a1;2@a2;..."  (broadcast)
//! dial peers with lower id / accept peers with higher id
//!   each new pair connection opens with "spnn-peer v1 id=.. token=.."
//! "spnn-ready digest=<d>" -------------------------->  verify all equal
//! <------------------------------------------------- "spnn-go"
//! ```
//!
//! The coordinator is the single source of truth for the training
//! configuration: it ships the canonical [`SessionSpec`] wire string in
//! the welcome, every party re-derives its local state (dataset synthesis,
//! batch plan, RNG seeds) from it, and echoes the config digest back in
//! `ready` so drift is caught before any training traffic flows.
//!
//! Without a PSK, the token (derived from the config and the rendezvous
//! address) keeps a stray client of a *different* session from wiring
//! into the mesh — a consistency check, not auth. With `--psk-file` on
//! both sides the rendezvous is mutually authenticated by the HMAC
//! proofs ([`super::auth`]), a wrong or missing key on any party aborts
//! the whole session with a diagnostic naming the role, and the mesh
//! token itself becomes an HMAC under the key so peer connections
//! require it too.
//!
//! After `go`, the [`JoinedSession`] keeps its peer listener and the
//! roster alive: the resilient links ([`super::relink`]) use them to
//! re-accept / re-dial dropped connections mid-training.

use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use super::auth::{self, Psk};
use super::tcp::connect_retry;
use super::wire;
use crate::config::{CompressCfg, ModelConfig, TrainConfig, TransportKind};
use crate::data::{synth_distress, synth_fraud, Dataset, SynthOpts};
use crate::netsim::{LinkSpec, Msg, PartyId, Payload, Phase, NO_TAG};
use crate::protocols::common::Fnv;
use crate::{Error, Result};

/// Handshake read deadline per step.
pub const HANDSHAKE_STEP_TIMEOUT: Duration = Duration::from_secs(30);

/// Everything a party needs to reconstruct the full training setup
/// locally: the canonical config record the coordinator broadcasts.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionSpec {
    /// Protocol name (`protocols::by_name`).
    pub protocol: String,
    /// Dataset name (`ModelConfig::by_name`).
    pub dataset: String,
    /// Synthetic dataset rows before the train/test split.
    pub rows: usize,
    /// Data-holder count.
    pub holders: usize,
    /// Modeled link bandwidth (the virtual clock works across backends).
    pub mbps: f64,
    /// All remaining training knobs (seed, epochs, batch, crypto, depth).
    pub tc: TrainConfig,
    /// Serve mode (`spnn serve --launch`): after training, the parties
    /// stay resident and answer inference requests with these knobs.
    /// `None` = ordinary train-and-exit session.
    pub serve: Option<crate::serve::ServeOpts>,
}

fn fmt_opt(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x}"),
        None => "-".into(),
    }
}

fn parse_opt(s: &str) -> Result<Option<f64>> {
    if s == "-" {
        return Ok(None);
    }
    s.parse::<f64>()
        .map(Some)
        .map_err(|_| Error::Config(format!("bad optional float {s:?}")))
}

impl SessionSpec {
    /// Canonical wire string. `Display` for `f64` prints the shortest
    /// representation that round-trips, so parse(to_wire()) is exact.
    /// The PSK path (`tc.psk_file`) deliberately does **not** appear:
    /// each process loads its own key material locally and proves
    /// possession through the handshake instead of shipping anything.
    pub fn to_wire(&self) -> String {
        let t = &self.tc;
        let mut s = format!(
            "spnn-cfg v1 proto={} ds={} rows={} holders={} mbps={} epochs={} batch={} \
             seed={} sgld={} lr={} noise={} pbits={} shortexp={} slot={} threads={} depth={}",
            self.protocol,
            self.dataset,
            self.rows,
            self.holders,
            self.mbps,
            t.epochs,
            t.batch,
            t.seed,
            t.sgld as u8,
            fmt_opt(t.lr_override),
            fmt_opt(t.sgld_noise),
            t.paillier_bits,
            t.paillier_short_exp as u8,
            t.slot_bits,
            t.exec_threads,
            t.pipeline_depth,
        );
        // bounded-staleness asynchrony rides the broadcast (every party
        // must drive the same lag schedule); absent when 0 so earlier
        // wire strings (and their digests) are unchanged
        if t.staleness != 0 {
            s.push_str(&format!(" stale={}", t.staleness));
        }
        // the feature-compression knob rides the broadcast in its
        // canonical form (field absent = uncompressed, keeping old wire
        // strings parseable and their digests unchanged)
        if let Some(cc) = &t.compress {
            s.push_str(&format!(" compress={}", cc.canonical()));
        }
        // warm-start (serve --from-checkpoint) rides the broadcast so all
        // parties run the zero-epoch schedule; absent when false so every
        // earlier wire string (and its digest) is unchanged
        if t.warm_start {
            s.push_str(" warm=1");
        }
        // serve mode rides the config broadcast so every worker process
        // builds the serve deployment (field absent = train-and-exit,
        // keeping old wire strings parseable). The timeout and max-queue
        // fields are only emitted when set, so earlier wire strings stay
        // identical.
        if let Some(sv) = &self.serve {
            if sv.max_queue != 0 {
                s.push_str(&format!(
                    " serve={},{},{},{}",
                    sv.coalesce, sv.depth, sv.request_timeout_ms, sv.max_queue
                ));
            } else if sv.request_timeout_ms != 0 {
                s.push_str(&format!(
                    " serve={},{},{}",
                    sv.coalesce, sv.depth, sv.request_timeout_ms
                ));
            } else {
                s.push_str(&format!(" serve={},{}", sv.coalesce, sv.depth));
            }
        }
        s
    }

    /// Parse the canonical wire string back into a spec (the party side
    /// of the config broadcast).
    pub fn from_wire(s: &str) -> Result<Self> {
        let mut words = s.split_whitespace();
        if words.next() != Some("spnn-cfg") || words.next() != Some("v1") {
            return Err(Error::Config(format!("not a session config: {s:?}")));
        }
        let mut kv = std::collections::HashMap::new();
        for w in words {
            let (k, v) = w
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("bad config field {w:?}")))?;
            kv.insert(k, v);
        }
        let get = |k: &str| -> Result<&str> {
            kv.get(k).copied().ok_or_else(|| Error::Config(format!("config missing {k}")))
        };
        let num = |k: &str| -> Result<usize> {
            get(k)?.parse().map_err(|_| Error::Config(format!("bad {k}={:?}", kv[k])))
        };
        let fnum = |k: &str| -> Result<f64> {
            get(k)?.parse().map_err(|_| Error::Config(format!("bad {k}={:?}", kv[k])))
        };
        let compress = match kv.get("compress") {
            None => None,
            Some(v) => Some(CompressCfg::parse(v).ok_or_else(|| {
                Error::Config(format!("bad compress={v:?} in session config"))
            })?),
        };
        let tc = TrainConfig {
            batch: num("batch")?,
            epochs: num("epochs")?,
            sgld: get("sgld")? == "1",
            seed: get("seed")?
                .parse()
                .map_err(|_| Error::Config(format!("bad seed={:?}", kv["seed"])))?,
            lr_override: parse_opt(get("lr")?)?,
            paillier_bits: num("pbits")?,
            paillier_short_exp: get("shortexp")? == "1",
            sgld_noise: parse_opt(get("noise")?)?,
            slot_bits: num("slot")?,
            exec_threads: num("threads")?,
            pipeline_depth: num("depth")?,
            // absent = 0 keeps every pre-staleness wire string parseable
            staleness: match kv.get("stale") {
                None => 0,
                Some(v) => v
                    .parse()
                    .map_err(|_| Error::Config(format!("bad stale={v:?}")))?,
            },
            transport: TransportKind::Tcp,
            psk_file: None,
            compress,
            // local-only (never broadcast): each process points the flag
            // at its own disk, like psk_file
            checkpoint_dir: None,
            warm_start: kv.get("warm").copied() == Some("1"),
            checkpoint_keep: None,
        };
        let serve = match kv.get("serve") {
            None => None,
            Some(v) => {
                // two fields predate --request-timeout, three predate
                // --max-queue; keep accepting every vintage
                let parts: Vec<&str> = v.split(',').collect();
                if parts.len() < 2 || parts.len() > 4 {
                    return Err(Error::Config(format!(
                        "bad serve={v:?} (want COALESCE,DEPTH[,TIMEOUT_MS[,MAX_QUEUE]])"
                    )));
                }
                let coalesce: usize = parts[0].parse().map_err(|_| {
                    Error::Config(format!("bad serve coalesce {:?}", parts[0]))
                })?;
                let depth: usize = parts[1].parse().map_err(|_| {
                    Error::Config(format!("bad serve depth {:?}", parts[1]))
                })?;
                let request_timeout_ms: u64 = match parts.get(2) {
                    None => 0,
                    Some(t) => t.parse().map_err(|_| {
                        Error::Config(format!("bad serve timeout {t:?}"))
                    })?,
                };
                let max_queue: usize = match parts.get(3) {
                    None => 0,
                    Some(t) => t.parse().map_err(|_| {
                        Error::Config(format!("bad serve max-queue {t:?}"))
                    })?,
                };
                Some(crate::serve::ServeOpts { coalesce, depth, request_timeout_ms, max_queue })
            }
        };
        Ok(SessionSpec {
            protocol: get("proto")?.to_string(),
            dataset: get("ds")?.to_string(),
            rows: num("rows")?,
            holders: num("holders")?,
            mbps: fnum("mbps")?,
            tc,
            serve,
        })
    }

    /// FNV digest over the canonical wire string (drift detection).
    pub fn digest(&self) -> u64 {
        let mut f = Fnv::new();
        f.add_bytes(self.to_wire().as_bytes());
        f.0
    }

    /// Modeled link for the virtual clock.
    pub fn link(&self) -> LinkSpec {
        LinkSpec::from_mbps(self.mbps)
    }

    /// Model config plus the deterministic synthetic train/test split —
    /// every process re-derives identical data from the seed, so nothing
    /// private ever travels through the coordinator.
    pub fn datasets(&self) -> Result<(&'static ModelConfig, Dataset, Dataset)> {
        let cfg = ModelConfig::by_name(&self.dataset)
            .ok_or_else(|| Error::Config(format!("unknown dataset {:?}", self.dataset)))?;
        let (ds, frac) = match self.dataset.as_str() {
            "fraud" => (
                synth_fraud(SynthOpts { rows: self.rows, seed: self.tc.seed, pos_boost: 10.0 }),
                0.8,
            ),
            _ => (
                synth_distress(SynthOpts { rows: self.rows, seed: self.tc.seed, pos_boost: 2.0 }),
                0.7,
            ),
        };
        let (train, test) = ds.split(frac, self.tc.seed);
        Ok((cfg, train, test))
    }

    /// Unauthenticated session token: ties peer connections to this
    /// config + rendezvous (consistency check). With a PSK the keyed
    /// [`Psk::mesh_token`] replaces it.
    pub fn token(&self, rendezvous: &str) -> u64 {
        let mut f = Fnv::new();
        f.add_bytes(self.to_wire().as_bytes());
        f.add_bytes(rendezvous.as_bytes());
        f.0 ^ 0x5e55_10f0_ba5e_d00d
    }

    /// The session token in force for this spec: keyed when a PSK is
    /// given, the config-digest consistency token otherwise.
    pub fn session_token(&self, rendezvous: &str, psk: Option<&Psk>) -> u64 {
        match psk {
            Some(k) => k.mesh_token(&self.to_wire(), rendezvous),
            None => self.token(rendezvous),
        }
    }
}

// ---------------------------------------------------------------------------
// Control-frame helpers
// ---------------------------------------------------------------------------

fn send_ctl(s: &mut TcpStream, from: PartyId, text: String) -> Result<()> {
    let payload = Payload::Control(text);
    let msg = Msg { from, tag: NO_TAG, payload, depart: 0.0, phase: Phase::Offline };
    wire::write_msg(s, &msg).map_err(|e| Error::Net(format!("handshake write: {e}")))
}

fn recv_ctl(s: &mut TcpStream) -> Result<(PartyId, String)> {
    match wire::read_msg(s)? {
        Some(m) => {
            let from = m.from;
            let text = m.payload.into_control()?;
            if let Some(e) = text.strip_prefix("spnn-err ") {
                return Err(Error::Protocol(format!("rejected by peer: {e}")));
            }
            Ok((from, text))
        }
        None => Err(Error::Net("peer closed the connection during the handshake".into())),
    }
}

fn field<'a>(text: &'a str, key: &str) -> Result<&'a str> {
    // `cfg=` consumes the rest of the line (the config string has spaces)
    if key == "cfg" {
        return text
            .split_once("cfg=")
            .map(|(_, v)| v)
            .ok_or_else(|| Error::Protocol(format!("missing cfg= in {text:?}")));
    }
    for w in text.split_whitespace() {
        if let Some(v) = w.strip_prefix(key).and_then(|r| r.strip_prefix('=')) {
            return Ok(v);
        }
    }
    Err(Error::Protocol(format!("missing {key}= in {text:?}")))
}

fn accept_with_deadline(listener: &TcpListener, deadline: Instant) -> Result<TcpStream> {
    listener
        .set_nonblocking(true)
        .map_err(|e| Error::Net(format!("set_nonblocking: {e}")))?;
    loop {
        match listener.accept() {
            Ok((s, _)) => {
                s.set_nonblocking(false).map_err(|e| Error::Net(format!("unset nb: {e}")))?;
                s.set_nodelay(true).ok();
                s.set_read_timeout(Some(HANDSHAKE_STEP_TIMEOUT))
                    .map_err(|e| Error::Net(format!("read timeout: {e}")))?;
                return Ok(s);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(Error::Net(
                        "rendezvous timed out waiting for parties to connect".into(),
                    ));
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => return Err(Error::Net(format!("accept: {e}"))),
        }
    }
}

// ---------------------------------------------------------------------------
// Host (coordinator) side
// ---------------------------------------------------------------------------

/// An established session as seen by the coordinator: one stream per
/// worker party (`streams[0]` is `None` — that is the host itself).
pub struct HostedSession {
    /// One stream per worker party (`streams[0]` is `None` — the host).
    pub streams: Vec<Option<TcpStream>>,
    /// The session token in force (keyed under the PSK when one is set).
    pub token: u64,
}

/// Run the PSK challenge/response for one accepted role claim.
/// `Ok(())` = authenticated; `Err` = the whole session must abort,
/// naming the offending role.
fn host_authenticate(
    s: &mut TcpStream,
    psk: &Psk,
    role: &str,
    nonce_a_hex: Option<&str>,
) -> Result<()> {
    let fail = |why: String| {
        Error::Protocol(format!(
            "party {role:?} failed PSK authentication ({why}) — wrong or missing \
             --psk-file on that party; aborting the session"
        ))
    };
    let nonce_a = nonce_a_hex
        .and_then(|h| auth::from_hex(h).ok())
        .ok_or_else(|| fail("hello carried no usable nonce".into()))?;
    let nonce_b = auth::fresh_nonce();
    send_ctl(
        s,
        0,
        format!(
            "spnn-auth v1 nonce={} proof={}",
            auth::to_hex(&nonce_b),
            psk.host_proof(&nonce_a, &nonce_b, role)
        ),
    )?;
    let reply = match recv_ctl(s) {
        Ok((_, t)) => t,
        Err(e) => return Err(fail(format!("{e}"))),
    };
    let rest = reply
        .strip_prefix("spnn-auth-proof v1 ")
        .ok_or_else(|| fail(format!("expected auth proof, got {reply:?}")))?;
    let proof = field(rest, "proof").map_err(|e| fail(format!("{e}")))?;
    if !psk.verify_party(proof, &nonce_a, &nonce_b, role) {
        let _ = send_ctl(s, 0, "spnn-err psk proof rejected by coordinator".into());
        return Err(fail("proof did not verify".into()));
    }
    Ok(())
}

/// Run the coordinator side of the rendezvous on an already-bound
/// listener. `names[i]` is party `i`'s role name; the host itself is
/// party 0. Returns when the full mesh is up and every party has
/// confirmed the config digest. With `psk` set, every role claim must
/// pass the challenge/response — one wrong key aborts the whole session.
pub fn host(
    listener: &TcpListener,
    spec: &SessionSpec,
    names: &[String],
    timeout: Duration,
    psk: Option<&Psk>,
) -> Result<HostedSession> {
    let n = names.len();
    let rendezvous = listener
        .local_addr()
        .map_err(|e| Error::Net(format!("local_addr: {e}")))?
        .to_string();
    let token = spec.session_token(&rendezvous, psk);
    let cfg_wire = spec.to_wire();
    let deadline = Instant::now() + timeout;

    // phase 1: role claims (+ PSK auth)
    let mut streams: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    let mut joined = 0usize;
    while joined < n - 1 {
        let mut s = accept_with_deadline(listener, deadline)?;
        let hello = match recv_ctl(&mut s) {
            Ok((_, t)) => t,
            Err(_) => continue, // stray / broken connection: keep waiting
        };
        let Some(rest) = hello.strip_prefix("spnn-hello v1 ") else {
            let _ = send_ctl(&mut s, 0, format!("spnn-err expected hello, got {hello:?}"));
            continue;
        };
        // malformed hello (no role=): reject this client, keep hosting
        let Ok(role) = field(rest, "role") else {
            let _ = send_ctl(&mut s, 0, format!("spnn-err hello missing role=: {hello:?}"));
            continue;
        };
        match names.iter().position(|r| r == role) {
            Some(0) | None => {
                let _ = send_ctl(
                    &mut s,
                    0,
                    format!("spnn-err unknown role {role:?} (expected one of {:?})", &names[1..]),
                );
                continue;
            }
            Some(id) if streams[id].is_some() => {
                let _ = send_ctl(&mut s, 0, format!("spnn-err role {role:?} already claimed"));
                continue;
            }
            Some(id) => {
                if let Some(psk) = psk {
                    // a failed proof aborts the session — a party with the
                    // wrong key would otherwise hang the deployment later
                    host_authenticate(&mut s, psk, role, field(rest, "nonce").ok())?;
                }
                send_ctl(
                    &mut s,
                    0,
                    format!("spnn-welcome v1 id={id} n={n} token={token} cfg={cfg_wire}"),
                )?;
                streams[id] = Some(s);
                joined += 1;
            }
        }
    }

    // phase 2: collect peer-listener addresses
    let mut addrs: Vec<String> = vec![String::new(); n];
    for id in 1..n {
        let s = streams[id].as_mut().unwrap();
        let (_, t) = recv_ctl(s)?;
        let addr = t
            .strip_prefix("spnn-listen ")
            .ok_or_else(|| Error::Protocol(format!("party {id}: expected listen, got {t:?}")))?;
        addrs[id] = addr.to_string();
    }

    // phase 3: roster broadcast (id@addr for every worker party)
    let roster: Vec<String> = (1..n).map(|id| format!("{id}@{}", addrs[id])).collect();
    let roster = format!("spnn-roster {}", roster.join(";"));
    for id in 1..n {
        send_ctl(streams[id].as_mut().unwrap(), 0, roster.clone())?;
    }

    // phase 4: readiness + config-digest verification (topology check:
    // every party proved it built the same deployment we did)
    let want = spec.digest();
    for id in 1..n {
        let s = streams[id].as_mut().unwrap();
        let (_, t) = recv_ctl(s)?;
        let d = field(
            t.strip_prefix("spnn-ready ")
                .ok_or_else(|| Error::Protocol(format!("party {id}: expected ready, got {t:?}")))?,
            "digest",
        )?;
        let d: u64 = d.parse().map_err(|_| Error::Protocol(format!("bad digest {d:?}")))?;
        if d != want {
            return Err(Error::Protocol(format!(
                "party {id} ({}) derived config digest {d:#018x}, host has {want:#018x} — \
                 config drift between processes",
                names[id]
            )));
        }
    }
    for id in 1..n {
        send_ctl(streams[id].as_mut().unwrap(), 0, "spnn-go".into())?;
    }
    Ok(HostedSession { streams, token })
}

// ---------------------------------------------------------------------------
// Party side
// ---------------------------------------------------------------------------

/// An established session as seen by a worker party. Carries everything
/// the resilient links need to survive mid-training connection drops:
/// the peer listener (kept open behind the relink accept hub), the
/// roster addresses (re-dial targets) and the session token.
pub struct JoinedSession {
    /// This party's id (index into the deployment's role names).
    pub id: PartyId,
    /// Total party count (coordinator included).
    pub n: usize,
    /// The authoritative config received from the coordinator.
    pub spec: SessionSpec,
    /// One stream per peer party (`streams[id]` is `None` — self).
    pub streams: Vec<Option<TcpStream>>,
    /// The session token in force (keyed under the PSK when one is set).
    pub token: u64,
    /// This party's peer listener, still bound (relink accept hub).
    pub listener: TcpListener,
    /// Roster: `peer_addrs[p]` is party `p`'s listener address
    /// (`None` for self and the coordinator).
    pub peer_addrs: Vec<Option<String>>,
    /// The coordinator's rendezvous address (re-dial target for link 0).
    pub coordinator_addr: String,
}

/// Join a session hosted at `addr` under a role name, bringing up this
/// party's slice of the full mesh. `bind_host` is the address peers dial
/// back on (`127.0.0.1` for single-host runs, a routable address
/// otherwise). With `psk` set, the coordinator must prove possession of
/// the same key before this party reveals anything beyond its role name.
pub fn join(
    addr: &str,
    role: &str,
    bind_host: &str,
    timeout: Duration,
    psk: Option<&Psk>,
) -> Result<JoinedSession> {
    let deadline = Instant::now() + timeout;
    let mut coord = connect_retry(addr, timeout)?;
    coord.set_nodelay(true).ok();
    coord
        .set_read_timeout(Some(HANDSHAKE_STEP_TIMEOUT))
        .map_err(|e| Error::Net(format!("read timeout: {e}")))?;
    // provisional sender id — the handshake assigns the real one
    let nonce_a = auth::fresh_nonce();
    send_ctl(
        &mut coord,
        usize::MAX,
        format!("spnn-hello v1 role={role} nonce={}", auth::to_hex(&nonce_a)),
    )?;

    // the coordinator either challenges (PSK sessions) or welcomes directly
    let (_, first) = recv_ctl(&mut coord)?;
    let welcome = if let Some(rest) = first.strip_prefix("spnn-auth v1 ") {
        let Some(psk) = psk else {
            let _ = send_ctl(&mut coord, usize::MAX, "spnn-err party holds no psk".into());
            return Err(Error::Protocol(format!(
                "session at {addr} requires a pre-shared key: start this party with \
                 --psk-file pointing at the launcher's key"
            )));
        };
        let nonce_b = auth::from_hex(field(rest, "nonce")?)?;
        let proof = field(rest, "proof")?;
        if !psk.verify_host(proof, &nonce_a, &nonce_b, role) {
            let _ = send_ctl(
                &mut coord,
                usize::MAX,
                format!("spnn-err psk proof rejected by party {role}"),
            );
            return Err(Error::Protocol(format!(
                "PSK mismatch joining as {role:?}: the coordinator's proof does not \
                 verify — this party's --psk-file differs from the launcher's"
            )));
        }
        send_ctl(
            &mut coord,
            usize::MAX,
            format!("spnn-auth-proof v1 proof={}", psk.party_proof(&nonce_a, &nonce_b, role)),
        )?;
        recv_ctl(&mut coord)?.1
    } else {
        if psk.is_some() {
            return Err(Error::Protocol(format!(
                "session at {addr} is not PSK-authenticated but this party was \
                 given --psk-file — refusing to join an unauthenticated session"
            )));
        }
        first
    };
    let rest = welcome
        .strip_prefix("spnn-welcome v1 ")
        .ok_or_else(|| Error::Protocol(format!("expected welcome, got {welcome:?}")))?;
    let id: PartyId = field(rest, "id")?
        .parse()
        .map_err(|_| Error::Protocol("bad welcome id".into()))?;
    let n: usize =
        field(rest, "n")?.parse().map_err(|_| Error::Protocol("bad welcome n".into()))?;
    let token: u64 = field(rest, "token")?
        .parse()
        .map_err(|_| Error::Protocol("bad welcome token".into()))?;
    let spec = SessionSpec::from_wire(field(rest, "cfg")?)?;
    if id == 0 || id >= n {
        return Err(Error::Protocol(format!("welcome assigned invalid id {id} of {n}")));
    }

    // peer listener + address advertisement
    let listener = TcpListener::bind((bind_host, 0))
        .map_err(|e| Error::Net(format!("bind {bind_host}: {e}")))?;
    let my_addr = listener.local_addr().map_err(|e| Error::Net(format!("local_addr: {e}")))?;
    send_ctl(&mut coord, id, format!("spnn-listen {my_addr}"))?;

    let (_, roster) = recv_ctl(&mut coord)?;
    let roster = roster
        .strip_prefix("spnn-roster ")
        .ok_or_else(|| Error::Protocol(format!("expected roster, got {roster:?}")))?;
    let mut peer_addr: Vec<Option<String>> = vec![None; n];
    for entry in roster.split(';').filter(|e| !e.is_empty()) {
        let (pid, a) = entry
            .split_once('@')
            .ok_or_else(|| Error::Protocol(format!("bad roster entry {entry:?}")))?;
        let pid: PartyId =
            pid.parse().map_err(|_| Error::Protocol(format!("bad roster id {pid:?}")))?;
        if pid == 0 || pid >= n {
            return Err(Error::Protocol(format!("roster id {pid} out of range")));
        }
        peer_addr[pid] = Some(a.to_string());
    }

    let mut streams: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();

    // dial peers with lower ids (they accept from us)
    for pid in 1..id {
        let a = peer_addr[pid]
            .as_deref()
            .ok_or_else(|| Error::Protocol(format!("roster missing party {pid}")))?;
        let mut s = connect_retry(a, timeout)?;
        s.set_nodelay(true).ok();
        s.set_read_timeout(Some(HANDSHAKE_STEP_TIMEOUT)).ok();
        send_ctl(&mut s, id, format!("spnn-peer v1 id={id} token={token}"))?;
        streams[pid] = Some(s);
    }
    // accept peers with higher ids; the listener may be on a routable
    // address, so stray/malformed connections are rejected and waiting
    // continues (only the session deadline aborts)
    let mut accepted = 0usize;
    while accepted < n.saturating_sub(id + 1) {
        let mut s = accept_with_deadline(&listener, deadline)?;
        let parsed = (|| -> Result<(PartyId, u64)> {
            let (_, t) = recv_ctl(&mut s)?;
            let rest = t
                .strip_prefix("spnn-peer v1 ")
                .ok_or_else(|| Error::Protocol(format!("expected peer hello, got {t:?}")))?;
            let pid: PartyId = field(rest, "id")?
                .parse()
                .map_err(|_| Error::Protocol("bad peer id".into()))?;
            let ptoken: u64 = field(rest, "token")?
                .parse()
                .map_err(|_| Error::Protocol("bad peer token".into()))?;
            Ok((pid, ptoken))
        })();
        let (pid, ptoken) = match parsed {
            Ok(v) => v,
            Err(e) => {
                eprintln!("spnn-session: party {id}: dropping stray connection ({e})");
                let _ = send_ctl(&mut s, id, format!("spnn-err {e}"));
                continue;
            }
        };
        if ptoken != token {
            eprintln!(
                "spnn-session: party {id}: peer {pid} presented a token for a \
                 different session — dropping"
            );
            let _ = send_ctl(&mut s, id, "spnn-err wrong session token".into());
            continue;
        }
        if pid <= id || pid >= n || streams[pid].is_some() {
            eprintln!(
                "spnn-session: party {id}: unexpected peer id {pid} (n {n}) — dropping"
            );
            let _ = send_ctl(&mut s, id, format!("spnn-err unexpected peer id {pid}"));
            continue;
        }
        streams[pid] = Some(s);
        accepted += 1;
    }

    send_ctl(&mut coord, id, format!("spnn-ready digest={}", spec.digest()))?;
    let (_, go) = recv_ctl(&mut coord)?;
    if go != "spnn-go" {
        return Err(Error::Protocol(format!("expected go, got {go:?}")));
    }
    streams[0] = Some(coord);
    Ok(JoinedSession {
        id,
        n,
        spec,
        streams,
        token,
        listener,
        peer_addrs: peer_addr,
        coordinator_addr: addr.to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SessionSpec {
        SessionSpec {
            protocol: "spnn-ss".into(),
            dataset: "fraud".into(),
            rows: 512,
            holders: 2,
            mbps: 100.0,
            tc: TrainConfig { epochs: 1, batch: 256, ..Default::default() },
            serve: None,
        }
    }

    #[test]
    fn session_spec_wire_roundtrip_is_exact() {
        let mut s = spec();
        s.tc.lr_override = Some(0.05);
        s.tc.sgld = true;
        s.tc.sgld_noise = Some(0.125);
        s.mbps = 12.5;
        let back = SessionSpec::from_wire(&s.to_wire()).unwrap();
        assert_eq!(s.to_wire(), back.to_wire());
        assert_eq!(s.digest(), back.digest());
        assert_eq!(back.tc.lr_override, Some(0.05));
        assert_eq!(back.tc.transport, TransportKind::Tcp);
        // digest is sensitive to every field
        let mut other = s.clone();
        other.tc.seed += 1;
        assert_ne!(s.digest(), other.digest());
        assert!(SessionSpec::from_wire("nonsense").is_err());
        assert!(SessionSpec::from_wire("spnn-cfg v1 proto=x").is_err());
        // the psk path never leaks into the broadcast config
        let mut k = s.clone();
        k.tc.psk_file = Some("/secret/key".into());
        assert_eq!(k.to_wire(), s.to_wire());
        assert_eq!(k.digest(), s.digest());
        assert!(SessionSpec::from_wire(&k.to_wire()).unwrap().tc.psk_file.is_none());
        // serve mode rides the config broadcast and roundtrips exactly
        let mut sv = s.clone();
        sv.serve = Some(crate::serve::ServeOpts {
            coalesce: 48,
            depth: 3,
            request_timeout_ms: 0,
            max_queue: 0,
        });
        assert_ne!(sv.digest(), s.digest(), "serve mode must change the digest");
        assert!(
            sv.to_wire().ends_with("serve=48,3"),
            "zero timeout and max-queue must keep the two-field wire form: {}",
            sv.to_wire()
        );
        let back = SessionSpec::from_wire(&sv.to_wire()).unwrap();
        assert_eq!(back.serve, sv.serve);
        sv.serve.as_mut().unwrap().request_timeout_ms = 1_500;
        assert!(
            sv.to_wire().ends_with("serve=48,3,1500"),
            "zero max-queue must keep the three-field wire form: {}",
            sv.to_wire()
        );
        let back = SessionSpec::from_wire(&sv.to_wire()).unwrap();
        assert_eq!(back.serve.as_ref().unwrap().request_timeout_ms, 1_500);
        assert_eq!(back.serve.as_ref().unwrap().max_queue, 0);
        // the admission cap rides as the fourth field and roundtrips
        sv.serve.as_mut().unwrap().max_queue = 32;
        assert!(sv.to_wire().ends_with("serve=48,3,1500,32"), "{}", sv.to_wire());
        let back = SessionSpec::from_wire(&sv.to_wire()).unwrap();
        assert_eq!(back.serve, sv.serve);
        assert!(SessionSpec::from_wire(&format!("{} serve=oops", s.to_wire())).is_err());
        assert!(
            SessionSpec::from_wire(&format!("{} serve=1,2,3,4,5", s.to_wire())).is_err()
        );
        // bounded staleness rides the broadcast (all parties must drive
        // the same lag schedule) and moves the digest; absent = 0, so
        // pre-staleness wire strings and digests are unchanged
        let mut st = s.clone();
        st.tc.staleness = 2;
        assert!(st.to_wire().contains(" stale=2"), "{}", st.to_wire());
        assert_ne!(st.digest(), s.digest(), "staleness must change the digest");
        let back = SessionSpec::from_wire(&st.to_wire()).unwrap();
        assert_eq!(back.tc.staleness, 2);
        assert_eq!(SessionSpec::from_wire(&s.to_wire()).unwrap().tc.staleness, 0);
        assert!(!s.to_wire().contains("stale="), "S=0 must keep the old wire form");
        assert!(SessionSpec::from_wire(&format!("{} stale=x", s.to_wire())).is_err());
        // checkpoint rotation is local-only, like the dir and the psk path
        let mut ck = s.clone();
        ck.tc.checkpoint_keep = Some(3);
        assert_eq!(ck.to_wire(), s.to_wire());
        assert!(SessionSpec::from_wire(&ck.to_wire()).unwrap().tc.checkpoint_keep.is_none());
        // the compression knob roundtrips in canonical form and moves the
        // config digest; absent = uncompressed, as before this field
        let mut cs = s.clone();
        cs.tc.compress = CompressCfg::parse("dct:0.5");
        assert!(cs.tc.compress.is_some());
        assert_ne!(cs.digest(), s.digest(), "compression must change the digest");
        let back = SessionSpec::from_wire(&cs.to_wire()).unwrap();
        assert_eq!(back.tc.compress, cs.tc.compress);
        assert!(SessionSpec::from_wire(&cs.to_wire()).unwrap().tc.compress.is_some());
        assert!(SessionSpec::from_wire(&s.to_wire()).unwrap().tc.compress.is_none());
        assert!(
            SessionSpec::from_wire(&format!("{} compress=1.5", s.to_wire())).is_err()
        );
    }

    #[test]
    fn session_spec_datasets_are_deterministic() {
        let s = spec();
        let (cfg, tr1, te1) = s.datasets().unwrap();
        let (_, tr2, te2) = s.datasets().unwrap();
        assert_eq!(cfg.name, "fraud");
        assert_eq!(tr1.x, tr2.x);
        assert_eq!(te1.y, te2.y);
        assert_eq!(tr1.len() + te1.len(), 512);
    }

    #[test]
    fn session_token_is_keyed_under_a_psk() {
        let s = spec();
        let plain = s.session_token("127.0.0.1:7000", None);
        assert_eq!(plain, s.token("127.0.0.1:7000"));
        let k = Psk::from_bytes(b"key");
        let keyed = s.session_token("127.0.0.1:7000", Some(&k));
        assert_ne!(plain, keyed);
        assert_ne!(keyed, s.session_token("127.0.0.1:7000", Some(&Psk::from_bytes(b"other"))));
    }

    #[test]
    fn rendezvous_brings_up_a_full_mesh() {
        // 4 parties: host (0) + three workers that join over real sockets,
        // then every pair exchanges one frame over its mesh connection
        let names: Vec<String> =
            ["coord", "server", "dealer", "holder0"].iter().map(|s| s.to_string()).collect();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let s = spec();
        let mut joiners = Vec::new();
        for role in ["server", "dealer", "holder0"] {
            let addr = addr.clone();
            joiners.push(std::thread::spawn(move || {
                join(&addr, role, "127.0.0.1", Duration::from_secs(20), None).unwrap()
            }));
        }
        let hosted = host(&listener, &s, &names, Duration::from_secs(20), None).unwrap();
        let sessions: Vec<JoinedSession> =
            joiners.into_iter().map(|h| h.join().unwrap()).collect();
        // ids are assigned by role, config survives the trip
        for sess in &sessions {
            assert_eq!(sess.n, 4);
            assert_eq!(sess.spec.digest(), s.digest());
            assert_eq!(sess.token, hosted.token);
            assert_eq!(sess.coordinator_addr, addr);
            assert!(sess.streams[sess.id].is_none());
            let connected = sess.streams.iter().filter(|s| s.is_some()).count();
            assert_eq!(connected, 3, "party {} mesh incomplete", sess.id);
            // the roster names every worker peer, and the kept listener
            // still answers on its advertised address (relink hub input)
            for pid in 1..4usize {
                if pid != sess.id {
                    assert!(sess.peer_addrs[pid].is_some(), "roster missing {pid}");
                }
            }
            assert!(sess.listener.local_addr().is_ok());
        }
        assert_eq!(hosted.streams.iter().filter(|s| s.is_some()).count(), 3);
        // ping over every worker<->worker pair to prove the wiring is real
        let mut handles = Vec::new();
        for sess in sessions {
            handles.push(std::thread::spawn(move || {
                let JoinedSession { id, mut streams, .. } = sess;
                for pid in 1..4usize {
                    if pid == id {
                        continue;
                    }
                    let st = streams[pid].as_mut().unwrap();
                    send_ctl(st, id, format!("ping {id}->{pid}")).unwrap();
                }
                let mut got = 0;
                for pid in 1..4usize {
                    if pid == id {
                        continue;
                    }
                    let st = streams[pid].as_mut().unwrap();
                    let (_, t) = recv_ctl(st).unwrap();
                    assert!(t.starts_with("ping "), "{t}");
                    got += 1;
                }
                got
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 2);
        }
    }

    #[test]
    fn wrong_role_is_rejected() {
        let names: Vec<String> = ["coord", "server"].iter().map(|s| s.to_string()).collect();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let s = spec();
        // host runs in a thread; the bad role is rejected (and observed)
        // BEFORE the good role joins, so the ordering is deterministic
        let hoster = std::thread::spawn({
            let names = names.clone();
            move || host(&listener, &s, &names, Duration::from_secs(20), None)
        });
        let err =
            join(&addr, "astronaut", "127.0.0.1", Duration::from_secs(20), None).unwrap_err();
        assert!(format!("{err}").contains("unknown role"), "{err}");
        join(&addr, "server", "127.0.0.1", Duration::from_secs(20), None).unwrap();
        let hosted = hoster.join().unwrap().unwrap();
        assert!(hosted.streams[1].is_some());
    }

    #[test]
    fn duplicate_role_claim_is_rejected_with_diagnostic() {
        // a hand-rolled first claimant lets the test control ordering
        // exactly: claim "server", then watch the second claim bounce,
        // then finish the session so the host returns cleanly
        let names: Vec<String> = ["coord", "server"].iter().map(|s| s.to_string()).collect();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let s = spec();
        let digest = s.digest();
        let hoster = std::thread::spawn({
            let names = names.clone();
            move || host(&listener, &s, &names, Duration::from_secs(20), None)
        });
        let mut first = connect_retry(&addr, Duration::from_secs(10)).unwrap();
        first.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_ctl(&mut first, usize::MAX, "spnn-hello v1 role=server nonce=00".into()).unwrap();
        let (_, welcome) = recv_ctl(&mut first).unwrap();
        assert!(welcome.starts_with("spnn-welcome v1 id=1"), "{welcome}");
        // second claim on the same role: named rejection, host keeps going
        let err =
            join(&addr, "server", "127.0.0.1", Duration::from_secs(20), None).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("already claimed") && msg.contains("server"), "{msg}");
        // the first claimant completes the remaining handshake phases
        send_ctl(&mut first, 1, "spnn-listen 127.0.0.1:1".into()).unwrap();
        let (_, roster) = recv_ctl(&mut first).unwrap();
        assert!(roster.starts_with("spnn-roster "), "{roster}");
        send_ctl(&mut first, 1, format!("spnn-ready digest={digest}")).unwrap();
        let (_, go) = recv_ctl(&mut first).unwrap();
        assert_eq!(go, "spnn-go");
        hoster.join().unwrap().unwrap();
    }

    #[test]
    fn config_digest_mismatch_aborts_with_drift_diagnostic() {
        let names: Vec<String> = ["coord", "server"].iter().map(|s| s.to_string()).collect();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let s = spec();
        let hoster = std::thread::spawn({
            let names = names.clone();
            move || host(&listener, &s, &names, Duration::from_secs(20), None)
        });
        // a party that completes the handshake but derived a different
        // config (seed drift, version skew, …) must be caught at ready
        let mut p = connect_retry(&addr, Duration::from_secs(10)).unwrap();
        p.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        send_ctl(&mut p, usize::MAX, "spnn-hello v1 role=server nonce=00".into()).unwrap();
        let (_, welcome) = recv_ctl(&mut p).unwrap();
        assert!(welcome.starts_with("spnn-welcome"), "{welcome}");
        send_ctl(&mut p, 1, "spnn-listen 127.0.0.1:1".into()).unwrap();
        let (_, _roster) = recv_ctl(&mut p).unwrap();
        send_ctl(&mut p, 1, "spnn-ready digest=12345".into()).unwrap();
        let err = hoster.join().unwrap().unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("config drift"), "{msg}");
        assert!(msg.contains("server"), "diagnostic must name the role: {msg}");
    }

    #[test]
    fn psk_sessions_authenticate_mutually() {
        let names: Vec<String> = ["coord", "server"].iter().map(|s| s.to_string()).collect();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let s = spec();
        let key = Psk::from_bytes(b"shared secret");
        let hoster = std::thread::spawn({
            let names = names.clone();
            let (s, key) = (s.clone(), key.clone());
            move || host(&listener, &s, &names, Duration::from_secs(20), Some(&key))
        });
        let sess =
            join(&addr, "server", "127.0.0.1", Duration::from_secs(20), Some(&key)).unwrap();
        let hosted = hoster.join().unwrap().unwrap();
        // the mesh token is the keyed one on both sides
        assert_eq!(sess.token, hosted.token);
        assert_eq!(sess.token, s.session_token(&addr, Some(&key)));
        assert_ne!(sess.token, s.token(&addr));
    }

    #[test]
    fn wrong_psk_aborts_the_session_naming_the_role() {
        let names: Vec<String> = ["coord", "server"].iter().map(|s| s.to_string()).collect();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let s = spec();
        let good = Psk::from_bytes(b"right key");
        let bad = Psk::from_bytes(b"wrong key");
        let hoster = std::thread::spawn({
            let names = names.clone();
            let (s, good) = (s.clone(), good.clone());
            move || host(&listener, &s, &names, Duration::from_secs(20), Some(&good))
        });
        let perr =
            join(&addr, "server", "127.0.0.1", Duration::from_secs(20), Some(&bad)).unwrap_err();
        let pmsg = format!("{perr}");
        assert!(pmsg.contains("PSK mismatch"), "{pmsg}");
        let herr = hoster.join().unwrap().unwrap_err();
        let hmsg = format!("{herr}");
        assert!(hmsg.contains("PSK authentication"), "{hmsg}");
        assert!(hmsg.contains("server"), "diagnostic must name the role: {hmsg}");
    }

    #[test]
    fn keyless_party_cannot_join_a_psk_session_and_vice_versa() {
        // case 1: host requires a key, party has none -> both sides abort
        let names: Vec<String> = ["coord", "server"].iter().map(|s| s.to_string()).collect();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let s = spec();
        let key = Psk::from_bytes(b"the key");
        let hoster = std::thread::spawn({
            let names = names.clone();
            let (s, key) = (s.clone(), key.clone());
            move || host(&listener, &s, &names, Duration::from_secs(20), Some(&key))
        });
        let perr =
            join(&addr, "server", "127.0.0.1", Duration::from_secs(20), None).unwrap_err();
        assert!(format!("{perr}").contains("requires a pre-shared key"), "{perr}");
        let herr = hoster.join().unwrap().unwrap_err();
        assert!(format!("{herr}").contains("server"), "{herr}");

        // case 2: party has a key, host does not -> the party refuses
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let hoster = std::thread::spawn({
            let names = names.clone();
            let s = s.clone();
            move || host(&listener, &s, &names, Duration::from_secs(20), None)
        });
        let perr = join(&addr, "server", "127.0.0.1", Duration::from_secs(20), Some(&key))
            .unwrap_err();
        assert!(format!("{perr}").contains("not PSK-authenticated"), "{perr}");
        // the refusing party had already claimed the role and then hung
        // up, so the host aborts when the handshake stream dies
        assert!(hoster.join().unwrap().is_err());
    }
}
