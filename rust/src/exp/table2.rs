//! Table 2: property-inference leakage, SGD vs SGLD (paper: task AUC
//! .9118 -> .9313, attack AUC .8223 -> .5951).

use super::report::{fmt_auc, md_table};
use super::ExpOpts;
use crate::attack::{property_attack, AttackOpts};
use crate::Result;

pub fn run(opts: &ExpOpts) -> Result<String> {
    let mut rows = Vec::new();
    // SGD baseline plus two SGLD noise levels: the privacy-utility
    // tradeoff curve (the paper reports one SGLD operating point)
    let settings: [(&str, bool, Option<f64>); 3] = [
        ("SGD", false, None),
        ("SGLD (moderate noise)", true, Some(0.05)),
        ("SGLD (strong noise)", true, Some(0.3)),
    ];
    for (label, sgld, noise) in settings {
        let aopts = AttackOpts {
            rows: opts.size(16_000, 4_000),
            epochs: if opts.quick { 3 } else { 6 },
            seed: opts.seed,
            noise,
        };
        let r = property_attack(sgld, &aopts)?;
        eprintln!("  {label}: task {:.4} attack {:.4}", r.task_auc, r.attack_auc);
        rows.push(vec![
            label.to_string(),
            fmt_auc(r.task_auc),
            fmt_auc(r.attack_auc),
        ]);
    }
    Ok(md_table(
        "Table 2 — information leakage on fraud dataset (paper: SGD .9118/.8223, SGLD .9313/.5951)",
        &["Optimizer", "Task AUC", "Attack AUC"],
        &rows,
    ))
}

#[cfg(test)]
mod tests {
    #[test]
    fn quick_mode_runs() {
        if !crate::runtime::default_artifact_dir().join("manifest.txt").exists() {
            return;
        }
        let md = super::run(&super::ExpOpts::quick()).unwrap();
        assert!(md.contains("Table 2"));
    }
}
