//! Experiment drivers: one module per table/figure in the paper's
//! evaluation (§6). Each returns a markdown section used by
//! `spnn repro ...` and recorded in EXPERIMENTS.md.
//!
//! Wall-time note: this is a 1-core container; dataset sizes default to
//! scaled-down-but-representative values (`ExpOpts::scale` grows them) and
//! network timings are *simulated* (netsim virtual clocks), so the numbers
//! to compare against the paper are orderings/ratios, not absolute seconds
//! (DESIGN.md §5, §10).

pub mod fig5;
pub mod fig67;
pub mod fig8;
pub mod fig9;
pub mod report;
pub mod table1;
pub mod table2;
pub mod table3;

use crate::Result;

/// Shared experiment options.
#[derive(Clone, Copy, Debug)]
pub struct ExpOpts {
    /// Multiplier on default dataset sizes / epochs.
    pub scale: f64,
    /// Quick mode: tiny sizes for tests and smoke benches.
    pub quick: bool,
    pub seed: u64,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts { scale: 1.0, quick: false, seed: 7 }
    }
}

impl ExpOpts {
    pub fn quick() -> Self {
        ExpOpts { quick: true, ..Default::default() }
    }

    /// Scaled size with a floor.
    pub fn size(&self, base: usize, floor: usize) -> usize {
        if self.quick {
            return floor;
        }
        ((base as f64 * self.scale) as usize).max(floor)
    }
}

/// Run every experiment, returning the combined markdown.
pub fn run_all(opts: &ExpOpts) -> Result<String> {
    let mut out = String::new();
    for (name, f) in experiments() {
        eprintln!("== running {name} ==");
        let section = f(opts)?;
        eprintln!("{section}");
        out.push_str(&section);
        out.push('\n');
    }
    Ok(out)
}

type ExpFn = fn(&ExpOpts) -> Result<String>;

/// Registry of (name, driver).
pub fn experiments() -> Vec<(&'static str, ExpFn)> {
    vec![
        ("table1", table1::run as ExpFn),
        ("table2", table2::run as ExpFn),
        ("table3", table3::run as ExpFn),
        ("fig5", fig5::run as ExpFn),
        ("fig67", fig67::run as ExpFn),
        ("fig8", fig8::run as ExpFn),
        ("fig9", fig9::run as ExpFn),
    ]
}

pub fn by_name(name: &str) -> Option<ExpFn> {
    experiments().into_iter().find(|(n, _)| *n == name).map(|(_, f)| f)
}
