//! Figure 9: (a) SPNN-SS epoch time vs batch size on LAN — fewer
//! interaction rounds as batches grow, flattening; (b)/(c) epoch time vs
//! training-data size — linear scaling for both SS and HE.

use super::report::{fmt_secs, md_table};
use super::ExpOpts;
use crate::config::{TrainConfig, FRAUD};
use crate::data::{synth_fraud, SynthOpts};
use crate::netsim::LinkSpec;
use crate::protocols::spnn::Spnn;
use crate::protocols::Trainer;
use crate::Result;

pub fn run(opts: &ExpOpts) -> Result<String> {
    let mut out = String::new();
    let ds = synth_fraud(SynthOpts {
        rows: opts.size(20_000, 1_500),
        seed: opts.seed,
        pos_boost: 10.0,
    });
    let (train, test) = ds.split(0.8, opts.seed);

    // --- (a) batch-size sweep on LAN ---
    let batches: Vec<usize> = if opts.quick {
        vec![256, 1024]
    } else {
        vec![256, 512, 1024, 2048, 5000]
    };
    let mut rows = Vec::new();
    for &b in &batches {
        let tc = TrainConfig { batch: b, epochs: 1, seed: opts.seed, ..Default::default() };
        let rep = Spnn { he: false }.train(&FRAUD, &tc, LinkSpec::lan(), &train, &test, 2)?;
        eprintln!("  batch {b}: {}", rep.summary());
        rows.push(vec![format!("{b}"), fmt_secs(rep.mean_epoch_time())]);
    }
    out.push_str(&md_table(
        "Figure 9a — SPNN-SS epoch time vs batch size, fraud, LAN (paper: decreasing, flattens)",
        &["batch size", "epoch seconds"],
        &rows,
    ));
    out.push('\n');

    // --- (b)/(c) data-size sweep at 100 Mbps ---
    let fracs: Vec<f64> = if opts.quick {
        vec![0.5, 1.0]
    } else {
        vec![0.2, 0.4, 0.6, 0.8, 1.0]
    };
    let he_train = train.subset_frac(if opts.quick { 1.0 } else { 0.25 });
    let mut rows = Vec::new();
    for &f in &fracs {
        let sub = train.subset_frac(f);
        let tc = TrainConfig { batch: 1024, epochs: 1, seed: opts.seed, ..Default::default() };
        let ss = Spnn { he: false }.train(&FRAUD, &tc, LinkSpec::mbps100(), &sub, &test, 2)?;
        // HE on a smaller base (Paillier cost), same fraction sweep
        let he_sub = he_train.subset_frac(f);
        let tc_he = TrainConfig {
            batch: 1024,
            epochs: 1,
            seed: opts.seed,
            paillier_bits: if opts.quick { 256 } else { 512 },
            ..Default::default()
        };
        let he = Spnn { he: true }.train(&FRAUD, &tc_he, LinkSpec::mbps100(), &he_sub, &test, 2)?;
        eprintln!("  frac {f}: SS {:.2}s, HE {:.2}s", ss.mean_epoch_time(), he.mean_epoch_time());
        rows.push(vec![
            format!("{:.0}%", f * 100.0),
            fmt_secs(ss.mean_epoch_time()),
            fmt_secs(he.mean_epoch_time()),
        ]);
    }
    out.push_str(&md_table(
        "Figure 9b/c — SPNN epoch time vs training-data size, fraud @100 Mbps (paper: linear; HE measured on a 1/4-size base, 512-bit keys)",
        &["data fraction", "SPNN-SS", "SPNN-HE"],
        &rows,
    ));
    Ok(out)
}
