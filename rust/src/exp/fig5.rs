//! Figure 5: AUC vs number of data holders (2..5) on fraud. Paper: SPNN and
//! SecureML stay flat (crypto preserves cross-holder interactions); SplitNN
//! declines as each holder's private encoder sees fewer features.

use super::report::{fmt_auc, md_table};
use super::ExpOpts;
use crate::config::{TrainConfig, FRAUD};
use crate::data::{synth_fraud, SynthOpts};
use crate::netsim::LinkSpec;
use crate::protocols;
use crate::Result;

pub fn run(opts: &ExpOpts) -> Result<String> {
    let ds = synth_fraud(SynthOpts {
        rows: opts.size(10_000, 1_200),
        seed: opts.seed,
        pos_boost: 20.0,
    });
    let (train, test) = ds.split(0.8, opts.seed);
    let ks: Vec<usize> = if opts.quick { vec![2, 3] } else { vec![2, 3, 4, 5] };
    let mut rows = Vec::new();
    for &k in &ks {
        let mut row = vec![format!("{k} holders")];
        for proto in ["splitnn", "secureml", "spnn-ss"] {
            let epochs = if opts.quick {
                1
            } else if proto == "secureml" {
                3
            } else {
                10
            };
            let tc = TrainConfig {
                batch: 1024,
                epochs,
                lr_override: Some(0.25),
                seed: opts.seed,
                ..Default::default()
            };
            let t = protocols::by_name(proto).unwrap();
            let rep = t.train(&FRAUD, &tc, LinkSpec::mbps100(), &train, &test, k)?;
            eprintln!("  k={k} {}", rep.summary());
            row.push(fmt_auc(rep.auc));
        }
        rows.push(row);
    }
    Ok(md_table(
        "Figure 5 — AUC vs number of data holders, fraud (paper: SplitNN declines, SecureML/SPNN flat)",
        &["k", "SplitNN", "SecureML", "SPNN"],
        &rows,
    ))
}
