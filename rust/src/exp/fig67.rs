//! Figures 6/7: SPNN average train/test loss per epoch on both datasets —
//! steady convergence, no overfitting gap.

use super::report::md_table;
use super::ExpOpts;
use crate::config::{TrainConfig, DISTRESS, FRAUD};
use crate::data::{synth_distress, synth_fraud, SynthOpts};
use crate::netsim::LinkSpec;
use crate::protocols::spnn::Spnn;
use crate::protocols::Trainer;
use crate::Result;

pub fn run(opts: &ExpOpts) -> Result<String> {
    let mut out = String::new();
    let runs: [(&str, _, _, f64); 2] = [
        (
            "Figure 6 — SPNN average loss per epoch, fraud",
            &FRAUD,
            synth_fraud(SynthOpts {
                rows: opts.size(10_000, 1_200),
                seed: opts.seed,
                pos_boost: 20.0,
            }),
            0.8,
        ),
        (
            "Figure 7 — SPNN average loss per epoch, financial distress",
            &DISTRESS,
            synth_distress(SynthOpts {
                rows: opts.size(3_672, 600),
                seed: opts.seed + 1,
                pos_boost: 2.0,
            }),
            0.7,
        ),
    ];
    for (title, cfg, ds, frac) in runs {
        let (train, test) = ds.split(frac, opts.seed);
        let epochs = if opts.quick { 2 } else { 8 };
        let tc = TrainConfig {
            batch: 1024,
            epochs,
            lr_override: Some(0.25),
            seed: opts.seed,
            ..Default::default()
        };
        // run SPNN once; per-epoch test loss via a second pass would double
        // cost — we report the final test loss alongside the train curve
        let rep = Spnn { he: false }.train(cfg, &tc, LinkSpec::mbps100(), &train, &test, 2)?;
        eprintln!("  {}", rep.summary());
        let mut rows: Vec<Vec<String>> = rep
            .train_losses
            .iter()
            .enumerate()
            .map(|(e, l)| vec![format!("{}", e + 1), format!("{l:.4}"), String::new()])
            .collect();
        if let (Some(last), Some(tl)) = (rows.last_mut(), rep.test_losses.first()) {
            last[2] = format!("{tl:.4}");
        }
        out.push_str(&md_table(title, &["epoch", "train loss", "test loss (final)"], &rows));
        out.push('\n');
        // convergence check mirrors the paper's qualitative claim
        let first = rep.train_losses.first().copied().unwrap_or(0.0);
        let last = rep.train_losses.last().copied().unwrap_or(0.0);
        out.push_str(&format!(
            "converged: train loss {first:.4} -> {last:.4}, final test loss {:.4} (no overfit gap)\n\n",
            rep.test_losses.first().copied().unwrap_or(f64::NAN)
        ));
    }
    Ok(out)
}
