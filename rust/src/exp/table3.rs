//! Table 3: training time per epoch at batch 5000, 100 Mbps (paper, secs:
//! fraud NN .2152 / SplitNN .7427 / SecureML 960.3 / SPNN-SS 37.22;
//! distress .0507 / .4541 / 751.3 / 21.84). The *ordering and ratios* are
//! the reproduction target: NN < SplitNN << SPNN-SS << SecureML.

use super::report::{fmt_secs, md_table, stage_breakdown};
use super::ExpOpts;
use crate::config::{TrainConfig, DISTRESS, FRAUD};
use crate::data::{synth_distress, synth_fraud, SynthOpts};
use crate::netsim::LinkSpec;
use crate::protocols;
use crate::Result;

pub fn run(opts: &ExpOpts) -> Result<String> {
    let mut rows = Vec::new();
    // fraud sized so one epoch has several full 5000-row batches; the
    // simulated time scales linearly in batches (Fig 9c), which the paper's
    // full 284,807 rows would multiply by ~14x uniformly across protocols.
    let fraud_rows = opts.size(25_000, 6_000);
    let datasets: [(&str, _, _, f64); 2] = [
        (
            "Fraud detection",
            &FRAUD,
            synth_fraud(SynthOpts { rows: fraud_rows, seed: opts.seed, pos_boost: 10.0 }),
            0.8,
        ),
        (
            "Financial distress",
            &DISTRESS,
            synth_distress(SynthOpts {
                rows: opts.size(3_672, 800),
                seed: opts.seed + 1,
                pos_boost: 2.0,
            }),
            0.7,
        ),
    ];
    // per-phase / per-stage breakdown of the most interesting column
    // (SPNN-SS): shows where the protocol's traffic goes
    let mut breakdowns = String::new();
    for (label, cfg, ds, frac) in datasets {
        let (train, test) = ds.split(frac, opts.seed);
        let mut row = vec![label.to_string()];
        for proto in ["nn", "splitnn", "secureml", "spnn-ss"] {
            let tc = TrainConfig {
                batch: if opts.quick { 1024 } else { 5000 },
                epochs: 1,
                seed: opts.seed,
                ..Default::default()
            };
            let t = protocols::by_name(proto).unwrap();
            let rep = t.train(cfg, &tc, LinkSpec::mbps100(), &train, &test, 2)?;
            eprintln!("  {}", rep.summary());
            row.push(fmt_secs(rep.mean_epoch_time()));
            if proto == "spnn-ss" {
                breakdowns.push('\n');
                breakdowns.push_str(&stage_breakdown(
                    &format!("Table 3b — {label}: SPNN-SS traffic by stage"),
                    &rep.stages,
                ));
            }
        }
        rows.push(row);
    }
    let mut out = md_table(
        "Table 3 — training time per epoch, seconds (simulated net + measured compute), batch 5000 @ 100 Mbps (paper: fraud .2152/.7427/960.3/37.22; distress .0507/.4541/751.3/21.84)",
        &["Training time", "NN", "SplitNN", "SecureML", "SPNN-SS"],
        &rows,
    );
    out.push_str(&breakdowns);
    Ok(out)
}
