//! Figure 8: SPNN-SS vs SPNN-HE epoch time across bandwidths
//! (100 Kbps .. 100 Mbps). Paper: SS wins at high bandwidth (cheap compute,
//! heavy wire), HE wins at very low bandwidth (heavy compute, light wire) —
//! the crossover is the result.
//!
//! Method: each variant runs ONCE per dataset; the per-epoch time at other
//! bandwidths is reconstructed as
//! `t(bw) = t_compute + bytes*8/bw`, with `t_compute` solved from the
//! measured run. This critical-path extrapolation is exact for SPNN's
//! lock-step protocol (every byte crosses the bottleneck link serially) and
//! avoids re-running the expensive HE epoch four times.

use super::report::{fmt_secs, md_table};
use super::ExpOpts;
use crate::config::{TrainConfig, DISTRESS, FRAUD};
use crate::data::{synth_distress, synth_fraud, Dataset, SynthOpts};
use crate::netsim::LinkSpec;
use crate::protocols::spnn::Spnn;
use crate::protocols::Trainer;
use crate::Result;

const BANDWIDTH_LABELS: [&str; 4] = ["100Kbps", "1Mbps", "10Mbps", "100Mbps"];
const BANDWIDTH_BPS: [f64; 4] = [1e5, 1e6, 1e7, 1e8];

struct Measured {
    compute_s: f64,
    online_bytes: f64,
    epochs: f64,
}

fn measure(
    he: bool,
    cfg: &'static crate::config::ModelConfig,
    train: &Dataset,
    test: &Dataset,
    opts: &ExpOpts,
    pbits: usize,
) -> Result<Measured> {
    let tc = TrainConfig {
        batch: 1024,
        epochs: 1,
        seed: opts.seed,
        paillier_bits: pbits,
        ..Default::default()
    };
    let base = LinkSpec::mbps100();
    let rep = Spnn { he }.train(cfg, &tc, base, train, test, 2)?;
    eprintln!("  {}", rep.summary());
    let bytes = rep.online_bytes as f64;
    let t = rep.mean_epoch_time();
    let compute = (t - bytes * 8.0 / base.bandwidth_bps).max(0.0);
    Ok(Measured { compute_s: compute, online_bytes: bytes, epochs: 1.0 })
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let mut out = String::new();
    // HE epochs are compute-heavy (b x h1 Paillier ops per batch); use a
    // 512-bit modulus and smaller row counts, and report both variants on
    // identical data so the comparison is apples-to-apples.
    let pbits = if opts.quick { 256 } else { 512 };
    let runs: [(&str, _, _, f64); 2] = [
        (
            "Figure 8 — SPNN-SS vs SPNN-HE epoch time vs bandwidth, fraud (seconds, simulated)",
            &FRAUD,
            synth_fraud(SynthOpts {
                rows: opts.size(8_000, 600),
                seed: opts.seed,
                pos_boost: 10.0,
            }),
            0.8,
        ),
        (
            "Figure 8 — SPNN-SS vs SPNN-HE epoch time vs bandwidth, distress (seconds, simulated)",
            &DISTRESS,
            synth_distress(SynthOpts {
                rows: opts.size(1_200, 400),
                seed: opts.seed + 1,
                pos_boost: 2.0,
            }),
            0.7,
        ),
    ];
    for (title, cfg, ds, frac) in runs {
        let (train, test) = ds.split(frac, opts.seed);
        let ss = measure(false, cfg, &train, &test, opts, pbits)?;
        let he = measure(true, cfg, &train, &test, opts, pbits)?;
        let mut rows = Vec::new();
        for (label, bps) in BANDWIDTH_LABELS.iter().zip(BANDWIDTH_BPS) {
            let t_ss = ss.compute_s + ss.online_bytes * 8.0 / bps;
            let t_he = he.compute_s + he.online_bytes * 8.0 / bps;
            rows.push(vec![label.to_string(), fmt_secs(t_ss), fmt_secs(t_he)]);
        }
        out.push_str(&md_table(title, &["bandwidth", "SPNN-SS", "SPNN-HE"], &rows));
        out.push_str(&format!(
            "SS: compute {:.2}s, {:.1} MB/epoch; HE: compute {:.2}s, {:.1} MB/epoch (Paillier {}-bit)\n\n",
            ss.compute_s,
            ss.online_bytes / 1e6,
            he.compute_s,
            he.online_bytes / 1e6,
            pbits,
        ));
    }
    Ok(out)
}
