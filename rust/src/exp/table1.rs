//! Table 1: AUC comparison of NN / SplitNN / SecureML / SPNN on both
//! datasets (paper: fraud .8772/.8624/.8558/.8637, distress
//! .9379/.9032/.9092/.9314 — check the *ordering*: NN >= SPNN > others).

use super::report::{fmt_auc, md_table};
use super::ExpOpts;
use crate::config::{TrainConfig, DISTRESS, FRAUD};
use crate::data::{synth_distress, synth_fraud, SynthOpts};
use crate::netsim::LinkSpec;
use crate::protocols;
use crate::Result;

pub fn run(opts: &ExpOpts) -> Result<String> {
    let mut rows = Vec::new();
    let specs: [(&str, _, _, usize, f64); 2] = [
        (
            "Fraud Detection",
            &FRAUD,
            synth_fraud(SynthOpts {
                rows: opts.size(12_000, 1_500),
                seed: opts.seed,
                pos_boost: 20.0,
            }),
            if opts.quick { 2 } else { 12 },
            0.8, // paper's train fraction
        ),
        (
            "Financial Distress",
            &DISTRESS,
            synth_distress(SynthOpts {
                rows: opts.size(3_672, 800),
                seed: opts.seed + 1,
                pos_boost: 3.0,
            }),
            if opts.quick { 1 } else { 12 },
            0.7,
        ),
    ];

    for (label, cfg, ds, epochs, frac) in specs {
        let (train, test) = ds.split(frac, opts.seed);
        let mut row = vec![label.to_string()];
        for proto in ["nn", "splitnn", "secureml", "spnn-ss"] {
            // whole-network MPC epochs are ~100x more expensive in wall
            // time; cap SecureML's epoch budget (its accuracy deficit
            // comes from the piecewise approximation either way)
            let epochs = if proto == "secureml" { epochs.min(3) } else { epochs };
            let tc = TrainConfig {
                batch: 1024,
                epochs,
                lr_override: Some(0.25),
                seed: opts.seed,
                ..Default::default()
            };
            let t = protocols::by_name(proto).unwrap();
            let rep = t.train(cfg, &tc, LinkSpec::mbps100(), &train, &test, 2)?;
            eprintln!("  {}", rep.summary());
            row.push(fmt_auc(rep.auc));
        }
        rows.push(row);
    }

    Ok(md_table(
        "Table 1 — AUC comparison (paper: NN .8772/.9379, SplitNN .8624/.9032, SecureML .8558/.9092, SPNN .8637/.9314)",
        &["AUC", "NN", "SplitNN", "SecureML", "SPNN"],
        &rows,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_mode_runs() {
        if !crate::runtime::default_artifact_dir().join("manifest.txt").exists() {
            return;
        }
        let md = run(&ExpOpts::quick()).unwrap();
        assert!(md.contains("Table 1"));
        assert!(md.contains("Fraud Detection"));
    }
}
