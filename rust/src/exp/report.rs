//! Markdown table rendering for experiment reports.

use crate::netsim::{Phase, StageRow};

/// Render a markdown table.
pub fn md_table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = format!("### {title}\n\n");
    s.push_str(&format!("| {} |\n", headers.join(" | ")));
    s.push_str(&format!("|{}\n", "---|".repeat(headers.len())));
    for row in rows {
        s.push_str(&format!("| {} |\n", row.join(" | ")));
    }
    s
}

/// Format seconds adaptively.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else {
        format!("{s:.4}")
    }
}

pub fn fmt_auc(a: f64) -> String {
    format!("{a:.4}")
}

/// Render a per-phase / per-stage traffic breakdown ("where do the bytes
/// go") from [`crate::netsim::NetStats::stage_rows`] — surfaced next to
/// the Table 2/3 traffic numbers.
pub fn stage_breakdown(title: &str, rows: &[StageRow]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                match r.phase {
                    Phase::Online => "online".to_string(),
                    Phase::Offline => "offline".to_string(),
                },
                r.stage.to_string(),
                format!("{:.3}", r.bytes as f64 / 1e6),
                r.msgs.to_string(),
                fmt_secs(r.wire_s),
            ]
        })
        .collect();
    md_table(
        title,
        &["phase", "stage", "MB", "msgs", "est. wire s"],
        &table_rows,
    )
}

/// An (x, y) series rendered as a compact markdown row set.
pub fn md_series(title: &str, xlabel: &str, series: &[(&str, Vec<(f64, f64)>)]) -> String {
    let mut s = format!("### {title}\n\n");
    // union of x values in order of first series
    let xs: Vec<f64> = series
        .first()
        .map(|(_, pts)| pts.iter().map(|p| p.0).collect())
        .unwrap_or_default();
    let mut headers = vec![xlabel.to_string()];
    headers.extend(series.iter().map(|(n, _)| n.to_string()));
    s.push_str(&format!("| {} |\n", headers.join(" | ")));
    s.push_str(&format!("|{}\n", "---|".repeat(headers.len())));
    for (i, x) in xs.iter().enumerate() {
        let mut row = vec![format!("{x}")];
        for (_, pts) in series {
            row.push(pts.get(i).map(|p| fmt_secs(p.1)).unwrap_or_default());
        }
        s.push_str(&format!("| {} |\n", row.join(" | ")));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_shape() {
        let t = md_table("T", &["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("### T"));
        assert!(t.contains("| a | b |"));
        assert!(t.contains("| 1 | 2 |"));
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(960.3), "960");
        assert_eq!(fmt_secs(37.22), "37.22");
        assert_eq!(fmt_secs(0.2152), "0.2152");
    }

    #[test]
    fn stage_breakdown_renders_rows() {
        let rows = vec![
            StageRow {
                phase: Phase::Online,
                stage: "server-fwd".into(),
                bytes: 2_000_000,
                msgs: 12,
                wire_s: 0.25,
            },
            StageRow {
                phase: Phase::Offline,
                stage: "dealer".into(),
                bytes: 500_000,
                msgs: 3,
                wire_s: 0.0,
            },
        ];
        let md = stage_breakdown("traffic by stage", &rows);
        assert!(md.contains("### traffic by stage"));
        assert!(md.contains("| online | server-fwd | 2.000 | 12 |"));
        assert!(md.contains("| offline | dealer |"));
        assert!(stage_breakdown("empty", &[]).is_empty());
    }
}
