//! Dependency-free chunked execution pool for the crypto hot paths.
//!
//! The SPNN hot loops — Paillier batch encryption/decryption
//! ([`paillier::pack`](crate::paillier::pack)), fixed-point encoding, the
//! native ring matmul and the Beaver combine step — are all
//! embarrassingly parallel over contiguous chunks. [`ExecPool`] fans such
//! work out over scoped OS threads (`std::thread::scope`, so borrowed
//! inputs need no `'static` gymnastics) and falls back to the calling
//! thread when the work is too small to amortize a spawn or the pool is
//! sized to one.
//!
//! **Determinism:** every operation assigns each output element to exactly
//! one worker and runs the same per-element code in the same order as the
//! serial path, so results are bit-identical for any thread count — the
//! protocol tests (seeded end-to-end runs) hold under `ExecPool::serial()`
//! and `ExecPool::new(0)` alike. Randomness is never drawn inside workers;
//! callers pre-draw RNG material serially (see
//! [`NoncePool::refill_parallel`](crate::paillier::NoncePool::refill_parallel)).
//!
//! Sizing: explicit count > `TrainConfig::exec_threads` via
//! [`set_default_threads`] > `SPNN_EXEC_THREADS` env var >
//! `std::thread::available_parallelism`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Process-wide default thread count (0 = auto-detect). Written once per
/// training run from `TrainConfig::exec_threads`.
static DEFAULT_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide default pool width (0 = auto-detect).
pub fn set_default_threads(n: usize) {
    DEFAULT_THREADS.store(n, Ordering::Relaxed);
}

/// Hardware/env auto-detection, computed once.
fn auto_threads() -> usize {
    static AUTO: OnceLock<usize> = OnceLock::new();
    *AUTO.get_or_init(|| {
        if let Ok(v) = std::env::var("SPNN_EXEC_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 1 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// The process-default pool (honors [`set_default_threads`], then the
/// `SPNN_EXEC_THREADS` env var, then the core count).
pub fn pool() -> ExecPool {
    ExecPool::new(DEFAULT_THREADS.load(Ordering::Relaxed))
}

/// A chunked fork-join pool. Cheap to copy — it is only a width; threads
/// are scoped per call, so there is no teardown/lifecycle to manage.
#[derive(Clone, Copy, Debug)]
pub struct ExecPool {
    threads: usize,
}

impl ExecPool {
    /// `threads = 0` resolves `SPNN_EXEC_THREADS`, then
    /// `available_parallelism`; any explicit count is taken as-is.
    pub fn new(threads: usize) -> Self {
        let t = if threads == 0 { auto_threads() } else { threads };
        ExecPool { threads: t.max(1) }
    }

    /// Single-thread pool: the deterministic baseline for tests/benches.
    pub fn serial() -> Self {
        ExecPool { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Chunk length splitting `n` items across the pool, floored at
    /// `min_chunk` so tiny work stays inline.
    fn chunk_len(&self, n: usize, min_chunk: usize) -> usize {
        n.div_ceil(self.threads).max(min_chunk.max(1))
    }

    /// Parallel map preserving input order. Chunks of at least `min_chunk`
    /// items ship to workers; if everything fits one chunk the map runs on
    /// the calling thread.
    pub fn par_map<T, R, F>(&self, items: &[T], min_chunk: usize, f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Sync,
    {
        let chunk = self.chunk_len(items.len(), min_chunk);
        if self.threads == 1 || chunk >= items.len() {
            return items.iter().map(f).collect();
        }
        let f = &f;
        std::thread::scope(|s| {
            let handles: Vec<_> = items
                .chunks(chunk)
                .map(|c| s.spawn(move || c.iter().map(f).collect::<Vec<R>>()))
                .collect();
            handles
                .into_iter()
                .flat_map(|h| {
                    // re-raise worker panics with their original payload
                    h.join().unwrap_or_else(|e| std::panic::resume_unwind(e))
                })
                .collect()
        })
    }

    /// Row-banded in-place fill: `out.len()` must be a multiple of
    /// `stride`; disjoint bands of whole rows go to workers as
    /// `(first_row, band)`. `stride = 1` gives plain elementwise chunking.
    /// Bands never split a row, so matrix kernels can index freely.
    pub fn par_rows_mut<T, F>(&self, out: &mut [T], stride: usize, min_rows: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(stride > 0 && out.len() % stride == 0, "par_rows_mut: bad stride");
        let rows = out.len() / stride;
        let chunk_rows = self.chunk_len(rows, min_rows);
        if self.threads == 1 || chunk_rows >= rows {
            f(0, out);
            return;
        }
        let f = &f;
        std::thread::scope(|s| {
            for (i, band) in out.chunks_mut(chunk_rows * stride).enumerate() {
                s.spawn(move || f(i * chunk_rows, band));
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order_and_values() {
        let xs: Vec<u64> = (0..10_000).collect();
        for pool in [ExecPool::serial(), ExecPool::new(2), ExecPool::new(7)] {
            let got = pool.par_map(&xs, 1, |&x| x * x + 1);
            let want: Vec<u64> = xs.iter().map(|&x| x * x + 1).collect();
            assert_eq!(got, want, "threads={}", pool.threads());
        }
    }

    #[test]
    fn par_map_small_input_runs_inline() {
        let xs = [1u32, 2, 3];
        let got = ExecPool::new(8).par_map(&xs, 64, |&x| x + 1);
        assert_eq!(got, vec![2, 3, 4]);
    }

    #[test]
    fn par_rows_mut_bands_never_split_rows() {
        let (rows, cols) = (97, 13); // deliberately non-round
        for pool in [ExecPool::serial(), ExecPool::new(3), ExecPool::new(16)] {
            let mut out = vec![0usize; rows * cols];
            pool.par_rows_mut(&mut out, cols, 1, |row0, band| {
                assert_eq!(band.len() % cols, 0, "band split a row");
                for (i, v) in band.iter_mut().enumerate() {
                    let r = row0 + i / cols;
                    let c = i % cols;
                    *v = r * 1000 + c;
                }
            });
            for r in 0..rows {
                for c in 0..cols {
                    assert_eq!(out[r * cols + c], r * 1000 + c);
                }
            }
        }
    }

    #[test]
    fn par_rows_mut_empty_and_single() {
        let mut empty: Vec<u8> = vec![];
        ExecPool::new(4).par_rows_mut(&mut empty, 1, 1, |_, _| {});
        let mut one = vec![7u8];
        ExecPool::new(4).par_rows_mut(&mut one, 1, 1, |off, c| {
            assert_eq!(off, 0);
            c[0] += 1;
        });
        assert_eq!(one, vec![8]);
    }

    #[test]
    fn pool_resolves_to_at_least_one_thread() {
        assert!(ExecPool::new(0).threads() >= 1);
        assert_eq!(ExecPool::serial().threads(), 1);
        assert_eq!(ExecPool::new(5).threads(), 5);
        assert!(pool().threads() >= 1);
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        // the determinism contract the protocol tests lean on
        let xs: Vec<f64> = (0..5000).map(|i| (i as f64) * 0.37 - 900.0).collect();
        let serial = ExecPool::serial().par_map(&xs, 1, |&x| (x * 1.000001).to_bits());
        let par = ExecPool::new(4).par_map(&xs, 1, |&x| (x * 1.000001).to_bits());
        assert_eq!(serial, par);
    }
}
