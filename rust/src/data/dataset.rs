//! Dataset container, train/test split, vertical partitioning, batching.

use crate::rng::{Pcg64, Rng64};

/// Row-major feature matrix + binary labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub n_features: usize,
    /// `n x d`, row-major.
    pub x: Vec<f32>,
    /// `n` binary labels.
    pub y: Vec<f32>,
}

/// One mini-batch padded to a static artifact batch size.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Padded to `cap` rows; padding rows are zero.
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    /// 1.0 for real rows, 0.0 for padding.
    pub mask: Vec<f32>,
    /// Real (unpadded) row count.
    pub rows: usize,
    /// Padded row count (the artifact's static batch).
    pub cap: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.n_features..(i + 1) * self.n_features]
    }

    /// Shuffled split into train/test by fraction.
    pub fn split(&self, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
        let n = self.len();
        let mut idx: Vec<usize> = (0..n).collect();
        let mut rng = Pcg64::seed_from_u64(seed);
        rng.shuffle(&mut idx);
        let n_train = (n as f64 * train_frac).round() as usize;
        let take = |ids: &[usize]| -> Dataset {
            let mut x = Vec::with_capacity(ids.len() * self.n_features);
            let mut y = Vec::with_capacity(ids.len());
            for &i in ids {
                x.extend_from_slice(self.row(i));
                y.push(self.y[i]);
            }
            Dataset { n_features: self.n_features, x, y }
        };
        (take(&idx[..n_train]), take(&idx[n_train..]))
    }

    /// Keep the first `frac` of rows (Fig 9b/c data-size sweeps).
    pub fn subset_frac(&self, frac: f64) -> Dataset {
        let keep = ((self.len() as f64) * frac).round() as usize;
        Dataset {
            n_features: self.n_features,
            x: self.x[..keep * self.n_features].to_vec(),
            y: self.y[..keep].to_vec(),
        }
    }

    /// Mini-batches of `batch` rows, each padded to `cap` rows with a mask.
    pub fn batches(&self, batch: usize, cap: usize) -> Vec<Batch> {
        assert!(batch <= cap, "batch {batch} exceeds artifact cap {cap}");
        let d = self.n_features;
        let mut out = Vec::new();
        let mut start = 0;
        while start < self.len() {
            let rows = batch.min(self.len() - start);
            let mut x = vec![0.0f32; cap * d];
            let mut y = vec![0.0f32; cap];
            let mut mask = vec![0.0f32; cap];
            x[..rows * d].copy_from_slice(&self.x[start * d..(start + rows) * d]);
            y[..rows].copy_from_slice(&self.y[start..start + rows]);
            for m in mask.iter_mut().take(rows) {
                *m = 1.0;
            }
            out.push(Batch { x, y, mask, rows, cap });
            start += rows;
        }
        out
    }

    /// Fraction of positive labels.
    pub fn positive_rate(&self) -> f64 {
        self.y.iter().filter(|&&v| v > 0.5).count() as f64 / self.len() as f64
    }
}

/// A vertical (feature-wise) partition of a dataset across `k` holders.
///
/// The paper assumes samples are pre-aligned by PSI (§3.1.1); synthetic data
/// is aligned by construction. Holder 0 (`A`) additionally owns the labels.
#[derive(Clone, Debug)]
pub struct VerticalSplit {
    /// Column ranges per holder: `[start, end)`.
    pub ranges: Vec<(usize, usize)>,
}

impl VerticalSplit {
    /// Split `d` features into `k` near-equal contiguous ranges.
    pub fn even(d: usize, k: usize) -> Self {
        assert!(k >= 1 && k <= d, "bad split {k} of {d}");
        let base = d / k;
        let extra = d % k;
        let mut ranges = Vec::with_capacity(k);
        let mut start = 0;
        for i in 0..k {
            let w = base + usize::from(i < extra);
            ranges.push((start, start + w));
            start += w;
        }
        Self { ranges }
    }

    pub fn k(&self) -> usize {
        self.ranges.len()
    }

    /// Extract holder `i`'s feature block from a row-major matrix.
    pub fn slice_x(&self, x: &[f32], d: usize, holder: usize) -> Vec<f32> {
        let (s, e) = self.ranges[holder];
        let rows = x.len() / d;
        let w = e - s;
        let mut out = Vec::with_capacity(rows * w);
        for r in 0..rows {
            out.extend_from_slice(&x[r * d + s..r * d + e]);
        }
        out
    }

    /// Holder `i`'s feature width.
    pub fn width(&self, holder: usize) -> usize {
        let (s, e) = self.ranges[holder];
        e - s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize, d: usize) -> Dataset {
        Dataset {
            n_features: d,
            x: (0..n * d).map(|i| i as f32).collect(),
            y: (0..n).map(|i| (i % 2) as f32).collect(),
        }
    }

    #[test]
    fn split_preserves_rows_and_is_disjoint() {
        let ds = toy(100, 3);
        let (tr, te) = ds.split(0.8, 1);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
        // all original first-column values present exactly once
        let mut firsts: Vec<i64> = tr
            .x
            .chunks(3)
            .chain(te.x.chunks(3))
            .map(|r| r[0] as i64)
            .collect();
        firsts.sort_unstable();
        assert_eq!(firsts, (0..100).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn batches_pad_and_mask() {
        let ds = toy(10, 2);
        let batches = ds.batches(4, 6);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].rows, 4);
        assert_eq!(batches[2].rows, 2);
        let last = &batches[2];
        assert_eq!(last.mask[..2], [1.0, 1.0]);
        assert_eq!(last.mask[2..], [0.0, 0.0, 0.0, 0.0]);
        assert!(last.x[2 * 2..].iter().all(|&v| v == 0.0), "padding not zero");
        // batch rows preserve data
        assert_eq!(last.x[0], ds.x[8 * 2]);
    }

    #[test]
    fn vertical_split_covers_all_columns() {
        for (d, k) in [(28, 2), (28, 3), (28, 5), (556, 2), (7, 7)] {
            let vs = VerticalSplit::even(d, k);
            assert_eq!(vs.k(), k);
            assert_eq!(vs.ranges[0].0, 0);
            assert_eq!(vs.ranges[k - 1].1, d);
            let total: usize = (0..k).map(|i| vs.width(i)).sum();
            assert_eq!(total, d);
            // widths differ by at most 1
            let ws: Vec<usize> = (0..k).map(|i| vs.width(i)).collect();
            assert!(ws.iter().max().unwrap() - ws.iter().min().unwrap() <= 1);
        }
    }

    #[test]
    fn slice_x_extracts_columns() {
        let ds = toy(3, 4);
        let vs = VerticalSplit::even(4, 2);
        let xa = vs.slice_x(&ds.x, 4, 0);
        let xb = vs.slice_x(&ds.x, 4, 1);
        assert_eq!(xa, vec![0.0, 1.0, 4.0, 5.0, 8.0, 9.0]);
        assert_eq!(xb, vec![2.0, 3.0, 6.0, 7.0, 10.0, 11.0]);
    }

    #[test]
    fn subset_frac_truncates() {
        let ds = toy(10, 2);
        let s = ds.subset_frac(0.3);
        assert_eq!(s.len(), 3);
        assert_eq!(s.x.len(), 6);
    }
}
