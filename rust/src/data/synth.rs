//! Seeded synthetic generators matching the paper's two benchmarks in
//! shape, imbalance and learnability (DESIGN.md §3 substitution table).
//!
//! Both generators plant a low-dimensional discriminative structure so the
//! paper's MLPs reach high-but-not-perfect AUC (the regime where the
//! *relative* ordering NN >= SPNN > SplitNN/SecureML is observable), and
//! spread the signal across **both holders' feature blocks** so SplitNN's
//! per-holder encoders lose cross-party feature interactions (the effect
//! Figure 5 measures).

use super::Dataset;
use crate::rng::{NormalSampler, Pcg64, Rng64};

/// Generation options (sizes default to the paper's datasets).
#[derive(Clone, Copy, Debug)]
pub struct SynthOpts {
    pub rows: usize,
    pub seed: u64,
    /// Multiplier on the positive rate (1.0 = paper-matched imbalance).
    /// Small test datasets need a boost or they contain no positives at
    /// all and AUC degenerates to 0.5.
    pub pos_boost: f64,
}

impl SynthOpts {
    pub fn fraud_full() -> Self {
        SynthOpts { rows: 284_807, seed: 42, pos_boost: 1.0 }
    }

    pub fn distress_full() -> Self {
        SynthOpts { rows: 3_672, seed: 43, pos_boost: 1.0 }
    }

    /// Reduced sizes for fast tests/examples (positives boosted to ~9%).
    pub fn small(rows: usize) -> Self {
        SynthOpts { rows, seed: 42, pos_boost: 50.0 }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Credit-card-fraud-like dataset: 28 features, ~0.173% positives.
///
/// Features 0..27 mimic the PCA components of the real dataset (decorrelated
/// Gaussians with decaying scale); feature 27 is the `Amount`-like value the
/// Table 2 property attack targets: log-normal, and *correlated with the
/// discriminative directions* so the first hidden layer necessarily encodes
/// it (that is what makes the attack non-trivial).
pub fn synth_fraud(opts: SynthOpts) -> Dataset {
    let d = 28;
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let mut ns = NormalSampler::new();
    let n = opts.rows;

    // class-discriminative directions, spread across ALL features so every
    // holder's block carries part of the signal
    let dirs: Vec<Vec<f64>> = (0..3)
        .map(|_| (0..d).map(|_| ns.sample(&mut rng)).collect())
        .collect();

    let mut x = vec![0.0f32; n * d];
    let mut y = vec![0.0f32; n];
    let pos_rate = (0.00173 * opts.pos_boost).min(0.4);
    for i in 0..n {
        let is_pos = rng.f64_unit() < pos_rate;
        y[i] = is_pos as u64 as f32;
        // latent factors: positives shifted along the planted directions
        let mut z: Vec<f64> = (0..3).map(|_| ns.sample(&mut rng)).collect();
        if is_pos {
            for v in z.iter_mut() {
                *v += 2.2; // separation strength tuned for AUC ~ 0.95 ceiling
            }
        }
        let row = &mut x[i * d..(i + 1) * d];
        for (j, r) in row.iter_mut().enumerate().take(d - 1) {
            // PCA-like decaying scales + planted signal
            let scale = 1.5 / (1.0 + j as f64 * 0.12);
            let mut v = ns.sample(&mut rng) * scale;
            for (f, dir) in dirs.iter().enumerate() {
                v += z[f] * dir[j] * 0.35;
            }
            *r = v as f32;
        }
        // Amount: log-normal driven by the SAME latent factors (plus noise)
        // so hidden layers encode it -> property-attack target (Table 2)
        let amount = (0.8 * z[0] + 0.4 * z[1] + 0.6 * ns.sample(&mut rng)).exp();
        row[d - 1] = amount as f32;
    }
    standardize(&mut x, d, d);
    Dataset { n_features: d, x, y }
}

/// Financial-distress-like dataset: 83 raw features (30 numeric + 53
/// categorical) one-hot encoded to exactly 556 columns, ~3.7% positives.
pub fn synth_distress(opts: SynthOpts) -> Dataset {
    let n = opts.rows;
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let mut ns = NormalSampler::new();

    // 30 numeric + 53 categorical expanding to 526 one-hot columns = 556
    let n_num = 30usize;
    let mut levels = vec![10usize; 53];
    for l in levels.iter_mut().take(4) {
        *l = 9;
    }
    let d: usize = n_num + levels.iter().sum::<usize>();
    assert_eq!(d, 556, "one-hot layout drifted");

    let dirs: Vec<Vec<f64>> = (0..2)
        .map(|_| (0..n_num).map(|_| ns.sample(&mut rng)).collect())
        .collect();

    let mut x = vec![0.0f32; n * d];
    let mut y = vec![0.0f32; n];
    let pos_rate = (0.037 * opts.pos_boost).min(0.4);
    for i in 0..n {
        let is_pos = rng.f64_unit() < pos_rate;
        y[i] = is_pos as u64 as f32;
        let mut z: Vec<f64> = (0..2).map(|_| ns.sample(&mut rng)).collect();
        if is_pos {
            for v in z.iter_mut() {
                *v += 1.8;
            }
        }
        let row = &mut x[i * d..(i + 1) * d];
        for j in 0..n_num {
            let mut v = ns.sample(&mut rng);
            for (f, dir) in dirs.iter().enumerate() {
                v += z[f] * dir[j] * 0.5;
            }
            row[j] = v as f32;
        }
        // categoricals: level selection biased by the latent factor so the
        // one-hot block also carries signal
        let mut off = n_num;
        for (c, &lv) in levels.iter().enumerate() {
            let bias = (z[c % 2] * 1.2).tanh(); // in (-1, 1)
            let u = (rng.f64_unit() + bias * 0.25).clamp(0.0, 0.999_999);
            let pick = (u * lv as f64) as usize;
            row[off + pick] = 1.0;
            off += lv;
        }
    }
    standardize(&mut x, d, n_num); // standardize the numeric block only
    // note: one-hot columns are left as 0/1 (standard practice)
    Dataset { n_features: d, x, y }
}

/// Column-wise standardization of the first `d_std` columns of a row-major
/// matrix with row stride `stride`.
fn standardize(x: &mut [f32], stride: usize, d_std: usize) {
    if x.is_empty() {
        return;
    }
    assert_eq!(x.len() % stride, 0);
    let rows = x.len() / stride;
    for c in 0..d_std.min(stride) {
        let mut mean = 0.0f64;
        for r in 0..rows {
            mean += x[r * stride + c] as f64;
        }
        mean /= rows as f64;
        let mut var = 0.0f64;
        for r in 0..rows {
            let v = x[r * stride + c] as f64 - mean;
            var += v * v;
        }
        let sd = (var / rows as f64).sqrt().max(1e-6);
        for r in 0..rows {
            let v = &mut x[r * stride + c];
            *v = ((*v as f64 - mean) / sd) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fraud_shape_and_imbalance() {
        let ds = synth_fraud(SynthOpts { rows: 50_000, seed: 1, pos_boost: 1.0 });
        assert_eq!(ds.n_features, 28);
        assert_eq!(ds.len(), 50_000);
        let rate = ds.positive_rate();
        assert!(rate > 0.0005 && rate < 0.004, "positive rate {rate}");
    }

    #[test]
    fn fraud_is_deterministic_per_seed() {
        let a = synth_fraud(SynthOpts { rows: 100, seed: 5, pos_boost: 1.0 });
        let b = synth_fraud(SynthOpts { rows: 100, seed: 5, pos_boost: 1.0 });
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = synth_fraud(SynthOpts { rows: 100, seed: 6, pos_boost: 1.0 });
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn fraud_features_standardized() {
        let ds = synth_fraud(SynthOpts { rows: 20_000, seed: 2, pos_boost: 1.0 });
        for c in [0usize, 13, 27] {
            let vals: Vec<f64> = (0..ds.len()).map(|r| ds.row(r)[c] as f64).collect();
            let mean = vals.iter().sum::<f64>() / vals.len() as f64;
            let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
                / vals.len() as f64;
            assert!(mean.abs() < 0.05, "col {c} mean {mean}");
            assert!((var - 1.0).abs() < 0.1, "col {c} var {var}");
        }
    }

    #[test]
    fn fraud_is_linearly_separable_enough() {
        // a trivial linear probe on the raw features should already beat 0.8
        // AUC — the planted signal must be learnable
        let ds = synth_fraud(SynthOpts { rows: 30_000, seed: 3, pos_boost: 1.0 });
        // use class-mean difference as the probe direction
        let d = ds.n_features;
        let mut mu_pos = vec![0.0f64; d];
        let mut mu_neg = vec![0.0f64; d];
        let (mut np, mut nn) = (0.0f64, 0.0f64);
        for i in 0..ds.len() {
            let row = ds.row(i);
            if ds.y[i] > 0.5 {
                np += 1.0;
                for (m, &v) in mu_pos.iter_mut().zip(row) {
                    *m += v as f64;
                }
            } else {
                nn += 1.0;
                for (m, &v) in mu_neg.iter_mut().zip(row) {
                    *m += v as f64;
                }
            }
        }
        for m in mu_pos.iter_mut() {
            *m /= np;
        }
        for m in mu_neg.iter_mut() {
            *m /= nn;
        }
        let w: Vec<f64> = mu_pos.iter().zip(&mu_neg).map(|(p, q)| p - q).collect();
        let scores: Vec<f32> = (0..ds.len())
            .map(|i| ds.row(i).iter().zip(&w).map(|(&v, &c)| v as f64 * c).sum::<f64>() as f32)
            .collect();
        let a = crate::data::auc(&scores, &ds.y);
        assert!(a > 0.8, "linear probe AUC {a}");
    }

    #[test]
    fn distress_shape_and_onehot() {
        let ds = synth_distress(SynthOpts { rows: 3_672, seed: 4, pos_boost: 1.0 });
        assert_eq!(ds.n_features, 556);
        assert_eq!(ds.len(), 3_672);
        let rate = ds.positive_rate();
        assert!(rate > 0.02 && rate < 0.06, "positive rate {rate}");
        // each categorical block has exactly one hot bit per row
        let row = ds.row(0);
        let onehot_sum: f32 = row[30..].iter().sum();
        assert_eq!(onehot_sum, 53.0, "one-hot blocks must each have one 1");
        assert!(row[30..].iter().all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn amount_column_correlates_with_features() {
        // property-attack target: 'amount' (col 27) must be predictable
        // from the other features (it shares latent factors)
        let ds = synth_fraud(SynthOpts { rows: 20_000, seed: 7, pos_boost: 1.0 });
        // correlation of col 27 with col 0 via the shared z0 factor
        let (mut sxy, mut sx, mut sy, mut sx2, mut sy2) = (0f64, 0f64, 0f64, 0f64, 0f64);
        let n = ds.len() as f64;
        for i in 0..ds.len() {
            let a = ds.row(i)[0] as f64;
            let b = ds.row(i)[27] as f64;
            sxy += a * b;
            sx += a;
            sy += b;
            sx2 += a * a;
            sy2 += b * b;
        }
        let corr = (sxy - sx * sy / n)
            / ((sx2 - sx * sx / n).sqrt() * (sy2 - sy * sy / n).sqrt());
        assert!(corr.abs() > 0.05, "amount decorrelated: corr {corr}");
    }
}
