//! Holder-side feature transforms: seeded, deterministic **orthogonal
//! projections** applied to each private feature block *before* any
//! encryption or secret sharing (ROADMAP item 3, DCT-CryptoNets-style
//! frequency-domain compression).
//!
//! Each data holder maps its `rows x d_p` private block to `rows x k_p`
//! with an orthonormal matrix `Q_p` (`Q_pᵀ Q_p = I_k`), so everything
//! downstream of the holder — Paillier plaintexts, secret shares, Beaver
//! triple shapes, dealer scripts, wire bytes — shrinks proportionally to
//! `k_p / d_p`. Two bases are available
//! ([`crate::config::CompressBasis`]):
//!
//! * **DCT** — the `k` lowest-frequency columns of the orthonormal DCT-II
//!   basis. Deterministic (no randomness at all), the classic
//!   energy-compaction choice.
//! * **Sketch** — seeded Gaussian columns orthonormalized by *serial*
//!   modified Gram–Schmidt, so the matrix is a function of the seed alone
//!   (bit-identical at any `exec` thread count).
//!
//! Both are pure `f64` linear algebra on the holder's own plaintext: the
//! transform never touches a ciphertext or a share, and because `Q` is
//! derived from the broadcast session seed, every process derives the
//! identical matrix — transcript determinism is preserved (the digest
//! tests pin the *compressed* transcript across transports and depths).

use crate::config::{CompressBasis, CompressCfg, CompressK};
use crate::nn::MatF64;
use crate::rng::{splitmix64, ChaChaRng, Rng64};
use crate::{Error, Result};

use super::dataset::{Dataset, VerticalSplit};

/// One holder's orthogonal projection `Q` (`d x k`, orthonormal columns).
#[derive(Clone, Debug)]
pub struct FeatureTransform {
    /// Input width (the holder's raw feature count `d_p`).
    pub d: usize,
    /// Output width (kept columns, `k_p <= d_p`).
    pub k: usize,
    /// The projection matrix, `d x k` with `QᵀQ = I_k`.
    pub q: MatF64,
}

impl FeatureTransform {
    /// The `k` lowest-frequency columns of the orthonormal DCT-II basis:
    /// `Q[i][j] = c_j * cos(pi * (i + 0.5) * j / d)` with
    /// `c_0 = sqrt(1/d)`, `c_j = sqrt(2/d)` otherwise.
    pub fn dct(d: usize, k: usize) -> Self {
        assert!(k >= 1 && k <= d, "bad transform {d} -> {k}");
        let mut data = vec![0.0f64; d * k];
        for i in 0..d {
            for j in 0..k {
                let c = if j == 0 { (1.0 / d as f64).sqrt() } else { (2.0 / d as f64).sqrt() };
                data[i * k + j] =
                    c * (std::f64::consts::PI * (i as f64 + 0.5) * j as f64 / d as f64).cos();
            }
        }
        FeatureTransform { d, k, q: MatF64::from_data(d, k, data) }
    }

    /// Seeded random-orthogonal sketch: `k` standard-Gaussian columns,
    /// orthonormalized by serial modified Gram–Schmidt. All randomness
    /// comes from one ChaCha stream drawn in a fixed order, so the result
    /// is a pure function of `(d, k, seed)` — independent of thread count.
    pub fn sketch(d: usize, k: usize, seed: u64) -> Self {
        assert!(k >= 1 && k <= d, "bad transform {d} -> {k}");
        let mut rng = ChaChaRng::seed_from_u64(seed);
        let mut cols: Vec<Vec<f64>> = Vec::with_capacity(k);
        for _ in 0..k {
            // redraw a column if it lands (numerically) in the span of the
            // previous ones — probability ~0 for Gaussian draws, but the
            // guard keeps the constructor total
            loop {
                let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
                for c in &cols {
                    let dot: f64 = c.iter().zip(&v).map(|(a, b)| a * b).sum();
                    for (vi, ci) in v.iter_mut().zip(c) {
                        *vi -= dot * ci;
                    }
                }
                let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
                if norm > 1e-6 {
                    for vi in v.iter_mut() {
                        *vi /= norm;
                    }
                    cols.push(v);
                    break;
                }
            }
        }
        let mut data = vec![0.0f64; d * k];
        for (j, c) in cols.iter().enumerate() {
            for i in 0..d {
                data[i * k + j] = c[i];
            }
        }
        FeatureTransform { d, k, q: MatF64::from_data(d, k, data) }
    }

    /// Build from a [`CompressCfg`] basis choice.
    pub fn build(basis: CompressBasis, d: usize, k: usize, seed: u64) -> Self {
        match basis {
            CompressBasis::Dct => Self::dct(d, k),
            CompressBasis::Sketch => Self::sketch(d, k, seed),
        }
    }

    /// Project a `rows x d` block to `rows x k`: `X · Q`. Row-banded over
    /// the `exec` pool with bit-identical results at any width.
    pub fn apply(&self, x: &MatF64) -> MatF64 {
        assert_eq!(x.cols, self.d, "transform width mismatch");
        x.matmul(&self.q)
    }
}

/// Per-holder transform seed: a splitmix64 chain over the session seed and
/// the holder index (decorrelated from every other seed-derived stream).
fn holder_transform_seed(seed: u64, holder: usize) -> u64 {
    let mut s = seed ^ 0xfea7_0c0d_ec11_ab1e ^ (holder as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s)
}

/// The full compression layout for one training/serving session: the raw
/// `d`-domain vertical split (how private columns are sliced from the
/// table), the compressed `k`-domain split (how shares / theta blocks /
/// dealer shapes are sized), and one [`FeatureTransform`] per holder.
///
/// Built identically by every party from the broadcast `(compress, seed)`
/// pair, exactly like the model init.
#[derive(Clone, Debug)]
pub struct CompressPlan {
    /// Raw feature split (`d` columns across the holders).
    pub raw: VerticalSplit,
    /// Compressed split (`k_total` columns across the holders) — the
    /// split every crypto shape downstream is sized by.
    pub csplit: VerticalSplit,
    /// One projection per holder (`tfs[j]` maps `raw.width(j)` columns to
    /// `csplit.width(j)`).
    pub tfs: Vec<FeatureTransform>,
    /// Total raw feature count `d`.
    pub d_total: usize,
}

impl CompressPlan {
    /// Build the plan for `parts` holders over `d` raw features.
    pub fn build(cc: &CompressCfg, d: usize, parts: usize, seed: u64) -> Result<CompressPlan> {
        let raw = VerticalSplit::even(d, parts);
        let widths: Vec<usize> = match cc.k {
            CompressK::Ratio(r) => {
                if !(r > 0.0 && r <= 1.0) {
                    return Err(Error::Config(format!("compress ratio {r} not in (0, 1]")));
                }
                (0..parts)
                    .map(|j| {
                        let dj = raw.width(j);
                        ((dj as f64 * r).round() as usize).clamp(1, dj)
                    })
                    .collect()
            }
            CompressK::Cols(k) => {
                if k < parts || k > d {
                    return Err(Error::Config(format!(
                        "compress k={k} out of range for {d} features across {parts} holders \
                         (need {parts} <= k <= {d})"
                    )));
                }
                let ks = VerticalSplit::even(k, parts);
                (0..parts).map(|j| ks.width(j)).collect()
            }
        };
        let mut ranges = Vec::with_capacity(parts);
        let mut start = 0;
        for &w in &widths {
            ranges.push((start, start + w));
            start += w;
        }
        let csplit = VerticalSplit { ranges };
        let tfs = (0..parts)
            .map(|j| {
                FeatureTransform::build(
                    cc.basis,
                    raw.width(j),
                    widths[j],
                    holder_transform_seed(seed, j),
                )
            })
            .collect();
        Ok(CompressPlan { raw, csplit, tfs, d_total: d })
    }

    /// `None`-transparent builder: `compress = None` yields `Ok(None)`
    /// (the seed behavior, no transform anywhere).
    pub fn maybe(
        cc: Option<&CompressCfg>,
        d: usize,
        parts: usize,
        seed: u64,
    ) -> Result<Option<CompressPlan>> {
        cc.map(|c| Self::build(c, d, parts, seed)).transpose()
    }

    /// Total compressed width `k = sum_p k_p` (the first model layer's
    /// input dimension under compression).
    pub fn k_total(&self) -> usize {
        self.csplit.ranges.last().map(|&(_, e)| e).unwrap_or(0)
    }

    /// Holder `j`'s transform (cloned for the holder's `FeatureSource`).
    pub fn tf(&self, j: usize) -> FeatureTransform {
        self.tfs[j].clone()
    }

    /// Apply the block-diagonal transform to a full-width row-major table
    /// (`n x d` -> `n x k_total`) — used to build the compressed held-out
    /// evaluation set.
    pub fn apply_table(&self, x: &[f32]) -> Vec<f32> {
        let d = self.d_total;
        let rows = x.len() / d;
        let k_total = self.k_total();
        let mut out = vec![0.0f32; rows * k_total];
        for j in 0..self.tfs.len() {
            let xj = self.raw.slice_x(x, d, j);
            let xm = MatF64::from_f32(rows, self.raw.width(j), &xj);
            let z = self.tfs[j].apply(&xm).to_f32();
            let (s, e) = self.csplit.ranges[j];
            let kj = e - s;
            for r in 0..rows {
                out[r * k_total + s..r * k_total + e]
                    .copy_from_slice(&z[r * kj..(r + 1) * kj]);
            }
        }
        out
    }

    /// The compressed twin of a dataset: same rows/labels, `k_total`
    /// feature columns (feeds the unchanged evaluation paths, which size
    /// themselves by `Dataset::n_features`).
    pub fn transform_dataset(&self, ds: &Dataset) -> Dataset {
        Dataset { n_features: self.k_total(), x: self.apply_table(&ds.x), y: ds.y.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CompressBasis;
    use crate::data::{synth_fraud, SynthOpts};

    fn assert_orthonormal(t: &FeatureTransform, tol: f64) {
        // QᵀQ = I_k
        let qtq = t.q.transpose().matmul(&t.q);
        for i in 0..t.k {
            for j in 0..t.k {
                let want = if i == j { 1.0 } else { 0.0 };
                let got = qtq.at(i, j);
                assert!(
                    (got - want).abs() < tol,
                    "QᵀQ[{i}][{j}] = {got} (want {want}) for d={} k={}",
                    t.d,
                    t.k
                );
            }
        }
    }

    #[test]
    fn dct_columns_are_orthonormal() {
        for (d, k) in [(1, 1), (14, 7), (14, 14), (28, 7), (278, 70)] {
            assert_orthonormal(&FeatureTransform::dct(d, k), 1e-9);
        }
    }

    #[test]
    fn sketch_columns_are_orthonormal() {
        for (d, k) in [(1, 1), (14, 4), (14, 14), (28, 7), (278, 70)] {
            assert_orthonormal(&FeatureTransform::sketch(d, k, 0xabc), 1e-9);
        }
    }

    #[test]
    fn transforms_are_seed_deterministic() {
        // the sketch is a pure function of (d, k, seed): two builds are
        // bit-identical (the serial Gram-Schmidt never touches the exec
        // pool), and different seeds give different matrices
        let a = FeatureTransform::sketch(14, 7, 42);
        let b = FeatureTransform::sketch(14, 7, 42);
        assert_eq!(a.q.data, b.q.data);
        let c = FeatureTransform::sketch(14, 7, 43);
        assert_ne!(a.q.data, c.q.data);
        // apply() is row-banded over the exec pool with deterministic
        // banding: two applications are bit-identical
        let x = MatF64::from_data(5, 14, (0..70).map(|i| i as f64 * 0.1).collect());
        assert_eq!(a.apply(&x).data, b.apply(&x).data);
        // and the DCT has no randomness at all
        let d1 = FeatureTransform::dct(28, 7);
        let d2 = FeatureTransform::dct(28, 7);
        assert_eq!(d1.q.data, d2.q.data);
    }

    #[test]
    fn plan_budgets_ratio_and_cols() {
        use crate::config::{CompressCfg, CompressK};
        // ratio 0.5 on fraud (28 features, 2 holders): 7 + 7 kept
        let cc = CompressCfg { basis: CompressBasis::Dct, k: CompressK::Ratio(0.5) };
        let p = CompressPlan::build(&cc, 28, 2, 7).unwrap();
        assert_eq!(p.k_total(), 14);
        assert_eq!(p.csplit.ranges, vec![(0, 7), (7, 14)]);
        assert_eq!(p.raw.ranges, vec![(0, 14), (14, 28)]);
        assert_eq!(p.tfs[0].d, 14);
        assert_eq!(p.tfs[0].k, 7);
        // absolute k = 7 across 3 holders: 3 + 2 + 2
        let cc = CompressCfg { basis: CompressBasis::Dct, k: CompressK::Cols(7) };
        let p = CompressPlan::build(&cc, 28, 3, 7).unwrap();
        assert_eq!(p.k_total(), 7);
        let ws: Vec<usize> = (0..3).map(|j| p.csplit.width(j)).collect();
        assert_eq!(ws, vec![3, 2, 2]);
        for j in 0..3 {
            assert!(p.csplit.width(j) <= p.raw.width(j));
        }
        // tiny ratios clamp to >= 1 column per holder
        let cc = CompressCfg { basis: CompressBasis::Dct, k: CompressK::Ratio(0.001) };
        let p = CompressPlan::build(&cc, 28, 2, 7).unwrap();
        assert_eq!(p.k_total(), 2);
        // out-of-range absolute k is rejected
        let cc = CompressCfg { basis: CompressBasis::Dct, k: CompressK::Cols(29) };
        assert!(CompressPlan::build(&cc, 28, 2, 7).is_err());
        let cc = CompressCfg { basis: CompressBasis::Dct, k: CompressK::Cols(1) };
        assert!(CompressPlan::build(&cc, 28, 2, 7).is_err());
        // None passes through
        assert!(CompressPlan::maybe(None, 28, 2, 7).unwrap().is_none());
    }

    #[test]
    fn transform_dataset_preserves_rows_and_energy() {
        let ds = synth_fraud(SynthOpts::small(64));
        let cc = crate::config::CompressCfg {
            basis: CompressBasis::Dct,
            k: crate::config::CompressK::Ratio(1.0),
        };
        // ratio 1.0: a full orthonormal rotation — row count, labels, and
        // per-holder-block row energy are all preserved exactly
        let p = CompressPlan::build(&cc, ds.n_features, 2, 7).unwrap();
        let t = p.transform_dataset(&ds);
        assert_eq!(t.len(), ds.len());
        assert_eq!(t.n_features, ds.n_features);
        assert_eq!(t.y, ds.y);
        for r in 0..4 {
            let e0: f64 = ds.row(r).iter().map(|&v| (v as f64) * (v as f64)).sum();
            let e1: f64 = t.row(r).iter().map(|&v| (v as f64) * (v as f64)).sum();
            assert!((e0 - e1).abs() < 1e-6 * (1.0 + e0), "row {r}: {e0} vs {e1}");
        }
        // ratio 0.5 halves the width
        let cc = crate::config::CompressCfg {
            basis: CompressBasis::Sketch,
            k: crate::config::CompressK::Ratio(0.5),
        };
        let p = CompressPlan::build(&cc, ds.n_features, 2, 7).unwrap();
        let t = p.transform_dataset(&ds);
        assert_eq!(t.n_features, 14);
        assert_eq!(t.x.len(), ds.len() * 14);
    }

    #[test]
    fn transformed_features_stay_in_fixed_point_range() {
        // orthogonal projections bound each output by the row norm:
        // |z_i| <= ||x_row||_2 <= sqrt(d) * max|x|. The synthetic features
        // are O(10), d <= 556, so transformed values sit far below the
        // 2^46 encode guard — asserted here through fixed::encode itself
        // (which debug_asserts the headroom) and an explicit margin.
        let ds = synth_fraud(SynthOpts::small(128));
        for basis in [CompressBasis::Dct, CompressBasis::Sketch] {
            let cc = crate::config::CompressCfg {
                basis,
                k: crate::config::CompressK::Ratio(0.5),
            };
            let p = CompressPlan::build(&cc, ds.n_features, 2, 7).unwrap();
            let t = p.transform_dataset(&ds);
            let max = t.x.iter().fold(0.0f64, |m, &v| m.max((v as f64).abs()));
            // far inside the paper's fixed-point product headroom
            assert!(max < crate::fixed::product_headroom(), "max |z| = {max}");
            for &v in t.x.iter().take(4 * t.n_features) {
                let enc = crate::fixed::encode(v as f64);
                assert!((crate::fixed::decode(enc) - v as f64).abs() < 1e-4);
            }
        }
    }
}
