//! Area under the ROC curve (the paper's metric, §6.1), computed by the
//! rank statistic (Mann–Whitney U) with midrank tie handling.

/// AUC of `scores` against binary `labels` (1.0 = positive).
///
/// Returns 0.5 when either class is empty (undefined AUC).
pub fn auc(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n = scores.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());

    // midranks over ties
    let mut ranks = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let mid = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = mid;
        }
        i = j + 1;
    }

    let n_pos = labels.iter().filter(|&&y| y > 0.5).count();
    let n_neg = n - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let rank_sum: f64 = (0..n).filter(|&i| labels[i] > 0.5).map(|i| ranks[i]).sum();
    let u = rank_sum - (n_pos as f64 * (n_pos as f64 + 1.0)) / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng64};

    #[test]
    fn perfect_and_inverted_classifiers() {
        let scores = [0.1f32, 0.2, 0.8, 0.9];
        let labels = [0.0f32, 0.0, 1.0, 1.0];
        assert_eq!(auc(&scores, &labels), 1.0);
        let inv = [0.9f32, 0.8, 0.2, 0.1];
        assert_eq!(auc(&inv, &labels), 0.0);
    }

    #[test]
    fn random_scores_near_half() {
        let mut rng = Pcg64::seed_from_u64(1);
        let n = 20_000;
        let scores: Vec<f32> = (0..n).map(|_| rng.f64_unit() as f32).collect();
        let labels: Vec<f32> = (0..n).map(|_| (rng.next_u64() & 1) as f32).collect();
        let a = auc(&scores, &labels);
        assert!((a - 0.5).abs() < 0.02, "auc {a}");
    }

    #[test]
    fn matches_brute_force_pair_counting() {
        let mut rng = Pcg64::seed_from_u64(2);
        let n = 200;
        let scores: Vec<f32> =
            (0..n).map(|_| (rng.f64_unit() * 10.0).round() as f32 / 10.0).collect();
        let labels: Vec<f32> = (0..n).map(|_| (rng.next_u64() % 4 == 0) as u64 as f32).collect();
        // brute force: P(score_pos > score_neg) + 0.5 P(equal)
        let (mut wins, mut ties, mut pairs) = (0f64, 0f64, 0f64);
        for i in 0..n {
            if labels[i] < 0.5 {
                continue;
            }
            for j in 0..n {
                if labels[j] > 0.5 {
                    continue;
                }
                pairs += 1.0;
                if scores[i] > scores[j] {
                    wins += 1.0;
                } else if scores[i] == scores[j] {
                    ties += 1.0;
                }
            }
        }
        let want = (wins + 0.5 * ties) / pairs;
        let got = auc(&scores, &labels);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(auc(&[0.3, 0.4], &[1.0, 1.0]), 0.5);
        assert_eq!(auc(&[0.3, 0.4], &[0.0, 0.0]), 0.5);
    }
}
