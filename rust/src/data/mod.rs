//! Datasets: synthetic stand-ins for the paper's two benchmarks, vertical
//! partitioning, batching, and the AUC metric.
//!
//! The paper evaluates on the Kaggle credit-card-fraud dataset
//! (284,807 x 28, 0.173% positives) and the Kaggle financial-distress
//! dataset (3,672 x 83 -> 556 one-hot). Neither is redistributable and this
//! environment has no network, so `synth` generates seeded synthetic
//! equivalents with matched dimensionality, class imbalance, and — for the
//! Table 2 property attack — an `amount`-like feature whose signal is
//! carried by the same features the network consumes (DESIGN.md §3).

mod auc;
mod dataset;
mod synth;
mod transform;

pub use auc::auc;
pub use dataset::{Batch, Dataset, VerticalSplit};
pub use synth::{synth_distress, synth_fraud, SynthOpts};
pub use transform::{CompressPlan, FeatureTransform};
