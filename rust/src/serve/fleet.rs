//! Replicated serving **fleet router**: one front door over N serve
//! replicas.
//!
//! Each replica is a [`Backend`] — either a **local** in-process serve
//! session (the coordinator's request queue, typically warm-started from
//! a shared checkpoint dir so N replicas cost one training run) or a
//! **remote** downstream `spnn serve` front door reached over TCP. The
//! [`Fleet`] owns one slot per replica and routes each request:
//!
//! * **queue-depth-aware round robin** — candidates are ordered by their
//!   live in-flight count, with a rotating offset breaking ties, so an
//!   idle replica is preferred over a busy one but equal replicas share
//!   the load evenly;
//! * **sticky failover** — a replica whose queue is gone (process died,
//!   handle dropped) or whose socket dies mid-request is marked dead and
//!   skipped from then on; the request retries on a sibling. Application
//!   errors (row out of range, queue overflow) do **not** fail over: the
//!   replica answered, the answer is a rejection.
//! * **prompt terminal error** — when every replica is dead the client
//!   gets `replica unavailable: ...` immediately instead of a hang.
//!
//! The router is itself just a [`Scorer`], so the shared
//! [`frontdoor`](super::frontdoor) accept/quota/auth machinery serves it
//! unchanged via [`run_door`].

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::frontdoor::{self, Scorer};
use super::Request;
use crate::obs;
use crate::transport::auth::Psk;
use crate::{Error, Result};

/// Where a replica's requests go.
pub enum Backend {
    /// An in-process serve session: the coordinator's request queue.
    /// (Wrapped in a `Mutex` so the fleet is `Sync` without leaning on
    /// `mpsc::Sender`'s `Sync`-ness; the lock is held only to clone.)
    Local(Mutex<mpsc::Sender<Request>>),
    /// A downstream `spnn serve` front door, dialed per request.
    Remote(String),
}

impl Backend {
    /// Wrap an in-process serve session's request queue.
    pub fn local(tx: mpsc::Sender<Request>) -> Backend {
        Backend::Local(Mutex::new(tx))
    }
    /// Point at a downstream front door by address.
    pub fn remote(addr: impl Into<String>) -> Backend {
        Backend::Remote(addr.into())
    }
}

/// One replica: its backend plus the router's live view of it.
struct Slot {
    name: String,
    backend: Backend,
    /// Requests currently dispatched to this replica (the load signal).
    inflight: AtomicUsize,
    /// Sticky: once a replica's transport dies it stays out of rotation.
    dead: AtomicBool,
}

/// How one dispatch attempt ended, seen from the router.
enum Dispatch {
    /// The replica answered — scores or an application-level rejection.
    /// Either way the answer is final: no failover.
    Answered(Result<Vec<f32>>),
    /// The replica's transport died before an answer; retry a sibling.
    Dead(Error),
}

/// The router: a set of replica slots plus the routing state.
pub struct Fleet {
    slots: Vec<Slot>,
    /// Rotating tie-break offset for the round robin.
    rr: AtomicUsize,
    /// Per-request connect budget for [`Backend::Remote`] dials.
    pub connect_timeout: Duration,
    /// How long to wait for a replica's answer before declaring it dead.
    /// `None` waits indefinitely — right for a fleet that is still
    /// training, wrong for one that should already be warm.
    pub reply_timeout: Option<Duration>,
    /// PSK presented to keyed downstream doors ([`Backend::Remote`]).
    pub downstream_psk: Option<Psk>,
}

impl Fleet {
    /// Build a fleet over named backends. Names only label log lines and
    /// errors (`replica-0`, `10.0.0.7:7450`, ...).
    pub fn new(backends: Vec<(String, Backend)>) -> Fleet {
        let slots = backends
            .into_iter()
            .map(|(name, backend)| Slot {
                name,
                backend,
                inflight: AtomicUsize::new(0),
                dead: AtomicBool::new(false),
            })
            .collect();
        Fleet {
            slots,
            rr: AtomicUsize::new(0),
            connect_timeout: Duration::from_secs(10),
            reply_timeout: None,
            downstream_psk: None,
        }
    }

    /// How many replicas are still in rotation.
    pub fn alive(&self) -> usize {
        self.slots.iter().filter(|s| !s.dead.load(Ordering::SeqCst)).count()
    }

    /// Route one request: try replicas in load order, failing over past
    /// dead ones, until one answers or none are left.
    pub fn score(&self, rows: &[u32]) -> Result<Vec<f32>> {
        let n = self.slots.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n.max(1);
        let mut order: Vec<usize> = (0..n).map(|k| (start + k) % n).collect();
        // stable sort: equal in-flight counts keep the rotated order, so
        // an idle fleet degenerates to plain round robin
        order.sort_by_key(|&i| self.slots[i].inflight.load(Ordering::Relaxed));
        let mut last_err: Option<Error> = None;
        for i in order {
            let slot = &self.slots[i];
            if slot.dead.load(Ordering::SeqCst) {
                continue;
            }
            slot.inflight.fetch_add(1, Ordering::SeqCst);
            let outcome = self.dispatch(slot, rows);
            slot.inflight.fetch_sub(1, Ordering::SeqCst);
            match outcome {
                Dispatch::Answered(reply) => return reply,
                Dispatch::Dead(e) => {
                    slot.dead.store(true, Ordering::SeqCst);
                    obs::counter_add("fleet_failover_total", 1);
                    obs::gauge_set("fleet_replicas_alive", self.alive() as f64);
                    eprintln!(
                        "spnn fleet: replica {} is down ({e}); failing over \
                         ({} of {n} replicas alive)",
                        slot.name,
                        self.alive(),
                    );
                    last_err = Some(e);
                }
            }
        }
        // the satellite fix: a dead or draining mesh used to hang the
        // client until the 7-day idle timeout — now it is told at once
        Err(Error::Protocol(format!(
            "replica unavailable: all {n} serve replica(s) are down or draining{}",
            match last_err {
                Some(e) => format!(" (last error: {e})"),
                None => String::new(),
            }
        )))
    }

    fn dispatch(&self, slot: &Slot, rows: &[u32]) -> Dispatch {
        match &slot.backend {
            Backend::Local(tx) => {
                let tx = tx.lock().expect("fleet sender lock").clone();
                let (rtx, rrx) = mpsc::channel();
                let req =
                    Request { rows: rows.to_vec(), reply: rtx, enqueued: Instant::now() };
                if tx.send(req).is_err() {
                    return Dispatch::Dead(Error::Net(
                        "serve session is gone (parties exited)".into(),
                    ));
                }
                let got = match self.reply_timeout {
                    Some(t) => rrx.recv_timeout(t).map_err(|e| match e {
                        mpsc::RecvTimeoutError::Timeout => Error::Net(format!(
                            "no reply within {:.1}s (replica wedged?)",
                            t.as_secs_f64()
                        )),
                        mpsc::RecvTimeoutError::Disconnected => Error::Net(
                            "serve session ended before replying".into(),
                        ),
                    }),
                    None => rrx.recv().map_err(|_| {
                        Error::Net("serve session ended before replying".into())
                    }),
                };
                match got {
                    Ok(reply) => Dispatch::Answered(reply),
                    Err(e) => Dispatch::Dead(e),
                }
            }
            Backend::Remote(addr) => {
                let r = frontdoor::infer_once_opts(
                    addr,
                    rows,
                    self.connect_timeout,
                    self.reply_timeout,
                    self.downstream_psk.as_ref(),
                );
                match r {
                    // transport-level death (connect refused, closed
                    // before replying, reply timeout) → failover
                    Err(e @ Error::Net(_)) => Dispatch::Dead(e),
                    // scores or an application rejection → final
                    other => Dispatch::Answered(other),
                }
            }
        }
    }

    /// Wrap the fleet as a [`Scorer`] for the shared front door.
    pub fn into_scorer(self) -> Scorer {
        let fleet = Arc::new(self);
        Arc::new(move |rows: &[u32]| fleet.score(rows))
    }
}

/// Run the shared front door with this fleet as the scorer. `psk` keys
/// the door itself (client auth); the fleet's own `downstream_psk` keys
/// its dials to remote replicas.
pub fn run_door(
    listener: std::net::TcpListener,
    fleet: Fleet,
    max_requests: usize,
    psk: Option<Psk>,
) -> Result<()> {
    obs::gauge_set("fleet_replicas_alive", fleet.alive() as f64);
    frontdoor::serve_clients(listener, fleet.into_scorer(), max_requests, psk)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A stub replica: answers `row / 100` until told to die, then drops
    /// its receiver (exactly what a crashed serve session looks like).
    fn stub_replica(die_after: usize) -> (mpsc::Sender<Request>, std::thread::JoinHandle<u64>) {
        let (tx, rx) = mpsc::channel::<Request>();
        let h = std::thread::spawn(move || {
            let mut answered = 0u64;
            while let Ok(req) = rx.recv() {
                if die_after > 0 && answered as usize >= die_after {
                    break; // rx drops: session gone
                }
                let reply = if req.rows.contains(&99) {
                    Err(Error::Config("row 99 out of range".into()))
                } else {
                    Ok(req.rows.iter().map(|&r| r as f32 / 100.0).collect())
                };
                let _ = req.reply.send(reply);
                answered += 1;
            }
            answered
        });
        (tx, h)
    }

    /// Load-aware round robin over healthy replicas: both replicas see
    /// traffic, and application errors come back without failover.
    #[test]
    fn fleet_balances_and_returns_app_errors() {
        let (tx0, h0) = stub_replica(0);
        let (tx1, h1) = stub_replica(0);
        let fleet = Fleet::new(vec![
            ("r0".into(), Backend::local(tx0)),
            ("r1".into(), Backend::local(tx1)),
        ]);
        for k in 0..10u32 {
            assert_eq!(fleet.score(&[k]).unwrap(), vec![k as f32 / 100.0]);
        }
        // an app rejection is NOT a dead replica: it propagates, and both
        // replicas stay in rotation
        let err = fleet.score(&[99]).unwrap_err();
        assert!(format!("{err}").contains("out of range"), "{err}");
        assert_eq!(fleet.alive(), 2);
        drop(fleet);
        // both stubs answered: the round robin actually spread the load
        let (n0, n1) = (h0.join().unwrap(), h1.join().unwrap());
        assert_eq!(n0 + n1, 11);
        assert!(n0 >= 2 && n1 >= 2, "unbalanced: {n0} vs {n1}");
    }

    /// One replica dies mid-traffic: the request that hits it fails over
    /// to the sibling transparently, and the dead slot is sticky.
    #[test]
    fn fleet_fails_over_when_a_replica_dies() {
        let (tx0, _h0) = stub_replica(2); // dies after 2 answers
        let (tx1, h1) = stub_replica(0);
        let fleet = Fleet::new(vec![
            ("r0".into(), Backend::local(tx0)),
            ("r1".into(), Backend::local(tx1)),
        ]);
        for k in 0..12u32 {
            assert_eq!(fleet.score(&[k]).unwrap(), vec![k as f32 / 100.0]);
        }
        assert_eq!(fleet.alive(), 1, "dead replica must leave the rotation");
        drop(fleet);
        assert!(h1.join().unwrap() >= 10, "survivor must absorb the load");
    }

    /// The regression the fleet exists to fix: a client of a fully dead
    /// mesh must get a prompt "replica unavailable" error, not a hang
    /// until the 7-day idle timeout.
    #[test]
    fn dead_fleet_reports_replica_unavailable_promptly() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(rx); // the serve session is gone before the first request
        let fleet = Fleet::new(vec![("r0".into(), Backend::local(tx))]);
        let t0 = Instant::now();
        let err = fleet.score(&[1, 2, 3]).unwrap_err();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "dead-mesh error must be prompt, took {:?}",
            t0.elapsed()
        );
        let msg = format!("{err}");
        assert!(msg.contains("replica unavailable"), "{msg}");
        assert!(msg.contains("1 serve replica"), "{msg}");
        // and it is terminal for every later request too
        let err = fleet.score(&[4]).unwrap_err();
        assert!(format!("{err}").contains("replica unavailable"), "{err}");
    }

    /// Remote backends through real sockets: the fleet dials downstream
    /// doors, fails over past an address nobody listens on, and the
    /// full door-over-fleet stack round-trips for a TCP client.
    #[test]
    fn fleet_routes_remote_backends_and_serves_a_door() {
        use std::net::TcpListener;
        // downstream replica: a real (stub-backed) front door
        let down = TcpListener::bind("127.0.0.1:0").unwrap();
        let down_addr = down.local_addr().unwrap().to_string();
        let (tx, h) = stub_replica(0);
        let down_door = std::thread::spawn(move || frontdoor::run(down, tx, 4));
        // a second "replica" on a port nobody listens on: dead on arrival
        let vacant = TcpListener::bind("127.0.0.1:0").unwrap();
        let vacant_addr = vacant.local_addr().unwrap().to_string();
        drop(vacant);
        let mut fleet = Fleet::new(vec![
            ("ghost".into(), Backend::remote(&vacant_addr)),
            ("live".into(), Backend::remote(&down_addr)),
        ]);
        fleet.connect_timeout = Duration::from_millis(300);
        // front door over the fleet, quota 3
        let up = TcpListener::bind("127.0.0.1:0").unwrap();
        let up_addr = up.local_addr().unwrap().to_string();
        let up_door = std::thread::spawn(move || run_door(up, fleet, 3, None));
        let t = Duration::from_secs(10);
        for k in 1..=3u32 {
            let got = frontdoor::infer_once(&up_addr, &[k], t).unwrap();
            assert_eq!(got, vec![k as f32 / 100.0]);
        }
        up_door.join().unwrap().unwrap();
        // drain the downstream door's quota so it exits too
        let _ = frontdoor::infer_once(&down_addr, &[1], t);
        down_door.join().unwrap().unwrap();
        drop(h);
    }
}
