//! Private-inference **serving runtime**: after training, the parties stay
//! resident and answer streaming prediction requests on isolated private
//! features — the paper system's deployment story (fraud scoring on live
//! traffic) rather than another epoch.
//!
//! # Shape
//!
//! A serve session is an ordinary protocol deployment built through
//! [`Trainer::serve_deployment`]: training runs exactly as always (same
//! transcripts, same weight digests), and when the coordinator's stop
//! order has been consumed every forward-capable role enters
//! [`party_serve_loop`] over the same [`ForwardPass`] objects the train
//! loop just drove — the trained weights never move.
//!
//! * The **coordinator** becomes the request front ([`coordinator_serve`]):
//!   it drains client requests from a [`ServeQueue`], **coalesces** every
//!   queued request's rows into one stream, cuts it with the shared
//!   [`batch_plan`] (ragged tails included) so crypto costs amortize
//!   across requests, and announces each batch to the serving parties as a
//!   tagged [`Payload::InferReq`]. Up to `ServeOpts::depth` batches are
//!   announced ahead of the one being answered.
//! * Each **party** receives announcements in tag order, stages the row
//!   ids into its [`FeatureSource`](crate::protocols::fwd::FeatureSource)
//!   (its private slice of the held-out table), runs the forward-pass
//!   `prefetch` for announced-but-unanswered batches — Paillier nonces,
//!   dealer triples, share masks land inside the wait window, exactly like
//!   the train pipeline — and then the critical-path `forward`.
//! * The **scoring role** (SPNN: the label holder A; SplitNN: the server;
//!   SecureML: A after the probability shares are opened to it) returns a
//!   tagged [`Payload::InferResp`], which the coordinator splits back per
//!   request.
//!
//! Everything is multiplexed over the existing `Channel` transports, so a
//! serve session runs on netsim, loopback TCP, UDS, or as separate OS
//! processes (`spnn serve --launch`, via `transport::runner`) — and the
//! predictions are bit-identical across all of them and across pipeline
//! depths (the serve parity tests).
//!
//! The in-process entry point is [`serve`], which returns a
//! [`ServeHandle`]; `spnn serve` additionally opens a TCP front door for
//! `spnn infer` clients ([`frontdoor`]).

pub mod fleet;
pub mod frontdoor;

use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::config::{ModelConfig, TrainConfig};
use crate::data::Dataset;
use crate::netsim::{LinkSpec, PartyId, Payload};
use crate::parties::{self, run_parties, PartyFn, PartyOut};
use crate::protocols::common::{batch_plan, BatchCtx};
use crate::protocols::fwd::ForwardPass;
use crate::protocols::{TrainReport, Trainer};
use crate::transport::Channel;
use crate::{Error, Result};

/// Receive deadline while a serving party is parked waiting for the next
/// request batch: effectively "wait forever" (the training default of ten
/// minutes would kill an idle but healthy serve session).
pub const IDLE_TIMEOUT: Duration = Duration::from_secs(7 * 24 * 3600);

/// The training-era receive deadline, restored after the serve loop so
/// teardown deadlocks still surface as diagnostics.
const TEARDOWN_TIMEOUT: Duration = Duration::from_secs(600);

/// Serving knobs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeOpts {
    /// Maximum rows coalesced into one crypto batch (clamped to the
    /// artifact batch cap by each protocol's `serve_deployment`). Bigger
    /// batches amortize per-batch crypto — Paillier packing, dealer
    /// round-trips, share exchanges — across more requests.
    pub coalesce: usize,
    /// Request batches announced ahead of the one being answered (the
    /// parties prefetch value-independent crypto for announced batches,
    /// mirroring `TrainConfig::pipeline_depth`).
    pub depth: usize,
    /// Maximum milliseconds a request may sit queued before the
    /// coordinator rejects it with a clean error instead of scoring it
    /// (`0` = never expire). Requests are checked when a round is
    /// assembled, so a request stuck behind a long training phase or a
    /// slow earlier round fails fast rather than holding its client
    /// indefinitely.
    pub request_timeout_ms: u64,
    /// Admission cap: the most requests accepted into one coalesced round
    /// (`0` = unlimited). When a round is assembled, requests beyond the
    /// cap are rejected with a clean "queue full" error before any crypto
    /// is spent on them — load-shedding back-pressure for an overloaded
    /// coordinator — and counted in `serve_rejected_queue_full_total`.
    pub max_queue: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts { coalesce: 256, depth: 2, request_timeout_ms: 0, max_queue: 0 }
    }
}

/// The per-role slice of the serve configuration threaded through a
/// protocol's `serve_deployment` role bodies (`None` = train-only).
#[derive(Clone, Copy, Debug)]
pub struct ServeRole {
    /// Request batches prefetched ahead (see [`ServeOpts::depth`]).
    pub depth: usize,
}

/// One client inference request: row ids into the held-out table, plus the
/// reply slot the coordinator answers into.
pub struct Request {
    /// Rows of the serve table to score (duplicates allowed; order is the
    /// reply order).
    pub rows: Vec<u32>,
    /// Where the scores (or the rejection) go.
    pub reply: mpsc::Sender<Result<Vec<f32>>>,
    /// When the request entered the queue — the reference point for
    /// [`ServeOpts::request_timeout_ms`].
    pub enqueued: Instant,
}

/// The request queue handed to the coordinator's serve role. Worker
/// processes in a multi-process deployment build their (never-run)
/// coordinator closure with [`ServeQueue::detached`].
pub struct ServeQueue(Option<mpsc::Receiver<Request>>);

impl ServeQueue {
    /// A live queue around the receiving end of a request channel.
    pub fn new(rx: mpsc::Receiver<Request>) -> Self {
        ServeQueue(Some(rx))
    }

    /// A placeholder for deployments whose coordinator role never runs
    /// locally (worker processes of `spnn serve --launch`).
    pub fn detached() -> Self {
        ServeQueue(None)
    }

    fn into_receiver(self) -> Result<mpsc::Receiver<Request>> {
        self.0.ok_or_else(|| {
            Error::Config(
                "this process has no serve request queue (detached coordinator role)"
                    .into(),
            )
        })
    }
}

/// One blocking request round-trip through a serve queue sender. Clients
/// on other threads clone [`ServeHandle::sender`] and call this.
pub fn request_scores(tx: &mpsc::Sender<Request>, rows: &[u32]) -> Result<Vec<f32>> {
    let (rtx, rrx) = mpsc::channel();
    tx.send(Request { rows: rows.to_vec(), reply: rtx, enqueued: Instant::now() })
        .map_err(|_| Error::Protocol("serve session is gone (parties exited)".into()))?;
    rrx.recv().map_err(|_| {
        Error::Protocol(
            "serve session ended before replying (a party likely errored)".into(),
        )
    })?
}

// ---------------------------------------------------------------------------
// Coordinator serve role
// ---------------------------------------------------------------------------

/// Build a protocol's coordinator role body: the ordinary training
/// coordinator, or — when `serve` is given — the serving request front
/// ([`coordinator_serve`]), with the coalesce size clamped to the
/// artifact batch cap the parties pad to. Shared by every protocol's
/// `build()` so the clamp and the stand-down protocol live in one place.
pub fn coordinator_role(
    tc: &TrainConfig,
    workers: Vec<PartyId>,
    reporter: PartyId,
    serve_workers: Vec<PartyId>,
    responder: PartyId,
    max_row: usize,
    serve: Option<(ServeOpts, ServeQueue)>,
) -> PartyFn {
    let epochs = tc.epochs;
    match serve {
        Some((mut opts, queue)) => {
            // never coalesce past the artifact cap the parties pad to
            opts.coalesce = opts.coalesce.clamp(1, ModelConfig::pick_batch(tc.batch));
            Box::new(move |p: &mut dyn Channel| {
                coordinator_serve(
                    p,
                    &workers,
                    reporter,
                    &serve_workers,
                    responder,
                    epochs,
                    queue,
                    &opts,
                    max_row,
                )
            })
        }
        None => Box::new(move |p: &mut dyn Channel| {
            parties::coordinator_run(p, &workers, reporter, epochs)
        }),
    }
}

/// The coordinator's full serve role body: run the ordinary training
/// control protocol ([`parties::coordinator_run`]), then turn into the
/// request front — coalesce queued requests into crypto-amortized batches,
/// announce up to `opts.depth` of them ahead to `serve_workers`, collect
/// the scoring role's replies, and fan the scores back per request. When
/// the queue closes (every sender dropped), broadcast the stand-down order
/// and return.
#[allow(clippy::too_many_arguments)]
pub fn coordinator_serve(
    p: &mut dyn Channel,
    workers: &[PartyId],
    reporter: PartyId,
    serve_workers: &[PartyId],
    responder: PartyId,
    epochs: usize,
    queue: ServeQueue,
    opts: &ServeOpts,
    max_row: usize,
) -> Result<PartyOut> {
    let queue = queue.into_receiver()?;
    // 1) training, unchanged (same transcripts and digests as train-only)
    let mut out = parties::coordinator_run(p, workers, reporter, epochs)?;

    // 2) the serve loop
    p.set_stage("serve");
    let depth = opts.depth.max(1);
    let coalesce = opts.coalesce.max(1);
    let mut next_tag = 0u64;
    let mut served_rows = 0u64;
    let mut served_batches = 0u64;
    loop {
        // block for the next request; a closed queue is the shutdown order
        let first = match queue.recv() {
            Ok(r) => r,
            Err(_) => break,
        };
        // coalesce whatever else is already queued into this round
        let mut round = vec![first];
        while let Ok(r) = queue.try_recv() {
            round.push(r);
        }
        let queued = round.len();
        crate::obs::gauge_set("serve_queue_depth", queued as f64);
        crate::obs::counter_add("serve_requests_total", queued as u64);
        // admission control: shed everything beyond the cap with a clean
        // error before validation or crypto touches it (FIFO keeps the
        // oldest requests)
        if opts.max_queue > 0 && queued > opts.max_queue {
            for r in round.drain(opts.max_queue..) {
                crate::obs::counter_add("serve_rejected_queue_full_total", 1);
                crate::obs::trace::emit(
                    p.id(),
                    "virt",
                    p.now(),
                    "serve_reject",
                    &[("reason", crate::obs::trace::Val::S("queue_full"))],
                );
                let _ = r.reply.send(Err(Error::Protocol(format!(
                    "serve queue full ({queued} request(s) queued, --max-queue {})",
                    opts.max_queue
                ))));
            }
        }
        // validate and flatten the round's rows into one stream
        let timeout = match opts.request_timeout_ms {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        };
        let mut good: Vec<(Request, usize)> = Vec::new();
        let mut all: Vec<u32> = Vec::new();
        for r in round {
            // expire stale requests before spending any crypto on them —
            // a request stuck behind training or a slow round fails fast
            if let Some(t) = timeout {
                let waited = r.enqueued.elapsed();
                if waited > t {
                    crate::obs::counter_add("serve_rejected_timeout_total", 1);
                    let _ = r.reply.send(Err(Error::Protocol(format!(
                        "inference request timed out after {}ms in the serve queue \
                         (--request-timeout {}ms)",
                        waited.as_millis(),
                        t.as_millis()
                    ))));
                    continue;
                }
            }
            if let Some(&bad) = r.rows.iter().find(|&&id| id as usize >= max_row) {
                crate::obs::counter_add("serve_rejected_range_total", 1);
                let _ = r.reply.send(Err(Error::Config(format!(
                    "inference request row {bad} out of range (serve table has \
                     {max_row} rows)"
                ))));
                continue;
            }
            if r.rows.is_empty() {
                let _ = r.reply.send(Ok(Vec::new()));
                continue;
            }
            let waited = r.enqueued.elapsed().as_secs_f64();
            crate::obs::observe_secs("serve_queue_wait_seconds", waited);
            let start = all.len();
            all.extend_from_slice(&r.rows);
            good.push((r, start));
        }
        if all.is_empty() {
            continue;
        }
        // the shared batch plan handles the ragged tail uniformly
        let plan = batch_plan(all.len(), coalesce);
        crate::obs::gauge_set(
            "serve_coalesce_fill",
            all.len() as f64 / (plan.len() * coalesce) as f64,
        );
        crate::obs::trace::emit(
            p.id(),
            "virt",
            p.now(),
            "serve_round",
            &[
                ("requests", crate::obs::trace::Val::U(good.len() as u64)),
                ("rows", crate::obs::trace::Val::U(all.len() as u64)),
                ("batches", crate::obs::trace::Val::U(plan.len() as u64)),
            ],
        );
        let round_t0 = crate::obs::enabled().then(Instant::now);
        let mut scores: Vec<f32> = Vec::with_capacity(all.len());
        let mut announced = 0usize;
        let mut completed = 0usize;
        while completed < plan.len() {
            // announce up to `depth` batches ahead of the awaited one —
            // the parties prefetch their crypto for announced batches
            while announced < plan.len() && announced < completed + depth {
                let (s, rows) = plan[announced];
                let ids = all[s..s + rows].to_vec();
                let tag = next_tag + announced as u64;
                for &w in serve_workers {
                    p.send_tagged(w, tag, Payload::InferReq(ids.clone()))?;
                }
                announced += 1;
            }
            let tag = next_tag + completed as u64;
            let batch_t0 = crate::obs::enabled().then(Instant::now);
            let got = p.recv_tagged(responder, tag)?.into_infer_resp()?;
            if let Some(t0) = batch_t0 {
                crate::obs::observe_secs("serve_batch_seconds", t0.elapsed().as_secs_f64());
            }
            if got.len() != plan[completed].1 {
                return Err(Error::Protocol(format!(
                    "serve: responder returned {} score(s) for a {}-row batch",
                    got.len(),
                    plan[completed].1
                )));
            }
            scores.extend_from_slice(&got);
            completed += 1;
        }
        next_tag += plan.len() as u64;
        served_batches += plan.len() as u64;
        served_rows += all.len() as u64;
        if let Some(t0) = round_t0 {
            crate::obs::observe_secs("serve_crypto_seconds", t0.elapsed().as_secs_f64());
        }
        // fan the scores back out per request
        for (r, start) in good {
            let n = r.rows.len();
            crate::obs::observe_secs("serve_request_seconds", r.enqueued.elapsed().as_secs_f64());
            let _ = r.reply.send(Ok(scores[start..start + n].to_vec()));
        }
        crate::obs::gauge_set("serve_queue_depth", 0.0);
    }

    // 3) stand-down: every serving party is parked on tag `next_tag`
    for &w in serve_workers {
        p.send_tagged(w, next_tag, Payload::Control("serve-stop".into()))?;
    }
    out.metrics.push(("served_rows".into(), served_rows as f64));
    out.metrics.push(("served_batches".into(), served_batches as f64));
    out.sim_time = p.now();
    Ok(out)
}

// ---------------------------------------------------------------------------
// Party serve loop
// ---------------------------------------------------------------------------

enum Announce {
    Batch(Vec<u32>),
    Stop,
}

fn parse_announce(payload: Payload) -> Result<Announce> {
    match payload {
        Payload::InferReq(ids) => Ok(Announce::Batch(ids)),
        Payload::Control(s) if s == "serve-stop" => Ok(Announce::Stop),
        other => Err(Error::Protocol(format!(
            "serve: expected an InferReq or serve-stop announcement, got {}",
            other.kind()
        ))),
    }
}

/// Drive one serving party's request loop over its [`ForwardPass`].
///
/// Announcements arrive from the coordinator tagged with consecutive batch
/// indexes. For every announced batch the party stages the row ids and
/// runs the value-independent `prefetch` immediately (in tag order, so RNG
/// transcripts stay deterministic); up to `depth` batches are held
/// announced-but-unanswered, which places the prefetch work of future
/// batches inside the wait for the current batch's remote results — the
/// same overlap the train pipeline exploits. The critical-path `forward`
/// then runs per batch; the scoring role's result is shipped back as a
/// tagged [`Payload::InferResp`].
pub fn party_serve_loop(
    p: &mut dyn Channel,
    coord: PartyId,
    depth: usize,
    fwd: &mut dyn ForwardPass,
) -> Result<()> {
    let depth = depth.max(1);
    // an idle-but-healthy serve session must not trip the training-era
    // deadlock detector while parked between requests
    p.set_recv_timeout(IDLE_TIMEOUT);
    let mut next = 0u64;
    let mut pending: VecDeque<BatchCtx> = VecDeque::new();
    let mut stopped = false;
    loop {
        // block for the next announcement when nothing is in flight
        while !stopped && pending.is_empty() {
            match parse_announce(p.recv_tagged(coord, next)?)? {
                Announce::Batch(ids) => {
                    let b = BatchCtx::new(next as usize, 0, ids.len());
                    fwd.stage_rows(next, &ids);
                    fwd.prefetch(p, &b)?;
                    pending.push_back(b);
                    next += 1;
                }
                Announce::Stop => stopped = true,
            }
        }
        // opportunistically extend the prefetch window up to `depth`
        while !stopped && pending.len() < depth {
            match p.try_recv_tagged(coord, next)? {
                None => break,
                Some(payload) => match parse_announce(payload)? {
                    Announce::Batch(ids) => {
                        let b = BatchCtx::new(next as usize, 0, ids.len());
                        fwd.stage_rows(next, &ids);
                        fwd.prefetch(p, &b)?;
                        pending.push_back(b);
                        next += 1;
                    }
                    Announce::Stop => stopped = true,
                },
            }
        }
        let Some(b) = pending.pop_front() else { break };
        if let Some(scores) = fwd.forward(p, &b)? {
            p.set_stage("serve");
            p.send_tagged(coord, b.tag(), Payload::InferResp(scores))?;
        }
    }
    p.set_recv_timeout(TEARDOWN_TIMEOUT);
    Ok(())
}

// ---------------------------------------------------------------------------
// In-process serve runtime
// ---------------------------------------------------------------------------

/// What the background session thread resolves to: every party's output
/// plus the whole-mesh traffic summary (exactly `run_parties`' result).
type SessionJoin = std::thread::JoinHandle<Result<(Vec<PartyOut>, parties::NetSummary)>>;

/// A live in-process serve session: training + serving run on background
/// threads (one per party, over `tc.transport`); requests go through
/// [`ServeHandle::infer`] / [`ServeHandle::sender`]. Dropping the handle
/// (or calling [`ServeHandle::shutdown`]) closes the queue, which stands
/// the parties down and ends the session.
pub struct ServeHandle {
    tx: Option<mpsc::Sender<Request>>,
    join: Option<SessionJoin>,
    trainer: Box<dyn Trainer>,
    cfg: &'static ModelConfig,
    tc: TrainConfig,
    test: Dataset,
    wall: Instant,
}

/// Start an in-process serve session: build the trainer's serve deployment
/// and run every party on its own thread over `tc.transport`. Returns
/// immediately — training proceeds in the background, and the first
/// [`ServeHandle::infer`] call blocks until the model is trained and the
/// scores come back.
#[allow(clippy::too_many_arguments)]
pub fn serve(
    trainer: Box<dyn Trainer>,
    cfg: &'static ModelConfig,
    tc: &TrainConfig,
    spec: LinkSpec,
    train: &Dataset,
    test: &Dataset,
    n_holders: usize,
    opts: &ServeOpts,
) -> Result<ServeHandle> {
    crate::exec::set_default_threads(tc.exec_threads);
    let (tx, rx) = mpsc::channel();
    let dep =
        trainer.serve_deployment(cfg, tc, train, test, n_holders, opts, ServeQueue::new(rx))?;
    let kind = tc.transport;
    // the session thread inherits the caller's trace session id so its
    // events stay attributable to this serve session
    let sid = crate::obs::trace::sid();
    let join = std::thread::Builder::new()
        .name("spnn-serve".into())
        .spawn(move || {
            crate::obs::trace::set_sid(sid);
            run_parties(spec, kind, dep)
        })
        .map_err(Error::Io)?;
    Ok(ServeHandle {
        tx: Some(tx),
        join: Some(join),
        trainer,
        cfg,
        tc: tc.clone(),
        test: test.clone(),
        wall: Instant::now(),
    })
}

impl ServeHandle {
    /// A clonable sender into the request queue (for concurrent clients /
    /// the TCP front door). Each extra sender keeps the session alive —
    /// drop them all (plus the handle) to stand the parties down.
    pub fn sender(&self) -> mpsc::Sender<Request> {
        self.tx.as_ref().expect("live serve handle").clone()
    }

    /// Score `rows` of the held-out serve table (blocking round-trip).
    pub fn infer(&self, rows: &[u32]) -> Result<Vec<f32>> {
        request_scores(self.tx.as_ref().expect("live serve handle"), rows)
    }

    /// End the session: close the queue (the coordinator broadcasts the
    /// stand-down), join every party, and assemble the final
    /// [`TrainReport`] — the same report (same `weight_digest`) a plain
    /// training run of this config produces.
    pub fn shutdown(mut self) -> Result<TrainReport> {
        self.tx = None;
        let join = self.join.take().expect("live serve handle");
        let (outs, net) = join
            .join()
            .map_err(|_| Error::Protocol("serve session panicked".into()))??;
        self.trainer.finish(
            self.cfg,
            &self.tc,
            &self.test,
            &outs,
            net,
            self.wall.elapsed().as_secs_f64(),
        )
    }
}

impl Drop for ServeHandle {
    fn drop(&mut self) {
        self.tx = None;
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{TransportKind, FRAUD};
    use crate::data::{synth_fraud, SynthOpts};
    use crate::protocols;
    use crate::protocols::fwd::{params_from_report, splitnn_direct_scores, spnn_direct_scores};

    /// Train + serve one session and score every request in order.
    #[allow(clippy::too_many_arguments)]
    fn serve_session(
        proto: &str,
        rows_total: usize,
        kind: TransportKind,
        depth: usize,
        coalesce: usize,
        batch: usize,
        holders: usize,
        reqs: &[Vec<u32>],
    ) -> (Vec<Vec<f32>>, TrainReport, Dataset) {
        let ds = synth_fraud(SynthOpts::small(rows_total));
        let (train, test) = ds.split(0.8, 77);
        let tc = TrainConfig {
            batch,
            epochs: 1,
            lr_override: Some(0.05),
            paillier_bits: 256, // test-size keys
            pipeline_depth: depth,
            transport: kind,
            ..Default::default()
        };
        let trainer = protocols::by_name(proto).expect("known trainer");
        let opts = ServeOpts { coalesce, depth, ..Default::default() };
        let h = serve(
            trainer,
            &FRAUD,
            &tc,
            LinkSpec::lan(),
            &train,
            &test,
            holders,
            &opts,
        )
        .unwrap();
        let scores: Vec<Vec<f32>> = reqs.iter().map(|r| h.infer(r).unwrap()).collect();
        let rep = h.shutdown().unwrap();
        (scores, rep, test)
    }

    fn bits(scores: &[Vec<f32>]) -> Vec<Vec<u32>> {
        scores
            .iter()
            .map(|v| v.iter().map(|s| s.to_bits()).collect())
            .collect()
    }

    #[test]
    fn spnn_ss_serving_is_bit_identical_across_transports_and_depths() {
        // the acceptance criterion: `infer` answers are bit-identical over
        // netsim / TCP / UDS and across serve pipeline depths — including
        // a ragged request (25 rows through coalesce 16 = 16 + 9)
        let reqs = vec![(0..25u32).collect::<Vec<_>>(), vec![3, 1, 4, 1, 5]];
        let mut all = Vec::new();
        for kind in [TransportKind::Netsim, TransportKind::Tcp, TransportKind::Uds] {
            let (scores, rep, _) =
                serve_session("spnn-ss", 240, kind, 1, 16, 64, 2, &reqs);
            assert_eq!(scores[0].len(), 25);
            assert_eq!(scores[1].len(), 5);
            assert!(
                scores.iter().flatten().all(|s| (0.0..=1.0).contains(s)),
                "scores out of range"
            );
            assert_ne!(rep.weight_digest, 0);
            all.push((bits(&scores), rep.weight_digest));
        }
        // a deeper serve pipeline must not change a single bit
        let (scores_d2, rep_d2, _) =
            serve_session("spnn-ss", 240, TransportKind::Netsim, 2, 16, 64, 2, &reqs);
        all.push((bits(&scores_d2), rep_d2.weight_digest));
        for w in all.windows(2) {
            assert_eq!(w[0], w[1], "served predictions diverged across backends/depths");
        }
        // serving must not have perturbed training: same digest as a plain
        // training run of the identical config
        let ds = synth_fraud(SynthOpts::small(240));
        let (train, test) = ds.split(0.8, 77);
        let tc = TrainConfig {
            batch: 64,
            epochs: 1,
            lr_override: Some(0.05),
            paillier_bits: 256,
            ..Default::default()
        };
        use crate::protocols::Trainer;
        let plain = crate::protocols::spnn::Spnn { he: false }
            .train(&FRAUD, &tc, LinkSpec::lan(), &train, &test, 2)
            .unwrap();
        assert_eq!(plain.weight_digest, all[0].1, "serving changed the trained model");
        // SS agrees with the direct fixed-point forward up to the
        // truncation's probabilistic low-order bit
        let params = params_from_report(&FRAUD, &rep_d2).unwrap();
        let direct = spnn_direct_scores(&FRAUD, &params, 2, &test, &reqs[0], None).unwrap();
        for (got, want) in scores_d2[0].iter().zip(&direct) {
            assert!(
                (got - want).abs() < 1e-2,
                "SS served {got} vs direct {want}"
            );
        }
    }

    #[test]
    fn spnn_he_serving_matches_the_direct_forward_bit_exactly() {
        // Paillier decryption of a packed sum is exactly the slot-wise sum
        // of fixed-point encodes, so the served predictions must equal the
        // channel-free reference forward bit for bit
        let reqs = vec![(0..20u32).collect::<Vec<_>>()];
        let (scores, rep, test) =
            serve_session("spnn-he", 200, TransportKind::Netsim, 2, 8, 64, 2, &reqs);
        let params = params_from_report(&FRAUD, &rep).unwrap();
        let direct = spnn_direct_scores(&FRAUD, &params, 2, &test, &reqs[0], None).unwrap();
        assert_eq!(scores[0].len(), direct.len());
        for (i, (got, want)) in scores[0].iter().zip(&direct).enumerate() {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "row {i}: served {got} vs direct {want}"
            );
        }
    }

    #[test]
    fn splitnn_serving_matches_direct_forward_and_coalesces_concurrent_clients() {
        // SplitNN's forward is plaintext and row-independent, so (a) the
        // served scores equal the channel-free reference bit for bit, and
        // (b) coalescing concurrent clients into shared batches must not
        // change anyone's answer
        let ds = synth_fraud(SynthOpts::small(300));
        let (train, test) = ds.split(0.8, 41);
        let tc = TrainConfig {
            batch: 64,
            epochs: 1,
            lr_override: Some(0.3),
            ..Default::default()
        };
        let trainer = protocols::by_name("splitnn").unwrap();
        let opts = ServeOpts { coalesce: 16, depth: 2, ..Default::default() };
        let h = serve(trainer, &FRAUD, &tc, LinkSpec::lan(), &train, &test, 2, &opts)
            .unwrap();
        // sequential reference answers, one row per request
        let rows: Vec<u32> = (0..12).collect();
        let reference: Vec<f32> =
            rows.iter().map(|&r| h.infer(&[r]).unwrap()[0]).collect();
        // four concurrent clients over overlapping row sets: their requests
        // coalesce into shared crypto batches, answers must not change
        let mut threads = Vec::new();
        for t in 0..4u32 {
            let tx = h.sender();
            let rows = rows.clone();
            threads.push(std::thread::spawn(move || {
                let mine: Vec<u32> =
                    rows.iter().copied().filter(|r| r % 2 == (t % 2)).collect();
                let scores = request_scores(&tx, &mine).unwrap();
                (mine, scores)
            }));
        }
        for t in threads {
            let (mine, scores) = t.join().unwrap();
            for (r, s) in mine.iter().zip(&scores) {
                assert_eq!(
                    s.to_bits(),
                    reference[*r as usize].to_bits(),
                    "row {r} changed under coalescing"
                );
            }
        }
        let rep = h.shutdown().unwrap();
        assert_ne!(rep.weight_digest, 0);
        let direct = splitnn_direct_scores(&FRAUD, &rep, 2, &test, &rows, None).unwrap();
        for (r, want) in rows.iter().zip(&direct) {
            assert_eq!(
                reference[*r as usize].to_bits(),
                want.to_bits(),
                "row {r}: served vs direct forward"
            );
        }
    }

    #[test]
    fn secureml_serving_is_bit_identical_across_transports() {
        // forward-only MPC with the probability shares opened to A: same
        // request stream over netsim and real sockets must score
        // bit-identically (same mask RNG schedule, same truncations)
        let reqs = vec![(0..10u32).collect::<Vec<_>>(), vec![7, 7, 0]];
        let mut all = Vec::new();
        for kind in [TransportKind::Netsim, TransportKind::Tcp] {
            let (scores, rep, _) =
                serve_session("secureml", 200, kind, 2, 8, 64, 2, &reqs);
            assert_eq!(scores[0].len(), 10);
            assert_eq!(scores[1].len(), 3);
            assert!(scores.iter().flatten().all(|s| (0.0..=1.0).contains(s)));
            assert_ne!(rep.weight_digest, 0);
            all.push(bits(&scores));
        }
        assert_eq!(all[0], all[1], "SecureML served scores diverged over TCP");
    }

    #[test]
    fn ragged_train_and_serve_sizes_do_not_panic() {
        // regression (ISSUE 5 satellite): a training set with
        // n % batch != 0 AND requests whose row counts do not divide the
        // coalesce size must flow through the shared batch_plan cleanly
        let ds = synth_fraud(SynthOpts::small(150)); // 120 train (64+56), 30 test
        let (train, test) = ds.split(0.8, 19);
        assert_ne!(train.len() % 64, 0, "test setup: want a ragged train tail");
        let tc = TrainConfig {
            batch: 64,
            epochs: 1,
            lr_override: Some(0.05),
            ..Default::default()
        };
        let trainer = protocols::by_name("spnn-ss").unwrap();
        let opts = ServeOpts { coalesce: 8, depth: 2, ..Default::default() };
        let h = serve(trainer, &FRAUD, &tc, LinkSpec::lan(), &train, &test, 2, &opts)
            .unwrap();
        // 23 rows through coalesce 8 = 8 + 8 + 7 (ragged tail)
        let rows: Vec<u32> = (0..23).collect();
        let scores = h.infer(&rows).unwrap();
        assert_eq!(scores.len(), 23);
        // an empty request is answered, not announced
        assert_eq!(h.infer(&[]).unwrap(), Vec::<f32>::new());
        // an out-of-range row is rejected without killing the session
        let err = h.infer(&[9_999]).unwrap_err();
        assert!(format!("{err}").contains("out of range"), "{err}");
        // ...and the session still answers afterwards
        let again = h.infer(&rows).unwrap();
        assert_eq!(again.len(), 23);
        let rep = h.shutdown().unwrap();
        assert_ne!(rep.weight_digest, 0);
    }

    #[test]
    fn stale_requests_are_rejected_without_killing_the_session() {
        // ISSUE 7 satellite: a request that sat queued past
        // `request_timeout_ms` is failed cleanly at round assembly — no
        // crypto is spent on it and the session keeps serving
        let ds = synth_fraud(SynthOpts::small(150));
        let (train, test) = ds.split(0.8, 19);
        let tc = TrainConfig {
            batch: 64,
            epochs: 1,
            lr_override: Some(0.05),
            ..Default::default()
        };
        let trainer = protocols::by_name("spnn-ss").unwrap();
        let opts =
            ServeOpts { coalesce: 8, depth: 1, request_timeout_ms: 2_000, ..Default::default() };
        let h = serve(trainer, &FRAUD, &tc, LinkSpec::lan(), &train, &test, 2, &opts)
            .unwrap();
        // a fresh request scores normally under the timeout
        let fresh = h.infer(&[0, 1, 2]).unwrap();
        assert_eq!(fresh.len(), 3);
        // forge a request that "entered the queue" ten seconds ago
        let stale_at = Instant::now()
            .checked_sub(Duration::from_secs(10))
            .expect("clock supports a 10s rewind");
        let (rtx, rrx) = mpsc::channel();
        h.sender()
            .send(Request { rows: vec![0, 1], reply: rtx, enqueued: stale_at })
            .unwrap();
        let err = rrx.recv().unwrap().unwrap_err();
        assert!(format!("{err}").contains("timed out"), "{err}");
        // ...and the session still answers afterwards
        let again = h.infer(&[3, 4]).unwrap();
        assert_eq!(again.len(), 2);
        let rep = h.shutdown().unwrap();
        assert_ne!(rep.weight_digest, 0);
    }

    #[test]
    fn excess_requests_are_rejected_when_the_queue_is_capped() {
        // ISSUE 8 satellite: with --max-queue 1, a round assembled from a
        // backlog keeps the oldest request and sheds the rest with a clean
        // "queue full" error before any crypto is spent on them
        let ds = synth_fraud(SynthOpts::small(150));
        let (train, test) = ds.split(0.8, 19);
        let tc = TrainConfig {
            batch: 64,
            epochs: 1,
            lr_override: Some(0.05),
            ..Default::default()
        };
        let trainer = protocols::by_name("spnn-ss").unwrap();
        let opts = ServeOpts { coalesce: 8, depth: 1, max_queue: 1, ..Default::default() };
        let h = serve(trainer, &FRAUD, &tc, LinkSpec::lan(), &train, &test, 2, &opts)
            .unwrap();
        // enqueue three requests while training still runs: the first
        // round is assembled only after training, so all three are queued
        // by then and FIFO admission keeps exactly the first
        let mut replies = Vec::new();
        for _ in 0..3 {
            let (rtx, rrx) = mpsc::channel();
            h.sender()
                .send(Request { rows: vec![0, 1], reply: rtx, enqueued: Instant::now() })
                .unwrap();
            replies.push(rrx);
        }
        let first = replies.remove(0).recv().unwrap().unwrap();
        assert_eq!(first.len(), 2);
        for rrx in replies {
            let err = rrx.recv().unwrap().unwrap_err();
            assert!(format!("{err}").contains("queue full"), "{err}");
        }
        // the session still serves after shedding load
        assert_eq!(h.infer(&[2, 3]).unwrap().len(), 2);
        let rep = h.shutdown().unwrap();
        assert_ne!(rep.weight_digest, 0);
    }

    #[test]
    fn plaintext_nn_has_no_serving_story() {
        let ds = synth_fraud(SynthOpts::small(120));
        let (train, test) = ds.split(0.8, 3);
        let tc = TrainConfig { batch: 64, epochs: 1, ..Default::default() };
        let trainer = protocols::by_name("nn").unwrap();
        let err = serve(
            trainer,
            &FRAUD,
            &tc,
            LinkSpec::lan(),
            &train,
            &test,
            2,
            &ServeOpts::default(),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("does not support serving"), "{err}");
    }
}
