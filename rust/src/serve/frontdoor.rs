//! The TCP **front door** of a serve session: `spnn serve` listens here
//! for `spnn infer` clients.
//!
//! The protocol is deliberately minimal and rides the existing
//! [`wire`](crate::transport::wire) framing: a client connects, writes one
//! frame per request carrying a [`Payload::InferReq`] (its `tag` is the
//! client's request id, echoed back), and reads one reply frame per
//! request — [`Payload::InferResp`] with the scores, or a
//! `Control("spnn-err ...")` frame naming the rejection. Connections
//! stream: a client may keep the socket open and send many requests.
//!
//! With a pre-shared key the door additionally challenges every client
//! before the first request: it sends `Control("spnn-serve-auth v1
//! nonce=<hex>")` and expects `Control("spnn-serve-auth-ok proof=<hex>")`
//! back, where the proof is the PSK-keyed HMAC transcript of
//! [`Psk::party_proof`] under the `"infer-client"` role label. Wrong or
//! missing proofs are rejected before any score is computed.
//!
//! Each accepted connection gets its own thread feeding the shared
//! scorer. The production scorer pushes [`Request`]s into the shared
//! queue, so concurrent clients **coalesce** into shared crypto batches
//! inside [`coordinator_serve`](super::coordinator_serve); the fleet
//! router ([`fleet`](super::fleet)) plugs in a scorer that load-balances
//! across replicas instead.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use super::{request_scores, Request};
use crate::netsim::{Msg, Payload, Phase};
use crate::transport::auth::{self, Psk};
use crate::transport::wire;
use crate::{Error, Result};

/// How long an idle client connection may sit between requests once the
/// front door is draining toward a request quota (keeps the final join
/// bounded).
const CLIENT_IDLE_TIMEOUT: Duration = Duration::from_secs(60);

/// How long the door waits for a client's auth proof, and how long a
/// keyed client waits for the door's challenge. Bounds the damage an
/// unauthenticated half-open connection can do to either side.
const AUTH_TIMEOUT: Duration = Duration::from_secs(10);

/// Anything that turns row ids into scores: the single-session queue
/// ([`request_scores`]), or a fleet router fanning out over replicas.
pub type Scorer = Arc<dyn Fn(&[u32]) -> Result<Vec<f32>> + Send + Sync>;

/// Run the front door on an already-bound listener, feeding `tx`.
///
/// `max_requests > 0` makes the door close after that many requests have
/// been answered (deterministic smoke tests / CI); `0` serves until the
/// process dies. All queue senders are dropped before returning, so a
/// caller that then drops its own handle stands the whole session down.
pub fn run(
    listener: TcpListener,
    tx: mpsc::Sender<Request>,
    max_requests: usize,
) -> Result<()> {
    let scorer: Scorer = Arc::new(move |rows: &[u32]| request_scores(&tx, rows));
    serve_clients(listener, scorer, max_requests, None)
}

/// The generalized front door: accept clients on `listener`, answer each
/// request through `scorer`, optionally demanding PSK client auth first.
///
/// [`run`] is this with the single-session queue scorer; the fleet router
/// calls it with a load-balancing scorer. The scorer (and whatever queue
/// senders it captured) is dropped before returning, preserving the
/// drop-to-shutdown semantics of the original single-queue door.
pub fn serve_clients(
    listener: TcpListener,
    scorer: Scorer,
    max_requests: usize,
    psk: Option<Psk>,
) -> Result<()> {
    let psk = psk.map(Arc::new);
    let served = Arc::new(AtomicUsize::new(0));
    let mut clients: Vec<std::thread::JoinHandle<()>> = Vec::new();
    listener
        .set_nonblocking(true)
        .map_err(|e| Error::Net(format!("front door set_nonblocking: {e}")))?;
    loop {
        if max_requests > 0 && served.load(Ordering::SeqCst) >= max_requests {
            break;
        }
        // reap finished client threads so a long-lived door (the
        // max_requests = 0 production mode) does not accumulate a
        // JoinHandle per connect/disconnect cycle forever
        clients.retain(|c| !c.is_finished());
        match listener.accept() {
            Ok((stream, addr)) => {
                let scorer = scorer.clone();
                let served = served.clone();
                let psk = psk.clone();
                eprintln!("spnn serve: client {addr} connected");
                clients.push(std::thread::spawn(move || {
                    if let Err(e) = client_loop(stream, scorer, served, max_requests, psk) {
                        eprintln!("spnn serve: client {addr}: {e}");
                    }
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(e) => return Err(Error::Net(format!("front door accept: {e}"))),
        }
    }
    // drop our scorer before joining so no queue sender it captured can
    // outlive the quota, then wait for the per-client threads (bounded by
    // their idle timeout)
    drop(scorer);
    for c in clients {
        let _ = c.join();
    }
    Ok(())
}

/// Challenge one freshly-accepted client and verify its proof. Leaves the
/// stream's read timeout set; the caller restores the idle policy.
fn challenge_client(stream: &mut TcpStream, psk: &Psk) -> Result<()> {
    let nonce = auth::fresh_nonce();
    wire::write_msg(
        stream,
        &Msg {
            from: 0,
            tag: 0,
            payload: Payload::Control(format!(
                "spnn-serve-auth v1 nonce={}",
                auth::to_hex(&nonce)
            )),
            depart: 0.0,
            phase: Phase::Online,
        },
    )
    .map_err(|e| Error::Net(format!("auth challenge send: {e}")))?;
    stream
        .set_read_timeout(Some(AUTH_TIMEOUT))
        .map_err(|e| Error::Net(format!("auth read timeout: {e}")))?;
    let ok = match wire::read_msg(stream) {
        Ok(Some(Msg { payload: Payload::Control(c), .. })) => c
            .strip_prefix("spnn-serve-auth-ok proof=")
            .map(|p| psk.verify_party(p.trim(), &nonce, b"", "infer-client"))
            .unwrap_or(false),
        _ => false, // wrong frame kind, timeout, or disconnect
    };
    if !ok {
        // name the rejection for honest-but-misconfigured clients before
        // hanging up (an attacker learns nothing: the nonce is spent)
        let _ = wire::write_msg(
            stream,
            &Msg {
                from: 0,
                tag: 0,
                payload: Payload::Control(
                    "spnn-err client authentication failed (wrong or missing pre-shared key)"
                        .into(),
                ),
                depart: 0.0,
                phase: Phase::Online,
            },
        );
        return Err(Error::Protocol("client failed PSK authentication".into()));
    }
    Ok(())
}

fn client_loop(
    mut stream: TcpStream,
    scorer: Scorer,
    served: Arc<AtomicUsize>,
    max_requests: usize,
    psk: Option<Arc<Psk>>,
) -> Result<()> {
    // the listener polls nonblocking; the accepted stream must block
    stream
        .set_nonblocking(false)
        .map_err(|e| Error::Net(format!("client unset nonblocking: {e}")))?;
    stream.set_nodelay(true).ok();
    if let Some(psk) = &psk {
        challenge_client(&mut stream, psk)?;
    }
    // bound the final join when draining toward a quota: an idle
    // streaming client is disconnected (also undoes the auth timeout)
    let idle = if max_requests > 0 { Some(CLIENT_IDLE_TIMEOUT) } else { None };
    stream
        .set_read_timeout(idle)
        .map_err(|e| Error::Net(format!("client read timeout: {e}")))?;
    loop {
        let Some(msg) = wire::read_msg(&mut stream)? else {
            return Ok(()); // clean disconnect
        };
        let rows = msg.payload.into_infer_req()?;
        // reserve a quota slot BEFORE serving, so racing clients cannot
        // push the session past --serve-requests
        let slot = if max_requests > 0 {
            let prior = served.fetch_add(1, Ordering::SeqCst);
            if prior >= max_requests {
                return Ok(()); // quota fully reserved — drop the connection
            }
            prior + 1
        } else {
            0
        };
        let reply = match scorer(&rows) {
            Ok(scores) => Payload::InferResp(scores),
            Err(e) => Payload::Control(format!("spnn-err {e}")),
        };
        wire::write_msg(
            &mut stream,
            &Msg { from: 0, tag: msg.tag, payload: reply, depart: 0.0, phase: Phase::Online },
        )
        .map_err(|e| Error::Net(format!("client write: {e}")))?;
        if max_requests > 0 && slot >= max_requests {
            return Ok(());
        }
    }
}

/// One-shot inference client (`spnn infer`): connect to a front door —
/// retrying while the server is still coming up — send the row ids, and
/// block until the scores arrive (the first request of a session waits for
/// training to finish).
pub fn infer_once(connect: &str, rows: &[u32], connect_timeout: Duration) -> Result<Vec<f32>> {
    infer_once_opts(connect, rows, connect_timeout, None, None)
}

/// [`infer_once`] with the full knob set: an optional **reply timeout**
/// (how long to wait for the scores once connected — `None` waits
/// indefinitely, which the first request of a fresh session needs while
/// training finishes) and an optional **PSK** answering the door's auth
/// challenge.
pub fn infer_once_opts(
    connect: &str,
    rows: &[u32],
    connect_timeout: Duration,
    reply_timeout: Option<Duration>,
    psk: Option<&Psk>,
) -> Result<Vec<f32>> {
    let deadline = Instant::now() + connect_timeout;
    let mut stream = loop {
        match TcpStream::connect(connect) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(Error::Net(format!("connect {connect}: {e}")));
                }
                std::thread::sleep(Duration::from_millis(250));
            }
        }
    };
    stream.set_nodelay(true).ok();
    if let Some(psk) = psk {
        // a keyed client leads by waiting for the challenge; a door that
        // never sends one (started without --psk-file) is caught by the
        // bounded wait instead of deadlocking both sides
        stream
            .set_read_timeout(Some(AUTH_TIMEOUT))
            .map_err(|e| Error::Net(format!("auth read timeout: {e}")))?;
        let nonce = match wire::read_msg(&mut stream) {
            Ok(Some(Msg { payload: Payload::Control(c), .. })) => c
                .strip_prefix("spnn-serve-auth v1 nonce=")
                .map(str::trim)
                .map(auth::from_hex)
                .transpose()?,
            Ok(_) => None,
            Err(_) => {
                return Err(Error::Protocol(
                    "front door sent no auth challenge (server started without --psk-file?); \
                     drop --psk-file or key the server"
                        .into(),
                ))
            }
        };
        let Some(nonce) = nonce else {
            return Err(Error::Protocol(
                "front door sent no auth challenge (server started without --psk-file?); \
                 drop --psk-file or key the server"
                    .into(),
            ));
        };
        wire::write_msg(
            &mut stream,
            &Msg {
                from: 0,
                tag: 0,
                payload: Payload::Control(format!(
                    "spnn-serve-auth-ok proof={}",
                    psk.party_proof(&nonce, b"", "infer-client")
                )),
                depart: 0.0,
                phase: Phase::Online,
            },
        )
        .map_err(|e| Error::Net(format!("auth proof send: {e}")))?;
    }
    stream
        .set_read_timeout(reply_timeout)
        .map_err(|e| Error::Net(format!("reply timeout: {e}")))?;
    wire::write_msg(
        &mut stream,
        &Msg {
            from: 0,
            tag: 1,
            payload: Payload::InferReq(rows.to_vec()),
            depart: 0.0,
            phase: Phase::Online,
        },
    )
    .map_err(|e| Error::Net(format!("infer send: {e}")))?;
    let reply = match (wire::read_msg(&mut stream), reply_timeout) {
        (Err(e), Some(t)) => {
            return Err(Error::Net(format!(
                "no reply within {:.1}s (replica dead or draining?): {e}",
                t.as_secs_f64()
            )))
        }
        (r, _) => r?,
    };
    match reply {
        Some(Msg { payload: Payload::InferResp(scores), .. }) => Ok(scores),
        Some(Msg { payload: Payload::Control(e), .. }) => {
            if e.starts_with("spnn-serve-auth v1 ") {
                // we sent a bare InferReq into a keyed door: its challenge
                // frame arrives where we expected scores
                return Err(Error::Protocol(
                    "this front door requires authentication (retry with --psk-file)".into(),
                ));
            }
            Err(Error::Protocol(match e.strip_prefix("spnn-err ") {
                Some(r) => r.to_string(),
                None => e,
            }))
        }
        Some(m) => Err(Error::Protocol(format!(
            "infer: unexpected reply payload {}",
            m.payload.kind()
        ))),
        None => Err(Error::Net("server closed the connection before replying".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end through real sockets: a front door backed by a stub
    /// scorer thread (no training needed) must round-trip requests,
    /// reject errors as spnn-err frames, and honor the request quota.
    #[test]
    fn front_door_roundtrips_and_honors_the_quota() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let (tx, rx) = mpsc::channel::<Request>();
        // stub scorer: score = row id / 100; row 99 is rejected
        let scorer = std::thread::spawn(move || {
            while let Ok(req) = rx.recv() {
                let reply = if req.rows.contains(&99) {
                    Err(Error::Config("row 99 out of range".into()))
                } else {
                    Ok(req.rows.iter().map(|&r| r as f32 / 100.0).collect())
                };
                let _ = req.reply.send(reply);
            }
        });
        let door = std::thread::spawn(move || run(listener, tx, 3));

        let t = Duration::from_secs(10);
        let got = infer_once(&addr, &[1, 2, 3], t).unwrap();
        assert_eq!(got, vec![0.01, 0.02, 0.03]);
        let err = infer_once(&addr, &[99], t).unwrap_err();
        assert!(format!("{err}").contains("out of range"), "{err}");
        let got = infer_once(&addr, &[50], t).unwrap();
        assert_eq!(got, vec![0.5]);

        // quota of 3 reached: the door closes, its queue senders drop, the
        // scorer drains and exits
        door.join().unwrap().unwrap();
        scorer.join().unwrap();
        // new connections are refused (or time out) once the door is shut
        assert!(infer_once(&addr, &[1], Duration::from_millis(400)).is_err());
    }

    /// A keyed door accepts the right proof, rejects the wrong key with a
    /// named error, and tells bare clients they need a key.
    #[test]
    fn front_door_psk_auth_accepts_and_rejects() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let psk = Psk::from_bytes(b"front-door-secret");
        let scorer: Scorer = Arc::new(|rows: &[u32]| {
            Ok(rows.iter().map(|&r| r as f32 / 100.0).collect())
        });
        let door_psk = psk.clone();
        let door =
            std::thread::spawn(move || serve_clients(listener, scorer, 3, Some(door_psk)));

        let t = Duration::from_secs(10);
        // right key: full round trip
        let got = infer_once_opts(&addr, &[7, 8], t, None, Some(&psk)).unwrap();
        assert_eq!(got, vec![0.07, 0.08]);
        // wrong key: named rejection, and the request never reaches the
        // scorer (quota still has 2 slots — both consumed below)
        let bad = Psk::from_bytes(b"not-the-secret");
        let err = infer_once_opts(&addr, &[1], t, None, Some(&bad)).unwrap_err();
        assert!(format!("{err}").contains("authentication failed"), "{err}");
        // no key at all: the challenge frame arrives where scores were
        // expected and is translated into a "requires authentication" error
        let err = infer_once(&addr, &[1], t).unwrap_err();
        assert!(format!("{err}").contains("requires authentication"), "{err}");
        // the two remaining quota slots still serve keyed clients
        let got = infer_once_opts(&addr, &[50], t, None, Some(&psk)).unwrap();
        assert_eq!(got, vec![0.5]);
        let got = infer_once_opts(&addr, &[51], t, None, Some(&psk)).unwrap();
        assert_eq!(got, vec![0.51]);
        door.join().unwrap().unwrap();
    }
}
