//! The TCP **front door** of a serve session: `spnn serve` listens here
//! for `spnn infer` clients.
//!
//! The protocol is deliberately minimal and rides the existing
//! [`wire`](crate::transport::wire) framing: a client connects, writes one
//! frame per request carrying a [`Payload::InferReq`] (its `tag` is the
//! client's request id, echoed back), and reads one reply frame per
//! request — [`Payload::InferResp`] with the scores, or a
//! `Control("spnn-err ...")` frame naming the rejection. Connections
//! stream: a client may keep the socket open and send many requests.
//!
//! Each accepted connection gets its own thread feeding the shared
//! [`Request`] queue, so concurrent clients **coalesce** into shared
//! crypto batches inside [`coordinator_serve`](super::coordinator_serve).

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use super::{request_scores, Request};
use crate::netsim::{Msg, Payload, Phase};
use crate::transport::wire;
use crate::{Error, Result};

/// How long an idle client connection may sit between requests once the
/// front door is draining toward a request quota (keeps the final join
/// bounded).
const CLIENT_IDLE_TIMEOUT: Duration = Duration::from_secs(60);

/// Run the front door on an already-bound listener, feeding `tx`.
///
/// `max_requests > 0` makes the door close after that many requests have
/// been answered (deterministic smoke tests / CI); `0` serves until the
/// process dies. All queue senders are dropped before returning, so a
/// caller that then drops its own handle stands the whole session down.
pub fn run(
    listener: TcpListener,
    tx: mpsc::Sender<Request>,
    max_requests: usize,
) -> Result<()> {
    let served = Arc::new(AtomicUsize::new(0));
    let mut clients: Vec<std::thread::JoinHandle<()>> = Vec::new();
    listener
        .set_nonblocking(true)
        .map_err(|e| Error::Net(format!("front door set_nonblocking: {e}")))?;
    loop {
        if max_requests > 0 && served.load(Ordering::SeqCst) >= max_requests {
            break;
        }
        // reap finished client threads so a long-lived door (the
        // max_requests = 0 production mode) does not accumulate a
        // JoinHandle per connect/disconnect cycle forever
        clients.retain(|c| !c.is_finished());
        match listener.accept() {
            Ok((stream, addr)) => {
                let tx = tx.clone();
                let served = served.clone();
                eprintln!("spnn serve: client {addr} connected");
                clients.push(std::thread::spawn(move || {
                    if let Err(e) = client_loop(stream, tx, served, max_requests) {
                        eprintln!("spnn serve: client {addr}: {e}");
                    }
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(e) => return Err(Error::Net(format!("front door accept: {e}"))),
        }
    }
    // drop our sender before joining so no request can outlive the quota,
    // then wait for the per-client threads (bounded by their idle timeout)
    drop(tx);
    for c in clients {
        let _ = c.join();
    }
    Ok(())
}

fn client_loop(
    mut stream: TcpStream,
    tx: mpsc::Sender<Request>,
    served: Arc<AtomicUsize>,
    max_requests: usize,
) -> Result<()> {
    // the listener polls nonblocking; the accepted stream must block
    stream
        .set_nonblocking(false)
        .map_err(|e| Error::Net(format!("client unset nonblocking: {e}")))?;
    stream.set_nodelay(true).ok();
    if max_requests > 0 {
        // bound the final join: an idle streaming client is disconnected
        stream
            .set_read_timeout(Some(CLIENT_IDLE_TIMEOUT))
            .map_err(|e| Error::Net(format!("client read timeout: {e}")))?;
    }
    loop {
        let Some(msg) = wire::read_msg(&mut stream)? else {
            return Ok(()); // clean disconnect
        };
        let rows = msg.payload.into_infer_req()?;
        // reserve a quota slot BEFORE serving, so racing clients cannot
        // push the session past --serve-requests
        let slot = if max_requests > 0 {
            let prior = served.fetch_add(1, Ordering::SeqCst);
            if prior >= max_requests {
                return Ok(()); // quota fully reserved — drop the connection
            }
            prior + 1
        } else {
            0
        };
        let reply = match request_scores(&tx, &rows) {
            Ok(scores) => Payload::InferResp(scores),
            Err(e) => Payload::Control(format!("spnn-err {e}")),
        };
        wire::write_msg(
            &mut stream,
            &Msg { from: 0, tag: msg.tag, payload: reply, depart: 0.0, phase: Phase::Online },
        )
        .map_err(|e| Error::Net(format!("client write: {e}")))?;
        if max_requests > 0 && slot >= max_requests {
            return Ok(());
        }
    }
}

/// One-shot inference client (`spnn infer`): connect to a front door —
/// retrying while the server is still coming up — send the row ids, and
/// block until the scores arrive (the first request of a session waits for
/// training to finish).
pub fn infer_once(connect: &str, rows: &[u32], connect_timeout: Duration) -> Result<Vec<f32>> {
    let deadline = Instant::now() + connect_timeout;
    let mut stream = loop {
        match TcpStream::connect(connect) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(Error::Net(format!("connect {connect}: {e}")));
                }
                std::thread::sleep(Duration::from_millis(250));
            }
        }
    };
    stream.set_nodelay(true).ok();
    wire::write_msg(
        &mut stream,
        &Msg {
            from: 0,
            tag: 1,
            payload: Payload::InferReq(rows.to_vec()),
            depart: 0.0,
            phase: Phase::Online,
        },
    )
    .map_err(|e| Error::Net(format!("infer send: {e}")))?;
    match wire::read_msg(&mut stream)? {
        Some(Msg { payload: Payload::InferResp(scores), .. }) => Ok(scores),
        Some(Msg { payload: Payload::Control(e), .. }) => {
            Err(Error::Protocol(match e.strip_prefix("spnn-err ") {
                Some(r) => r.to_string(),
                None => e,
            }))
        }
        Some(m) => Err(Error::Protocol(format!(
            "infer: unexpected reply payload {}",
            m.payload.kind()
        ))),
        None => Err(Error::Net("server closed the connection before replying".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end through real sockets: a front door backed by a stub
    /// scorer thread (no training needed) must round-trip requests,
    /// reject errors as spnn-err frames, and honor the request quota.
    #[test]
    fn front_door_roundtrips_and_honors_the_quota() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let (tx, rx) = mpsc::channel::<Request>();
        // stub scorer: score = row id / 100; row 99 is rejected
        let scorer = std::thread::spawn(move || {
            while let Ok(req) = rx.recv() {
                let reply = if req.rows.contains(&99) {
                    Err(Error::Config("row 99 out of range".into()))
                } else {
                    Ok(req.rows.iter().map(|&r| r as f32 / 100.0).collect())
                };
                let _ = req.reply.send(reply);
            }
        });
        let door = std::thread::spawn(move || run(listener, tx, 3));

        let t = Duration::from_secs(10);
        let got = infer_once(&addr, &[1, 2, 3], t).unwrap();
        assert_eq!(got, vec![0.01, 0.02, 0.03]);
        let err = infer_once(&addr, &[99], t).unwrap_err();
        assert!(format!("{err}").contains("out of range"), "{err}");
        let got = infer_once(&addr, &[50], t).unwrap();
        assert_eq!(got, vec![0.5]);

        // quota of 3 reached: the door closes, its queue senders drop, the
        // scorer drains and exits
        door.join().unwrap().unwrap();
        scorer.join().unwrap();
        // new connections are refused (or time out) once the door is shut
        assert!(infer_once(&addr, &[1], Duration::from_millis(400)).is_err());
    }
}
