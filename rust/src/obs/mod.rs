//! End-to-end observability: metric registry, span timers, Prometheus
//! export, structured trace.
//!
//! Zero-dependency, process-wide, observe-only. The subsystem never
//! touches an RNG, never sends a protocol message, and never blocks the
//! hot path on I/O — so every weight/prediction digest is bit-identical
//! with instrumentation on or off (asserted by `tests/obs_e2e.rs`), and
//! the netsim hot path stays within ~2% of uninstrumented sim-time
//! (`benches/obs_overhead.rs` → `BENCH_obs.json`).
//!
//! Three pieces:
//!
//! * **Registry** ([`registry`]) — named [`Counter`]s, [`Gauge`]s and
//!   log-bucketed latency [`Hist`]ograms (`module_thing_seconds` naming;
//!   an optional `{label="v"}` suffix becomes a Prometheus label). Worker
//!   parties export their registry through
//!   [`crate::parties::PartyOut::timings`] and the coordinator
//!   [`Registry::absorb`]s the rows — the timing sibling of
//!   [`crate::netsim::merge_stage_rows`].
//! * **Spans** ([`span`], [`timer`]) — wall-clock interval timers that
//!   record into a histogram on drop. When [`enabled`] is off (the A/B
//!   switch the overhead bench flips) a span is two no-ops.
//! * **Trace** ([`trace`]) — JSONL event log, deterministic modulo
//!   timestamps under netsim; [`prom`] renders the registry as
//!   Prometheus text for `spnn serve --metrics-listen`.
//!
//! What is on the hot path: one relaxed atomic load when disabled; two
//! `Instant::now` calls plus one atomic `fetch_add` per span when enabled.
//! Registry name lookups take a `Mutex`, so per-message call sites
//! (transport) cache their `Arc<Hist>` handles instead of looking up per
//! event.

pub mod hist;
pub mod prom;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

pub use hist::{Hist, HistSnapshot};

/// Monotonically increasing event count.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (stored as `f64` bits).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Process-wide metric registry. All maps are name → shared handle;
/// handles stay valid (and keep recording) across [`Registry::reset`],
/// they just stop being exported.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    hists: Mutex<BTreeMap<String, Arc<Hist>>>,
}

static ENABLED: AtomicBool = AtomicBool::new(true);
static REGISTRY: OnceLock<Registry> = OnceLock::new();

/// The process-wide registry.
pub fn registry() -> &'static Registry {
    REGISTRY.get_or_init(Registry::default)
}

/// Is recording on? (Default yes; the overhead bench A/Bs this.)
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn all recording on or off process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

impl Registry {
    /// Find or create the named counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut g = self.counters.lock().unwrap();
        g.entry(name.to_string()).or_default().clone()
    }

    /// Find or create the named gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut g = self.gauges.lock().unwrap();
        g.entry(name.to_string()).or_default().clone()
    }

    /// Find or create the named histogram.
    pub fn hist(&self, name: &str) -> Arc<Hist> {
        let mut g = self.hists.lock().unwrap();
        g.entry(name.to_string()).or_insert_with(|| Arc::new(Hist::new())).clone()
    }

    /// Forget every metric (benches isolate runs with this).
    pub fn reset(&self) {
        self.counters.lock().unwrap().clear();
        self.gauges.lock().unwrap().clear();
        self.hists.lock().unwrap().clear();
    }

    /// Flatten every metric to named rows for [`crate::parties::PartyOut`]:
    /// counters as `c:name → [v]`, gauges as `g:name → [v]`, histograms as
    /// `h:name → [count, sum_ns, idx, n, ...]` (sparse snapshot).
    pub fn export(&self) -> Vec<(String, Vec<f64>)> {
        let mut rows = Vec::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            rows.push((format!("c:{name}"), vec![c.get() as f64]));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            rows.push((format!("g:{name}"), vec![g.get()]));
        }
        for (name, h) in self.hists.lock().unwrap().iter() {
            rows.push((format!("h:{name}"), h.snapshot().to_row()));
        }
        rows
    }

    /// Merge rows produced by another registry's [`Self::export`]:
    /// counters add, gauges last-write-win, histograms merge bucketwise.
    pub fn absorb(&self, rows: &[(String, Vec<f64>)]) {
        for (key, row) in rows {
            if let Some(name) = key.strip_prefix("c:") {
                if let Some(v) = row.first() {
                    self.counter(name).add(*v as u64);
                }
            } else if let Some(name) = key.strip_prefix("g:") {
                if let Some(v) = row.first() {
                    self.gauge(name).set(*v);
                }
            } else if let Some(name) = key.strip_prefix("h:") {
                self.hist(name).merge_from(&HistSnapshot::from_row(row));
            }
        }
    }

    /// Counter values, name-sorted.
    pub fn counter_values(&self) -> Vec<(String, u64)> {
        self.counters.lock().unwrap().iter().map(|(n, c)| (n.clone(), c.get())).collect()
    }

    /// Gauge values, name-sorted.
    pub fn gauge_values(&self) -> Vec<(String, f64)> {
        self.gauges.lock().unwrap().iter().map(|(n, g)| (n.clone(), g.get())).collect()
    }

    /// Histogram handles, name-sorted.
    pub fn hist_handles(&self) -> Vec<(String, Arc<Hist>)> {
        self.hists.lock().unwrap().iter().map(|(n, h)| (n.clone(), h.clone())).collect()
    }
}

/// A wall-clock interval recorded into a histogram when dropped.
/// Inert (no `Instant::now`) when [`enabled`] is off at creation.
pub struct Span {
    start: Option<(Instant, Arc<Hist>)>,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((t0, h)) = self.start.take() {
            h.record_ns(t0.elapsed().as_nanos() as u64);
        }
    }
}

/// Open a span recording into the named histogram on drop.
pub fn span(name: &str) -> Span {
    if !enabled() {
        return Span { start: None };
    }
    Span { start: Some((Instant::now(), registry().hist(name))) }
}

/// A pre-resolved histogram handle for timing repeated closures — the
/// loop-friendly sibling of [`span`] (one registry lookup, many
/// observations).
pub struct Timer {
    hist: Option<Arc<Hist>>,
}

impl Timer {
    /// Run `f`, recording its wall duration if recording is on.
    pub fn observe<T>(&self, f: impl FnOnce() -> T) -> T {
        match &self.hist {
            Some(h) => {
                let t0 = Instant::now();
                let r = f();
                h.record_ns(t0.elapsed().as_nanos() as u64);
                r
            }
            None => f(),
        }
    }
}

/// Make a [`Timer`] for the named histogram (inert when disabled).
pub fn timer(name: &str) -> Timer {
    Timer { hist: enabled().then(|| registry().hist(name)) }
}

/// Bump the named counter by `n` (no-op when disabled).
pub fn counter_add(name: &str, n: u64) {
    if enabled() {
        registry().counter(name).add(n);
    }
}

/// Set the named gauge (no-op when disabled).
pub fn gauge_set(name: &str, v: f64) {
    if enabled() {
        registry().gauge(name).set(v);
    }
}

/// Record a measured duration, in seconds, into the named histogram
/// (no-op when disabled). For intervals whose endpoints are not a single
/// lexical scope — e.g. a request's enqueue→reply lifetime.
pub fn observe_secs(name: &str, secs: f64) {
    if enabled() {
        registry().hist(name).record_secs(secs);
    }
}

/// Render the registry's histograms as the "time by stage" markdown table
/// printed beside the Table-3b traffic table. Empty string when nothing
/// was recorded.
pub fn time_table_md(title: &str) -> String {
    let mut hists: Vec<(String, Arc<Hist>)> = registry()
        .hist_handles()
        .into_iter()
        .filter(|(_, h)| h.count() > 0)
        .collect();
    if hists.is_empty() {
        return String::new();
    }
    // biggest total time first: that is the column operators scan
    hists.sort_by(|a, b| {
        b.1.total_secs().partial_cmp(&a.1.total_secs()).unwrap_or(std::cmp::Ordering::Equal)
    });
    let rows: Vec<Vec<String>> = hists
        .iter()
        .map(|(name, h)| {
            vec![
                name.clone(),
                h.count().to_string(),
                crate::exp::report::fmt_secs(h.total_secs()),
                format!("{:.3}", h.mean_secs() * 1e3),
                format!("{:.3}", h.quantile_secs(0.5) * 1e3),
                format!("{:.3}", h.quantile_secs(0.95) * 1e3),
                format!("{:.3}", h.quantile_secs(0.99) * 1e3),
            ]
        })
        .collect();
    crate::exp::report::md_table(
        title,
        &["span", "count", "total s", "mean ms", "p50 ms", "p95 ms", "p99 ms"],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the tests that read or flip the process-wide [`enabled`]
    /// switch (the test harness runs tests concurrently).
    static TOGGLE: Mutex<()> = Mutex::new(());

    #[test]
    fn export_absorb_roundtrip() {
        let a = Registry::default();
        a.counter("obs_test_requests_total").add(3);
        a.gauge("obs_test_depth").set(7.0);
        let h = a.hist("obs_test_seconds");
        h.record_ns(1_000);
        h.record_ns(2_000_000);
        let b = Registry::default();
        b.counter("obs_test_requests_total").add(2);
        b.absorb(&a.export());
        b.absorb(&a.export());
        assert_eq!(b.counter("obs_test_requests_total").get(), 8);
        assert_eq!(b.gauge("obs_test_depth").get(), 7.0);
        let merged = b.hist("obs_test_seconds");
        assert_eq!(merged.count(), 4);
        assert!((merged.total_secs() - 2.0 * (1_000.0 + 2_000_000.0) / 1e9).abs() < 1e-12);
    }

    #[test]
    fn span_and_timer_record_when_enabled() {
        let _g = TOGGLE.lock().unwrap();
        let name = "obs_test_span_seconds";
        {
            let _s = span(name);
            std::hint::black_box(0u64);
        }
        let h = registry().hist(name);
        assert!(h.count() >= 1);
        let before = h.count();
        let t = timer(name);
        let out = t.observe(|| 41 + 1);
        assert_eq!(out, 42);
        assert_eq!(registry().hist(name).count(), before + 1);
    }

    #[test]
    fn disabled_spans_are_inert() {
        let _g = TOGGLE.lock().unwrap();
        // toggle off, record, toggle back on: nothing must land
        set_enabled(false);
        {
            let _s = span("obs_test_disabled_seconds");
        }
        counter_add("obs_test_disabled_total", 5);
        let t = timer("obs_test_disabled_seconds");
        t.observe(|| ());
        set_enabled(true);
        assert_eq!(registry().hist("obs_test_disabled_seconds").count(), 0);
        assert_eq!(registry().counter("obs_test_disabled_total").get(), 0);
    }

    #[test]
    fn time_table_lists_recorded_spans() {
        registry().hist("obs_test_table_seconds").record_ns(5_000_000);
        let md = time_table_md("time by stage");
        assert!(md.contains("### time by stage"), "{md}");
        assert!(md.contains("obs_test_table_seconds"), "{md}");
        assert!(md.contains("| span | count | total s |"), "{md}");
    }
}
