//! Prometheus text exposition for the metric registry.
//!
//! Hand-rolled (zero-dependency) renderer plus a minimal HTTP/1.1
//! exporter thread, following the `serve/frontdoor.rs` pattern: the serve
//! coordinator binds a `TcpListener` (`spnn serve --metrics-listen ADDR`)
//! and every `GET` gets the full registry as `text/plain; version=0.0.4`.
//!
//! Histograms render as Prometheus *summaries* (`{quantile="..."}` series
//! plus `_sum`/`_count`), since the log-bucket layout extracts p50/p95/p99
//! directly. Registry names may carry a label suffix
//! (`transport_send_seconds{peer="1"}`); the renderer splits it so the
//! `# TYPE` header names the bare metric once.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::thread::JoinHandle;
use std::time::Duration;

use super::registry;

/// Split `name{labels}` into `(name, Some(labels))`.
fn split_labels(name: &str) -> (&str, Option<&str>) {
    match name.find('{') {
        Some(i) => (&name[..i], Some(name[i + 1..].trim_end_matches('}'))),
        None => (name, None),
    }
}

/// Join a base name, optional registry labels, and optional extra label.
fn series(base: &str, labels: Option<&str>, extra: Option<&str>) -> String {
    match (labels, extra) {
        (None, None) => base.to_string(),
        (Some(l), None) => format!("{base}{{{l}}}"),
        (None, Some(e)) => format!("{base}{{{e}}}"),
        (Some(l), Some(e)) => format!("{base}{{{l},{e}}}"),
    }
}

/// Emit `# TYPE` once per metric base name.
fn type_line(out: &mut String, seen: &mut Vec<String>, base: &str, kind: &str) {
    if !seen.iter().any(|s| s == base) {
        out.push_str(&format!("# TYPE {base} {kind}\n"));
        seen.push(base.to_string());
    }
}

/// Render the whole registry as Prometheus text exposition format.
pub fn render() -> String {
    let r = registry();
    let mut out = String::new();
    let mut seen = Vec::new();
    for (name, v) in r.counter_values() {
        let (base, labels) = split_labels(&name);
        type_line(&mut out, &mut seen, base, "counter");
        out.push_str(&format!("{} {v}\n", series(base, labels, None)));
    }
    for (name, v) in r.gauge_values() {
        let (base, labels) = split_labels(&name);
        type_line(&mut out, &mut seen, base, "gauge");
        out.push_str(&format!("{} {v}\n", series(base, labels, None)));
    }
    for (name, h) in r.hist_handles() {
        let (base, labels) = split_labels(&name);
        type_line(&mut out, &mut seen, base, "summary");
        for q in ["0.5", "0.95", "0.99"] {
            let v = h.quantile_secs(q.parse().expect("static quantile"));
            let label = format!("quantile=\"{q}\"");
            out.push_str(&format!("{} {v}\n", series(base, labels, Some(&label))));
        }
        out.push_str(&format!(
            "{} {}\n",
            series(&format!("{base}_sum"), labels, None),
            h.total_secs()
        ));
        out.push_str(&format!(
            "{} {}\n",
            series(&format!("{base}_count"), labels, None),
            h.count()
        ));
    }
    out
}

/// Answer one scrape: drain the request head, write the full registry,
/// close. The request path is ignored — everything is `/metrics`.
fn answer(mut s: TcpStream) {
    let _ = s.set_read_timeout(Some(Duration::from_secs(2)));
    let mut head = [0u8; 1024];
    let _ = s.read(&mut head);
    let body = render();
    let resp = format!(
        "HTTP/1.1 200 OK\r\ncontent-type: text/plain; version=0.0.4; charset=utf-8\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{}",
        body.len(),
        body
    );
    let _ = s.write_all(resp.as_bytes());
}

/// Serve scrapes on `listener` forever from a named background thread.
/// The thread dies with the process — the exporter is pure observer, so
/// no drain/shutdown protocol is needed.
pub fn spawn_exporter(listener: TcpListener) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("spnn-metrics".into())
        .spawn(move || {
            for s in listener.incoming().flatten() {
                answer(s);
            }
        })
        .expect("spawn metrics exporter thread")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_counters_gauges_and_summaries() {
        let r = registry();
        r.counter("prom_test_requests_total").add(4);
        r.gauge("prom_test_depth").set(2.0);
        let h = r.hist("prom_test_seconds");
        for _ in 0..100 {
            h.record_ns(1_000_000); // 1ms
        }
        let h2 = r.hist("prom_test_seconds{peer=\"1\"}");
        h2.record_ns(2_000_000);
        let text = render();
        assert!(text.contains("# TYPE prom_test_requests_total counter"), "{text}");
        assert!(text.contains("prom_test_requests_total 4"), "{text}");
        assert!(text.contains("# TYPE prom_test_depth gauge"), "{text}");
        assert!(text.contains("prom_test_depth 2"), "{text}");
        assert!(text.contains("# TYPE prom_test_seconds summary"), "{text}");
        assert!(
            text.matches("# TYPE prom_test_seconds summary").count() == 1,
            "one TYPE line per base name:\n{text}"
        );
        assert!(text.contains("prom_test_seconds{quantile=\"0.99\"}"), "{text}");
        assert!(text.contains("prom_test_seconds{peer=\"1\",quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("prom_test_seconds_count{peer=\"1\"} 1"), "{text}");
        assert!(text.contains("prom_test_seconds_count 100"), "{text}");
        // p50 of a hundred 1ms samples sits in the 1ms bucket (~25% floor error)
        let p50 = text
            .lines()
            .find(|l| l.starts_with("prom_test_seconds{quantile=\"0.5\"}"))
            .and_then(|l| l.rsplit(' ').next())
            .and_then(|v| v.parse::<f64>().ok())
            .expect("p50 line");
        assert!(p50 > 0.0007 && p50 <= 0.001, "p50 {p50}");
    }

    #[test]
    fn exporter_answers_http_scrapes() {
        registry().hist("prom_test_http_seconds").record_ns(5_000);
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let _h = spawn_exporter(listener);
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(b"GET /metrics HTTP/1.0\r\nhost: x\r\n\r\n").expect("request");
        let mut resp = String::new();
        s.read_to_string(&mut resp).expect("response");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("text/plain"), "{resp}");
        assert!(resp.contains("prom_test_http_seconds_count"), "{resp}");
    }
}
