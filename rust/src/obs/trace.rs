//! Structured JSONL event trace.
//!
//! One process-wide sink (opened by `--trace-out FILE` on any CLI verb)
//! receives events from every party thread. Each line is a flat JSON
//! object:
//!
//! ```json
//! {"sid":1,"party":0,"seq":4,"clock":"virt","t":0.812,"ev":"epoch","epoch":2,"loss":0.301}
//! ```
//!
//! * `sid` — trace session id. Threads inherit the session id of whoever
//!   spawned them ([`crate::parties::run_parties`] propagates it), so
//!   concurrent sessions in one process (e.g. parallel tests) can be
//!   separated after the fact.
//! * `party`/`seq` — emitting party and its per-`(sid, party)` sequence
//!   number. Together they give a stable total order per party.
//! * `clock`/`t` — timestamp and which clock produced it: `"virt"` is the
//!   channel's virtual clock (deterministic message schedule under netsim,
//!   but the *value* folds in real wall time spent computing), `"wall"` is
//!   plain wall clock (client-side events).
//!
//! Because `t` is the only wall-dependent field, [`canonical_digest`]
//! hashes a canonical form — drop `sid`/`t`, sort by `(party, seq)` — and
//! that digest is bit-stable across netsim runs (asserted in
//! `tests/obs_e2e.rs`).

use std::cell::Cell;
use std::collections::HashMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::{Error, Result};

static ACTIVE: AtomicBool = AtomicBool::new(false);
static NEXT_SID: AtomicU64 = AtomicU64::new(1);
static SINK: Mutex<Option<Sink>> = Mutex::new(None);

struct Sink {
    w: BufWriter<File>,
    /// Next sequence number per (sid, party).
    seq: HashMap<(u64, usize), u64>,
}

thread_local! {
    static SID: Cell<u64> = const { Cell::new(0) };
}

/// Reserve a fresh trace session id (does not change this thread's id).
pub fn alloc_sid() -> u64 {
    NEXT_SID.fetch_add(1, Ordering::Relaxed)
}

/// Adopt `sid` as this thread's trace session id.
pub fn set_sid(sid: u64) {
    SID.with(|s| s.set(sid));
}

/// This thread's trace session id (0 until one is adopted).
pub fn sid() -> u64 {
    SID.with(|s| s.get())
}

/// Open (truncate) `path` as the process-wide trace sink.
pub fn init(path: &str) -> Result<()> {
    let f = File::create(path)
        .map_err(|e| Error::Config(format!("--trace-out {path}: {e}")))?;
    *SINK.lock().unwrap() = Some(Sink { w: BufWriter::new(f), seq: HashMap::new() });
    ACTIVE.store(true, Ordering::SeqCst);
    Ok(())
}

/// Flush and close the sink; subsequent [`emit`] calls are no-ops.
pub fn close() {
    ACTIVE.store(false, Ordering::SeqCst);
    if let Some(mut sink) = SINK.lock().unwrap().take() {
        let _ = sink.w.flush();
    }
}

/// Cheap "is a sink open" probe — one relaxed atomic load.
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// An event field value.
pub enum Val<'a> {
    /// Float field.
    F(f64),
    /// Unsigned integer field.
    U(u64),
    /// String field (escaped on write).
    S(&'a str),
}

/// Append one event line. No-op unless a sink is open.
pub fn emit(party: usize, clock: &str, t: f64, ev: &str, fields: &[(&str, Val)]) {
    if !active() {
        return;
    }
    let sid = sid();
    let mut g = SINK.lock().unwrap();
    let Some(sink) = g.as_mut() else { return };
    let seq = sink.seq.entry((sid, party)).or_insert(0);
    let mut line = format!(
        "{{\"sid\":{sid},\"party\":{party},\"seq\":{seq},\"clock\":\"{clock}\",\"t\":{t:.6},\"ev\":\"{ev}\""
    );
    *seq += 1;
    for (k, v) in fields {
        match v {
            Val::F(x) if x.is_finite() => line.push_str(&format!(",\"{k}\":{x}")),
            Val::F(_) => line.push_str(&format!(",\"{k}\":null")),
            Val::U(x) => line.push_str(&format!(",\"{k}\":{x}")),
            Val::S(s) => {
                let esc = s.replace('\\', "\\\\").replace('"', "\\\"");
                line.push_str(&format!(",\"{k}\":\"{esc}\""));
            }
        }
    }
    line.push('}');
    let _ = writeln!(sink.w, "{line}");
    let _ = sink.w.flush();
}

/// FNV-1a 64 over the canonical form of one trace session: keep only
/// lines with this `sid`, drop the `sid` and `t` fields, sort by
/// `(party, seq)`. Under netsim the result is bit-stable across runs.
pub fn canonical_digest(path: &str, sid: u64) -> Result<u64> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Config(format!("trace {path}: {e}")))?;
    let mut rows: Vec<(u64, u64, String)> = Vec::new();
    for line in text.lines() {
        if field_u64(line, "sid") != Some(sid) {
            continue;
        }
        let party = field_u64(line, "party").unwrap_or(u64::MAX);
        let seq = field_u64(line, "seq").unwrap_or(u64::MAX);
        let canon = strip_field(&strip_field(line, "t"), "sid");
        rows.push((party, seq, canon));
    }
    rows.sort();
    let mut h = 0xcbf29ce484222325u64;
    for (_, _, line) in &rows {
        for b in line.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h ^= b'\n' as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    Ok(h)
}

/// Extract an unsigned top-level field from a flat JSONL line.
fn field_u64(line: &str, key: &str) -> Option<u64> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Remove `,"key":value` (a number-valued field) from a flat JSONL line.
fn strip_field(line: &str, key: &str) -> String {
    let pat = format!(",\"{key}\":");
    let Some(start) = line.find(&pat) else {
        // leading position: {"key":v, — drop "key":v,
        let lead = format!("\"{key}\":");
        let Some(s) = line.find(&lead) else { return line.to_string() };
        let rest = &line[s + lead.len()..];
        let end = rest
            .find([',', '}'])
            .map(|i| i + 1) // also eat the trailing comma
            .unwrap_or(rest.len());
        return format!("{}{}", &line[..s], &rest[end.min(rest.len())..]);
    };
    let rest = &line[start + pat.len()..];
    let end = rest.find([',', '}']).unwrap_or(rest.len());
    format!("{}{}", &line[..start], &rest[end..])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_parsing_and_stripping() {
        let line = r#"{"sid":3,"party":1,"seq":9,"clock":"virt","t":1.250000,"ev":"epoch","loss":0.5}"#;
        assert_eq!(field_u64(line, "sid"), Some(3));
        assert_eq!(field_u64(line, "party"), Some(1));
        assert_eq!(field_u64(line, "seq"), Some(9));
        assert_eq!(field_u64(line, "missing"), None);
        let canon = strip_field(&strip_field(line, "t"), "sid");
        assert!(!canon.contains("\"t\":"), "{canon}");
        assert!(!canon.contains("\"sid\":"), "{canon}");
        assert!(canon.contains("\"party\":1"), "{canon}");
        assert!(canon.contains("\"loss\":0.5"), "{canon}");
        // stripping a leading field keeps the object well-formed-ish
        let lead = strip_field(r#"{"sid":3,"party":1}"#, "sid");
        assert_eq!(lead, r#"{"party":1}"#);
    }
}
