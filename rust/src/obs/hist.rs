//! Log-bucketed latency histogram with exact count/sum and bounded-error
//! quantiles.
//!
//! Values are nanosecond durations. Buckets follow the HDR scheme: the
//! bucket index is derived from the value's most-significant bit plus the
//! next two bits, giving four sub-buckets per octave — a worst-case
//! relative quantile error of 25% of the bucket floor (one part in four),
//! constant 252 slots covering the full `u64` range, and O(1) lock-free
//! recording (`fetch_add` on one slot). Quantile extraction reports the
//! *floor* of the bucket holding the requested rank, so a reported p99 is
//! never an overestimate of the true p99's bucket.
//!
//! Histograms merge by bucketwise addition ([`Hist::merge_from`]), which is
//! associative and commutative — the property the coordinator relies on
//! when folding per-party snapshots shipped through
//! [`crate::parties::PartyOut`] into one table.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets: indices 0..4 are exact (values 0–3), then four
/// sub-buckets per octave up to `u64::MAX` (msb 63 → index 251).
pub const N_BUCKETS: usize = 252;

/// Map a nanosecond value to its bucket index.
pub fn bucket_index(v: u64) -> usize {
    if v < 4 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as usize; // >= 2
    let sub = ((v >> (msb - 2)) & 3) as usize;
    (msb - 1) * 4 + sub
}

/// Smallest value mapping to bucket `i` (the value a quantile reports).
pub fn bucket_floor(i: usize) -> u64 {
    if i < 4 {
        return i as u64;
    }
    let msb = i / 4 + 1;
    let sub = (i % 4) as u64;
    (1u64 << msb) | (sub << (msb - 2))
}

/// Concurrent log-bucketed histogram of nanosecond durations.
pub struct Hist {
    count: AtomicU64,
    sum_ns: AtomicU64,
    buckets: Vec<AtomicU64>,
}

impl Default for Hist {
    fn default() -> Self {
        Hist::new()
    }
}

impl Hist {
    pub fn new() -> Self {
        Hist {
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Record one duration in nanoseconds.
    pub fn record_ns(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(v, Ordering::Relaxed);
    }

    /// Record one duration in (non-negative) seconds.
    pub fn record_secs(&self, s: f64) {
        self.record_ns(if s > 0.0 { (s * 1e9) as u64 } else { 0 });
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact total of all recorded durations, in seconds.
    pub fn total_secs(&self) -> f64 {
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn mean_secs(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.total_secs() / n as f64
        }
    }

    /// Quantile `q` in `[0, 1]`: the floor (in ns) of the bucket holding
    /// rank `ceil(q * count)`. Returns 0 for an empty histogram.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return bucket_floor(i);
            }
        }
        bucket_floor(N_BUCKETS - 1)
    }

    pub fn quantile_secs(&self, q: f64) -> f64 {
        self.quantile_ns(q) as f64 / 1e9
    }

    /// Sparse snapshot (non-empty buckets only), suitable for shipping
    /// between parties and re-merging.
    pub fn snapshot(&self) -> HistSnapshot {
        let buckets: Vec<(usize, u64)> = self
            .buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let n = b.load(Ordering::Relaxed);
                (n > 0).then_some((i, n))
            })
            .collect();
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            buckets,
        }
    }

    /// Fold another histogram's snapshot into this one (bucketwise add).
    pub fn merge_from(&self, s: &HistSnapshot) {
        for &(i, n) in &s.buckets {
            if i < N_BUCKETS {
                self.buckets[i].fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(s.count, Ordering::Relaxed);
        self.sum_ns.fetch_add(s.sum_ns, Ordering::Relaxed);
    }
}

/// Point-in-time sparse copy of a [`Hist`].
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum_ns: u64,
    /// `(bucket index, count)` pairs, ascending by index, zeros omitted.
    pub buckets: Vec<(usize, u64)>,
}

impl HistSnapshot {
    /// Flatten to the `PartyOut` wire layout:
    /// `[count, sum_ns, idx0, n0, idx1, n1, ...]`.
    pub fn to_row(&self) -> Vec<f64> {
        let mut row = vec![self.count as f64, self.sum_ns as f64];
        for &(i, n) in &self.buckets {
            row.push(i as f64);
            row.push(n as f64);
        }
        row
    }

    /// Inverse of [`Self::to_row`]; ignores trailing odd garbage.
    pub fn from_row(row: &[f64]) -> Self {
        if row.len() < 2 {
            return HistSnapshot::default();
        }
        let buckets = row[2..]
            .chunks_exact(2)
            .map(|c| (c[0] as usize, c[1] as u64))
            .collect();
        HistSnapshot { count: row[0] as u64, sum_ns: row[1] as u64, buckets }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic xorshift64 stream for property tests.
    fn xorshift(seed: u64) -> impl FnMut() -> u64 {
        let mut x = seed | 1;
        move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            x
        }
    }

    #[test]
    fn bucket_boundaries_roundtrip() {
        // every bucket floor maps back to its own bucket, and the value
        // just below the next floor still maps to this bucket
        for i in 0..N_BUCKETS {
            assert_eq!(bucket_index(bucket_floor(i)), i, "floor of bucket {i}");
            if i + 1 < N_BUCKETS {
                let below_next = bucket_floor(i + 1) - 1;
                assert_eq!(bucket_index(below_next), i, "ceiling of bucket {i}");
            }
        }
        // indices are monotone in the value
        let mut rng = xorshift(7);
        for _ in 0..10_000 {
            let a = rng();
            let b = rng();
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            assert!(bucket_index(lo) <= bucket_index(hi), "{lo} vs {hi}");
        }
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn quantiles_match_sorted_oracle_bucket() {
        // the histogram quantile must land in the same bucket as the true
        // rank statistic of the raw stream, across value scales
        let mut rng = xorshift(42);
        for scale_bits in [8, 20, 40, 63] {
            let h = Hist::new();
            let mut vals: Vec<u64> = (0..5000).map(|_| rng() >> (64 - scale_bits)).collect();
            for &v in &vals {
                h.record_ns(v);
            }
            vals.sort_unstable();
            for q in [0.01, 0.5, 0.9, 0.95, 0.99, 1.0] {
                let rank = ((q * vals.len() as f64).ceil() as usize).clamp(1, vals.len());
                let oracle = vals[rank - 1];
                let got = h.quantile_ns(q);
                assert_eq!(
                    got,
                    bucket_floor(bucket_index(oracle)),
                    "q={q} scale={scale_bits}: oracle {oracle} got {got}"
                );
                // bounded relative error: floor <= oracle < floor * 1.5
                assert!(got <= oracle);
            }
        }
        assert_eq!(Hist::new().quantile_ns(0.99), 0, "empty histogram");
    }

    #[test]
    fn merge_is_associative_and_matches_concatenation() {
        let mut rng = xorshift(1234);
        let streams: Vec<Vec<u64>> =
            (0..3).map(|_| (0..400).map(|_| rng() >> 34).collect()).collect();
        let hist_of = |streams: &[&[u64]]| {
            let h = Hist::new();
            for s in streams {
                for &v in *s {
                    h.record_ns(v);
                }
            }
            h
        };
        let [a, b, c] = [
            hist_of(&[&streams[0]]),
            hist_of(&[&streams[1]]),
            hist_of(&[&streams[2]]),
        ];
        // (a + b) + c
        let left = Hist::new();
        left.merge_from(&a.snapshot());
        left.merge_from(&b.snapshot());
        left.merge_from(&c.snapshot());
        // a + (b + c)  — built by merging into a fresh hist in other order
        let bc = Hist::new();
        bc.merge_from(&c.snapshot());
        bc.merge_from(&b.snapshot());
        let right = Hist::new();
        right.merge_from(&bc.snapshot());
        right.merge_from(&a.snapshot());
        let direct = hist_of(&[&streams[0], &streams[1], &streams[2]]);
        assert_eq!(left.snapshot(), right.snapshot());
        assert_eq!(left.snapshot(), direct.snapshot());
        assert_eq!(left.count(), 1200);
    }

    #[test]
    fn snapshot_row_roundtrips() {
        let h = Hist::new();
        for v in [0, 3, 17, 1 << 30, u64::MAX] {
            h.record_ns(v);
        }
        let snap = h.snapshot();
        assert_eq!(HistSnapshot::from_row(&snap.to_row()), snap);
        assert_eq!(HistSnapshot::from_row(&[]), HistSnapshot::default());
    }
}
