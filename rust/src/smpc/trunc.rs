//! SecureML local share truncation (Mohassel–Zhang 2017, §IV-A, Thm 1).
//!
//! After a fixed-point multiply the shared product carries `2·l_F`
//! fractional bits. Each party truncates its own share *locally* — no
//! interaction — and reconstruction is correct to within 1 ulp except with
//! probability `~2^{l_x + 1 - 64}` (negligible for our value ranges):
//!
//! * party 0: `z0 <- floor_signed(z0 / 2^f)`     (arithmetic shift)
//! * party 1: `z1 <- -floor_signed(-z1 / 2^f)`   (two's complement trick)
//!
//! This mirrors the L1 Pallas `trunc_share` kernel bit-for-bit (see
//! `python/compile/kernels/fixed_matmul.py`); the pytest suite checks the
//! kernel, and the tests here check the rust twin against the same spec.

use super::ring::RingMat;
use crate::fixed::FRAC_BITS;

/// Truncate one party's share of a fixed-point product.
#[inline]
pub fn trunc_share_val(v: u64, role: u8) -> u64 {
    trunc_share_val_bits(v, role, FRAC_BITS)
}

#[inline]
pub fn trunc_share_val_bits(v: u64, role: u8, f: u32) -> u64 {
    let z = v as i64;
    if role == 0 {
        (z >> f) as u64
    } else {
        (-((-z) >> f)) as u64
    }
}

/// Truncate a whole share matrix in place.
pub fn trunc_share_mat(m: &mut RingMat, role: u8) {
    for v in m.data.iter_mut() {
        *v = trunc_share_val(*v, role);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{self, SCALE};
    use crate::rng::{ChaChaRng, Pcg64, Rng64};
    use crate::smpc::share::{reconstruct2, share2};

    #[test]
    fn truncated_shares_reconstruct_within_one_ulp() {
        let mut rng = Pcg64::seed_from_u64(1);
        let mut crng = ChaChaRng::seed_from_u64(2);
        for _ in 0..200 {
            // a fixed-point product value (2*l_F fractional bits)
            let a = (rng.f64_unit() - 0.5) * 50.0;
            let b = (rng.f64_unit() - 0.5) * 50.0;
            let prod = fixed::encode(a).wrapping_mul(fixed::encode(b));
            let x = RingMat::from_data(1, 1, vec![prod]);
            let (mut s0, mut s1) = share2(&mut crng, &x);
            trunc_share_mat(&mut s0, 0);
            trunc_share_mat(&mut s1, 1);
            let rec = reconstruct2(&s0, &s1).data[0];
            let want = fixed::trunc_plain(prod);
            let diff = (rec as i64).wrapping_sub(want as i64).unsigned_abs();
            assert!(diff <= 1, "a={a} b={b} diff={diff}");
        }
    }

    #[test]
    fn decoded_product_error_is_small() {
        let mut rng = Pcg64::seed_from_u64(3);
        let mut crng = ChaChaRng::seed_from_u64(4);
        let mut worst: f64 = 0.0;
        for _ in 0..500 {
            let a = (rng.f64_unit() - 0.5) * 10.0;
            let b = (rng.f64_unit() - 0.5) * 10.0;
            let prod = fixed::encode(a).wrapping_mul(fixed::encode(b));
            let x = RingMat::from_data(1, 1, vec![prod]);
            let (mut s0, mut s1) = share2(&mut crng, &x);
            trunc_share_mat(&mut s0, 0);
            trunc_share_mat(&mut s1, 1);
            let got = fixed::decode(reconstruct2(&s0, &s1).data[0]);
            worst = worst.max((got - a * b).abs());
        }
        // half-ulp operand rounding + 1 ulp trunc + 1 ulp share jitter
        assert!(worst < 12.0 / SCALE, "worst error {worst}");
    }

    #[test]
    fn roles_differ_on_shares_with_low_bits() {
        // floor vs ceil: role 0 and role 1 disagree on any share whose low
        // f bits are nonzero — the asymmetry is what cancels the rounding
        // of the two shares against each other
        let v = (5u64 << 16) | 0x1234;
        assert_ne!(trunc_share_val(v, 0), trunc_share_val(v, 1));
        // and agree when the value is exactly representable
        let w = 7u64 << 16;
        assert_eq!(trunc_share_val(w, 0), trunc_share_val(w, 1));
    }

    #[test]
    fn matches_pallas_kernel_spec() {
        // the exact formulas the L1 kernel implements
        for v in [0u64, 1, u64::MAX, 1 << 16, (1u64 << 63) + 12345, 0xdead_beef_0000] {
            let z = v as i64;
            assert_eq!(trunc_share_val(v, 0), (z >> 16) as u64);
            assert_eq!(trunc_share_val(v, 1), (-((-z) >> 16)) as u64);
        }
    }
}
