//! Additive secret sharing over `Z_{2^64}` (paper §3.3).

use super::RingMat;
use crate::rng::Rng64;

/// Split `x` into two additive shares: `(x - r, r)` with uniform `r`.
/// Either share alone is uniformly distributed (perfect secrecy).
pub fn share2<R: Rng64>(rng: &mut R, x: &RingMat) -> (RingMat, RingMat) {
    let r = RingMat::random(rng, x.rows, x.cols);
    share2_from_mask(x, r)
}

/// [`share2`] with a pre-drawn mask: the mask draw is value-independent,
/// so pipelined parties draw `r` in schedule order during prefetch and
/// bind the value (`x - r`) later — bit-identical to [`share2`] when `r`
/// comes from the same RNG stream position.
pub fn share2_from_mask(x: &RingMat, r: RingMat) -> (RingMat, RingMat) {
    assert_eq!(x.shape(), r.shape(), "mask shape mismatch");
    (x.sub(&r), r)
}

/// Split into `n >= 2` additive shares.
pub fn share_n<R: Rng64>(rng: &mut R, x: &RingMat, n: usize) -> Vec<RingMat> {
    assert!(n >= 2, "share_n needs >= 2 parties");
    let mut shares: Vec<RingMat> = (0..n - 1)
        .map(|_| RingMat::random(rng, x.rows, x.cols))
        .collect();
    let mut last = x.clone();
    for s in &shares {
        last = last.sub(s);
    }
    shares.push(last);
    shares
}

/// Reconstruct from two shares.
pub fn reconstruct2(a: &RingMat, b: &RingMat) -> RingMat {
    a.add(b)
}

/// Reconstruct from any number of shares.
pub fn reconstruct_n(shares: &[RingMat]) -> RingMat {
    assert!(!shares.is_empty());
    let mut acc = shares[0].clone();
    for s in &shares[1..] {
        acc.add_assign(s);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{ChaChaRng, Pcg64, Rng64};

    #[test]
    fn share2_reconstructs() {
        let mut rng = ChaChaRng::seed_from_u64(1);
        let x = RingMat::random(&mut Pcg64::seed_from_u64(2), 5, 7);
        let (s0, s1) = share2(&mut rng, &x);
        assert_eq!(reconstruct2(&s0, &s1), x);
        assert_ne!(s0, x, "share leaks plaintext");
        assert_ne!(s1, x);
    }

    #[test]
    fn share2_from_mask_matches_share2() {
        // same RNG stream position => identical shares
        let x = RingMat::encode_f64(2, 3, &[1.0, -2.0, 3.5, 0.0, 9.0, -4.25]);
        let mut r1 = ChaChaRng::seed_from_u64(11);
        let mut r2 = ChaChaRng::seed_from_u64(11);
        let (a, b) = share2(&mut r1, &x);
        let mask = RingMat::random(&mut r2, x.rows, x.cols);
        let (a2, b2) = share2_from_mask(&x, mask);
        assert_eq!(a, a2);
        assert_eq!(b, b2);
        assert_eq!(reconstruct2(&a2, &b2), x);
    }

    #[test]
    fn share_n_reconstructs_for_many_parties() {
        let mut rng = ChaChaRng::seed_from_u64(3);
        let x = RingMat::random(&mut Pcg64::seed_from_u64(4), 3, 3);
        for n in 2..=6 {
            let shares = share_n(&mut rng, &x, n);
            assert_eq!(shares.len(), n);
            assert_eq!(reconstruct_n(&shares), x);
        }
    }

    #[test]
    fn linearity_of_shares() {
        // <x> + <y> reconstructs to x + y without communication
        let mut rng = ChaChaRng::seed_from_u64(5);
        let mut prng = Pcg64::seed_from_u64(6);
        let x = RingMat::random(&mut prng, 4, 4);
        let y = RingMat::random(&mut prng, 4, 4);
        let (x0, x1) = share2(&mut rng, &x);
        let (y0, y1) = share2(&mut rng, &y);
        let z = reconstruct2(&x0.add(&y0), &x1.add(&y1));
        assert_eq!(z, x.add(&y));
    }

    #[test]
    fn single_share_is_statistically_masked() {
        // sharing the zero matrix must still look uniform: check bit balance
        let mut rng = ChaChaRng::seed_from_u64(7);
        let zero = RingMat::zeros(32, 32);
        let (s0, _) = share2(&mut rng, &zero);
        let ones: u64 = s0.data.iter().map(|v| v.count_ones() as u64).sum();
        let frac = ones as f64 / (64.0 * s0.data.len() as f64);
        assert!((frac - 0.5).abs() < 0.01, "share not uniform: {frac}");
    }

    #[test]
    fn fixed_point_value_shares() {
        let mut rng = ChaChaRng::seed_from_u64(8);
        let x = RingMat::encode_f64(2, 2, &[1.25, -3.5, 0.0, 42.0]);
        let (s0, s1) = share2(&mut rng, &x);
        let back = reconstruct2(&s0, &s1).decode_f64();
        assert_eq!(back, vec![1.25, -3.5, 0.0, 42.0]);
    }
}
