//! Dense matrices over the ring `Z_{2^64}` (wrapping u64 arithmetic).
//!
//! This is the data type every share, triple and protocol message is made
//! of. The native `matmul` here is the rust-side fallback / oracle; the
//! production hot path for the big first-layer products goes through the
//! AOT-compiled Pallas ring kernel (`runtime::Engine::ring_matmul`).

use crate::fixed;
use crate::rng::Rng64;

/// Row-major matrix over `Z_{2^64}`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RingMat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<u64>,
}

impl RingMat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        RingMat { rows, cols, data: vec![0; rows * cols] }
    }

    pub fn from_data(rows: usize, cols: usize, data: Vec<u64>) -> Self {
        assert_eq!(data.len(), rows * cols, "RingMat shape mismatch");
        RingMat { rows, cols, data }
    }

    /// Uniformly random matrix (mask / share material).
    pub fn random<R: Rng64>(rng: &mut R, rows: usize, cols: usize) -> Self {
        let mut data = vec![0u64; rows * cols];
        rng.fill_u64(&mut data);
        RingMat { rows, cols, data }
    }

    /// Embed a decimal matrix as fixed-point ring elements.
    pub fn encode_f64(rows: usize, cols: usize, xs: &[f64]) -> Self {
        assert_eq!(xs.len(), rows * cols);
        RingMat { rows, cols, data: fixed::encode_vec(xs) }
    }

    /// Decode back to decimals (assumes single-`l_F` scaling).
    pub fn decode_f64(&self) -> Vec<f64> {
        fixed::decode_vec(&self.data)
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> u64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: u64) {
        self.data[r * self.cols + c] = v;
    }

    /// Elementwise wrapping addition.
    pub fn add(&self, other: &Self) -> Self {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a.wrapping_add(*b))
            .collect();
        RingMat { rows: self.rows, cols: self.cols, data }
    }

    /// In-place wrapping addition (hot path — avoids reallocation).
    pub fn add_assign(&mut self, other: &Self) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a = a.wrapping_add(*b);
        }
    }

    /// Elementwise wrapping subtraction.
    pub fn sub(&self, other: &Self) -> Self {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a.wrapping_sub(*b))
            .collect();
        RingMat { rows: self.rows, cols: self.cols, data }
    }

    /// Negate (two's complement).
    pub fn neg(&self) -> Self {
        let data = self.data.iter().map(|a| a.wrapping_neg()).collect();
        RingMat { rows: self.rows, cols: self.cols, data }
    }

    /// Native ring matmul `self @ other mod 2^64` (ikj loop order).
    pub fn matmul(&self, other: &Self) -> Self {
        assert_eq!(self.cols, other.rows, "matmul inner dim");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = vec![0u64; m * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o = o.wrapping_add(a.wrapping_mul(b));
                }
            }
        }
        RingMat { rows: m, cols: n, data: out }
    }

    /// Transpose.
    pub fn transpose(&self) -> Self {
        let mut out = vec![0u64; self.rows * self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        RingMat { rows: self.cols, cols: self.rows, data: out }
    }

    /// Horizontal concatenation (the paper's `⊕` in Algorithm 2).
    pub fn concat_cols(&self, other: &Self) -> Self {
        assert_eq!(self.rows, other.rows, "concat_cols row mismatch");
        let cols = self.cols + other.cols;
        let mut data = Vec::with_capacity(self.rows * cols);
        for r in 0..self.rows {
            data.extend_from_slice(&self.data[r * self.cols..(r + 1) * self.cols]);
            data.extend_from_slice(&other.data[r * other.cols..(r + 1) * other.cols]);
        }
        RingMat { rows: self.rows, cols, data }
    }

    /// Vertical concatenation.
    pub fn concat_rows(&self, other: &Self) -> Self {
        assert_eq!(self.cols, other.cols, "concat_rows col mismatch");
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        RingMat { rows: self.rows + other.rows, cols: self.cols, data }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn matmul_matches_naive_wrapping() {
        let mut rng = Pcg64::seed_from_u64(1);
        let a = RingMat::random(&mut rng, 7, 5);
        let b = RingMat::random(&mut rng, 5, 3);
        let c = a.matmul(&b);
        for i in 0..7 {
            for j in 0..3 {
                let mut acc = 0u64;
                for k in 0..5 {
                    acc = acc.wrapping_add(a.at(i, k).wrapping_mul(b.at(k, j)));
                }
                assert_eq!(c.at(i, j), acc);
            }
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::seed_from_u64(2);
        let a = RingMat::random(&mut rng, 4, 4);
        let mut eye = RingMat::zeros(4, 4);
        for i in 0..4 {
            eye.set(i, i, 1);
        }
        assert_eq!(a.matmul(&eye), a);
        assert_eq!(eye.matmul(&a), a);
    }

    #[test]
    fn add_sub_roundtrip() {
        let mut rng = Pcg64::seed_from_u64(3);
        let a = RingMat::random(&mut rng, 6, 6);
        let b = RingMat::random(&mut rng, 6, 6);
        assert_eq!(a.add(&b).sub(&b), a);
        assert_eq!(a.sub(&a), RingMat::zeros(6, 6));
        assert_eq!(a.add(&a.neg()), RingMat::zeros(6, 6));
    }

    #[test]
    fn distributive_law_in_ring() {
        let mut rng = Pcg64::seed_from_u64(4);
        let a = RingMat::random(&mut rng, 3, 4);
        let b = RingMat::random(&mut rng, 4, 2);
        let c = RingMat::random(&mut rng, 4, 2);
        assert_eq!(a.matmul(&b.add(&c)), a.matmul(&b).add(&a.matmul(&c)));
    }

    #[test]
    fn transpose_involution_and_product_rule() {
        let mut rng = Pcg64::seed_from_u64(5);
        let a = RingMat::random(&mut rng, 3, 5);
        let b = RingMat::random(&mut rng, 5, 2);
        assert_eq!(a.transpose().transpose(), a);
        // (AB)^T = B^T A^T holds in any ring
        assert_eq!(
            a.matmul(&b).transpose(),
            b.transpose().matmul(&a.transpose())
        );
    }

    #[test]
    fn concat_cols_matches_blockwise_matmul() {
        // [Xa | Xb] @ [Ta; Tb] == Xa Ta + Xb Tb — the Algorithm 2 identity
        let mut rng = Pcg64::seed_from_u64(6);
        let xa = RingMat::random(&mut rng, 4, 3);
        let xb = RingMat::random(&mut rng, 4, 2);
        let ta = RingMat::random(&mut rng, 3, 5);
        let tb = RingMat::random(&mut rng, 2, 5);
        let lhs = xa.concat_cols(&xb).matmul(&ta.concat_rows(&tb));
        let rhs = xa.matmul(&ta).add(&xb.matmul(&tb));
        assert_eq!(lhs, rhs);
    }

    #[test]
    fn fixed_point_embedding_roundtrip() {
        let xs = vec![1.5, -2.25, 0.0, 100.0625];
        let m = RingMat::encode_f64(2, 2, &xs);
        let back = m.decode_f64();
        assert_eq!(back, xs);
    }

    #[test]
    fn fixed_point_matmul_approximates_float() {
        let a = RingMat::encode_f64(2, 2, &[1.5, 2.0, -0.5, 3.0]);
        let b = RingMat::encode_f64(2, 1, &[2.0, -1.0]);
        let prod = a.matmul(&b);
        // products carry 2*l_F fractional bits
        let got: Vec<f64> = prod.data.iter().map(|&v| crate::fixed::decode_wide(v)).collect();
        assert!((got[0] - 1.0).abs() < 1e-3, "{got:?}");
        assert!((got[1] - -4.0).abs() < 1e-3, "{got:?}");
    }
}
