//! Dense matrices over the ring `Z_{2^64}` (wrapping u64 arithmetic).
//!
//! This is the data type every share, triple and protocol message is made
//! of. The native `matmul` here is the rust-side fallback / oracle; the
//! production hot path for the big first-layer products goes through the
//! AOT-compiled Pallas ring kernel (`runtime::Engine::ring_matmul`).
//!
//! `matmul`/`add`/`sub`/`add_assign` and the fixed-point encode are
//! chunk-parallel over the process [`exec::pool`] once the work passes a
//! spawn-amortizing threshold (small fraud-shape ops stay inline); the
//! `*_with` variants take an explicit [`ExecPool`] for benches and
//! determinism baselines. Ring arithmetic is exact, so results are
//! bit-identical at any pool width.

use crate::exec::{self, ExecPool};
use crate::fixed;
use crate::rng::Rng64;

/// Minimum elements for a parallel elementwise op (below this the spawn
/// overhead beats the win).
const PAR_MIN_ELEMS: usize = 1 << 15;

/// Minimum multiply-accumulate count for a parallel matmul.
const PAR_MIN_WORK: usize = 1 << 17;

/// Row-major matrix over `Z_{2^64}`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RingMat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<u64>,
}

impl RingMat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        RingMat { rows, cols, data: vec![0; rows * cols] }
    }

    pub fn from_data(rows: usize, cols: usize, data: Vec<u64>) -> Self {
        assert_eq!(data.len(), rows * cols, "RingMat shape mismatch");
        RingMat { rows, cols, data }
    }

    /// Uniformly random matrix (mask / share material).
    pub fn random<R: Rng64>(rng: &mut R, rows: usize, cols: usize) -> Self {
        let mut data = vec![0u64; rows * cols];
        rng.fill_u64(&mut data);
        RingMat { rows, cols, data }
    }

    /// Embed a decimal matrix as fixed-point ring elements.
    pub fn encode_f64(rows: usize, cols: usize, xs: &[f64]) -> Self {
        Self::encode_f64_with(&exec::pool(), rows, cols, xs)
    }

    /// [`Self::encode_f64`] over an explicit pool.
    pub fn encode_f64_with(exec: &ExecPool, rows: usize, cols: usize, xs: &[f64]) -> Self {
        assert_eq!(xs.len(), rows * cols);
        let mut data = vec![0u64; xs.len()];
        exec.par_rows_mut(&mut data, 1, PAR_MIN_ELEMS, |off, chunk| {
            for (o, &x) in chunk.iter_mut().zip(&xs[off..]) {
                *o = fixed::encode(x);
            }
        });
        RingMat { rows, cols, data }
    }

    /// Decode back to decimals (assumes single-`l_F` scaling).
    pub fn decode_f64(&self) -> Vec<f64> {
        fixed::decode_vec(&self.data)
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> u64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: u64) {
        self.data[r * self.cols + c] = v;
    }

    /// Elementwise wrapping addition.
    pub fn add(&self, other: &Self) -> Self {
        self.add_with(&exec::pool(), other)
    }

    /// [`Self::add`] over an explicit pool.
    pub fn add_with(&self, exec: &ExecPool, other: &Self) -> Self {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut data = vec![0u64; self.data.len()];
        exec.par_rows_mut(&mut data, 1, PAR_MIN_ELEMS, |off, chunk| {
            for ((o, a), b) in chunk.iter_mut().zip(&self.data[off..]).zip(&other.data[off..]) {
                *o = a.wrapping_add(*b);
            }
        });
        RingMat { rows: self.rows, cols: self.cols, data }
    }

    /// In-place wrapping addition (hot path — avoids reallocation).
    pub fn add_assign(&mut self, other: &Self) {
        let exec = exec::pool();
        self.add_assign_with(&exec, other);
    }

    /// [`Self::add_assign`] over an explicit pool.
    pub fn add_assign_with(&mut self, exec: &ExecPool, other: &Self) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        exec.par_rows_mut(&mut self.data, 1, PAR_MIN_ELEMS, |off, chunk| {
            for (a, b) in chunk.iter_mut().zip(&other.data[off..]) {
                *a = a.wrapping_add(*b);
            }
        });
    }

    /// Elementwise wrapping subtraction.
    pub fn sub(&self, other: &Self) -> Self {
        self.sub_with(&exec::pool(), other)
    }

    /// [`Self::sub`] over an explicit pool.
    pub fn sub_with(&self, exec: &ExecPool, other: &Self) -> Self {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut data = vec![0u64; self.data.len()];
        exec.par_rows_mut(&mut data, 1, PAR_MIN_ELEMS, |off, chunk| {
            for ((o, a), b) in chunk.iter_mut().zip(&self.data[off..]).zip(&other.data[off..]) {
                *o = a.wrapping_sub(*b);
            }
        });
        RingMat { rows: self.rows, cols: self.cols, data }
    }

    /// Negate (two's complement).
    pub fn neg(&self) -> Self {
        let data = self.data.iter().map(|a| a.wrapping_neg()).collect();
        RingMat { rows: self.rows, cols: self.cols, data }
    }

    /// Native ring matmul `self @ other mod 2^64` (ikj loop order,
    /// row-banded across the exec pool for big shapes).
    pub fn matmul(&self, other: &Self) -> Self {
        self.matmul_with(&exec::pool(), other)
    }

    /// [`Self::matmul`] over an explicit pool ([`ExecPool::serial`] is the
    /// single-thread baseline the benches compare against).
    pub fn matmul_with(&self, exec: &ExecPool, other: &Self) -> Self {
        assert_eq!(self.cols, other.rows, "matmul inner dim");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = vec![0u64; m * n];
        if n > 0 && m > 0 {
            // band rows so each spawn carries at least PAR_MIN_WORK macs
            let min_rows = (PAR_MIN_WORK / (k * n).max(1)).max(1);
            exec.par_rows_mut(&mut out, n, min_rows, |row0, band| {
                for (bi, orow) in band.chunks_mut(n).enumerate() {
                    let i = row0 + bi;
                    let arow = &self.data[i * k..(i + 1) * k];
                    for (kk, &a) in arow.iter().enumerate() {
                        if a == 0 {
                            continue;
                        }
                        let brow = &other.data[kk * n..(kk + 1) * n];
                        for (o, &b) in orow.iter_mut().zip(brow) {
                            *o = o.wrapping_add(a.wrapping_mul(b));
                        }
                    }
                }
            });
        }
        RingMat { rows: m, cols: n, data: out }
    }

    /// Transpose.
    pub fn transpose(&self) -> Self {
        let mut out = vec![0u64; self.rows * self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        RingMat { rows: self.cols, cols: self.rows, data: out }
    }

    /// Horizontal concatenation (the paper's `⊕` in Algorithm 2).
    pub fn concat_cols(&self, other: &Self) -> Self {
        assert_eq!(self.rows, other.rows, "concat_cols row mismatch");
        let cols = self.cols + other.cols;
        let mut data = Vec::with_capacity(self.rows * cols);
        for r in 0..self.rows {
            data.extend_from_slice(&self.data[r * self.cols..(r + 1) * self.cols]);
            data.extend_from_slice(&other.data[r * other.cols..(r + 1) * other.cols]);
        }
        RingMat { rows: self.rows, cols, data }
    }

    /// Vertical concatenation.
    pub fn concat_rows(&self, other: &Self) -> Self {
        assert_eq!(self.cols, other.cols, "concat_rows col mismatch");
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        RingMat { rows: self.rows + other.rows, cols: self.cols, data }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn matmul_matches_naive_wrapping() {
        let mut rng = Pcg64::seed_from_u64(1);
        let a = RingMat::random(&mut rng, 7, 5);
        let b = RingMat::random(&mut rng, 5, 3);
        let c = a.matmul(&b);
        for i in 0..7 {
            for j in 0..3 {
                let mut acc = 0u64;
                for k in 0..5 {
                    acc = acc.wrapping_add(a.at(i, k).wrapping_mul(b.at(k, j)));
                }
                assert_eq!(c.at(i, j), acc);
            }
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::seed_from_u64(2);
        let a = RingMat::random(&mut rng, 4, 4);
        let mut eye = RingMat::zeros(4, 4);
        for i in 0..4 {
            eye.set(i, i, 1);
        }
        assert_eq!(a.matmul(&eye), a);
        assert_eq!(eye.matmul(&a), a);
    }

    #[test]
    fn add_sub_roundtrip() {
        let mut rng = Pcg64::seed_from_u64(3);
        let a = RingMat::random(&mut rng, 6, 6);
        let b = RingMat::random(&mut rng, 6, 6);
        assert_eq!(a.add(&b).sub(&b), a);
        assert_eq!(a.sub(&a), RingMat::zeros(6, 6));
        assert_eq!(a.add(&a.neg()), RingMat::zeros(6, 6));
    }

    #[test]
    fn distributive_law_in_ring() {
        let mut rng = Pcg64::seed_from_u64(4);
        let a = RingMat::random(&mut rng, 3, 4);
        let b = RingMat::random(&mut rng, 4, 2);
        let c = RingMat::random(&mut rng, 4, 2);
        assert_eq!(a.matmul(&b.add(&c)), a.matmul(&b).add(&a.matmul(&c)));
    }

    #[test]
    fn transpose_involution_and_product_rule() {
        let mut rng = Pcg64::seed_from_u64(5);
        let a = RingMat::random(&mut rng, 3, 5);
        let b = RingMat::random(&mut rng, 5, 2);
        assert_eq!(a.transpose().transpose(), a);
        // (AB)^T = B^T A^T holds in any ring
        assert_eq!(
            a.matmul(&b).transpose(),
            b.transpose().matmul(&a.transpose())
        );
    }

    #[test]
    fn concat_cols_matches_blockwise_matmul() {
        // [Xa | Xb] @ [Ta; Tb] == Xa Ta + Xb Tb — the Algorithm 2 identity
        let mut rng = Pcg64::seed_from_u64(6);
        let xa = RingMat::random(&mut rng, 4, 3);
        let xb = RingMat::random(&mut rng, 4, 2);
        let ta = RingMat::random(&mut rng, 3, 5);
        let tb = RingMat::random(&mut rng, 2, 5);
        let lhs = xa.concat_cols(&xb).matmul(&ta.concat_rows(&tb));
        let rhs = xa.matmul(&ta).add(&xb.matmul(&tb));
        assert_eq!(lhs, rhs);
    }

    #[test]
    fn pooled_ops_match_serial_bitwise() {
        // sizes chosen to actually cross the parallel thresholds
        use crate::exec::ExecPool;
        let serial = ExecPool::serial();
        let par = ExecPool::new(4);
        let mut rng = Pcg64::seed_from_u64(77);
        let a = RingMat::random(&mut rng, 130, 70);
        let b = RingMat::random(&mut rng, 70, 50);
        assert_eq!(a.matmul_with(&serial, &b), a.matmul_with(&par, &b));
        let x = RingMat::random(&mut rng, 300, 200);
        let y = RingMat::random(&mut rng, 300, 200);
        assert_eq!(x.add_with(&serial, &y), x.add_with(&par, &y));
        assert_eq!(x.sub_with(&serial, &y), x.sub_with(&par, &y));
        let mut z = x.clone();
        z.add_assign_with(&par, &y);
        assert_eq!(z, x.add_with(&serial, &y));
        let xs: Vec<f64> = (0..300 * 200).map(|i| i as f64 * 0.01 - 300.0).collect();
        assert_eq!(
            RingMat::encode_f64_with(&serial, 300, 200, &xs),
            RingMat::encode_f64_with(&par, 300, 200, &xs)
        );
    }

    #[test]
    fn fixed_point_embedding_roundtrip() {
        let xs = vec![1.5, -2.25, 0.0, 100.0625];
        let m = RingMat::encode_f64(2, 2, &xs);
        let back = m.decode_f64();
        assert_eq!(back, xs);
    }

    #[test]
    fn fixed_point_matmul_approximates_float() {
        let a = RingMat::encode_f64(2, 2, &[1.5, 2.0, -0.5, 3.0]);
        let b = RingMat::encode_f64(2, 1, &[2.0, -1.0]);
        let prod = a.matmul(&b);
        // products carry 2*l_F fractional bits
        let got: Vec<f64> = prod.data.iter().map(|&v| crate::fixed::decode_wide(v)).collect();
        assert!((got[0] - 1.0).abs() < 1e-3, "{got:?}");
        assert!((got[1] - -4.0).abs() < 1e-3, "{got:?}");
    }
}
