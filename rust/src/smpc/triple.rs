//! Beaver matrix triples with PRG-compressed correlated randomness.
//!
//! A matrix triple for shapes `(m,k) x (k,n)` is `(<U>, <V>, <W>)` with
//! `W = U·V mod 2^64`. The trusted dealer compresses its output (SecureML
//! §IV-style offline phase, compression as in modern dealers à la
//! CrypTen/MP-SPDZ):
//!
//! * party **B**'s shares `<U>_1, <V>_1, <W>_1` are all expanded from one
//!   32-byte ChaCha seed — the dealer sends B *only the seed*;
//! * party **A** receives its `<U>_0, <V>_0` expansions from its own seed
//!   and the explicit `W`-correction matrix
//!   `<W>_0 = U·V - <W>_1` (the only Ω(m·n) transfer).
//!
//! Per-triple offline traffic: `32 + 32 + 8·m·n` bytes instead of
//! `8·(2mk + 2kn + 2mn)`.

use super::ring::RingMat;
use crate::rng::{ChaChaRng, Rng64};

/// One party's view of a Beaver matrix triple.
#[derive(Clone, Debug)]
pub struct MatTriple {
    pub u: RingMat, // share of U (m x k)
    pub v: RingMat, // share of V (k x n)
    pub w: RingMat, // share of W = U·V (m x n)
}

/// Domain-separation nonces for the three expansions of one seed.
const NONCE_U: u64 = 0x5452_4950_4c45_5f55; // "TRIPLE_U"
const NONCE_V: u64 = 0x5452_4950_4c45_5f56;
const NONCE_W: u64 = 0x5452_4950_4c45_5f57;

/// Expand one party's triple shares from a seed (B-side; dealer and B both
/// run this — determinism is the compression).
pub fn expand_triple_shares(seed: [u8; 32], m: usize, k: usize, n: usize) -> MatTriple {
    let mut ru = ChaChaRng::from_seed(seed, NONCE_U);
    let mut rv = ChaChaRng::from_seed(seed, NONCE_V);
    let mut rw = ChaChaRng::from_seed(seed, NONCE_W);
    MatTriple {
        u: RingMat::random(&mut ru, m, k),
        v: RingMat::random(&mut rv, k, n),
        w: RingMat::random(&mut rw, m, n),
    }
}

/// Expand only U/V from a seed (A-side: A's W share arrives explicitly).
pub fn expand_uv(seed: [u8; 32], m: usize, k: usize, n: usize) -> (RingMat, RingMat) {
    let mut ru = ChaChaRng::from_seed(seed, NONCE_U);
    let mut rv = ChaChaRng::from_seed(seed, NONCE_V);
    (RingMat::random(&mut ru, m, k), RingMat::random(&mut rv, k, n))
}

/// Dealer-side triple generator.
pub struct TripleGen {
    rng: ChaChaRng,
}

/// Dealer output for one triple: what goes to each party.
pub struct DealtTriple {
    /// Seed for party A's U/V expansion.
    pub seed_a: [u8; 32],
    /// Seed for party B's full expansion.
    pub seed_b: [u8; 32],
    /// Explicit `<W>_0` correction for A.
    pub w_a: RingMat,
}

impl TripleGen {
    pub fn new(seed: u64) -> Self {
        TripleGen { rng: ChaChaRng::seed_from_u64(seed) }
    }

    /// Deal one `(m,k)x(k,n)` matrix triple.
    pub fn deal(&mut self, m: usize, k: usize, n: usize) -> DealtTriple {
        let seed_a = self.rng.gen_seed();
        let seed_b = self.rng.gen_seed();
        let (ua, va) = expand_uv(seed_a, m, k, n);
        let tb = expand_triple_shares(seed_b, m, k, n);
        let u = ua.add(&tb.u);
        let v = va.add(&tb.v);
        let w = u.matmul(&v);
        let w_a = w.sub(&tb.w);
        DealtTriple { seed_a, seed_b, w_a }
    }

    /// Reassemble A's triple view from a dealt triple.
    pub fn triple_a(dealt: &DealtTriple, m: usize, k: usize, n: usize) -> MatTriple {
        let (u, v) = expand_uv(dealt.seed_a, m, k, n);
        MatTriple { u, v, w: dealt.w_a.clone() }
    }

    /// Reassemble B's triple view.
    pub fn triple_b(dealt: &DealtTriple, m: usize, k: usize, n: usize) -> MatTriple {
        expand_triple_shares(dealt.seed_b, m, k, n)
    }
}

/// Offline bytes this triple costs the dealer (for accounting).
pub fn triple_offline_bytes(m: usize, n: usize) -> usize {
    32 + 32 + 8 * m * n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::smpc::share::reconstruct2;

    #[test]
    fn dealt_triple_satisfies_w_eq_uv() {
        let mut gen = TripleGen::new(42);
        for (m, k, n) in [(3, 4, 2), (1, 1, 1), (8, 16, 8), (5, 2, 9)] {
            let dealt = gen.deal(m, k, n);
            let ta = TripleGen::triple_a(&dealt, m, k, n);
            let tb = TripleGen::triple_b(&dealt, m, k, n);
            let u = reconstruct2(&ta.u, &tb.u);
            let v = reconstruct2(&ta.v, &tb.v);
            let w = reconstruct2(&ta.w, &tb.w);
            assert_eq!(w, u.matmul(&v), "({m},{k},{n})");
        }
    }

    #[test]
    fn seed_expansion_is_deterministic() {
        let seed = [9u8; 32];
        let t1 = expand_triple_shares(seed, 4, 4, 4);
        let t2 = expand_triple_shares(seed, 4, 4, 4);
        assert_eq!(t1.u, t2.u);
        assert_eq!(t1.v, t2.v);
        assert_eq!(t1.w, t2.w);
        // and the A-side expansion agrees on U/V
        let (u, v) = expand_uv(seed, 4, 4, 4);
        assert_eq!(u, t1.u);
        assert_eq!(v, t1.v);
    }

    #[test]
    fn distinct_triples_are_independent() {
        let mut gen = TripleGen::new(1);
        let d1 = gen.deal(4, 4, 4);
        let d2 = gen.deal(4, 4, 4);
        assert_ne!(d1.seed_a, d2.seed_a);
        assert_ne!(d1.seed_b, d2.seed_b);
        assert_ne!(d1.w_a, d2.w_a);
    }

    #[test]
    fn compression_accounting() {
        // 256x8 output: naive transfer would be ~ 8*(2*256*28+2*28*8+2*256*8)
        let b = triple_offline_bytes(256, 8);
        assert_eq!(b, 64 + 8 * 256 * 8);
    }
}
