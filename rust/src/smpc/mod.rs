//! Secure multi-party computation engine over `Z_{2^64}`.
//!
//! Implements everything Algorithm 2 (SPNN-SS) and the SecureML baseline
//! need, under the paper's semi-honest threat model with a trusted dealer
//! for input-independent preprocessing (the standard offline/online split;
//! SecureML realizes the dealer with OT/HE, which only changes *offline*
//! cost — accounted, not simulated):
//!
//! * [`ring`] — dense matrices over `Z_{2^64}` with wrapping arithmetic and
//!   fixed-point embedding (Q47.16).
//! * [`share`] — additive secret sharing (2-party and n-party).
//! * [`triple`] — Beaver **matrix** triples, PRG-compressed: each party
//!   expands its `U`/`V`/(one side of) `W` shares from a 32-byte seed, so
//!   the dealer ships `O(1)` bytes to B and only the `W` correction to A.
//! * [`matmul`] — the online Beaver protocol: open `X-U`, `Y-V`, combine.
//! * [`trunc`] — SecureML local share truncation after fixed-point products.
//! * [`boolean`] — bit-sliced XOR sharing, dealer AND triples, Kogge–Stone
//!   borrow comparison (MSB extraction), daBit B2A, DReLU and the SecureML
//!   piecewise sigmoid. Used by the SecureML baseline's non-linearities.
//! * [`dealer`] — the trusted-dealer actor serving preprocessing requests
//!   over the simulated network (offline phase).

pub mod boolean;
pub mod dealer;
pub mod matmul;
pub mod ring;
pub mod share;
pub mod triple;
pub mod trunc;

pub use matmul::beaver_matmul;
pub use ring::RingMat;
pub use share::{reconstruct2, share2, share2_from_mask, share_n};
pub use triple::{MatTriple, TripleGen};
pub use trunc::trunc_share_mat;
