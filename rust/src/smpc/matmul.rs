//! Online Beaver protocols: secure matrix multiplication and elementwise
//! (Hadamard) multiplication over `Z_{2^64}`.
//!
//! The combine steps (`E·V + U·F + W`, and the per-element Hadamard
//! combine) ride the process [`exec::pool`](crate::exec::pool): matrix
//! products and `add_assign` are chunk-parallel inside [`RingMat`], and
//! the elementwise combine below is banded explicitly. Ring math is
//! exact, so the transcript is unchanged at any pool width.

use super::ring::RingMat;
use super::triple::MatTriple;
use crate::netsim::{PartyId, Payload};
use crate::transport::Channel;
use crate::Result;

/// Pluggable ring-matmul backend: the protocols call this for every local
/// matrix product, so the coordinator can route the big ones through the
/// AOT-compiled Pallas kernel and keep small ones native.
pub type MatmulFn<'a> = &'a dyn Fn(&RingMat, &RingMat) -> RingMat;

/// Native backend (used by tests and small shapes).
pub fn native_mm(a: &RingMat, b: &RingMat) -> RingMat {
    a.matmul(b)
}

/// Beaver secure matmul: both parties hold `<X>` (m,k) and `<Y>` (k,n) and a
/// matching [`MatTriple`]; each obtains `<X·Y>`.
///
/// Round structure (1 round): exchange `E_p = <X>_p - <U>_p` and
/// `F_p = <Y>_p - <V>_p`; reconstruct `E, F`; combine locally:
/// `<Z>_p = [p=0]·E·F + E·<V>_p + <U>_p·F + <W>_p`.
pub fn beaver_matmul(
    port: &mut dyn Channel,
    peer: PartyId,
    role: u8,
    x: &RingMat,
    y: &RingMat,
    triple: &MatTriple,
    mm: MatmulFn,
) -> Result<RingMat> {
    assert_eq!(x.shape(), triple.u.shape(), "triple U shape mismatch");
    assert_eq!(y.shape(), triple.v.shape(), "triple V shape mismatch");
    let e_p = x.sub(&triple.u);
    let f_p = y.sub(&triple.v);
    // single message carrying both E and F halves
    let mut buf = e_p.data.clone();
    buf.extend_from_slice(&f_p.data);
    port.send(peer, Payload::U64s(buf))?;
    let theirs = port.recv_u64s(peer)?;
    if theirs.len() != e_p.len() + f_p.len() {
        return Err(crate::Error::Protocol(format!(
            "beaver_matmul: expected {} words, got {}",
            e_p.len() + f_p.len(),
            theirs.len()
        )));
    }
    let e_o = RingMat::from_data(x.rows, x.cols, theirs[..e_p.len()].to_vec());
    let f_o = RingMat::from_data(y.rows, y.cols, theirs[e_p.len()..].to_vec());
    let e = e_p.add(&e_o);
    let f = f_p.add(&f_o);

    // Z_p = [role=0] E·F + E·V_p + U_p·F + W_p
    let mut z = mm(&e, &triple.v);
    z.add_assign(&mm(&triple.u, &f));
    z.add_assign(&triple.w);
    if role == 0 {
        z.add_assign(&mm(&e, &f));
    }
    Ok(z)
}

/// Elementwise triple (`w = u ⊙ v`): stored as 1-column RingMats.
#[derive(Clone, Debug)]
pub struct ElemTriple {
    pub u: Vec<u64>,
    pub v: Vec<u64>,
    pub w: Vec<u64>,
}

/// Beaver elementwise (Hadamard) product of two shared vectors.
pub fn beaver_mul_elem(
    port: &mut dyn Channel,
    peer: PartyId,
    role: u8,
    x: &[u64],
    y: &[u64],
    triple: &ElemTriple,
) -> Result<Vec<u64>> {
    assert_eq!(x.len(), y.len());
    assert_eq!(x.len(), triple.u.len(), "elem triple size mismatch");
    let e_p: Vec<u64> = x.iter().zip(&triple.u).map(|(a, b)| a.wrapping_sub(*b)).collect();
    let f_p: Vec<u64> = y.iter().zip(&triple.v).map(|(a, b)| a.wrapping_sub(*b)).collect();
    let mut buf = e_p.clone();
    buf.extend_from_slice(&f_p);
    port.send(peer, Payload::U64s(buf))?;
    let theirs = port.recv_u64s(peer)?;
    if theirs.len() != 2 * x.len() {
        return Err(crate::Error::Protocol("beaver_mul_elem size".into()));
    }
    let n = x.len();
    let mut out = vec![0u64; n];
    crate::exec::pool().par_rows_mut(&mut out, 1, 1 << 14, |off, chunk| {
        for (i, z) in chunk.iter_mut().enumerate() {
            let gi = off + i;
            let e = e_p[gi].wrapping_add(theirs[gi]);
            let f = f_p[gi].wrapping_add(theirs[n + gi]);
            let mut v = e
                .wrapping_mul(triple.v[gi])
                .wrapping_add(triple.u[gi].wrapping_mul(f))
                .wrapping_add(triple.w[gi]);
            if role == 0 {
                v = v.wrapping_add(e.wrapping_mul(f));
            }
            *z = v;
        }
    });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{full_mesh, LinkSpec};
    use crate::rng::{ChaChaRng, Pcg64};
    use crate::smpc::share::{reconstruct2, share2};
    use crate::smpc::triple::TripleGen;

    /// Run a two-party closure pair over a fresh LAN mesh.
    fn run2<F0, F1, T0: Send + 'static, T1: Send + 'static>(f0: F0, f1: F1) -> (T0, T1)
    where
        F0: FnOnce(NetPort) -> T0 + Send + 'static,
        F1: FnOnce(NetPort) -> T1 + Send + 'static,
    {
        let (mut ports, _) = full_mesh(&["P0", "P1"], LinkSpec::lan());
        let p1 = ports.pop().unwrap();
        let p0 = ports.pop().unwrap();
        let h1 = std::thread::spawn(move || f1(p1));
        let r0 = f0(p0);
        (r0, h1.join().expect("party 1 panicked"))
    }

    #[test]
    fn secure_matmul_equals_plaintext() {
        let mut rng = Pcg64::seed_from_u64(1);
        let x = RingMat::random(&mut rng, 6, 4);
        let y = RingMat::random(&mut rng, 4, 3);
        let mut crng = ChaChaRng::seed_from_u64(2);
        let (x0, x1) = share2(&mut crng, &x);
        let (y0, y1) = share2(&mut crng, &y);
        let mut gen = TripleGen::new(3);
        let dealt = gen.deal(6, 4, 3);
        let t0 = TripleGen::triple_a(&dealt, 6, 4, 3);
        let t1 = TripleGen::triple_b(&dealt, 6, 4, 3);

        let (z0, z1) = run2(
            move |mut p| beaver_matmul(&mut p, 1, 0, &x0, &y0, &t0, &native_mm).unwrap(),
            move |mut p| beaver_matmul(&mut p, 0, 1, &x1, &y1, &t1, &native_mm).unwrap(),
        );
        assert_eq!(reconstruct2(&z0, &z1), x.matmul(&y));
    }

    #[test]
    fn secure_matmul_fixed_point_values() {
        // Algorithm 2 semantics: fixed-point inputs, product carries 2*l_F
        let x = RingMat::encode_f64(2, 3, &[0.5, -1.0, 2.0, 1.5, 0.25, -0.75]);
        let y = RingMat::encode_f64(3, 1, &[1.0, 2.0, -1.0]);
        let mut crng = ChaChaRng::seed_from_u64(5);
        let (x0, x1) = share2(&mut crng, &x);
        let (y0, y1) = share2(&mut crng, &y);
        let mut gen = TripleGen::new(6);
        let dealt = gen.deal(2, 3, 1);
        let t0 = TripleGen::triple_a(&dealt, 2, 3, 1);
        let t1 = TripleGen::triple_b(&dealt, 2, 3, 1);
        let (z0, z1) = run2(
            move |mut p| beaver_matmul(&mut p, 1, 0, &x0, &y0, &t0, &native_mm).unwrap(),
            move |mut p| beaver_matmul(&mut p, 0, 1, &x1, &y1, &t1, &native_mm).unwrap(),
        );
        let z = reconstruct2(&z0, &z1);
        let got: Vec<f64> = z.data.iter().map(|&v| crate::fixed::decode_wide(v)).collect();
        // x@y = [0.5-2.0-2.0, 1.5+0.5+0.75]
        assert!((got[0] - -3.5).abs() < 1e-3, "{got:?}");
        assert!((got[1] - 2.75).abs() < 1e-3, "{got:?}");
    }

    #[test]
    fn elementwise_mul_equals_plaintext() {
        let mut rng = Pcg64::seed_from_u64(7);
        let x = RingMat::random(&mut rng, 1, 20);
        let y = RingMat::random(&mut rng, 1, 20);
        let mut crng = ChaChaRng::seed_from_u64(8);
        let (x0, x1) = share2(&mut crng, &x);
        let (y0, y1) = share2(&mut crng, &y);
        // dealer: elementwise triple
        let mut trng = ChaChaRng::seed_from_u64(9);
        let u = RingMat::random(&mut trng, 1, 20);
        let v = RingMat::random(&mut trng, 1, 20);
        let w: Vec<u64> = u.data.iter().zip(&v.data).map(|(a, b)| a.wrapping_mul(*b)).collect();
        let (u0, u1) = share2(&mut trng, &u);
        let (v0, v1) = share2(&mut trng, &v);
        let (w0, w1) = share2(&mut trng, &RingMat::from_data(1, 20, w));
        let t0 = ElemTriple { u: u0.data, v: v0.data, w: w0.data };
        let t1 = ElemTriple { u: u1.data, v: v1.data, w: w1.data };

        let (x0d, y0d) = (x0.data.clone(), y0.data.clone());
        let (x1d, y1d) = (x1.data.clone(), y1.data.clone());
        let (z0, z1) = run2(
            move |mut p| beaver_mul_elem(&mut p, 1, 0, &x0d, &y0d, &t0).unwrap(),
            move |mut p| beaver_mul_elem(&mut p, 0, 1, &x1d, &y1d, &t1).unwrap(),
        );
        for i in 0..20 {
            assert_eq!(
                z0[i].wrapping_add(z1[i]),
                x.data[i].wrapping_mul(y.data[i])
            );
        }
    }

    #[test]
    fn shape_mismatch_is_protocol_error() {
        let mut rng = Pcg64::seed_from_u64(10);
        let x = RingMat::random(&mut rng, 2, 2);
        let y = RingMat::random(&mut rng, 2, 2);
        let mut gen = TripleGen::new(11);
        let dealt = gen.deal(2, 2, 2);
        let t0 = TripleGen::triple_a(&dealt, 2, 2, 2);
        let t1 = TripleGen::triple_b(&dealt, 2, 2, 2);
        // party 1 sends a wrong-size opening
        let (r0, _r1) = run2(
            move |mut p| beaver_matmul(&mut p, 1, 0, &x, &y, &t0, &native_mm),
            move |mut p| {
                p.send(0, Payload::U64s(vec![0u64; 3])).unwrap();
                let _ = p.recv_u64s(0); // drain
                drop(t1);
            },
        );
        assert!(r0.is_err());
    }
}
