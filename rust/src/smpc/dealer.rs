//! The trusted-dealer actor: serves preprocessing material over the
//! simulated network (offline phase).
//!
//! The dealer abstraction is the standard MPC offline/online split
//! (SecureML realizes it with OT or HE between the parties themselves; that
//! changes *offline* cost only). All dealer traffic is tagged
//! [`Phase::Offline`]: byte-counted, reported separately, excluded from the
//! online epoch clock.
//!
//! Wire protocol (requests always come from party A, role 0; B runs the
//! matching `recv_*_b` at the same protocol step):
//!
//! ```text
//! A -> D: Control("mat:m,k,n")     D -> A: Seed, U64s(w_a)   D -> B: Seed
//! A -> D: Control("elem:len")      D -> A: Seed, U64s(w_a)   D -> B: Seed
//! A -> D: Control("bool:lanes")    D -> A: Seed, Bits(eda bits), Bits(c),
//!                                          U64s(dab arith), Bits(dab bits)
//!                                  D -> B: Seed
//! A -> D: Control("idle")          (dealer may now park arbitrarily long
//!                                   between requests — serving phase)
//! A -> D: Control("stop")          (dealer thread exits)
//! ```
//!
//! PRG compression: B's entire bundle expands from one 32-byte seed; A
//! expands its input-mask shares from its seed and receives only the
//! product/bit *corrections* explicitly — the information-theoretic minimum
//! for a dealer that must fix `W = U·V` / `c = a∧b` / bit-consistency.
//!
//! **Streaming ahead of demand:** requests and replies carry a batch tag
//! (echoed verbatim by the dealer), so the pipelined trainers issue the
//! requests for future batches from their `Step::Prefetch` stage
//! (`protocols::common::run_pipeline`) and pull the replies with
//! `recv_tagged` at point of use. The dealer computes while the parties'
//! online critical path runs, and its early departure stamps let the
//! netsim clock absorb the preprocessing into the parties' wait windows
//! instead of serializing a request round-trip into every batch.

use std::collections::{HashMap, VecDeque};

use super::boolean::{words_for, BitMat, BoolBundle, DaBits, EdaBits, TripleBank};
use super::matmul::ElemTriple;
use super::ring::RingMat;
use super::triple::{expand_triple_shares, expand_uv, MatTriple};
use crate::netsim::{PartyId, Payload, Phase, NO_TAG};
use crate::rng::{ChaChaRng, Rng64};
use crate::transport::Channel;
use crate::{Error, Result};

/// One preprocessing request (the wire strings in [`serve`]'s protocol).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Req {
    /// Matrix triple for an `(m x k) @ (k x n)` Beaver multiplication.
    Mat(usize, usize, usize),
    /// Elementwise (Hadamard) triple over `len` lanes.
    Elem(usize),
    /// Boolean bundle (edaBit + AND bank + daBits) for one DReLU batch.
    Bool(usize),
}

/// A-side: fire one tagged request without blocking for the reply
/// (prefetch stage). The dealer echoes the tag on every reply message.
pub fn send_request_tagged(
    port: &mut dyn Channel,
    dealer: PartyId,
    req: Req,
    tag: u64,
) -> Result<()> {
    let s = match req {
        Req::Mat(m, k, n) => format!("mat:{m},{k},{n}"),
        Req::Elem(len) => format!("elem:{len}"),
        Req::Bool(lanes) => format!("bool:{lanes}"),
    };
    port.send_tagged_phase(dealer, tag, Payload::Control(s), Phase::Offline)
}

// Domain-separation nonces for A-side / B-side bundle expansions.
const NONCE_ELEM_U: u64 = 0x454c_454d_5f55;
const NONCE_ELEM_V: u64 = 0x454c_454d_5f56;
const NONCE_ELEM_W: u64 = 0x454c_454d_5f57;
const NONCE_BOOL_RA: u64 = 0x424f_4f4c_5f52;
const NONCE_BOOL_TA: u64 = 0x424f_4f4c_5f41;
const NONCE_BOOL_TB: u64 = 0x424f_4f4c_5f42;

fn expand_vec(seed: [u8; 32], nonce: u64, n: usize) -> Vec<u64> {
    let mut rng = ChaChaRng::from_seed(seed, nonce);
    let mut v = vec![0u64; n];
    rng.fill_u64(&mut v);
    v
}

// ---------------------------------------------------------------------------
// Dealer-side
// ---------------------------------------------------------------------------

/// Serve preprocessing requests until `Control("stop")`.
///
/// Every reply is tagged with the request's tag, so prefetched requests
/// for several future batches can be outstanding at once and the parties
/// reassemble them per batch with `recv_tagged`.
pub fn serve(port: &mut dyn Channel, a: PartyId, b: PartyId, seed: u64) -> Result<()> {
    serve_from(port, a, b, seed, None).map(|_| ())
}

/// [`serve`] with a checkpointable RNG stream: optionally seeks the
/// dealer's seed-expansion RNG to a cursor saved by an earlier session
/// (`resume`), and returns the **end-of-training** cursor so a deployment
/// with a checkpoint dir can persist it (see [`crate::ckpt`]).
///
/// "End of training" is the first `idle` request (the requester's
/// training→serving transition) or, for train-and-exit sessions that
/// never idle, the `stop`. Every role checkpoints its RNG position at
/// that same boundary, so a warm-started session replays the *serving*
/// randomness stream from exactly where the continuous session's serving
/// phase would start — which is what keeps warm-start serve transcripts
/// bit-identical to the continuous train→serve path.
pub fn serve_from(
    port: &mut dyn Channel,
    a: PartyId,
    b: PartyId,
    seed: u64,
    resume: Option<(u64, u64)>,
) -> Result<(u64, u64)> {
    let mut rng = ChaChaRng::seed_from_u64(seed);
    if let Some(cur) = resume {
        rng.seek(cur)?;
    }
    let mut end_of_train: Option<(u64, u64)> = None;
    port.set_stage("dealer");
    loop {
        let (tag, payload) = port.recv_any_tag(a)?;
        let req = payload.into_control()?;
        let (kind, args) = req.split_once(':').unwrap_or((req.as_str(), ""));
        if kind == "idle" && end_of_train.is_none() {
            end_of_train = Some(rng.cursor());
        }
        match kind {
            "stop" => return Ok(end_of_train.unwrap_or_else(|| rng.cursor())),
            // the requester entered its serving phase: requests may now be
            // arbitrarily far apart, so the training-era deadlock timeout
            // must not fire while everyone is healthily idle
            "idle" => port.set_recv_timeout(crate::serve::IDLE_TIMEOUT),
            "mat" => {
                let d: Vec<usize> = parse_dims(args, 3)?;
                let (m, k, n) = (d[0], d[1], d[2]);
                let seed_a = rng.gen_seed();
                let seed_b = rng.gen_seed();
                let (ua, va) = expand_uv(seed_a, m, k, n);
                let tb = expand_triple_shares(seed_b, m, k, n);
                let u = ua.add(&tb.u);
                let v = va.add(&tb.v);
                let w_a = u.matmul(&v).sub(&tb.w);
                port.send_tagged_phase(a, tag, Payload::Seed(seed_a), Phase::Offline)?;
                port.send_tagged_phase(a, tag, Payload::U64s(w_a.data), Phase::Offline)?;
                port.send_tagged_phase(b, tag, Payload::Seed(seed_b), Phase::Offline)?;
            }
            "elem" => {
                let d = parse_dims(args, 1)?;
                let len = d[0];
                let seed_a = rng.gen_seed();
                let seed_b = rng.gen_seed();
                let (ua, va) = (
                    expand_vec(seed_a, NONCE_ELEM_U, len),
                    expand_vec(seed_a, NONCE_ELEM_V, len),
                );
                let (ub, vb, wb) = (
                    expand_vec(seed_b, NONCE_ELEM_U, len),
                    expand_vec(seed_b, NONCE_ELEM_V, len),
                    expand_vec(seed_b, NONCE_ELEM_W, len),
                );
                let w_a: Vec<u64> = (0..len)
                    .map(|i| {
                        let u = ua[i].wrapping_add(ub[i]);
                        let v = va[i].wrapping_add(vb[i]);
                        u.wrapping_mul(v).wrapping_sub(wb[i])
                    })
                    .collect();
                port.send_tagged_phase(a, tag, Payload::Seed(seed_a), Phase::Offline)?;
                port.send_tagged_phase(a, tag, Payload::U64s(w_a), Phase::Offline)?;
                port.send_tagged_phase(b, tag, Payload::Seed(seed_b), Phase::Offline)?;
            }
            "bool" => {
                let d = parse_dims(args, 1)?;
                let lanes = d[0];
                let words = super::boolean::drelu_triple_words(lanes);
                let wpl = words_for(lanes);
                let seed_a = rng.gen_seed();
                let seed_b = rng.gen_seed();

                // edaBit: r = ra + rb; bits(r) = bits_a ^ bits_b
                let ra = expand_vec(seed_a, NONCE_BOOL_RA, lanes);
                let bund_b = expand_bool_b(seed_b, lanes, words);
                let r: Vec<u64> = ra
                    .iter()
                    .zip(&bund_b.eda.r_arith)
                    .map(|(x, y)| x.wrapping_add(*y))
                    .collect();
                let bits = BitMat::decompose(&r);
                let eda_bits_a = bits.xor(&bund_b.eda.r_bits);

                // AND triples: a = aa ^ ab, b = ba ^ bb, c = a&b; c_a = c ^ c_b
                let aa = expand_vec(seed_a, NONCE_BOOL_TA, words);
                let ba = expand_vec(seed_a, NONCE_BOOL_TB, words);
                let c_a: Vec<u64> = (0..words)
                    .map(|i| {
                        let av = aa[i] ^ bund_b.bank.a[i];
                        let bv = ba[i] ^ bund_b.bank.b[i];
                        (av & bv) ^ bund_b.bank.c[i]
                    })
                    .collect();

                // daBits: fresh bits; B side fully from seed, A explicit
                let mut dab_bits = vec![0u64; wpl];
                rng.fill_u64(&mut dab_bits);
                if lanes % 64 != 0 {
                    dab_bits[wpl - 1] &= (1u64 << (lanes % 64)) - 1;
                }
                let dab_arith_a: Vec<u64> = (0..lanes)
                    .map(|l| {
                        ((dab_bits[l / 64] >> (l % 64)) & 1)
                            .wrapping_sub(bund_b.dab.arith[l])
                    })
                    .collect();
                let dab_bits_a: Vec<u64> = dab_bits
                    .iter()
                    .zip(&bund_b.dab.bits)
                    .map(|(x, y)| x ^ y)
                    .collect();

                port.send_tagged_phase(a, tag, Payload::Seed(seed_a), Phase::Offline)?;
                port.send_tagged_phase(a, tag, Payload::Bits(eda_bits_a.words), Phase::Offline)?;
                port.send_tagged_phase(a, tag, Payload::Bits(c_a), Phase::Offline)?;
                port.send_tagged_phase(a, tag, Payload::U64s(dab_arith_a), Phase::Offline)?;
                port.send_tagged_phase(a, tag, Payload::Bits(dab_bits_a), Phase::Offline)?;
                port.send_tagged_phase(b, tag, Payload::Seed(seed_b), Phase::Offline)?;
            }
            other => {
                return Err(Error::Protocol(format!("dealer: unknown request {other:?}")));
            }
        }
    }
}

fn parse_dims(s: &str, n: usize) -> Result<Vec<usize>> {
    let v: Vec<usize> = s.split(',').filter_map(|t| t.trim().parse().ok()).collect();
    if v.len() != n {
        return Err(Error::Protocol(format!("dealer: bad dims {s:?} (want {n})")));
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// Party-side
// ---------------------------------------------------------------------------

/// A-side expansion of a matrix triple from its two reply payloads (the
/// expensive part — exposed so pipelined parties can expand material the
/// moment it is polled off the wire, inside their prefetch window).
pub fn mat_triple_from_parts(
    seed: [u8; 32],
    w: Vec<u64>,
    m: usize,
    k: usize,
    n: usize,
) -> MatTriple {
    let _sp = crate::obs::span("crypto_triple_expand_seconds");
    let (u, v) = expand_uv(seed, m, k, n);
    MatTriple { u, v, w: RingMat::from_data(m, n, w) }
}

/// A-side expansion of an elementwise triple from its reply payloads.
pub fn elem_triple_from_parts(seed: [u8; 32], w: Vec<u64>, len: usize) -> ElemTriple {
    let _sp = crate::obs::span("crypto_triple_expand_seconds");
    ElemTriple {
        u: expand_vec(seed, NONCE_ELEM_U, len),
        v: expand_vec(seed, NONCE_ELEM_V, len),
        w,
    }
}

/// A-side expansion of a boolean bundle from its five reply payloads.
pub fn bool_bundle_from_parts(
    seed: [u8; 32],
    eda_bits: Vec<u64>,
    c: Vec<u64>,
    dab_arith: Vec<u64>,
    dab_bits: Vec<u64>,
    lanes: usize,
) -> Result<BoolBundle> {
    let _sp = crate::obs::span("crypto_triple_expand_seconds");
    let words = super::boolean::drelu_triple_words(lanes);
    let wpl = words_for(lanes);
    if eda_bits.len() != 64 * wpl || c.len() != words || dab_arith.len() != lanes {
        return Err(Error::Protocol("bool bundle size mismatch".into()));
    }
    Ok(BoolBundle {
        eda: EdaBits {
            r_arith: expand_vec(seed, NONCE_BOOL_RA, lanes),
            r_bits: BitMat { lanes, wpl, words: eda_bits },
        },
        bank: TripleBank::new(
            expand_vec(seed, NONCE_BOOL_TA, words),
            expand_vec(seed, NONCE_BOOL_TB, words),
            c,
        ),
        dab: DaBits { arith: dab_arith, bits: dab_bits },
    })
}

/// A-side (role 0): receive one matrix triple previously requested with
/// [`send_request_tagged`] (`Req::Mat`) under `tag`.
pub fn recv_mat_triple_a(
    port: &mut dyn Channel,
    dealer: PartyId,
    m: usize,
    k: usize,
    n: usize,
    tag: u64,
) -> Result<MatTriple> {
    let seed = port.recv_tagged(dealer, tag)?.into_seed()?;
    let w = port.recv_tagged(dealer, tag)?.into_u64s()?;
    Ok(mat_triple_from_parts(seed, w, m, k, n))
}

/// A-side (role 0): request + receive one matrix triple (lock-step path).
pub fn request_mat_triple(
    port: &mut dyn Channel,
    dealer: PartyId,
    m: usize,
    k: usize,
    n: usize,
) -> Result<MatTriple> {
    send_request_tagged(port, dealer, Req::Mat(m, k, n), NO_TAG)?;
    recv_mat_triple_a(port, dealer, m, k, n, NO_TAG)
}

/// B-side (role 1): receive the matching matrix triple under `tag`.
pub fn recv_mat_triple_b_tagged(
    port: &mut dyn Channel,
    dealer: PartyId,
    m: usize,
    k: usize,
    n: usize,
    tag: u64,
) -> Result<MatTriple> {
    let seed = port.recv_tagged(dealer, tag)?.into_seed()?;
    Ok(expand_triple_shares(seed, m, k, n))
}

/// B-side (role 1): receive the matching matrix triple (lock-step path).
pub fn recv_mat_triple_b(
    port: &mut dyn Channel,
    dealer: PartyId,
    m: usize,
    k: usize,
    n: usize,
) -> Result<MatTriple> {
    recv_mat_triple_b_tagged(port, dealer, m, k, n, NO_TAG)
}

/// A-side: receive an elementwise triple requested under `tag`.
pub fn recv_elem_triple_a(
    port: &mut dyn Channel,
    dealer: PartyId,
    len: usize,
    tag: u64,
) -> Result<ElemTriple> {
    let seed = port.recv_tagged(dealer, tag)?.into_seed()?;
    let w = port.recv_tagged(dealer, tag)?.into_u64s()?;
    Ok(elem_triple_from_parts(seed, w, len))
}

/// A-side: request + receive an elementwise triple (lock-step path).
pub fn request_elem_triple(
    port: &mut dyn Channel,
    dealer: PartyId,
    len: usize,
) -> Result<ElemTriple> {
    send_request_tagged(port, dealer, Req::Elem(len), NO_TAG)?;
    recv_elem_triple_a(port, dealer, len, NO_TAG)
}

/// B-side: receive the matching elementwise triple under `tag`.
pub fn recv_elem_triple_b_tagged(
    port: &mut dyn Channel,
    dealer: PartyId,
    len: usize,
    tag: u64,
) -> Result<ElemTriple> {
    let seed = port.recv_tagged(dealer, tag)?.into_seed()?;
    Ok(ElemTriple {
        u: expand_vec(seed, NONCE_ELEM_U, len),
        v: expand_vec(seed, NONCE_ELEM_V, len),
        w: expand_vec(seed, NONCE_ELEM_W, len),
    })
}

/// B-side: receive the matching elementwise triple (lock-step path).
pub fn recv_elem_triple_b(
    port: &mut dyn Channel,
    dealer: PartyId,
    len: usize,
) -> Result<ElemTriple> {
    recv_elem_triple_b_tagged(port, dealer, len, NO_TAG)
}

/// A-side: receive a boolean bundle (edaBit + AND bank + daBits) requested
/// under `tag`, sized for one DReLU batch over `lanes` values.
pub fn recv_bool_bundle_a(
    port: &mut dyn Channel,
    dealer: PartyId,
    lanes: usize,
    tag: u64,
) -> Result<BoolBundle> {
    let seed = port.recv_tagged(dealer, tag)?.into_seed()?;
    let eda_bits = port.recv_tagged(dealer, tag)?.into_bits()?;
    let c = port.recv_tagged(dealer, tag)?.into_bits()?;
    let dab_arith = port.recv_tagged(dealer, tag)?.into_u64s()?;
    let dab_bits = port.recv_tagged(dealer, tag)?.into_bits()?;
    bool_bundle_from_parts(seed, eda_bits, c, dab_arith, dab_bits, lanes)
}

/// A-side: request + receive a boolean bundle (lock-step path).
pub fn request_bool_bundle(
    port: &mut dyn Channel,
    dealer: PartyId,
    lanes: usize,
) -> Result<BoolBundle> {
    send_request_tagged(port, dealer, Req::Bool(lanes), NO_TAG)?;
    recv_bool_bundle_a(port, dealer, lanes, NO_TAG)
}

/// B-side: expand the matching boolean bundle from the dealer seed
/// received under `tag`.
pub fn recv_bool_bundle_b_tagged(
    port: &mut dyn Channel,
    dealer: PartyId,
    lanes: usize,
    tag: u64,
) -> Result<BoolBundle> {
    let seed = port.recv_tagged(dealer, tag)?.into_seed()?;
    let words = super::boolean::drelu_triple_words(lanes);
    Ok(expand_bool_b(seed, lanes, words))
}

/// B-side: expand the matching boolean bundle (lock-step path).
pub fn recv_bool_bundle_b(
    port: &mut dyn Channel,
    dealer: PartyId,
    lanes: usize,
) -> Result<BoolBundle> {
    recv_bool_bundle_b_tagged(port, dealer, lanes, NO_TAG)
}

/// Expand party B's full boolean bundle from a seed.
fn expand_bool_b(seed: [u8; 32], lanes: usize, words: usize) -> BoolBundle {
    let wpl = words_for(lanes);
    let mut bits_rng = ChaChaRng::from_seed(seed, NONCE_BOOL_RA ^ 0xF0F0);
    let mut eda_words = vec![0u64; 64 * wpl];
    bits_rng.fill_u64(&mut eda_words);
    mask_tail(&mut eda_words, wpl, lanes);
    let mut dab_rng = ChaChaRng::from_seed(seed, NONCE_BOOL_RA ^ 0xDAB1);
    let mut dab_arith = vec![0u64; lanes];
    dab_rng.fill_u64(&mut dab_arith);
    let mut dab_bits = vec![0u64; wpl];
    dab_rng.fill_u64(&mut dab_bits);
    mask_tail(&mut dab_bits, wpl, lanes);
    BoolBundle {
        eda: EdaBits {
            r_arith: expand_vec(seed, NONCE_BOOL_RA, lanes),
            r_bits: BitMat { lanes, wpl, words: eda_words },
        },
        bank: TripleBank::new(
            expand_vec(seed, NONCE_BOOL_TA, words),
            expand_vec(seed, NONCE_BOOL_TB, words),
            expand_vec(seed, NONCE_BOOL_TB ^ 0xC0C0, words),
        ),
        dab: DaBits { arith: dab_arith, bits: dab_bits },
    }
}

fn mask_tail(words: &mut [u64], wpl: usize, lanes: usize) {
    if lanes % 64 != 0 {
        let mask = (1u64 << (lanes % 64)) - 1;
        let rows = words.len() / wpl;
        for r in 0..rows {
            words[r * wpl + wpl - 1] &= mask;
        }
    }
}

/// Stop the dealer (protocol teardown).
pub fn stop(port: &mut dyn Channel, dealer: PartyId) -> Result<()> {
    port.send_phase(dealer, Payload::Control("stop".into()), Phase::Offline)
}

/// Tell the dealer the requester entered its serving phase (requests may
/// now be arbitrarily far apart; see [`serve`]'s wire protocol).
pub fn idle(port: &mut dyn Channel, dealer: PartyId) -> Result<()> {
    port.send_phase(dealer, Payload::Control("idle".into()), Phase::Offline)
}

// ---------------------------------------------------------------------------
// A-side opportunistic feed
// ---------------------------------------------------------------------------

/// Expanded A-side dealer material, ready for consumption.
pub enum Material {
    /// A matrix triple (`Req::Mat`).
    Mat(MatTriple),
    /// An elementwise triple (`Req::Elem`).
    Elem(ElemTriple),
    /// A boolean bundle (`Req::Bool`).
    Bool(BoolBundle),
}

/// A-side dealer feed with **opportunistic expansion**: requests are fired
/// from `Prefetch` ([`Self::request`]); [`Self::pump`] then polls the
/// dealer link without blocking (`try_recv_tagged`) and expands whatever
/// replies have already landed — so the PRG expansion of `(U, V)` shares
/// and boolean bundles happens inside the prefetch window instead of
/// blocking in `Submit`/`Complete` on the critical path. [`Self::next`]
/// falls back to blocking receives for anything not pumped yet.
///
/// Correctness leans on two FIFO facts: A fires requests in consumption
/// order (the batch script), and the dealer answers its single request
/// stream in arrival order — so the global reply stream matches
/// `outstanding` front-to-back, and per-tag `recv_tagged` order equals
/// per-request reply order. Expansion is pure (seeded PRG), so *when* it
/// runs cannot change the transcript — guarded by the
/// `*_depths_are_transcript_equal` tests of every trainer that uses it
/// (SecureML since PR 3; SPNN-SS's A role since the serving PR).
pub struct DealerFeed {
    dealer: PartyId,
    /// Requests awaiting full reply, in fire order, with parts collected
    /// so far.
    outstanding: VecDeque<(u64, Req, Vec<Payload>)>,
    /// Expanded material per batch tag, in request order.
    ready: HashMap<u64, VecDeque<Material>>,
}

impl DealerFeed {
    /// An empty feed talking to the dealer at party id `dealer`.
    pub fn new(dealer: PartyId) -> Self {
        DealerFeed { dealer, outstanding: VecDeque::new(), ready: HashMap::new() }
    }

    fn parts_needed(req: &Req) -> usize {
        match req {
            Req::Mat(..) | Req::Elem(_) => 2, // Seed + correction
            Req::Bool(_) => 5,                // Seed + 4 explicit payloads
        }
    }

    fn expand(req: Req, mut parts: Vec<Payload>) -> Result<Material> {
        let mut rest = parts.split_off(1);
        let seed = parts.pop().expect("seed part").into_seed()?;
        Ok(match req {
            Req::Mat(m, k, n) => Material::Mat(mat_triple_from_parts(
                seed,
                rest.pop().expect("w part").into_u64s()?,
                m,
                k,
                n,
            )),
            Req::Elem(len) => Material::Elem(elem_triple_from_parts(
                seed,
                rest.pop().expect("w part").into_u64s()?,
                len,
            )),
            Req::Bool(lanes) => {
                let dab_bits = rest.pop().expect("dab bits").into_bits()?;
                let dab_arith = rest.pop().expect("dab arith").into_u64s()?;
                let c = rest.pop().expect("and c").into_bits()?;
                let eda_bits = rest.pop().expect("eda bits").into_bits()?;
                Material::Bool(bool_bundle_from_parts(
                    seed, eda_bits, c, dab_arith, dab_bits, lanes,
                )?)
            }
        })
    }

    /// Fire one tagged request (prefetch stage).
    pub fn request(&mut self, p: &mut dyn Channel, req: Req, tag: u64) -> Result<()> {
        send_request_tagged(p, self.dealer, req, tag)?;
        self.outstanding.push_back((tag, req, Vec::new()));
        Ok(())
    }

    /// Non-blocking drain: pull every already-delivered reply off the
    /// dealer link and expand completed requests, front to back.
    pub fn pump(&mut self, p: &mut dyn Channel) -> Result<()> {
        while let Some(front) = self.outstanding.front_mut() {
            while front.2.len() < Self::parts_needed(&front.1) {
                match p.try_recv_tagged(self.dealer, front.0)? {
                    Some(payload) => front.2.push(payload),
                    None => return Ok(()), // nothing more on the wire yet
                }
            }
            let (tag, req, parts) = self.outstanding.pop_front().expect("front exists");
            self.ready.entry(tag).or_default().push_back(Self::expand(req, parts)?);
        }
        Ok(())
    }

    /// Next material for `tag`, blocking on the wire only for whatever the
    /// prefetch-window pumping did not get to.
    pub fn next(&mut self, p: &mut dyn Channel, tag: u64) -> Result<Material> {
        loop {
            // take the tag's queue out entirely: a drained queue must not
            // linger in the map (serve sessions run an unbounded monotonic
            // tag stream — leftover empties would leak one entry per batch)
            if let Some(mut q) = self.ready.remove(&tag) {
                if let Some(m) = q.pop_front() {
                    if !q.is_empty() {
                        self.ready.insert(tag, q);
                    }
                    return Ok(m);
                }
            }
            let front = self.outstanding.front_mut().ok_or_else(|| {
                Error::Protocol(format!(
                    "dealer feed empty while awaiting material for tag {tag}"
                ))
            })?;
            while front.2.len() < Self::parts_needed(&front.1) {
                front.2.push(p.recv_tagged(self.dealer, front.0)?);
            }
            let (t, req, parts) = self.outstanding.pop_front().expect("front exists");
            self.ready.entry(t).or_default().push_back(Self::expand(req, parts)?);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{full_mesh, LinkSpec, NetPort};
    use crate::rng::Pcg64;
    use crate::smpc::boolean::drelu_arith;
    use crate::smpc::matmul::{beaver_matmul, beaver_mul_elem, native_mm};
    use crate::smpc::share::{reconstruct2, share2};

    /// Spin up A(0), B(1), Dealer(2); run fa/fb; dealer serves until stop.
    fn run_with_dealer<FA, FB, TA: Send + 'static, TB: Send + 'static>(
        fa: FA,
        fb: FB,
    ) -> (TA, TB, usize)
    where
        FA: FnOnce(&mut NetPort) -> TA + Send + 'static,
        FB: FnOnce(&mut NetPort) -> TB + Send + 'static,
    {
        let (mut ports, stats) = full_mesh(&["A", "B", "D"], LinkSpec::lan());
        let mut pd = ports.pop().unwrap();
        let mut pb = ports.pop().unwrap();
        let mut pa = ports.pop().unwrap();
        let hd = std::thread::spawn(move || serve(&mut pd, 0, 1, 99).unwrap());
        let hb = std::thread::spawn(move || fb(&mut pb));
        let ra = fa(&mut pa);
        stop(&mut pa, 2).unwrap();
        let rb = hb.join().expect("B panicked");
        hd.join().expect("dealer panicked");
        let off = stats.bytes_phase(crate::netsim::Phase::Offline);
        (ra, rb, off)
    }

    #[test]
    fn networked_mat_triple_works_end_to_end() {
        let mut rng = Pcg64::seed_from_u64(1);
        let x = RingMat::random(&mut rng, 5, 3);
        let y = RingMat::random(&mut rng, 3, 4);
        let mut crng = crate::rng::ChaChaRng::seed_from_u64(2);
        let (x0, x1) = share2(&mut crng, &x);
        let (y0, y1) = share2(&mut crng, &y);
        let want = x.matmul(&y);
        let (z0, z1, off_bytes) = run_with_dealer(
            move |p| {
                let t = request_mat_triple(p, 2, 5, 3, 4).unwrap();
                beaver_matmul(p, 1, 0, &x0, &y0, &t, &native_mm).unwrap()
            },
            move |p| {
                let t = recv_mat_triple_b(p, 2, 5, 3, 4).unwrap();
                beaver_matmul(p, 0, 1, &x1, &y1, &t, &native_mm).unwrap()
            },
        );
        assert_eq!(reconstruct2(&z0, &z1), want);
        assert!(off_bytes > 0, "offline traffic not accounted");
    }

    #[test]
    fn networked_elem_triple() {
        let mut rng = Pcg64::seed_from_u64(3);
        let x = RingMat::random(&mut rng, 1, 9);
        let y = RingMat::random(&mut rng, 1, 9);
        let mut crng = crate::rng::ChaChaRng::seed_from_u64(4);
        let (x0, x1) = share2(&mut crng, &x);
        let (y0, y1) = share2(&mut crng, &y);
        let (xc, yc) = (x.clone(), y.clone());
        let (z0, z1, _) = run_with_dealer(
            move |p| {
                let t = request_elem_triple(p, 2, 9).unwrap();
                beaver_mul_elem(p, 1, 0, &x0.data, &y0.data, &t).unwrap()
            },
            move |p| {
                let t = recv_elem_triple_b(p, 2, 9).unwrap();
                beaver_mul_elem(p, 0, 1, &x1.data, &y1.data, &t).unwrap()
            },
        );
        for i in 0..9 {
            assert_eq!(z0[i].wrapping_add(z1[i]), xc.data[i].wrapping_mul(yc.data[i]));
        }
    }

    #[test]
    fn tagged_prefetch_streams_ahead_of_demand() {
        // A fires the requests for two "batches" up front (prefetch), then
        // consumes the replies in REVERSE order; the reorder buffers must
        // hand every party the right material for each tag.
        let (ta, tb, _) = run_with_dealer(
            move |p| {
                send_request_tagged(p, 2, Req::Mat(5, 3, 4), 0).unwrap();
                send_request_tagged(p, 2, Req::Mat(4, 2, 2), 1).unwrap();
                let t1 = recv_mat_triple_a(p, 2, 4, 2, 2, 1).unwrap();
                let t0 = recv_mat_triple_a(p, 2, 5, 3, 4, 0).unwrap();
                (t0, t1)
            },
            move |p| {
                let t1 = recv_mat_triple_b_tagged(p, 2, 4, 2, 2, 1).unwrap();
                let t0 = recv_mat_triple_b_tagged(p, 2, 5, 3, 4, 0).unwrap();
                (t0, t1)
            },
        );
        // each reconstructed triple must satisfy W = U · V
        for (a, b) in [(&ta.0, &tb.0), (&ta.1, &tb.1)] {
            let u = reconstruct2(&a.u, &b.u);
            let v = reconstruct2(&a.v, &b.v);
            let w = reconstruct2(&a.w, &b.w);
            assert_eq!(u.matmul(&v), w, "tagged triple is inconsistent");
        }
        assert_ne!(ta.0.u.shape(), ta.1.u.shape());
    }

    #[test]
    fn networked_bool_bundle_drives_drelu() {
        let lanes = 80usize;
        let mut rng = Pcg64::seed_from_u64(5);
        let x: Vec<u64> = (0..lanes)
            .map(|i| if i % 2 == 0 { rng.next_u64() >> 1 } else { rng.next_u64() | (1 << 63) })
            .collect();
        let xs1: Vec<u64> = (0..lanes).map(|_| rng.next_u64()).collect();
        let xs0: Vec<u64> = x.iter().zip(&xs1).map(|(v, s)| v.wrapping_sub(*s)).collect();
        let xc = x.clone();
        let (d0, d1, _) = run_with_dealer(
            move |p| {
                let mut bb = request_bool_bundle(p, 2, lanes).unwrap();
                drelu_arith(p, 1, 0, &xs0, &bb.eda, &mut bb.bank, &bb.dab).unwrap()
            },
            move |p| {
                let mut bb = recv_bool_bundle_b(p, 2, lanes).unwrap();
                drelu_arith(p, 0, 1, &xs1, &bb.eda, &mut bb.bank, &bb.dab).unwrap()
            },
        );
        for i in 0..lanes {
            let bit = d0[i].wrapping_add(d1[i]);
            assert_eq!(bit, ((xc[i] as i64) >= 0) as u64, "lane {i}");
        }
    }
}
