//! Boolean (XOR) sharing and the secure-comparison stack.
//!
//! Used by the SecureML baseline for its non-linearities: ReLU's derivative
//! (DReLU) and the piecewise sigmoid both reduce to *most-significant-bit
//! extraction* of a shared value. SPNN itself deliberately avoids all of
//! this (its server computes activations in plaintext) — reproducing the
//! cost difference is exactly the point of the baseline.
//!
//! Protocol (trusted-dealer GMW, bit-sliced 64 lanes per word):
//!
//! 1. **Open** `c = x + r` with a dealer edaBit `r` (arith shares of `r` +
//!    XOR shares of `r`'s bits). `c` is uniform, reveals nothing.
//! 2. **Borrow circuit**: `msb(x) = msb(c - r)`, computed by a Kogge–Stone
//!    borrow-lookahead over the shared bits of `r` and the public bits of
//!    `c`: generate `g = ¬c ∧ r` and propagate `p = ¬(c ⊕ r)` are *local*
//!    (one operand public); the `log2(64) = 6` prefix levels each cost one
//!    batched secure-AND round.
//! 3. **B2A** via dealer daBits to get an arithmetic share of the bit.

use crate::netsim::{PartyId, Payload};
use crate::rng::{ChaChaRng, Rng64};
use crate::transport::Channel;
use crate::Result;

/// Words needed to pack `lanes` bits.
#[inline]
pub fn words_for(lanes: usize) -> usize {
    lanes.div_ceil(64)
}

/// Bit-sliced matrix: 64 bit-positions x `lanes` elements, each position a
/// packed word row. `words[pos * wpl + w]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitMat {
    pub lanes: usize,
    pub wpl: usize,
    pub words: Vec<u64>,
}

impl BitMat {
    pub fn zeros(lanes: usize) -> Self {
        let wpl = words_for(lanes);
        BitMat { lanes, wpl, words: vec![0; 64 * wpl] }
    }

    /// Bit-decompose `vals` (lane-major) into slices.
    pub fn decompose(vals: &[u64]) -> Self {
        let lanes = vals.len();
        let mut m = Self::zeros(lanes);
        for (lane, &v) in vals.iter().enumerate() {
            let (w, off) = (lane / 64, lane % 64);
            for pos in 0..64 {
                if (v >> pos) & 1 == 1 {
                    m.words[pos * m.wpl + w] |= 1u64 << off;
                }
            }
        }
        m
    }

    /// Recompose to values (inverse of [`Self::decompose`]).
    pub fn recompose(&self) -> Vec<u64> {
        let mut out = vec![0u64; self.lanes];
        for pos in 0..64 {
            let row = &self.words[pos * self.wpl..(pos + 1) * self.wpl];
            for (lane, o) in out.iter_mut().enumerate() {
                let (w, off) = (lane / 64, lane % 64);
                *o |= ((row[w] >> off) & 1) << pos;
            }
        }
        out
    }

    /// Packed word row of one bit position.
    pub fn row(&self, pos: usize) -> &[u64] {
        &self.words[pos * self.wpl..(pos + 1) * self.wpl]
    }

    pub fn xor(&self, other: &Self) -> Self {
        assert_eq!(self.lanes, other.lanes);
        let words = self.words.iter().zip(&other.words).map(|(a, b)| a ^ b).collect();
        BitMat { lanes: self.lanes, wpl: self.wpl, words }
    }

    /// Random bit matrix (XOR-share material).
    pub fn random<R: Rng64>(rng: &mut R, lanes: usize) -> Self {
        let wpl = words_for(lanes);
        let mut words = vec![0u64; 64 * wpl];
        rng.fill_u64(&mut words);
        // mask tail bits of the last word so lanes stay canonical
        let tail = lanes % 64;
        if tail != 0 {
            let mask = (1u64 << tail) - 1;
            for pos in 0..64 {
                words[pos * wpl + wpl - 1] &= mask;
            }
        }
        BitMat { lanes, wpl, words }
    }
}

// ---------------------------------------------------------------------------
// Dealer material
// ---------------------------------------------------------------------------

/// Bank of AND-triple words (XOR shares of `a, b, c = a & b`), consumed
/// sequentially by the comparison circuit.
#[derive(Clone, Debug, Default)]
pub struct TripleBank {
    pub a: Vec<u64>,
    pub b: Vec<u64>,
    pub c: Vec<u64>,
    cursor: usize,
}

impl TripleBank {
    pub fn new(a: Vec<u64>, b: Vec<u64>, c: Vec<u64>) -> Self {
        assert!(a.len() == b.len() && b.len() == c.len());
        TripleBank { a, b, c, cursor: 0 }
    }

    pub fn take(&mut self, n: usize) -> (&[u64], &[u64], &[u64]) {
        assert!(
            self.cursor + n <= self.a.len(),
            "TripleBank exhausted: need {n}, have {}",
            self.a.len() - self.cursor
        );
        let s = self.cursor;
        self.cursor += n;
        (&self.a[s..s + n], &self.b[s..s + n], &self.c[s..s + n])
    }

    pub fn remaining(&self) -> usize {
        self.a.len() - self.cursor
    }

    /// AND-triple words one 64-lane comparison batch consumes.
    pub fn words_per_compare(wpl: usize) -> usize {
        // Kogge–Stone levels d ∈ {1,2,4,8,16,32}: (63-d+1) positions... we
        // combine positions i ∈ [d, 64) — (64-d) nodes, 2 ANDs each.
        let positions: usize = [1usize, 2, 4, 8, 16, 32].iter().map(|d| 64 - d).sum();
        2 * positions * wpl
    }
}

/// edaBit: shares of a uniform `r` in both representations.
#[derive(Clone, Debug)]
pub struct EdaBits {
    /// Additive share of `r` (per lane).
    pub r_arith: Vec<u64>,
    /// XOR shares of `r`'s bit-decomposition.
    pub r_bits: BitMat,
}

/// daBit vector: shares of uniform bits in both representations.
#[derive(Clone, Debug)]
pub struct DaBits {
    /// Additive share of each bit's 0/1 value (per lane).
    pub arith: Vec<u64>,
    /// XOR share of the bits (packed words).
    pub bits: Vec<u64>,
}

/// In-memory dealer for the boolean stack (the network dealer in
/// `smpc::dealer` wraps these with PRG compression + byte accounting).
pub struct BoolDealer {
    rng: ChaChaRng,
}

impl BoolDealer {
    pub fn new(seed: u64) -> Self {
        BoolDealer { rng: ChaChaRng::seed_from_u64(seed) }
    }

    /// Deal `n` AND-triple words to two parties.
    pub fn and_triples(&mut self, n: usize) -> (TripleBank, TripleBank) {
        let mut a = vec![0u64; n];
        let mut b = vec![0u64; n];
        self.rng.fill_u64(&mut a);
        self.rng.fill_u64(&mut b);
        let c: Vec<u64> = a.iter().zip(&b).map(|(x, y)| x & y).collect();
        let mut a1 = vec![0u64; n];
        let mut b1 = vec![0u64; n];
        let mut c1 = vec![0u64; n];
        self.rng.fill_u64(&mut a1);
        self.rng.fill_u64(&mut b1);
        self.rng.fill_u64(&mut c1);
        let a0: Vec<u64> = a.iter().zip(&a1).map(|(x, s)| x ^ s).collect();
        let b0: Vec<u64> = b.iter().zip(&b1).map(|(x, s)| x ^ s).collect();
        let c0: Vec<u64> = c.iter().zip(&c1).map(|(x, s)| x ^ s).collect();
        (
            TripleBank { a: a0, b: b0, c: c0, cursor: 0 },
            TripleBank { a: a1, b: b1, c: c1, cursor: 0 },
        )
    }

    /// Deal edaBits for `lanes` values.
    pub fn edabits(&mut self, lanes: usize) -> (EdaBits, EdaBits) {
        let mut r = vec![0u64; lanes];
        self.rng.fill_u64(&mut r);
        let bits = BitMat::decompose(&r);
        // arithmetic shares
        let mut r1 = vec![0u64; lanes];
        self.rng.fill_u64(&mut r1);
        let r0: Vec<u64> = r.iter().zip(&r1).map(|(x, s)| x.wrapping_sub(*s)).collect();
        // boolean shares
        let b1 = BitMat::random(&mut self.rng, lanes);
        let b0 = bits.xor(&b1);
        (
            EdaBits { r_arith: r0, r_bits: b0 },
            EdaBits { r_arith: r1, r_bits: b1 },
        )
    }

    /// Deal daBits for `lanes` bits.
    pub fn dabits(&mut self, lanes: usize) -> (DaBits, DaBits) {
        let wpl = words_for(lanes);
        let mut packed = vec![0u64; wpl];
        self.rng.fill_u64(&mut packed);
        if lanes % 64 != 0 {
            packed[wpl - 1] &= (1u64 << (lanes % 64)) - 1;
        }
        // arith shares of each bit value
        let mut arith1 = vec![0u64; lanes];
        self.rng.fill_u64(&mut arith1);
        let arith0: Vec<u64> = (0..lanes)
            .map(|l| ((packed[l / 64] >> (l % 64)) & 1).wrapping_sub(arith1[l]))
            .collect();
        // bool shares
        let mut bits1 = vec![0u64; wpl];
        self.rng.fill_u64(&mut bits1);
        let bits0: Vec<u64> = packed.iter().zip(&bits1).map(|(x, s)| x ^ s).collect();
        (
            DaBits { arith: arith0, bits: bits0 },
            DaBits { arith: arith1, bits: bits1 },
        )
    }
}

// ---------------------------------------------------------------------------
// Online protocols
// ---------------------------------------------------------------------------

/// Batched secure AND of packed bit words (GMW + Beaver-style triples).
/// One round: open `d = x ⊕ a`, `e = y ⊕ b`.
pub fn secure_and(
    port: &mut dyn Channel,
    peer: PartyId,
    role: u8,
    x: &[u64],
    y: &[u64],
    bank: &mut TripleBank,
) -> Result<Vec<u64>> {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    let (ta, tb, tc) = {
        let (a, b, c) = bank.take(n);
        (a.to_vec(), b.to_vec(), c.to_vec())
    };
    let d_p: Vec<u64> = x.iter().zip(&ta).map(|(v, a)| v ^ a).collect();
    let e_p: Vec<u64> = y.iter().zip(&tb).map(|(v, b)| v ^ b).collect();
    let mut buf = d_p.clone();
    buf.extend_from_slice(&e_p);
    port.send(peer, Payload::Bits(buf))?;
    let theirs = port.recv(peer)?.into_bits()?;
    if theirs.len() != 2 * n {
        return Err(crate::Error::Protocol("secure_and size".into()));
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let d = d_p[i] ^ theirs[i];
        let e = e_p[i] ^ theirs[n + i];
        let mut z = (d & tb[i]) ^ (ta[i] & e) ^ tc[i];
        if role == 0 {
            z ^= d & e;
        }
        out.push(z);
    }
    Ok(out)
}

/// MSB of `x = c - r` where `c` is public and `r`'s bits are XOR-shared.
///
/// Returns an XOR share of `msb(x)` packed into `wpl` words.
/// Borrow recurrence (`g` = generate, `p` = propagate, mutually exclusive,
/// so OR == XOR): `b_{i+1} = g_i ⊕ (p_i ∧ b_i)`; Kogge–Stone prefix:
/// `(g,p) ∘ (g',p') = (g ⊕ (p ∧ g'), p ∧ p')`.
pub fn shared_msb_of_diff(
    port: &mut dyn Channel,
    peer: PartyId,
    role: u8,
    c_pub: &[u64],
    r_bits: &BitMat,
    bank: &mut TripleBank,
) -> Result<Vec<u64>> {
    let lanes = c_pub.len();
    assert_eq!(lanes, r_bits.lanes);
    let wpl = r_bits.wpl;
    let c_bits = BitMat::decompose(c_pub);

    // local generate / propagate per bit position
    // g = (¬c) ∧ r      (public ∧ shared: each party ANDs its share)
    // p = ¬(c ⊕ r) = ¬c ⊕ r  (public ⊕ shared: party 0 applies the flip)
    let mut g = vec![0u64; 64 * wpl];
    let mut p = vec![0u64; 64 * wpl];
    for pos in 0..64 {
        for w in 0..wpl {
            let idx = pos * wpl + w;
            let notc = !c_bits.words[idx];
            g[idx] = notc & r_bits.words[idx];
            p[idx] = if role == 0 { notc ^ r_bits.words[idx] } else { r_bits.words[idx] };
        }
    }
    // lane-tail hygiene: keep only valid lanes in the packed words
    let tail_mask = if lanes % 64 == 0 { u64::MAX } else { (1u64 << (lanes % 64)) - 1 };
    let mask_row = |row: &mut [u64]| {
        if wpl > 0 {
            row[wpl - 1] &= tail_mask;
        }
    };
    for pos in 0..64 {
        mask_row(&mut g[pos * wpl..(pos + 1) * wpl]);
        mask_row(&mut p[pos * wpl..(pos + 1) * wpl]);
    }

    // Kogge–Stone prefix: after all levels, g[pos] = borrow out of bit pos
    for d in [1usize, 2, 4, 8, 16, 32] {
        // batch this level's two AND groups: p_i ∧ g_{i-d} and p_i ∧ p_{i-d}
        let npos = 64 - d;
        let mut lhs = Vec::with_capacity(2 * npos * wpl);
        let mut rhs = Vec::with_capacity(2 * npos * wpl);
        for i in d..64 {
            lhs.extend_from_slice(&p[i * wpl..(i + 1) * wpl]);
            rhs.extend_from_slice(&g[(i - d) * wpl..(i - d + 1) * wpl]);
        }
        for i in d..64 {
            lhs.extend_from_slice(&p[i * wpl..(i + 1) * wpl]);
            rhs.extend_from_slice(&p[(i - d) * wpl..(i - d + 1) * wpl]);
        }
        let anded = secure_and(port, peer, role, &lhs, &rhs, bank)?;
        let (pg, pp) = anded.split_at(npos * wpl);
        for (k, i) in (d..64).enumerate() {
            for w in 0..wpl {
                g[i * wpl + w] ^= pg[k * wpl + w];
                p[i * wpl + w] = pp[k * wpl + w];
            }
        }
    }

    // msb(x) = c_63 ⊕ r_63 ⊕ borrow_in(63);  borrow_in(63) = g[62]
    let mut msb = vec![0u64; wpl];
    for w in 0..wpl {
        msb[w] = r_bits.words[63 * wpl + w] ^ g[62 * wpl + w];
        if role == 0 {
            msb[w] ^= c_bits.words[63 * wpl + w];
        }
        msb[w] &= tail_mask_for(w, wpl, lanes);
    }
    Ok(msb)
}

fn tail_mask_for(w: usize, wpl: usize, lanes: usize) -> u64 {
    if w == wpl - 1 && lanes % 64 != 0 {
        (1u64 << (lanes % 64)) - 1
    } else {
        u64::MAX
    }
}

/// Convert XOR-shared bits to additive shares of 0/1 values using daBits.
/// One opening round: `t = β ⊕ b` is public; `β = t + b - 2·t·b` is local.
pub fn b2a(
    port: &mut dyn Channel,
    peer: PartyId,
    role: u8,
    bool_share: &[u64],
    dab: &DaBits,
    lanes: usize,
) -> Result<Vec<u64>> {
    let wpl = words_for(lanes);
    assert_eq!(bool_share.len(), wpl);
    let t_p: Vec<u64> = bool_share.iter().zip(&dab.bits).map(|(x, b)| x ^ b).collect();
    port.send(peer, Payload::Bits(t_p.clone()))?;
    let theirs = port.recv(peer)?.into_bits()?;
    if theirs.len() != wpl {
        return Err(crate::Error::Protocol("b2a size".into()));
    }
    let mut out = Vec::with_capacity(lanes);
    for l in 0..lanes {
        let t = ((t_p[l / 64] ^ theirs[l / 64]) >> (l % 64)) & 1;
        let b = dab.arith[l];
        // β = t + (1 - 2t)·b
        let coeff: u64 = 1u64.wrapping_sub(2u64.wrapping_mul(t));
        let mut v = coeff.wrapping_mul(b);
        if role == 0 {
            v = v.wrapping_add(t);
        }
        out.push(v);
    }
    Ok(out)
}

/// DReLU: additive shares of `[x >= 0]` for a vector of shared ring values.
///
/// Cost per 64-lane word: 1 opening + 6 AND rounds + 1 daBit opening.
pub fn drelu_arith(
    port: &mut dyn Channel,
    peer: PartyId,
    role: u8,
    x_share: &[u64],
    eda: &EdaBits,
    bank: &mut TripleBank,
    dab: &DaBits,
) -> Result<Vec<u64>> {
    let lanes = x_share.len();
    assert_eq!(lanes, eda.r_arith.len(), "edaBit lane mismatch");
    // open c = x + r
    let m_p: Vec<u64> = x_share
        .iter()
        .zip(&eda.r_arith)
        .map(|(x, r)| x.wrapping_add(*r))
        .collect();
    port.send(peer, Payload::U64s(m_p.clone()))?;
    let theirs = port.recv_u64s(peer)?;
    if theirs.len() != lanes {
        return Err(crate::Error::Protocol("drelu open size".into()));
    }
    let c: Vec<u64> = m_p.iter().zip(&theirs).map(|(a, b)| a.wrapping_add(*b)).collect();
    // msb(x) shared, then flip: drelu = ¬msb
    let mut msb = shared_msb_of_diff(port, peer, role, &c, &eda.r_bits, bank)?;
    if role == 0 {
        let wpl = words_for(lanes);
        for (w, m) in msb.iter_mut().enumerate() {
            *m ^= tail_mask_for(w, wpl, lanes);
        }
    }
    b2a(port, peer, role, &msb, dab, lanes)
}

/// Dealer material sizing for one DReLU batch of `lanes` values.
pub fn drelu_triple_words(lanes: usize) -> usize {
    TripleBank::words_per_compare(words_for(lanes))
}

/// Expand a full boolean-dealer bundle for one DReLU batch from a seed
/// (party-B-side PRG decompression; see `smpc::dealer`).
pub struct BoolBundle {
    pub eda: EdaBits,
    pub bank: TripleBank,
    pub dab: DaBits,
}

impl std::fmt::Debug for BoolBundle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BoolBundle(lanes={})", self.eda.r_arith.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{full_mesh, LinkSpec};
    use crate::rng::Pcg64;

    fn run2<F0, F1, T0: Send + 'static, T1: Send + 'static>(f0: F0, f1: F1) -> (T0, T1)
    where
        F0: FnOnce(NetPort) -> T0 + Send + 'static,
        F1: FnOnce(NetPort) -> T1 + Send + 'static,
    {
        let (mut ports, _) = full_mesh(&["P0", "P1"], LinkSpec::lan());
        let p1 = ports.pop().unwrap();
        let p0 = ports.pop().unwrap();
        let h1 = std::thread::spawn(move || f1(p1));
        let r0 = f0(p0);
        (r0, h1.join().expect("party 1 panicked"))
    }

    #[test]
    fn bitmat_decompose_recompose() {
        let mut rng = Pcg64::seed_from_u64(1);
        for lanes in [1usize, 63, 64, 65, 130] {
            let vals: Vec<u64> = (0..lanes).map(|_| rng.next_u64()).collect();
            let m = BitMat::decompose(&vals);
            assert_eq!(m.recompose(), vals, "lanes={lanes}");
        }
    }

    #[test]
    fn secure_and_matches_plaintext() {
        let mut rng = Pcg64::seed_from_u64(2);
        let x: Vec<u64> = (0..10).map(|_| rng.next_u64()).collect();
        let y: Vec<u64> = (0..10).map(|_| rng.next_u64()).collect();
        // XOR-share inputs
        let xs1: Vec<u64> = (0..10).map(|_| rng.next_u64()).collect();
        let ys1: Vec<u64> = (0..10).map(|_| rng.next_u64()).collect();
        let xs0: Vec<u64> = x.iter().zip(&xs1).map(|(v, s)| v ^ s).collect();
        let ys0: Vec<u64> = y.iter().zip(&ys1).map(|(v, s)| v ^ s).collect();
        let mut dealer = BoolDealer::new(3);
        let (mut b0, mut b1) = dealer.and_triples(10);
        let (z0, z1) = run2(
            move |mut p| secure_and(&mut p, 1, 0, &xs0, &ys0, &mut b0).unwrap(),
            move |mut p| secure_and(&mut p, 0, 1, &xs1, &ys1, &mut b1).unwrap(),
        );
        for i in 0..10 {
            assert_eq!(z0[i] ^ z1[i], x[i] & y[i], "word {i}");
        }
    }

    #[test]
    fn msb_extraction_matches_sign() {
        let lanes = 100usize;
        let mut rng = Pcg64::seed_from_u64(4);
        // mix of positive/negative (two's complement) values
        let x: Vec<u64> = (0..lanes)
            .map(|i| {
                if i % 3 == 0 {
                    rng.next_u64() | (1u64 << 63) // negative
                } else {
                    rng.next_u64() >> 1 // positive
                }
            })
            .collect();
        // arithmetic shares of x
        let xs1: Vec<u64> = (0..lanes).map(|_| rng.next_u64()).collect();
        let xs0: Vec<u64> = x.iter().zip(&xs1).map(|(v, s)| v.wrapping_sub(*s)).collect();
        let mut dealer = BoolDealer::new(5);
        let (eda0, eda1) = dealer.edabits(lanes);
        let need = drelu_triple_words(lanes);
        let (mut bank0, mut bank1) = dealer.and_triples(need);
        let (dab0, dab1) = dealer.dabits(lanes);

        let x_check = x.clone();
        let (d0, d1) = run2(
            move |mut p| drelu_arith(&mut p, 1, 0, &xs0, &eda0, &mut bank0, &dab0).unwrap(),
            move |mut p| drelu_arith(&mut p, 0, 1, &xs1, &eda1, &mut bank1, &dab1).unwrap(),
        );
        for i in 0..lanes {
            let bit = d0[i].wrapping_add(d1[i]);
            let want = ((x_check[i] as i64) >= 0) as u64;
            assert_eq!(bit, want, "lane {i}: x={:#x}", x_check[i]);
        }
    }

    #[test]
    fn b2a_converts_bits() {
        let lanes = 70usize;
        let mut rng = Pcg64::seed_from_u64(6);
        let wpl = words_for(lanes);
        // random bool-shared bits
        let mut bits = vec![0u64; wpl];
        rng.fill_u64(&mut bits);
        bits[wpl - 1] &= (1u64 << (lanes % 64)) - 1;
        let mut s1 = vec![0u64; wpl];
        rng.fill_u64(&mut s1);
        s1[wpl - 1] &= (1u64 << (lanes % 64)) - 1;
        let s0: Vec<u64> = bits.iter().zip(&s1).map(|(b, s)| b ^ s).collect();
        let mut dealer = BoolDealer::new(7);
        let (dab0, dab1) = dealer.dabits(lanes);
        let bits_check = bits.clone();
        let (a0, a1) = run2(
            move |mut p| b2a(&mut p, 1, 0, &s0, &dab0, lanes).unwrap(),
            move |mut p| b2a(&mut p, 0, 1, &s1, &dab1, lanes).unwrap(),
        );
        for l in 0..lanes {
            let want = (bits_check[l / 64] >> (l % 64)) & 1;
            assert_eq!(a0[l].wrapping_add(a1[l]), want, "lane {l}");
        }
    }

    #[test]
    fn triple_bank_exhaustion_panics() {
        let mut dealer = BoolDealer::new(8);
        let (mut b0, _) = dealer.and_triples(4);
        let _ = b0.take(3);
        assert_eq!(b0.remaining(), 1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = b0.take(2);
        }));
        assert!(r.is_err());
    }

    #[test]
    fn edabit_consistency() {
        // arith reconstruction and bit reconstruction agree
        let mut dealer = BoolDealer::new(9);
        let (e0, e1) = dealer.edabits(50);
        let r: Vec<u64> = e0
            .r_arith
            .iter()
            .zip(&e1.r_arith)
            .map(|(a, b)| a.wrapping_add(*b))
            .collect();
        let bits = e0.r_bits.xor(&e1.r_bits).recompose();
        assert_eq!(r, bits);
    }
}
