//! Paillier **plaintext packing**: many fixed-point values per ciphertext.
//!
//! The Algorithm 3 hot loop encrypts a `rows x h1_dim` matrix per
//! mini-batch. A Paillier plaintext lives in `Z_n` (1024 bits at the
//! experiments' default) while each matrix entry is a ~48-bit fixed-point
//! ring value, so encrypting one entry per ciphertext wastes >95% of every
//! plaintext — and of every wire byte, since a ciphertext is `2·n_bits`.
//! Packing (the BatchCrypt lever from "Industrial Scale Privacy Preserving
//! Deep Neural Network", Zheng et al. 2020) lays
//! `slots = floor((n_bits-1)/slot_bits)` values side by side in one
//! plaintext, shrinking both the encryption count and the HE traffic by
//! `slots`x.
//!
//! Homomorphic addition adds all slots componentwise, which is exactly the
//! `k`-holder ciphertext-chain sum SPNN-HE needs — provided no slot ever
//! carries into its neighbor. Two measures guarantee that:
//!
//! * **offset encoding**: a signed value `v` is stored as `v + bias` with
//!   `bias = 2^(value_bits-1)`, so slot contents are non-negative and
//!   two's-complement borrows cannot cross slot boundaries;
//! * **headroom**: `value_bits = slot_bits - ceil(log2(max_addends))`, so
//!   the sum of `max_addends` slots stays `< 2^slot_bits`.
//!
//! Decoding a sum of `k` ciphertexts subtracts `k·bias` per slot. Unused
//! trailing slots in the last ciphertext are left all-zero (no bias) and
//! never read back.
//!
//! Layout: little-endian slot order, `slot_bits/8` bytes per slot, so
//! packing/unpacking is pure byte movement (no bignum shifts).
//!
//! The batch pipeline has two levels: the `*_resident` functions keep
//! ciphertexts in Montgomery form ([`CtElem`]) across whole
//! encrypt→add→…→add chains, converting to wire bytes once per chain
//! ([`resident_to_block`]); the [`Ciphertext`]-level wrappers
//! ([`encrypt_batch`], [`add_batch`]) convert per call and exist for
//! call sites that need canonical values immediately.

use crate::bignum::{BigUint, MontElem};
use crate::exec::ExecPool;
use crate::{Error, Result};

use super::{Ciphertext, CtElem, NoncePool, PublicKey, SecretKey};

/// Default per-slot width in bits (`TrainConfig::slot_bits`): 21 slots per
/// 1024-bit plaintext, 5 per test-size 256-bit plaintext.
pub const DEFAULT_SLOT_BITS: usize = 48;

/// Minimum items per worker chunk for batched modular arithmetic; one
/// Paillier op is microseconds-to-milliseconds, so tiny chunks are fine
/// but single-digit batches stay inline.
const PAR_MIN_OPS: usize = 8;

/// Packing geometry for one public key: how many fixed-point values share
/// a plaintext and how much per-slot headroom a `k`-holder sum needs.
#[derive(Clone, Copy, Debug)]
pub struct Packing {
    slot_bits: usize,
    slot_bytes: usize,
    /// Values per ciphertext.
    slots: usize,
    /// Per-slot offset making stored slot contents non-negative.
    bias: u64,
    /// Largest number of ciphertexts the slots leave headroom to sum.
    max_addends: usize,
}

impl Packing {
    /// `slot_bits` must be a multiple of 8 in `[16, 56]` (so a summed slot
    /// always fits a `u64` read); `max_addends >= 1` is the number of
    /// homomorphic addends — SPNN-HE passes the holder count.
    pub fn new(pk: &PublicKey, slot_bits: usize, max_addends: usize) -> Result<Self> {
        if slot_bits % 8 != 0 || !(16..=56).contains(&slot_bits) {
            return Err(Error::Crypto(format!(
                "packing: slot_bits {slot_bits} must be a multiple of 8 in [16, 56]"
            )));
        }
        if max_addends == 0 {
            return Err(Error::Crypto("packing: max_addends must be >= 1".into()));
        }
        let headroom = usize::BITS as usize - (max_addends - 1).leading_zeros() as usize;
        let value_bits = slot_bits
            .checked_sub(headroom)
            .filter(|&vb| vb >= 8)
            .ok_or_else(|| {
                Error::Crypto(format!(
                    "packing: slot_bits {slot_bits} leaves no room for \
                     {max_addends}-addend headroom"
                ))
            })?;
        // packed plaintexts stay < 2^(n_bits-1) < n, so Z_n never wraps
        let slots = (pk.n.bits() - 1) / slot_bits;
        if slots == 0 {
            return Err(Error::Crypto(format!(
                "packing: modulus of {} bits too small for slot_bits {slot_bits}",
                pk.n.bits()
            )));
        }
        Ok(Packing {
            slot_bits,
            slot_bytes: slot_bits / 8,
            slots,
            bias: 1u64 << (value_bits - 1),
            max_addends,
        })
    }

    /// Values per ciphertext.
    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn slot_bits(&self) -> usize {
        self.slot_bits
    }

    pub fn max_addends(&self) -> usize {
        self.max_addends
    }

    /// Ciphertexts needed for `count` values.
    pub fn ct_count(&self, count: usize) -> usize {
        count.div_ceil(self.slots)
    }

    /// Largest value magnitude one slot can carry: values must lie in
    /// `[-max, max]` with `max = bias - 1` (the fixed-point products of
    /// normalized features sit far below this at the default 48-bit slots).
    pub fn max_value(&self) -> i64 {
        (self.bias - 1) as i64
    }

    /// Pack signed fixed-point values into plaintext integers,
    /// [`Self::slots`] per number, little-endian slot order.
    ///
    /// Panics if a value exceeds [`Self::max_value`] — that is a protocol
    /// sizing bug (increase `slot_bits` or shrink the fixed-point scale),
    /// not a runtime condition to limp past.
    pub fn pack(&self, vals: &[i64]) -> Vec<BigUint> {
        vals.chunks(self.slots)
            .map(|chunk| {
                let mut bytes = vec![0u8; chunk.len() * self.slot_bytes];
                for (i, &v) in chunk.iter().enumerate() {
                    assert!(
                        v.unsigned_abs() < self.bias,
                        "packing: value {v} exceeds slot capacity {} \
                         (slot_bits {}, {} addends) — increase slot_bits",
                        self.max_value(),
                        self.slot_bits,
                        self.max_addends
                    );
                    let u = (v + self.bias as i64) as u64;
                    bytes[i * self.slot_bytes..(i + 1) * self.slot_bytes]
                        .copy_from_slice(&u.to_le_bytes()[..self.slot_bytes]);
                }
                BigUint::from_bytes_le(&bytes)
            })
            .collect()
    }

    /// Unpack plaintexts that are the sum of `addends` packed ciphertexts
    /// back into `count` signed values (`addends = 1` decodes a single
    /// unpaired encryption).
    pub fn unpack_sum(&self, plains: &[BigUint], count: usize, addends: usize) -> Result<Vec<i64>> {
        if addends == 0 || addends > self.max_addends {
            return Err(Error::Crypto(format!(
                "unpack: {addends} addends exceeds the packing headroom ({})",
                self.max_addends
            )));
        }
        if plains.len() != self.ct_count(count) {
            return Err(Error::Protocol(format!(
                "unpack: {} plaintexts for {count} values (expected {})",
                plains.len(),
                self.ct_count(count)
            )));
        }
        let k_bias = (addends as u64 * self.bias) as i64;
        let mut out = Vec::with_capacity(count);
        for (ci, m) in plains.iter().enumerate() {
            let bytes = m.to_bytes_le(); // trailing zero bytes are trimmed
            let here = (count - ci * self.slots).min(self.slots);
            for i in 0..here {
                let start = i * self.slot_bytes;
                let mut buf = [0u8; 8];
                for (b, slot) in buf.iter_mut().take(self.slot_bytes).enumerate() {
                    *slot = bytes.get(start + b).copied().unwrap_or(0);
                }
                out.push(u64::from_le_bytes(buf) as i64 - k_bias);
            }
        }
        Ok(out)
    }
}

/// Pack and encrypt `vals` into **Montgomery-resident** ciphertexts: one
/// [`NoncePool`] nonce per ciphertext (drawn serially — the pool order is
/// part of the deterministic transcript), the modular multiplications fanned
/// out over `exec`. The result stays resident for chain-adds; convert at
/// the wire boundary with [`resident_to_block`].
pub fn encrypt_batch_resident(
    pk: &PublicKey,
    packing: &Packing,
    vals: &[i64],
    pool: &mut NoncePool,
    exec: &ExecPool,
) -> Vec<CtElem> {
    let _sp = crate::obs::span("crypto_encrypt_batch_seconds");
    let plains = packing.pack(vals);
    let jobs: Vec<(BigUint, MontElem)> =
        plains.into_iter().map(|m| (m, pool.take())).collect();
    crate::obs::counter_add("crypto_cts_encrypted_total", jobs.len() as u64);
    exec.par_map(&jobs, PAR_MIN_OPS, |(m, rn)| pk.encrypt_resident(m, rn))
}

/// Pack and encrypt `vals` into wire-form ciphertexts (the resident path
/// plus one conversion per ciphertext).
pub fn encrypt_batch(
    pk: &PublicKey,
    packing: &Packing,
    vals: &[i64],
    pool: &mut NoncePool,
    exec: &ExecPool,
) -> Vec<Ciphertext> {
    let res = encrypt_batch_resident(pk, packing, vals, pool, exec);
    exec.par_map(&res, PAR_MIN_OPS, |c| pk.from_resident(c))
}

/// Decrypt a batch of packed ciphertexts (parallel CRT decryptions) and
/// unpack the per-slot sums of `addends` original ciphertexts.
pub fn decrypt_batch(
    sk: &SecretKey,
    packing: &Packing,
    cts: &[Ciphertext],
    count: usize,
    addends: usize,
    exec: &ExecPool,
) -> Result<Vec<i64>> {
    let _sp = crate::obs::span("crypto_decrypt_batch_seconds");
    crate::obs::counter_add("crypto_cts_decrypted_total", cts.len() as u64);
    let plains = exec.par_map(cts, PAR_MIN_OPS / 4, |c| sk.decrypt(c));
    packing.unpack_sum(&plains, count, addends)
}

/// Elementwise homomorphic addition of two equal-length ciphertext
/// batches, fanned out over `exec`.
pub fn add_batch(
    pk: &PublicKey,
    a: &[Ciphertext],
    b: &[Ciphertext],
    exec: &ExecPool,
) -> Result<Vec<Ciphertext>> {
    if a.len() != b.len() {
        return Err(Error::Protocol(format!(
            "add_batch: {} vs {} ciphertexts",
            a.len(),
            b.len()
        )));
    }
    let _sp = crate::obs::span("crypto_chain_add_seconds");
    let idx: Vec<usize> = (0..a.len()).collect();
    Ok(exec.par_map(&idx, PAR_MIN_OPS, |&i| pk.add(&a[i], &b[i])))
}

/// Elementwise homomorphic addition of two equal-length **resident**
/// ciphertext batches: one Montgomery multiply per element, no conversions.
pub fn add_batch_resident(
    pk: &PublicKey,
    a: &[CtElem],
    b: &[CtElem],
    exec: &ExecPool,
) -> Result<Vec<CtElem>> {
    if a.len() != b.len() {
        return Err(Error::Protocol(format!(
            "add_batch: {} vs {} ciphertexts",
            a.len(),
            b.len()
        )));
    }
    let _sp = crate::obs::span("crypto_chain_add_seconds");
    let idx: Vec<usize> = (0..a.len()).collect();
    Ok(exec.par_map(&idx, PAR_MIN_OPS, |&i| pk.add_resident(&a[i], &b[i])))
}

/// Parse a flat wire block straight into Montgomery-resident form (one
/// conversion multiply per ciphertext, fanned out over `exec`).
pub fn block_to_resident(
    pk: &PublicKey,
    data: &[u8],
    ct_bytes: usize,
    count: usize,
    exec: &ExecPool,
) -> Result<Vec<CtElem>> {
    let cts = block_to_cts(data, ct_bytes, count)?;
    Ok(exec.par_map(&cts, PAR_MIN_OPS, |c| pk.to_resident(c)))
}

/// Flatten resident ciphertexts to the `Payload::CipherBlock` wire format —
/// the only point a resident chain leaves Montgomery form.
pub fn resident_to_block(
    pk: &PublicKey,
    cts: &[CtElem],
    ct_bytes: usize,
    exec: &ExecPool,
) -> Vec<u8> {
    let wire = exec.par_map(cts, PAR_MIN_OPS, |c| pk.from_resident(c));
    cts_to_block(&wire, ct_bytes)
}

/// Flatten ciphertexts into one contiguous buffer, each padded to
/// `ct_bytes` (use [`PublicKey::ciphertext_bytes`]) — the
/// `Payload::CipherBlock` wire format.
pub fn cts_to_block(cts: &[Ciphertext], ct_bytes: usize) -> Vec<u8> {
    let mut data = vec![0u8; cts.len() * ct_bytes];
    for (i, c) in cts.iter().enumerate() {
        let b = c.0.to_bytes_le();
        assert!(
            b.len() <= ct_bytes,
            "cts_to_block: ciphertext of {} bytes exceeds ct_bytes {ct_bytes}",
            b.len()
        );
        data[i * ct_bytes..i * ct_bytes + b.len()].copy_from_slice(&b);
    }
    data
}

/// Parse a flat ciphertext block (inverse of [`cts_to_block`]).
pub fn block_to_cts(data: &[u8], ct_bytes: usize, count: usize) -> Result<Vec<Ciphertext>> {
    if ct_bytes == 0 || data.len() != ct_bytes * count {
        return Err(Error::Protocol(format!(
            "cipher block: {} bytes != {count} ciphertexts x {ct_bytes} bytes",
            data.len()
        )));
    }
    Ok(data
        .chunks(ct_bytes)
        .map(|c| Ciphertext(BigUint::from_bytes_le(c)))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::paillier::keygen;
    use crate::rng::{ChaChaRng, Pcg64, Rng64};

    fn keys_256() -> (PublicKey, SecretKey) {
        let mut rng = ChaChaRng::seed_from_u64(0x9ac4);
        let kp = keygen(&mut rng, 256);
        (kp.pk, kp.sk)
    }

    #[test]
    fn geometry_at_default_slot_bits() {
        let (pk, _) = keys_256();
        let p = Packing::new(&pk, DEFAULT_SLOT_BITS, 2).unwrap();
        // 255 usable bits / 48 = 5 slots: a >= 4x wire reduction even at
        // test-size keys (21 slots at the 1024-bit experiments default)
        assert_eq!(p.slots(), 5);
        assert_eq!(p.ct_count(0), 0);
        assert_eq!(p.ct_count(5), 1);
        assert_eq!(p.ct_count(6), 2);
        assert_eq!(p.ct_count(2048), 410);
        // headroom: 48 - ceil(log2(2)) - 1 = 46 bits of magnitude
        assert_eq!(p.max_value(), (1i64 << 46) - 1);
    }

    #[test]
    fn rejects_bad_geometry() {
        let (pk, _) = keys_256();
        assert!(Packing::new(&pk, 47, 2).is_err(), "not a byte multiple");
        assert!(Packing::new(&pk, 8, 2).is_err(), "below minimum");
        assert!(Packing::new(&pk, 64, 2).is_err(), "above u64-safe maximum");
        assert!(Packing::new(&pk, 48, 0).is_err(), "zero addends");
        assert!(Packing::new(&pk, 16, 1 << 10).is_err(), "headroom eats the slot");
    }

    #[test]
    fn pack_unpack_roundtrip_single() {
        let (pk, _) = keys_256();
        let p = Packing::new(&pk, 48, 3).unwrap();
        let mut rng = Pcg64::seed_from_u64(1);
        for _ in 0..50 {
            let n = (rng.next_u64() % 23) as usize;
            let vals: Vec<i64> = (0..n)
                .map(|_| {
                    let span = 2 * p.max_value() as u128 + 1;
                    (rng.next_u64() as u128 % span) as i64 - p.max_value()
                })
                .collect();
            let plains = p.pack(&vals);
            assert_eq!(plains.len(), p.ct_count(n));
            let back = p.unpack_sum(&plains, n, 1).unwrap();
            assert_eq!(back, vals);
        }
    }

    #[test]
    fn packed_sum_matches_plaintext_sum_for_k_holders() {
        // the exact SPNN-HE flow: k holders each encrypt_batch their local
        // products, the ciphertext chain adds them, the server decrypts the
        // per-slot sums — exercised for k in {2, 3, 5}
        let (pk, sk) = keys_256();
        let exec = ExecPool::new(2);
        let mut rng = ChaChaRng::seed_from_u64(2);
        for k in [2usize, 3, 5] {
            let p = Packing::new(&pk, 48, k).unwrap();
            let count = 37; // deliberately not a slot multiple
            let per_holder_max = p.max_value() / k as i64;
            let holders: Vec<Vec<i64>> = (0..k)
                .map(|_| {
                    (0..count)
                        .map(|_| {
                            let span = 2 * per_holder_max as u128 + 1;
                            (rng.next_u64() as u128 % span) as i64 - per_holder_max
                        })
                        .collect()
                })
                .collect();
            let mut acc: Option<Vec<Ciphertext>> = None;
            for vals in &holders {
                let mut pool = NoncePool::new(&pk, true);
                pool.refill_parallel(&mut rng, p.ct_count(count), &exec);
                let mine = encrypt_batch(&pk, &p, vals, &mut pool, &exec);
                acc = Some(match acc {
                    None => mine,
                    Some(prev) => add_batch(&pk, &prev, &mine, &exec).unwrap(),
                });
            }
            let got = decrypt_batch(&sk, &p, &acc.unwrap(), count, k, &exec).unwrap();
            let want: Vec<i64> = (0..count)
                .map(|i| holders.iter().map(|h| h[i]).sum::<i64>())
                .collect();
            assert_eq!(got, want, "k={k}");
        }
    }

    #[test]
    fn boundary_magnitudes_respect_headroom() {
        // every holder at +/- the per-holder extreme: the slot sum touches
        // its design limit without carrying into the neighbor slot
        let (pk, sk) = keys_256();
        let exec = ExecPool::serial();
        let mut rng = ChaChaRng::seed_from_u64(3);
        for k in [2usize, 3, 5] {
            let p = Packing::new(&pk, 48, k).unwrap();
            let m = p.max_value() / k as i64;
            let vals = vec![m, -m, m, -m, m, -m, m]; // crosses one ct boundary
            let mut acc: Option<Vec<Ciphertext>> = None;
            for _ in 0..k {
                let mut pool = NoncePool::new(&pk, false);
                pool.refill(&mut rng, p.ct_count(vals.len()));
                let mine = encrypt_batch(&pk, &p, &vals, &mut pool, &exec);
                acc = Some(match acc {
                    None => mine,
                    Some(prev) => add_batch(&pk, &prev, &mine, &exec).unwrap(),
                });
            }
            let got = decrypt_batch(&sk, &p, &acc.unwrap(), vals.len(), k, &exec).unwrap();
            let want: Vec<i64> = vals.iter().map(|v| v * k as i64).collect();
            assert_eq!(got, want, "k={k}");
        }
    }

    #[test]
    fn parallel_and_serial_encryption_agree() {
        // exec width must never change the transcript: same pool nonces,
        // same ciphertexts
        let (pk, _) = keys_256();
        let p = Packing::new(&pk, 48, 2).unwrap();
        let vals: Vec<i64> = (-40..40).map(|v| v * 1000).collect();
        let mk = |exec: &ExecPool| {
            let mut rng = ChaChaRng::seed_from_u64(4);
            let mut pool = NoncePool::new(&pk, true);
            pool.refill_parallel(&mut rng, p.ct_count(vals.len()), exec);
            encrypt_batch(&pk, &p, &vals, &mut pool, exec)
        };
        let serial = mk(&ExecPool::serial());
        let par = mk(&ExecPool::new(4));
        assert_eq!(serial, par);
    }

    #[test]
    fn refill_parallel_matches_refill() {
        let (pk, sk) = keys_256();
        let exec = ExecPool::new(3);
        for short in [false, true] {
            let mut a = NoncePool::new(&pk, short);
            let mut b = NoncePool::new(&pk, short);
            let mut ra = ChaChaRng::seed_from_u64(5);
            let mut rb = ChaChaRng::seed_from_u64(5);
            a.refill(&mut ra, 6);
            b.refill_parallel(&mut rb, 6, &exec);
            assert_eq!(a.remaining(), b.remaining());
            // same nonces => identical ciphertexts for identical messages
            for i in 0..6 {
                let m = BigUint::from_u64(100 + i);
                let ca = pk.encrypt_with_pool(&m, &mut a);
                let cb = pk.encrypt_with_pool(&m, &mut b);
                assert_eq!(ca, cb, "short={short} i={i}");
                assert_eq!(sk.decrypt(&ca), m);
            }
        }
    }

    #[test]
    fn block_roundtrip_and_size_checks() {
        let (pk, _) = keys_256();
        let p = Packing::new(&pk, 48, 2).unwrap();
        let mut rng = ChaChaRng::seed_from_u64(6);
        let mut pool = NoncePool::new(&pk, false);
        pool.refill(&mut rng, 3);
        let vals: Vec<i64> = (0..11).map(|v| v - 5).collect();
        let cts = encrypt_batch(&pk, &p, &vals, &mut pool, &ExecPool::serial());
        assert_eq!(cts.len(), 3);
        let ct_bytes = pk.ciphertext_bytes();
        let block = cts_to_block(&cts, ct_bytes);
        assert_eq!(block.len(), 3 * ct_bytes);
        let back = block_to_cts(&block, ct_bytes, 3).unwrap();
        assert_eq!(back, cts);
        assert!(block_to_cts(&block, ct_bytes, 2).is_err());
        assert!(block_to_cts(&block[1..], ct_bytes, 3).is_err());
        assert!(block_to_cts(&block, 0, 0).is_err());
    }

    #[test]
    fn unpack_guards_addends_and_length() {
        let (pk, _) = keys_256();
        let p = Packing::new(&pk, 48, 2).unwrap();
        let plains = p.pack(&[1, 2, 3]);
        assert!(p.unpack_sum(&plains, 3, 3).is_err(), "past headroom");
        assert!(p.unpack_sum(&plains, 3, 0).is_err());
        assert!(p.unpack_sum(&plains, 99, 1).is_err(), "length mismatch");
    }

    #[test]
    fn resident_chain_matches_wire_form_chain() {
        // the full SPNN-HE hop both ways: resident encrypt→add→…→exit must
        // produce byte-identical wire blocks to the Ciphertext-level chain
        let (pk, sk) = keys_256();
        let exec = ExecPool::new(2);
        let k = 3;
        let p = Packing::new(&pk, 48, k).unwrap();
        let count = 23;
        let vals: Vec<Vec<i64>> = (0..k)
            .map(|h| (0..count as i64).map(|i| (i - 11) * (h as i64 + 1)).collect())
            .collect();
        let run = |resident: bool| -> Vec<u8> {
            let mut rng = ChaChaRng::seed_from_u64(7);
            let ct_bytes = pk.ciphertext_bytes();
            if resident {
                let mut acc: Option<Vec<CtElem>> = None;
                for v in &vals {
                    let mut pool = NoncePool::new(&pk, true);
                    pool.refill_parallel(&mut rng, p.ct_count(count), &exec);
                    let mine = encrypt_batch_resident(&pk, &p, v, &mut pool, &exec);
                    acc = Some(match acc {
                        None => mine,
                        Some(prev) => add_batch_resident(&pk, &prev, &mine, &exec).unwrap(),
                    });
                }
                resident_to_block(&pk, &acc.unwrap(), ct_bytes, &exec)
            } else {
                let mut acc: Option<Vec<Ciphertext>> = None;
                for v in &vals {
                    let mut pool = NoncePool::new(&pk, true);
                    pool.refill_parallel(&mut rng, p.ct_count(count), &exec);
                    let mine = encrypt_batch(&pk, &p, v, &mut pool, &exec);
                    acc = Some(match acc {
                        None => mine,
                        Some(prev) => add_batch(&pk, &prev, &mine, &exec).unwrap(),
                    });
                }
                cts_to_block(&acc.unwrap(), ct_bytes)
            }
        };
        let res_block = run(true);
        let wire_block = run(false);
        assert_eq!(res_block, wire_block, "resident chain diverged from wire chain");
        // and it decrypts to the right sums
        let ct_bytes = pk.ciphertext_bytes();
        let cts = block_to_cts(&res_block, ct_bytes, p.ct_count(count)).unwrap();
        let got = decrypt_batch(&sk, &p, &cts, count, k, &exec).unwrap();
        let want: Vec<i64> = (0..count as i64).map(|i| (i - 11) * 6).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn block_to_resident_roundtrips() {
        let (pk, _) = keys_256();
        let p = Packing::new(&pk, 48, 2).unwrap();
        let exec = ExecPool::serial();
        let mut rng = ChaChaRng::seed_from_u64(8);
        let mut pool = NoncePool::new(&pk, true);
        pool.refill(&mut rng, 2);
        let res = encrypt_batch_resident(&pk, &p, &[5, -7, 11, 0, 1, 2], &mut pool, &exec);
        let ct_bytes = pk.ciphertext_bytes();
        let block = resident_to_block(&pk, &res, ct_bytes, &exec);
        let back = block_to_resident(&pk, &block, ct_bytes, res.len(), &exec).unwrap();
        assert_eq!(back, res, "wire round-trip changed the resident values");
        assert!(block_to_resident(&pk, &block[1..], ct_bytes, res.len(), &exec).is_err());
    }

    #[test]
    fn resident_scalar_mul_matches_naive_chain() {
        // mul_plain_resident vs the BigUint mul-rem oracle (per ISSUE:
        // resident add/scalar-mul chains against the naive chain)
        let (pk, sk) = keys_256();
        let mut rng = ChaChaRng::seed_from_u64(9);
        let m = BigUint::from_u64(1234);
        let c = pk.encrypt(&m, &mut rng);
        for k in [0u64, 1, 2, 5, 1000] {
            let res = pk.mul_plain_resident(&pk.to_resident(&c), &BigUint::from_u64(k));
            let got = pk.from_resident(&res);
            assert_eq!(got, pk.mul_plain(&c, &BigUint::from_u64(k)), "k={k}");
            // naive oracle: c^k by repeated mul+rem on raw BigUints
            let mut naive = BigUint::one().rem(&pk.n2);
            for _ in 0..k {
                naive = naive.mul(&c.0).rem(&pk.n2);
            }
            assert_eq!(got.0, naive, "k={k}");
            if k > 0 {
                assert_eq!(sk.decrypt(&got), m.mul_u64(k).rem(&pk.n), "k={k}");
            }
        }
    }

    #[test]
    fn fixed_base_refill_deterministic_across_thread_counts() {
        // the FixedBaseTable is shared by reference across refill workers;
        // pool contents must be identical for any exec width
        let (pk, _) = keys_256();
        let vals: Vec<i64> = (0..30).collect();
        let p = Packing::new(&pk, 48, 2).unwrap();
        let mk = |threads: usize| -> Vec<Ciphertext> {
            let exec = if threads == 0 {
                ExecPool::serial()
            } else {
                ExecPool::new(threads)
            };
            let mut rng = ChaChaRng::seed_from_u64(10);
            let mut pool = NoncePool::new(&pk, true);
            pool.refill_parallel(&mut rng, p.ct_count(vals.len()), &exec);
            encrypt_batch(&pk, &p, &vals, &mut pool, &exec)
        };
        let base = mk(0);
        for threads in [1usize, 2, 7] {
            assert_eq!(mk(threads), base, "threads={threads}");
        }
    }
}
