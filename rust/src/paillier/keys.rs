//! Paillier key generation, encryption, decryption, homomorphic operators.

use std::sync::Arc;

use crate::bignum::{gen_prime, modinv, BigUint, MontElem, Montgomery};
use crate::rng::Rng64;

use super::NoncePool;

/// A Paillier ciphertext: an element of `Z_{n^2}^*` in canonical (wire)
/// form.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Ciphertext(pub BigUint);

/// A ciphertext resident in Montgomery form of `n^2`. The batched pipeline
/// ([`crate::paillier::pack`]) keeps whole encrypt→add chains in this
/// representation and converts to [`Ciphertext`] only at the wire boundary,
/// saving two conversions plus a division per homomorphic op.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CtElem(pub(crate) MontElem);

/// Public key. `g = n + 1` is implicit.
#[derive(Clone)]
pub struct PublicKey {
    /// Modulus `n = p·q`.
    pub n: BigUint,
    /// `n^2` — the ciphertext modulus.
    pub n2: BigUint,
    /// Half of n (signed-embedding threshold).
    pub half_n: BigUint,
    /// Montgomery context for `n^2` (shared; contexts are immutable).
    pub(crate) mont_n2: Arc<Montgomery>,
}

/// Secret key with CRT precomputation.
#[derive(Clone)]
pub struct SecretKey {
    pub p: BigUint,
    pub q: BigUint,
    p2: BigUint,
    q2: BigUint,
    /// `p - 1` / `q - 1`: the CRT decryption exponents, cached so the hot
    /// path does zero subtractions/allocations before each pow.
    p1: BigUint,
    q1: BigUint,
    mont_p2: Arc<Montgomery>,
    mont_q2: Arc<Montgomery>,
    /// `h_p = L_p(g^{p-1} mod p^2)^{-1} mod p`
    hp: BigUint,
    hq: BigUint,
    /// `q^{-1} mod p` for the CRT recombination.
    q_inv_p: BigUint,
    /// Copy of the public side for decode helpers.
    pub pk: PublicKey,
}

/// Key pair.
pub struct KeyPair {
    pub pk: PublicKey,
    pub sk: SecretKey,
}

/// Generate a Paillier keypair with an `n_bits` modulus.
///
/// `n_bits = 1024` is the experiments' default; tests use smaller. Primes
/// are rejected until `gcd(pq, (p-1)(q-1)) = 1` holds (automatic for
/// same-size primes) and `p != q`.
pub fn keygen<R: Rng64>(rng: &mut R, n_bits: usize) -> KeyPair {
    assert!(n_bits >= 64 && n_bits % 2 == 0, "keygen: bad n_bits {n_bits}");
    loop {
        let p = gen_prime(rng, n_bits / 2);
        let q = gen_prime(rng, n_bits / 2);
        if p == q {
            continue;
        }
        let n = p.mul(&q);
        if n.bits() != n_bits {
            continue; // product came out one bit short
        }
        let n2 = n.square();
        let pk = PublicKey {
            half_n: n.shr_bits(1),
            mont_n2: Arc::new(Montgomery::new(&n2)),
            n2,
            n,
        };

        // CRT precomputation. With g = n+1:
        //   L_p(g^{p-1} mod p^2) = (g^{p-1} mod p^2 - 1)/p,  hp = its inverse mod p
        let p2 = p.square();
        let q2 = q.square();
        let p1 = p.sub_u64(1);
        let q1 = q.sub_u64(1);
        let mont_p2 = Arc::new(Montgomery::new(&p2));
        let mont_q2 = Arc::new(Montgomery::new(&q2));
        let g = pk.n.add_u64(1);
        let lp = l_func(&mont_p2.pow(&g, &p1), &p);
        let lq = l_func(&mont_q2.pow(&g, &q1), &q);
        let (hp, hq) = match (modinv(&lp, &p), modinv(&lq, &q)) {
            (Some(a), Some(b)) => (a, b),
            _ => continue, // pathological primes; retry
        };
        let q_inv_p = match modinv(&q, &p) {
            Some(v) => v,
            None => continue,
        };
        let sk = SecretKey {
            p,
            q,
            p2,
            q2,
            p1,
            q1,
            mont_p2,
            mont_q2,
            hp,
            hq,
            q_inv_p,
            pk: pk.clone(),
        };
        return KeyPair { pk, sk };
    }
}

/// Paillier's `L(u) = (u - 1) / d` (exact division).
fn l_func(u: &BigUint, d: &BigUint) -> BigUint {
    u.sub_u64(1).div(d)
}

impl PublicKey {
    /// Rebuild a public key from its modulus (what travels on the wire —
    /// `g = n+1` is implicit, everything else is derived).
    pub fn from_n(n: BigUint) -> Self {
        let n2 = n.square();
        PublicKey {
            half_n: n.shr_bits(1),
            mont_n2: Arc::new(Montgomery::new(&n2)),
            n2,
            n,
        }
    }

    /// Encrypt with a fresh random nonce (`r^n` exponentiation inline).
    pub fn encrypt<R: Rng64>(&self, m: &BigUint, rng: &mut R) -> Ciphertext {
        let r = self.sample_unit(rng);
        let rn = self.mont_n2.pow_elem(&self.mont_n2.enter(&r), &self.n);
        self.from_resident(&self.encrypt_resident(m, &rn))
    }

    /// Encrypt consuming a precomputed `r^n` from a [`NoncePool`]
    /// — the hot-path entry point (zero exponentiations).
    pub fn encrypt_with_pool(&self, m: &BigUint, pool: &mut NoncePool) -> Ciphertext {
        let rn = pool.take();
        self.from_resident(&self.encrypt_resident(m, &rn))
    }

    /// `c = (1 + m·n) · rn  mod n^2` in resident form, with `rn` a
    /// Montgomery-form `r^n`. The binomial shortcut for `g^m` needs no
    /// reduction — `m < n` keeps `1 + m·n < n^2` — so this is one
    /// conversion multiply plus one Montgomery multiply, zero divisions.
    pub(crate) fn encrypt_resident(&self, m: &BigUint, rn: &MontElem) -> CtElem {
        debug_assert!(m < &self.n, "plaintext out of range");
        let gm = m.mul(&self.n).add_u64(1);
        CtElem(self.mont_n2.mul_elem(&self.mont_n2.enter(&gm), rn))
    }

    /// Convert a wire-form ciphertext into Montgomery-resident form.
    pub fn to_resident(&self, c: &Ciphertext) -> CtElem {
        CtElem(self.mont_n2.enter(&c.0))
    }

    /// Convert a resident ciphertext back to the canonical wire form.
    pub fn from_resident(&self, c: &CtElem) -> Ciphertext {
        Ciphertext(self.mont_n2.exit(&c.0))
    }

    /// Homomorphic addition in resident form: one Montgomery multiply
    /// (vs two conversions + multiply + conversion for wire-form [`Self::add`]).
    pub fn add_resident(&self, a: &CtElem, b: &CtElem) -> CtElem {
        CtElem(self.mont_n2.mul_elem(&a.0, &b.0))
    }

    /// Plaintext scalar multiply in resident form: `c^k` (sliding window).
    pub fn mul_plain_resident(&self, c: &CtElem, k: &BigUint) -> CtElem {
        CtElem(self.mont_n2.pow_elem(&c.0, k))
    }

    /// Sample `r` in `[1, n)` with `gcd(r, n) = 1` (whp for RSA-like n).
    pub(crate) fn sample_unit<R: Rng64>(&self, rng: &mut R) -> BigUint {
        loop {
            let r = BigUint::random_below(rng, &self.n);
            if !r.is_zero() {
                return r;
            }
        }
    }

    /// Homomorphic addition: `Dec(add(a,b)) = Dec(a) + Dec(b) mod n`.
    pub fn add(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        Ciphertext(self.mont_n2.mul(&a.0, &b.0))
    }

    /// Add a plaintext constant: `c · g^k = c · (1 + k·n)`.
    pub fn add_plain(&self, c: &Ciphertext, k: &BigUint) -> Ciphertext {
        // k mod n < n keeps 1 + (k mod n)·n < n^2: no outer reduction
        let gk = k.rem(&self.n).mul(&self.n).add_u64(1);
        Ciphertext(self.mont_n2.mul(&c.0, &gk))
    }

    /// Multiply the plaintext by a constant: `c^k`.
    pub fn mul_plain(&self, c: &Ciphertext, k: &BigUint) -> Ciphertext {
        Ciphertext(self.mont_n2.pow(&c.0, k))
    }

    /// Encode a signed value into `Z_n` (negative as `n - |v|`).
    pub fn encode_i64(&self, v: i64) -> BigUint {
        if v >= 0 {
            BigUint::from_u64(v as u64)
        } else {
            self.n.sub(&BigUint::from_u64(v.unsigned_abs()))
        }
    }

    /// Encrypt a signed 64-bit value (fixed-point ring element).
    pub fn encrypt_i64<R: Rng64>(&self, v: i64, rng: &mut R) -> Ciphertext {
        self.encrypt(&self.encode_i64(v), rng)
    }

    /// Encrypt a signed value using pool randomness.
    pub fn encrypt_i64_with_pool(&self, v: i64, pool: &mut NoncePool) -> Ciphertext {
        self.encrypt_with_pool(&self.encode_i64(v), pool)
    }

    /// Wire size of one ciphertext (bytes) for network accounting.
    pub fn ciphertext_bytes(&self) -> usize {
        2 * self.n.bits().div_ceil(8)
    }
}

impl SecretKey {
    /// CRT decryption: two half-size sliding-window exponentiations with
    /// cached `p-1` / `q-1` exponents.
    pub fn decrypt(&self, c: &Ciphertext) -> BigUint {
        // m_p = L_p(c^{p-1} mod p^2) · hp mod p
        let cp = self.mont_p2.pow(&c.0.rem(&self.p2), &self.p1);
        let mp = l_func(&cp, &self.p).mul(&self.hp).rem(&self.p);
        let cq = self.mont_q2.pow(&c.0.rem(&self.q2), &self.q1);
        let mq = l_func(&cq, &self.q).mul(&self.hq).rem(&self.q);
        // CRT: m = mq + q * ((mp - mq) * q^{-1} mod p)
        let diff = if mp >= mq {
            mp.sub(&mq) // < p since mp < p
        } else {
            // (mp - mq) mod p for mp < mq
            let d = mq.sub(&mp).rem(&self.p);
            if d.is_zero() {
                d
            } else {
                self.p.sub(&d)
            }
        };
        let t = diff.mul(&self.q_inv_p).rem(&self.p);
        mq.add(&t.mul(&self.q))
    }

    /// Decrypt into a signed value (inverse of [`PublicKey::encode_i64`]).
    pub fn decrypt_i64(&self, c: &Ciphertext) -> i64 {
        let m = self.decrypt(c);
        if m > self.pk.half_n {
            let mag = self.pk.n.sub(&m);
            -(mag.to_u64().expect("signed magnitude too large") as i64)
        } else {
            m.to_u64().expect("magnitude too large") as i64
        }
    }

    /// Decrypt into the `Z_{2^64}` ring (two's complement).
    pub fn decrypt_ring(&self, c: &Ciphertext) -> u64 {
        self.decrypt_i64(c) as u64
    }
}
