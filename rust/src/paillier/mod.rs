//! Paillier additively homomorphic encryption (paper §3.4, Algorithm 3).
//!
//! Implements the scheme exactly as SPNN-HE uses it: the *server* generates
//! the keypair and distributes `pk` to the data holders; holders encrypt
//! their partial first-layer products; ciphertexts are added homomorphically
//! and only the final sum travels back to the server for decryption.
//!
//! Implementation notes:
//! * `g = n + 1`, so encryption is `c = (1 + m·n) · r^n  mod n^2` — one
//!   modular exponentiation (`r^n`) per ciphertext.
//! * Decryption uses the standard CRT split over `p^2` / `q^2` (~4x faster
//!   than the textbook `λ`-based formula).
//! * [`PublicKey::encrypt_with_pool`] consumes pre-generated `r^n` values
//!   from a [`NoncePool`] so the hot loop does zero exponentiations; the
//!   pool can also be filled with **short-exponent** randomizers
//!   (Damgård–Jurik–Nielsen style `h_s^{r'}` with a 400-bit `r'`), the main
//!   lever found in the §Perf pass. `h_s` is fixed per key, so refills run
//!   through a fixed-base window table (zero squarings per nonce).
//! * Exponentiation is sliding-window Montgomery throughout, and the batch
//!   pipeline keeps ciphertexts **Montgomery-resident** ([`CtElem`]) across
//!   encrypt→add chains, converting to canonical wire form once per chain.
//!   All of this is value-preserving: transcripts are bit-identical to the
//!   plain square-and-multiply implementation.
//! * Ring payloads (`Z_{2^64}` fixed-point, two's complement) are embedded
//!   as signed integers: non-negative as-is, negative as `n - |x|`. Sums
//!   stay ≪ `n/2`, so decoding is unambiguous.
//! * [`pack`] packs `floor((n_bits-1)/slot_bits)` fixed-point values per
//!   plaintext (offset-encoded, with headroom for the k-holder ciphertext
//!   sum), with pool-parallel `encrypt_batch`/`decrypt_batch` — the
//!   Algorithm 3 hot path encrypts per *slot group*, not per element.

mod keys;
mod nonce;
pub mod pack;

pub use keys::{keygen, Ciphertext, CtElem, KeyPair, PublicKey, SecretKey};
pub use nonce::NoncePool;
pub use pack::Packing;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bignum::BigUint;
    use crate::rng::{ChaChaRng, Rng64};

    fn small_keys() -> (PublicKey, SecretKey) {
        let mut rng = ChaChaRng::seed_from_u64(1000);
        let kp = keygen(&mut rng, 256); // test-size modulus
        (kp.pk, kp.sk)
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let (pk, sk) = small_keys();
        let mut rng = ChaChaRng::seed_from_u64(1);
        for _ in 0..20 {
            let m = BigUint::random_below(&mut rng, &pk.n);
            let c = pk.encrypt(&m, &mut rng);
            assert_eq!(sk.decrypt(&c), m);
        }
    }

    #[test]
    fn homomorphic_addition() {
        let (pk, sk) = small_keys();
        let mut rng = ChaChaRng::seed_from_u64(2);
        for _ in 0..10 {
            let a = BigUint::from_u64(rng.next_u64() >> 8);
            let b = BigUint::from_u64(rng.next_u64() >> 8);
            let ca = pk.encrypt(&a, &mut rng);
            let cb = pk.encrypt(&b, &mut rng);
            let sum = pk.add(&ca, &cb);
            assert_eq!(sk.decrypt(&sum), a.add(&b));
        }
    }

    #[test]
    fn homomorphic_scalar_multiplication() {
        let (pk, sk) = small_keys();
        let mut rng = ChaChaRng::seed_from_u64(3);
        let m = BigUint::from_u64(123_456_789);
        let c = pk.encrypt(&m, &mut rng);
        let c5 = pk.mul_plain(&c, &BigUint::from_u64(5));
        assert_eq!(sk.decrypt(&c5), m.mul_u64(5));
    }

    #[test]
    fn add_plain() {
        let (pk, sk) = small_keys();
        let mut rng = ChaChaRng::seed_from_u64(4);
        let m = BigUint::from_u64(1_000_000);
        let c = pk.encrypt(&m, &mut rng);
        let c2 = pk.add_plain(&c, &BigUint::from_u64(999));
        assert_eq!(sk.decrypt(&c2), BigUint::from_u64(1_000_999));
    }

    #[test]
    fn probabilistic_encryption_differs() {
        let (pk, _) = small_keys();
        let mut rng = ChaChaRng::seed_from_u64(5);
        let m = BigUint::from_u64(42);
        let c1 = pk.encrypt(&m, &mut rng);
        let c2 = pk.encrypt(&m, &mut rng);
        assert_ne!(c1.0, c2.0, "same randomness reused");
    }

    #[test]
    fn signed_ring_embedding_roundtrip() {
        let (pk, sk) = small_keys();
        let mut rng = ChaChaRng::seed_from_u64(6);
        for v in [0i64, 1, -1, 42, -42, i32::MAX as i64, -(1i64 << 40)] {
            let c = pk.encrypt_i64(v, &mut rng);
            assert_eq!(sk.decrypt_i64(&c), v, "v={v}");
        }
    }

    #[test]
    fn signed_sums_match_ring_addition() {
        // the exact SPNN-HE flow: two ring (u64 two's-complement) partial
        // products, encrypted and added, decrypted back into the ring
        let (pk, sk) = small_keys();
        let mut rng = ChaChaRng::seed_from_u64(7);
        for _ in 0..20 {
            // values bounded like fixed-point pre-truncation products
            let a = (rng.next_u64() >> 20) as i64 - (1i64 << 43);
            let b = (rng.next_u64() >> 20) as i64 - (1i64 << 43);
            let ca = pk.encrypt_i64(a, &mut rng);
            let cb = pk.encrypt_i64(b, &mut rng);
            let got = sk.decrypt_i64(&pk.add(&ca, &cb));
            assert_eq!(got, a + b);
        }
    }

    #[test]
    fn nonce_pool_encryption_matches() {
        let (pk, sk) = small_keys();
        let mut rng = ChaChaRng::seed_from_u64(8);
        let mut pool = NoncePool::new(&pk, false);
        pool.refill(&mut rng, 8);
        for i in 0..8 {
            let m = BigUint::from_u64(1000 + i);
            let c = pk.encrypt_with_pool(&m, &mut pool);
            assert_eq!(sk.decrypt(&c), m);
        }
        assert_eq!(pool.remaining(), 0);
    }

    #[test]
    fn short_exponent_pool_decrypts_correctly() {
        let (pk, sk) = small_keys();
        let mut rng = ChaChaRng::seed_from_u64(9);
        let mut pool = NoncePool::new(&pk, true); // DJN short randomizer
        pool.refill(&mut rng, 4);
        let m = BigUint::from_u64(777);
        let c = pk.encrypt_with_pool(&m, &mut pool);
        assert_eq!(sk.decrypt(&c), m);
    }

    #[test]
    fn ciphertext_size_accounting() {
        let (pk, _) = small_keys();
        // a ciphertext lives in Z_{n^2}: 2x modulus bits
        assert_eq!(pk.ciphertext_bytes(), 2 * 256 / 8);
    }

    #[test]
    fn keygen_distinct_primes_and_sizes() {
        let mut rng = ChaChaRng::seed_from_u64(10);
        let kp = keygen(&mut rng, 128);
        assert_eq!(kp.pk.n.bits(), 128);
        assert_ne!(kp.sk.p, kp.sk.q);
    }
}
