//! Precomputed encryption randomness (`r^n mod n^2`) pools.
//!
//! Every Paillier encryption needs one `r^n` — the only expensive part of
//! encryption once `g = n+1`. SPNN-HE encrypts `batch x h1_dim` values per
//! iteration, so the holders keep a pool that is refilled outside the
//! timed/critical path (the paper's offline/online split; SecureML makes the
//! same distinction for triples).
//!
//! Two refill strategies:
//! * `full`:  `r ← [1,n)`, `r^n mod n^2` — textbook, 1 `n_bits`-bit exponent.
//! * `short` (Damgård–Jurik–Nielsen): precompute `h_s = h^n mod n^2` once
//!   for a random quadratic non-residue-ish `h`, then each nonce is
//!   `h_s^{r'}` with a 400-bit `r'` — ~2.5x less exponent work at the same
//!   decisional-composite-residuosity hardness (DJN03 §4.2).

use std::collections::VecDeque;

use crate::bignum::BigUint;
use crate::exec::ExecPool;
use crate::rng::Rng64;

use super::PublicKey;

/// Short-exponent bit length (kappa = 400 per DJN recommendation for
/// ~128-bit security at 2048-bit moduli; conservative for smaller ones).
const SHORT_EXP_BITS: usize = 400;

/// Pool of ready-to-use `r^n mod n^2` values.
pub struct NoncePool {
    pk: PublicKey,
    /// `h^n mod n^2` base for the short-exponent scheme (None = full).
    hs: Option<BigUint>,
    pool: VecDeque<BigUint>,
}

impl NoncePool {
    /// Create an empty pool. `short_exponent` selects the DJN strategy.
    pub fn new(pk: &PublicKey, short_exponent: bool) -> Self {
        NoncePool {
            pk: pk.clone(),
            hs: None,
            pool: VecDeque::new(),
        }
        .with_short(short_exponent)
    }

    fn with_short(mut self, short: bool) -> Self {
        if short {
            // h = -y^2 mod n for random y: a generator of the 2n-th residue
            // subgroup whp. We take y from a fixed-seed expansion of n so the
            // base is deterministic per key (it is public anyway).
            let y = self.pk.n.shr_bits(2).add_u64(3);
            let y2 = y.square().rem(&self.pk.n);
            let h = self.pk.n.sub(&y2); // -y^2 mod n
            self.hs = Some(self.pk.mont_n2.pow(&h, &self.pk.n));
        }
        self
    }

    /// Generate `count` nonces now (call off the critical path).
    pub fn refill<R: Rng64>(&mut self, rng: &mut R, count: usize) {
        for _ in 0..count {
            let rn = match &self.hs {
                Some(hs) => {
                    let rp = BigUint::random_bits(rng, SHORT_EXP_BITS);
                    self.pk.mont_n2.pow(hs, &rp)
                }
                None => {
                    let r = self.pk.sample_unit(rng);
                    self.pk.mont_n2.pow(&r, &self.pk.n)
                }
            };
            self.pool.push_back(rn);
        }
    }

    /// Parallel refill: the random exponents are drawn **serially** (the
    /// same RNG stream as [`Self::refill`], so the pool contents are
    /// bit-identical for any pool width) and the expensive modular
    /// exponentiations fan out over `exec`. This is the dominant per-batch
    /// cost of SPNN-HE, now one exponentiation per *packed* ciphertext.
    pub fn refill_parallel<R: Rng64>(&mut self, rng: &mut R, count: usize, exec: &ExecPool) {
        let exps: Vec<BigUint> = (0..count)
            .map(|_| match &self.hs {
                Some(_) => BigUint::random_bits(rng, SHORT_EXP_BITS),
                None => self.pk.sample_unit(rng),
            })
            .collect();
        let pk = &self.pk;
        let hs = self.hs.as_ref();
        let rns = exec.par_map(&exps, 1, |e| match hs {
            Some(hs) => pk.mont_n2.pow(hs, e),
            None => pk.mont_n2.pow(e, &pk.n),
        });
        self.pool.extend(rns);
    }

    /// Take one nonce; panics if the pool ran dry (a protocol bug: refill
    /// sizing is deterministic per batch).
    pub fn take(&mut self) -> BigUint {
        self.pool
            .pop_front()
            .expect("NoncePool exhausted — refill sizing bug")
    }

    pub fn remaining(&self) -> usize {
        self.pool.len()
    }

    /// Whether the pool uses the short-exponent strategy.
    pub fn is_short(&self) -> bool {
        self.hs.is_some()
    }
}
