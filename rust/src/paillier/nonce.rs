//! Precomputed encryption randomness (`r^n mod n^2`) pools.
//!
//! Every Paillier encryption needs one `r^n` — the only expensive part of
//! encryption once `g = n+1`. SPNN-HE encrypts `batch x h1_dim` values per
//! iteration, so the holders keep a pool that is refilled outside the
//! timed/critical path (the paper's offline/online split; SecureML makes the
//! same distinction for triples).
//!
//! Two refill strategies:
//! * `full`:  `r ← [1,n)`, `r^n mod n^2` — textbook, 1 `n_bits`-bit exponent
//!   (sliding-window).
//! * `short` (Damgård–Jurik–Nielsen): precompute `h_s = h^n mod n^2` once
//!   for a random quadratic non-residue-ish `h`, then each nonce is
//!   `h_s^{r'}` with a 400-bit `r'` — ~2.5x less exponent work at the same
//!   decisional-composite-residuosity hardness (DJN03 §4.2). Because `h_s`
//!   is **fixed per key**, the pool builds a [`FixedBaseTable`] over it once
//!   and every refill nonce is ~`400/w` table multiplies with zero
//!   squarings — the classic 4–8x on top of the short exponent.
//!
//! Pool entries are stored in Montgomery-resident form ([`MontElem`]): the
//! encryption path consumes them with a single `mont_mul` and never pays a
//! conversion (the ciphertext itself stays resident through the batch
//! pipeline — see [`super::pack`]).

use std::collections::VecDeque;

use crate::bignum::{BigUint, FixedBaseTable, MontElem};
use crate::exec::ExecPool;
use crate::rng::Rng64;

use super::PublicKey;

/// Short-exponent bit length (kappa = 400 per DJN recommendation for
/// ~128-bit security at 2048-bit moduli; conservative for smaller ones).
const SHORT_EXP_BITS: usize = 400;

/// Pool of ready-to-use `r^n mod n^2` values (Montgomery-resident).
pub struct NoncePool {
    pk: PublicKey,
    /// Fixed-base window table over `h_s = h^n mod n^2` for the
    /// short-exponent scheme (None = full strategy). Built once per key;
    /// shared by reference across the exec-pool refill workers.
    hs: Option<FixedBaseTable>,
    pool: VecDeque<MontElem>,
}

impl NoncePool {
    /// Create an empty pool. `short_exponent` selects the DJN strategy.
    pub fn new(pk: &PublicKey, short_exponent: bool) -> Self {
        NoncePool {
            pk: pk.clone(),
            hs: None,
            pool: VecDeque::new(),
        }
        .with_short(short_exponent)
    }

    fn with_short(mut self, short: bool) -> Self {
        if short {
            // h = -y^2 mod n for random y: a generator of the 2n-th residue
            // subgroup whp. We take y from a fixed-seed expansion of n so the
            // base is deterministic per key (it is public anyway).
            let y = self.pk.n.shr_bits(2).add_u64(3);
            let y2 = y.square().rem(&self.pk.n);
            let h = self.pk.n.sub(&y2); // -y^2 mod n
            let hs = self.pk.mont_n2.pow(&h, &self.pk.n);
            self.hs = Some(FixedBaseTable::for_bits(&self.pk.mont_n2, &hs, SHORT_EXP_BITS));
        }
        self
    }

    /// Generate `count` nonces now (call off the critical path).
    pub fn refill<R: Rng64>(&mut self, rng: &mut R, count: usize) {
        let _sp = crate::obs::span("crypto_nonce_refill_seconds");
        crate::obs::counter_add("crypto_nonces_total", count as u64);
        for _ in 0..count {
            let rn = match &self.hs {
                Some(tbl) => {
                    let rp = BigUint::random_bits(rng, SHORT_EXP_BITS);
                    tbl.pow(&self.pk.mont_n2, &rp)
                }
                None => {
                    let r = self.pk.sample_unit(rng);
                    self.pk.mont_n2.pow_elem(&self.pk.mont_n2.enter(&r), &self.pk.n)
                }
            };
            self.pool.push_back(rn);
        }
    }

    /// Parallel refill: the random exponents are drawn **serially** (the
    /// same RNG stream as [`Self::refill`], so the pool contents are
    /// bit-identical for any pool width) and the expensive modular
    /// exponentiations fan out over `exec`. This is the dominant per-batch
    /// cost of SPNN-HE, now one exponentiation per *packed* ciphertext.
    pub fn refill_parallel<R: Rng64>(&mut self, rng: &mut R, count: usize, exec: &ExecPool) {
        let _sp = crate::obs::span("crypto_nonce_refill_seconds");
        crate::obs::counter_add("crypto_nonces_total", count as u64);
        let exps: Vec<BigUint> = (0..count)
            .map(|_| match &self.hs {
                Some(_) => BigUint::random_bits(rng, SHORT_EXP_BITS),
                None => self.pk.sample_unit(rng),
            })
            .collect();
        let pk = &self.pk;
        let tbl = self.hs.as_ref();
        let rns = exec.par_map(&exps, 1, |e| match tbl {
            Some(tbl) => tbl.pow(&pk.mont_n2, e),
            None => pk.mont_n2.pow_elem(&pk.mont_n2.enter(e), &pk.n),
        });
        self.pool.extend(rns);
    }

    /// Take one nonce (a Montgomery-resident `r^n`); panics if the pool ran
    /// dry (a protocol bug: refill sizing is deterministic per batch).
    pub fn take(&mut self) -> MontElem {
        self.pool
            .pop_front()
            .expect("NoncePool exhausted — refill sizing bug")
    }

    pub fn remaining(&self) -> usize {
        self.pool.len()
    }

    /// Whether the pool uses the short-exponent strategy.
    pub fn is_short(&self) -> bool {
        self.hs.is_some()
    }
}
