//! Binary cross-entropy with logits (numerically stable), with mask support
//! matching the L2 jax graphs.

/// Mean masked BCE: `mean_i mask_i * [log(1+e^{z_i}) - y_i z_i] / sum(mask)`.
pub fn bce_with_logits(logits: &[f64], y: &[f64], mask: &[f64]) -> f64 {
    assert_eq!(logits.len(), y.len());
    assert_eq!(logits.len(), mask.len());
    let mut total = 0.0;
    let mut denom = 0.0;
    for i in 0..logits.len() {
        let z = logits[i];
        // log(1 + e^z) computed stably
        let softplus = if z > 0.0 { z + (-z).exp().ln_1p() } else { z.exp().ln_1p() };
        total += mask[i] * (softplus - y[i] * z);
        denom += mask[i];
    }
    total / denom.max(1.0)
}

/// Gradient of the mean masked BCE w.r.t. the logits:
/// `mask_i * (sigmoid(z_i) - y_i) / sum(mask)`.
pub fn bce_with_logits_grad(logits: &[f64], y: &[f64], mask: &[f64]) -> Vec<f64> {
    let denom: f64 = mask.iter().sum::<f64>().max(1.0);
    logits
        .iter()
        .zip(y)
        .zip(mask)
        .map(|((&z, &yi), &m)| m * (sigmoid(z) - yi) / denom)
        .collect()
}

#[inline]
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_at_zero_logits_is_ln2() {
        let n = 4;
        let loss = bce_with_logits(&vec![0.0; n], &[0., 1., 0., 1.], &vec![1.0; n]);
        assert!((loss - (2.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn grad_matches_finite_difference() {
        let logits = vec![0.3, -1.2, 2.0, 0.0];
        let y = vec![1.0, 0.0, 1.0, 0.0];
        let mask = vec![1.0, 1.0, 1.0, 0.0];
        let g = bce_with_logits_grad(&logits, &y, &mask);
        let eps = 1e-6;
        for i in 0..4 {
            let mut lp = logits.clone();
            lp[i] += eps;
            let mut lm = logits.clone();
            lm[i] -= eps;
            let fd = (bce_with_logits(&lp, &y, &mask) - bce_with_logits(&lm, &y, &mask))
                / (2.0 * eps);
            assert!((g[i] - fd).abs() < 1e-6, "i={i}: {} vs {fd}", g[i]);
        }
    }

    #[test]
    fn masked_rows_do_not_contribute() {
        let full = bce_with_logits(&[1.0, -2.0], &[1.0, 0.0], &[1.0, 1.0]);
        let padded = bce_with_logits(&[1.0, -2.0, 99.0], &[1.0, 0.0, 1.0], &[1.0, 1.0, 0.0]);
        assert!((full - padded).abs() < 1e-12);
    }

    #[test]
    fn extreme_logits_are_stable() {
        let loss = bce_with_logits(&[1000.0, -1000.0], &[1.0, 0.0], &[1.0, 1.0]);
        assert!(loss.is_finite() && loss < 1e-6);
        let g = bce_with_logits_grad(&[1000.0, -1000.0], &[0.0, 1.0], &[1.0, 1.0]);
        assert!((g[0] - 0.5).abs() < 1e-9 && (g[1] + 0.5).abs() < 1e-9);
    }
}
