//! Multi-layer perceptron with manual backprop.

use super::loss::sigmoid;
use super::MatF64;
use crate::rng::Rng64;

/// Layer activation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Identity,
    Sigmoid,
    Relu,
    Tanh,
}

impl Activation {
    pub fn apply(&self, x: f64) -> f64 {
        match self {
            Activation::Identity => x,
            Activation::Sigmoid => sigmoid(x),
            Activation::Relu => x.max(0.0),
            Activation::Tanh => x.tanh(),
        }
    }

    /// Derivative expressed in terms of the activation *output*.
    pub fn grad_from_output(&self, a: f64) -> f64 {
        match self {
            Activation::Identity => 1.0,
            Activation::Sigmoid => a * (1.0 - a),
            Activation::Relu => f64::from(a > 0.0),
            Activation::Tanh => 1.0 - a * a,
        }
    }
}

impl From<crate::config::Act> for Activation {
    fn from(a: crate::config::Act) -> Self {
        match a {
            crate::config::Act::Sigmoid => Activation::Sigmoid,
            crate::config::Act::Relu => Activation::Relu,
            crate::config::Act::Identity => Activation::Identity,
        }
    }
}

/// Fully-connected network: `dims[0] -> dims[1] -> ... -> dims.last()`,
/// one activation per layer. Bias per layer optional (SPNN's first layer
/// has no bias to match `h1 = X·theta`).
#[derive(Clone, Debug)]
pub struct Mlp {
    pub weights: Vec<MatF64>,
    pub biases: Vec<Vec<f64>>, // empty vec = no bias for that layer
    pub acts: Vec<Activation>,
}

/// Gradients with the same layout as [`Mlp`].
#[derive(Clone, Debug)]
pub struct MlpGrads {
    pub d_weights: Vec<MatF64>,
    pub d_biases: Vec<Vec<f64>>,
    /// Gradient w.r.t. the network input (chained to upstream models).
    pub d_input: MatF64,
}

impl Mlp {
    /// Xavier-initialized network. `with_bias[i]` controls layer i's bias.
    pub fn new<R: Rng64>(
        rng: &mut R,
        dims: &[usize],
        acts: &[Activation],
        with_bias: &[bool],
    ) -> Self {
        assert_eq!(dims.len() - 1, acts.len());
        assert_eq!(acts.len(), with_bias.len());
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for (i, win) in dims.windows(2).enumerate() {
            weights.push(MatF64::xavier(rng, win[0], win[1]));
            biases.push(if with_bias[i] { vec![0.0; win[1]] } else { vec![] });
        }
        Mlp { weights, biases, acts: acts.to_vec() }
    }

    pub fn n_layers(&self) -> usize {
        self.weights.len()
    }

    /// Forward pass returning every layer's activation output (index 0 is
    /// the input itself) for backprop.
    pub fn forward_cached(&self, x: &MatF64) -> Vec<MatF64> {
        let mut outs = Vec::with_capacity(self.n_layers() + 1);
        outs.push(x.clone());
        for l in 0..self.n_layers() {
            let mut z = outs[l].matmul(&self.weights[l]);
            if !self.biases[l].is_empty() {
                z = z.add_bias(&self.biases[l]);
            }
            let act = self.acts[l];
            outs.push(z.map(|v| act.apply(v)));
        }
        outs
    }

    /// Forward only (last activation).
    pub fn forward(&self, x: &MatF64) -> MatF64 {
        self.forward_cached(x).pop().unwrap()
    }

    /// Backprop from `d_out` (gradient w.r.t. the last activation output).
    pub fn backward(&self, cache: &[MatF64], d_out: &MatF64) -> MlpGrads {
        assert_eq!(cache.len(), self.n_layers() + 1);
        let mut d_weights = vec![MatF64::zeros(0, 0); self.n_layers()];
        let mut d_biases = vec![vec![]; self.n_layers()];
        let mut delta = d_out.clone();
        for l in (0..self.n_layers()).rev() {
            let a = &cache[l + 1];
            let act = self.acts[l];
            // delta at pre-activation
            let dz = delta.hadamard(&a.map(|v| act.grad_from_output(v)));
            d_weights[l] = cache[l].transpose().matmul(&dz);
            if !self.biases[l].is_empty() {
                d_biases[l] = dz.col_sums();
            }
            delta = dz.matmul(&self.weights[l].transpose());
        }
        MlpGrads { d_weights, d_biases, d_input: delta }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::loss::{bce_with_logits, bce_with_logits_grad};
    use crate::rng::Pcg64;

    fn toy_net(seed: u64) -> Mlp {
        let mut rng = Pcg64::seed_from_u64(seed);
        Mlp::new(
            &mut rng,
            &[5, 4, 3, 1],
            &[Activation::Sigmoid, Activation::Relu, Activation::Identity],
            &[false, true, true],
        )
    }

    #[test]
    fn forward_shapes() {
        let net = toy_net(1);
        let x = MatF64::zeros(7, 5);
        let cache = net.forward_cached(&x);
        assert_eq!(cache.len(), 4);
        assert_eq!(cache[1].shape(), (7, 4));
        assert_eq!(cache[3].shape(), (7, 1));
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut net = toy_net(2);
        let mut rng = Pcg64::seed_from_u64(3);
        let x = MatF64::gaussian(&mut rng, 6, 5, 1.0);
        let y: Vec<f64> = (0..6).map(|i| f64::from(i % 2 == 0)).collect();
        let mask = vec![1.0; 6];

        let loss_of = |net: &Mlp| -> f64 {
            let out = net.forward(&x);
            bce_with_logits(&out.data, &y, &mask)
        };

        // analytic gradients
        let cache = net.forward_cached(&x);
        let logits = &cache[net.n_layers()];
        let dlogit = bce_with_logits_grad(&logits.data, &y, &mask);
        let grads = net.backward(&cache, &MatF64::from_data(6, 1, dlogit));

        let eps = 1e-6;
        // check a sample of weight entries in every layer
        for l in 0..net.n_layers() {
            for &idx in &[0usize, net.weights[l].data.len() / 2] {
                let orig = net.weights[l].data[idx];
                net.weights[l].data[idx] = orig + eps;
                let lp = loss_of(&net);
                net.weights[l].data[idx] = orig - eps;
                let lm = loss_of(&net);
                net.weights[l].data[idx] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads.d_weights[l].data[idx];
                assert!(
                    (fd - an).abs() < 1e-5,
                    "layer {l} idx {idx}: fd {fd} vs {an}"
                );
            }
            if !net.biases[l].is_empty() {
                let orig = net.biases[l][0];
                net.biases[l][0] = orig + eps;
                let lp = loss_of(&net);
                net.biases[l][0] = orig - eps;
                let lm = loss_of(&net);
                net.biases[l][0] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                assert!((fd - grads.d_biases[l][0]).abs() < 1e-5, "bias {l}");
            }
        }
    }

    #[test]
    fn d_input_matches_finite_differences() {
        let net = toy_net(4);
        let mut rng = Pcg64::seed_from_u64(5);
        let mut x = MatF64::gaussian(&mut rng, 3, 5, 1.0);
        let y = vec![1.0, 0.0, 1.0];
        let mask = vec![1.0; 3];
        let cache = net.forward_cached(&x);
        let logits = &cache[net.n_layers()];
        let dlogit = bce_with_logits_grad(&logits.data, &y, &mask);
        let grads = net.backward(&cache, &MatF64::from_data(3, 1, dlogit));
        let eps = 1e-6;
        for idx in [0usize, 7, 14] {
            let orig = x.data[idx];
            x.data[idx] = orig + eps;
            let lp = bce_with_logits(&net.forward(&x).data, &y, &mask);
            x.data[idx] = orig - eps;
            let lm = bce_with_logits(&net.forward(&x).data, &y, &mask);
            x.data[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - grads.d_input.data[idx]).abs() < 1e-5, "idx {idx}");
        }
    }

    #[test]
    fn training_decreases_loss() {
        let mut net = toy_net(6);
        let mut rng = Pcg64::seed_from_u64(7);
        let x = MatF64::gaussian(&mut rng, 64, 5, 1.0);
        // separable labels
        let y: Vec<f64> = (0..64).map(|i| f64::from(x.at(i, 0) + x.at(i, 1) > 0.0)).collect();
        let mask = vec![1.0; 64];
        let mut losses = vec![];
        for _ in 0..200 {
            let cache = net.forward_cached(&x);
            let logits = &cache[net.n_layers()];
            losses.push(bce_with_logits(&logits.data, &y, &mask));
            let dlogit = bce_with_logits_grad(&logits.data, &y, &mask);
            let grads = net.backward(&cache, &MatF64::from_data(64, 1, dlogit));
            for l in 0..net.n_layers() {
                net.weights[l] = net.weights[l].sub(&grads.d_weights[l].scale(2.0));
                for (b, g) in net.biases[l].iter_mut().zip(&grads.d_biases[l]) {
                    *b -= 2.0 * g;
                }
            }
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.7),
            "{:?}",
            &losses[..3]
        );
    }
}
