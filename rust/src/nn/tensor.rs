//! Minimal row-major f64 matrix for the reference NN.
//!
//! `matmul` is row-banded over the process [`exec::pool`](crate::exec)
//! above a work threshold (each output row is still accumulated in serial
//! order, so results are bit-identical at any pool width) — the SPNN-HE
//! holders' local `X_j·theta_j` products ride this.

use crate::exec;
use crate::rng::{NormalSampler, Rng64};

/// Minimum multiply-accumulate count before matmul fans out.
const PAR_MIN_WORK: usize = 1 << 17;

/// Row-major f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct MatF64 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl MatF64 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatF64 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_data(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        MatF64 { rows, cols, data }
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        MatF64 { rows, cols, data: data.iter().map(|&v| v as f64).collect() }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    /// Xavier/Glorot-uniform initialization.
    pub fn xavier<R: Rng64>(rng: &mut R, rows: usize, cols: usize) -> Self {
        let limit = (6.0 / (rows + cols) as f64).sqrt();
        let data = (0..rows * cols)
            .map(|_| (rng.f64_unit() * 2.0 - 1.0) * limit)
            .collect();
        MatF64 { rows, cols, data }
    }

    /// Gaussian init with given std.
    pub fn gaussian<R: Rng64>(rng: &mut R, rows: usize, cols: usize, std: f64) -> Self {
        let mut ns = NormalSampler::new();
        let data = (0..rows * cols).map(|_| ns.sample(rng) * std).collect();
        MatF64 { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    pub fn matmul(&self, other: &Self) -> Self {
        assert_eq!(self.cols, other.rows, "matmul inner dim");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = vec![0.0f64; m * n];
        if n > 0 && m > 0 {
            let min_rows = (PAR_MIN_WORK / (k * n).max(1)).max(1);
            exec::pool().par_rows_mut(&mut out, n, min_rows, |row0, band| {
                for (bi, orow) in band.chunks_mut(n).enumerate() {
                    let i = row0 + bi;
                    for kk in 0..k {
                        let a = self.data[i * k + kk];
                        if a == 0.0 {
                            continue;
                        }
                        let brow = &other.data[kk * n..(kk + 1) * n];
                        for (o, &b) in orow.iter_mut().zip(brow) {
                            *o += a * b;
                        }
                    }
                }
            });
        }
        MatF64 { rows: m, cols: n, data: out }
    }

    pub fn transpose(&self) -> Self {
        let mut out = vec![0.0; self.rows * self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        MatF64 { rows: self.cols, cols: self.rows, data: out }
    }

    pub fn add(&self, other: &Self) -> Self {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        MatF64 { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Self) -> Self {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        MatF64 { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale(&self, s: f64) -> Self {
        MatF64 {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|v| v * s).collect(),
        }
    }

    /// Add a row-vector bias to every row.
    pub fn add_bias(&self, bias: &[f64]) -> Self {
        assert_eq!(bias.len(), self.cols);
        let mut out = self.clone();
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[r * self.cols + c] += bias[c];
            }
        }
        out
    }

    /// Column sums (bias gradient).
    pub fn col_sums(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[c] += self.data[r * self.cols + c];
            }
        }
        out
    }

    pub fn map(&self, f: impl Fn(f64) -> f64) -> Self {
        MatF64 {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    pub fn hadamard(&self, other: &Self) -> Self {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        MatF64 { rows: self.rows, cols: self.cols, data }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn matmul_known_values() {
        let a = MatF64::from_data(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = MatF64::from_data(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.matmul(&b).data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_and_bias() {
        let a = MatF64::from_data(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().data, vec![1., 4., 2., 5., 3., 6.]);
        let ab = a.add_bias(&[10.0, 20.0, 30.0]);
        assert_eq!(ab.data, vec![11., 22., 33., 14., 25., 36.]);
        assert_eq!(a.col_sums(), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn xavier_scale_is_sane() {
        let mut rng = Pcg64::seed_from_u64(1);
        let m = MatF64::xavier(&mut rng, 100, 50);
        let limit = (6.0f64 / 150.0).sqrt();
        assert!(m.data.iter().all(|v| v.abs() <= limit));
        let mean: f64 = m.data.iter().sum::<f64>() / m.data.len() as f64;
        assert!(mean.abs() < 0.02, "{mean}");
    }
}
