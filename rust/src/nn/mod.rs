//! Pure-rust reference neural network (f64).
//!
//! Three jobs:
//! 1. the SplitNN baseline's *holder-side encoders* (each data holder trains
//!    a private bottom network — tiny, so native rust is the right tool),
//! 2. the logistic-regression attacker for the Table 2 property attack,
//! 3. an independent correctness oracle for the PJRT/JAX pipeline.

mod loss;
mod mlp;
mod optimizer;
mod tensor;

pub use loss::{bce_with_logits, bce_with_logits_grad};
pub use mlp::{Activation, Mlp, MlpGrads};
pub use optimizer::{Optimizer, Sgd, Sgld};
pub use tensor::MatF64;
