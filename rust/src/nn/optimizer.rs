//! Parameter update rules: SGD and SGLD (paper Eq. 1 / Eq. 2).
//!
//! SGLD is the paper's leakage mitigation (§4.6): gradient steps get an
//! isotropic Gaussian perturbation `eta_t ~ N(0, alpha_t I)`, i.e. std
//! `sqrt(alpha_t)`, with the gradient term scaled by `alpha_t / 2`. Table 2
//! measures the resulting drop in property-inference attack AUC.

use crate::rng::{NormalSampler, Pcg64, Rng64};

/// Update rule applied elementwise to a parameter slice.
pub trait Optimizer {
    /// Apply one step given `grads` (same length as `params`).
    fn step(&mut self, params: &mut [f64], grads: &[f64]);

    /// Current learning rate (for logging).
    fn lr(&self) -> f64;
}

/// Plain SGD: `theta <- theta - alpha * g`.
pub struct Sgd {
    pub alpha: f64,
}

impl Sgd {
    pub fn new(alpha: f64) -> Self {
        Sgd { alpha }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [f64], grads: &[f64]) {
        assert_eq!(params.len(), grads.len());
        for (p, g) in params.iter_mut().zip(grads) {
            *p -= self.alpha * g;
        }
    }

    fn lr(&self) -> f64 {
        self.alpha
    }
}

/// SGLD: `theta <- theta - (alpha_t/2 * g + eta_t)`, `eta_t ~ N(0, alpha_t)`.
///
/// The schedule decays `alpha_t = alpha0 / (1 + t * decay)` so the noise
/// anneals as training converges (Welling & Teh 2011).
pub struct Sgld {
    pub alpha0: f64,
    pub decay: f64,
    t: u64,
    rng: Pcg64,
    ns: NormalSampler,
    /// Scale factor on the injected noise (1.0 = textbook SGLD; smaller
    /// values interpolate toward SGD for ablations).
    pub noise_scale: f64,
}

impl Sgld {
    pub fn new(alpha0: f64, seed: u64) -> Self {
        Sgld {
            alpha0,
            decay: 1e-4,
            t: 0,
            rng: Pcg64::seed_from_u64(seed),
            ns: NormalSampler::new(),
            noise_scale: 1.0,
        }
    }

    pub fn alpha_t(&self) -> f64 {
        self.alpha0 / (1.0 + self.t as f64 * self.decay)
    }

    /// Advance the step counter (call once per iteration, after updating
    /// all parameter groups with the same `alpha_t`).
    pub fn tick(&mut self) {
        self.t += 1;
    }
}

impl Optimizer for Sgld {
    fn step(&mut self, params: &mut [f64], grads: &[f64]) {
        assert_eq!(params.len(), grads.len());
        let a = self.alpha_t();
        let sigma = a.sqrt() * self.noise_scale;
        for (p, g) in params.iter_mut().zip(grads) {
            let eta = sigma * self.ns.sample(&mut self.rng);
            *p -= a / 2.0 * g + eta;
        }
    }

    fn lr(&self) -> f64 {
        self.alpha_t()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_step_is_exact() {
        let mut p = vec![1.0, 2.0];
        Sgd::new(0.1).step(&mut p, &[10.0, -10.0]);
        assert_eq!(p, vec![0.0, 3.0]);
    }

    #[test]
    fn sgld_noise_has_requested_variance() {
        let mut opt = Sgld::new(0.01, 42);
        let n = 50_000;
        let mut p = vec![0.0; n];
        opt.step(&mut p, &vec![0.0; n]); // pure noise step
        let var: f64 = p.iter().map(|v| v * v).sum::<f64>() / n as f64;
        assert!((var - 0.01).abs() < 0.001, "noise var {var}");
    }

    #[test]
    fn sgld_gradient_term_is_half_alpha() {
        let mut opt = Sgld::new(0.01, 1);
        opt.noise_scale = 0.0; // isolate the deterministic part
        let mut p = vec![1.0];
        opt.step(&mut p, &[2.0]);
        assert!((p[0] - (1.0 - 0.01 / 2.0 * 2.0)).abs() < 1e-12);
    }

    #[test]
    fn sgld_schedule_decays() {
        let mut opt = Sgld::new(0.1, 2);
        let a0 = opt.alpha_t();
        for _ in 0..1000 {
            opt.tick();
        }
        assert!(opt.alpha_t() < a0);
        assert!(opt.alpha_t() > 0.0);
    }

    #[test]
    fn sgld_converges_on_quadratic_despite_noise() {
        // minimize (x-3)^2 — SGLD should get near 3 on average
        let mut opt = Sgld::new(0.05, 3);
        let mut p = vec![0.0];
        for _ in 0..3000 {
            let g = 2.0 * (p[0] - 3.0);
            opt.step(&mut p, &[g]);
            opt.tick();
        }
        assert!((p[0] - 3.0).abs() < 1.0, "ended at {}", p[0]);
    }
}
