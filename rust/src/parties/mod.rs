//! Decentralized party harness (paper §5, Figure 3).
//!
//! A deployment is a set of named parties — coordinator, server, dealer,
//! data holders — each running as its own thread connected through the
//! [`netsim`](crate::netsim) mesh. The coordinator only ever exchanges
//! [`Payload::Control`] messages: it splits the computation graph (decides
//! each party's role parameters), starts training, monitors per-epoch
//! status, and terminates the run — it can never touch features, labels or
//! shares, which is enforced by the message types it sends/accepts.
//!
//! Inside a deployment every worker drives its mini-batch loop through the
//! pipelined session framework (`protocols::common::run_pipeline`), which
//! keeps up to `TrainConfig::pipeline_depth` batches of value-independent
//! work in flight; the coordinator handshake stays strictly sequential.

use std::sync::Arc;

use crate::netsim::{full_mesh, LinkSpec, NetPort, NetStats, PartyId, Payload};
use crate::{Error, Result};

/// Canonical party ids used by all protocol deployments.
pub mod ids {
    use super::PartyId;
    pub const COORDINATOR: PartyId = 0;
    pub const SERVER: PartyId = 1;
    pub const DEALER: PartyId = 2;
    /// First data holder (A — owns the labels).
    pub const HOLDER0: PartyId = 3;

    pub fn holder(i: usize) -> PartyId {
        HOLDER0 + i
    }
}

/// What each party thread returns to the harness.
#[derive(Clone, Debug, Default)]
pub struct PartyOut {
    /// Final virtual-clock value (simulated seconds).
    pub sim_time: f64,
    /// Per-epoch simulated time (parties that track epochs).
    pub epoch_times: Vec<f64>,
    /// Per-epoch average training loss (label holder / server).
    pub epoch_losses: Vec<f64>,
    /// Bit-exact digest of the weights this party finished with (parties
    /// that own the full model, e.g. the plaintext trainer); 0 = unset.
    pub weight_digest: u64,
    /// Free-form key=value metrics.
    pub metrics: Vec<(String, f64)>,
}

/// Spawn one thread per party function and join them all.
///
/// `fns[i]` runs as party id `i` (see [`ids`]). Panics in any party are
/// converted into errors naming the party, and the mesh statistics are
/// returned for traffic reporting.
pub fn run_parties(
    names: &[&str],
    spec: LinkSpec,
    fns: Vec<Box<dyn FnOnce(NetPort) -> Result<PartyOut> + Send>>,
) -> Result<(Vec<PartyOut>, Arc<NetStats>)> {
    assert_eq!(names.len(), fns.len());
    let (ports, stats) = full_mesh(names, spec);
    let mut handles = Vec::new();
    for ((port, f), name) in ports.into_iter().zip(fns).zip(names) {
        let name = name.to_string();
        handles.push((
            name.clone(),
            std::thread::Builder::new()
                .name(name)
                .spawn(move || f(port))
                .map_err(Error::Io)?,
        ));
    }
    let mut outs = Vec::new();
    let mut first_err = None;
    for (name, h) in handles {
        match h.join() {
            Ok(Ok(out)) => outs.push(out),
            Ok(Err(e)) => {
                first_err.get_or_insert(Error::Protocol(format!("party {name}: {e}")));
                outs.push(PartyOut::default());
            }
            Err(_) => {
                first_err.get_or_insert(Error::Protocol(format!("party {name} panicked")));
                outs.push(PartyOut::default());
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok((outs, stats)),
    }
}

// ---------------------------------------------------------------------------
// Coordinator protocol
// ---------------------------------------------------------------------------

/// Coordinator role: broadcast start, collect one status per epoch from the
/// `reporter` party, broadcast stop. Returns the reported epoch losses.
pub fn coordinator_run(
    port: &mut NetPort,
    workers: &[PartyId],
    reporter: PartyId,
    epochs: usize,
) -> Result<PartyOut> {
    for &w in workers {
        port.send(w, Payload::Control(format!("start:{epochs}")))?;
    }
    let mut losses = Vec::with_capacity(epochs);
    for _ in 0..epochs {
        let status = port.recv(reporter)?.into_control()?;
        let loss = status
            .strip_prefix("epoch_done:")
            .and_then(|s| s.parse::<f64>().ok())
            .ok_or_else(|| Error::Protocol(format!("bad status {status:?}")))?;
        losses.push(loss);
    }
    for &w in workers {
        port.send(w, Payload::Control("stop".into()))?;
    }
    Ok(PartyOut {
        sim_time: port.now(),
        epoch_losses: losses,
        ..Default::default()
    })
}

/// Worker-side handshake: wait for the coordinator's start order.
pub fn await_start(port: &mut NetPort) -> Result<usize> {
    let msg = port.recv(ids::COORDINATOR)?.into_control()?;
    msg.strip_prefix("start:")
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::Protocol(format!("expected start order, got {msg:?}")))
}

/// Reporter-side: send the epoch status to the coordinator.
pub fn report_epoch(port: &mut NetPort, loss: f64) -> Result<()> {
    port.send(ids::COORDINATOR, Payload::Control(format!("epoch_done:{loss}")))
}

/// Worker-side: consume the final stop order.
pub fn await_stop(port: &mut NetPort) -> Result<()> {
    let msg = port.recv(ids::COORDINATOR)?.into_control()?;
    if msg != "stop" {
        return Err(Error::Protocol(format!("expected stop, got {msg:?}")));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_runs_and_collects() {
        let fns: Vec<Box<dyn FnOnce(NetPort) -> Result<PartyOut> + Send>> = vec![
            Box::new(|mut p: NetPort| {
                p.send(1, Payload::Control("hi".into()))?;
                Ok(PartyOut { metrics: vec![("x".into(), 1.0)], ..Default::default() })
            }),
            Box::new(|mut p: NetPort| {
                let m = p.recv(0)?.into_control()?;
                assert_eq!(m, "hi");
                Ok(PartyOut::default())
            }),
        ];
        let (outs, stats) = run_parties(&["a", "b"], LinkSpec::lan(), fns).unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].metrics[0].0, "x");
        assert!(stats.total_bytes() > 0);
    }

    #[test]
    fn party_error_is_named() {
        let fns: Vec<Box<dyn FnOnce(NetPort) -> Result<PartyOut> + Send>> = vec![
            Box::new(|_p| Err(Error::Protocol("boom".into()))),
            Box::new(|_p| Ok(PartyOut::default())),
        ];
        let err = run_parties(&["bad", "ok"], LinkSpec::lan(), fns).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("bad") && msg.contains("boom"), "{msg}");
    }

    #[test]
    fn coordinator_roundtrip() {
        let fns: Vec<Box<dyn FnOnce(NetPort) -> Result<PartyOut> + Send>> = vec![
            Box::new(|mut p: NetPort| coordinator_run(&mut p, &[1], 1, 2)),
            Box::new(|mut p: NetPort| {
                let epochs = await_start(&mut p)?;
                assert_eq!(epochs, 2);
                for e in 0..epochs {
                    report_epoch(&mut p, 0.5 - e as f64 * 0.1)?;
                }
                await_stop(&mut p)?;
                Ok(PartyOut::default())
            }),
        ];
        let (outs, _) = run_parties(&["coord", "w"], LinkSpec::lan(), fns).unwrap();
        assert_eq!(outs[0].epoch_losses, vec![0.5, 0.4]);
    }
}
