//! Decentralized party harness (paper §5, Figure 3).
//!
//! A deployment is a set of named parties — coordinator, server, dealer,
//! data holders — each running its role body against a
//! [`Channel`](crate::transport::Channel). The same boxed role closures
//! ([`PartyFn`]) run in three execution modes:
//!
//! * **in-process / netsim** — one thread per party over the
//!   [`netsim`](crate::netsim) mesh (the seed behavior),
//! * **in-process / TCP** — one thread per party over real loopback
//!   sockets ([`crate::transport::tcp::loopback_mesh`]),
//! * **multi-process** — one OS process per party over TCP, rendezvoused
//!   by the session handshake and driven by
//!   [`crate::transport::runner`] (`spnn party` / `spnn launch`).
//!
//! The coordinator only ever exchanges [`Payload::Control`] messages: it
//! splits the computation graph (decides each party's role parameters),
//! starts training, monitors per-epoch status, and terminates the run — it
//! can never touch features, labels or shares, which is enforced by the
//! message types it sends/accepts. Each party returns a [`PartyOut`] with
//! its metrics and (for evaluation only) its final parameter blocks; in
//! multi-process mode the blocks travel to the coordinator over the wire
//! ([`send_party_out`] / [`recv_party_out`]) instead of shared memory.

use std::sync::Arc;

use crate::config::TransportKind;
use crate::netsim::{full_mesh, LinkSpec, NetPort, NetStats, PartyId, Payload, Phase, StageRow};
use crate::obs::trace;
use crate::transport::{tcp, Channel};
use crate::{Error, Result};

/// Canonical party ids used by all protocol deployments.
pub mod ids {
    use super::PartyId;
    pub const COORDINATOR: PartyId = 0;
    pub const SERVER: PartyId = 1;
    pub const DEALER: PartyId = 2;
    /// First data holder (A — owns the labels).
    pub const HOLDER0: PartyId = 3;

    pub fn holder(i: usize) -> PartyId {
        HOLDER0 + i
    }
}

/// One party's role body, runnable on any transport backend.
pub type PartyFn = Box<dyn FnOnce(&mut dyn Channel) -> Result<PartyOut> + Send>;

/// A protocol's full party roster: role names (index = party id; name
/// doubles as the `spnn party --role` claim) and the role bodies.
pub struct Deployment {
    pub names: Vec<String>,
    pub fns: Vec<PartyFn>,
}

/// What each party returns to the harness.
#[derive(Clone, Debug, Default)]
pub struct PartyOut {
    /// Final virtual-clock value (simulated seconds).
    pub sim_time: f64,
    /// Per-epoch simulated time (parties that track epochs).
    pub epoch_times: Vec<f64>,
    /// Per-epoch average training loss (label holder / server).
    pub epoch_losses: Vec<f64>,
    /// Bit-exact digest of the weights this party finished with (parties
    /// that own the full model, e.g. the plaintext trainer); 0 = unset.
    pub weight_digest: u64,
    /// Free-form key=value metrics.
    pub metrics: Vec<(String, f64)>,
    /// Named final-parameter blocks this party contributes to the
    /// evaluation harness (bit-exact f64s; assembled by the trainer's
    /// `finish` step on whichever process collects the outputs).
    pub params: Vec<(String, Vec<f64>)>,
    /// This party's sender-side per-stage traffic rows (multi-process
    /// mode ships them to the coordinator, which merges all parties'
    /// rows into the whole-mesh Table-3b breakdown via
    /// [`crate::netsim::merge_stage_rows`]).
    pub stages: Vec<StageRow>,
    /// This party's observability snapshot ([`crate::obs::Registry::export`]
    /// rows: counters, gauges, latency histograms). Multi-process mode
    /// ships them home with the rest of the output and the coordinator
    /// [`crate::obs::Registry::absorb`]s them — the timing sibling of
    /// `stages`.
    pub timings: Vec<(String, Vec<f64>)>,
}

impl PartyOut {
    /// Look up a parameter block by name.
    pub fn param(&self, name: &str) -> Option<&[f64]> {
        self.params.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_slice())
    }

    /// Required parameter block (protocol error when missing).
    pub fn need_param(&self, name: &str) -> Result<&[f64]> {
        self.param(name)
            .ok_or_else(|| Error::Protocol(format!("missing final-parameter block {name:?}")))
    }

    /// Look up a metric by name.
    pub fn metric(&self, name: &str) -> Option<f64> {
        self.metrics.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }
}

/// Whole-mesh traffic totals handed to the trainer's `finish` step —
/// built from the shared [`NetStats`] in-process, or reassembled from the
/// parties' sender-side counters in multi-process mode.
#[derive(Clone, Debug, Default)]
pub struct NetSummary {
    pub online_bytes: usize,
    pub offline_bytes: usize,
    /// Per-phase / per-stage traffic breakdown. In multi-process mode this
    /// covers only the collecting process's own links (each process keeps
    /// its own stage map); the byte totals above are whole-mesh either way.
    pub stages: Vec<StageRow>,
}

impl NetSummary {
    pub fn from_stats(stats: &NetStats) -> Self {
        NetSummary {
            online_bytes: stats.bytes_phase(Phase::Online),
            offline_bytes: stats.bytes_phase(Phase::Offline),
            stages: stats.stage_rows(),
        }
    }
}

/// Run every party of `dep` in this process — one thread each — over the
/// selected transport backend, and join them all.
///
/// Panics in any party are converted into errors naming the party, and
/// the mesh-wide traffic summary is returned for reporting.
pub fn run_parties(
    spec: LinkSpec,
    kind: TransportKind,
    dep: Deployment,
) -> Result<(Vec<PartyOut>, NetSummary)> {
    let Deployment { names, fns } = dep;
    assert_eq!(names.len(), fns.len());
    let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let (ports, stats): (Vec<_>, Arc<NetStats>) = match kind {
        TransportKind::Netsim => full_mesh(&name_refs, spec),
        TransportKind::Tcp => tcp::loopback_mesh(&name_refs, spec)?,
        TransportKind::Uds => uds_mesh(&name_refs, spec)?,
    };
    // party threads inherit the caller's trace session id, so one
    // process hosting several sessions (tests, benches) can split the
    // trace per session afterwards
    let sid = trace::sid();
    let mut handles = Vec::new();
    for ((mut port, f), name) in ports.into_iter().zip(fns).zip(&names) {
        let name = name.clone();
        handles.push((
            name.clone(),
            std::thread::Builder::new()
                .name(name)
                .spawn(move || {
                    trace::set_sid(sid);
                    f(&mut port)
                })
                .map_err(Error::Io)?,
        ));
    }
    let mut outs = Vec::new();
    let mut first_err = None;
    for (name, h) in handles {
        match h.join() {
            Ok(Ok(out)) => outs.push(out),
            Ok(Err(e)) => {
                first_err.get_or_insert(Error::Protocol(format!("party {name}: {e}")));
                outs.push(PartyOut::default());
            }
            Err(_) => {
                first_err.get_or_insert(Error::Protocol(format!("party {name} panicked")));
                outs.push(PartyOut::default());
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok((outs, NetSummary::from_stats(&stats))),
    }
}

/// Unix-domain socketpair mesh (co-located parties).
#[cfg(unix)]
fn uds_mesh(names: &[&str], spec: LinkSpec) -> Result<(Vec<NetPort>, Arc<NetStats>)> {
    crate::transport::uds::pair_mesh(names, spec)
}

/// The uds transport is a unix-only backend.
#[cfg(not(unix))]
fn uds_mesh(_names: &[&str], _spec: LinkSpec) -> Result<(Vec<NetPort>, Arc<NetStats>)> {
    Err(Error::Config("the uds transport requires a unix platform".into()))
}

// ---------------------------------------------------------------------------
// Coordinator protocol
// ---------------------------------------------------------------------------

/// Coordinator role: broadcast start, collect one status per epoch from the
/// `reporter` party, broadcast stop. Returns the reported epoch losses.
pub fn coordinator_run(
    port: &mut dyn Channel,
    workers: &[PartyId],
    reporter: PartyId,
    epochs: usize,
) -> Result<PartyOut> {
    trace::emit(
        port.id(),
        "virt",
        port.now(),
        "run_start",
        &[
            ("epochs", trace::Val::U(epochs as u64)),
            ("workers", trace::Val::U(workers.len() as u64)),
        ],
    );
    for &w in workers {
        port.send(w, Payload::Control(format!("start:{epochs}")))?;
    }
    let mut losses = Vec::with_capacity(epochs);
    for e in 0..epochs {
        let status = port.recv(reporter)?.into_control()?;
        let loss = status
            .strip_prefix("epoch_done:")
            .and_then(|s| s.parse::<f64>().ok())
            .ok_or_else(|| Error::Protocol(format!("bad status {status:?}")))?;
        trace::emit(
            port.id(),
            "virt",
            port.now(),
            "epoch",
            &[("epoch", trace::Val::U(e as u64)), ("loss", trace::Val::F(loss))],
        );
        losses.push(loss);
    }
    for &w in workers {
        port.send(w, Payload::Control("stop".into()))?;
    }
    trace::emit(port.id(), "virt", port.now(), "run_stop", &[]);
    Ok(PartyOut {
        sim_time: port.now(),
        epoch_losses: losses,
        ..Default::default()
    })
}

/// Worker-side handshake: wait for the coordinator's start order.
pub fn await_start(port: &mut dyn Channel) -> Result<usize> {
    let msg = port.recv(ids::COORDINATOR)?.into_control()?;
    msg.strip_prefix("start:")
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::Protocol(format!("expected start order, got {msg:?}")))
}

/// Reporter-side: send the epoch status to the coordinator.
pub fn report_epoch(port: &mut dyn Channel, loss: f64) -> Result<()> {
    port.send(ids::COORDINATOR, Payload::Control(format!("epoch_done:{loss}")))
}

/// Worker-side: consume the final stop order.
pub fn await_stop(port: &mut dyn Channel) -> Result<()> {
    let msg = port.recv(ids::COORDINATOR)?.into_control()?;
    if msg != "stop" {
        return Err(Error::Protocol(format!("expected stop, got {msg:?}")));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// PartyOut over the wire (multi-process result collection)
// ---------------------------------------------------------------------------

/// Ship a finished party's [`PartyOut`] to the collector (party 0 in the
/// multi-process runner). Counted as offline traffic: result collection
/// is harness bookkeeping, not protocol cost.
pub fn send_party_out(port: &mut dyn Channel, to: PartyId, out: &PartyOut) -> Result<()> {
    port.send_phase(
        to,
        Payload::Control(format!(
            "partyout {} {} {} {} {} {}",
            out.metrics.len(),
            out.params.len(),
            out.stages.len(),
            out.timings.len(),
            out.weight_digest,
            out.sim_time,
        )),
        Phase::Offline,
    )?;
    port.send_phase(to, Payload::F64s(out.epoch_times.clone()), Phase::Offline)?;
    port.send_phase(to, Payload::F64s(out.epoch_losses.clone()), Phase::Offline)?;
    for (name, v) in &out.metrics {
        port.send_phase(to, Payload::Control(name.clone()), Phase::Offline)?;
        port.send_phase(to, Payload::F64s(vec![*v]), Phase::Offline)?;
    }
    for (name, data) in &out.params {
        port.send_phase(to, Payload::Control(name.clone()), Phase::Offline)?;
        port.send_phase(to, Payload::F64s(data.clone()), Phase::Offline)?;
    }
    for (name, data) in &out.timings {
        port.send_phase(to, Payload::Control(name.clone()), Phase::Offline)?;
        port.send_phase(to, Payload::F64s(data.clone()), Phase::Offline)?;
    }
    for r in &out.stages {
        let phase = match r.phase {
            Phase::Online => "on",
            Phase::Offline => "off",
        };
        // stage name last: it is the only free-form field
        port.send_phase(
            to,
            Payload::Control(format!(
                "stage {phase} {} {} {} {}",
                r.bytes, r.msgs, r.wire_s, r.stage
            )),
            Phase::Offline,
        )?;
    }
    Ok(())
}

/// Collector side of [`send_party_out`].
pub fn recv_party_out(port: &mut dyn Channel, from: PartyId) -> Result<PartyOut> {
    let header = port.recv(from)?.into_control()?;
    let fields: Vec<&str> = header.split_whitespace().collect();
    if fields.len() != 7 || fields[0] != "partyout" {
        return Err(Error::Protocol(format!("bad partyout header {header:?}")));
    }
    let parse = |s: &str| -> Result<usize> {
        s.parse().map_err(|_| Error::Protocol(format!("bad partyout count {s:?}")))
    };
    let n_metrics = parse(fields[1])?;
    let n_params = parse(fields[2])?;
    let n_stages = parse(fields[3])?;
    let n_timings = parse(fields[4])?;
    let weight_digest: u64 = fields[5]
        .parse()
        .map_err(|_| Error::Protocol(format!("bad partyout digest {:?}", fields[5])))?;
    let sim_time: f64 = fields[6]
        .parse()
        .map_err(|_| Error::Protocol(format!("bad partyout sim_time {:?}", fields[6])))?;
    let epoch_times = port.recv(from)?.into_f64s()?;
    let epoch_losses = port.recv(from)?.into_f64s()?;
    let mut metrics = Vec::with_capacity(n_metrics);
    for _ in 0..n_metrics {
        let name = port.recv(from)?.into_control()?;
        let v = port.recv(from)?.into_f64s()?;
        metrics.push((name, v.first().copied().unwrap_or(f64::NAN)));
    }
    let mut params = Vec::with_capacity(n_params);
    for _ in 0..n_params {
        let name = port.recv(from)?.into_control()?;
        params.push((name, port.recv(from)?.into_f64s()?));
    }
    let mut timings = Vec::with_capacity(n_timings);
    for _ in 0..n_timings {
        let name = port.recv(from)?.into_control()?;
        timings.push((name, port.recv(from)?.into_f64s()?));
    }
    let mut stages = Vec::with_capacity(n_stages);
    for _ in 0..n_stages {
        let row = port.recv(from)?.into_control()?;
        let rest = row
            .strip_prefix("stage ")
            .ok_or_else(|| Error::Protocol(format!("bad stage row {row:?}")))?;
        let mut it = rest.splitn(5, ' ');
        let bad = || Error::Protocol(format!("bad stage row {row:?}"));
        let phase = match it.next().ok_or_else(bad)? {
            "on" => Phase::Online,
            "off" => Phase::Offline,
            _ => return Err(bad()),
        };
        let bytes: u64 = it.next().ok_or_else(bad)?.parse().map_err(|_| bad())?;
        let msgs: u64 = it.next().ok_or_else(bad)?.parse().map_err(|_| bad())?;
        let wire_s: f64 = it.next().ok_or_else(bad)?.parse().map_err(|_| bad())?;
        let stage = it.next().ok_or_else(bad)?.to_string();
        stages.push(StageRow { phase, stage, bytes, msgs, wire_s });
    }
    Ok(PartyOut {
        sim_time,
        epoch_times,
        epoch_losses,
        weight_digest,
        metrics,
        params,
        stages,
        timings,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_party_dep(fa: PartyFn, fb: PartyFn) -> Deployment {
        Deployment { names: vec!["a".into(), "b".into()], fns: vec![fa, fb] }
    }

    #[test]
    fn harness_runs_and_collects() {
        for kind in [TransportKind::Netsim, TransportKind::Tcp, TransportKind::Uds] {
            let dep = two_party_dep(
                Box::new(|p: &mut dyn Channel| {
                    p.send(1, Payload::Control("hi".into()))?;
                    Ok(PartyOut { metrics: vec![("x".into(), 1.0)], ..Default::default() })
                }),
                Box::new(|p: &mut dyn Channel| {
                    let m = p.recv(0)?.into_control()?;
                    assert_eq!(m, "hi");
                    Ok(PartyOut::default())
                }),
            );
            let (outs, net) = run_parties(LinkSpec::lan(), kind, dep).unwrap();
            assert_eq!(outs.len(), 2);
            assert_eq!(outs[0].metrics[0].0, "x");
            assert_eq!(outs[0].metric("x"), Some(1.0));
            assert!(net.online_bytes > 0, "no traffic accounted on {kind:?}");
        }
    }

    #[test]
    fn party_error_is_named() {
        let dep = Deployment {
            names: vec!["bad".into(), "ok".into()],
            fns: vec![
                Box::new(|_p: &mut dyn Channel| Err(Error::Protocol("boom".into()))),
                Box::new(|_p: &mut dyn Channel| Ok(PartyOut::default())),
            ],
        };
        let err = run_parties(LinkSpec::lan(), TransportKind::Netsim, dep).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("bad") && msg.contains("boom"), "{msg}");
    }

    #[test]
    fn coordinator_roundtrip() {
        let dep = two_party_dep(
            Box::new(|p: &mut dyn Channel| coordinator_run(p, &[1], 1, 2)),
            Box::new(|p: &mut dyn Channel| {
                let epochs = await_start(p)?;
                assert_eq!(epochs, 2);
                for e in 0..epochs {
                    report_epoch(p, 0.5 - e as f64 * 0.1)?;
                }
                await_stop(p)?;
                Ok(PartyOut::default())
            }),
        );
        let (outs, _) = run_parties(LinkSpec::lan(), TransportKind::Netsim, dep).unwrap();
        assert_eq!(outs[0].epoch_losses, vec![0.5, 0.4]);
    }

    #[test]
    fn party_out_roundtrips_over_any_channel() {
        let sent = PartyOut {
            sim_time: 12.5,
            epoch_times: vec![1.0, 2.0],
            epoch_losses: vec![0.7],
            weight_digest: 0xdead_beef_cafe_f00d,
            metrics: vec![("auc".into(), 0.91), ("bytes".into(), 123.0)],
            params: vec![("theta".into(), vec![1.5, -2.5]), ("by".into(), vec![])],
            stages: vec![
                StageRow {
                    phase: Phase::Online,
                    stage: "fwd".into(),
                    bytes: 9,
                    msgs: 2,
                    wire_s: 0.5,
                },
                StageRow {
                    phase: Phase::Offline,
                    stage: "triple".into(),
                    bytes: 4,
                    msgs: 1,
                    wire_s: 0.0,
                },
            ],
            timings: vec![
                ("c:serve_requests_total".into(), vec![7.0]),
                ("h:serve_request_seconds".into(), vec![2.0, 3_000_000.0, 40.0, 2.0]),
            ],
        };
        let expect = sent.clone();
        let dep = two_party_dep(
            Box::new(move |p: &mut dyn Channel| {
                send_party_out(p, 1, &sent)?;
                Ok(PartyOut::default())
            }),
            Box::new(move |p: &mut dyn Channel| recv_party_out(p, 0)),
        );
        let (outs, net) = run_parties(LinkSpec::lan(), TransportKind::Tcp, dep).unwrap();
        let got = &outs[1];
        assert_eq!(got.sim_time, expect.sim_time);
        assert_eq!(got.epoch_times, expect.epoch_times);
        assert_eq!(got.epoch_losses, expect.epoch_losses);
        assert_eq!(got.weight_digest, expect.weight_digest);
        assert_eq!(got.metrics, expect.metrics);
        assert_eq!(got.params, expect.params);
        assert_eq!(got.stages, expect.stages);
        assert_eq!(got.timings, expect.timings);
        assert_eq!(got.need_param("theta").unwrap(), &[1.5, -2.5]);
        assert!(got.need_param("nope").is_err());
        // result collection is offline traffic
        assert_eq!(net.online_bytes, 0);
        assert!(net.offline_bytes > 0);
    }
}
