//! Stand-in for the `xla` PJRT bindings (xla-rs surface).
//!
//! The container's build is offline and the xla_extension shared objects
//! are not linkable from `cargo test`, so this shim keeps the
//! [`Engine`](super::Engine) code compiling against the exact call surface
//! the real bindings expose and fails fast at client construction with an
//! actionable message. Nothing reaches these paths in a stub build:
//! [`Engine::load`](super::Engine::load) first requires
//! `artifacts/manifest.txt` (produced by `make artifacts`), and every
//! artifact-gated test skips when it is absent. Swapping the real
//! `xla = "0.5"` bindings back in is a one-line change in Cargo.toml plus
//! deleting this module.

use std::fmt;

/// Error surfaced by the (stub) XLA runtime.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

type XlaResult<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> XlaResult<T> {
    Err(Error(format!(
        "{what}: XLA PJRT backend is not linked in this build (offline stub); \
         run `make artifacts` and build against the real xla bindings"
    )))
}

/// Stub PJRT client: construction fails, so the engine reports a clear
/// runtime-unavailable error instead of a missing-symbol crash.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> XlaResult<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (text interchange — see module docs in `runtime`).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> XlaResult<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A compiled-and-loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A host-side literal (tensor value).
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_vals: &[T]) -> Self {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> XlaResult<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(self) -> XlaResult<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> XlaResult<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_actionable_message() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        let msg = format!("{err}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
