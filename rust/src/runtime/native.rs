//! Native (pure-rust) fallback for the AOT artifact graphs.
//!
//! The PJRT path needs `make artifacts` (python/JAX, build-time) plus the
//! real xla bindings — neither exists in offline containers or plain CI
//! runners, which used to make every end-to-end trainer path unrunnable
//! there. This module reimplements the small set of SPNN graphs
//! (`python/compile/model.py`) directly on [`MatF64`], so
//! [`Engine`](super::Engine) can fall back transparently when
//! `artifacts/manifest.txt` is absent: `spnn train`, `spnn launch`, the
//! transport-parity tests and the decentralized CI smoke job all run with
//! zero toolchain beyond cargo.
//!
//! Numerics: f64 accumulation with f32 I/O at the artifact boundary. The
//! values differ from the XLA-compiled f32 graphs in low-order bits, but
//! every process/backend runs the identical code path, so transcripts
//! (and `weight_digest`) stay bit-exact across netsim/TCP and
//! single/multi-process runs — which is what the parity tests assert.
//!
//! Graph semantics mirrored from `model.py` (shapes per [`ModelConfig`]):
//!
//! * `server_fwd(h1, W1, b1, ...) -> (hL,)` — `a = act(h1)`, then
//!   `a = act_i(a @ W_i + b_i)` per server layer.
//! * `server_bwd(h1, g_hL, W1, b1, ...) -> (g_h1, g_W1, g_b1, ...)` —
//!   recomputes the forward, then standard backprop (vjp).
//! * `label_grad(hL, y, mask, wy, by) -> (p, loss, g_hL, g_wy, g_by)` —
//!   masked mean BCE from the logit, numerically stable softplus.
//! * `label_fwd(hL, wy, by) -> (p,)`.
//! * `nn_train(x, y, mask, theta0, thetaS..., wy, by) ->
//!   (loss, p, g_theta0, g_thetaS..., g_wy, g_by)` — the monolithic
//!   plaintext graph.

use crate::config::{Act, ModelConfig};
use crate::nn::MatF64;
use crate::{Error, Result};

use super::engine::{TensorIn, TensorOut};

/// Parse `<kind>_<dataset>_b<batch>` into the graph kind + model config.
pub(crate) fn parse_name(name: &str) -> Result<(&str, &'static ModelConfig)> {
    let (rest, _batch) = name
        .rsplit_once("_b")
        .ok_or_else(|| Error::Artifact(format!("{name}: not a <kind>_<ds>_b<N> artifact name")))?;
    let (kind, ds) = rest
        .rsplit_once('_')
        .ok_or_else(|| Error::Artifact(format!("{name}: missing dataset component")))?;
    let cfg = ModelConfig::by_name(ds)
        .ok_or_else(|| Error::Artifact(format!("{name}: unknown dataset {ds:?}")))?;
    Ok((kind, cfg))
}

/// Execute one graph natively. `ring_matmul` is intentionally absent — the
/// engine's [`Engine::ring_matmul`](super::Engine::ring_matmul) shortcut
/// handles it without flattening through the artifact calling convention.
pub(crate) fn execute(name: &str, inputs: &[TensorIn]) -> Result<Vec<TensorOut>> {
    let (kind, cfg) = parse_name(name)?;
    match kind {
        "server_fwd" => server_fwd(cfg, inputs),
        "server_bwd" => server_bwd(cfg, inputs),
        "label_grad" => label_grad(cfg, inputs),
        "label_fwd" => label_fwd(cfg, inputs),
        "nn_train" => nn_train(cfg, inputs),
        other => Err(Error::Artifact(format!(
            "{name}: no native fallback for graph kind {other:?} — run `make artifacts`"
        ))),
    }
}

fn f32_input<'a>(inputs: &'a [TensorIn], i: usize, what: &str) -> Result<&'a [f32]> {
    match inputs.get(i) {
        Some(TensorIn::F32(v)) => Ok(v),
        Some(TensorIn::U64(_)) => Err(Error::Artifact(format!("input {i} ({what}): wanted f32"))),
        None => Err(Error::Artifact(format!("missing input {i} ({what})"))),
    }
}

fn act_apply(a: Act, x: f64) -> f64 {
    match a {
        Act::Sigmoid => sigmoid(x),
        Act::Relu => x.max(0.0),
        Act::Identity => x,
    }
}

/// Activation derivative in terms of the activation *output*.
fn act_grad_from_output(a: Act, out: f64) -> f64 {
    match a {
        Act::Sigmoid => out * (1.0 - out),
        Act::Relu => f64::from(out > 0.0),
        Act::Identity => 1.0,
    }
}

fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// `log(1 + e^z)`, stable for large |z| (jnp.logaddexp(0, z)).
fn softplus(z: f64) -> f64 {
    z.max(0.0) + (-z.abs()).exp().ln_1p()
}

/// Rows of a flat f32 slice given the column count (validated).
fn rows_of(len: usize, cols: usize, what: &str) -> Result<usize> {
    if cols == 0 || len % cols != 0 {
        return Err(Error::Artifact(format!("{what}: length {len} not a multiple of {cols}")));
    }
    Ok(len / cols)
}

/// Server stack parameters (W, b) pairs from the artifact input list
/// starting at `at`, shaped per the config.
fn server_params(
    cfg: &ModelConfig,
    inputs: &[TensorIn],
    at: usize,
) -> Result<(Vec<MatF64>, Vec<Vec<f64>>)> {
    let mut dims = vec![cfg.h1_dim];
    dims.extend_from_slice(cfg.server_dims);
    let mut ws = Vec::new();
    let mut bs = Vec::new();
    for (i, win) in dims.windows(2).enumerate() {
        let w = f32_input(inputs, at + 2 * i, "W")?;
        if w.len() != win[0] * win[1] {
            return Err(Error::Artifact(format!(
                "W{i}: wanted {}x{}, got {} elements",
                win[0],
                win[1],
                w.len()
            )));
        }
        ws.push(MatF64::from_f32(win[0], win[1], w));
        let b = f32_input(inputs, at + 2 * i + 1, "b")?;
        if b.len() != win[1] {
            return Err(Error::Artifact(format!("b{i}: wanted {}, got {}", win[1], b.len())));
        }
        bs.push(b.iter().map(|&v| v as f64).collect());
    }
    Ok((ws, bs))
}

/// Forward through the server stack, returning every activation:
/// `acts[0] = act(h1)`, `acts[i+1] = act_i(acts[i] @ W_i + b_i)`.
fn stack_forward(
    cfg: &ModelConfig,
    h1: &MatF64,
    ws: &[MatF64],
    bs: &[Vec<f64>],
) -> Vec<MatF64> {
    let mut acts = vec![h1.map(|v| act_apply(cfg.first_act, v))];
    for (i, (w, b)) in ws.iter().zip(bs).enumerate() {
        let z = acts.last().unwrap().matmul(w).add_bias(b);
        acts.push(z.map(|v| act_apply(cfg.server_acts[i], v)));
    }
    acts
}

/// Backprop `g` (gradient w.r.t. the stack output) through the stack.
/// Returns `(g_h1, [(g_W_i, g_b_i)...])`.
fn stack_backward(
    cfg: &ModelConfig,
    acts: &[MatF64],
    ws: &[MatF64],
    mut g: MatF64,
) -> (MatF64, Vec<(MatF64, Vec<f64>)>) {
    let n_layers = ws.len();
    let mut grads: Vec<(MatF64, Vec<f64>)> = Vec::with_capacity(n_layers);
    for i in (0..n_layers).rev() {
        let out = &acts[i + 1];
        let deriv = out.map(|v| act_grad_from_output(cfg.server_acts[i], v));
        let g_z = g.hadamard(&deriv);
        let g_w = acts[i].transpose().matmul(&g_z);
        let g_b = g_z.col_sums();
        g = g_z.matmul(&ws[i].transpose());
        grads.push((g_w, g_b));
    }
    grads.reverse();
    // through the first activation applied to h1 (derivative from output)
    let first_deriv = acts[0].map(|v| act_grad_from_output(cfg.first_act, v));
    (g.hadamard(&first_deriv), grads)
}

fn server_fwd(cfg: &ModelConfig, inputs: &[TensorIn]) -> Result<Vec<TensorOut>> {
    let h1 = f32_input(inputs, 0, "h1")?;
    let b = rows_of(h1.len(), cfg.h1_dim, "h1")?;
    let (ws, bs) = server_params(cfg, inputs, 1)?;
    let acts = stack_forward(cfg, &MatF64::from_f32(b, cfg.h1_dim, h1), &ws, &bs);
    Ok(vec![TensorOut::F32(acts.last().unwrap().to_f32())])
}

fn server_bwd(cfg: &ModelConfig, inputs: &[TensorIn]) -> Result<Vec<TensorOut>> {
    let h1 = f32_input(inputs, 0, "h1")?;
    let b = rows_of(h1.len(), cfg.h1_dim, "h1")?;
    let g_hl = f32_input(inputs, 1, "g_hl")?;
    if g_hl.len() != b * cfg.hl_dim() {
        return Err(Error::Artifact(format!(
            "g_hl: wanted {}x{}, got {} elements",
            b,
            cfg.hl_dim(),
            g_hl.len()
        )));
    }
    let (ws, bs) = server_params(cfg, inputs, 2)?;
    let h1 = MatF64::from_f32(b, cfg.h1_dim, h1);
    let acts = stack_forward(cfg, &h1, &ws, &bs);
    let g = MatF64::from_f32(b, cfg.hl_dim(), g_hl);
    let (g_h1, grads) = stack_backward(cfg, &acts, &ws, g);
    let mut outs = vec![TensorOut::F32(g_h1.to_f32())];
    for (g_w, g_b) in grads {
        outs.push(TensorOut::F32(g_w.to_f32()));
        outs.push(TensorOut::F32(g_b.iter().map(|&v| v as f32).collect()));
    }
    Ok(outs)
}

/// Shared label-layer math: logit, probability, masked-mean BCE and the
/// logit gradient `(sigmoid(z) - y) * mask / denom`.
struct LabelOut {
    p: Vec<f64>,
    loss: f64,
    d_logit: Vec<f64>,
}

fn label_core(hl: &MatF64, y: &[f32], mask: &[f32], wy: &[f64], by: f64) -> LabelOut {
    let b = hl.rows;
    let mut p = Vec::with_capacity(b);
    let mut d_logit = vec![0.0; b];
    let denom: f64 = mask.iter().map(|&m| m as f64).sum::<f64>().max(1.0);
    let mut loss = 0.0;
    for r in 0..b {
        let mut z = by;
        for c in 0..hl.cols {
            z += hl.at(r, c) * wy[c];
        }
        let pr = sigmoid(z);
        p.push(pr);
        let yr = y[r] as f64;
        let mr = mask[r] as f64;
        loss += (softplus(z) - yr * z) * mr;
        d_logit[r] = (pr - yr) * mr / denom;
    }
    LabelOut { p, loss: loss / denom, d_logit }
}

fn label_grad(cfg: &ModelConfig, inputs: &[TensorIn]) -> Result<Vec<TensorOut>> {
    let hl_dim = cfg.hl_dim();
    let hl = f32_input(inputs, 0, "hl")?;
    let y = f32_input(inputs, 1, "y")?;
    let mask = f32_input(inputs, 2, "mask")?;
    let wy = f32_input(inputs, 3, "wy")?;
    let by = f32_input(inputs, 4, "by")?;
    let b = rows_of(hl.len(), hl_dim, "hl")?;
    if y.len() != b || mask.len() != b || wy.len() != hl_dim || by.len() != 1 {
        return Err(Error::Artifact("label_grad: input shape mismatch".into()));
    }
    let hl = MatF64::from_f32(b, hl_dim, hl);
    let wy64: Vec<f64> = wy.iter().map(|&v| v as f64).collect();
    let out = label_core(&hl, y, mask, &wy64, by[0] as f64);
    // g_hl[r,c] = d_logit[r] * wy[c];  g_wy[c] = sum_r hl[r,c] * d_logit[r]
    let mut g_hl = vec![0.0f32; b * hl_dim];
    let mut g_wy = vec![0.0f64; hl_dim];
    let mut g_by = 0.0f64;
    for r in 0..b {
        let d = out.d_logit[r];
        g_by += d;
        for c in 0..hl_dim {
            g_hl[r * hl_dim + c] = (d * wy64[c]) as f32;
            g_wy[c] += hl.at(r, c) * d;
        }
    }
    Ok(vec![
        TensorOut::F32(out.p.iter().map(|&v| v as f32).collect()),
        TensorOut::F32(vec![out.loss as f32]),
        TensorOut::F32(g_hl),
        TensorOut::F32(g_wy.iter().map(|&v| v as f32).collect()),
        TensorOut::F32(vec![g_by as f32]),
    ])
}

fn label_fwd(cfg: &ModelConfig, inputs: &[TensorIn]) -> Result<Vec<TensorOut>> {
    let hl_dim = cfg.hl_dim();
    let hl = f32_input(inputs, 0, "hl")?;
    let wy = f32_input(inputs, 1, "wy")?;
    let by = f32_input(inputs, 2, "by")?;
    let b = rows_of(hl.len(), hl_dim, "hl")?;
    if wy.len() != hl_dim || by.len() != 1 {
        return Err(Error::Artifact("label_fwd: input shape mismatch".into()));
    }
    let hl = MatF64::from_f32(b, hl_dim, hl);
    let mut p = Vec::with_capacity(b);
    for r in 0..b {
        let mut z = by[0] as f64;
        for c in 0..hl_dim {
            z += hl.at(r, c) * wy[c] as f64;
        }
        p.push(sigmoid(z) as f32);
    }
    Ok(vec![TensorOut::F32(p)])
}

fn nn_train(cfg: &ModelConfig, inputs: &[TensorIn]) -> Result<Vec<TensorOut>> {
    let x = f32_input(inputs, 0, "x")?;
    let y = f32_input(inputs, 1, "y")?;
    let mask = f32_input(inputs, 2, "mask")?;
    let theta0 = f32_input(inputs, 3, "theta0")?;
    let b = y.len();
    if x.len() != b * cfg.n_features || mask.len() != b {
        return Err(Error::Artifact("nn_train: input shape mismatch".into()));
    }
    if theta0.len() != cfg.n_features * cfg.h1_dim {
        return Err(Error::Artifact("nn_train: theta0 shape mismatch".into()));
    }
    let ns = 2 * cfg.server_dims.len();
    let (ws, bs) = server_params(cfg, inputs, 4)?;
    let wy = f32_input(inputs, 4 + ns, "wy")?;
    let by = f32_input(inputs, 5 + ns, "by")?;
    if wy.len() != cfg.hl_dim() || by.len() != 1 {
        return Err(Error::Artifact("nn_train: label params shape mismatch".into()));
    }

    let x = MatF64::from_f32(b, cfg.n_features, x);
    let theta0 = MatF64::from_f32(cfg.n_features, cfg.h1_dim, theta0);
    let h1 = x.matmul(&theta0);
    let acts = stack_forward(cfg, &h1, &ws, &bs);
    let al = acts.last().unwrap();
    let wy64: Vec<f64> = wy.iter().map(|&v| v as f64).collect();
    let out = label_core(al, y, mask, &wy64, by[0] as f64);

    // label-layer gradients, then backprop into the stack and theta0
    let hl_dim = cfg.hl_dim();
    let mut g_al = MatF64::zeros(b, hl_dim);
    let mut g_wy = vec![0.0f64; hl_dim];
    let mut g_by = 0.0f64;
    for r in 0..b {
        let d = out.d_logit[r];
        g_by += d;
        for c in 0..hl_dim {
            g_al.data[r * hl_dim + c] = d * wy64[c];
            g_wy[c] += al.at(r, c) * d;
        }
    }
    let (g_h1, grads) = stack_backward(cfg, &acts, &ws, g_al);
    let g_theta0 = x.transpose().matmul(&g_h1);

    let mut outs = vec![
        TensorOut::F32(vec![out.loss as f32]),
        TensorOut::F32(out.p.iter().map(|&v| v as f32).collect()),
        TensorOut::F32(g_theta0.to_f32()),
    ];
    for (g_w, g_b) in grads {
        outs.push(TensorOut::F32(g_w.to_f32()));
        outs.push(TensorOut::F32(g_b.iter().map(|&v| v as f32).collect()));
    }
    outs.push(TensorOut::F32(g_wy.iter().map(|&v| v as f32).collect()));
    outs.push(TensorOut::F32(vec![g_by as f32]));
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FRAUD;
    use crate::rng::Pcg64;

    fn rand_f32(rng: &mut Pcg64, n: usize, scale: f32) -> Vec<f32> {
        use crate::rng::Rng64;
        (0..n)
            .map(|_| {
                let u = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
                ((u as f32) - 0.5) * 2.0 * scale
            })
            .collect()
    }

    #[test]
    fn name_parsing_resolves_kind_and_config() {
        let (kind, cfg) = parse_name("server_fwd_fraud_b256").unwrap();
        assert_eq!(kind, "server_fwd");
        assert_eq!(cfg.name, "fraud");
        let (kind, cfg) = parse_name("ring_matmul_distress_b5000").unwrap();
        assert_eq!(kind, "ring_matmul");
        assert_eq!(cfg.name, "distress");
        assert!(parse_name("garbage").is_err());
        assert!(parse_name("server_fwd_mars_b256").is_err());
        assert!(execute("ring_matmul_fraud_b256", &[]).is_err());
    }

    #[test]
    fn server_fwd_shapes_and_range() {
        let b = 16;
        let h1 = vec![0.1f32; b * 8];
        let w = vec![0.05f32; 64];
        let bias = vec![0.0f32; 8];
        let outs = execute(
            "server_fwd_fraud_b256",
            &[TensorIn::F32(&h1), TensorIn::F32(&w), TensorIn::F32(&bias)],
        )
        .unwrap();
        let hl = outs.into_iter().next().unwrap().f32().unwrap();
        assert_eq!(hl.len(), b * 8);
        assert!(hl.iter().all(|&v| v > 0.0 && v < 1.0), "sigmoid range");
        // wrong shapes are rejected
        assert!(execute("server_fwd_fraud_b256", &[TensorIn::F32(&h1)]).is_err());
        assert!(execute(
            "server_fwd_fraud_b256",
            &[TensorIn::F32(&h1[..5]), TensorIn::F32(&w), TensorIn::F32(&bias)]
        )
        .is_err());
    }

    /// Finite-difference check of every gradient the label graph returns.
    #[test]
    fn label_grad_matches_finite_differences() {
        let mut rng = Pcg64::seed_from_u64(5);
        let b = 6;
        let hl_dim = 8;
        let hl = rand_f32(&mut rng, b * hl_dim, 1.0);
        let y: Vec<f32> = (0..b).map(|i| (i % 2) as f32).collect();
        let mut mask = vec![1.0f32; b];
        mask[b - 1] = 0.0; // one padded row
        let wy = rand_f32(&mut rng, hl_dim, 0.5);
        let by = vec![0.1f32];
        let run = |hl: &[f32], wy: &[f32], by: &[f32]| -> (f64, Vec<f32>, Vec<f32>, f32) {
            let outs = execute(
                "label_grad_fraud_b256",
                &[
                    TensorIn::F32(hl),
                    TensorIn::F32(&y),
                    TensorIn::F32(&mask),
                    TensorIn::F32(wy),
                    TensorIn::F32(by),
                ],
            )
            .unwrap();
            let loss = outs[1].scalar().unwrap();
            let g_hl = outs[2].clone().f32().unwrap();
            let g_wy = outs[3].clone().f32().unwrap();
            let g_by = outs[4].clone().f32().unwrap()[0];
            (loss, g_hl, g_wy, g_by)
        };
        let (_, g_hl, g_wy, g_by) = run(&hl, &wy, &by);
        let eps = 1e-3f32;
        let fd = |plus: f64, minus: f64| (plus - minus) / (2.0 * eps as f64);
        // spot-check several coordinates of each gradient
        for idx in [0usize, 7, 13, b * hl_dim - 1] {
            let mut hp = hl.clone();
            hp[idx] += eps;
            let mut hm = hl.clone();
            hm[idx] -= eps;
            let want = fd(run(&hp, &wy, &by).0, run(&hm, &wy, &by).0);
            assert!(
                (g_hl[idx] as f64 - want).abs() < 1e-3,
                "g_hl[{idx}]: {} vs fd {want}",
                g_hl[idx]
            );
        }
        for idx in 0..hl_dim {
            let mut wp = wy.clone();
            wp[idx] += eps;
            let mut wm = wy.clone();
            wm[idx] -= eps;
            let want = fd(run(&hl, &wp, &by).0, run(&hl, &wm, &by).0);
            assert!(
                (g_wy[idx] as f64 - want).abs() < 1e-3,
                "g_wy[{idx}]: {} vs fd {want}",
                g_wy[idx]
            );
        }
        let want = fd(run(&hl, &wy, &[by[0] + eps]).0, run(&hl, &wy, &[by[0] - eps]).0);
        assert!((g_by as f64 - want).abs() < 1e-3, "g_by: {g_by} vs fd {want}");
        // the padded row contributes no gradient
        let pad_start = (b - 1) * hl_dim;
        assert!(g_hl[pad_start..].iter().all(|&g| g == 0.0), "masked row leaked gradient");
    }

    /// Finite-difference check of the server backward graph.
    #[test]
    fn server_bwd_matches_finite_differences() {
        let mut rng = Pcg64::seed_from_u64(9);
        let b = 5;
        let h1 = rand_f32(&mut rng, b * 8, 1.0);
        let w = rand_f32(&mut rng, 64, 0.5);
        let bias = rand_f32(&mut rng, 8, 0.2);
        let g_hl = rand_f32(&mut rng, b * 8, 1.0);
        // scalar objective: sum(hL * g_hl) — its gradient w.r.t. any input
        // equals the vjp the graph computes
        let fwd = |h1: &[f32], w: &[f32], bias: &[f32]| -> f64 {
            let outs = execute(
                "server_fwd_fraud_b256",
                &[TensorIn::F32(h1), TensorIn::F32(w), TensorIn::F32(bias)],
            )
            .unwrap();
            let hl = outs.into_iter().next().unwrap().f32().unwrap();
            hl.iter().zip(&g_hl).map(|(&a, &g)| a as f64 * g as f64).sum()
        };
        let outs = execute(
            "server_bwd_fraud_b256",
            &[
                TensorIn::F32(&h1),
                TensorIn::F32(&g_hl),
                TensorIn::F32(&w),
                TensorIn::F32(&bias),
            ],
        )
        .unwrap();
        assert_eq!(outs.len(), 3); // g_h1, g_W1, g_b1
        let g_h1 = outs[0].clone().f32().unwrap();
        let g_w = outs[1].clone().f32().unwrap();
        let g_b = outs[2].clone().f32().unwrap();
        let eps = 1e-3f32;
        for idx in [0usize, 11, b * 8 - 1] {
            let mut p = h1.clone();
            p[idx] += eps;
            let mut m = h1.clone();
            m[idx] -= eps;
            let want = (fwd(&p, &w, &bias) - fwd(&m, &w, &bias)) / (2.0 * eps as f64);
            assert!(
                (g_h1[idx] as f64 - want).abs() < 2e-3,
                "g_h1[{idx}]: {} vs fd {want}",
                g_h1[idx]
            );
        }
        for idx in [0usize, 33, 63] {
            let mut p = w.clone();
            p[idx] += eps;
            let mut m = w.clone();
            m[idx] -= eps;
            let want = (fwd(&h1, &p, &bias) - fwd(&h1, &m, &bias)) / (2.0 * eps as f64);
            assert!(
                (g_w[idx] as f64 - want).abs() < 2e-3,
                "g_W[{idx}]: {} vs fd {want}",
                g_w[idx]
            );
        }
        for idx in [0usize, 7] {
            let mut p = bias.clone();
            p[idx] += eps;
            let mut m = bias.clone();
            m[idx] -= eps;
            let want = (fwd(&h1, &w, &p) - fwd(&h1, &w, &m)) / (2.0 * eps as f64);
            assert!(
                (g_b[idx] as f64 - want).abs() < 2e-3,
                "g_b[{idx}]: {} vs fd {want}",
                g_b[idx]
            );
        }
    }

    /// nn_train's loss must drop under plain gradient descent, and its
    /// gradient for theta0 must match finite differences.
    #[test]
    fn nn_train_descends_and_theta0_grad_checks() {
        let mut rng = Pcg64::seed_from_u64(3);
        let b = 12;
        let x = rand_f32(&mut rng, b * FRAUD.n_features, 1.0);
        let y: Vec<f32> = (0..b).map(|i| (i % 2) as f32).collect();
        let mask = vec![1.0f32; b];
        let mut theta0 = rand_f32(&mut rng, FRAUD.n_features * 8, 0.3);
        let mut w1 = rand_f32(&mut rng, 64, 0.3);
        let mut b1 = vec![0.0f32; 8];
        let mut wy = rand_f32(&mut rng, 8, 0.3);
        let mut by = vec![0.0f32];
        let run = |theta0: &[f32], w1: &[f32], b1: &[f32], wy: &[f32], by: &[f32]| {
            execute(
                "nn_train_fraud_b256",
                &[
                    TensorIn::F32(&x),
                    TensorIn::F32(&y),
                    TensorIn::F32(&mask),
                    TensorIn::F32(theta0),
                    TensorIn::F32(w1),
                    TensorIn::F32(b1),
                    TensorIn::F32(wy),
                    TensorIn::F32(by),
                ],
            )
            .unwrap()
        };
        // finite-difference check on theta0
        let outs = run(&theta0, &w1, &b1, &wy, &by);
        assert_eq!(outs.len(), 7); // loss, p, g_theta0, g_W1, g_b1, g_wy, g_by
        let g_theta0 = outs[2].clone().f32().unwrap();
        let eps = 1e-3f32;
        for idx in [0usize, 57, theta0.len() - 1] {
            let mut p = theta0.clone();
            p[idx] += eps;
            let mut m = theta0.clone();
            m[idx] -= eps;
            let want =
                (run(&p, &w1, &b1, &wy, &by)[0].scalar().unwrap()
                    - run(&m, &w1, &b1, &wy, &by)[0].scalar().unwrap())
                    / (2.0 * eps as f64);
            assert!(
                (g_theta0[idx] as f64 - want).abs() < 2e-3,
                "g_theta0[{idx}]: {} vs fd {want}",
                g_theta0[idx]
            );
        }
        // a few SGD steps reduce the loss
        let first_loss = outs[0].scalar().unwrap();
        let mut last = first_loss;
        for _ in 0..30 {
            let outs = run(&theta0, &w1, &b1, &wy, &by);
            last = outs[0].scalar().unwrap();
            let lr = 0.5f32;
            let step = |p: &mut [f32], g: &[f32]| {
                for (pv, gv) in p.iter_mut().zip(g) {
                    *pv -= lr * gv;
                }
            };
            step(&mut theta0, &outs[2].clone().f32().unwrap());
            step(&mut w1, &outs[3].clone().f32().unwrap());
            step(&mut b1, &outs[4].clone().f32().unwrap());
            step(&mut wy, &outs[5].clone().f32().unwrap());
            step(&mut by, &outs[6].clone().f32().unwrap());
        }
        assert!(last < first_loss, "loss did not descend: {first_loss} -> {last}");
    }
}
