//! PJRT runtime: load AOT HLO artifacts and execute them from the rust
//! training hot path.
//!
//! `make artifacts` (python, build-time only) lowers every SPNN graph to
//! `artifacts/*.hlo.txt` plus a `manifest.txt` describing I/O signatures.
//! The [`Engine`] parses the manifest, compiles artifacts **lazily** on
//! first use (a party only pays for the graphs it runs), caches the loaded
//! executables, and marshals between rust slices and XLA literals.
//!
//! Interchange is HLO *text* — the image's xla_extension 0.5.1 rejects
//! jax>=0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md).

mod artifact;
mod engine;
mod native;
pub mod xla;

pub use artifact::{Manifest, TensorSig, Dt};
pub use engine::{Engine, TensorIn, TensorOut};

/// Default artifact directory (relative to the repo root / cwd).
pub fn default_artifact_dir() -> std::path::PathBuf {
    // honor an override for tests and deployments
    if let Ok(d) = std::env::var("SPNN_ARTIFACTS") {
        return d.into();
    }
    "artifacts".into()
}
