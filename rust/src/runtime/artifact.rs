//! Artifact manifest parsing (`artifacts/manifest.txt`, written by
//! `python/compile/aot.py`).
//!
//! Format, one artifact per line (tab-separated):
//! `name \t file \t in_sig \t out_sig` where a sig is
//! `shape:dtype;shape:dtype;...`, shape is `AxBxC` or `scalar`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::{Error, Result};

/// Element dtype of a tensor crossing the FFI boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dt {
    F32,
    U64,
    S64,
}

impl Dt {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dt::F32),
            "u64" => Ok(Dt::U64),
            "s64" => Ok(Dt::S64),
            other => Err(Error::Artifact(format!("unknown dtype {other:?}"))),
        }
    }
}

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dt: Dt,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(s: &str) -> Result<Self> {
        let (shape_s, dt_s) = s
            .split_once(':')
            .ok_or_else(|| Error::Artifact(format!("bad sig {s:?}")))?;
        let shape = if shape_s == "scalar" {
            vec![]
        } else {
            shape_s
                .split('x')
                .map(|d| {
                    d.parse::<usize>()
                        .map_err(|_| Error::Artifact(format!("bad dim {d:?} in {s:?}")))
                })
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSig { shape, dt: Dt::parse(dt_s)? })
    }
}

/// One artifact's manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// Parsed manifest: artifact name -> entry.
#[derive(Debug, Default)]
pub struct Manifest {
    pub entries: HashMap<String, ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut entries = HashMap::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 4 {
                return Err(Error::Artifact(format!(
                    "manifest line {}: expected 4 columns, got {}",
                    ln + 1,
                    cols.len()
                )));
            }
            let parse_sigs = |s: &str| -> Result<Vec<TensorSig>> {
                s.split(';').map(TensorSig::parse).collect()
            };
            let entry = ArtifactEntry {
                name: cols[0].to_string(),
                path: dir.join(cols[1]),
                inputs: parse_sigs(cols[2])?,
                outputs: parse_sigs(cols[3])?,
            };
            entries.insert(entry.name.clone(), entry);
        }
        Ok(Manifest { entries })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries.get(name).ok_or_else(|| {
            Error::Artifact(format!(
                "artifact {name:?} not in manifest ({} known)",
                self.entries.len()
            ))
        })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "# name\tfile\tinputs\toutputs\n\
        label_fwd_fraud_b256\tlabel_fwd_fraud_b256.hlo.txt\t256x8:f32;8x1:f32;1:f32\t256:f32\n\
        ring_matmul_fraud_b256\tring_matmul_fraud_b256.hlo.txt\t256x28:u64;28x8:u64\t256x8:u64\n\
        scalar_thing\ts.hlo.txt\tscalar:f32\tscalar:f32\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        assert_eq!(m.len(), 3);
        let e = m.get("ring_matmul_fraud_b256").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![256, 28]);
        assert_eq!(e.inputs[0].dt, Dt::U64);
        assert_eq!(e.outputs[0].elements(), 256 * 8);
        assert_eq!(e.path, PathBuf::from("/art/ring_matmul_fraud_b256.hlo.txt"));
        let s = m.get("scalar_thing").unwrap();
        assert_eq!(s.inputs[0].shape, Vec::<usize>::new());
        assert_eq!(s.inputs[0].elements(), 1);
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn malformed_lines_error() {
        assert!(Manifest::parse("a\tb\tc", Path::new("/")).is_err());
        assert!(Manifest::parse("a\tb\t1x2:f99\t1:f32", Path::new("/")).is_err());
        assert!(Manifest::parse("a\tb\t1xq:f32\t1:f32", Path::new("/")).is_err());
    }
}
