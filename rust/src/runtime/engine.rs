//! The PJRT execution engine: lazy compile cache + literal marshaling.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use super::artifact::{ArtifactEntry, Dt, Manifest, TensorSig};
use super::{native, xla};
use crate::smpc::RingMat;
use crate::{Error, Result};

/// Input tensor handed to [`Engine::execute`].
pub enum TensorIn<'a> {
    F32(&'a [f32]),
    U64(&'a [u64]),
}

/// Output tensor returned by [`Engine::execute`].
#[derive(Clone, Debug)]
pub enum TensorOut {
    F32(Vec<f32>),
    U64(Vec<u64>),
}

impl TensorOut {
    pub fn f32(self) -> Result<Vec<f32>> {
        match self {
            TensorOut::F32(v) => Ok(v),
            TensorOut::U64(_) => Err(Error::Artifact("expected f32 output".into())),
        }
    }

    pub fn u64(self) -> Result<Vec<u64>> {
        match self {
            TensorOut::U64(v) => Ok(v),
            TensorOut::F32(_) => Err(Error::Artifact("expected u64 output".into())),
        }
    }

    /// First element as f64 (scalar outputs like the loss).
    pub fn scalar(&self) -> Result<f64> {
        match self {
            TensorOut::F32(v) => v
                .first()
                .map(|&x| x as f64)
                .ok_or_else(|| Error::Artifact("empty scalar".into())),
            TensorOut::U64(v) => v
                .first()
                .map(|&x| x as f64)
                .ok_or_else(|| Error::Artifact("empty scalar".into())),
        }
    }
}

/// Per-party PJRT engine. Artifacts compile on first use and stay cached;
/// every `execute` validates shapes/dtypes against the manifest signature.
///
/// When the artifact directory has no `manifest.txt` (no `make artifacts`
/// run — offline containers, plain CI runners, fresh checkouts), the
/// engine drops into **native mode**: the known SPNN graphs execute
/// through the pure-rust reimplementation in [`native`] instead of PJRT.
/// Same call surface, same determinism across processes; only the
/// low-order float bits differ from the XLA-compiled versions.
pub struct Engine {
    client: Option<xla::PjRtClient>,
    manifest: Manifest,
    native: bool,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions per artifact (perf accounting).
    pub exec_counts: HashMap<String, u64>,
}

impl Engine {
    /// Build from an artifact directory (reads `manifest.txt`), falling
    /// back to the native graph implementations when it does not exist.
    pub fn load(dir: &Path) -> Result<Self> {
        if !dir.join("manifest.txt").exists() {
            // once per process: repro/bench numbers from the fallback are
            // not the published Pallas/XLA path, and that should be visible
            static NOTICE: std::sync::Once = std::sync::Once::new();
            NOTICE.call_once(|| {
                eprintln!(
                    "spnn: no AOT artifacts at {} — using the native pure-rust \
                     graph fallback (bit-exact across runs, but not the \
                     Pallas/XLA numeric path; run `make artifacts` for it)",
                    dir.display()
                );
            });
            return Ok(Engine {
                client: None,
                manifest: Manifest::default(),
                native: true,
                compiled: HashMap::new(),
                exec_counts: HashMap::new(),
            });
        }
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client: Some(client),
            manifest,
            native: false,
            compiled: HashMap::new(),
            exec_counts: HashMap::new(),
        })
    }

    /// True when running on the native graph fallback (no AOT artifacts).
    pub fn is_native(&self) -> bool {
        self.native
    }

    /// Engine over the default artifact dir.
    pub fn load_default() -> Result<Self> {
        Self::load(&super::default_artifact_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn compile_if_needed(
        &mut self,
        name: &str,
    ) -> Result<(&xla::PjRtLoadedExecutable, ArtifactEntry)> {
        let entry = self.manifest.get(name)?.clone();
        if !self.compiled.contains_key(name) {
            let proto = xla::HloModuleProto::from_text_file(
                entry.path.to_str().ok_or_else(|| {
                    Error::Artifact(format!("non-utf8 path {:?}", entry.path))
                })?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .as_ref()
                .expect("artifact mode has a client")
                .compile(&comp)?;
            self.compiled.insert(name.to_string(), exe);
        }
        Ok((self.compiled.get(name).unwrap(), entry))
    }

    /// Execute artifact `name` with validated inputs; returns all outputs.
    pub fn execute(&mut self, name: &str, inputs: &[TensorIn]) -> Result<Vec<TensorOut>> {
        if self.native {
            let outs = native::execute(name, inputs)?;
            *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
            return Ok(outs);
        }
        let (_, entry) = self.compile_if_needed(name)?;
        if inputs.len() != entry.inputs.len() {
            return Err(Error::Artifact(format!(
                "{name}: expected {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (input, sig)) in inputs.iter().zip(&entry.inputs).enumerate() {
            literals.push(to_literal(input, sig).map_err(|e| {
                Error::Artifact(format!("{name}: input {i}: {e}"))
            })?);
        }
        let exe = self.compiled.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != entry.outputs.len() {
            return Err(Error::Artifact(format!(
                "{name}: expected {} outputs, got {}",
                entry.outputs.len(),
                parts.len()
            )));
        }
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        parts
            .into_iter()
            .zip(&entry.outputs)
            .map(|(lit, sig)| from_literal(lit, sig))
            .collect()
    }

    /// Ring matmul through the AOT Pallas kernel, padding ragged shapes to
    /// the artifact's static shape (zero rows/cols are exact in ring math).
    ///
    /// `artifact` must be a `ring_matmul_*` entry with signature
    /// `(B x D, D x H) -> (B x H)` and `x.rows <= B`, `x.cols <= D`,
    /// `w.cols <= H`.
    pub fn ring_matmul(&mut self, artifact: &str, x: &RingMat, w: &RingMat) -> Result<RingMat> {
        if self.native {
            if x.cols != w.rows {
                return Err(Error::Artifact(format!(
                    "{artifact}: shape ({},{})x({},{}) mismatch",
                    x.rows, x.cols, w.rows, w.cols
                )));
            }
            *self.exec_counts.entry(artifact.to_string()).or_insert(0) += 1;
            return Ok(x.matmul(w));
        }
        let entry = self.manifest.get(artifact)?.clone();
        let (b_cap, d_cap) = (entry.inputs[0].shape[0], entry.inputs[0].shape[1]);
        let h_cap = entry.inputs[1].shape[1];
        if x.rows > b_cap || x.cols > d_cap || w.cols > h_cap || x.cols != w.rows {
            return Err(Error::Artifact(format!(
                "{artifact}: shape ({},{})x({},{}) exceeds cap ({b_cap},{d_cap})x({d_cap},{h_cap})",
                x.rows, x.cols, w.rows, w.cols
            )));
        }
        // pad inputs into artifact-shaped buffers
        let mut xb = vec![0u64; b_cap * d_cap];
        for r in 0..x.rows {
            xb[r * d_cap..r * d_cap + x.cols]
                .copy_from_slice(&x.data[r * x.cols..(r + 1) * x.cols]);
        }
        let mut wb = vec![0u64; d_cap * h_cap];
        for r in 0..w.rows {
            wb[r * h_cap..r * h_cap + w.cols]
                .copy_from_slice(&w.data[r * w.cols..(r + 1) * w.cols]);
        }
        let outs = self.execute(artifact, &[TensorIn::U64(&xb), TensorIn::U64(&wb)])?;
        let full = outs.into_iter().next().unwrap().u64()?;
        // crop to the logical shape
        let mut out = RingMat::zeros(x.rows, w.cols);
        for r in 0..x.rows {
            out.data[r * w.cols..(r + 1) * w.cols]
                .copy_from_slice(&full[r * h_cap..r * h_cap + w.cols]);
        }
        Ok(out)
    }

    /// Total artifact executions (perf accounting).
    pub fn total_execs(&self) -> u64 {
        self.exec_counts.values().sum()
    }
}

fn to_literal(input: &TensorIn, sig: &TensorSig) -> Result<xla::Literal> {
    let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
    match (input, sig.dt) {
        (TensorIn::F32(v), Dt::F32) => {
            check_len(v.len(), sig)?;
            let lit = xla::Literal::vec1(v);
            if sig.shape.is_empty() {
                Ok(lit.reshape(&[])?)
            } else {
                Ok(lit.reshape(&dims)?)
            }
        }
        (TensorIn::U64(v), Dt::U64) => {
            check_len(v.len(), sig)?;
            let lit = xla::Literal::vec1(v);
            if sig.shape.is_empty() {
                Ok(lit.reshape(&[])?)
            } else {
                Ok(lit.reshape(&dims)?)
            }
        }
        _ => Err(Error::Artifact("dtype mismatch".into())),
    }
}

fn check_len(len: usize, sig: &TensorSig) -> Result<()> {
    if len != sig.elements() {
        return Err(Error::Artifact(format!(
            "length {len} != signature elements {} (shape {:?})",
            sig.elements(),
            sig.shape
        )));
    }
    Ok(())
}

fn from_literal(lit: xla::Literal, sig: &TensorSig) -> Result<TensorOut> {
    match sig.dt {
        Dt::F32 => Ok(TensorOut::F32(lit.to_vec::<f32>()?)),
        Dt::U64 => Ok(TensorOut::U64(lit.to_vec::<u64>()?)),
        Dt::S64 => {
            let v = lit.to_vec::<i64>()?;
            Ok(TensorOut::U64(v.into_iter().map(|x| x as u64).collect()))
        }
    }
}

/// Resolve the artifact dir for tests: prefer `SPNN_ARTIFACTS`, else the
/// repo-relative `artifacts/` (tests are run from the workspace root).
pub fn test_artifact_dir() -> PathBuf {
    super::default_artifact_dir()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn engine() -> Option<Engine> {
        let dir = test_artifact_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping engine tests: run `make artifacts` first");
            return None;
        }
        Some(Engine::load(&dir).expect("engine"))
    }

    #[test]
    fn native_fallback_runs_without_artifacts() {
        let dir = test_artifact_dir();
        if dir.join("manifest.txt").exists() {
            return; // artifact mode covered by the gated tests below
        }
        let mut eng = Engine::load(&dir).unwrap();
        assert!(eng.is_native());
        let h1 = vec![0.1f32; 4 * 8];
        let w = vec![0.05f32; 64];
        let b = vec![0.0f32; 8];
        let outs = eng
            .execute(
                "server_fwd_fraud_b256",
                &[TensorIn::F32(&h1), TensorIn::F32(&w), TensorIn::F32(&b)],
            )
            .unwrap();
        assert_eq!(outs[0].clone().f32().unwrap().len(), 4 * 8);
        // ring matmul shortcut is exact ring math
        let mut rng = Pcg64::seed_from_u64(4);
        let x = RingMat::random(&mut rng, 9, 5);
        let y = RingMat::random(&mut rng, 5, 3);
        let got = eng.ring_matmul("ring_matmul_fraud_b256", &x, &y).unwrap();
        assert_eq!(got, x.matmul(&y));
        assert_eq!(eng.total_execs(), 2);
        // unknown graphs still error clearly
        assert!(eng.execute("mystery_fraud_b256", &[]).is_err());
    }

    #[test]
    fn ring_matmul_matches_native() {
        let Some(mut eng) = engine() else { return };
        let mut rng = Pcg64::seed_from_u64(1);
        let x = RingMat::random(&mut rng, 100, 28);
        let w = RingMat::random(&mut rng, 28, 8);
        let got = eng.ring_matmul("ring_matmul_fraud_b256", &x, &w).unwrap();
        assert_eq!(got, x.matmul(&w), "PJRT ring kernel != native ring matmul");
    }

    #[test]
    fn ring_matmul_full_batch() {
        let Some(mut eng) = engine() else { return };
        let mut rng = Pcg64::seed_from_u64(2);
        let x = RingMat::random(&mut rng, 256, 28);
        let w = RingMat::random(&mut rng, 28, 8);
        let got = eng.ring_matmul("ring_matmul_fraud_b256", &x, &w).unwrap();
        assert_eq!(got, x.matmul(&w));
        assert_eq!(eng.total_execs(), 1);
    }

    #[test]
    fn server_fwd_runs_and_shapes() {
        let Some(mut eng) = engine() else { return };
        let b = 256;
        let h1 = vec![0.1f32; b * 8];
        let w = vec![0.05f32; 8 * 8];
        let bias = vec![0.0f32; 8];
        let outs = eng
            .execute(
                "server_fwd_fraud_b256",
                &[TensorIn::F32(&h1), TensorIn::F32(&w), TensorIn::F32(&bias)],
            )
            .unwrap();
        let hl = outs.into_iter().next().unwrap().f32().unwrap();
        assert_eq!(hl.len(), b * 8);
        // sigmoid outputs in (0,1)
        assert!(hl.iter().all(|&v| v > 0.0 && v < 1.0));
    }

    #[test]
    fn wrong_inputs_are_rejected() {
        let Some(mut eng) = engine() else { return };
        let bad = vec![0.0f32; 3];
        assert!(eng
            .execute("server_fwd_fraud_b256", &[TensorIn::F32(&bad)])
            .is_err());
        let h1 = vec![0.0f32; 256 * 8];
        assert!(eng
            .execute(
                "server_fwd_fraud_b256",
                &[TensorIn::F32(&h1), TensorIn::F32(&bad), TensorIn::F32(&bad)]
            )
            .is_err());
        assert!(eng.execute("not_an_artifact", &[]).is_err());
    }
}
