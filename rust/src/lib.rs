//! # SPNN — Scalable and Privacy-Preserving Deep Neural Network
//!
//! Rust + JAX + Pallas reproduction of *"Towards Scalable and
//! Privacy-Preserving Deep Neural Network via Algorithmic-Cryptographic
//! Co-design"* (Zhou, Zheng, Chen et al., ACM TIST 2021).
//!
//! The paper co-designs an algorithmic split of the DNN computation graph
//! with cryptographic protocols: isolated data holders jointly compute the
//! first hidden layer under **arithmetic secret sharing** (Algorithm 2) or
//! **Paillier additive homomorphic encryption** (Algorithm 3); a semi-honest
//! compute server runs the heavy plaintext hidden stack; the label holder
//! computes predictions and the loss. Training uses SGD or SGLD (noise
//! injection to blunt property-inference attacks on the exposed hidden
//! features).
//!
//! ## Architecture (three layers)
//!
//! * **Layer 3 (this crate)** — the decentralized coordinator: party actors
//!   ([`parties`]), a pluggable [`transport`] layer (the deterministic
//!   [`netsim`] simulator, a real-TCP backend with PSK-authenticated
//!   session rendezvous and journaled reconnect/resume links, and a
//!   Unix-socketpair backend, all behind one `Channel` trait, so the same
//!   roles run in-process or as separate OS processes via `spnn launch` /
//!   `spnn party`), the protocol-agnostic forward-pass layer
//!   ([`protocols::fwd`]) and the private-inference serving runtime built
//!   on it ([`serve`], `spnn serve` / `spnn infer`), the MPC
//!   engine ([`smpc`]), a from-scratch [`bignum`]/[`paillier`] stack (with
//!   plaintext packing, [`paillier::pack`]), the chunked [`exec`] thread
//!   pool that fans the crypto hot paths out across cores, the PJRT
//!   [`runtime`] (with a pure-rust graph fallback when artifacts are
//!   absent), the five training [`protocols`], and the zero-dependency
//!   observability layer ([`obs`]: span timers, latency histograms, a
//!   Prometheus-text endpoint and a structured JSONL trace).
//! * **Layer 2** — JAX graphs (`python/compile/model.py`), AOT-lowered to
//!   `artifacts/*.hlo.txt` once by `make artifacts`.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`): the blocked
//!   `Z_{2^64}` ring matmul (Algorithm 2's hot spot) and the fused f32
//!   dense layer used by the server stack.
//!
//! Python never runs on the training path: the rust binary loads the HLO
//! artifacts at startup and drives everything else natively.

pub mod attack;
pub mod bench_harness;
pub mod bignum;
pub mod ckpt;
pub mod config;
pub mod data;
pub mod error;
pub mod exec;
pub mod exp;
pub mod fixed;
pub mod netsim;
pub mod nn;
pub mod obs;
pub mod paillier;
pub mod parties;
pub mod protocols;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod smpc;
pub mod testutil;
pub mod transport;

pub use error::{Error, Result};
