//! Fixed-point encoding over the ring `Z_{2^64}` (paper §3.3.2).
//!
//! Decimal values are embedded as two's-complement integers scaled by
//! `2^FRAC_BITS` with `FRAC_BITS = l_F = 16` (the paper's choice). All MPC
//! arithmetic then happens in the ring with natural wrap-around; after each
//! fixed-point multiplication the extra `l_F` fractional bits are removed by
//! the SecureML local-truncation trick (see [`smpc::trunc`](crate::smpc)).

/// Number of fractional bits (`l_F` in the paper).
pub const FRAC_BITS: u32 = 16;

/// Scale factor `2^l_F`.
pub const SCALE: f64 = (1u64 << FRAC_BITS) as f64;

/// Encode a decimal into the ring (round-to-nearest).
#[inline]
pub fn encode(x: f64) -> u64 {
    debug_assert!(
        x.abs() < (1u64 << 46) as f64,
        "fixed::encode overflow risk: {x}"
    );
    (x * SCALE).round() as i64 as u64
}

/// Decode a ring element back to a decimal (two's-complement).
#[inline]
pub fn decode(v: u64) -> f64 {
    (v as i64) as f64 / SCALE
}

/// Decode a value carrying `2*l_F` fractional bits (a raw product that has
/// not been truncated yet).
#[inline]
pub fn decode_wide(v: u64) -> f64 {
    (v as i64) as f64 / (SCALE * SCALE)
}

/// Encode a slice.
pub fn encode_vec(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|&x| encode(x)).collect()
}

/// Decode a slice.
pub fn decode_vec(vs: &[u64]) -> Vec<f64> {
    vs.iter().map(|&v| decode(v)).collect()
}

/// Truncate a *plaintext* ring value by `l_F` bits (arithmetic shift on the
/// signed interpretation). The share-level version lives in `smpc::trunc`.
#[inline]
pub fn trunc_plain(v: u64) -> u64 {
    ((v as i64) >> FRAC_BITS) as u64
}

/// Maximum decimal magnitude that survives one fixed-point multiply without
/// wrapping: products carry 2*l_F fractional bits, so |x*y| must stay below
/// 2^(63 - 2*l_F) in decimal terms.
pub fn product_headroom() -> f64 {
    ((1u128 << (63 - 2 * FRAC_BITS)) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng64};

    #[test]
    fn roundtrip_exact_for_representable() {
        for x in [-3.5, 0.0, 1.0, 0.5, -0.25, 1000.125, -77.0625] {
            assert_eq!(decode(encode(x)), x, "{x}");
        }
    }

    #[test]
    fn roundtrip_error_bounded_by_half_ulp() {
        let mut rng = Pcg64::seed_from_u64(5);
        for _ in 0..10_000 {
            let x = (rng.f64_unit() - 0.5) * 2000.0;
            let err = (decode(encode(x)) - x).abs();
            assert!(err <= 0.5 / SCALE + 1e-12, "x={x} err={err}");
        }
    }

    #[test]
    fn negative_values_use_twos_complement() {
        let v = encode(-1.0);
        assert_eq!(v, (-(1i64 << FRAC_BITS)) as u64);
        assert_eq!(decode(v), -1.0);
    }

    #[test]
    fn addition_is_ring_addition() {
        let mut rng = Pcg64::seed_from_u64(6);
        for _ in 0..1000 {
            let a = (rng.f64_unit() - 0.5) * 100.0;
            let b = (rng.f64_unit() - 0.5) * 100.0;
            let sum = decode(encode(a).wrapping_add(encode(b)));
            assert!((sum - (a + b)).abs() < 2.0 / SCALE, "{a}+{b}={sum}");
        }
    }

    #[test]
    fn multiply_then_truncate() {
        let mut rng = Pcg64::seed_from_u64(7);
        for _ in 0..1000 {
            let a = (rng.f64_unit() - 0.5) * 20.0;
            let b = (rng.f64_unit() - 0.5) * 20.0;
            let prod = encode(a).wrapping_mul(encode(b));
            let got = decode(trunc_plain(prod));
            // operand rounding propagates as |a|*0.5ulp + |b|*0.5ulp, plus
            // one ulp from the truncation itself
            let tol = (a.abs() + b.abs() + 2.0) * 0.5 / SCALE + 1.0 / SCALE;
            assert!((got - a * b).abs() < tol, "{a}*{b}={got}");
        }
    }

    #[test]
    fn trunc_plain_matches_float_division() {
        assert_eq!(decode(trunc_plain(encode(2.0).wrapping_mul(encode(3.0)))), 6.0);
        let v = encode(-2.5).wrapping_mul(encode(4.0));
        let dec = decode(trunc_plain(v));
        assert!((dec - -10.0).abs() <= 1.0 / SCALE);
    }

    #[test]
    fn wide_decode_sees_untruncated_products() {
        let prod = encode(1.5).wrapping_mul(encode(2.0));
        assert!((decode_wide(prod) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn headroom_is_sane() {
        let h = product_headroom();
        // values below the headroom multiply without wrapping
        let x = h * 0.9;
        let prod = encode(x).wrapping_mul(encode(x));
        let dec = decode_wide(prod);
        assert!((dec - x * x).abs() / (x * x) < 1e-3, "{dec} vs {}", x * x);
    }
}
