//! Durable per-role checkpoints: each party persists **its own** trained
//! parameter blocks (and the RNG cursors needed to resume serving
//! deterministically) to `<dir>/<role>.ckpt`, mirroring the privacy split
//! on disk — a holder's file holds only that holder's shares/weights, a
//! server's only the server stack, and no file ever contains another
//! party's secrets.
//!
//! ## On-disk format (version 1)
//!
//! Little-endian, length-prefixed, FNV-checksummed:
//!
//! ```text
//! magic    8 B   "SPNNCKPT"
//! version  4 B   u32 (currently 1)
//! protocol 4+N B u32 length + utf-8 (e.g. "spnn-he")
//! role     4+N B u32 length + utf-8 (e.g. "holder0")
//! cfg      8 B   u64 config digest (see [`config_digest`])
//! blocks   4 B   u32 count, then per block:
//!                  name (u32 length + utf-8)
//!                  tag  (1 B: 0 = f64, 1 = u64)
//!                  len  (u64 element count)
//!                  data (len * 8 B; f64 via to_bits)
//! cursors  4 B   u32 count, then per cursor:
//!                  name (u32 length + utf-8)
//!                  counter (u64), pos (u64)   — see `ChaChaRng::cursor`
//! checksum 8 B   u64 FNV-1a over every preceding byte
//! ```
//!
//! Writes are atomic (`<role>.ckpt.tmp` + rename), so a crash mid-write
//! leaves either the previous checkpoint or none. Loads verify magic,
//! version and checksum and report a *specific* diagnostic for each
//! failure mode (truncation, corruption, wrong version, wrong role /
//! protocol / config) — the rejection tests below pin the wording.

use std::fs;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::protocols::common::Fnv;

/// Format version written by this build.
pub const VERSION: u32 = 1;

const MAGIC: &[u8; 8] = b"SPNNCKPT";

/// One named parameter block: either plaintext / share floats or raw
/// `Z_{2^64}` ring words (SecureML layer shares live in the ring).
#[derive(Clone, Debug, PartialEq)]
pub enum BlockData {
    /// IEEE-754 doubles, stored via `to_bits` (bit-exact roundtrip).
    F64(Vec<f64>),
    /// Ring / raw words.
    U64(Vec<u64>),
}

impl BlockData {
    fn tag(&self) -> u8 {
        match self {
            BlockData::F64(_) => 0,
            BlockData::U64(_) => 1,
        }
    }

    fn len(&self) -> usize {
        match self {
            BlockData::F64(v) => v.len(),
            BlockData::U64(v) => v.len(),
        }
    }
}

/// One role's durable state: parameter blocks + RNG cursors, tagged with
/// the protocol/role/config they belong to so a mismatched load fails
/// loudly instead of serving garbage.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Trainer name (`spnn-he`, `spnn-ss`, `secureml`, `splitnn`).
    pub protocol: String,
    /// Role name from the deployment roster (`server`, `holder0`, ...).
    pub role: String,
    /// [`config_digest`] of the session that produced this checkpoint.
    pub cfg_digest: u64,
    /// Named parameter blocks, in a role-defined order.
    pub blocks: Vec<(String, BlockData)>,
    /// Named RNG / dealer-stream cursors (`(counter, pos)` pairs).
    pub cursors: Vec<(String, (u64, u64))>,
}

impl Checkpoint {
    /// Empty checkpoint shell for a role.
    pub fn new(protocol: &str, role: &str, cfg_digest: u64) -> Self {
        Checkpoint {
            protocol: protocol.to_string(),
            role: role.to_string(),
            cfg_digest,
            blocks: Vec::new(),
            cursors: Vec::new(),
        }
    }

    /// Append an f64 block.
    pub fn push_f64(&mut self, name: &str, data: Vec<f64>) {
        self.blocks.push((name.to_string(), BlockData::F64(data)));
    }

    /// Append a u64 (ring) block.
    pub fn push_u64(&mut self, name: &str, data: Vec<u64>) {
        self.blocks.push((name.to_string(), BlockData::U64(data)));
    }

    /// Append an RNG cursor.
    pub fn push_cursor(&mut self, name: &str, cursor: (u64, u64)) {
        self.cursors.push((name.to_string(), cursor));
    }

    /// Find an f64 block by name.
    pub fn f64s(&self, name: &str) -> Result<&[f64]> {
        match self.blocks.iter().find(|(n, _)| n == name) {
            Some((_, BlockData::F64(v))) => Ok(v),
            Some((_, BlockData::U64(_))) => Err(Error::Config(format!(
                "checkpoint block {name:?} holds u64 ring words, expected f64"
            ))),
            None => Err(Error::Config(format!("checkpoint is missing block {name:?}"))),
        }
    }

    /// Find a u64 (ring) block by name.
    pub fn u64s(&self, name: &str) -> Result<&[u64]> {
        match self.blocks.iter().find(|(n, _)| n == name) {
            Some((_, BlockData::U64(v))) => Ok(v),
            Some((_, BlockData::F64(_))) => Err(Error::Config(format!(
                "checkpoint block {name:?} holds f64 values, expected u64"
            ))),
            None => Err(Error::Config(format!("checkpoint is missing block {name:?}"))),
        }
    }

    /// Copy an f64 block into an existing parameter buffer, rejecting
    /// shape drift with a diagnostic instead of serving garbage.
    pub fn copy_f64(&self, name: &str, dst: &mut [f64]) -> Result<()> {
        let blk = self.f64s(name)?;
        if blk.len() != dst.len() {
            return Err(Error::Config(format!(
                "checkpoint block {name:?} holds {} values, this model wants {} \
                 (was the checkpoint written at a different shape?)",
                blk.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(blk);
        Ok(())
    }

    /// [`Checkpoint::copy_f64`] for u64 ring blocks.
    pub fn copy_u64(&self, name: &str, dst: &mut [u64]) -> Result<()> {
        let blk = self.u64s(name)?;
        if blk.len() != dst.len() {
            return Err(Error::Config(format!(
                "checkpoint block {name:?} holds {} words, this model wants {} \
                 (was the checkpoint written at a different shape?)",
                blk.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(blk);
        Ok(())
    }

    /// Find a cursor by name.
    pub fn cursor(&self, name: &str) -> Result<(u64, u64)> {
        self.cursors
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, c)| *c)
            .ok_or_else(|| Error::Config(format!("checkpoint is missing cursor {name:?}")))
    }

    /// Validate that this checkpoint belongs to (protocol, role, config);
    /// the specific mismatch diagnostics are pinned by tests.
    pub fn expect(&self, protocol: &str, role: &str, cfg_digest: u64) -> Result<()> {
        if self.protocol != protocol {
            return Err(Error::Config(format!(
                "checkpoint protocol mismatch: file was written by {:?}, this session runs {:?}",
                self.protocol, protocol
            )));
        }
        if self.role != role {
            return Err(Error::Config(format!(
                "checkpoint role mismatch: file belongs to role {:?}, this party is {:?}",
                self.role, role
            )));
        }
        if self.cfg_digest != cfg_digest {
            return Err(Error::Config(format!(
                "checkpoint config mismatch: file has digest 0x{:016x}, session has 0x{:016x} \
                 (batch/seed/key-size/compression must match the training run)",
                self.cfg_digest, cfg_digest
            )));
        }
        Ok(())
    }

    /// Serialize to the on-disk byte layout (checksum included).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        put_str(&mut out, &self.protocol);
        put_str(&mut out, &self.role);
        out.extend_from_slice(&self.cfg_digest.to_le_bytes());
        out.extend_from_slice(&(self.blocks.len() as u32).to_le_bytes());
        for (name, data) in &self.blocks {
            put_str(&mut out, name);
            out.push(data.tag());
            out.extend_from_slice(&(data.len() as u64).to_le_bytes());
            match data {
                BlockData::F64(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_bits().to_le_bytes());
                    }
                }
                BlockData::U64(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        out.extend_from_slice(&(self.cursors.len() as u32).to_le_bytes());
        for (name, (counter, pos)) in &self.cursors {
            put_str(&mut out, name);
            out.extend_from_slice(&counter.to_le_bytes());
            out.extend_from_slice(&pos.to_le_bytes());
        }
        let mut f = Fnv::new();
        f.add_bytes(&out);
        out.extend_from_slice(&f.0.to_le_bytes());
        out
    }

    /// Parse + verify the on-disk byte layout.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        // the checksum footer is verified first: a flipped bit anywhere
        // (header, payload or footer itself) is "corrupt", while a short
        // file is "truncated"
        if bytes.len() < MAGIC.len() + 4 + 8 {
            return Err(Error::Config(format!(
                "checkpoint truncated: {} bytes is shorter than the fixed header",
                bytes.len()
            )));
        }
        let (body, foot) = bytes.split_at(bytes.len() - 8);
        if &body[..8] != MAGIC {
            return Err(Error::Config(
                "not a checkpoint file (bad magic; expected SPNNCKPT)".into(),
            ));
        }
        let mut f = Fnv::new();
        f.add_bytes(body);
        let want = u64::from_le_bytes(foot.try_into().unwrap());
        if f.0 != want {
            return Err(Error::Config(format!(
                "checkpoint corrupt: checksum mismatch (stored 0x{want:016x}, \
                 computed 0x{:016x})",
                f.0
            )));
        }
        let mut r = Reader { buf: body, pos: 8 };
        let version = r.u32()?;
        if version != VERSION {
            return Err(Error::Config(format!(
                "unsupported checkpoint version {version} (this build reads version {VERSION})"
            )));
        }
        let protocol = r.str()?;
        let role = r.str()?;
        let cfg_digest = r.u64()?;
        let n_blocks = r.u32()? as usize;
        let mut blocks = Vec::with_capacity(n_blocks);
        for _ in 0..n_blocks {
            let name = r.str()?;
            let tag = r.u8()?;
            let len = r.u64()? as usize;
            let data = match tag {
                0 => {
                    let mut v = Vec::with_capacity(len);
                    for _ in 0..len {
                        v.push(f64::from_bits(r.u64()?));
                    }
                    BlockData::F64(v)
                }
                1 => {
                    let mut v = Vec::with_capacity(len);
                    for _ in 0..len {
                        v.push(r.u64()?);
                    }
                    BlockData::U64(v)
                }
                t => {
                    return Err(Error::Config(format!(
                        "checkpoint corrupt: unknown block tag {t} for {name:?}"
                    )))
                }
            };
            blocks.push((name, data));
        }
        let n_cursors = r.u32()? as usize;
        let mut cursors = Vec::with_capacity(n_cursors);
        for _ in 0..n_cursors {
            let name = r.str()?;
            let counter = r.u64()?;
            let pos = r.u64()?;
            cursors.push((name, (counter, pos)));
        }
        if r.pos != body.len() {
            return Err(Error::Config(format!(
                "checkpoint corrupt: {} trailing bytes after the cursor table",
                body.len() - r.pos
            )));
        }
        Ok(Checkpoint { protocol, role, cfg_digest, blocks, cursors })
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl Reader<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Config(format!(
                "checkpoint truncated: wanted {n} bytes at offset {}, file body ends at {}",
                self.pos,
                self.buf.len()
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        if len > 1 << 20 {
            return Err(Error::Config(format!(
                "checkpoint corrupt: implausible string length {len}"
            )));
        }
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::Config("checkpoint corrupt: non-utf8 string".into()))
    }
}

/// Path of a role's checkpoint file inside a checkpoint dir.
pub fn path_for(dir: &str, role: &str) -> PathBuf {
    Path::new(dir).join(format!("{role}.ckpt"))
}

/// Atomically persist a role's checkpoint under `dir` (created if
/// absent): write `<role>.ckpt.tmp`, fsync-free rename over the final
/// name — a crash mid-write never leaves a half-written checkpoint
/// visible.
pub fn save(dir: &str, ck: &Checkpoint) -> Result<()> {
    fs::create_dir_all(dir)?;
    let path = path_for(dir, &ck.role);
    let tmp = path.with_extension("ckpt.tmp");
    fs::write(&tmp, ck.encode())?;
    fs::rename(&tmp, &path)?;
    Ok(())
}

/// Path of a rotated checkpoint generation (`generation >= 1`);
/// generation 0 is the live `<role>.ckpt` itself ([`path_for`]).
pub fn rotated_path(dir: &str, role: &str, generation: usize) -> PathBuf {
    Path::new(dir).join(format!("{role}.{generation}.ckpt"))
}

/// [`save`] with generation rotation (`--checkpoint-keep N`): the
/// previous live checkpoint survives as `<role>.1.ckpt`, the one before
/// as `<role>.2.ckpt`, …, and every generation `>= N` is pruned, so the
/// dir holds at most `N` generations per role. Every step is a rename or
/// an atomic tmp+rename write — a crash at any point leaves each
/// surviving generation intact, and the live `<role>.ckpt` (written
/// last) always warm-starts. `keep = None` is exactly [`save`].
pub fn save_rotated(dir: &str, ck: &Checkpoint, keep: Option<usize>) -> Result<()> {
    let Some(n) = keep else { return save(dir, ck) };
    let n = n.max(1);
    fs::create_dir_all(dir)?;
    if n >= 2 {
        // shift surviving generations up, oldest first, then retire the
        // live file to generation 1
        for g in (1..=n - 2).rev() {
            let from = rotated_path(dir, &ck.role, g);
            if from.exists() {
                fs::rename(&from, rotated_path(dir, &ck.role, g + 1))?;
            }
        }
        let live = path_for(dir, &ck.role);
        if live.exists() {
            fs::rename(&live, rotated_path(dir, &ck.role, 1))?;
        }
    }
    prune_generations(dir, &ck.role, n)?;
    save(dir, ck)
}

/// Remove this role's rotated generations at index `>= keep` (also
/// handles a lowered `--checkpoint-keep` against an older, deeper dir).
fn prune_generations(dir: &str, role: &str, keep: usize) -> Result<()> {
    let prefix = format!("{role}.");
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(mid) = name.strip_prefix(&prefix).and_then(|r| r.strip_suffix(".ckpt"))
        else {
            continue;
        };
        if let Ok(g) = mid.parse::<usize>() {
            if g >= keep {
                fs::remove_file(entry.path())?;
            }
        }
    }
    Ok(())
}

/// Load a role's checkpoint from `dir`, with a clear error when the file
/// is missing (the most common operator mistake: serving from a dir that
/// was never trained into).
pub fn load(dir: &str, role: &str) -> Result<Checkpoint> {
    let path = path_for(dir, role);
    let bytes = fs::read(&path).map_err(|e| {
        Error::Config(format!(
            "cannot read checkpoint {} for role {role:?}: {e} \
             (train with --checkpoint-dir first)",
            path.display()
        ))
    })?;
    Checkpoint::decode(&bytes)
}

/// The checkpoint dir a warm start reads from, with the operator-facing
/// diagnostic when the process was launched without one. The dir is a
/// process-local knob (never broadcast), so in launch mode every party
/// process needs its own flag.
pub fn warm_dir(tc: &crate::config::TrainConfig) -> Result<&str> {
    tc.checkpoint_dir.as_deref().ok_or_else(|| {
        Error::Config(
            "warm start requires a checkpoint dir on this process \
             (--from-checkpoint DIR or --checkpoint-dir DIR)"
                .into(),
        )
    })
}

/// Load + validate one role's checkpoint for a warm-starting session:
/// reads `<tc.checkpoint_dir>/<role>.ckpt` and rejects protocol / role /
/// config mismatches via [`Checkpoint::expect`].
pub fn load_verified(
    tc: &crate::config::TrainConfig,
    protocol: &str,
    role: &str,
    n_holders: usize,
) -> Result<Checkpoint> {
    let ck = load(warm_dir(tc)?, role)?;
    ck.expect(protocol, role, config_digest(protocol, tc, n_holders))?;
    Ok(ck)
}

/// Digest of the configuration knobs a checkpoint's blocks depend on.
/// Loading under any other value is rejected by [`Checkpoint::expect`]:
/// the blocks would be shaped/scaled for a different run. Deliberately
/// excludes process-local knobs (threads, transport, pipeline depth,
/// checkpoint dir) that do not change the trained values.
pub fn config_digest(protocol: &str, tc: &crate::config::TrainConfig, n_holders: usize) -> u64 {
    let compress = tc.compress.map(|c| c.canonical()).unwrap_or_default();
    let mut s = format!(
        "ckpt-cfg v1 proto={protocol} holders={n_holders} batch={} seed={} sgld={} \
         lr={:?} pbits={} shortexp={} noise={:?} slot={} compress={compress}",
        tc.batch,
        tc.seed,
        tc.sgld as u8,
        tc.lr_override,
        tc.paillier_bits,
        tc.paillier_short_exp as u8,
        tc.sgld_noise,
        tc.slot_bits,
    );
    // bounded staleness reorders weight updates, so the trained blocks
    // differ from the lock-step run; appended only when nonzero so every
    // checkpoint written before this field keeps its digest
    if tc.staleness != 0 {
        s.push_str(&format!(" stale={}", tc.staleness));
    }
    let mut f = Fnv::new();
    f.add_bytes(s.as_bytes());
    f.0
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Representative role blocks for all four trainers: SPNN-HE/SS
    /// holder (f64 theta + mask-RNG cursor), SPNN server stack (f64),
    /// SecureML party (u64 ring shares + dealer/mask cursors), SplitNN
    /// holder encoder (f64, no cursors).
    fn samples() -> Vec<Checkpoint> {
        let mut hld = Checkpoint::new("spnn-he", "holder0", 0x1111);
        hld.push_f64("theta", vec![0.25, -1.5, 3.0e-9, f64::MIN_POSITIVE]);
        hld.push_cursor("rng", (42, 6));
        let mut srv = Checkpoint::new("spnn-ss", "server", 0x2222);
        srv.push_f64("server0_w", (0..64).map(|i| i as f64 * 0.125).collect());
        srv.push_f64("server0_b", vec![0.0; 8]);
        srv.push_cursor("rng", (7, 0));
        srv.push_cursor("dealer", (9, 14));
        let mut mpc = Checkpoint::new("secureml", "party0", 0x3333);
        mpc.push_u64("w0", vec![u64::MAX, 0, 1, 0x8000_0000_0000_0000]);
        mpc.push_u64("b0", vec![3, 5, 7]);
        mpc.push_cursor("rng", (1, 2));
        let mut spl = Checkpoint::new("splitnn", "holder1", 0x4444);
        spl.push_f64("enc", vec![-0.5; 24]);
        vec![hld, srv, mpc, spl]
    }

    #[test]
    fn roundtrips_all_four_trainers_role_blocks_bit_exactly() {
        for ck in samples() {
            let bytes = ck.encode();
            let back = Checkpoint::decode(&bytes).unwrap();
            assert_eq!(back, ck, "{}/{}", ck.protocol, ck.role);
        }
        // f64 payloads roundtrip via to_bits: NaN and -0.0 included
        let mut ck = Checkpoint::new("spnn-he", "server", 1);
        ck.push_f64("w", vec![f64::NAN, -0.0, f64::INFINITY]);
        let back = Checkpoint::decode(&ck.encode()).unwrap();
        let w = back.f64s("w").unwrap();
        assert!(w[0].is_nan());
        assert_eq!(w[1].to_bits(), (-0.0f64).to_bits());
        assert_eq!(w[2], f64::INFINITY);
    }

    #[test]
    fn every_truncation_is_reported_as_truncated_or_corrupt() {
        let ck = &samples()[1];
        let bytes = ck.encode();
        for cut in 0..bytes.len() {
            let err = Checkpoint::decode(&bytes[..cut]).unwrap_err().to_string();
            // a prefix either fails the length check, the checksum (the
            // last 8 bytes of the prefix are not a valid footer), or the
            // magic — never parses successfully
            assert!(
                err.contains("truncated") || err.contains("checksum") || err.contains("magic"),
                "cut={cut}: {err}"
            );
        }
    }

    #[test]
    fn flipped_bytes_fail_the_checksum() {
        let ck = &samples()[2];
        let bytes = ck.encode();
        for &at in &[0usize, 9, 20, bytes.len() / 2, bytes.len() - 9, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[at] ^= 0x40;
            let err = Checkpoint::decode(&bad).unwrap_err().to_string();
            assert!(
                err.contains("checksum") || err.contains("magic"),
                "flip at {at}: {err}"
            );
        }
    }

    #[test]
    fn wrong_version_header_is_rejected_by_number() {
        let ck = &samples()[0];
        let mut bytes = ck.encode();
        // bump the version field (offset 8) and re-stamp the checksum so
        // only the version check can fire
        bytes[8] = 99;
        let n = bytes.len();
        let mut f = Fnv::new();
        f.add_bytes(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&f.0.to_le_bytes());
        let err = Checkpoint::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("unsupported checkpoint version 99"), "{err}");
    }

    #[test]
    fn cross_role_and_cross_protocol_loads_are_rejected() {
        let ck = &samples()[0]; // spnn-he / holder0 / 0x1111
        let err = ck.expect("spnn-he", "holder1", 0x1111).unwrap_err().to_string();
        assert!(err.contains("role mismatch"), "{err}");
        assert!(err.contains("holder0") && err.contains("holder1"), "{err}");
        let err = ck.expect("spnn-ss", "holder0", 0x1111).unwrap_err().to_string();
        assert!(err.contains("protocol mismatch"), "{err}");
        let err = ck.expect("spnn-he", "holder0", 0xdead).unwrap_err().to_string();
        assert!(err.contains("config mismatch"), "{err}");
        ck.expect("spnn-he", "holder0", 0x1111).unwrap();
    }

    #[test]
    fn save_is_atomic_and_load_reports_missing_files() {
        let dir = std::env::temp_dir().join(format!("spnn-ckpt-test-{}", std::process::id()));
        let dir = dir.to_str().unwrap().to_string();
        let _ = fs::remove_dir_all(&dir);
        let err = load(&dir, "server").unwrap_err().to_string();
        assert!(err.contains("cannot read checkpoint"), "{err}");
        let ck = &samples()[1];
        save(&dir, ck).unwrap();
        // no tmp file left behind
        assert!(!path_for(&dir, "server").with_extension("ckpt.tmp").exists());
        let back = load(&dir, "server").unwrap();
        assert_eq!(&back, ck);
        // overwrite is atomic too (rename over the existing file)
        save(&dir, ck).unwrap();
        assert_eq!(&load(&dir, "server").unwrap(), ck);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn config_digest_tracks_training_knobs_only() {
        let tc = crate::config::TrainConfig::default();
        let base = config_digest("spnn-he", &tc, 2);
        assert_eq!(base, config_digest("spnn-he", &tc.clone(), 2));
        assert_ne!(base, config_digest("spnn-ss", &tc, 2));
        assert_ne!(base, config_digest("spnn-he", &tc, 3));
        let mut t2 = tc.clone();
        t2.seed = 8;
        assert_ne!(base, config_digest("spnn-he", &t2, 2));
        let mut t3 = tc.clone();
        t3.batch = 512;
        assert_ne!(base, config_digest("spnn-he", &t3, 2));
        // process-local knobs do not change the digest
        let mut t4 = tc.clone();
        t4.exec_threads = 4;
        t4.pipeline_depth = 3;
        t4.transport = crate::config::TransportKind::Tcp;
        t4.checkpoint_dir = Some("/tmp/x".into());
        t4.warm_start = true;
        t4.checkpoint_keep = Some(3);
        assert_eq!(base, config_digest("spnn-he", &t4, 2));
        // bounded staleness changes the trained values, so it taints the
        // digest — but only when nonzero, keeping old checkpoints valid
        let mut t5 = tc.clone();
        t5.staleness = 2;
        assert_ne!(base, config_digest("spnn-he", &t5, 2));
        let mut t6 = tc.clone();
        t6.staleness = 0;
        assert_eq!(base, config_digest("spnn-he", &t6, 2));
    }

    #[test]
    fn rotation_keeps_n_generations_and_pruned_dir_warm_starts() {
        let dir = std::env::temp_dir().join(format!("spnn-ckpt-rot-{}", std::process::id()));
        let dir = dir.to_str().unwrap().to_string();
        let _ = fs::remove_dir_all(&dir);
        let gen_ck = |v: f64| {
            let mut ck = Checkpoint::new("splitnn", "server", 0xabc);
            ck.push_f64("enc", vec![v; 4]);
            ck
        };
        // keep=2: live + one rotated generation, older ones pruned
        for i in 0..4 {
            save_rotated(&dir, &gen_ck(i as f64), Some(2)).unwrap();
        }
        let live = load(&dir, "server").unwrap();
        assert_eq!(live.f64s("enc").unwrap(), &[3.0; 4]);
        let prev_bytes = fs::read(rotated_path(&dir, "server", 1)).unwrap();
        let prev = Checkpoint::decode(&prev_bytes).unwrap();
        assert_eq!(prev.f64s("enc").unwrap(), &[2.0; 4]);
        assert!(!rotated_path(&dir, "server", 2).exists(), "generation 2 not pruned");
        assert!(!path_for(&dir, "server").with_extension("ckpt.tmp").exists());
        // a pruned dir still warm-starts: the live file is always the
        // newest generation and loads verbatim
        let back = load(&dir, "server").unwrap();
        back.expect("splitnn", "server", 0xabc).unwrap();
        // lowering keep prunes the now-excess generation too
        save_rotated(&dir, &gen_ck(4.0), Some(1)).unwrap();
        assert_eq!(load(&dir, "server").unwrap().f64s("enc").unwrap(), &[4.0; 4]);
        assert!(!rotated_path(&dir, "server", 1).exists());
        // keep=None is exactly save(): no rotated files appear
        save_rotated(&dir, &gen_ck(5.0), None).unwrap();
        assert_eq!(load(&dir, "server").unwrap().f64s("enc").unwrap(), &[5.0; 4]);
        assert!(!rotated_path(&dir, "server", 1).exists());
        // other roles' files are untouched by this role's pruning
        save(&dir, &samples()[0]).unwrap();
        save_rotated(&dir, &gen_ck(6.0), Some(1)).unwrap();
        assert!(path_for(&dir, "holder0").exists());
        let _ = fs::remove_dir_all(&dir);
    }
}
