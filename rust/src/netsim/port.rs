//! Per-party network endpoint with a Lamport-style virtual clock and
//! tagged out-of-order delivery.
//!
//! Pipelined protocols keep several mini-batches in flight per link, so a
//! receiver may be handed batch `t+1`'s message while it still waits for
//! batch `t`. Every [`Msg`] therefore carries a `tag` (batch / stream id);
//! [`NetPort::recv_tagged`] delivers the next message matching a tag and
//! parks mismatches in a per-peer reorder buffer, preserving FIFO order
//! within each tag. Untagged traffic ([`NO_TAG`]) and [`NetPort::recv`]
//! keep the seed semantics.
//!
//! Clock accounting credits overlap: wall time blocked inside a receive is
//! *not* compute (the wall anchor restarts on delivery), and a message's
//! arrival stamp depends only on its departure time and size — so work done
//! ahead of demand (prefetched crypto material) is absorbed into the wait
//! for slower remote results instead of extending the critical path.
//!
//! The sender's **uplink is a shared resource**: concurrent in-flight
//! online messages from one party serialize on it, so a message's
//! departure is `max(clock, uplink_free)` and the uplink stays busy for
//! the message's transfer time. Without this, k messages pushed back to
//! back would each see the full link bandwidth and the sim would credit a
//! k-times-too-fast network (see `EXPERIMENTS.md` §Crypto substrate —
//! honest accounting matters most once crypto stops dominating). Latency
//! still overlaps across messages (propagation is not a shared resource),
//! and offline-phase traffic is excluded, mirroring its exclusion from the
//! online clock.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::{LinkSpec, NetStats, PartyId, Payload, Phase};
use crate::{Error, Result};

/// Record a wall duration into a lazily-created per-peer transport
/// histogram (`transport_<kind>_seconds{peer="N"}`). The `Arc` handles are
/// cached in the port so the registry lock is not taken per message.
fn record_peer_ns(
    cache: &mut HashMap<PartyId, Arc<crate::obs::Hist>>,
    kind: &str,
    peer: PartyId,
    ns: u64,
) {
    cache
        .entry(peer)
        .or_insert_with(|| {
            crate::obs::registry().hist(&format!("transport_{kind}_seconds{{peer=\"{peer}\"}}"))
        })
        .record_ns(ns);
}

/// Tag carried by messages sent through the untagged [`NetPort::send`] /
/// [`NetPort::send_phase`] API.
pub const NO_TAG: u64 = u64::MAX;

/// A message in flight.
#[derive(Debug)]
pub struct Msg {
    pub from: PartyId,
    /// Batch / stream id for out-of-order matching ([`NO_TAG`] = untagged).
    pub tag: u64,
    pub payload: Payload,
    /// Sender's virtual clock at departure — after queueing for the
    /// sender's shared uplink (online phase).
    pub depart: f64,
    pub phase: Phase,
}

/// One party's connection to the simulated mesh.
///
/// Wall time elapsed between calls on this port is accounted as local
/// compute and advances the virtual clock; receives forward the clock past
/// the simulated wire delay. Deadlocks are caught by a receive timeout
/// that reports both endpoints, the awaited tag, the current protocol
/// stage, and the reorder-buffer depths.
pub struct NetPort {
    pub id: PartyId,
    pub name: String,
    spec: LinkSpec,
    txs: HashMap<PartyId, mpsc::Sender<Msg>>,
    rxs: HashMap<PartyId, mpsc::Receiver<Msg>>,
    /// Out-of-order messages parked per peer, in arrival order.
    pending: HashMap<PartyId, VecDeque<Msg>>,
    stats: Arc<NetStats>,
    /// Protocol-stage label stamped on sends (traffic breakdown) and
    /// reported by deadlock diagnostics.
    stage: &'static str,
    now_s: f64,
    /// Virtual time at which this party's uplink finishes its current
    /// transfer — the bandwidth-contention cursor for online sends.
    uplink_free_s: f64,
    last_wall: Instant,
    recv_timeout: Duration,
    /// Cached per-peer send/recv latency histograms (observability).
    obs_send: HashMap<PartyId, Arc<crate::obs::Hist>>,
    obs_recv: HashMap<PartyId, Arc<crate::obs::Hist>>,
}

impl NetPort {
    /// Build a port from raw per-peer channel endpoints. The netsim
    /// [`full_mesh`](super::full_mesh) wires both ends in-process; the TCP
    /// backend ([`crate::transport::tcp`]) wires each endpoint to socket
    /// reader/writer threads instead — the clock, reorder-buffer, stats,
    /// and diagnostic machinery here is backend-agnostic.
    pub(crate) fn new(
        id: PartyId,
        name: &str,
        spec: LinkSpec,
        txs: HashMap<PartyId, mpsc::Sender<Msg>>,
        rxs: HashMap<PartyId, mpsc::Receiver<Msg>>,
        stats: Arc<NetStats>,
    ) -> Self {
        NetPort {
            id,
            name: name.to_string(),
            spec,
            txs,
            rxs,
            pending: HashMap::new(),
            stats,
            stage: "run",
            now_s: 0.0,
            uplink_free_s: 0.0,
            last_wall: Instant::now(),
            recv_timeout: Duration::from_secs(600),
            obs_send: HashMap::new(),
            obs_recv: HashMap::new(),
        }
    }

    /// Accumulate wall time since the last netsim call as compute time.
    fn absorb_compute(&mut self) {
        let dt = self.last_wall.elapsed().as_secs_f64();
        self.now_s += dt;
        self.last_wall = Instant::now();
    }

    /// Current virtual time (compute + wire delays so far).
    pub fn now(&mut self) -> f64 {
        self.absorb_compute();
        self.now_s
    }

    /// Manually advance the virtual clock (extrapolated compute sections).
    pub fn advance(&mut self, dt: f64) {
        self.absorb_compute();
        self.now_s += dt;
    }

    /// Reset the clock (e.g. between timed epochs).
    pub fn reset_clock(&mut self) {
        self.now_s = 0.0;
        self.uplink_free_s = 0.0;
        self.last_wall = Instant::now();
    }

    /// Label the current protocol stage: stamped on outgoing traffic for
    /// the per-stage byte breakdown and echoed in deadlock diagnostics.
    pub fn set_stage(&mut self, stage: &'static str) {
        self.stage = stage;
    }

    /// Send `payload` to party `to` (online phase, untagged).
    pub fn send(&mut self, to: PartyId, payload: Payload) -> Result<()> {
        self.send_tagged_phase(to, NO_TAG, payload, Phase::Online)
    }

    /// Send with explicit phase tag.
    pub fn send_phase(&mut self, to: PartyId, payload: Payload, phase: Phase) -> Result<()> {
        self.send_tagged_phase(to, NO_TAG, payload, phase)
    }

    /// Send tagged with a batch / stream id (online phase).
    pub fn send_tagged(&mut self, to: PartyId, tag: u64, payload: Payload) -> Result<()> {
        self.send_tagged_phase(to, tag, payload, Phase::Online)
    }

    /// Send with explicit tag and phase.
    pub fn send_tagged_phase(
        &mut self,
        to: PartyId,
        tag: u64,
        payload: Payload,
        phase: Phase,
    ) -> Result<()> {
        let t0 = crate::obs::enabled().then(Instant::now);
        self.absorb_compute();
        let bytes = payload.total_bytes();
        self.stats.record(self.id, to, bytes, phase);
        // per-message wire time for the stage breakdown (queueing behind
        // earlier sends shows up in the clock, not here)
        let wire_s = match phase {
            Phase::Online => self.spec.latency_s + self.spec.transfer_time(bytes),
            Phase::Offline => 0.0,
        };
        self.stats.record_stage(phase, self.stage, bytes, wire_s);
        // online sends queue on this party's shared uplink: departure waits
        // for the previous transfer to drain, then occupies the link
        let depart = match phase {
            Phase::Online => {
                let depart = self.now_s.max(self.uplink_free_s);
                self.uplink_free_s = depart + self.spec.transfer_time(bytes);
                depart
            }
            Phase::Offline => self.now_s,
        };
        let msg = Msg { from: self.id, tag, payload, depart, phase };
        let res = self
            .txs
            .get(&to)
            .ok_or_else(|| Error::Net(format!("{}: unknown peer {to}", self.name)))?
            .send(msg)
            .map_err(|_| Error::Net(format!("{}: peer {to} disconnected", self.name)));
        if let Some(t0) = t0 {
            record_peer_ns(&mut self.obs_send, "send", to, t0.elapsed().as_nanos() as u64);
        }
        res
    }

    /// Consume a delivered message: restart the wall anchor (blocked time
    /// is idle-wait, not compute) and forward the virtual clock past the
    /// simulated arrival.
    fn accept(&mut self, msg: Msg) -> (u64, Payload) {
        self.last_wall = Instant::now();
        if msg.phase == Phase::Online {
            let arrival = msg.depart
                + self.spec.latency_s
                + self.spec.transfer_time(msg.payload.total_bytes());
            self.now_s = self.now_s.max(arrival);
        } else {
            // offline traffic: causality only, no wire delay
            self.now_s = self.now_s.max(msg.depart);
        }
        (msg.tag, msg.payload)
    }

    /// Pull the next channel message from `from` within the deadline.
    fn next_msg(&self, from: PartyId, remaining: Duration, awaited: &str) -> Result<Msg> {
        let rx = self
            .rxs
            .get(&from)
            .ok_or_else(|| Error::Net(format!("{}: unknown peer {from}", self.name)))?;
        rx.recv_timeout(remaining).map_err(|e| match e {
            mpsc::RecvTimeoutError::Disconnected => Error::Net(format!(
                "{}: peer {} ({}) disconnected while {} awaited {}",
                self.name,
                from,
                self.stats.name(from),
                self.name,
                awaited
            )),
            mpsc::RecvTimeoutError::Timeout => self.timeout_error(from, awaited),
        })
    }

    /// Deadlock diagnostic: both endpoints, awaited tag, stage, and
    /// reorder-buffer queue depths.
    fn timeout_error(&self, from: PartyId, awaited: &str) -> Error {
        let fmt_tag =
            |t: u64| if t == NO_TAG { "-".to_string() } else { t.to_string() };
        let here: Vec<String> = self
            .pending
            .get(&from)
            .map(|q| q.iter().map(|m| fmt_tag(m.tag)).collect())
            .unwrap_or_default();
        let elsewhere: usize = self
            .pending
            .iter()
            .filter(|(p, _)| **p != from)
            .map(|(_, q)| q.len())
            .sum();
        Error::Net(format!(
            "{}(party {}) timed out after {:.0}s receiving from {}(party {}): \
             awaited {} in stage {:?}; reorder buffer holds {} message(s) from \
             this peer (tags [{}]) and {} from other peers — the parties are \
             likely deadlocked on mismatched send/recv schedules",
            self.name,
            self.id,
            self.recv_timeout.as_secs_f64(),
            self.stats.name(from),
            from,
            awaited,
            self.stage,
            here.len(),
            here.join(", "),
            elsewhere,
        ))
    }

    /// Blocking receive of the next message from `from` regardless of tag
    /// (buffered messages first, in arrival order), advancing the virtual
    /// clock past the message's simulated arrival time.
    pub fn recv(&mut self, from: PartyId) -> Result<Payload> {
        self.recv_any_tag(from).map(|(_, p)| p)
    }

    /// Like [`Self::recv`] but also returns the message's tag (used by
    /// actors that echo tags, e.g. the dealer).
    pub fn recv_any_tag(&mut self, from: PartyId) -> Result<(u64, Payload)> {
        let t0 = crate::obs::enabled().then(Instant::now);
        let res = self.recv_any_tag_inner(from);
        if let Some(t0) = t0 {
            record_peer_ns(&mut self.obs_recv, "recv", from, t0.elapsed().as_nanos() as u64);
        }
        res
    }

    fn recv_any_tag_inner(&mut self, from: PartyId) -> Result<(u64, Payload)> {
        self.absorb_compute(); // compute up to the blocking point
        if let Some(msg) = self.pending.get_mut(&from).and_then(|q| q.pop_front()) {
            return Ok(self.accept(msg));
        }
        let msg = self.next_msg(from, self.recv_timeout, "any message")?;
        Ok(self.accept(msg))
    }

    /// Blocking receive of the next message from `from` carrying `tag`.
    ///
    /// Messages with other tags arriving first are parked in the per-peer
    /// reorder buffer (FIFO within each tag) and delivered by their own
    /// `recv_tagged` / [`Self::recv`] calls later.
    pub fn recv_tagged(&mut self, from: PartyId, tag: u64) -> Result<Payload> {
        let t0 = crate::obs::enabled().then(Instant::now);
        let res = self.recv_tagged_inner(from, tag);
        if let Some(t0) = t0 {
            record_peer_ns(&mut self.obs_recv, "recv", from, t0.elapsed().as_nanos() as u64);
        }
        res
    }

    fn recv_tagged_inner(&mut self, from: PartyId, tag: u64) -> Result<Payload> {
        self.absorb_compute();
        if let Some(q) = self.pending.get_mut(&from) {
            if let Some(pos) = q.iter().position(|m| m.tag == tag) {
                let msg = q.remove(pos).expect("position within queue");
                return Ok(self.accept(msg).1);
            }
        }
        let awaited = format!("tag {tag}");
        let deadline = Instant::now() + self.recv_timeout;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            let msg = self.next_msg(from, remaining, &awaited)?;
            if msg.tag == tag {
                return Ok(self.accept(msg).1);
            }
            self.pending.entry(from).or_default().push_back(msg);
        }
    }

    /// Non-blocking variant of [`Self::recv_tagged`]: deliver the next
    /// `tag` message from `from` if one is already buffered or sitting in
    /// the channel, parking mismatches, and return `None` when the channel
    /// is drained. Lets pipelined parties pull remote material inside
    /// their prefetch window instead of blocking for it on the critical
    /// path.
    pub fn try_recv_tagged(&mut self, from: PartyId, tag: u64) -> Result<Option<Payload>> {
        self.absorb_compute();
        if let Some(q) = self.pending.get_mut(&from) {
            if let Some(pos) = q.iter().position(|m| m.tag == tag) {
                let msg = q.remove(pos).expect("position within queue");
                return Ok(Some(self.accept(msg).1));
            }
        }
        loop {
            let polled = {
                let rx = self
                    .rxs
                    .get(&from)
                    .ok_or_else(|| Error::Net(format!("{}: unknown peer {from}", self.name)))?;
                rx.try_recv()
            };
            match polled {
                Ok(msg) if msg.tag == tag => return Ok(Some(self.accept(msg).1)),
                Ok(msg) => self.pending.entry(from).or_default().push_back(msg),
                Err(mpsc::TryRecvError::Empty) => return Ok(None),
                Err(mpsc::TryRecvError::Disconnected) => {
                    return Err(Error::Net(format!(
                        "{}: peer {} ({}) disconnected while {} polled tag {tag}",
                        self.name,
                        from,
                        self.stats.name(from),
                        self.name,
                    )))
                }
            }
        }
    }

    /// Receive and assert the u64 variant (the most common case).
    pub fn recv_u64s(&mut self, from: PartyId) -> Result<Vec<u64>> {
        self.recv(from)?.into_u64s()
    }

    pub fn recv_f32s(&mut self, from: PartyId) -> Result<Vec<f32>> {
        self.recv(from)?.into_f32s()
    }

    pub fn set_recv_timeout(&mut self, d: Duration) {
        self.recv_timeout = d;
    }

    /// Link spec (for cost estimation in reports).
    pub fn spec(&self) -> LinkSpec {
        self.spec
    }
}
