//! Per-party network endpoint with a Lamport-style virtual clock.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::{LinkSpec, NetStats, PartyId, Payload, Phase};
use crate::{Error, Result};

/// A message in flight.
#[derive(Debug)]
pub struct Msg {
    pub from: PartyId,
    pub payload: Payload,
    /// Sender's virtual clock at departure.
    pub depart: f64,
    pub phase: Phase,
}

/// One party's connection to the simulated mesh.
///
/// Wall time elapsed between calls on this port is accounted as local
/// compute and advances the virtual clock; receives forward the clock past
/// the simulated wire delay. Deadlocks are caught by a receive timeout.
pub struct NetPort {
    pub id: PartyId,
    pub name: String,
    spec: LinkSpec,
    txs: HashMap<PartyId, mpsc::Sender<Msg>>,
    rxs: HashMap<PartyId, mpsc::Receiver<Msg>>,
    stats: Arc<NetStats>,
    now_s: f64,
    last_wall: Instant,
    recv_timeout: Duration,
}

impl NetPort {
    pub(super) fn new(
        id: PartyId,
        name: &str,
        spec: LinkSpec,
        txs: HashMap<PartyId, mpsc::Sender<Msg>>,
        rxs: HashMap<PartyId, mpsc::Receiver<Msg>>,
        stats: Arc<NetStats>,
    ) -> Self {
        NetPort {
            id,
            name: name.to_string(),
            spec,
            txs,
            rxs,
            stats,
            now_s: 0.0,
            last_wall: Instant::now(),
            recv_timeout: Duration::from_secs(600),
        }
    }

    /// Accumulate wall time since the last netsim call as compute time.
    fn absorb_compute(&mut self) {
        let dt = self.last_wall.elapsed().as_secs_f64();
        self.now_s += dt;
        self.last_wall = Instant::now();
    }

    /// Current virtual time (compute + wire delays so far).
    pub fn now(&mut self) -> f64 {
        self.absorb_compute();
        self.now_s
    }

    /// Manually advance the virtual clock (extrapolated compute sections).
    pub fn advance(&mut self, dt: f64) {
        self.absorb_compute();
        self.now_s += dt;
    }

    /// Reset the clock (e.g. between timed epochs).
    pub fn reset_clock(&mut self) {
        self.now_s = 0.0;
        self.last_wall = Instant::now();
    }

    /// Send `payload` to party `to` (online phase).
    pub fn send(&mut self, to: PartyId, payload: Payload) -> Result<()> {
        self.send_phase(to, payload, Phase::Online)
    }

    /// Send with explicit phase tag.
    pub fn send_phase(&mut self, to: PartyId, payload: Payload, phase: Phase) -> Result<()> {
        self.absorb_compute();
        let bytes = payload.total_bytes();
        self.stats.record(self.id, to, bytes, phase);
        let msg = Msg { from: self.id, payload, depart: self.now_s, phase };
        self.txs
            .get(&to)
            .ok_or_else(|| Error::Net(format!("{}: unknown peer {to}", self.name)))?
            .send(msg)
            .map_err(|_| Error::Net(format!("{}: peer {to} disconnected", self.name)))
    }

    /// Blocking receive from party `from`, advancing the virtual clock past
    /// the message's simulated arrival time.
    pub fn recv(&mut self, from: PartyId) -> Result<Payload> {
        self.absorb_compute(); // compute up to the blocking point
        let rx = self
            .rxs
            .get(&from)
            .ok_or_else(|| Error::Net(format!("{}: unknown peer {from}", self.name)))?;
        let msg = rx
            .recv_timeout(self.recv_timeout)
            .map_err(|e| Error::Net(format!("{}: recv from {from}: {e}", self.name)))?;
        // blocked wall time is NOT compute; restart the wall anchor
        self.last_wall = Instant::now();
        if msg.phase == Phase::Online {
            let arrival = msg.depart
                + self.spec.latency_s
                + self.spec.transfer_time(msg.payload.total_bytes());
            self.now_s = self.now_s.max(arrival);
        } else {
            // offline traffic: causality only, no wire delay
            self.now_s = self.now_s.max(msg.depart);
        }
        Ok(msg.payload)
    }

    /// Receive and assert the u64 variant (the most common case).
    pub fn recv_u64s(&mut self, from: PartyId) -> Result<Vec<u64>> {
        self.recv(from)?.into_u64s()
    }

    pub fn recv_f32s(&mut self, from: PartyId) -> Result<Vec<f32>> {
        self.recv(from)?.into_f32s()
    }

    pub fn set_recv_timeout(&mut self, d: Duration) {
        self.recv_timeout = d;
    }

    /// Link spec (for cost estimation in reports).
    pub fn spec(&self) -> LinkSpec {
        self.spec
    }
}
