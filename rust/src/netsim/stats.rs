//! Shared traffic statistics for a simulated deployment.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::{PartyId, Phase};

/// One row of the per-phase / per-stage traffic breakdown. Owned
/// strings so rows can be shipped across process boundaries in a
/// [`PartyOut`](crate::parties::PartyOut) and re-aggregated by the
/// collecting coordinator ([`merge_stage_rows`]).
#[derive(Clone, Debug, PartialEq)]
pub struct StageRow {
    /// Online or offline traffic.
    pub phase: Phase,
    /// Protocol-stage label ([`super::NetPort::set_stage`]).
    pub stage: String,
    /// Accounted wire bytes sent in this stage.
    pub bytes: u64,
    /// Messages sent in this stage.
    pub msgs: u64,
    /// Estimated wire seconds (latency + serialization) for the online
    /// phase; 0 for offline traffic (which never delays the online clock).
    pub wire_s: f64,
}

#[derive(Default)]
struct StageEntry {
    bytes: u64,
    msgs: u64,
    wire_s: f64,
}

/// Lock-free per-link byte/message counters, plus a coarse per-stage map.
///
/// Indexed `[from][to]`; phases tracked separately so experiments can report
/// online vs offline traffic (SecureML-style accounting). The stage map is
/// keyed by the sender's current stage label and answers "where does the
/// traffic go" for the Table 2/3 reports.
#[derive(Debug)]
pub struct NetStats {
    names: Vec<String>,
    n: usize,
    bytes_online: Vec<AtomicU64>,
    bytes_offline: Vec<AtomicU64>,
    msgs_online: Vec<AtomicU64>,
    msgs_offline: Vec<AtomicU64>,
    stages: Mutex<HashMap<(Phase, &'static str), StageEntry>>,
}

impl std::fmt::Debug for StageEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}B/{}msg/{:.3}s", self.bytes, self.msgs, self.wire_s)
    }
}

impl NetStats {
    pub fn new(names: &[&str]) -> Self {
        let n = names.len();
        let mk = || (0..n * n).map(|_| AtomicU64::new(0)).collect();
        NetStats {
            names: names.iter().map(|s| s.to_string()).collect(),
            n,
            bytes_online: mk(),
            bytes_offline: mk(),
            msgs_online: mk(),
            msgs_offline: mk(),
            stages: Mutex::new(HashMap::new()),
        }
    }

    /// Party name by id (deadlock diagnostics), `"?"` if out of range.
    pub fn name(&self, id: PartyId) -> &str {
        self.names.get(id).map(|s| s.as_str()).unwrap_or("?")
    }

    pub(super) fn record(&self, from: PartyId, to: PartyId, bytes: usize, phase: Phase) {
        if from >= self.n || to >= self.n {
            return; // send() will fail with unknown peer anyway
        }
        let idx = from * self.n + to;
        let (b, m) = match phase {
            Phase::Online => (&self.bytes_online, &self.msgs_online),
            Phase::Offline => (&self.bytes_offline, &self.msgs_offline),
        };
        b[idx].fetch_add(bytes as u64, Ordering::Relaxed);
        m[idx].fetch_add(1, Ordering::Relaxed);
    }

    pub(super) fn record_stage(
        &self,
        phase: Phase,
        stage: &'static str,
        bytes: usize,
        wire_s: f64,
    ) {
        let mut map = self.stages.lock().unwrap();
        let e = map.entry((phase, stage)).or_default();
        e.bytes += bytes as u64;
        e.msgs += 1;
        e.wire_s += wire_s;
    }

    /// Per-phase / per-stage traffic rows, online first, largest first.
    pub fn stage_rows(&self) -> Vec<StageRow> {
        let map = self.stages.lock().unwrap();
        let mut rows: Vec<StageRow> = map
            .iter()
            .map(|(&(phase, stage), e)| StageRow {
                phase,
                stage: stage.to_string(),
                bytes: e.bytes,
                msgs: e.msgs,
                wire_s: e.wire_s,
            })
            .collect();
        sort_stage_rows(&mut rows);
        rows
    }

    /// Total bytes from `a` to `b` (both phases).
    pub fn bytes_between(&self, a: PartyId, b: PartyId) -> usize {
        let idx = a * self.n + b;
        (self.bytes_online[idx].load(Ordering::Relaxed)
            + self.bytes_offline[idx].load(Ordering::Relaxed)) as usize
    }

    /// Total bytes party `from` sent in one phase (all destinations).
    /// Multi-process deployments report this per party so the coordinator
    /// can reassemble whole-mesh traffic totals from each process's
    /// sender-side counters.
    pub fn bytes_sent_by(&self, from: PartyId, phase: Phase) -> usize {
        if from >= self.n {
            return 0;
        }
        let v = match phase {
            Phase::Online => &self.bytes_online,
            Phase::Offline => &self.bytes_offline,
        };
        (0..self.n)
            .map(|to| v[from * self.n + to].load(Ordering::Relaxed))
            .sum::<u64>() as usize
    }

    /// Total bytes in one phase across all links.
    pub fn bytes_phase(&self, phase: Phase) -> usize {
        let v = match phase {
            Phase::Online => &self.bytes_online,
            Phase::Offline => &self.bytes_offline,
        };
        v.iter().map(|a| a.load(Ordering::Relaxed)).sum::<u64>() as usize
    }

    /// Total messages in one phase.
    pub fn msgs_phase(&self, phase: Phase) -> usize {
        let v = match phase {
            Phase::Online => &self.msgs_online,
            Phase::Offline => &self.msgs_offline,
        };
        v.iter().map(|a| a.load(Ordering::Relaxed)).sum::<u64>() as usize
    }

    /// Grand total bytes.
    pub fn total_bytes(&self) -> usize {
        self.bytes_phase(Phase::Online) + self.bytes_phase(Phase::Offline)
    }

    /// Reset all counters (between timed epochs).
    pub fn reset(&self) {
        for v in [
            &self.bytes_online,
            &self.bytes_offline,
            &self.msgs_online,
            &self.msgs_offline,
        ] {
            for a in v.iter() {
                a.store(0, Ordering::Relaxed);
            }
        }
        self.stages.lock().unwrap().clear();
    }

    /// Human-readable per-link traffic table.
    pub fn report(&self) -> String {
        let mut s = String::from("link traffic (online bytes / offline bytes):\n");
        for a in 0..self.n {
            for b in 0..self.n {
                if a == b {
                    continue;
                }
                let idx = a * self.n + b;
                let on = self.bytes_online[idx].load(Ordering::Relaxed);
                let off = self.bytes_offline[idx].load(Ordering::Relaxed);
                if on + off > 0 {
                    s.push_str(&format!(
                        "  {} -> {}: {} / {}\n",
                        self.names[a], self.names[b], on, off
                    ));
                }
            }
        }
        s
    }
}

/// Canonical stage-row ordering: online first, largest first.
fn sort_stage_rows(rows: &mut [StageRow]) {
    rows.sort_by(|a, b| {
        let pa = (a.phase == Phase::Offline) as u8;
        let pb = (b.phase == Phase::Offline) as u8;
        pa.cmp(&pb).then(b.bytes.cmp(&a.bytes)).then(a.stage.cmp(&b.stage))
    });
}

/// Merge per-process stage rows into one whole-mesh breakdown: rows with
/// the same `(phase, stage)` key are summed, then re-sorted canonically.
/// The multi-process runner feeds this with the coordinator's own rows
/// plus every worker's shipped rows, producing the same Table-3b
/// breakdown an in-process run reports.
pub fn merge_stage_rows<I>(row_sets: I) -> Vec<StageRow>
where
    I: IntoIterator,
    I::Item: IntoIterator<Item = StageRow>,
{
    let mut map: HashMap<(Phase, String), StageEntry> = HashMap::new();
    for rows in row_sets {
        for r in rows {
            let e = map.entry((r.phase, r.stage)).or_default();
            e.bytes += r.bytes;
            e.msgs += r.msgs;
            e.wire_s += r.wire_s;
        }
    }
    let mut rows: Vec<StageRow> = map
        .into_iter()
        .map(|((phase, stage), e)| StageRow {
            phase,
            stage,
            bytes: e.bytes,
            msgs: e.msgs,
            wire_s: e.wire_s,
        })
        .collect();
    sort_stage_rows(&mut rows);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let s = NetStats::new(&["A", "B", "S"]);
        s.record(0, 1, 100, Phase::Online);
        s.record(0, 1, 50, Phase::Online);
        s.record(1, 2, 7, Phase::Offline);
        assert_eq!(s.bytes_between(0, 1), 150);
        assert_eq!(s.bytes_between(1, 2), 7);
        assert_eq!(s.bytes_between(2, 0), 0);
        assert_eq!(s.bytes_phase(Phase::Online), 150);
        assert_eq!(s.bytes_phase(Phase::Offline), 7);
        assert_eq!(s.bytes_sent_by(0, Phase::Online), 150);
        assert_eq!(s.bytes_sent_by(1, Phase::Offline), 7);
        assert_eq!(s.bytes_sent_by(1, Phase::Online), 0);
        assert_eq!(s.bytes_sent_by(9, Phase::Online), 0);
        assert_eq!(s.msgs_phase(Phase::Online), 2);
        assert_eq!(s.total_bytes(), 157);
        assert!(s.report().contains("A -> B"));
        assert_eq!(s.name(2), "S");
        assert_eq!(s.name(9), "?");
        s.reset();
        assert_eq!(s.total_bytes(), 0);
    }

    #[test]
    fn stage_breakdown_aggregates_and_sorts() {
        let s = NetStats::new(&["A", "B"]);
        s.record_stage(Phase::Online, "fwd", 100, 0.5);
        s.record_stage(Phase::Online, "fwd", 50, 0.25);
        s.record_stage(Phase::Online, "bwd", 400, 1.0);
        s.record_stage(Phase::Offline, "triple", 9000, 0.0);
        let rows = s.stage_rows();
        assert_eq!(rows.len(), 3);
        // online first, largest first; offline last
        assert_eq!((rows[0].stage.as_str(), rows[0].bytes, rows[0].msgs), ("bwd", 400, 1));
        assert_eq!((rows[1].stage.as_str(), rows[1].bytes, rows[1].msgs), ("fwd", 150, 2));
        assert!((rows[1].wire_s - 0.75).abs() < 1e-12);
        assert_eq!(rows[2].phase, Phase::Offline);
        assert_eq!(rows[2].bytes, 9000);
        s.reset();
        assert!(s.stage_rows().is_empty());
    }

    #[test]
    fn merge_stage_rows_sums_across_processes() {
        let row = |phase, stage: &str, bytes, msgs, wire_s| StageRow {
            phase,
            stage: stage.into(),
            bytes,
            msgs,
            wire_s,
        };
        let a = vec![
            row(Phase::Online, "fwd", 100, 2, 0.5),
            row(Phase::Offline, "triple", 10, 1, 0.0),
        ];
        let b = vec![
            row(Phase::Online, "fwd", 50, 1, 0.25),
            row(Phase::Online, "bwd", 400, 1, 1.0),
        ];
        let merged = merge_stage_rows([a, b]);
        assert_eq!(merged.len(), 3);
        assert_eq!((merged[0].stage.as_str(), merged[0].bytes), ("bwd", 400));
        assert_eq!((merged[1].stage.as_str(), merged[1].bytes, merged[1].msgs), ("fwd", 150, 3));
        assert!((merged[1].wire_s - 0.75).abs() < 1e-12);
        assert_eq!(merged[2].phase, Phase::Offline);
        // merging one process's rows is the identity
        let solo = merge_stage_rows([vec![merged[2].clone()]]);
        assert_eq!(solo, vec![merged[2].clone()]);
    }
}
