//! Shared traffic statistics for a simulated deployment.

use std::sync::atomic::{AtomicU64, Ordering};

use super::{PartyId, Phase};

/// Lock-free per-link byte/message counters.
///
/// Indexed `[from][to]`; phases tracked separately so experiments can report
/// online vs offline traffic (SecureML-style accounting).
#[derive(Debug)]
pub struct NetStats {
    names: Vec<String>,
    n: usize,
    bytes_online: Vec<AtomicU64>,
    bytes_offline: Vec<AtomicU64>,
    msgs_online: Vec<AtomicU64>,
    msgs_offline: Vec<AtomicU64>,
}

impl NetStats {
    pub fn new(names: &[&str]) -> Self {
        let n = names.len();
        let mk = || (0..n * n).map(|_| AtomicU64::new(0)).collect();
        NetStats {
            names: names.iter().map(|s| s.to_string()).collect(),
            n,
            bytes_online: mk(),
            bytes_offline: mk(),
            msgs_online: mk(),
            msgs_offline: mk(),
        }
    }

    pub(super) fn record(&self, from: PartyId, to: PartyId, bytes: usize, phase: Phase) {
        if from >= self.n || to >= self.n {
            return; // send() will fail with unknown peer anyway
        }
        let idx = from * self.n + to;
        let (b, m) = match phase {
            Phase::Online => (&self.bytes_online, &self.msgs_online),
            Phase::Offline => (&self.bytes_offline, &self.msgs_offline),
        };
        b[idx].fetch_add(bytes as u64, Ordering::Relaxed);
        m[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Total bytes from `a` to `b` (both phases).
    pub fn bytes_between(&self, a: PartyId, b: PartyId) -> usize {
        let idx = a * self.n + b;
        (self.bytes_online[idx].load(Ordering::Relaxed)
            + self.bytes_offline[idx].load(Ordering::Relaxed)) as usize
    }

    /// Total bytes in one phase across all links.
    pub fn bytes_phase(&self, phase: Phase) -> usize {
        let v = match phase {
            Phase::Online => &self.bytes_online,
            Phase::Offline => &self.bytes_offline,
        };
        v.iter().map(|a| a.load(Ordering::Relaxed)).sum::<u64>() as usize
    }

    /// Total messages in one phase.
    pub fn msgs_phase(&self, phase: Phase) -> usize {
        let v = match phase {
            Phase::Online => &self.msgs_online,
            Phase::Offline => &self.msgs_offline,
        };
        v.iter().map(|a| a.load(Ordering::Relaxed)).sum::<u64>() as usize
    }

    /// Grand total bytes.
    pub fn total_bytes(&self) -> usize {
        self.bytes_phase(Phase::Online) + self.bytes_phase(Phase::Offline)
    }

    /// Reset all counters (between timed epochs).
    pub fn reset(&self) {
        for v in [
            &self.bytes_online,
            &self.bytes_offline,
            &self.msgs_online,
            &self.msgs_offline,
        ] {
            for a in v.iter() {
                a.store(0, Ordering::Relaxed);
            }
        }
    }

    /// Human-readable per-link traffic table.
    pub fn report(&self) -> String {
        let mut s = String::from("link traffic (online bytes / offline bytes):\n");
        for a in 0..self.n {
            for b in 0..self.n {
                if a == b {
                    continue;
                }
                let idx = a * self.n + b;
                let on = self.bytes_online[idx].load(Ordering::Relaxed);
                let off = self.bytes_offline[idx].load(Ordering::Relaxed);
                if on + off > 0 {
                    s.push_str(&format!(
                        "  {} -> {}: {} / {}\n",
                        self.names[a], self.names[b], on, off
                    ));
                }
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let s = NetStats::new(&["A", "B", "S"]);
        s.record(0, 1, 100, Phase::Online);
        s.record(0, 1, 50, Phase::Online);
        s.record(1, 2, 7, Phase::Offline);
        assert_eq!(s.bytes_between(0, 1), 150);
        assert_eq!(s.bytes_between(1, 2), 7);
        assert_eq!(s.bytes_between(2, 0), 0);
        assert_eq!(s.bytes_phase(Phase::Online), 150);
        assert_eq!(s.bytes_phase(Phase::Offline), 7);
        assert_eq!(s.msgs_phase(Phase::Online), 2);
        assert_eq!(s.total_bytes(), 157);
        assert!(s.report().contains("A -> B"));
        s.reset();
        assert_eq!(s.total_bytes(), 0);
    }
}
