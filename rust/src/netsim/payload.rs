//! Typed message payloads with exact wire-size accounting.
//!
//! No serde offline, and no real serialization is needed (in-process
//! channels move the data by ownership); the only thing the simulator needs
//! is *how many bytes this would be on the wire*. Batch / stream ids ride
//! the [`Msg`](super::Msg) envelope (not the payload), so tagging adds no
//! accounted bytes beyond the fixed [`Payload::HEADER_BYTES`] frame.

/// Message payload variants used by the SPNN protocols.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Ring elements / secret shares (`Z_{2^64}`).
    U64s(Vec<u64>),
    /// Dense activations / gradients.
    F32s(Vec<f32>),
    /// High-precision values (label-holder loss, metrics).
    F64s(Vec<f64>),
    /// Paillier ciphertexts as little-endian byte strings — the legacy
    /// per-ciphertext framing (one length prefix each). Kept for small
    /// one-off messages (key broadcast); the hot path uses
    /// [`Payload::CipherBlock`].
    Cipher(Vec<Vec<u8>>),
    /// A contiguous block of `count` equal-size ciphertexts, `ct_bytes`
    /// each, zero-padded to fixed width — the HE hot-path wire format.
    /// One allocation, one length prefix for the whole block.
    CipherBlock {
        data: Vec<u8>,
        ct_bytes: usize,
        count: usize,
    },
    /// A 32-byte PRG seed (compressed correlated randomness).
    Seed([u8; 32]),
    /// Boolean-share bit-matrix packed 64/word (secureml comparison).
    Bits(Vec<u64>),
    /// Control messages (coordinator orders, acks).
    Control(String),
    /// Serving: one coalesced inference batch — row ids into the parties'
    /// aligned private feature tables (the serve coordinator broadcasts
    /// these, tagged with the batch index; see [`crate::serve`]).
    InferReq(Vec<u32>),
    /// Serving: the scoring party's reply — one probability per requested
    /// row, in request order, tagged with the batch index.
    InferResp(Vec<f32>),
}

impl Payload {
    /// Fixed per-message framing overhead, matching the real socket
    /// envelope in [`crate::transport::wire`]: length prefix (4) +
    /// frame type (1) + seq (8) + ack (8) + from (4) + tag (8) +
    /// depart stamp (8) + phase (1) + payload kind (1).
    pub const HEADER_BYTES: usize = 43;

    /// Per-item length framing for the legacy [`Payload::Cipher`] variant:
    /// variable-size byte strings each need their own u32 length prefix.
    pub const CIPHER_ITEM_FRAME: usize = 4;

    /// Per-message framing for [`Payload::CipherBlock`]: one `ct_bytes` +
    /// one `count` word (u32 each) describing the whole block.
    pub const CIPHER_BLOCK_FRAME: usize = 8;

    /// Payload bytes on the wire (excluding [`Self::HEADER_BYTES`]).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::U64s(v) => v.len() * 8,
            Payload::F32s(v) => v.len() * 4,
            Payload::F64s(v) => v.len() * 8,
            Payload::Cipher(cs) => {
                cs.iter().map(|c| c.len() + Self::CIPHER_ITEM_FRAME).sum()
            }
            Payload::CipherBlock { data, .. } => data.len() + Self::CIPHER_BLOCK_FRAME,
            Payload::Seed(_) => 32,
            Payload::Bits(v) => v.len() * 8,
            Payload::Control(s) => s.len(),
            Payload::InferReq(v) => v.len() * 4,
            Payload::InferResp(v) => v.len() * 4,
        }
    }

    /// Total bytes including framing.
    pub fn total_bytes(&self) -> usize {
        self.wire_bytes() + Self::HEADER_BYTES
    }

    /// Helpers that unwrap a specific variant (protocol phase mismatches
    /// are bugs, so these return protocol errors, not panics).
    pub fn into_u64s(self) -> crate::Result<Vec<u64>> {
        match self {
            Payload::U64s(v) => Ok(v),
            other => Err(crate::Error::Protocol(format!(
                "expected U64s, got {}", other.kind()
            ))),
        }
    }

    pub fn into_f32s(self) -> crate::Result<Vec<f32>> {
        match self {
            Payload::F32s(v) => Ok(v),
            other => Err(crate::Error::Protocol(format!(
                "expected F32s, got {}", other.kind()
            ))),
        }
    }

    pub fn into_f64s(self) -> crate::Result<Vec<f64>> {
        match self {
            Payload::F64s(v) => Ok(v),
            other => Err(crate::Error::Protocol(format!(
                "expected F64s, got {}", other.kind()
            ))),
        }
    }

    pub fn into_cipher(self) -> crate::Result<Vec<Vec<u8>>> {
        match self {
            Payload::Cipher(v) => Ok(v),
            other => Err(crate::Error::Protocol(format!(
                "expected Cipher, got {}", other.kind()
            ))),
        }
    }

    /// Unwrap a flat ciphertext block as `(data, ct_bytes, count)`.
    pub fn into_cipher_block(self) -> crate::Result<(Vec<u8>, usize, usize)> {
        match self {
            Payload::CipherBlock { data, ct_bytes, count } => Ok((data, ct_bytes, count)),
            other => Err(crate::Error::Protocol(format!(
                "expected CipherBlock, got {}", other.kind()
            ))),
        }
    }

    pub fn into_seed(self) -> crate::Result<[u8; 32]> {
        match self {
            Payload::Seed(s) => Ok(s),
            other => Err(crate::Error::Protocol(format!(
                "expected Seed, got {}", other.kind()
            ))),
        }
    }

    pub fn into_bits(self) -> crate::Result<Vec<u64>> {
        match self {
            Payload::Bits(v) => Ok(v),
            other => Err(crate::Error::Protocol(format!(
                "expected Bits, got {}", other.kind()
            ))),
        }
    }

    pub fn into_control(self) -> crate::Result<String> {
        match self {
            Payload::Control(s) => Ok(s),
            other => Err(crate::Error::Protocol(format!(
                "expected Control, got {}", other.kind()
            ))),
        }
    }

    pub fn into_infer_req(self) -> crate::Result<Vec<u32>> {
        match self {
            Payload::InferReq(v) => Ok(v),
            other => Err(crate::Error::Protocol(format!(
                "expected InferReq, got {}", other.kind()
            ))),
        }
    }

    pub fn into_infer_resp(self) -> crate::Result<Vec<f32>> {
        match self {
            Payload::InferResp(v) => Ok(v),
            other => Err(crate::Error::Protocol(format!(
                "expected InferResp, got {}", other.kind()
            ))),
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Payload::U64s(_) => "U64s",
            Payload::F32s(_) => "F32s",
            Payload::F64s(_) => "F64s",
            Payload::Cipher(_) => "Cipher",
            Payload::CipherBlock { .. } => "CipherBlock",
            Payload::Seed(_) => "Seed",
            Payload::Bits(_) => "Bits",
            Payload::Control(_) => "Control",
            Payload::InferReq(_) => "InferReq",
            Payload::InferResp(_) => "InferResp",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes_per_variant() {
        assert_eq!(Payload::U64s(vec![0; 10]).wire_bytes(), 80);
        assert_eq!(Payload::F32s(vec![0.0; 10]).wire_bytes(), 40);
        assert_eq!(Payload::F64s(vec![0.0; 10]).wire_bytes(), 80);
        assert_eq!(Payload::Seed([0; 32]).wire_bytes(), 32);
        assert_eq!(Payload::Bits(vec![0; 4]).wire_bytes(), 32);
        assert_eq!(Payload::Control("go".into()).wire_bytes(), 2);
        assert_eq!(Payload::InferReq(vec![0; 6]).wire_bytes(), 24);
        assert_eq!(Payload::InferResp(vec![0.0; 6]).wire_bytes(), 24);
    }

    #[test]
    fn cipher_counts_per_item_framing() {
        // each variable-size ciphertext needs its own u32 length prefix
        assert_eq!(
            Payload::Cipher(vec![vec![0u8; 256], vec![0u8; 256]]).wire_bytes(),
            2 * (256 + Payload::CIPHER_ITEM_FRAME)
        );
        assert_eq!(Payload::Cipher(vec![]).wire_bytes(), 0);
        assert_eq!(
            Payload::Cipher(vec![vec![1]]).wire_bytes(),
            1 + Payload::CIPHER_ITEM_FRAME
        );
    }

    #[test]
    fn cipher_block_counts_one_frame_total() {
        let blk = Payload::CipherBlock { data: vec![0u8; 4 * 256], ct_bytes: 256, count: 4 };
        assert_eq!(blk.wire_bytes(), 4 * 256 + Payload::CIPHER_BLOCK_FRAME);
        // flat framing beats per-item framing for every count > 2
        let legacy = Payload::Cipher(vec![vec![0u8; 256]; 4]);
        assert!(blk.wire_bytes() < legacy.wire_bytes());
    }

    #[test]
    fn unwrap_helpers_enforce_variant() {
        assert!(Payload::U64s(vec![1]).into_u64s().is_ok());
        assert!(Payload::U64s(vec![1]).into_f32s().is_err());
        assert_eq!(Payload::InferReq(vec![3, 9]).into_infer_req().unwrap(), vec![3, 9]);
        assert!(Payload::InferReq(vec![3]).into_infer_resp().is_err());
        assert_eq!(Payload::InferResp(vec![0.5]).into_infer_resp().unwrap(), vec![0.5]);
        assert!(Payload::InferResp(vec![0.5]).into_infer_req().is_err());
        assert!(Payload::Control("x".into()).into_control().is_ok());
        assert!(Payload::Seed([1; 32]).into_seed().is_ok());
        let blk = Payload::CipherBlock { data: vec![7; 12], ct_bytes: 4, count: 3 };
        let (data, ct_bytes, count) = blk.into_cipher_block().unwrap();
        assert_eq!((data.len(), ct_bytes, count), (12, 4, 3));
        assert!(Payload::Cipher(vec![]).into_cipher_block().is_err());
        assert!(
            Payload::CipherBlock { data: vec![], ct_bytes: 0, count: 0 }
                .into_cipher()
                .is_err()
        );
    }
}
