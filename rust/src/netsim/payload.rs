//! Typed message payloads with exact wire-size accounting.
//!
//! No serde offline, and no real serialization is needed (in-process
//! channels move the data by ownership); the only thing the simulator needs
//! is *how many bytes this would be on the wire*.

/// Message payload variants used by the SPNN protocols.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Ring elements / secret shares (`Z_{2^64}`).
    U64s(Vec<u64>),
    /// Dense activations / gradients.
    F32s(Vec<f32>),
    /// High-precision values (label-holder loss, metrics).
    F64s(Vec<f64>),
    /// Paillier ciphertexts as little-endian byte strings.
    Cipher(Vec<Vec<u8>>),
    /// A 32-byte PRG seed (compressed correlated randomness).
    Seed([u8; 32]),
    /// Boolean-share bit-matrix packed 64/word (secureml comparison).
    Bits(Vec<u64>),
    /// Control messages (coordinator orders, acks).
    Control(String),
}

impl Payload {
    /// Fixed per-message framing overhead (type tag, lengths, routing) —
    /// roughly a gRPC/HTTP2 frame header.
    pub const HEADER_BYTES: usize = 16;

    /// Payload bytes on the wire (excluding [`Self::HEADER_BYTES`]).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::U64s(v) => v.len() * 8,
            Payload::F32s(v) => v.len() * 4,
            Payload::F64s(v) => v.len() * 8,
            Payload::Cipher(cs) => cs.iter().map(|c| c.len()).sum(),
            Payload::Seed(_) => 32,
            Payload::Bits(v) => v.len() * 8,
            Payload::Control(s) => s.len(),
        }
    }

    /// Total bytes including framing.
    pub fn total_bytes(&self) -> usize {
        self.wire_bytes() + Self::HEADER_BYTES
    }

    /// Helpers that unwrap a specific variant (protocol phase mismatches
    /// are bugs, so these return protocol errors, not panics).
    pub fn into_u64s(self) -> crate::Result<Vec<u64>> {
        match self {
            Payload::U64s(v) => Ok(v),
            other => Err(crate::Error::Protocol(format!(
                "expected U64s, got {}", other.kind()
            ))),
        }
    }

    pub fn into_f32s(self) -> crate::Result<Vec<f32>> {
        match self {
            Payload::F32s(v) => Ok(v),
            other => Err(crate::Error::Protocol(format!(
                "expected F32s, got {}", other.kind()
            ))),
        }
    }

    pub fn into_f64s(self) -> crate::Result<Vec<f64>> {
        match self {
            Payload::F64s(v) => Ok(v),
            other => Err(crate::Error::Protocol(format!(
                "expected F64s, got {}", other.kind()
            ))),
        }
    }

    pub fn into_cipher(self) -> crate::Result<Vec<Vec<u8>>> {
        match self {
            Payload::Cipher(v) => Ok(v),
            other => Err(crate::Error::Protocol(format!(
                "expected Cipher, got {}", other.kind()
            ))),
        }
    }

    pub fn into_seed(self) -> crate::Result<[u8; 32]> {
        match self {
            Payload::Seed(s) => Ok(s),
            other => Err(crate::Error::Protocol(format!(
                "expected Seed, got {}", other.kind()
            ))),
        }
    }

    pub fn into_bits(self) -> crate::Result<Vec<u64>> {
        match self {
            Payload::Bits(v) => Ok(v),
            other => Err(crate::Error::Protocol(format!(
                "expected Bits, got {}", other.kind()
            ))),
        }
    }

    pub fn into_control(self) -> crate::Result<String> {
        match self {
            Payload::Control(s) => Ok(s),
            other => Err(crate::Error::Protocol(format!(
                "expected Control, got {}", other.kind()
            ))),
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Payload::U64s(_) => "U64s",
            Payload::F32s(_) => "F32s",
            Payload::F64s(_) => "F64s",
            Payload::Cipher(_) => "Cipher",
            Payload::Seed(_) => "Seed",
            Payload::Bits(_) => "Bits",
            Payload::Control(_) => "Control",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes_per_variant() {
        assert_eq!(Payload::U64s(vec![0; 10]).wire_bytes(), 80);
        assert_eq!(Payload::F32s(vec![0.0; 10]).wire_bytes(), 40);
        assert_eq!(Payload::F64s(vec![0.0; 10]).wire_bytes(), 80);
        assert_eq!(Payload::Seed([0; 32]).wire_bytes(), 32);
        assert_eq!(Payload::Bits(vec![0; 4]).wire_bytes(), 32);
        assert_eq!(Payload::Control("go".into()).wire_bytes(), 2);
        assert_eq!(
            Payload::Cipher(vec![vec![0u8; 256], vec![0u8; 256]]).wire_bytes(),
            512
        );
    }

    #[test]
    fn unwrap_helpers_enforce_variant() {
        assert!(Payload::U64s(vec![1]).into_u64s().is_ok());
        assert!(Payload::U64s(vec![1]).into_f32s().is_err());
        assert!(Payload::Control("x".into()).into_control().is_ok());
        assert!(Payload::Seed([1; 32]).into_seed().is_ok());
    }
}
