//! Deterministic network simulator for the decentralized SPNN runtime.
//!
//! The paper's experiments (§6.4) sweep the network bandwidth from 100 Kbps
//! to 100 Mbps across machines; this environment is a single host, so the
//! parties talk over in-process channels and the simulator models the wire:
//!
//! * every message is **byte-accounted** from its payload type,
//! * each party carries a **virtual clock** (Lamport-style): wall-clock time
//!   between its netsim calls is accumulated as compute time, and a received
//!   message forwards the clock to
//!   `max(local, sender_depart + latency + bytes/bandwidth)`,
//! * per-link statistics (bytes, messages, per [`Phase`]) feed the
//!   experiment reports.
//!
//! Offline-phase traffic (trusted-dealer triples — the standard MPC
//! offline/online split, SecureML §IV) is byte-counted but does not delay
//! the online clock; Table 3 / Fig 8 report online epoch time, and the
//! offline bytes are reported separately by the benches.

mod payload;
mod port;
mod stats;

pub use payload::Payload;
pub use port::{Msg, NetPort};
pub use stats::NetStats;

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;

/// Party identifier within one simulated deployment.
pub type PartyId = usize;

/// Link characteristics applied to every edge of the mesh.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    /// Bandwidth in bits per second.
    pub bandwidth_bps: f64,
    /// One-way latency in seconds.
    pub latency_s: f64,
}

impl LinkSpec {
    /// The paper's default experiment setting: 100 Mbps.
    pub fn mbps100() -> Self {
        Self::from_mbps(100.0)
    }

    /// Local-area network (Fig 9a setting): 1 Gbps, 1 ms one-way.
    pub fn lan() -> Self {
        LinkSpec { bandwidth_bps: 1e9, latency_s: 0.001 }
    }

    pub fn from_mbps(mbps: f64) -> Self {
        LinkSpec { bandwidth_bps: mbps * 1e6, latency_s: 0.001 }
    }

    pub fn from_kbps(kbps: f64) -> Self {
        LinkSpec { bandwidth_bps: kbps * 1e3, latency_s: 0.001 }
    }

    /// Seconds to push `bytes` through the link (excluding latency).
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        (bytes as f64 * 8.0) / self.bandwidth_bps
    }
}

/// Message phase for accounting (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Input-independent preprocessing (dealer triples, key setup).
    Offline,
    /// The per-iteration critical path.
    Online,
}

/// Build a full mesh of simulated links between `names.len()` parties.
///
/// Returns one [`NetPort`] per party (move each into its thread) and the
/// shared [`NetStats`].
pub fn full_mesh(names: &[&str], spec: LinkSpec) -> (Vec<NetPort>, Arc<NetStats>) {
    let n = names.len();
    let stats = Arc::new(NetStats::new(names));
    // channel per ordered pair (i -> j)
    let mut txs: Vec<HashMap<PartyId, mpsc::Sender<Msg>>> =
        (0..n).map(|_| HashMap::new()).collect();
    let mut rxs: Vec<HashMap<PartyId, mpsc::Receiver<Msg>>> =
        (0..n).map(|_| HashMap::new()).collect();
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            let (tx, rx) = mpsc::channel();
            txs[i].insert(j, tx);
            rxs[j].insert(i, rx);
        }
    }
    let ports = txs
        .into_iter()
        .zip(rxs)
        .enumerate()
        .map(|(id, (tx, rx))| NetPort::new(id, names[id], spec, tx, rx, stats.clone()))
        .collect();
    (ports, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_math() {
        let s = LinkSpec::from_mbps(100.0);
        // 12.5 MB at 100 Mbps = 1 s
        assert!((s.transfer_time(12_500_000) - 1.0).abs() < 1e-9);
        let k = LinkSpec::from_kbps(100.0);
        assert!((k.transfer_time(12_500) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mesh_roundtrip_and_byte_accounting() {
        let (mut ports, stats) = full_mesh(&["A", "B"], LinkSpec::lan());
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        let h = std::thread::spawn(move || {
            let p = b.recv(0).unwrap();
            match p {
                Payload::U64s(v) => {
                    assert_eq!(v, vec![1, 2, 3]);
                    b.send(0, Payload::F32s(vec![9.0])).unwrap();
                }
                _ => panic!("wrong payload"),
            }
            b
        });
        a.send(1, Payload::U64s(vec![1, 2, 3])).unwrap();
        match a.recv(1).unwrap() {
            Payload::F32s(v) => assert_eq!(v, vec![9.0]),
            _ => panic!("wrong payload"),
        }
        let mut b = h.join().unwrap();
        // bytes: 3*8 + header one way, 4 + header the other
        let sent_ab = stats.bytes_between(0, 1);
        let sent_ba = stats.bytes_between(1, 0);
        assert_eq!(sent_ab, 24 + Payload::HEADER_BYTES);
        assert_eq!(sent_ba, 4 + Payload::HEADER_BYTES);
        assert!(a.now() > 0.0 && b.now() > 0.0);
    }

    #[test]
    fn virtual_clock_includes_bandwidth_delay() {
        // 1 MB at 1 Mbps = 8 s simulated, instant in wall time
        let spec = LinkSpec { bandwidth_bps: 1e6, latency_s: 0.0 };
        let (mut ports, _stats) = full_mesh(&["A", "B"], spec);
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        let h = std::thread::spawn(move || {
            a.send(1, Payload::U64s(vec![0u64; 125_000])).unwrap();
            a
        });
        b.recv(0).unwrap();
        let _ = h.join().unwrap();
        assert!(b.now() >= 8.0, "clock {} missing transfer delay", b.now());
        assert!(b.now() < 9.0, "clock {} wildly over", b.now());
    }

    #[test]
    fn offline_phase_skips_clock_delay() {
        let spec = LinkSpec { bandwidth_bps: 1e3, latency_s: 0.0 }; // 1 kbps!
        let (mut ports, stats) = full_mesh(&["A", "B"], spec);
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        let h = std::thread::spawn(move || {
            a.send_phase(1, Payload::U64s(vec![0u64; 10_000]), Phase::Offline)
                .unwrap();
            a
        });
        b.recv(0).unwrap();
        h.join().unwrap();
        assert!(b.now() < 1.0, "offline message delayed the online clock");
        assert!(stats.bytes_phase(Phase::Offline) > 10_000);
        assert_eq!(stats.bytes_phase(Phase::Online), 0);
    }

    #[test]
    fn latency_counts_once_per_message() {
        let spec = LinkSpec { bandwidth_bps: 1e12, latency_s: 0.5 };
        let (mut ports, _) = full_mesh(&["A", "B"], spec);
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        let h = std::thread::spawn(move || {
            for _ in 0..4 {
                a.send(1, Payload::U64s(vec![1])).unwrap();
            }
            a
        });
        for _ in 0..4 {
            b.recv(0).unwrap();
        }
        h.join().unwrap();
        // messages pipeline: sender stamps all ~immediately, each arrival is
        // depart+0.5 — the clock lands near 0.5, NOT 2.0
        assert!(b.now() >= 0.5 && b.now() < 0.7, "clock {}", b.now());
    }

    #[test]
    fn unknown_peer_errors() {
        let (mut ports, _) = full_mesh(&["A"], LinkSpec::lan());
        let mut a = ports.pop().unwrap();
        assert!(a.send(5, Payload::U64s(vec![])).is_err());
    }
}
