//! Deterministic network simulator for the decentralized SPNN runtime.
//!
//! The paper's experiments (§6.4) sweep the network bandwidth from 100 Kbps
//! to 100 Mbps across machines; this environment is a single host, so the
//! parties talk over in-process channels and the simulator models the wire:
//!
//! * every message is **byte-accounted** from its payload type,
//! * each party carries a **virtual clock** (Lamport-style): wall-clock time
//!   between its netsim calls is accumulated as compute time, and a received
//!   message forwards the clock to
//!   `max(local, sender_depart + latency + bytes/bandwidth)`,
//! * each party's **uplink is a shared link**: concurrent online sends
//!   serialize (`depart = max(clock, uplink_free)`), so back-to-back bulk
//!   messages contend for bandwidth instead of each seeing the full link,
//! * per-link statistics (bytes, messages, per [`Phase`]) feed the
//!   experiment reports.
//!
//! Offline-phase traffic (trusted-dealer triples — the standard MPC
//! offline/online split, SecureML §IV) is byte-counted but does not delay
//! the online clock; Table 3 / Fig 8 report online epoch time, and the
//! offline bytes are reported separately by the benches.
//!
//! Pipelined protocols tag messages with a batch / stream id and receive
//! them out of order through [`NetPort::recv_tagged`] (per-peer reorder
//! buffers, FIFO within a tag); blocked wall time never counts as compute
//! and each message's arrival stamp depends only on its own (queued)
//! departure and size, so work done ahead of demand is absorbed into the
//! wait for slower remote results (overlap credit).

mod payload;
mod port;
mod stats;

pub use payload::Payload;
pub use port::{Msg, NetPort, NO_TAG};
pub use stats::{merge_stage_rows, NetStats, StageRow};

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;

/// Party identifier within one simulated deployment.
pub type PartyId = usize;

/// Link characteristics applied to every edge of the mesh.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    /// Bandwidth in bits per second.
    pub bandwidth_bps: f64,
    /// One-way latency in seconds.
    pub latency_s: f64,
}

impl LinkSpec {
    /// The paper's default experiment setting: 100 Mbps.
    pub fn mbps100() -> Self {
        Self::from_mbps(100.0)
    }

    /// Local-area network (Fig 9a setting): 1 Gbps, 1 ms one-way.
    pub fn lan() -> Self {
        LinkSpec { bandwidth_bps: 1e9, latency_s: 0.001 }
    }

    pub fn from_mbps(mbps: f64) -> Self {
        LinkSpec { bandwidth_bps: mbps * 1e6, latency_s: 0.001 }
    }

    pub fn from_kbps(kbps: f64) -> Self {
        LinkSpec { bandwidth_bps: kbps * 1e3, latency_s: 0.001 }
    }

    /// Seconds to push `bytes` through the link (excluding latency).
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        (bytes as f64 * 8.0) / self.bandwidth_bps
    }
}

/// Message phase for accounting (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Input-independent preprocessing (dealer triples, key setup).
    Offline,
    /// The per-iteration critical path.
    Online,
}

/// Build a full mesh of simulated links between `names.len()` parties.
///
/// Returns one [`NetPort`] per party (move each into its thread) and the
/// shared [`NetStats`].
pub fn full_mesh(names: &[&str], spec: LinkSpec) -> (Vec<NetPort>, Arc<NetStats>) {
    let n = names.len();
    let stats = Arc::new(NetStats::new(names));
    // channel per ordered pair (i -> j)
    let mut txs: Vec<HashMap<PartyId, mpsc::Sender<Msg>>> =
        (0..n).map(|_| HashMap::new()).collect();
    let mut rxs: Vec<HashMap<PartyId, mpsc::Receiver<Msg>>> =
        (0..n).map(|_| HashMap::new()).collect();
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            let (tx, rx) = mpsc::channel();
            txs[i].insert(j, tx);
            rxs[j].insert(i, rx);
        }
    }
    let ports = txs
        .into_iter()
        .zip(rxs)
        .enumerate()
        .map(|(id, (tx, rx))| NetPort::new(id, names[id], spec, tx, rx, stats.clone()))
        .collect();
    (ports, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_math() {
        let s = LinkSpec::from_mbps(100.0);
        // 12.5 MB at 100 Mbps = 1 s
        assert!((s.transfer_time(12_500_000) - 1.0).abs() < 1e-9);
        let k = LinkSpec::from_kbps(100.0);
        assert!((k.transfer_time(12_500) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mesh_roundtrip_and_byte_accounting() {
        let (mut ports, stats) = full_mesh(&["A", "B"], LinkSpec::lan());
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        let h = std::thread::spawn(move || {
            let p = b.recv(0).unwrap();
            match p {
                Payload::U64s(v) => {
                    assert_eq!(v, vec![1, 2, 3]);
                    b.send(0, Payload::F32s(vec![9.0])).unwrap();
                }
                _ => panic!("wrong payload"),
            }
            b
        });
        a.send(1, Payload::U64s(vec![1, 2, 3])).unwrap();
        match a.recv(1).unwrap() {
            Payload::F32s(v) => assert_eq!(v, vec![9.0]),
            _ => panic!("wrong payload"),
        }
        let mut b = h.join().unwrap();
        // bytes: 3*8 + header one way, 4 + header the other
        let sent_ab = stats.bytes_between(0, 1);
        let sent_ba = stats.bytes_between(1, 0);
        assert_eq!(sent_ab, 24 + Payload::HEADER_BYTES);
        assert_eq!(sent_ba, 4 + Payload::HEADER_BYTES);
        assert!(a.now() > 0.0 && b.now() > 0.0);
    }

    #[test]
    fn virtual_clock_includes_bandwidth_delay() {
        // 1 MB at 1 Mbps = 8 s simulated, instant in wall time
        let spec = LinkSpec { bandwidth_bps: 1e6, latency_s: 0.0 };
        let (mut ports, _stats) = full_mesh(&["A", "B"], spec);
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        let h = std::thread::spawn(move || {
            a.send(1, Payload::U64s(vec![0u64; 125_000])).unwrap();
            a
        });
        b.recv(0).unwrap();
        let _ = h.join().unwrap();
        assert!(b.now() >= 8.0, "clock {} missing transfer delay", b.now());
        assert!(b.now() < 9.0, "clock {} wildly over", b.now());
    }

    #[test]
    fn offline_phase_skips_clock_delay() {
        let spec = LinkSpec { bandwidth_bps: 1e3, latency_s: 0.0 }; // 1 kbps!
        let (mut ports, stats) = full_mesh(&["A", "B"], spec);
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        let h = std::thread::spawn(move || {
            a.send_phase(1, Payload::U64s(vec![0u64; 10_000]), Phase::Offline)
                .unwrap();
            a
        });
        b.recv(0).unwrap();
        h.join().unwrap();
        assert!(b.now() < 1.0, "offline message delayed the online clock");
        assert!(stats.bytes_phase(Phase::Offline) > 10_000);
        assert_eq!(stats.bytes_phase(Phase::Online), 0);
    }

    #[test]
    fn latency_counts_once_per_message() {
        let spec = LinkSpec { bandwidth_bps: 1e12, latency_s: 0.5 };
        let (mut ports, _) = full_mesh(&["A", "B"], spec);
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        let h = std::thread::spawn(move || {
            for _ in 0..4 {
                a.send(1, Payload::U64s(vec![1])).unwrap();
            }
            a
        });
        for _ in 0..4 {
            b.recv(0).unwrap();
        }
        h.join().unwrap();
        // messages pipeline: sender stamps all ~immediately, each arrival is
        // depart+0.5 — the clock lands near 0.5, NOT 2.0
        assert!(b.now() >= 0.5 && b.now() < 0.7, "clock {}", b.now());
    }

    #[test]
    fn unknown_peer_errors() {
        let (mut ports, _) = full_mesh(&["A"], LinkSpec::lan());
        let mut a = ports.pop().unwrap();
        assert!(a.send(5, Payload::U64s(vec![])).is_err());
    }

    #[test]
    fn tagged_out_of_order_reassembles_in_order_per_tag() {
        // property: for any interleaving of tagged streams on one link
        // (per-tag send order preserved, cross-tag order arbitrary), the
        // receiver can consume the tags in any order and sees each tag's
        // messages in their original sequence.
        use crate::rng::{Pcg64, Rng64};
        const TAGS: u64 = 4;
        const PER_TAG: u64 = 3;
        for trial in 0..8u64 {
            let (mut ports, _) = full_mesh(&["A", "B"], LinkSpec::lan());
            let mut b = ports.pop().unwrap();
            let mut a = ports.pop().unwrap();
            // build a random interleaving: next-seq cursor per tag
            let mut rng = Pcg64::seed_from_u64(1000 + trial);
            let mut next = vec![0u64; TAGS as usize];
            let mut sent = 0;
            while sent < TAGS * PER_TAG {
                let t = (rng.next_u64() % TAGS) as usize;
                if next[t] < PER_TAG {
                    a.send_tagged(1, t as u64, Payload::U64s(vec![t as u64, next[t]]))
                        .unwrap();
                    next[t] += 1;
                    sent += 1;
                }
            }
            // consume tags in a rotated order, sequences must reassemble
            for k in 0..TAGS {
                let tag = (trial + k) % TAGS;
                for seq in 0..PER_TAG {
                    let got = b.recv_tagged(0, tag).unwrap().into_u64s().unwrap();
                    assert_eq!(got, vec![tag, seq], "trial {trial} tag {tag}");
                }
            }
        }
    }

    #[test]
    fn recv_drains_reorder_buffer_in_arrival_order() {
        let (mut ports, _) = full_mesh(&["A", "B"], LinkSpec::lan());
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        a.send_tagged(1, 5, Payload::U64s(vec![5])).unwrap();
        a.send_tagged(1, 6, Payload::U64s(vec![6])).unwrap();
        a.send_tagged(1, 7, Payload::U64s(vec![7])).unwrap();
        // pulling tag 7 first parks tags 5 and 6 in the reorder buffer
        assert_eq!(b.recv_tagged(0, 7).unwrap().into_u64s().unwrap(), vec![7]);
        // untagged recv drains buffered messages in arrival order
        assert_eq!(b.recv(0).unwrap().into_u64s().unwrap(), vec![5]);
        assert_eq!(b.recv(0).unwrap().into_u64s().unwrap(), vec![6]);
    }

    #[test]
    fn try_recv_tagged_polls_without_blocking() {
        let (mut ports, _) = full_mesh(&["A", "B"], LinkSpec::lan());
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        // nothing sent yet: poll returns None immediately
        assert!(b.try_recv_tagged(0, 3).unwrap().is_none());
        a.send_tagged(1, 4, Payload::U64s(vec![4])).unwrap();
        a.send_tagged(1, 3, Payload::U64s(vec![3])).unwrap();
        // tag 3 is behind tag 4 in the channel: the poll parks 4 and
        // delivers 3; the parked message is still delivered later
        assert_eq!(
            b.try_recv_tagged(0, 3).unwrap().unwrap().into_u64s().unwrap(),
            vec![3]
        );
        assert!(b.try_recv_tagged(0, 9).unwrap().is_none());
        assert_eq!(b.recv_tagged(0, 4).unwrap().into_u64s().unwrap(), vec![4]);
        // dropped sender surfaces as a disconnect error, not a hang
        drop(a);
        assert!(b.try_recv_tagged(0, 9).is_err());
    }

    #[test]
    fn recv_timeout_reports_endpoints_tag_stage_and_queues() {
        let (mut ports, _) = full_mesh(&["alice", "bob"], LinkSpec::lan());
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        a.send_tagged(1, 7, Payload::U64s(vec![1])).unwrap();
        b.set_recv_timeout(std::time::Duration::from_millis(50));
        b.set_stage("bwd");
        let err = b.recv_tagged(0, 9).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("bob"), "{msg}");
        assert!(msg.contains("alice"), "{msg}");
        assert!(msg.contains("tag 9"), "{msg}");
        assert!(msg.contains("bwd"), "{msg}");
        assert!(msg.contains("1 message(s)"), "{msg}");
        assert!(msg.contains("[7]"), "{msg}");
    }

    #[test]
    fn out_of_order_clock_uses_per_message_arrival() {
        // a big tag-2 message consumed first must not drag the clock past
        // the earlier small tag-1 message's own arrival: arrival stamps are
        // per message (departure + size), not per consumption point.
        let spec = LinkSpec { bandwidth_bps: 1e6, latency_s: 0.0 };
        let (mut ports, _) = full_mesh(&["A", "B"], spec);
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        // small first (arrives ~0 s), then 1 MB at 1 Mbps = 8 s
        a.send_tagged(1, 1, Payload::U64s(vec![1])).unwrap();
        a.send_tagged(1, 2, Payload::U64s(vec![0u64; 125_000])).unwrap();
        b.recv_tagged(0, 2).unwrap();
        let after_big = b.now();
        assert!((8.0..9.0).contains(&after_big), "clock {after_big}");
        assert_eq!(b.recv_tagged(0, 1).unwrap().into_u64s().unwrap(), vec![1]);
        let after_small = b.now();
        // the small message's own arrival is ~0 s: consuming it after the
        // big one must not advance the clock further
        assert!(
            (after_small - after_big).abs() < 1e-6,
            "small message re-advanced the clock: {after_small} vs {after_big}"
        );
    }

    #[test]
    fn uplink_contention_serializes_concurrent_sends() {
        // two 1 MB online messages pushed back to back share the sender's
        // uplink: the second departs when the first finishes, so arrivals
        // land at ~8 s and ~16 s — not both at 8 s.
        let spec = LinkSpec { bandwidth_bps: 1e6, latency_s: 0.0 };
        let (mut ports, _) = full_mesh(&["A", "B", "C"], spec);
        let mut c = ports.pop().unwrap();
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        let blob = || Payload::U64s(vec![0u64; 125_000]); // 1 MB
        a.send(1, blob()).unwrap();
        a.send(2, blob()).unwrap(); // different peer, same shared uplink
        b.recv(0).unwrap();
        assert!((8.0..9.0).contains(&b.now()), "first transfer: {}", b.now());
        c.recv(0).unwrap();
        assert!((16.0..17.0).contains(&c.now()), "second transfer queued: {}", c.now());
    }

    #[test]
    fn uplink_contention_skips_offline_and_resets() {
        // offline traffic neither queues on the uplink nor occupies it
        let spec = LinkSpec { bandwidth_bps: 1e6, latency_s: 0.0 };
        let (mut ports, _) = full_mesh(&["A", "B"], spec);
        let mut b = ports.pop().unwrap();
        let mut a = ports.pop().unwrap();
        a.send_phase(1, Payload::U64s(vec![0u64; 125_000]), Phase::Offline).unwrap();
        a.send(1, Payload::U64s(vec![1])).unwrap();
        b.recv(0).unwrap();
        b.recv(0).unwrap();
        assert!(b.now() < 1.0, "offline send occupied the uplink: {}", b.now());
        // reset_clock clears the contention cursor along with the clock
        a.reset_clock();
        a.send(1, Payload::U64s(vec![2])).unwrap();
        b.reset_clock();
        b.recv(0).unwrap();
        assert!(b.now() < 1.0, "uplink cursor survived reset: {}", b.now());
    }
}
