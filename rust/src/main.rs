//! `spnn` — the SPNN coordinator CLI (leader entrypoint).
//!
//! Subcommands:
//!   train   — run one protocol end-to-end on a synthetic benchmark
//!             (all parties in this process; netsim or loopback TCP)
//!   launch  — run one protocol genuinely decentralized: host the session
//!             and spawn every role as its own OS process over TCP
//!   party   — join a hosted session as one role (multi-terminal /
//!             multi-host deployments)
//!   serve   — train, then keep the parties resident and answer streaming
//!             inference requests on a TCP front door (in-process parties
//!             by default; --launch for one OS process per role)
//!   infer   — client for `spnn serve`: score rows of the held-out table
//!             (--local runs an in-process reference serve session)
//!   repro   — regenerate one (or all) of the paper's tables/figures
//!   attack  — run the Table 2 property-inference attack standalone
//!   info    — list loaded AOT artifacts
//!
//! Hand-rolled argument parsing (no clap in the offline vendor set), and a
//! boxed error alias instead of anyhow for the same reason.

use std::collections::HashMap;

use spnn::attack::{property_attack, AttackOpts};
use spnn::config::{CompressCfg, TrainConfig, TransportKind, DISTRESS, FRAUD};
use spnn::exp::{self, ExpOpts};
use spnn::protocols;
use spnn::runtime::Engine;
use spnn::serve::{self, ServeOpts};
use spnn::transport::auth::Psk;
use spnn::transport::runner::{run_launch, run_party, run_serve, LaunchOpts};
use spnn::transport::session::SessionSpec;

type CliError = Box<dyn std::error::Error>;
type CliResult<T> = std::result::Result<T, CliError>;

fn err(msg: String) -> CliError {
    msg.into()
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> CliResult<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..]);
    // --trace-out works on every verb: open the JSONL sink before any
    // party thread spawns so the whole run lands in one trace session
    let tracing = if let Some(path) = flags.get("trace-out") {
        spnn::obs::trace::init(path)?;
        spnn::obs::trace::set_sid(spnn::obs::trace::alloc_sid());
        true
    } else {
        false
    };
    let res = dispatch(cmd, &flags, args);
    if tracing {
        spnn::obs::trace::close();
    }
    res
}

fn dispatch(cmd: &str, flags: &HashMap<String, String>, args: &[String]) -> CliResult<()> {
    match cmd {
        "train" => cmd_train(flags),
        "launch" => cmd_launch(flags),
        "party" => cmd_party(flags),
        "serve" => cmd_serve(flags),
        "infer" => cmd_infer(flags),
        "repro" => cmd_repro(&args[1..], flags),
        "attack" => cmd_attack(flags),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            Err(err(format!("unknown command {other:?}")))
        }
    }
}

fn print_usage() {
    eprintln!(
        "spnn — Scalable and Privacy-Preserving DNN (TIST 2021 reproduction)

USAGE:
  spnn train  [--protocol nn|splitnn|secureml|spnn-ss|spnn-he]
              [--dataset fraud|distress] [--rows N] [--epochs E]
              [--batch B] [--holders K] [--mbps M] [--sgld] [--lr F]
              [--paillier-bits N] [--slot-bits N] [--threads T] [--seed S]
              [--pipeline-depth D] [--staleness S]
              [--transport netsim|tcp|uds]
              --staleness lets weight updates land up to S batches late
              on a seed-derived schedule (bounded-staleness asynchrony):
              batches overlap across the update dependency and across
              epoch boundaries; 0 (default) is strict lock-step,
              bit-identical to the synchronous transcript
              [--compress [dct:|sketch:]K]  K = kept-column ratio in (0,1]
              (write the dot: 0.5) or an absolute column total >= holders;
              every holder projects its private feature block through a
              seeded orthogonal basis before any encryption or sharing
              [--checkpoint-dir DIR] [--from-checkpoint [DIR]]
              [--checkpoint-keep N]
              --checkpoint-dir writes each role's private parameter
              blocks (plus RNG/nonce cursors) at the end of training;
              --from-checkpoint warm-starts from those blocks with zero
              epochs — bit-identical to the run that wrote them;
              --checkpoint-keep rotates N checkpoint generations per
              role and prunes older ones atomically
  spnn launch [same training flags as train]
              [--listen HOST:PORT] [--no-spawn] [--psk-file PATH]
              [--chaos ROLE:N]
              runs every role as its own OS process over real TCP;
              --no-spawn prints the `spnn party` commands instead of
              forking (join them from other terminals or hosts);
              --psk-file authenticates every role claim against a shared
              key; --chaos makes ROLE sever a connection after N frames
              (reconnect drill)
  spnn party  --role <name> --connect HOST:PORT [--bind HOST]
              [--psk-file PATH] [--chaos-kill N]
              [--checkpoint-dir DIR] [--from-checkpoint [DIR]]
              [--checkpoint-keep N]
              join a hosted session as one role (e.g. server, dealer,
              holder0, holder1 — role names come from the protocol);
              the checkpoint dir holds THIS role's private blocks and
              its crash-durable relink journal, so a killed party can
              relaunch and rejoin with exactly-once delivery
  spnn serve  [same training flags as train] [--listen HOST:PORT]
              [--coalesce N] [--serve-depth D] [--serve-requests N]
              [--request-timeout MS] [--max-queue N]
              [--metrics-listen HOST:PORT]
              [--launch [--rendezvous HOST:PORT] [--no-spawn]]
              [--replicas N] [--fleet ADDR,ADDR,...]
              [--door-psk-file PATH] [--reply-timeout S]
              --request-timeout fails requests that sat queued longer
              than MS milliseconds (0 = never, the default); --max-queue
              rejects requests beyond N queued per round before any
              crypto runs (0 = unbounded); --metrics-listen exposes the
              live Prometheus-text metrics endpoint (request latency
              p50/p95/p99, queue depth, per-stage crypto timings)
              train, then stay resident: a TCP front door coalesces
              inference requests into crypto-amortized batches the
              trained parties answer; --serve-requests N exits after N
              requests (smoke tests); --launch runs every role as its
              own OS process (workers join via `spnn party` as usual);
              --replicas runs N in-process serve sessions behind one
              load-balancing door (pair with --from-checkpoint so each
              warm-starts instead of retraining); --fleet skips training
              and routes to downstream serve front doors, failing over
              when a replica dies and answering `replica unavailable`
              once none are left; --door-psk-file demands PSK client
              auth at the door (and keys downstream --fleet dials)
  spnn infer  --connect HOST:PORT [--ids 1,2,3 | --count N [--offset K]]
              [--repeat R] [--psk-file PATH] [--reply-timeout S]
              | --local [training flags]
              score rows of the held-out table against a running
              `spnn serve` (prints the scores, per-request wall-clock
              latency with a min/mean/max summary, and a bit-exact
              infer_digest); --repeat sends the same request R times
              (latency sampling); --psk-file answers a keyed door's auth
              challenge; --reply-timeout bounds the wait for scores
              (default: wait out training); --local trains in this
              process instead and scores through an in-process serve
              session (the parity reference the serve smoke test
              compares against)
  spnn repro  <table1|table2|table3|fig5|fig67|fig8|fig9|all>
              [--scale F] [--quick] [--out FILE]
  spnn attack [--rows N] [--epochs E] [--seed S]
  spnn info

Every command also takes --trace-out FILE: append a structured JSONL
event trace (spans, serve round lifecycle, epoch markers) for offline
analysis; deterministic under netsim modulo timestamps.
"
    );
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            out.insert(key.to_string(), val);
        }
        i += 1;
    }
    out
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags
        .get(key)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Assemble the canonical session config from the shared training flags —
/// `train` and `launch` build the exact same [`SessionSpec`], which is
/// what makes their `weight_digest`s comparable.
fn spec_from_flags(flags: &HashMap<String, String>) -> CliResult<SessionSpec> {
    let proto = flags.get("protocol").map(|s| s.as_str()).unwrap_or("spnn-ss");
    let dataset = flags.get("dataset").map(|s| s.as_str()).unwrap_or("fraud");
    if !matches!(dataset, "fraud" | "distress") {
        return Err(err(format!("unknown dataset {dataset:?}")));
    }
    if protocols::by_name(proto).is_none() {
        return Err(err(format!("unknown protocol {proto:?}")));
    }
    let rows = flag(flags, "rows", if dataset == "fraud" { 12_000 } else { 3_672 });
    let seed = flag(flags, "seed", 7u64);
    // --checkpoint-dir DIR: write per-role checkpoints at the end of
    // training. --from-checkpoint [DIR]: warm-start (zero epochs, load
    // blocks from DIR, or from --checkpoint-dir when given bare).
    let warm = flags.contains_key("from-checkpoint");
    let ckpt_dir = match flags.get("from-checkpoint") {
        Some(v) if v != "true" => Some(v.clone()),
        _ => flags.get("checkpoint-dir").cloned(),
    };
    if warm && ckpt_dir.is_none() {
        return Err(err(
            "--from-checkpoint needs a directory (inline or via --checkpoint-dir)".into(),
        ));
    }
    let tc = TrainConfig {
        batch: flag(flags, "batch", 1024),
        // a warm start replays checkpointed blocks instead of training:
        // zero epochs through the unchanged coordinator protocol, so all
        // pre-epoch setup (key broadcast, init sharing) still runs
        epochs: if warm { 0 } else { flag(flags, "epochs", 3) },
        sgld: flags.contains_key("sgld"),
        seed,
        lr_override: flags.get("lr").and_then(|v| v.parse().ok()),
        paillier_bits: flag(flags, "paillier-bits", 1024),
        paillier_short_exp: true,
        sgld_noise: None,
        slot_bits: flag(flags, "slot-bits", spnn::paillier::pack::DEFAULT_SLOT_BITS),
        exec_threads: flag(flags, "threads", 0usize),
        pipeline_depth: flag(flags, "pipeline-depth", 1usize),
        staleness: flag(flags, "staleness", 0usize),
        transport: flags
            .get("transport")
            .map(|v| TransportKind::parse(v).ok_or_else(|| err(format!("unknown transport {v:?}"))))
            .transpose()?
            .unwrap_or(TransportKind::Netsim),
        psk_file: flags.get("psk-file").cloned(),
        compress: flags
            .get("compress")
            .map(|v| {
                CompressCfg::parse(v).ok_or_else(|| {
                    err(format!(
                        "bad --compress {v:?} (want [dct:|sketch:]<ratio in (0,1] \
                         with a dot, or columns >= 1>)"
                    ))
                })
            })
            .transpose()?,
        checkpoint_dir: ckpt_dir,
        warm_start: warm,
        checkpoint_keep: flags.get("checkpoint-keep").and_then(|v| v.parse().ok()),
    };
    Ok(SessionSpec {
        protocol: proto.to_string(),
        dataset: dataset.to_string(),
        rows,
        holders: flag(flags, "holders", 2usize),
        mbps: flag(flags, "mbps", 100.0),
        tc,
        serve: None,
    })
}

fn print_report(rep: &spnn::protocols::TrainReport) {
    println!("{}", rep.summary());
    println!("train losses: {:?}", rep.train_losses);
    println!("epoch times (sim s): {:?}", rep.epoch_times);
    // Table-3b style per-stage traffic breakdown; in a `spnn launch` run
    // the rows are merged from every party process's shipped counters
    let breakdown = spnn::exp::report::stage_breakdown("traffic by stage", &rep.stages);
    if !breakdown.is_empty() {
        println!("{breakdown}");
    }
    // process-global span histograms: where the wall-clock went, by
    // layer (crypto, pipeline, transport) — workers in a `spnn launch`
    // run ship their registries home, so this too covers the whole mesh
    let timings = spnn::obs::time_table_md("time by stage");
    if !timings.is_empty() {
        println!("{timings}");
    }
    // machine-readable digest line (scripted parity checks grep this)
    println!("weight_digest=0x{:016x}", rep.weight_digest);
}

fn cmd_train(flags: &HashMap<String, String>) -> CliResult<()> {
    let spec = spec_from_flags(flags)?;
    let (cfg, train, test) = spec.datasets()?;
    let trainer = protocols::by_name(&spec.protocol)
        .ok_or_else(|| err(format!("unknown protocol {:?}", spec.protocol)))?;
    eprintln!(
        "training {} on {} ({} train / {} test rows, {} holders, {} transport)",
        spec.protocol,
        spec.dataset,
        train.len(),
        test.len(),
        spec.holders,
        spec.tc.transport.name(),
    );
    let rep = trainer.train(cfg, &spec.tc, spec.link(), &train, &test, spec.holders)?;
    print_report(&rep);
    Ok(())
}

fn cmd_launch(flags: &HashMap<String, String>) -> CliResult<()> {
    let spec = spec_from_flags(flags)?;
    let chaos = flags
        .get("chaos")
        .map(|v| -> CliResult<(String, u64)> {
            let (role, n) = v
                .split_once(':')
                .ok_or_else(|| err(format!("--chaos wants ROLE:N, got {v:?}")))?;
            let n: u64 =
                n.parse().map_err(|_| err(format!("bad --chaos frame count {n:?}")))?;
            if n == 0 {
                return Err(err("--chaos frame count must be >= 1".into()));
            }
            Ok((role.to_string(), n))
        })
        .transpose()?;
    let opts = LaunchOpts {
        listen: flags.get("listen").cloned().unwrap_or_else(|| "127.0.0.1:0".into()),
        spawn: !flags.contains_key("no-spawn"),
        chaos,
    };
    eprintln!(
        "launching {} on {} decentralized ({} holders, multi-process TCP{})",
        spec.protocol,
        spec.dataset,
        spec.holders,
        if spec.tc.psk_file.is_some() { ", PSK-authenticated" } else { "" },
    );
    let rep = run_launch(&spec, &opts)?;
    print_report(&rep);
    Ok(())
}

fn cmd_party(flags: &HashMap<String, String>) -> CliResult<()> {
    let role = flags.get("role").ok_or_else(|| err("party needs --role <name>".into()))?;
    let connect = flags
        .get("connect")
        .ok_or_else(|| err("party needs --connect HOST:PORT".into()))?;
    let bind = flags.get("bind").map(|s| s.as_str()).unwrap_or("127.0.0.1");
    let psk = flags
        .get("psk-file")
        .map(|p| Psk::from_file(std::path::Path::new(p)))
        .transpose()?;
    let chaos_kill = flags
        .get("chaos-kill")
        .map(|v| v.parse::<u64>().map_err(|_| err(format!("bad --chaos-kill count {v:?}"))))
        .transpose()?;
    if chaos_kill == Some(0) {
        return Err(err("--chaos-kill count must be >= 1 (the kill fires after N frames)".into()));
    }
    // the checkpoint dir is process-local (it holds THIS role's private
    // blocks); whether the session warm-starts rides the config broadcast
    let ckpt_dir = match flags.get("from-checkpoint") {
        Some(v) if v != "true" => Some(v.clone()),
        _ => flags.get("checkpoint-dir").cloned(),
    };
    let ckpt_keep = flags.get("checkpoint-keep").and_then(|v| v.parse().ok());
    run_party(connect, role, bind, psk.as_ref(), chaos_kill, ckpt_dir.as_deref(), ckpt_keep)?;
    Ok(())
}

/// The serve knobs, defaulting to [`ServeOpts::default`] — one source of
/// truth shared by `spnn serve` and the `spnn infer --local` parity
/// reference (divergent defaults would silently break the parity check
/// for batching-sensitive protocols).
fn serve_opts_from_flags(flags: &HashMap<String, String>) -> ServeOpts {
    let d = ServeOpts::default();
    ServeOpts {
        coalesce: flag(flags, "coalesce", d.coalesce),
        depth: flag(flags, "serve-depth", d.depth),
        request_timeout_ms: flag(flags, "request-timeout", d.request_timeout_ms),
        max_queue: flag(flags, "max-queue", d.max_queue),
    }
}

fn cmd_serve(flags: &HashMap<String, String>) -> CliResult<()> {
    let max_requests = flag(flags, "serve-requests", 0usize);
    let listen = flags
        .get("listen")
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:7450".into());
    let listener = std::net::TcpListener::bind(&listen)
        .map_err(|e| err(format!("bind front door {listen}: {e}")))?;
    let addr = listener.local_addr().map_err(|e| err(format!("{e}")))?;
    if let Some(maddr) = flags.get("metrics-listen") {
        let ml = std::net::TcpListener::bind(maddr)
            .map_err(|e| err(format!("bind metrics endpoint {maddr}: {e}")))?;
        let got = ml.local_addr().map_err(|e| err(format!("{e}")))?;
        eprintln!("spnn serve: Prometheus metrics endpoint on http://{got}/metrics");
        let _exporter = spnn::obs::prom::spawn_exporter(ml);
    }
    let door_psk = flags
        .get("door-psk-file")
        .map(|p| Psk::from_file(std::path::Path::new(p)))
        .transpose()?;
    let reply_timeout = flags
        .get("reply-timeout")
        .map(|v| {
            v.parse::<u64>().map_err(|_| err(format!("bad --reply-timeout seconds {v:?}")))
        })
        .transpose()?
        .map(std::time::Duration::from_secs);
    if let Some(list) = flags.get("fleet") {
        // pure router mode: no training in this process — a front door
        // load-balancing over downstream `spnn serve` replicas, failing
        // over when one dies
        let addrs: Vec<String> = list
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect();
        if addrs.is_empty() {
            return Err(err("--fleet wants a comma-separated list of serve addresses".into()));
        }
        eprintln!(
            "spnn serve: fleet router on {addr} over {} remote replica(s): {}",
            addrs.len(),
            addrs.join(", "),
        );
        let mut fleet = serve::fleet::Fleet::new(
            addrs
                .into_iter()
                .map(|a| (a.clone(), serve::fleet::Backend::remote(a)))
                .collect(),
        );
        fleet.connect_timeout =
            std::time::Duration::from_secs(flag(flags, "connect-timeout", 10u64));
        fleet.reply_timeout = reply_timeout;
        fleet.downstream_psk = door_psk.clone();
        serve::fleet::run_door(listener, fleet, max_requests, door_psk)?;
        return Ok(());
    }
    let mut spec = spec_from_flags(flags)?;
    let opts = serve_opts_from_flags(flags);
    spec.serve = Some(opts.clone());
    let replicas = flag(flags, "replicas", 1usize).max(1);
    eprintln!(
        "spnn serve: training {} on {} ({} rows, {} holders), then serving the \
         held-out table on {addr} (coalesce {}, depth {}{}{})",
        spec.protocol,
        spec.dataset,
        spec.rows,
        spec.holders,
        opts.coalesce,
        opts.depth,
        if replicas > 1 { format!(", {replicas} replicas") } else { String::new() },
        if max_requests > 0 {
            format!(", exiting after {max_requests} request(s)")
        } else {
            String::new()
        },
    );
    let rep = if flags.contains_key("launch") {
        if replicas > 1 {
            return Err(err(
                "--replicas needs in-process mode; for multi-process fleets point a \
                 `spnn serve --fleet` router at N independent serves instead"
                    .into(),
            ));
        }
        // one OS process per role: host the rendezvous here, front door
        // feeds the coordinator's request queue
        let (tx, rx) = std::sync::mpsc::channel();
        let lopts = LaunchOpts {
            listen: flags
                .get("rendezvous")
                .cloned()
                .unwrap_or_else(|| "127.0.0.1:0".into()),
            spawn: !flags.contains_key("no-spawn"),
            chaos: None,
        };
        let spec2 = spec.clone();
        let host = std::thread::spawn(move || run_serve(&spec2, &lopts, rx));
        let scorer: serve::frontdoor::Scorer =
            std::sync::Arc::new(move |rows: &[u32]| serve::request_scores(&tx, rows));
        serve::frontdoor::serve_clients(listener, scorer, max_requests, door_psk)?;
        host.join().map_err(|_| err("serve host panicked".into()))??
    } else {
        // in-process parties over the selected transport
        let (cfg, train, test) = spec.datasets()?;
        let mk = || {
            protocols::by_name(&spec.protocol)
                .ok_or_else(|| err(format!("unknown protocol {:?}", spec.protocol)))
        };
        if replicas > 1 {
            // N resident sessions behind one load-balancing door. Pair
            // with --from-checkpoint so each replica warm-starts from the
            // same blocks instead of retraining; without it the shared
            // seed still makes every replica bit-identical, just slower.
            let mut handles = Vec::with_capacity(replicas);
            for _ in 0..replicas {
                handles.push(serve::serve(
                    mk()?,
                    cfg,
                    &spec.tc,
                    spec.link(),
                    &train,
                    &test,
                    spec.holders,
                    &opts,
                )?);
            }
            let mut fleet = serve::fleet::Fleet::new(
                handles
                    .iter()
                    .enumerate()
                    .map(|(i, h)| {
                        (format!("replica-{i}"), serve::fleet::Backend::local(h.sender()))
                    })
                    .collect(),
            );
            fleet.reply_timeout = reply_timeout;
            serve::fleet::run_door(listener, fleet, max_requests, door_psk)?;
            let mut rep = None;
            for h in handles {
                rep = Some(h.shutdown()?);
            }
            rep.ok_or_else(|| err("no replica produced a report".into()))?
        } else {
            let handle = serve::serve(
                mk()?,
                cfg,
                &spec.tc,
                spec.link(),
                &train,
                &test,
                spec.holders,
                &opts,
            )?;
            let tx = handle.sender();
            let scorer: serve::frontdoor::Scorer =
                std::sync::Arc::new(move |rows: &[u32]| serve::request_scores(&tx, rows));
            serve::frontdoor::serve_clients(listener, scorer, max_requests, door_psk)?;
            handle.shutdown()?
        }
    };
    print_report(&rep);
    Ok(())
}

fn cmd_infer(flags: &HashMap<String, String>) -> CliResult<()> {
    // rows to score: --ids 1,2,3 or --count N [--offset K]. (`--rows`
    // stays the dataset-size training flag, so `--local` can combine both.)
    let rows: Vec<u32> = if let Some(list) = flags.get("ids") {
        list.split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| {
                s.trim()
                    .parse::<u32>()
                    .map_err(|_| err(format!("bad row id {s:?}")))
            })
            .collect::<CliResult<_>>()?
    } else {
        let count = flag(flags, "count", 16u32);
        let offset = flag(flags, "offset", 0u32);
        let end = offset
            .checked_add(count)
            .ok_or_else(|| err("--offset + --count overflows the u32 row-id space".into()))?;
        (offset..end).collect()
    };
    let repeat = flag(flags, "repeat", 1usize).max(1);
    let mut lat_ms: Vec<f64> = Vec::with_capacity(repeat);
    let scores = if flags.contains_key("local") {
        // parity reference: train + serve entirely in this process, same
        // seeds — must score bit-identically to a remote `spnn serve` of
        // the same config (the serve-smoke CI job asserts it)
        let spec = spec_from_flags(flags)?;
        let opts = serve_opts_from_flags(flags);
        let (cfg, train, test) = spec.datasets()?;
        let trainer = protocols::by_name(&spec.protocol)
            .ok_or_else(|| err(format!("unknown protocol {:?}", spec.protocol)))?;
        eprintln!(
            "spnn infer --local: training {} in-process, then scoring {} row(s)",
            spec.protocol,
            rows.len()
        );
        let h = serve::serve(
            trainer,
            cfg,
            &spec.tc,
            spec.link(),
            &train,
            &test,
            spec.holders,
            &opts,
        )?;
        let mut scores = Vec::new();
        for k in 0..repeat {
            let t0 = std::time::Instant::now();
            scores = h.infer(&rows)?;
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            eprintln!("request {k}: {} row(s) in {ms:.2} ms", scores.len());
            lat_ms.push(ms);
        }
        let rep = h.shutdown()?;
        println!("weight_digest=0x{:016x}", rep.weight_digest);
        scores
    } else {
        let connect = flags
            .get("connect")
            .ok_or_else(|| err("infer needs --connect HOST:PORT (or --local)".into()))?;
        let timeout = std::time::Duration::from_secs(flag(flags, "connect-timeout", 30u64));
        let psk = flags
            .get("psk-file")
            .map(|p| Psk::from_file(std::path::Path::new(p)))
            .transpose()?;
        let reply_timeout = flags
            .get("reply-timeout")
            .map(|v| {
                v.parse::<u64>()
                    .map_err(|_| err(format!("bad --reply-timeout seconds {v:?}")))
            })
            .transpose()?
            .map(std::time::Duration::from_secs);
        let mut scores = Vec::new();
        for k in 0..repeat {
            let t0 = std::time::Instant::now();
            scores = serve::frontdoor::infer_once_opts(
                connect,
                &rows,
                timeout,
                reply_timeout,
                psk.as_ref(),
            )?;
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            eprintln!("request {k}: {} row(s) in {ms:.2} ms", scores.len());
            lat_ms.push(ms);
        }
        scores
    };
    if scores.len() <= 32 {
        for (r, s) in rows.iter().zip(&scores) {
            println!("row {r}: {s:.6}");
        }
    } else {
        println!("{} scores (first 4: {:?})", scores.len(), &scores[..4]);
    }
    // bit-exact digest over the score stream (scripted parity checks)
    let mut f = spnn::protocols::common::Fnv::new();
    for s in &scores {
        f.add_bytes(&s.to_bits().to_le_bytes());
    }
    println!("infer_digest=0x{:016x}", f.0);
    let min = lat_ms.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = lat_ms.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mean = lat_ms.iter().sum::<f64>() / lat_ms.len() as f64;
    println!(
        "latency_ms min={min:.2} mean={mean:.2} max={max:.2} over {} request(s)",
        lat_ms.len()
    );
    Ok(())
}

fn cmd_repro(args: &[String], flags: &HashMap<String, String>) -> CliResult<()> {
    let which = args
        .iter()
        .find(|a| !a.starts_with("--") && a.parse::<f64>().is_err())
        .map(|s| s.as_str())
        .unwrap_or("all");
    let opts = ExpOpts {
        scale: flag(flags, "scale", 1.0),
        quick: flags.contains_key("quick"),
        seed: flag(flags, "seed", 7u64),
    };
    let md = if which == "all" {
        exp::run_all(&opts)?
    } else {
        let f = exp::by_name(which)
            .ok_or_else(|| err(format!("unknown experiment {which:?}")))?;
        f(&opts)?
    };
    println!("{md}");
    if let Some(path) = flags.get("out") {
        std::fs::write(path, &md)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_attack(flags: &HashMap<String, String>) -> CliResult<()> {
    let opts = AttackOpts {
        rows: flag(flags, "rows", 16_000),
        epochs: flag(flags, "epochs", 6),
        seed: flag(flags, "seed", 11u64),
        noise: flags.get("noise").and_then(|v| v.parse().ok()),
    };
    for sgld in [false, true] {
        let r = property_attack(sgld, &opts)?;
        println!(
            "{:>4}: task AUC {:.4}  attack AUC {:.4}",
            r.optimizer, r.task_auc, r.attack_auc
        );
    }
    Ok(())
}

fn cmd_info() -> CliResult<()> {
    let engine = Engine::load_default()?;
    if engine.is_native() {
        println!(
            "no AOT artifacts (run `make artifacts`); using the native \
             pure-rust graph fallback"
        );
    }
    let m = engine.manifest();
    println!("{} artifacts loaded:", m.len());
    let mut names: Vec<&String> = m.entries.keys().collect();
    names.sort();
    for n in names {
        let e = &m.entries[n];
        println!("  {n}: {} inputs, {} outputs", e.inputs.len(), e.outputs.len());
    }
    println!("configs: {} / {}", FRAUD.name, DISTRESS.name);
    Ok(())
}
