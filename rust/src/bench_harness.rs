//! Minimal timing harness for `cargo bench` targets (criterion is not in
//! the offline vendor set). Warms up, runs a fixed iteration budget, and
//! prints mean / median / min with throughput hooks.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
}

impl BenchStats {
    pub fn print(&self) {
        println!(
            "{:<44} {:>4} iters  mean {:>12}  median {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.median_s),
            fmt_time(self.min_s)
        );
    }

    /// Print with an items/sec throughput line.
    pub fn print_throughput(&self, items: f64, unit: &str) {
        self.print();
        println!(
            "{:<44}       -> {:.2} {unit}/s",
            "",
            items / self.mean_s
        );
    }
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        mean_s: times.iter().sum::<f64>() / iters as f64,
        median_s: times[iters / 2],
        min_s: times[0],
    };
    stats.print();
    stats
}

/// Time a one-shot (expensive) operation.
pub fn bench_once<F: FnOnce()>(name: &str, f: F) -> f64 {
    let t = Instant::now();
    f();
    let dt = t.elapsed().as_secs_f64();
    println!("{name:<44}    1 iter   {:>12}", fmt_time(dt));
    dt
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let s = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.min_s <= s.median_s && s.median_s <= s.mean_s * 5.0);
        assert_eq!(s.iters, 5);
    }
}
