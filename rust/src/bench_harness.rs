//! Minimal timing harness for `cargo bench` targets (criterion is not in
//! the offline vendor set). Warms up, runs a fixed iteration budget, and
//! prints mean / median / min with throughput hooks.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
}

impl BenchStats {
    pub fn print(&self) {
        println!(
            "{:<44} {:>4} iters  mean {:>12}  median {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.median_s),
            fmt_time(self.min_s)
        );
    }

    /// Print with an items/sec throughput line.
    pub fn print_throughput(&self, items: f64, unit: &str) {
        self.print();
        println!(
            "{:<44}       -> {:.2} {unit}/s",
            "",
            items / self.mean_s
        );
    }
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        mean_s: times.iter().sum::<f64>() / iters as f64,
        median_s: times[iters / 2],
        min_s: times[0],
    };
    stats.print();
    stats
}

/// Time a one-shot (expensive) operation.
pub fn bench_once<F: FnOnce()>(name: &str, f: F) -> f64 {
    let t = Instant::now();
    f();
    let dt = t.elapsed().as_secs_f64();
    println!("{name:<44}    1 iter   {:>12}", fmt_time(dt));
    dt
}

/// Minimal JSON object builder for machine-readable bench artifacts
/// (`BENCH_pipeline.json` etc.) — no serde in the offline vendor set.
#[derive(Default)]
pub struct JsonObj {
    fields: Vec<(String, String)>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn num(mut self, key: &str, v: f64) -> Self {
        let val = if v.is_finite() { format!("{v}") } else { "null".to_string() };
        self.fields.push((key.to_string(), val));
        self
    }

    pub fn int(mut self, key: &str, v: u64) -> Self {
        self.fields.push((key.to_string(), format!("{v}")));
        self
    }

    pub fn str(mut self, key: &str, v: &str) -> Self {
        self.fields.push((key.to_string(), format!("\"{}\"", json_escape(v))));
        self
    }

    /// Nest a sub-object (consumes its rendering).
    pub fn obj(mut self, key: &str, v: JsonObj) -> Self {
        self.fields.push((key.to_string(), v.render()));
        self
    }

    pub fn render(&self) -> String {
        let body: Vec<String> = self
            .fields
            .iter()
            .map(|(k, v)| format!("\"{}\": {v}", json_escape(k)))
            .collect();
        format!("{{{}}}", body.join(", "))
    }
}

/// Escape a string for JSON embedding.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let s = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.min_s <= s.median_s && s.median_s <= s.mean_s * 5.0);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn json_obj_renders_and_escapes() {
        let j = JsonObj::new()
            .str("name", "spnn-\"ss\"\n")
            .num("sim_s", 1.5)
            .int("bytes", 42)
            .obj("nested", JsonObj::new().int("depth", 2));
        let s = j.render();
        assert_eq!(
            s,
            "{\"name\": \"spnn-\\\"ss\\\"\\n\", \"sim_s\": 1.5, \"bytes\": 42, \
             \"nested\": {\"depth\": 2}}"
        );
        assert_eq!(JsonObj::new().num("x", f64::NAN).render(), "{\"x\": null}");
    }
}
