//! Property-inference attack on the exposed hidden features (paper §6.3,
//! Table 2), following Ganju et al. 2018 / Shokri et al. 2017 shadow
//! training.
//!
//! Threat: the semi-honest server sees `h1` for every training sample and
//! tries to infer a private input property — here the fraud dataset's
//! `amount` feature, binarized at its median. Mitigation under test: SGLD
//! (noise-injected updates) vs plain SGD.
//!
//! Procedure (paper's split: 50% shadow / 25% train / 25% test; §6.3
//! notes the simplification "we assume the attacker somehow gets the
//! 'amount' label and the corresponding hidden features, with which the
//! attacker trains the attack model"):
//! 1. train the target SPNN (SGD or SGLD) on the train partition,
//! 2. train the attack model (logistic regression) on the target's hidden
//!    features over the shadow partition vs the known `amount` bits,
//! 3. score the held-out quarter's hidden features. Report attack AUC and
//!    the target's task AUC.
//!
//! The hidden features are what the server receives — `h1 = X·theta0`,
//! identical under SS, HE, or plaintext execution (the crypto changes who
//! sees what, not the values; SS adds <=1 ulp fixed-point noise). We train
//! the target through the plaintext pipeline for wall-time reasons and
//! note the equivalence.

use crate::config::{ModelConfig, TrainConfig, FRAUD};
use crate::data::{auc, Dataset};
use crate::nn::MatF64;
use crate::protocols::common::ModelParams;
use crate::rng::{Pcg64, Rng64};
use crate::Result;

/// Outcome of one attack experiment.
#[derive(Clone, Debug)]
pub struct AttackResult {
    pub optimizer: &'static str,
    /// Target model's fraud-detection AUC (utility).
    pub task_auc: f64,
    /// Attacker's property-inference AUC (leakage; 0.5 = none).
    pub attack_auc: f64,
}

/// Options for the Table 2 experiment.
#[derive(Clone, Debug)]
pub struct AttackOpts {
    pub rows: usize,
    pub epochs: usize,
    pub seed: u64,
    /// SGLD noise-scale override (None = lr-matched default).
    pub noise: Option<f64>,
}

impl Default for AttackOpts {
    fn default() -> Self {
        AttackOpts { rows: 20_000, epochs: 6, seed: 11, noise: None }
    }
}

/// Run the property attack against SGD- or SGLD-trained SPNN.
pub fn property_attack(sgld: bool, opts: &AttackOpts) -> Result<AttackResult> {
    let cfg: &ModelConfig = &FRAUD;
    let ds = crate::data::synth_fraud(crate::data::SynthOpts {
        rows: opts.rows,
        seed: opts.seed,
        pos_boost: 20.0, // keep the task learnable at this scale
    });

    // property: 'amount' (last feature) binarized at the median
    let amount: Vec<f64> = (0..ds.len()).map(|i| ds.row(i)[27] as f64).collect();
    let mut sorted = amount.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = sorted[sorted.len() / 2];
    let prop: Vec<f32> = amount.iter().map(|&v| (v > median) as u32 as f32).collect();

    // 50/25/25 split
    let n = ds.len();
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Pcg64::seed_from_u64(opts.seed ^ 0xA77);
    rng.shuffle(&mut idx);
    let (sh_end, tr_end) = (n / 2, n * 3 / 4);
    let take = |ids: &[usize]| -> (Dataset, Vec<f32>) {
        let mut x = Vec::with_capacity(ids.len() * ds.n_features);
        let mut y = Vec::with_capacity(ids.len());
        let mut pr = Vec::with_capacity(ids.len());
        for &i in ids {
            x.extend_from_slice(ds.row(i));
            y.push(ds.y[i]);
            pr.push(prop[i]);
        }
        (Dataset { n_features: ds.n_features, x, y }, pr)
    };
    let (shadow, shadow_prop) = take(&idx[..sh_end]);
    let (target_train, _) = take(&idx[sh_end..tr_end]);
    let (holdout, holdout_prop) = take(&idx[tr_end..]);

    // --- train target model (SGD or SGLD) ---
    let tc_target = TrainConfig {
        batch: 1024,
        epochs: opts.epochs,
        sgld,
        seed: opts.seed ^ 0x52,
        lr_override: Some(0.05),
        sgld_noise: opts.noise,
        ..Default::default()
    };
    let (target_params, task_auc) =
        train_plain_with_auc(cfg, &tc_target, &target_train, &holdout)?;

    // --- attack model: LR on the target's hidden features over the
    // attacker-known partition (paper §6.3's simplification) ---
    let h_shadow = hidden_features(&shadow, &target_params);
    let (w, b) = train_logreg(&h_shadow, &shadow_prop, 600, 2.0, opts.seed ^ 0x53);

    // --- score the target's hidden features on the holdout ---
    let h_target = hidden_features(&holdout, &target_params);
    let scores: Vec<f32> = (0..holdout.len())
        .map(|i| {
            let row = &h_target.data[i * cfg.h1_dim..(i + 1) * cfg.h1_dim];
            let z: f64 = row.iter().zip(&w).map(|(a, c)| a * c).sum::<f64>() + b;
            z as f32
        })
        .collect();
    let attack_auc = auc(&scores, &holdout_prop);

    Ok(AttackResult {
        optimizer: if sgld { "SGLD" } else { "SGD" },
        task_auc,
        attack_auc,
    })
}

/// Hidden features the server sees: `h1 = X @ theta0`.
fn hidden_features(ds: &Dataset, params: &ModelParams) -> MatF64 {
    let x = MatF64::from_f32(ds.len(), ds.n_features, &ds.x);
    x.matmul(&params.theta0)
}

/// Plaintext-pipeline training returning the final params and test AUC.
pub fn train_plain_with_auc(
    cfg: &ModelConfig,
    tc: &TrainConfig,
    train: &Dataset,
    test: &Dataset,
) -> Result<(ModelParams, f64)> {
    use crate::protocols::common::{evaluate, Updater};
    use crate::runtime::{Engine, TensorIn};

    let mut engine = Engine::load_default()?;
    let mut params = ModelParams::init(cfg, tc.seed);
    let mut up = Updater::new(tc, cfg, tc.seed);
    let cap = ModelConfig::pick_batch(tc.batch);
    let art = cfg.artifact("nn_train", cap);
    let batches = train.batches(tc.batch, cap);
    for _ in 0..tc.epochs {
        for b in &batches {
            let theta0 = params.theta0_f32();
            let server = params.server_f32();
            let wy = params.wy_f32();
            let by = params.by_f32();
            let mut inputs: Vec<TensorIn> = vec![
                TensorIn::F32(&b.x),
                TensorIn::F32(&b.y),
                TensorIn::F32(&b.mask),
                TensorIn::F32(&theta0),
            ];
            for s in &server {
                inputs.push(TensorIn::F32(s));
            }
            inputs.push(TensorIn::F32(&wy));
            inputs.push(TensorIn::F32(&by));
            let outs = engine.execute(&art, &inputs)?;
            let g_theta0 = outs[2].clone().f32()?;
            up.step_mat_f32(&mut params.theta0, &g_theta0);
            let ns = params.server.len();
            for i in 0..ns {
                let g = outs[3 + i].clone().f32()?;
                up.step_mat_f32(&mut params.server[i], &g);
            }
            let g_wy = outs[3 + ns].clone().f32()?;
            let g_by = outs[4 + ns].clone().f32()?;
            up.step_mat_f32(&mut params.wy, &g_wy);
            up.step_mat_f32(&mut params.by, &g_by);
            up.tick();
        }
    }
    let (a, _) = evaluate(&mut engine, cfg, &params, test)?;
    Ok((params, a))
}

/// Simple full-batch logistic regression (the attack model).
/// Returns (weights, bias) over the hidden-feature space.
pub fn train_logreg(
    x: &MatF64,
    y: &[f32],
    iters: usize,
    lr: f64,
    seed: u64,
) -> (Vec<f64>, f64) {
    let (n, d) = x.shape();
    assert_eq!(n, y.len());
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut w: Vec<f64> = (0..d).map(|_| (rng.f64_unit() - 0.5) * 0.01).collect();
    let mut b = 0.0f64;
    for _ in 0..iters {
        let mut gw = vec![0.0f64; d];
        let mut gb = 0.0f64;
        for i in 0..n {
            let row = &x.data[i * d..(i + 1) * d];
            let z: f64 = row.iter().zip(&w).map(|(a, c)| a * c).sum::<f64>() + b;
            let g = crate::nn::bce_with_logits_grad(&[z], &[y[i] as f64], &[1.0])[0];
            for (gv, &a) in gw.iter_mut().zip(row) {
                *gv += g * a;
            }
            gb += g;
        }
        let inv_n = 1.0; // bce grad is already mean-normalized per sample call
        for (wv, g) in w.iter_mut().zip(&gw) {
            *wv -= lr * g * inv_n / n as f64;
        }
        b -= lr * gb / n as f64;
    }
    (w, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logreg_learns_separable_data() {
        let mut rng = Pcg64::seed_from_u64(1);
        let n = 400;
        let d = 4;
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let row: Vec<f64> = (0..d).map(|_| rng.f64_unit() * 2.0 - 1.0).collect();
            y.push((row[0] + row[1] > 0.0) as u32 as f32);
            x.extend(row);
        }
        let xm = MatF64::from_data(n, d, x);
        let (w, b) = train_logreg(&xm, &y, 500, 5.0, 2);
        let scores: Vec<f32> = (0..n)
            .map(|i| {
                let row = &xm.data[i * d..(i + 1) * d];
                (row.iter().zip(&w).map(|(a, c)| a * c).sum::<f64>() + b) as f32
            })
            .collect();
        assert!(auc(&scores, &y) > 0.95, "auc {}", auc(&scores, &y));
    }

    #[test]
    fn attack_runs_and_sgld_reduces_leakage() {
        if !crate::runtime::default_artifact_dir().join("manifest.txt").exists() {
            return;
        }
        let opts = AttackOpts { rows: 6000, epochs: 3, seed: 5, noise: None };
        let sgd = property_attack(false, &opts).unwrap();
        let sgld = property_attack(true, &opts).unwrap();
        assert!(sgd.task_auc > 0.55, "SGD task AUC {}", sgd.task_auc);
        assert!(sgd.attack_auc > 0.5, "attack should leak under SGD: {}", sgd.attack_auc);
        // Table 2's qualitative claim: SGLD reduces attack AUC
        assert!(
            sgld.attack_auc <= sgd.attack_auc + 0.02,
            "SGLD {} vs SGD {}",
            sgld.attack_auc,
            sgd.attack_auc
        );
    }
}
