//! SplitNN baseline (Vepakomma et al. 2018, paper Figure 1b).
//!
//! Each data holder trains a *private bottom encoder* on its own feature
//! block (plaintext, no crypto); the cut-layer activations are concatenated
//! at the server, which owns everything above the cut **including the
//! labels** — the privacy weakness the paper calls out (labels leak to the
//! server, and per-holder encoders cannot model cross-holder feature
//! interactions, which costs accuracy as the holder count grows — Fig 5).
//!
//! Cut-layer width is `h1_dim` split evenly across holders, so the server
//! stack reuses the same AOT graphs as SPNN.
//!
//! The per-batch forward lives in the shared forward layer
//! ([`super::fwd::SplitHolderFwd`] / [`super::fwd::SplitServerFwd`]); the
//! role bodies here add the training-only label gradients / backward, and
//! the same forward objects answer inference requests after training
//! (the **server** is the scoring role — it owns the label layer).
//!
//! The party loops run on the shared [`run_epochs`] batch-stage state
//! machine: holders stage their (value-independent) feature-block decode
//! in `Prefetch`, send cut-layer activations in `Submit` and consume the
//! server's gradients in `Complete`, so the knob sweep in the pipeline
//! bench — and the bounded-staleness mode (`TrainConfig::staleness`) —
//! covers this baseline too.

use super::common::{batch_plan, run_epochs, Ev, Fnv, ModelParams, Step, TrainReport, Updater};
use super::fwd::{FeatureSource, SplitHolderFwd, SplitServerFwd};
use super::Trainer;
use crate::ckpt;
use crate::config::{ModelConfig, TrainConfig};
use crate::data::{auc, CompressPlan, Dataset, FeatureTransform, VerticalSplit};
use crate::netsim::Payload;
use crate::nn::MatF64;
use crate::parties::{self, ids, Deployment, NetSummary, PartyFn, PartyOut};
use crate::rng::Pcg64;
use crate::runtime::{Engine, TensorIn};
use crate::serve::{self, ServeOpts, ServeQueue, ServeRole};
use crate::transport::Channel;
use crate::{Error, Result};
use std::collections::VecDeque;

pub struct SplitNn;

/// Cut-layer split: how many h1 units each holder produces.
fn unit_split(h1: usize, k: usize) -> VerticalSplit {
    VerticalSplit::even(h1, k)
}

impl SplitNn {
    /// Build the party roster; with `serve` set the holders + server stay
    /// resident and score request rows of the held-out table (the server
    /// is the responder — it owns the label layer by design).
    fn build(
        &self,
        cfg: &ModelConfig,
        tc: &TrainConfig,
        train: &Dataset,
        test: &Dataset,
        n_holders: usize,
        serve: Option<(ServeOpts, ServeQueue)>,
    ) -> Result<Deployment> {
        let fsplit = VerticalSplit::even(cfg.n_features, n_holders);
        let usplit = unit_split(cfg.h1_dim, n_holders);
        // optional holder-side feature compression: each encoder consumes
        // its holder's post-transform columns (`k_j x u_j`)
        let cplan = CompressPlan::maybe(tc.compress.as_ref(), cfg.n_features, n_holders, tc.seed)?;
        let plan = batch_plan(train.len(), tc.batch);
        let params = ModelParams::init(cfg, tc.seed);

        let mut names = vec!["coord".to_string(), "server".to_string(), "dealer".to_string()];
        for j in 0..n_holders {
            names.push(format!("holder{j}"));
        }
        let role_serve = serve.as_ref().map(|(o, _)| ServeRole { depth: o.depth });
        let mut fns: Vec<PartyFn> = Vec::new();

        // coordinator (the serve request front when serving; SplitNN's
        // responder is the server — it owns the label layer)
        {
            let workers: Vec<usize> =
                (1..names.len()).filter(|&i| i != ids::DEALER).collect();
            let serve_workers: Vec<usize> = std::iter::once(ids::SERVER)
                .chain((0..n_holders).map(ids::holder))
                .collect();
            fns.push(serve::coordinator_role(
                tc,
                workers,
                ids::SERVER,
                serve_workers,
                ids::SERVER,
                test.len(),
                serve,
            ));
        }
        // server (owns labels in SplitNN!)
        {
            let cfg = cfg.clone();
            let tc = tc.clone();
            let plan = plan.clone();
            let y = train.y.clone();
            let srv = role_serve;
            fns.push(Box::new(move |p: &mut dyn Channel| {
                server_role(p, &cfg, &tc, &plan, &y, params, n_holders, srv)
            }));
        }
        // dealer: unused in SplitNN — parks until the process ends
        fns.push(Box::new(move |_p: &mut dyn Channel| Ok(PartyOut::default())));
        // holders: encoder init derived from the seed (holder j maps its
        // d_j features to its u_j cut-layer units)
        for j in 0..n_holders {
            let tc = tc.clone();
            let plan = plan.clone();
            let xj = fsplit.slice_x(&train.x, cfg.n_features, j);
            let serve_xj =
                role_serve.map(|_| fsplit.slice_x(&test.x, cfg.n_features, j));
            let dj = fsplit.width(j);
            let tf = cplan.as_ref().map(|p| p.tf(j));
            // the encoder consumes post-transform columns (k_j == dj when
            // no transform is active, so the init draws are unchanged)
            let kj = tf.as_ref().map(|t| t.k).unwrap_or(dj);
            let mut rng = Pcg64::seed_from_u64(tc.seed ^ (77 + j as u64));
            let enc = MatF64::xavier(&mut rng, kj, usplit.width(j));
            let cfg = cfg.clone();
            let srv = role_serve;
            fns.push(Box::new(move |p: &mut dyn Channel| {
                holder_role(p, &cfg, &tc, &plan, j, n_holders, xj, dj, tf, enc, srv, serve_xj)
            }));
        }
        Ok(Deployment { names, fns })
    }
}

impl Trainer for SplitNn {
    fn name(&self) -> &'static str {
        "SplitNN"
    }

    fn deployment(
        &self,
        cfg: &ModelConfig,
        tc: &TrainConfig,
        train: &Dataset,
        test: &Dataset,
        n_holders: usize,
    ) -> Result<Deployment> {
        self.build(cfg, tc, train, test, n_holders, None)
    }

    #[allow(clippy::too_many_arguments)]
    fn serve_deployment(
        &self,
        cfg: &ModelConfig,
        tc: &TrainConfig,
        train: &Dataset,
        test: &Dataset,
        n_holders: usize,
        opts: &ServeOpts,
        queue: ServeQueue,
    ) -> Result<Deployment> {
        self.build(cfg, tc, train, test, n_holders, Some((opts.clone(), queue)))
    }

    fn finish(
        &self,
        cfg: &ModelConfig,
        tc: &TrainConfig,
        test: &Dataset,
        outs: &[PartyOut],
        net: NetSummary,
        wall_seconds: f64,
    ) -> Result<TrainReport> {
        let n_holders = outs.len() - ids::HOLDER0;
        let fsplit = VerticalSplit::even(cfg.n_features, n_holders);
        let usplit = unit_split(cfg.h1_dim, n_holders);
        let cplan = CompressPlan::maybe(tc.compress.as_ref(), cfg.n_features, n_holders, tc.seed)?;
        // encoders from the holders (k_j x u_j in the post-transform column
        // space), server stack + label layer from the server (theta0 stays
        // at init — SplitNN never trains it)
        let mut encoders = Vec::with_capacity(n_holders);
        for j in 0..n_holders {
            let data = outs[ids::holder(j)].need_param("enc")?;
            let kj = match &cplan {
                Some(p) => p.csplit.width(j),
                None => fsplit.width(j),
            };
            if data.len() != kj * usplit.width(j) {
                return Err(Error::Protocol(format!("holder{j}: encoder size")));
            }
            encoders.push(MatF64::from_data(kj, usplit.width(j), data.to_vec()));
        }
        let mut sp = ModelParams::init(cfg, tc.seed);
        for (i, m) in sp.server.iter_mut().enumerate() {
            let got = outs[ids::SERVER].need_param(&format!("server{i}"))?;
            if got.len() != m.data.len() {
                return Err(Error::Protocol(format!("server{i}: param size")));
            }
            m.data.copy_from_slice(got);
        }
        let wy = outs[ids::SERVER].need_param("wy")?;
        let by = outs[ids::SERVER].need_param("by")?;
        if wy.len() != sp.wy.data.len() || by.len() != sp.by.data.len() {
            return Err(Error::Protocol("server: label-layer param size".into()));
        }
        sp.wy.data.copy_from_slice(wy);
        sp.by.data.copy_from_slice(by);

        let mut engine = Engine::load_default()?;
        // on compressed runs, evaluate over the transformed table with the
        // compressed column split (the encoders consume k_j columns)
        let transformed;
        let (eval_test, esplit): (&Dataset, &VerticalSplit) = match &cplan {
            Some(plan) => {
                transformed = plan.transform_dataset(test);
                (&transformed, &plan.csplit)
            }
            None => (test, &fsplit),
        };
        let (a, test_loss) =
            eval_splitnn(&mut engine, cfg, esplit, &usplit, &encoders, &sp, eval_test)?;
        // digest over everything the composite model trains: the holders'
        // encoders plus the server stack and label layer
        let mut digest = Fnv::new();
        let mut params_out: Vec<(String, Vec<f64>)> = Vec::new();
        for (j, enc) in encoders.iter().enumerate() {
            digest.add_f64s(&enc.data);
            params_out.push((format!("enc{j}"), enc.data.clone()));
        }
        digest.add_u64(sp.digest());
        for (i, m) in sp.server.iter().enumerate() {
            params_out.push((format!("server{i}"), m.data.clone()));
        }
        params_out.push(("wy".to_string(), sp.wy.data.clone()));
        params_out.push(("by".to_string(), sp.by.data.clone()));

        Ok(TrainReport {
            protocol: self.name().into(),
            dataset: cfg.name.into(),
            auc: a,
            train_losses: outs[ids::COORDINATOR].epoch_losses.clone(),
            test_losses: vec![test_loss],
            epoch_times: outs[ids::SERVER].epoch_times.clone(),
            online_bytes: net.online_bytes,
            offline_bytes: net.offline_bytes,
            stages: net.stages,
            weight_digest: digest.0,
            params: params_out,
            wall_seconds,
        })
    }
}

#[allow(clippy::too_many_arguments)]
fn server_role(
    p: &mut dyn Channel,
    cfg: &ModelConfig,
    tc: &TrainConfig,
    plan: &[(usize, usize)],
    y: &[f32],
    params: ModelParams,
    n_holders: usize,
    srv: Option<ServeRole>,
) -> Result<PartyOut> {
    let epochs = parties::await_start(p)?;
    let mut up = Updater::new(tc, cfg, tc.seed ^ 0x3e7);
    let cap = ModelConfig::pick_batch(tc.batch);
    let h1 = cfg.h1_dim;
    let hl = cfg.hl_dim();
    let usplit = unit_split(h1, n_holders);
    // the forward layer owns the server stack + label layer and the cut
    // concatenation; training below updates fwd.params in place
    let mut fwd = SplitServerFwd::new(cfg, tc, params, n_holders, usplit.clone())?;
    let mut times = Vec::new();
    let mut losses = Vec::new();

    let mut bucket = vec![0.0f64; epochs];
    let mut prev_t = 0.0f64;
    run_epochs(plan, epochs, tc.pipeline_depth, tc.staleness, tc.seed, |ev| {
        let b = match ev {
            Ev::EpochStart(ep) => {
                // lock-step resets the sim clock per epoch (seed behavior);
                // async time flows across epochs — record deltas instead
                if tc.staleness == 0 || ep == 0 {
                    p.reset_clock();
                    prev_t = 0.0;
                }
                return Ok(());
            }
            Ev::EpochEnd(ep) => {
                let t = p.now();
                times.push(t - prev_t);
                prev_t = t;
                let mean = bucket[ep] / plan.len().max(1) as f64;
                losses.push(mean);
                return parties::report_epoch(p, mean);
            }
            // the server's whole per-batch load depends on the holders'
            // activations, so it all lives in Submit (no lookahead work)
            Ev::Step(Step::Submit, b) => b,
            Ev::Step(..) => return Ok(()),
        };
        {
            let (s, rows) = (b.start, b.rows);
            let tag = b.tag();
            // gather cut-layer blocks + hidden stack (the forward layer)
            let (h1_pad, hl_act) = fwd.hidden(p, b)?;
            // label layer runs on the SERVER (labels leaked by design)
            let mut y_pad = vec![0.0f32; cap];
            y_pad[..rows].copy_from_slice(&y[s..s + rows]);
            let mut mask = vec![0.0f32; cap];
            for m in mask.iter_mut().take(rows) {
                *m = 1.0;
            }
            let wy = fwd.params.wy_f32();
            let by = fwd.params.by_f32();
            let outs = fwd.engine.execute(
                &cfg.artifact("label_grad", cap),
                &[
                    TensorIn::F32(&hl_act),
                    TensorIn::F32(&y_pad),
                    TensorIn::F32(&mask),
                    TensorIn::F32(&wy),
                    TensorIn::F32(&by),
                ],
            )?;
            bucket[b.epoch] += outs[1].scalar()?;
            let g_hl = outs[2].clone().f32()?;
            let g_wy = outs[3].clone().f32()?;
            let g_by = outs[4].clone().f32()?;
            up.step_mat_f32(&mut fwd.params.wy, &g_wy);
            up.step_mat_f32(&mut fwd.params.by, &g_by);

            // backward through the server stack
            let mut g_hl_pad = vec![0.0f32; cap * hl];
            g_hl_pad.copy_from_slice(&g_hl);
            let server_f32 = fwd.params.server_f32();
            let mut inputs: Vec<TensorIn> =
                vec![TensorIn::F32(&h1_pad), TensorIn::F32(&g_hl_pad)];
            for sp in &server_f32 {
                inputs.push(TensorIn::F32(sp));
            }
            let mut outs = fwd.engine.execute(&cfg.artifact("server_bwd", cap), &inputs)?;
            let g_params: Vec<Vec<f32>> = outs
                .split_off(1)
                .into_iter()
                .map(|t| t.f32())
                .collect::<Result<_>>()?;
            let g_h1 = outs.remove(0).f32()?;
            for (m, g) in fwd.params.server.iter_mut().zip(&g_params) {
                up.step_mat_f32(m, g);
            }
            up.tick();
            // scatter cut-layer gradients back to holders
            for j in 0..n_holders {
                let (us, ue) = usplit.ranges[j];
                let w = ue - us;
                let mut blk = vec![0.0f32; rows * w];
                for r in 0..rows {
                    blk[r * w..(r + 1) * w]
                        .copy_from_slice(&g_h1[r * h1 + us..r * h1 + ue]);
                }
                p.send_tagged(ids::holder(j), tag, Payload::F32s(blk))?;
            }
            Ok(())
        }
    })?;
    parties::await_stop(p)?;

    // ---- checkpoint boundary (end of training): SplitNN serving is
    // RNG-free, so the server's durable state is just its stack + head ----
    if tc.warm_start {
        let ck = ckpt::load_verified(tc, "splitnn", "server", n_holders)?;
        for (i, m) in fwd.params.server.iter_mut().enumerate() {
            ck.copy_f64(&format!("server{i}"), &mut m.data)?;
        }
        ck.copy_f64("wy", &mut fwd.params.wy.data)?;
        ck.copy_f64("by", &mut fwd.params.by.data)?;
    } else if let Some(dir) = tc.checkpoint_dir.as_deref() {
        let digest = ckpt::config_digest("splitnn", tc, n_holders);
        let mut ck = ckpt::Checkpoint::new("splitnn", "server", digest);
        for (i, m) in fwd.params.server.iter().enumerate() {
            ck.push_f64(&format!("server{i}"), m.data.clone());
        }
        ck.push_f64("wy", fwd.params.wy.data.clone());
        ck.push_f64("by", fwd.params.by.data.clone());
        ckpt::save_rotated(dir, &ck, tc.checkpoint_keep)?;
    }

    // ---- serving: the server is the scoring role (owns the head) ----
    if let Some(sr) = srv {
        serve::party_serve_loop(p, ids::COORDINATOR, sr.depth, &mut fwd)?;
    }

    let mut out_params: Vec<(String, Vec<f64>)> = fwd
        .params
        .server
        .iter()
        .enumerate()
        .map(|(i, m)| (format!("server{i}"), m.data.clone()))
        .collect();
    out_params.push(("wy".to_string(), fwd.params.wy.data.clone()));
    out_params.push(("by".to_string(), fwd.params.by.data.clone()));
    Ok(PartyOut {
        sim_time: p.now(),
        epoch_times: times,
        epoch_losses: losses,
        params: out_params,
        ..Default::default()
    })
}

#[allow(clippy::too_many_arguments)]
fn holder_role(
    p: &mut dyn Channel,
    cfg: &ModelConfig,
    tc: &TrainConfig,
    plan: &[(usize, usize)],
    j: usize,
    n_holders: usize,
    xj: Vec<f32>,
    dj: usize,
    tf: Option<FeatureTransform>,
    enc: MatF64,
    srv: Option<ServeRole>,
    serve_xj: Option<Vec<f32>>,
) -> Result<PartyOut> {
    let epochs = parties::await_start(p)?;
    let mut up = Updater::new(tc, cfg, tc.seed ^ (0x591 + j as u64));
    // the forward layer owns the encoder; the backward updates it in place.
    // The source carries the optional transform, so the encoder (and its
    // gradient, x^T . g) sees post-transform columns throughout.
    let src = FeatureSource::slice(xj, dj).with_transform(tf.clone());
    let mut fwd = SplitHolderFwd::new(enc, src);
    // in-flight block queue for backward (staleness may defer Completes)
    let mut inflight: VecDeque<MatF64> = VecDeque::new();
    run_epochs(plan, epochs, tc.pipeline_depth, tc.staleness, tc.seed, |ev| {
        match ev {
            Ev::EpochStart(_) | Ev::EpochEnd(_) => Ok(()),
            Ev::Step(Step::Prefetch, b) => fwd.prefetch(p, b),
            Ev::Step(Step::Submit, b) => {
                inflight.push_back(fwd.submit(p, b)?);
                Ok(())
            }
            Ev::Step(Step::Complete, b) => {
                p.set_stage("cut-bwd");
                let x = inflight.pop_front().expect("submit before complete");
                let g = p.recv_tagged(ids::SERVER, b.tag())?.into_f32s()?;
                let g_m = MatF64::from_f32(b.rows, fwd.enc.cols, &g);
                let g_w = x.transpose().matmul(&g_m);
                up.step_mat_f32(&mut fwd.enc, &g_w.to_f32());
                up.tick();
                Ok(())
            }
        }
    })?;
    parties::await_stop(p)?;

    // ---- checkpoint boundary: the holder's only durable state is its
    // private bottom encoder (no serving RNG) ----
    let role_name = format!("holder{j}");
    if tc.warm_start {
        let ck = ckpt::load_verified(tc, "splitnn", &role_name, n_holders)?;
        ck.copy_f64("enc", &mut fwd.enc.data)?;
    } else if let Some(dir) = tc.checkpoint_dir.as_deref() {
        let digest = ckpt::config_digest("splitnn", tc, n_holders);
        let mut ck = ckpt::Checkpoint::new("splitnn", &role_name, digest);
        ck.push_f64("enc", fwd.enc.data.clone());
        ckpt::save_rotated(dir, &ck, tc.checkpoint_keep)?;
    }

    // ---- serving: score requests against the held-out table ----
    if let Some(sr) = srv {
        fwd.src =
            FeatureSource::gather(serve_xj.expect("serve slice"), dj).with_transform(tf);
        serve::party_serve_loop(p, ids::COORDINATOR, sr.depth, &mut fwd)?;
    }

    Ok(PartyOut {
        sim_time: p.now(),
        params: vec![("enc".to_string(), fwd.enc.data)],
        ..Default::default()
    })
}

/// Plaintext evaluation of the SplitNN composite model.
fn eval_splitnn(
    engine: &mut Engine,
    cfg: &ModelConfig,
    fsplit: &VerticalSplit,
    usplit: &VerticalSplit,
    encoders: &[MatF64],
    sp: &ModelParams,
    test: &Dataset,
) -> Result<(f64, f64)> {
    let cap = ModelConfig::pick_batch(test.len().min(5000));
    let h1 = cfg.h1_dim;
    let mut scores = Vec::with_capacity(test.len());
    let mut losses = Vec::new();
    for b in test.batches(cap, cap) {
        let mut h1_pad = vec![0.0f32; cap * h1];
        for (j, w) in encoders.iter().enumerate() {
            let xj = fsplit.slice_x(&b.x, test.n_features, j);
            let x = MatF64::from_f32(cap, fsplit.width(j), &xj);
            let z = x.matmul(w);
            let (us, ue) = usplit.ranges[j];
            for r in 0..cap {
                for c in us..ue {
                    h1_pad[r * h1 + c] = z.at(r, c - us) as f32;
                }
            }
        }
        let server_f32 = sp.server_f32();
        let mut inputs: Vec<TensorIn> = vec![TensorIn::F32(&h1_pad)];
        for s in &server_f32 {
            inputs.push(TensorIn::F32(s));
        }
        let hl = engine
            .execute(&cfg.artifact("server_fwd", cap), &inputs)?
            .remove(0)
            .f32()?;
        let wy = sp.wy_f32();
        let by = sp.by_f32();
        let outs = engine.execute(
            &cfg.artifact("label_grad", cap),
            &[
                TensorIn::F32(&hl),
                TensorIn::F32(&b.y),
                TensorIn::F32(&b.mask),
                TensorIn::F32(&wy),
                TensorIn::F32(&by),
            ],
        )?;
        let pvec = outs[0].clone().f32()?;
        losses.push(outs[1].scalar()?);
        scores.extend_from_slice(&pvec[..b.rows]);
    }
    Ok((
        auc(&scores, &test.y),
        losses.iter().sum::<f64>() / losses.len().max(1) as f64,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{TransportKind, FRAUD};
    use crate::data::{synth_fraud, SynthOpts};
    use crate::netsim::LinkSpec;

    #[test]
    fn splitnn_transports_are_transcript_equal() {
        // plaintext cut-layer traffic (F32s payloads) through the real
        // wire codec must train the same composite model as netsim
        let ds = synth_fraud(SynthOpts::small(400));
        let (train, test) = ds.split(0.8, 31);
        let mut digests = Vec::new();
        for kind in [TransportKind::Netsim, TransportKind::Tcp, TransportKind::Uds] {
            let tc = TrainConfig {
                batch: 128,
                epochs: 2,
                lr_override: Some(0.3),
                transport: kind,
                ..Default::default()
            };
            let rep = SplitNn
                .train(&FRAUD, &tc, LinkSpec::lan(), &train, &test, 2)
                .unwrap();
            assert_ne!(rep.weight_digest, 0);
            digests.push(rep.weight_digest);
        }
        assert_eq!(digests[0], digests[1], "SplitNN over TCP diverged from netsim");
        assert_eq!(digests[0], digests[2], "SplitNN over UDS diverged from netsim");
    }

    #[test]
    fn splitnn_async_transcript_is_pinned_across_depth_and_transport() {
        // bounded staleness replays a seed-derived lag schedule: the async
        // run trains the same composite model at any depth and over real
        // TCP sockets, and (when the schedule draws a nonzero lag)
        // different weights from the lock-step run it relaxes
        use crate::protocols::common::{batch_plan, staleness_lags};
        let ds = synth_fraud(SynthOpts::small(400));
        let (train, test) = ds.split(0.8, 31);
        let tc_for = |staleness: usize, depth: usize, kind: TransportKind| TrainConfig {
            batch: 64,
            epochs: 2,
            lr_override: Some(0.3),
            pipeline_depth: depth,
            staleness,
            transport: kind,
            ..Default::default()
        };
        let run = |tc: &TrainConfig| {
            SplitNn.train(&FRAUD, tc, LinkSpec::lan(), &train, &test, 2).unwrap()
        };
        let base = run(&tc_for(2, 1, TransportKind::Netsim));
        assert_ne!(base.weight_digest, 0);
        let deep = run(&tc_for(2, 4, TransportKind::Netsim));
        assert_eq!(
            base.weight_digest, deep.weight_digest,
            "depth 4 diverged from depth 1 at staleness 2"
        );
        let bits = |r: &TrainReport| -> Vec<u64> {
            r.train_losses.iter().map(|l| l.to_bits()).collect()
        };
        assert_eq!(bits(&base), bits(&deep), "loss transcript diverged with depth");
        let tcp = run(&tc_for(2, 4, TransportKind::Tcp));
        assert_eq!(base.weight_digest, tcp.weight_digest, "TCP diverged at staleness 2");
        let lockstep = run(&tc_for(0, 1, TransportKind::Netsim));
        let total = batch_plan(train.len(), 64).len() * 2;
        if staleness_lags(total, 2, tc_for(2, 1, TransportKind::Netsim).seed)
            .iter()
            .any(|&l| l != 0)
        {
            assert_ne!(
                base.weight_digest, lockstep.weight_digest,
                "a drawn lag must reorder updates vs lock-step"
            );
        }
    }

    #[test]
    fn splitnn_compressed_netsim_tcp_parity() {
        use crate::config::CompressCfg;
        let ds = synth_fraud(SynthOpts::small(200));
        let (train, test) = ds.split(0.8, 32);
        let mut digests = Vec::new();
        for kind in [TransportKind::Netsim, TransportKind::Tcp] {
            let tc = TrainConfig {
                batch: 128,
                epochs: 1,
                lr_override: Some(0.3),
                transport: kind,
                compress: Some(CompressCfg::parse("dct:0.5").unwrap()),
                ..Default::default()
            };
            let rep = SplitNn
                .train(&FRAUD, &tc, LinkSpec::lan(), &train, &test, 2)
                .unwrap();
            assert_ne!(rep.weight_digest, 0);
            // fraud 28 cols / 2 holders at 0.5 -> each encoder is 7 x u_j
            let enc0 = rep.param("enc0").expect("enc0 block");
            assert_eq!(enc0.len(), 7 * 4, "compressed encoder shape");
            digests.push(rep.weight_digest);
        }
        assert_eq!(digests[0], digests[1], "compressed SplitNN TCP diverged from netsim");
    }

    #[test]
    fn splitnn_trains_small() {
        if !crate::runtime::default_artifact_dir().join("manifest.txt").exists() {
            return;
        }
        let ds = synth_fraud(SynthOpts::small(2000));
        let (train, test) = ds.split(0.8, 3);
        let tc =
            TrainConfig { batch: 256, epochs: 8, lr_override: Some(0.3), ..Default::default() };
        let rep = SplitNn
            .train(&FRAUD, &tc, LinkSpec::lan(), &train, &test, 2)
            .unwrap();
        assert!(rep.auc > 0.55, "AUC {}", rep.auc);
        assert!(rep.train_losses.last().unwrap() <= &rep.train_losses[0]);
    }

    #[test]
    fn unit_split_matches_h1() {
        let us = unit_split(8, 3);
        assert_eq!(us.ranges, vec![(0, 3), (3, 6), (6, 8)]);
    }
}
