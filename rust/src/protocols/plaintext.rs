//! Plaintext NN baseline: the whole network trained centrally on the
//! concatenated data — no privacy, fastest, the accuracy ceiling of
//! Table 1 and the time floor of Table 3.
//!
//! Single "server" party; the only traffic is the coordinator handshake.
//! Uses the monolithic `nn_train` AOT graph (the same math the split
//! pipeline distributes across parties — `python/tests/test_model.py`
//! proves the two compose identically).

use std::time::Instant;

use super::common::{evaluate, run_pipeline, ModelParams, Step, TrainReport, Updater};
use super::Trainer;
use crate::config::{ModelConfig, TrainConfig};
use crate::data::Dataset;
use crate::netsim::{LinkSpec, NetPort};
use crate::parties::{self, run_parties, PartyOut};
use crate::runtime::{Engine, TensorIn};
use crate::Result;

pub struct PlainNn;

impl Trainer for PlainNn {
    fn name(&self) -> &'static str {
        "NN"
    }

    fn train(
        &self,
        cfg: &ModelConfig,
        tc: &TrainConfig,
        spec: LinkSpec,
        train: &Dataset,
        test: &Dataset,
        _n_holders: usize,
    ) -> Result<TrainReport> {
        let wall = Instant::now();
        crate::exec::set_default_threads(tc.exec_threads);
        let mut params = ModelParams::init(cfg, tc.seed);
        let cap = ModelConfig::pick_batch(tc.batch);
        let batches = train.batches(tc.batch, cap);
        // plan derived FROM the batches so the two can never disagree
        let plan: Vec<(usize, usize)> = {
            let mut start = 0usize;
            batches
                .iter()
                .map(|b| {
                    let e = (start, b.rows);
                    start += b.rows;
                    e
                })
                .collect()
        };
        let cfgc = cfg.clone();
        let tcc = tc.clone();

        // run as a 2-party deployment (coordinator + server) so the control
        // flow matches the decentralized protocols
        let test_c = test.clone();
        let (mut epoch_losses, mut epoch_times) = (Vec::new(), Vec::new());
        let fns: Vec<Box<dyn FnOnce(NetPort) -> Result<PartyOut> + Send>> = vec![
            Box::new(move |mut p: NetPort| {
                parties::coordinator_run(&mut p, &[1], 1, tcc.epochs)
            }),
            Box::new(move |mut p: NetPort| {
                let epochs = parties::await_start(&mut p)?;
                let mut engine = Engine::load_default()?;
                let mut up = Updater::new(&tcc, &cfgc, tcc.seed);
                let art = cfgc.artifact("nn_train", cap);
                let mut times = Vec::new();
                for _ in 0..epochs {
                    p.reset_clock();
                    let mut loss_sum = 0.0;
                    // single-party pipeline: there is no remote wait to
                    // overlap, but the loop rides the same state machine
                    // so the depth knob is honored uniformly
                    run_pipeline(&plan, tcc.pipeline_depth, |step, bc| {
                        if step != Step::Submit {
                            return Ok(());
                        }
                        let b = &batches[bc.index];
                        let theta0 = params.theta0_f32();
                        let server = params.server_f32();
                        let wy = params.wy_f32();
                        let by = params.by_f32();
                        let mut inputs: Vec<TensorIn> = vec![
                            TensorIn::F32(&b.x),
                            TensorIn::F32(&b.y),
                            TensorIn::F32(&b.mask),
                            TensorIn::F32(&theta0),
                        ];
                        for s in &server {
                            inputs.push(TensorIn::F32(s));
                        }
                        inputs.push(TensorIn::F32(&wy));
                        inputs.push(TensorIn::F32(&by));
                        let outs = engine.execute(&art, &inputs)?;
                        loss_sum += outs[0].scalar()?;
                        let g_theta0 = outs[2].clone().f32()?;
                        up.step_mat_f32(&mut params.theta0, &g_theta0);
                        let ns = params.server.len();
                        for i in 0..ns {
                            let g = outs[3 + i].clone().f32()?;
                            up.step_mat_f32(&mut params.server[i], &g);
                        }
                        let g_wy = outs[3 + ns].clone().f32()?;
                        let g_by = outs[4 + ns].clone().f32()?;
                        up.step_mat_f32(&mut params.wy, &g_wy);
                        up.step_mat_f32(&mut params.by, &g_by);
                        up.tick();
                        Ok(())
                    })?;
                    times.push(p.now());
                    parties::report_epoch(&mut p, loss_sum / batches.len() as f64)?;
                }
                parties::await_stop(&mut p)?;
                // evaluate inside the party (owns the params)
                let (auc, test_loss) = evaluate(&mut engine, &cfgc, &params, &test_c)?;
                Ok(PartyOut {
                    sim_time: p.now(),
                    epoch_times: times,
                    epoch_losses: vec![auc, test_loss],
                    weight_digest: params.digest(),
                    ..Default::default()
                })
            }),
        ];
        let (outs, stats) = run_parties(&["coord", "server"], spec, fns)?;
        epoch_losses.extend(outs[0].epoch_losses.clone());
        epoch_times.extend(outs[1].epoch_times.clone());
        let auc = outs[1].epoch_losses[0];
        let test_loss = outs[1].epoch_losses[1];

        Ok(TrainReport {
            protocol: self.name().into(),
            dataset: cfg.name.into(),
            auc,
            train_losses: epoch_losses,
            test_losses: vec![test_loss],
            epoch_times,
            online_bytes: stats.bytes_phase(crate::netsim::Phase::Online),
            offline_bytes: 0,
            stages: stats.stage_rows(),
            weight_digest: outs[1].weight_digest,
            wall_seconds: wall.elapsed().as_secs_f64(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FRAUD;
    use crate::data::{synth_fraud, SynthOpts};

    #[test]
    fn nn_trains_and_loss_decreases() {
        if !crate::runtime::default_artifact_dir().join("manifest.txt").exists() {
            return;
        }
        let ds = synth_fraud(SynthOpts::small(2000));
        let (train, test) = ds.split(0.8, 1);
        let tc = TrainConfig {
            batch: 256,
            epochs: 3,
            lr_override: Some(0.05),
            ..Default::default()
        };
        let rep = PlainNn
            .train(&FRAUD, &tc, LinkSpec::lan(), &train, &test, 1)
            .unwrap();
        assert_eq!(rep.train_losses.len(), 3);
        assert!(
            rep.train_losses[2] < rep.train_losses[0],
            "{:?}",
            rep.train_losses
        );
        assert!(rep.auc > 0.6, "AUC {}", rep.auc);
    }
}
