//! Plaintext NN baseline: the whole network trained centrally on the
//! concatenated data — no privacy, fastest, the accuracy ceiling of
//! Table 1 and the time floor of Table 3.
//!
//! Single "server" party; the only traffic is the coordinator handshake.
//! Uses the monolithic `nn_train` AOT graph (the same math the split
//! pipeline distributes across parties — `python/tests/test_model.py`
//! proves the two compose identically).

use super::common::{evaluate, run_pipeline, ModelParams, Step, TrainReport, Updater};
use super::Trainer;
use crate::config::{ModelConfig, TrainConfig};
use crate::data::Dataset;
use crate::parties::{self, Deployment, NetSummary, PartyFn, PartyOut};
use crate::runtime::{Engine, TensorIn};
use crate::transport::Channel;
use crate::{Error, Result};

pub struct PlainNn;

impl Trainer for PlainNn {
    fn name(&self) -> &'static str {
        "NN"
    }

    fn deployment(
        &self,
        cfg: &ModelConfig,
        tc: &TrainConfig,
        train: &Dataset,
        test: &Dataset,
        _n_holders: usize,
    ) -> Result<Deployment> {
        let mut params = ModelParams::init(cfg, tc.seed);
        let cap = ModelConfig::pick_batch(tc.batch);
        let batches = train.batches(tc.batch, cap);
        // plan derived FROM the batches so the two can never disagree
        let plan: Vec<(usize, usize)> = {
            let mut start = 0usize;
            batches
                .iter()
                .map(|b| {
                    let e = (start, b.rows);
                    start += b.rows;
                    e
                })
                .collect()
        };
        let cfgc = cfg.clone();
        let tcc = tc.clone();
        let tcc2 = tc.clone();

        // run as a 2-party deployment (coordinator + server) so the control
        // flow matches the decentralized protocols
        let test_c = test.clone();
        let fns: Vec<PartyFn> = vec![
            Box::new(move |p: &mut dyn Channel| {
                parties::coordinator_run(p, &[1], 1, tcc2.epochs)
            }),
            Box::new(move |p: &mut dyn Channel| {
                let epochs = parties::await_start(p)?;
                let mut engine = Engine::load_default()?;
                let mut up = Updater::new(&tcc, &cfgc, tcc.seed);
                let art = cfgc.artifact("nn_train", cap);
                let mut times = Vec::new();
                for _ in 0..epochs {
                    p.reset_clock();
                    let mut loss_sum = 0.0;
                    // single-party pipeline: there is no remote wait to
                    // overlap, but the loop rides the same state machine
                    // so the depth knob is honored uniformly
                    run_pipeline(&plan, tcc.pipeline_depth, |step, bc| {
                        if step != Step::Submit {
                            return Ok(());
                        }
                        let b = &batches[bc.index];
                        let theta0 = params.theta0_f32();
                        let server = params.server_f32();
                        let wy = params.wy_f32();
                        let by = params.by_f32();
                        let mut inputs: Vec<TensorIn> = vec![
                            TensorIn::F32(&b.x),
                            TensorIn::F32(&b.y),
                            TensorIn::F32(&b.mask),
                            TensorIn::F32(&theta0),
                        ];
                        for s in &server {
                            inputs.push(TensorIn::F32(s));
                        }
                        inputs.push(TensorIn::F32(&wy));
                        inputs.push(TensorIn::F32(&by));
                        let outs = engine.execute(&art, &inputs)?;
                        loss_sum += outs[0].scalar()?;
                        let g_theta0 = outs[2].clone().f32()?;
                        up.step_mat_f32(&mut params.theta0, &g_theta0);
                        let ns = params.server.len();
                        for i in 0..ns {
                            let g = outs[3 + i].clone().f32()?;
                            up.step_mat_f32(&mut params.server[i], &g);
                        }
                        let g_wy = outs[3 + ns].clone().f32()?;
                        let g_by = outs[4 + ns].clone().f32()?;
                        up.step_mat_f32(&mut params.wy, &g_wy);
                        up.step_mat_f32(&mut params.by, &g_by);
                        up.tick();
                        Ok(())
                    })?;
                    times.push(p.now());
                    parties::report_epoch(p, loss_sum / batches.len() as f64)?;
                }
                parties::await_stop(p)?;
                // evaluate inside the party (owns the params)
                let (auc, test_loss) = evaluate(&mut engine, &cfgc, &params, &test_c)?;
                Ok(PartyOut {
                    sim_time: p.now(),
                    epoch_times: times,
                    metrics: vec![("auc".into(), auc), ("test_loss".into(), test_loss)],
                    weight_digest: params.digest(),
                    ..Default::default()
                })
            }),
        ];
        Ok(Deployment { names: vec!["coord".into(), "server".into()], fns })
    }

    fn finish(
        &self,
        cfg: &ModelConfig,
        _tc: &TrainConfig,
        _test: &Dataset,
        outs: &[PartyOut],
        net: NetSummary,
        wall_seconds: f64,
    ) -> Result<TrainReport> {
        let auc = outs[1]
            .metric("auc")
            .ok_or_else(|| Error::Protocol("server: missing auc metric".into()))?;
        let test_loss = outs[1]
            .metric("test_loss")
            .ok_or_else(|| Error::Protocol("server: missing test_loss metric".into()))?;
        Ok(TrainReport {
            protocol: self.name().into(),
            dataset: cfg.name.into(),
            auc,
            train_losses: outs[0].epoch_losses.clone(),
            test_losses: vec![test_loss],
            epoch_times: outs[1].epoch_times.clone(),
            online_bytes: net.online_bytes,
            offline_bytes: 0,
            stages: net.stages,
            weight_digest: outs[1].weight_digest,
            params: Vec::new(),
            wall_seconds,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FRAUD;
    use crate::data::{synth_fraud, SynthOpts};
    use crate::netsim::LinkSpec;

    #[test]
    fn nn_trains_and_loss_decreases() {
        if !crate::runtime::default_artifact_dir().join("manifest.txt").exists() {
            return;
        }
        let ds = synth_fraud(SynthOpts::small(2000));
        let (train, test) = ds.split(0.8, 1);
        let tc = TrainConfig {
            batch: 256,
            epochs: 3,
            lr_override: Some(0.05),
            ..Default::default()
        };
        let rep = PlainNn
            .train(&FRAUD, &tc, LinkSpec::lan(), &train, &test, 1)
            .unwrap();
        assert_eq!(rep.train_losses.len(), 3);
        assert!(
            rep.train_losses[2] < rep.train_losses[0],
            "{:?}",
            rep.train_losses
        );
        assert!(rep.auc > 0.6, "AUC {}", rep.auc);
    }
}
