//! Shared protocol machinery: parameter containers, updates, evaluation.

use crate::config::{ModelConfig, TrainConfig};
use crate::data::{auc, Dataset};
use crate::nn::{Optimizer, Sgd, Sgld};
use crate::runtime::{Engine, TensorIn};
use crate::rng::Pcg64;
use crate::nn::MatF64;
use crate::Result;

/// All model parameters, in f64 master copies (updates) with f32 views
/// generated per artifact call.
///
/// Layout matches the artifact argument order:
/// `theta0 (D x H)`, then server `(W, b)` pairs, then `(wy, by)`.
#[derive(Clone, Debug)]
pub struct ModelParams {
    pub theta0: MatF64,
    /// Interleaved server weights and biases: `[W1, b1, W2, b2, ...]`
    /// (biases stored as 1 x n matrices).
    pub server: Vec<MatF64>,
    pub wy: MatF64,
    pub by: MatF64,
}

impl ModelParams {
    /// Paper-style initialization (Xavier weights, zero biases).
    pub fn init(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Pcg64::seed_from_u64(seed);
        let theta0 = MatF64::xavier(&mut rng, cfg.n_features, cfg.h1_dim);
        let mut server = Vec::new();
        let mut dims = vec![cfg.h1_dim];
        dims.extend_from_slice(cfg.server_dims);
        for win in dims.windows(2) {
            server.push(MatF64::xavier(&mut rng, win[0], win[1]));
            server.push(MatF64::zeros(1, win[1]));
        }
        let wy = MatF64::xavier(&mut rng, cfg.hl_dim(), 1);
        let by = MatF64::zeros(1, 1);
        ModelParams { theta0, server, wy, by }
    }

    /// f32 copies of the server parameters (artifact inputs).
    pub fn server_f32(&self) -> Vec<Vec<f32>> {
        self.server.iter().map(|m| m.to_f32()).collect()
    }

    pub fn wy_f32(&self) -> Vec<f32> {
        self.wy.to_f32()
    }

    pub fn by_f32(&self) -> Vec<f32> {
        self.by.to_f32()
    }

    pub fn theta0_f32(&self) -> Vec<f32> {
        self.theta0.to_f32()
    }
}

/// Per-party update rule: SGD or SGLD with the paper's schedule.
pub enum Updater {
    Sgd(Sgd),
    Sgld(Sgld),
}

impl Updater {
    pub fn new(tc: &TrainConfig, cfg: &ModelConfig, seed: u64) -> Self {
        let lr = tc.lr_override.unwrap_or(cfg.lr);
        if tc.sgld {
            // SGLD uses alpha = 2*lr so its drift term alpha/2 matches SGD.
            // The textbook noise std sqrt(alpha_t) is calibrated for lr ~1e-3
            // (the paper's setting); at our larger experiment lr it destroys
            // utility, so the noise is tempered to keep the same
            // noise-to-signal ratio the paper's configuration has.
            let mut o = Sgld::new(2.0 * lr, seed);
            o.noise_scale = tc
                .sgld_noise
                .unwrap_or_else(|| (0.002 / (2.0 * lr)).sqrt().min(1.0));
            Updater::Sgld(o)
        } else {
            Updater::Sgd(Sgd::new(lr))
        }
    }

    pub fn step(&mut self, params: &mut [f64], grads: &[f64]) {
        match self {
            Updater::Sgd(o) => o.step(params, grads),
            Updater::Sgld(o) => o.step(params, grads),
        }
    }

    /// Advance SGLD's schedule (no-op for SGD). Call once per iteration.
    pub fn tick(&mut self) {
        if let Updater::Sgld(o) = self {
            o.tick();
        }
    }

    /// Apply to a matrix given an f32 gradient slice.
    pub fn step_mat_f32(&mut self, m: &mut MatF64, g: &[f32]) {
        let g64: Vec<f64> = g.iter().map(|&v| v as f64).collect();
        self.step(&mut m.data, &g64);
    }
}

/// Evaluate test AUC (and mean loss) by running the plaintext pipeline
/// through the AOT artifacts — the same graphs training used.
pub fn evaluate(
    engine: &mut Engine,
    cfg: &ModelConfig,
    params: &ModelParams,
    test: &Dataset,
) -> Result<(f64, f64)> {
    let cap = crate::config::ModelConfig::pick_batch(test.len().min(5000));
    let server_f32 = params.server_f32();
    let wy = params.wy_f32();
    let by = params.by_f32();
    let mut scores: Vec<f32> = Vec::with_capacity(test.len());
    let mut losses = Vec::new();
    for batch in test.batches(cap, cap) {
        // h1 = X @ theta0 (plaintext eval path)
        let x = MatF64::from_f32(batch.cap, cfg.n_features, &batch.x);
        let h1 = x.matmul(&params.theta0).to_f32();
        let mut inputs: Vec<TensorIn> = vec![TensorIn::F32(&h1)];
        for s in &server_f32 {
            inputs.push(TensorIn::F32(s));
        }
        let hl = engine
            .execute(&cfg.artifact("server_fwd", cap), &inputs)?
            .remove(0)
            .f32()?;
        let outs = engine.execute(
            &cfg.artifact("label_grad", cap),
            &[
                TensorIn::F32(&hl),
                TensorIn::F32(&batch.y),
                TensorIn::F32(&batch.mask),
                TensorIn::F32(&wy),
                TensorIn::F32(&by),
            ],
        )?;
        let p = outs[0].clone().f32()?;
        losses.push(outs[1].scalar()?);
        scores.extend_from_slice(&p[..batch.rows]);
    }
    let a = auc(&scores, &test.y);
    let mean_loss = losses.iter().sum::<f64>() / losses.len().max(1) as f64;
    Ok((a, mean_loss))
}

/// Final output of one protocol training run.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub protocol: String,
    pub dataset: String,
    /// Test AUC after training.
    pub auc: f64,
    /// Per-epoch mean training loss.
    pub train_losses: Vec<f64>,
    /// Per-epoch test loss (protocols that track it).
    pub test_losses: Vec<f64>,
    /// Simulated online seconds per epoch (network + compute).
    pub epoch_times: Vec<f64>,
    /// Online / offline traffic (bytes, whole run).
    pub online_bytes: usize,
    pub offline_bytes: usize,
    /// Wall-clock seconds for the whole run (this harness, not the paper's).
    pub wall_seconds: f64,
}

impl TrainReport {
    /// Mean simulated epoch time (the Table 3 / Fig 8 statistic).
    pub fn mean_epoch_time(&self) -> f64 {
        if self.epoch_times.is_empty() {
            return 0.0;
        }
        self.epoch_times.iter().sum::<f64>() / self.epoch_times.len() as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "{} on {}: AUC {:.4}, epoch {:.2}s (sim), online {:.1} MB, offline {:.1} MB",
            self.protocol,
            self.dataset,
            self.auc,
            self.mean_epoch_time(),
            self.online_bytes as f64 / 1e6,
            self.offline_bytes as f64 / 1e6
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FRAUD;

    #[test]
    fn params_shapes() {
        let p = ModelParams::init(&FRAUD, 1);
        assert_eq!(p.theta0.shape(), (28, 8));
        assert_eq!(p.server.len(), 2);
        assert_eq!(p.server[0].shape(), (8, 8));
        assert_eq!(p.server[1].shape(), (1, 8));
        assert_eq!(p.wy.shape(), (8, 1));
    }

    #[test]
    fn updater_sgld_matches_paper_drift() {
        // with alpha = 2*lr the SGLD drift equals the SGD step in expectation
        let cfg = &FRAUD;
        let tc = TrainConfig { sgld: true, ..Default::default() };
        let mut up = Updater::new(&tc, cfg, 1);
        if let Updater::Sgld(ref mut o) = up {
            o.noise_scale = 0.0;
            let mut p = vec![1.0];
            o.step(&mut p, &[1.0]);
            assert!((p[0] - (1.0 - cfg.lr)).abs() < 1e-12);
        } else {
            panic!("expected sgld");
        }
    }

    #[test]
    fn evaluate_runs_on_artifacts() {
        let dir = crate::runtime::default_artifact_dir();
        if !dir.join("manifest.txt").exists() {
            return;
        }
        let mut eng = Engine::load(&dir).unwrap();
        let ds = crate::data::synth_fraud(crate::data::SynthOpts::small(600));
        let params = ModelParams::init(&FRAUD, 2);
        let (auc, loss) = evaluate(&mut eng, &FRAUD, &params, &ds).unwrap();
        assert!((0.0..=1.0).contains(&auc));
        assert!(loss.is_finite() && loss > 0.0);
    }
}
