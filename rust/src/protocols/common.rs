//! Shared protocol machinery: parameter containers, updates, evaluation,
//! and the **pipelined session framework** every trainer's party loop runs
//! on ([`run_pipeline`] / [`run_epochs`]).
//!
//! # Pipelined batch-stage state machine
//!
//! SGD's weight update makes each mini-batch *value-dependent* on the
//! previous one, so the tensor math cannot reorder. What can run ahead is
//! everything **value-independent**: Paillier nonce exponentiations,
//! dealer triples / boolean bundles, secret-share masks, fixed-point input
//! encodes. [`run_pipeline`] splits a party's per-batch work into three
//! [`Step`]s and drives up to `pipeline_depth` batches of
//! [`Step::Prefetch`] work ahead of demand, placing it inside the window
//! where the party would otherwise idle-wait on remote results
//! ([`Step::Submit`] has been sent, [`Step::Complete`] not yet received).
//! The netsim virtual clock then absorbs the prefetch wall time into the
//! wait (overlap credit) instead of the critical path.
//!
//! Prefetch runs in schedule order at every depth, so all RNG draws stay
//! in schedule order and the trained weights are **bit-identical at any
//! depth** (asserted by the transcript-equality tests via
//! [`TrainReport::weight_digest`]).
//!
//! # Bounded-staleness mode (`TrainConfig::staleness` > 0)
//!
//! Lock-step saturates once the prefetch window covers the crypto
//! lookahead: [`Step::Complete`] — the weight update — still serializes
//! every batch behind a full network round-trip, and the window drains at
//! every epoch boundary. [`run_epochs`] generalizes the machine with a
//! **deferred-update queue**: a batch's `Complete` may run up to `lag_t`
//! submits late, where `lag_t ∈ [0, staleness]` is drawn per batch from
//! the seed-derived [`staleness_lags`] schedule. Value-*dependent* work
//! (matmuls, HE forward hops, triple consumption) of up to `staleness + 1`
//! batches then overlaps, and the prefetch window flows straight across
//! epoch boundaries instead of draining.
//!
//! The contract of the deferred-update queue:
//!
//! - `Submit`s run in batch order; `Complete`s run in batch order (FIFO —
//!   updates are never applied out of order);
//! - the queue head `t` pops right before `Submit(t + lag_t + 1)`; a
//!   batch queued behind a larger-lag head pops with it, so for every
//!   batch `Complete(t)` runs before `Submit(t + staleness + 1)` — no
//!   weight update is ever applied more than `staleness` batches late;
//! - every party derives the identical `lag` schedule from `(seed,
//!   staleness)` alone, so all parties interleave their sends/receives at
//!   the same schedule positions (deadlock-free) and the *async*
//!   transcript is itself digest-pinned across netsim/TCP/UDS, pipeline
//!   depths, thread counts and process layouts;
//! - `staleness = 0` routes through the exact per-epoch lock-step loop —
//!   byte-identical to the seed schedule, tags and all.
//!
//! The party loops talk through the [`Channel`](crate::transport::Channel)
//! abstraction, so the same per-batch schedule runs unchanged on the
//! netsim simulator, over loopback TCP, or split across OS processes
//! (`spnn launch`) — and the digest is bit-identical across all of them
//! (the `*_transports_are_transcript_equal` tests).

use crate::config::{ModelConfig, TrainConfig};
use crate::data::{auc, Dataset};
use crate::netsim::StageRow;
use crate::nn::{Optimizer, Sgd, Sgld};
use crate::runtime::{Engine, TensorIn};
use crate::rng::Pcg64;
use crate::nn::MatF64;
use crate::Result;

/// Scheduler step of the pipelined session (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Step {
    /// Value-independent lookahead work for a batch (RNG draws in schedule
    /// order): nonce refills, dealer requests, share masks, input encodes.
    Prefetch,
    /// Critical-path work for a batch up to its last send.
    Submit,
    /// Blocking receives of remote results for a batch + state updates.
    Complete,
}

/// One mini-batch in flight through the pipelined session.
#[derive(Clone, Copy, Debug)]
pub struct BatchCtx {
    /// Batch index. Within an epoch for the lock-step path; global
    /// (monotone across epochs) for the bounded-staleness path, where
    /// batches from adjacent epochs are concurrently in flight.
    pub index: usize,
    /// Epoch this batch belongs to (always 0 on the legacy
    /// [`run_pipeline`] path, which is driven once per epoch).
    pub epoch: usize,
    /// First row of the batch in the training set.
    pub start: usize,
    /// Rows in this batch (the last batch may be partial).
    pub rows: usize,
    /// Message tag for this batch's traffic. Equal to `index` on the
    /// lock-step path (the seed wire format); globally unique on the
    /// staleness path so concurrent adjacent-epoch batches never collide.
    pub tag: u64,
}

impl BatchCtx {
    /// Lock-step construction: epoch 0, tag = index (the seed schedule).
    pub fn new(index: usize, start: usize, rows: usize) -> Self {
        BatchCtx { index, epoch: 0, start, rows, tag: index as u64 }
    }

    /// Message tag for this batch's traffic.
    pub fn tag(&self) -> u64 {
        self.tag
    }
}

/// Batch boundaries shared by every party (deterministic schedule): the
/// row stream `0..n` cut into `(start, rows)` pieces of at most `batch`
/// rows. Handles ragged tails uniformly — when `n % batch != 0` the final
/// entry simply carries the remainder (never an empty or oversized batch),
/// so both the train loops and the serve runtime's request coalescing
/// (`crate::serve`) run the same plan for any `n`.
pub fn batch_plan(n: usize, batch: usize) -> Vec<(usize, usize)> {
    let batch = batch.max(1);
    let mut out = Vec::new();
    let mut s = 0;
    while s < n {
        let rows = batch.min(n - s);
        out.push((s, rows));
        s += rows;
    }
    out
}

/// Drive one party's per-epoch batch loop with up to `depth` mini-batches
/// in flight.
///
/// For every batch `t` (in order): any outstanding `Prefetch` up to `t`
/// runs first (demand), then `Submit(t)`, then `Prefetch` for batches up
/// to `t + depth - 1` (the overlap window), then `Complete(t)`. Depth 1
/// reproduces the strict lock-step schedule: `Prefetch(t)` immediately
/// followed by `Submit(t)`, `Complete(t)`.
pub fn run_pipeline<F>(plan: &[(usize, usize)], depth: usize, mut step: F) -> Result<()>
where
    F: FnMut(Step, &BatchCtx) -> Result<()>,
{
    let ctx = |i: usize| BatchCtx::new(i, plan[i].0, plan[i].1);
    drive_lockstep(plan.len(), depth, &ctx, &mut step)
}

/// The lock-step schedule body shared by [`run_pipeline`] and the
/// `staleness = 0` path of [`run_epochs`]: identical event order, timers
/// and gauge in both, so `S=0` stays byte-identical to the seed.
fn drive_lockstep<F>(
    n: usize,
    depth: usize,
    ctx: &dyn Fn(usize) -> BatchCtx,
    step: &mut F,
) -> Result<()>
where
    F: FnMut(Step, &BatchCtx) -> Result<()>,
{
    let depth = depth.max(1);
    // wall-clock step timers + in-flight gauge; inert when obs is disabled
    let t_pre = crate::obs::timer("pipeline_prefetch_seconds");
    let t_sub = crate::obs::timer("pipeline_submit_seconds");
    let t_com = crate::obs::timer("pipeline_complete_seconds");
    let mut pre = 0usize;
    for t in 0..n {
        while pre <= t {
            t_pre.observe(|| step(Step::Prefetch, &ctx(pre)))?;
            pre += 1;
        }
        t_sub.observe(|| step(Step::Submit, &ctx(t)))?;
        while pre < n && pre < t + depth {
            t_pre.observe(|| step(Step::Prefetch, &ctx(pre)))?;
            pre += 1;
        }
        // batches prefetched beyond the one now completing = pipeline occupancy
        crate::obs::gauge_set("pipeline_inflight", (pre - t) as f64);
        t_com.observe(|| step(Step::Complete, &ctx(t)))?;
    }
    Ok(())
}

/// Per-batch staleness lags for a whole run: `out[g] ∈ [0, staleness]` is
/// how many later submits batch `g`'s `Complete` (weight update) may run
/// behind. Pure function of `(n, staleness, seed)` — every party computes
/// the identical schedule locally (no coordination round), which is what
/// keeps the async interleave deadlock-free and digest-pinned across
/// transports, depths and thread counts. `staleness = 0` is all-zeros.
pub fn staleness_lags(n: usize, staleness: usize, seed: u64) -> Vec<usize> {
    if staleness == 0 {
        return vec![0; n];
    }
    // splitmix64 stream keyed by FNV of (domain tag, seed, staleness)
    let mut f = Fnv::new();
    f.add_bytes(b"spnn-staleness-schedule v1");
    f.add_u64(seed);
    f.add_u64(staleness as u64);
    let mut state = f.0;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        out.push((z % (staleness as u64 + 1)) as usize);
    }
    out
}

/// Event stream of a whole multi-epoch training run (see [`run_epochs`]).
/// A single enum (rather than three callbacks) so one closure can borrow
/// the party's mutable state for all of them.
pub enum Ev<'a> {
    /// An epoch is starting. On the staleness path, tail batches of the
    /// previous epoch may still be in flight (the window does not drain).
    EpochStart(usize),
    /// A scheduler step for one batch, exactly as in [`run_pipeline`].
    Step(Step, &'a BatchCtx),
    /// All batches of this epoch have completed (their updates applied).
    EpochEnd(usize),
}

/// Drive a party's full multi-epoch batch loop.
///
/// With `staleness == 0` this is exactly `epochs` back-to-back
/// [`run_pipeline`] passes with `EpochStart`/`EpochEnd` brackets — same
/// event order, same per-epoch tags, byte-identical transcript to the
/// seed. With `staleness > 0` batches get globally-unique tags and each
/// batch's `Complete` is deferred by its [`staleness_lags`] lag: the
/// deferred-update queue pops in FIFO batch order right before the first
/// `Submit` that would exceed a pending batch's lag, and the prefetch
/// window flows across epoch boundaries. `EpochEnd(e)` fires when the
/// last batch of epoch `e` completes (possibly after submits of epoch
/// `e + 1` have already run).
pub fn run_epochs<F>(
    plan: &[(usize, usize)],
    epochs: usize,
    depth: usize,
    staleness: usize,
    seed: u64,
    mut ev: F,
) -> Result<()>
where
    F: FnMut(Ev) -> Result<()>,
{
    if staleness == 0 {
        for e in 0..epochs {
            ev(Ev::EpochStart(e))?;
            let ctx = |i: usize| BatchCtx {
                index: i,
                epoch: e,
                start: plan[i].0,
                rows: plan[i].1,
                tag: i as u64,
            };
            drive_lockstep(plan.len(), depth, &ctx, &mut |st, b| ev(Ev::Step(st, b)))?;
            ev(Ev::EpochEnd(e))?;
        }
        return Ok(());
    }
    run_async(plan, epochs, depth, staleness, seed, &mut ev)
}

/// The bounded-staleness schedule (see [`run_epochs`] and module docs).
fn run_async<F>(
    plan: &[(usize, usize)],
    epochs: usize,
    depth: usize,
    staleness: usize,
    seed: u64,
    ev: &mut F,
) -> Result<()>
where
    F: FnMut(Ev) -> Result<()>,
{
    let n = plan.len();
    if n == 0 {
        for e in 0..epochs {
            ev(Ev::EpochStart(e))?;
            ev(Ev::EpochEnd(e))?;
        }
        return Ok(());
    }
    let depth = depth.max(1);
    let total = n * epochs;
    let lags = staleness_lags(total, staleness, seed);
    let ctx = |g: usize| BatchCtx {
        index: g,
        epoch: g / n,
        start: plan[g % n].0,
        rows: plan[g % n].1,
        tag: g as u64,
    };
    let t_pre = crate::obs::timer("pipeline_prefetch_seconds");
    let t_sub = crate::obs::timer("pipeline_submit_seconds");
    let t_com = crate::obs::timer("pipeline_complete_seconds");
    let mut pre = 0usize; // next batch to prefetch
    let mut oldest = 0usize; // oldest batch whose Complete is still pending
    for g in 0..total {
        if g % n == 0 {
            ev(Ev::EpochStart(g / n))?;
        }
        // Deferred-update queue: pop (in FIFO batch order) while the head's
        // lag budget would be exceeded by this Submit. A batch t' queued
        // behind a larger-lag head t stays until t pops at g = t+lag_t+1,
        // where its own effective lag is g-1-t' = t+lag_t-t' < lag_t <= S
        // (t < t'), so every update still lands within `staleness` submits.
        while oldest < g && oldest + lags[oldest] < g {
            t_com.observe(|| ev(Ev::Step(Step::Complete, &ctx(oldest))))?;
            oldest += 1;
            if oldest % n == 0 {
                ev(Ev::EpochEnd(oldest / n - 1))?;
            }
        }
        while pre <= g {
            t_pre.observe(|| ev(Ev::Step(Step::Prefetch, &ctx(pre))))?;
            pre += 1;
        }
        t_sub.observe(|| ev(Ev::Step(Step::Submit, &ctx(g))))?;
        // the prefetch window flows across epoch boundaries: no drain
        while pre < total && pre < g + depth {
            t_pre.observe(|| ev(Ev::Step(Step::Prefetch, &ctx(pre))))?;
            pre += 1;
        }
        crate::obs::gauge_set("pipeline_inflight", (g + 1 - oldest) as f64);
    }
    // drain: all remaining updates apply in order at end of run
    while oldest < total {
        t_com.observe(|| ev(Ev::Step(Step::Complete, &ctx(oldest))))?;
        oldest += 1;
        if oldest % n == 0 {
            ev(Ev::EpochEnd(oldest / n - 1))?;
        }
    }
    Ok(())
}

/// FNV-1a 64 over raw bit patterns — the transcript digest used to assert
/// bit-identical training across pipeline depths.
pub struct Fnv(pub u64);

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv {
    pub fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    pub fn add_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn add_f64s(&mut self, xs: &[f64]) {
        for &x in xs {
            self.add_bytes(&x.to_bits().to_le_bytes());
        }
    }

    pub fn add_u64(&mut self, x: u64) {
        self.add_bytes(&x.to_le_bytes());
    }
}

/// All model parameters, in f64 master copies (updates) with f32 views
/// generated per artifact call.
///
/// Layout matches the artifact argument order:
/// `theta0 (D x H)`, then server `(W, b)` pairs, then `(wy, by)`.
#[derive(Clone, Debug)]
pub struct ModelParams {
    pub theta0: MatF64,
    /// Interleaved server weights and biases: `[W1, b1, W2, b2, ...]`
    /// (biases stored as 1 x n matrices).
    pub server: Vec<MatF64>,
    pub wy: MatF64,
    pub by: MatF64,
}

impl ModelParams {
    /// Paper-style initialization (Xavier weights, zero biases).
    pub fn init(cfg: &ModelConfig, seed: u64) -> Self {
        Self::init_with_input(cfg, seed, cfg.n_features)
    }

    /// Initialization with an explicit first-layer input width — the
    /// compressed-feature runs (`TrainConfig::compress`) train
    /// `theta0: k x h1` instead of `d x h1`. With `d_in == cfg.n_features`
    /// this is exactly [`ModelParams::init`] (same RNG stream, bit-identical
    /// parameters). Every party MUST use the same `d_in`: the theta0 draw
    /// count shifts the positions of all later draws (`wy` in particular).
    pub fn init_with_input(cfg: &ModelConfig, seed: u64, d_in: usize) -> Self {
        let mut rng = Pcg64::seed_from_u64(seed);
        let theta0 = MatF64::xavier(&mut rng, d_in, cfg.h1_dim);
        let mut server = Vec::new();
        let mut dims = vec![cfg.h1_dim];
        dims.extend_from_slice(cfg.server_dims);
        for win in dims.windows(2) {
            server.push(MatF64::xavier(&mut rng, win[0], win[1]));
            server.push(MatF64::zeros(1, win[1]));
        }
        let wy = MatF64::xavier(&mut rng, cfg.hl_dim(), 1);
        let by = MatF64::zeros(1, 1);
        ModelParams { theta0, server, wy, by }
    }

    /// f32 copies of the server parameters (artifact inputs).
    pub fn server_f32(&self) -> Vec<Vec<f32>> {
        self.server.iter().map(|m| m.to_f32()).collect()
    }

    pub fn wy_f32(&self) -> Vec<f32> {
        self.wy.to_f32()
    }

    pub fn by_f32(&self) -> Vec<f32> {
        self.by.to_f32()
    }

    pub fn theta0_f32(&self) -> Vec<f32> {
        self.theta0.to_f32()
    }

    /// Bit-exact digest of every parameter (transcript-equality checks).
    pub fn digest(&self) -> u64 {
        let mut f = Fnv::new();
        f.add_f64s(&self.theta0.data);
        for m in &self.server {
            f.add_f64s(&m.data);
        }
        f.add_f64s(&self.wy.data);
        f.add_f64s(&self.by.data);
        f.0
    }
}

/// Per-party update rule: SGD or SGLD with the paper's schedule.
pub enum Updater {
    Sgd(Sgd),
    Sgld(Sgld),
}

impl Updater {
    pub fn new(tc: &TrainConfig, cfg: &ModelConfig, seed: u64) -> Self {
        let lr = tc.lr_override.unwrap_or(cfg.lr);
        if tc.sgld {
            // SGLD uses alpha = 2*lr so its drift term alpha/2 matches SGD.
            // The textbook noise std sqrt(alpha_t) is calibrated for lr ~1e-3
            // (the paper's setting); at our larger experiment lr it destroys
            // utility, so the noise is tempered to keep the same
            // noise-to-signal ratio the paper's configuration has.
            let mut o = Sgld::new(2.0 * lr, seed);
            o.noise_scale = tc
                .sgld_noise
                .unwrap_or_else(|| (0.002 / (2.0 * lr)).sqrt().min(1.0));
            Updater::Sgld(o)
        } else {
            Updater::Sgd(Sgd::new(lr))
        }
    }

    pub fn step(&mut self, params: &mut [f64], grads: &[f64]) {
        match self {
            Updater::Sgd(o) => o.step(params, grads),
            Updater::Sgld(o) => o.step(params, grads),
        }
    }

    /// Advance SGLD's schedule (no-op for SGD). Call once per iteration.
    pub fn tick(&mut self) {
        if let Updater::Sgld(o) = self {
            o.tick();
        }
    }

    /// Apply to a matrix given an f32 gradient slice.
    pub fn step_mat_f32(&mut self, m: &mut MatF64, g: &[f32]) {
        let g64: Vec<f64> = g.iter().map(|&v| v as f64).collect();
        self.step(&mut m.data, &g64);
    }
}

/// Evaluate test AUC (and mean loss) by running the plaintext pipeline
/// through the AOT artifacts — the same graphs training used.
pub fn evaluate(
    engine: &mut Engine,
    cfg: &ModelConfig,
    params: &ModelParams,
    test: &Dataset,
) -> Result<(f64, f64)> {
    let cap = crate::config::ModelConfig::pick_batch(test.len().min(5000));
    let server_f32 = params.server_f32();
    let wy = params.wy_f32();
    let by = params.by_f32();
    let mut scores: Vec<f32> = Vec::with_capacity(test.len());
    let mut losses = Vec::new();
    for batch in test.batches(cap, cap) {
        // h1 = X @ theta0 (plaintext eval path). Sized by the dataset's
        // own width, not cfg.n_features: compressed-feature runs evaluate
        // on the transformed table (k columns, theta0 is k x h1).
        let x = MatF64::from_f32(batch.cap, test.n_features, &batch.x);
        let h1 = x.matmul(&params.theta0).to_f32();
        let mut inputs: Vec<TensorIn> = vec![TensorIn::F32(&h1)];
        for s in &server_f32 {
            inputs.push(TensorIn::F32(s));
        }
        let hl = engine
            .execute(&cfg.artifact("server_fwd", cap), &inputs)?
            .remove(0)
            .f32()?;
        let outs = engine.execute(
            &cfg.artifact("label_grad", cap),
            &[
                TensorIn::F32(&hl),
                TensorIn::F32(&batch.y),
                TensorIn::F32(&batch.mask),
                TensorIn::F32(&wy),
                TensorIn::F32(&by),
            ],
        )?;
        let p = outs[0].clone().f32()?;
        losses.push(outs[1].scalar()?);
        scores.extend_from_slice(&p[..batch.rows]);
    }
    let a = auc(&scores, &test.y);
    let mean_loss = losses.iter().sum::<f64>() / losses.len().max(1) as f64;
    Ok((a, mean_loss))
}

/// Final output of one protocol training run.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub protocol: String,
    pub dataset: String,
    /// Test AUC after training.
    pub auc: f64,
    /// Per-epoch mean training loss.
    pub train_losses: Vec<f64>,
    /// Per-epoch test loss (protocols that track it).
    pub test_losses: Vec<f64>,
    /// Simulated online seconds per epoch (network + compute).
    pub epoch_times: Vec<f64>,
    /// Online / offline traffic (bytes, whole run).
    pub online_bytes: usize,
    pub offline_bytes: usize,
    /// Per-phase / per-stage traffic breakdown (where the bytes go).
    pub stages: Vec<StageRow>,
    /// Bit-exact digest of the final model weights — equal digests mean
    /// transcript-equal training (used by the pipeline-depth tests).
    pub weight_digest: u64,
    /// The assembled final parameter blocks (same naming as the parties'
    /// `PartyOut::params`), so callers can run reference forward passes on
    /// the trained weights (the serve parity tests do).
    pub params: Vec<(String, Vec<f64>)>,
    /// Wall-clock seconds for the whole run (this harness, not the paper's).
    pub wall_seconds: f64,
}

impl TrainReport {
    /// Mean simulated epoch time (the Table 3 / Fig 8 statistic).
    pub fn mean_epoch_time(&self) -> f64 {
        if self.epoch_times.is_empty() {
            return 0.0;
        }
        self.epoch_times.iter().sum::<f64>() / self.epoch_times.len() as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "{} on {}: AUC {:.4}, epoch {:.2}s (sim), online {:.1} MB, offline {:.1} MB",
            self.protocol,
            self.dataset,
            self.auc,
            self.mean_epoch_time(),
            self.online_bytes as f64 / 1e6,
            self.offline_bytes as f64 / 1e6
        )
    }
}

impl TrainReport {
    /// Look up an assembled final-parameter block by name.
    pub fn param(&self, name: &str) -> Option<&[f64]> {
        self.params.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FRAUD;
    use crate::rng::{Pcg64, Rng64};

    #[test]
    fn batch_plan_covers_everything() {
        assert_eq!(batch_plan(10, 4), vec![(0, 4), (4, 4), (8, 2)]);
        assert_eq!(batch_plan(4, 4), vec![(0, 4)]);
        assert_eq!(batch_plan(3, 10), vec![(0, 3)]);
        assert!(batch_plan(0, 4).is_empty());
        // batch 0 coerces to 1 instead of looping forever
        assert_eq!(batch_plan(2, 0), vec![(0, 1), (1, 1)]);
    }

    #[test]
    fn batch_plan_properties() {
        // property sweep: exact cover, contiguity, no empty batches, every
        // batch but the last full, expected batch count
        let mut rng = Pcg64::seed_from_u64(42);
        for _ in 0..300 {
            let n = (rng.next_u64() % 5000) as usize + 1;
            let batch = (rng.next_u64() % 600) as usize + 1;
            let plan = batch_plan(n, batch);
            let mut cursor = 0usize;
            for &(s, rows) in &plan {
                assert_eq!(s, cursor, "gap or overlap at n={n} batch={batch}");
                assert!(rows >= 1, "empty batch at n={n} batch={batch}");
                assert!(rows <= batch, "oversized batch at n={n} batch={batch}");
                cursor += rows;
            }
            assert_eq!(cursor, n, "plan does not cover n={n} batch={batch}");
            for &(_, rows) in &plan[..plan.len() - 1] {
                assert_eq!(rows, batch, "non-final partial batch n={n} batch={batch}");
            }
            assert_eq!(plan.len(), n.div_ceil(batch));
            // last batch is the remainder (or a full batch)
            let want_last = if n % batch == 0 { batch } else { n % batch };
            assert_eq!(plan.last().unwrap().1, want_last);
        }
    }

    #[test]
    fn pipeline_depth1_is_lockstep() {
        let plan = [(0usize, 4usize), (4, 4), (8, 2)];
        let mut log = Vec::new();
        run_pipeline(&plan, 1, |st, b| {
            log.push((st, b.index));
            Ok(())
        })
        .unwrap();
        use Step::*;
        assert_eq!(
            log,
            vec![
                (Prefetch, 0),
                (Submit, 0),
                (Complete, 0),
                (Prefetch, 1),
                (Submit, 1),
                (Complete, 1),
                (Prefetch, 2),
                (Submit, 2),
                (Complete, 2),
            ]
        );
        // depth 0 coerces to 1
        let mut log0 = Vec::new();
        run_pipeline(&plan, 0, |st, b| {
            log0.push((st, b.index));
            Ok(())
        })
        .unwrap();
        assert_eq!(log0, log);
    }

    #[test]
    fn pipeline_depth2_prefetches_in_the_wait_window() {
        let plan = [(0usize, 4usize), (4, 4), (8, 2)];
        let mut log = Vec::new();
        run_pipeline(&plan, 2, |st, b| {
            log.push((st, b.index));
            Ok(())
        })
        .unwrap();
        use Step::*;
        // prefetch(t+1) lands between submit(t) and complete(t)
        assert_eq!(
            log,
            vec![
                (Prefetch, 0),
                (Submit, 0),
                (Prefetch, 1),
                (Complete, 0),
                (Submit, 1),
                (Prefetch, 2),
                (Complete, 1),
                (Submit, 2),
                (Complete, 2),
            ]
        );
    }

    #[test]
    fn pipeline_large_depth_saturates_then_drains() {
        let plan = [(0usize, 2usize), (2, 2), (4, 2)];
        let mut log = Vec::new();
        run_pipeline(&plan, 10, |st, b| {
            log.push((st, b.index));
            Ok(())
        })
        .unwrap();
        use Step::*;
        assert_eq!(
            log,
            vec![
                (Prefetch, 0),
                (Submit, 0),
                (Prefetch, 1),
                (Prefetch, 2),
                (Complete, 0),
                (Submit, 1),
                (Complete, 1),
                (Submit, 2),
                (Complete, 2),
            ]
        );
        // invariants at any depth: per-batch step order, prefetch in order
        for d in 1..6 {
            let mut seen_pre = Vec::new();
            let mut submitted = Vec::new();
            let mut completed = Vec::new();
            run_pipeline(&plan, d, |st, b| {
                match st {
                    Prefetch => seen_pre.push(b.index),
                    Submit => {
                        assert!(seen_pre.contains(&b.index), "submit before prefetch");
                        submitted.push(b.index);
                    }
                    Complete => {
                        assert_eq!(submitted.last(), Some(&b.index));
                        completed.push(b.index);
                    }
                }
                Ok(())
            })
            .unwrap();
            assert_eq!(seen_pre, vec![0, 1, 2], "depth {d}");
            assert_eq!(completed, vec![0, 1, 2], "depth {d}");
        }
    }

    #[test]
    fn staleness_schedule_is_seeded_and_bounded() {
        // pure function of (n, staleness, seed): identical on every call
        // (and hence identical across parties / exec thread counts)
        let a = staleness_lags(500, 3, 7);
        let b = staleness_lags(500, 3, 7);
        assert_eq!(a, b);
        assert!(a.iter().all(|&l| l <= 3));
        // prefixes agree: party loops sized by different epoch counts
        // still draw the same lags for shared batch positions
        assert_eq!(&staleness_lags(1000, 3, 7)[..500], &a[..]);
        // sensitive to both seed and bound
        assert_ne!(staleness_lags(500, 3, 8), a);
        assert_ne!(staleness_lags(500, 2, 7), a);
        // not degenerate: some nonzero and some zero lags in a long run
        assert!(a.iter().any(|&l| l > 0));
        assert!(a.iter().any(|&l| l == 0));
        // S=0 is the all-zeros (lock-step) schedule
        assert_eq!(staleness_lags(10, 0, 7), vec![0; 10]);
    }

    #[test]
    fn run_epochs_s0_matches_per_epoch_run_pipeline() {
        // staleness 0 must reproduce the seed's per-epoch loop event for
        // event, with per-epoch indices/tags and the right epoch labels
        let plan = [(0usize, 4usize), (4, 4), (8, 2)];
        for depth in 1..4 {
            let mut want = Vec::new();
            for e in 0..3 {
                want.push((None, e, 0, 0u64, true));
                run_pipeline(&plan, depth, |st, b| {
                    want.push((Some(st), e, b.index, b.tag(), true));
                    Ok(())
                })
                .unwrap();
                want.push((None, e, 0, 0, false));
            }
            let mut got = Vec::new();
            run_epochs(&plan, 3, depth, 0, 7, |ev| {
                match ev {
                    Ev::EpochStart(e) => got.push((None, e, 0, 0, true)),
                    Ev::Step(st, b) => {
                        assert_eq!(b.tag(), b.index as u64, "S=0 keeps per-epoch tags");
                        got.push((Some(st), b.epoch, b.index, b.tag(), true));
                    }
                    Ev::EpochEnd(e) => got.push((None, e, 0, 0, false)),
                }
                Ok(())
            })
            .unwrap();
            assert_eq!(got, want, "depth {depth}");
        }
    }

    #[test]
    fn run_epochs_async_respects_the_update_queue_contract() {
        let plan = batch_plan(37, 4);
        let n = plan.len();
        for &(staleness, depth, epochs) in
            &[(1usize, 1usize, 2usize), (2, 4, 3), (4, 2, 2), (3, 8, 1)]
        {
            let total = n * epochs;
            let lags = staleness_lags(total, staleness, 7);
            let mut prefetched = Vec::new();
            let mut submitted = Vec::new();
            let mut completed = Vec::new();
            let mut ends = Vec::new();
            run_epochs(&plan, epochs, depth, staleness, 7, |ev| {
                match ev {
                    Ev::EpochStart(_) => {}
                    Ev::Step(Step::Prefetch, b) => prefetched.push(b.index),
                    Ev::Step(Step::Submit, b) => {
                        assert!(prefetched.contains(&b.index), "submit before prefetch");
                        // the staleness bound: Complete(t) ran before
                        // Submit(t + S + 1) for every earlier batch (a
                        // batch may be held past its own lag by a
                        // larger-lag FIFO head, never past S)
                        for t in 0..b.index {
                            if t + staleness < b.index {
                                assert!(completed.contains(&t), "stale past bound S={staleness}");
                            }
                        }
                        // and the queue head itself honors its drawn lag
                        // (FIFO completes => head index == completed count)
                        let pending_head = completed.len();
                        if pending_head < b.index {
                            assert!(
                                pending_head + lags[pending_head] >= b.index,
                                "head popped late: lag schedule violated"
                            );
                        }
                        // globally-unique tags, monotone across epochs
                        assert_eq!(b.tag(), b.index as u64);
                        assert_eq!(b.epoch, b.index / n);
                        submitted.push(b.index);
                    }
                    Ev::Step(Step::Complete, b) => completed.push(b.index),
                    Ev::EpochEnd(e) => {
                        ends.push(e);
                        // an epoch ends exactly when its last update lands
                        assert_eq!(completed.len(), (e + 1) * n);
                    }
                }
                Ok(())
            })
            .unwrap();
            let all: Vec<usize> = (0..total).collect();
            assert_eq!(submitted, all, "submits in batch order");
            assert_eq!(completed, all, "updates applied FIFO");
            assert_eq!(prefetched, all, "prefetch in schedule order");
            assert_eq!(ends, (0..epochs).collect::<Vec<_>>());
        }
    }

    #[test]
    fn run_epochs_async_event_order_is_depth_invariant() {
        // at fixed S the Submit/Complete interleave is a function of the
        // lag schedule alone — pipeline depth only moves Prefetch events,
        // so trained weights stay bit-identical across depths
        let plan = batch_plan(29, 4);
        let order = |depth: usize| {
            let mut log = Vec::new();
            run_epochs(&plan, 2, depth, 2, 7, |ev| {
                if let Ev::Step(st, b) = ev {
                    if st != Step::Prefetch {
                        log.push((st, b.index));
                    }
                }
                Ok(())
            })
            .unwrap();
            log
        };
        let d1 = order(1);
        for d in 2..6 {
            assert_eq!(order(d), d1, "depth {d}");
        }
    }

    #[test]
    fn run_epochs_async_overlaps_across_epoch_boundary() {
        // with S>0 at least one Submit of epoch e+1 must land before the
        // final Complete of epoch e (the window no longer drains), and
        // some update must actually be deferred (lag realized)
        let plan = batch_plan(40, 4);
        let n = plan.len();
        let mut overlap = false;
        let mut deferred = false;
        let mut completed = 0usize;
        run_epochs(&plan, 2, 2, 2, 7, |ev| {
            match ev {
                Ev::Step(Step::Submit, b) => {
                    if b.epoch == 1 && completed < n {
                        overlap = true;
                    }
                    if b.index > completed + 1 {
                        deferred = true;
                    }
                }
                Ev::Step(Step::Complete, _) => completed += 1,
                _ => {}
            }
            Ok(())
        })
        .unwrap();
        assert!(overlap, "epoch boundary drained despite staleness");
        assert!(deferred, "no update was ever deferred at S=2");
    }

    #[test]
    fn run_epochs_empty_plan_still_brackets_epochs() {
        let mut events = Vec::new();
        run_epochs(&[], 2, 1, 3, 7, |ev| {
            match ev {
                Ev::EpochStart(e) => events.push((true, e)),
                Ev::EpochEnd(e) => events.push((false, e)),
                Ev::Step(..) => panic!("no steps for an empty plan"),
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(events, vec![(true, 0), (false, 0), (true, 1), (false, 1)]);
    }

    #[test]
    fn digest_is_stable_and_sensitive() {
        let p = ModelParams::init(&FRAUD, 3);
        let q = p.clone();
        assert_eq!(p.digest(), q.digest());
        let mut r = p.clone();
        r.theta0.data[0] += 1e-12;
        assert_ne!(p.digest(), r.digest());
        let mut f = Fnv::new();
        f.add_u64(7);
        let mut g = Fnv::new();
        g.add_u64(8);
        assert_ne!(f.0, g.0);
    }

    #[test]
    fn params_shapes() {
        let p = ModelParams::init(&FRAUD, 1);
        assert_eq!(p.theta0.shape(), (28, 8));
        assert_eq!(p.server.len(), 2);
        assert_eq!(p.server[0].shape(), (8, 8));
        assert_eq!(p.server[1].shape(), (1, 8));
        assert_eq!(p.wy.shape(), (8, 1));
    }

    #[test]
    fn init_with_input_matches_init_at_full_width() {
        // d_in == n_features must be the exact seed behavior (same RNG
        // stream, bit-identical digest) — the compress=None guarantee
        let a = ModelParams::init(&FRAUD, 9);
        let b = ModelParams::init_with_input(&FRAUD, 9, FRAUD.n_features);
        assert_eq!(a.digest(), b.digest());
        // a narrower input only changes theta0's shape (and, through the
        // shared RNG stream, downstream draw values — consistently so for
        // every party that uses the same d_in)
        let c = ModelParams::init_with_input(&FRAUD, 9, 14);
        assert_eq!(c.theta0.shape(), (14, 8));
        assert_eq!(c.server[0].shape(), (8, 8));
        assert_eq!(c.wy.shape(), (8, 1));
        let d = ModelParams::init_with_input(&FRAUD, 9, 14);
        assert_eq!(c.digest(), d.digest());
    }

    #[test]
    fn updater_sgld_matches_paper_drift() {
        // with alpha = 2*lr the SGLD drift equals the SGD step in expectation
        let cfg = &FRAUD;
        let tc = TrainConfig { sgld: true, ..Default::default() };
        let mut up = Updater::new(&tc, cfg, 1);
        if let Updater::Sgld(ref mut o) = up {
            o.noise_scale = 0.0;
            let mut p = vec![1.0];
            o.step(&mut p, &[1.0]);
            assert!((p[0] - (1.0 - cfg.lr)).abs() < 1e-12);
        } else {
            panic!("expected sgld");
        }
    }

    #[test]
    fn evaluate_runs_on_artifacts() {
        let dir = crate::runtime::default_artifact_dir();
        if !dir.join("manifest.txt").exists() {
            return;
        }
        let mut eng = Engine::load(&dir).unwrap();
        let ds = crate::data::synth_fraud(crate::data::SynthOpts::small(600));
        let params = ModelParams::init(&FRAUD, 2);
        let (auc, loss) = evaluate(&mut eng, &FRAUD, &params, &ds).unwrap();
        assert!((0.0..=1.0).contains(&auc));
        assert!(loss.is_finite() && loss > 0.0);
    }
}
