//! The protocol-agnostic **forward-pass layer**: every trainer's per-batch
//! forward computation, factored out of the train loops so the same code
//! serves predictions after training (`crate::serve`).
//!
//! # Why a separate layer
//!
//! SPNN's deployment story is *inference on isolated private features*
//! (fraud scoring): train once, then answer a stream of prediction
//! requests while every party keeps its inputs private. Before this
//! module, each protocol's forward math lived welded inside its
//! monolithic train loop; the pieces here are the exact same computations
//! — the train loops in [`super::spnn`], [`super::secureml`] and
//! [`super::splitnn`] now call them, so there is no duplicated math and
//! the trained weight digests are bit-identical to the pre-refactor code
//! (guarded by the `*_transports_are_transcript_equal` and
//! `*_depths_are_transcript_equal` tests).
//!
//! # Shape
//!
//! Each (protocol, role) pair gets a forward **state machine** owning the
//! role's long-lived forward state — keys, packing geometry, nonce pools,
//! dealer feeds, mask RNGs, engines, and the weights themselves (training
//! mutates them through the struct between batches; serving reads them):
//!
//! | protocol | holder side | server side | scoring role |
//! |---|---|---|---|
//! | SPNN-SS / SPNN-HE | [`SpnnHolderFwd`] (Alg. 2 / Alg. 3) | [`SpnnServerFwd`] | holder A via [`SpnnHeadFwd`] |
//! | SecureML | [`MlpMpcFwd`] (A/B), [`MlpExtraFwd`] (extra holders) | — (no server) | party A (opens `p`) |
//! | SplitNN | [`SplitHolderFwd`] | [`SplitServerFwd`] | the server (owns the head) |
//!
//! The [`ForwardPass`] trait is the uniform surface the serve runtime
//! drives (`prefetch` / `forward` mirror the train pipeline's
//! value-independent vs critical-path split); its impls delegate to the
//! same inherent methods the train loops call.
//!
//! Batch inputs come from a [`FeatureSource`]: contiguous mini-batch
//! slices of the training matrix while training, gathered request rows of
//! the held-out table while serving — the math downstream is identical.

use std::collections::{HashMap, VecDeque};

use super::common::{BatchCtx, ModelParams, TrainReport};
use crate::config::{Act, ModelConfig, TrainConfig};
use crate::data::{CompressPlan, Dataset, FeatureTransform, VerticalSplit};
use crate::exec::{self, ExecPool};
use crate::netsim::Payload;
use crate::nn::MatF64;
use crate::paillier::pack::{self, Packing};
use crate::paillier::{NoncePool, PublicKey, SecretKey};
use crate::parties::ids;
use crate::rng::ChaChaRng;
use crate::runtime::{Engine, TensorIn};
use crate::smpc::dealer::{self, DealerFeed, Material, Req};
use crate::smpc::matmul::{beaver_mul_elem, native_mm, ElemTriple};
use crate::smpc::{
    beaver_matmul, share2_from_mask, trunc_share_mat, MatTriple, RingMat,
};
use crate::transport::Channel;
use crate::{Error, Result};

// ---------------------------------------------------------------------------
// The protocol-agnostic surface
// ---------------------------------------------------------------------------

/// One role's slice of a protocol forward pass, drivable batch-by-batch.
///
/// Training calls the impls' inherent methods (which return the richer
/// per-role products the backward pass needs); the serve runtime drives
/// this uniform surface. Both paths execute the identical math.
pub trait ForwardPass {
    /// Role label for diagnostics.
    fn role(&self) -> &'static str;

    /// Stage the gathered request rows for an announced batch (holders
    /// resolve them against their private feature tables; roles without
    /// private features ignore them).
    fn stage_rows(&mut self, _index: u64, _ids: &[u32]) {}

    /// Value-independent lookahead work for batch `b` — the `Prefetch`
    /// stage of the train pipeline, reused verbatim while serving
    /// (Paillier nonce exponentiations, dealer requests, share masks,
    /// input encodes).
    fn prefetch(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<()>;

    /// Run this role's critical-path forward for batch `b`. The scoring
    /// role returns the per-row probabilities; every other role returns
    /// `None` after playing its part.
    fn forward(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<Option<Vec<f32>>>;
}

// ---------------------------------------------------------------------------
// Feature sources
// ---------------------------------------------------------------------------

/// How a [`FeatureSource`] selects rows for a [`BatchCtx`].
///
/// Both variants hold the party's **private vertical slice** (row-major,
/// `d` columns); they differ only in how a batch picks its rows.
enum SourceRows {
    /// Contiguous mini-batches of the training matrix: batch `b` covers
    /// rows `b.start .. b.start + b.rows` (the train loops).
    Slice {
        /// The slice data, row-major.
        x: Vec<f32>,
        /// Columns per row.
        d: usize,
    },
    /// Gathered request rows keyed by batch index (the serve runtime):
    /// [`FeatureSource::stage`] parks each announced batch's row ids, and
    /// the first [`FeatureSource::block`] call for that batch gathers
    /// them.
    Gather {
        /// The full held-out table slice, row-major.
        x: Vec<f32>,
        /// Columns per row.
        d: usize,
        /// Announced-but-unconsumed row ids per batch index.
        staged: HashMap<u64, Vec<u32>>,
    },
}

/// Where a holder's per-batch feature block comes from, plus the optional
/// holder-side **feature transform** (seeded orthogonal projection,
/// `d → k` columns) applied to every block before any crypto touches it.
/// With a transform attached, [`FeatureSource::width`] reports the
/// *compressed* width `k` — downstream share/ciphertext sizing follows
/// automatically.
pub struct FeatureSource {
    rows: SourceRows,
    tf: Option<FeatureTransform>,
}

impl FeatureSource {
    /// Training source: contiguous mini-batches of `x`.
    pub fn slice(x: Vec<f32>, d: usize) -> Self {
        FeatureSource { rows: SourceRows::Slice { x, d }, tf: None }
    }

    /// Serving source: per-batch gathered rows of `x`.
    pub fn gather(x: Vec<f32>, d: usize) -> Self {
        FeatureSource {
            rows: SourceRows::Gather { x, d, staged: HashMap::new() },
            tf: None,
        }
    }

    /// Attach (or clear) the holder's feature transform. The transform's
    /// input width must match the raw column count.
    pub fn with_transform(mut self, tf: Option<FeatureTransform>) -> Self {
        if let Some(t) = &tf {
            debug_assert_eq!(t.d, self.raw_width(), "transform input width");
        }
        self.tf = tf;
        self
    }

    /// Raw (pre-transform) columns per row of the backing table.
    pub fn raw_width(&self) -> usize {
        match &self.rows {
            SourceRows::Slice { d, .. } | SourceRows::Gather { d, .. } => *d,
        }
    }

    /// Columns per emitted block: the transform's `k` when one is
    /// attached, the raw width otherwise.
    pub fn width(&self) -> usize {
        match &self.tf {
            Some(t) => t.k,
            None => self.raw_width(),
        }
    }

    /// Park the row ids of an announced batch (gather mode; no-op for
    /// slice mode).
    pub fn stage(&mut self, index: u64, ids: &[u32]) {
        if let SourceRows::Gather { staged, .. } = &mut self.rows {
            staged.insert(index, ids.to_vec());
        }
    }

    /// The feature block for batch `b` (consumed once per batch), with
    /// the transform (if any) already applied — `b.rows x width()`.
    pub fn block(&mut self, b: &BatchCtx) -> Result<MatF64> {
        let raw = match &mut self.rows {
            SourceRows::Slice { x, d } => {
                let (s, rows) = (b.start, b.rows);
                if (s + rows) * *d > x.len() {
                    return Err(Error::Protocol(format!(
                        "feature source: batch rows {s}..{} beyond the table",
                        s + rows
                    )));
                }
                MatF64::from_f32(rows, *d, &x[s * *d..(s + rows) * *d])
            }
            SourceRows::Gather { x, d, staged } => {
                let ids = staged.remove(&(b.index as u64)).ok_or_else(|| {
                    Error::Protocol(format!(
                        "feature source: batch {} has no staged rows",
                        b.index
                    ))
                })?;
                if ids.len() != b.rows {
                    return Err(Error::Protocol(format!(
                        "feature source: staged {} row(s) for a {}-row batch",
                        ids.len(),
                        b.rows
                    )));
                }
                let n = x.len() / *d;
                let mut out = Vec::with_capacity(ids.len() * *d);
                for &id in &ids {
                    let id = id as usize;
                    if id >= n {
                        return Err(Error::Protocol(format!(
                            "feature source: row {id} out of range (table has {n} rows)"
                        )));
                    }
                    out.extend_from_slice(&x[id * *d..(id + 1) * *d]);
                }
                MatF64::from_f32(b.rows, *d, &out)
            }
        };
        Ok(match &self.tf {
            Some(t) => t.apply(&raw),
            None => raw,
        })
    }
}

// ---------------------------------------------------------------------------
// SPNN holder (Algorithms 2 and 3)
// ---------------------------------------------------------------------------

/// Value-independent SS material staged by the `Prefetch` step: the encoded
/// feature block and the pre-drawn share masks (drawn in schedule order, so
/// the RNG transcript is depth-invariant).
struct SsPre {
    xblk: MatF64,
    x_ring: RingMat,
    r_x: RingMat,
    r_t: RingMat,
}

/// Variant-specific holder state.
enum HolderMode {
    /// Algorithm 3: Paillier chain (packed + pool-parallel).
    He { pk: PublicKey, pool: NoncePool, packing: Packing },
    /// Algorithm 2: arithmetic sharing + one Beaver matmul on A/B.
    Ss {
        pre: VecDeque<SsPre>,
        /// A-side opportunistic dealer feed (triples expand inside the
        /// prefetch window — the SecureML `DealerFeed` pattern extended
        /// to SPNN-SS's A role).
        feed: Option<DealerFeed>,
        /// Ring-matmul engine (compute holders A and B only).
        engine: Option<Engine>,
        ring_art: String,
    },
}

/// Holder `j`'s private-feature forward (paper §4.3): jointly compute
/// `h1 = X·theta0` without revealing `X` or `theta0`, via SS (Algorithm 2)
/// or HE (Algorithm 3). Owns this holder's `theta` block — training's
/// backward pass updates it in place between batches.
pub struct SpnnHolderFwd {
    /// Holder index (0 = A, the label holder).
    pub j: usize,
    /// Where per-batch feature blocks come from (swapped to a gather
    /// source over the held-out table when serving starts).
    pub src: FeatureSource,
    /// This holder's rows of `theta0` (trained in place).
    pub theta: MatF64,
    n_holders: usize,
    split: VerticalSplit,
    h: usize,
    total_d: usize,
    rng: ChaChaRng,
    exec: ExecPool,
    mode: HolderMode,
}

impl SpnnHolderFwd {
    #[allow(clippy::too_many_arguments)]
    fn base(
        cfg: &ModelConfig,
        tc: &TrainConfig,
        j: usize,
        n_holders: usize,
        split: VerticalSplit,
        src: FeatureSource,
        theta: MatF64,
        mode: HolderMode,
    ) -> Self {
        // the split is over *post-transform* columns when compression is
        // on, so the triple/share sizing below follows the compressed
        // widths automatically
        let total_d = split.ranges.last().map(|&(_, e)| e).unwrap_or(0);
        SpnnHolderFwd {
            j,
            src,
            theta,
            n_holders,
            split,
            h: cfg.h1_dim,
            total_d,
            rng: ChaChaRng::seed_from_u64(tc.seed ^ (0x401d + j as u64)),
            exec: exec::pool(),
            mode,
        }
    }

    /// Position of the holder's private mask/nonce RNG, for checkpointing
    /// at the training→serving boundary (see [`crate::ckpt`]).
    pub fn rng_cursor(&self) -> (u64, u64) {
        self.rng.cursor()
    }

    /// Restore the mask/nonce RNG to a checkpointed cursor so a
    /// warm-started replica draws the same serving-phase randomness the
    /// continuous session would have.
    pub fn rng_seek(&mut self, cursor: (u64, u64)) -> Result<()> {
        self.rng.seek(cursor)
    }

    /// Algorithm 2 holder. A and B (j 0/1) carry the Beaver engine; A also
    /// runs the opportunistic dealer feed.
    #[allow(clippy::too_many_arguments)]
    pub fn new_ss(
        cfg: &ModelConfig,
        tc: &TrainConfig,
        j: usize,
        n_holders: usize,
        split: VerticalSplit,
        src: FeatureSource,
        theta: MatF64,
    ) -> Result<Self> {
        let engine = if j <= 1 { Some(Engine::load_default()?) } else { None };
        let cap = ModelConfig::pick_batch(tc.batch);
        let ring_art = cfg.artifact("ring_matmul", cap);
        let feed = if j == 0 { Some(DealerFeed::new(ids::DEALER)) } else { None };
        let mode = HolderMode::Ss { pre: VecDeque::new(), feed, engine, ring_art };
        Ok(Self::base(cfg, tc, j, n_holders, split, src, theta, mode))
    }

    /// Algorithm 3 holder: `pk` is the server's broadcast public key; the
    /// packing geometry is re-derived locally (nothing extra travels).
    #[allow(clippy::too_many_arguments)]
    pub fn new_he(
        cfg: &ModelConfig,
        tc: &TrainConfig,
        j: usize,
        n_holders: usize,
        split: VerticalSplit,
        src: FeatureSource,
        theta: MatF64,
        pk: PublicKey,
    ) -> Result<Self> {
        let pool = NoncePool::new(&pk, tc.paillier_short_exp);
        let packing = Packing::new(&pk, tc.slot_bits, n_holders)?;
        let mode = HolderMode::He { pk, pool, packing };
        Ok(Self::base(cfg, tc, j, n_holders, split, src, theta, mode))
    }

    /// `Step::Prefetch` body: HE refills the Paillier nonce pool for this
    /// batch (the dominant, value-independent holder cost); SS encodes the
    /// feature block, pre-draws the share masks, and (on A) fires the
    /// dealer triple request and pumps already-landed replies so triple
    /// expansion runs inside the prefetch window.
    pub fn prefetch(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<()> {
        p.set_stage("prefetch");
        let rows = b.rows;
        let h = self.h;
        let total_d = self.total_d;
        let exec = self.exec;
        let Self { mode, src, rng, .. } = self;
        match mode {
            HolderMode::He { pool, packing, .. } => {
                let n_cts = packing.ct_count(rows * h);
                pool.refill_parallel(rng, n_cts, &exec);
            }
            HolderMode::Ss { pre, feed, .. } => {
                let xblk = src.block(b)?;
                let dj = xblk.cols;
                let x_ring = RingMat::encode_f64_with(&exec, rows, dj, &xblk.data);
                let r_x = RingMat::random(rng, rows, dj);
                let r_t = RingMat::random(rng, dj, h);
                if let Some(feed) = feed.as_mut() {
                    feed.request(p, Req::Mat(rows, total_d, h), b.tag())?;
                    feed.pump(p)?;
                }
                pre.push_back(SsPre { xblk, x_ring, r_x, r_t });
            }
        }
        Ok(())
    }

    /// `Step::Submit` body: the Algorithm 2 / Algorithm 3 private-feature
    /// forward, up to this holder's last send (product shares or the
    /// ciphertext-chain hop toward the server). Returns the plaintext
    /// feature block — training's local first-layer backward needs it.
    pub fn submit(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<MatF64> {
        let rows = b.rows;
        let tag = b.tag();
        let j = self.j;
        let n_holders = self.n_holders;
        let h = self.h;
        let total_d = self.total_d;
        let exec = self.exec;
        let Self { mode, src, theta, split, .. } = self;
        match mode {
            HolderMode::He { pk, pool, packing } => {
                // ---- Algorithm 3 (packed + pool-parallel) ----
                p.set_stage("he-chain");
                let xblk = src.block(b)?;
                // local plaintext product, fixed-point encoded and packed
                // `slots` values per Paillier plaintext
                let prod = xblk.matmul(theta); // rows x h
                let vals: Vec<i64> = prod
                    .data
                    .iter()
                    .map(|&v| crate::fixed::encode(v) as i64)
                    .collect();
                let n_cts = packing.ct_count(vals.len());
                // Montgomery-resident hop: encrypt and chain-add stay in
                // Montgomery form; the only conversions are parsing the
                // incoming block and serializing the outgoing one.
                let mine = pack::encrypt_batch_resident(pk, packing, &vals, pool, &exec);
                let out_cts = if j == 0 {
                    mine
                } else {
                    // running ciphertext sum from holder j-1
                    let (data, ct_bytes, count) = p
                        .recv_tagged(ids::holder(j - 1), tag)?
                        .into_cipher_block()?;
                    if count != n_cts {
                        return Err(Error::Protocol(format!(
                            "holder{j}: expected {n_cts} packed ciphertexts, got {count}"
                        )));
                    }
                    let prev = pack::block_to_resident(pk, &data, ct_bytes, count, &exec)?;
                    pack::add_batch_resident(pk, &prev, &mine, &exec)?
                };
                let next =
                    if j + 1 < n_holders { ids::holder(j + 1) } else { ids::SERVER };
                let ct_bytes = pk.ciphertext_bytes();
                let data = pack::resident_to_block(pk, &out_cts, ct_bytes, &exec);
                p.send_tagged(
                    next,
                    tag,
                    Payload::CipherBlock { data, ct_bytes, count: n_cts },
                )?;
                Ok(xblk)
            }
            HolderMode::Ss { pre, feed, engine, ring_art } => {
                // ---- Algorithm 2 ----
                p.set_stage("share-mm");
                let SsPre { xblk, x_ring, r_x, r_t } =
                    pre.pop_front().expect("prefetch before submit");
                let dj = xblk.cols;
                let is_a = j == 0;
                let is_b = j == 1;
                let role: u8 = if is_a { 0 } else { 1 };
                let peer = if is_a { ids::holder(1) } else { ids::holder(0) };
                let t_ring = RingMat::encode_f64_with(&exec, dj, h, &theta.data);
                if is_a || is_b {
                    // 1) own block shares (masks pre-drawn)
                    let (x_mine, x_theirs) = share2_from_mask(&x_ring, r_x);
                    let (t_mine, t_theirs) = share2_from_mask(&t_ring, r_t);
                    let mut buf = x_theirs.data;
                    buf.extend_from_slice(&t_theirs.data);
                    p.send_tagged(peer, tag, Payload::U64s(buf))?;
                    let theirs = p.recv_tagged(peer, tag)?.into_u64s()?;
                    let dpeer = split.width(if is_a { 1 } else { 0 });
                    if theirs.len() != rows * dpeer + dpeer * h {
                        return Err(Error::Protocol("holder: peer share size".into()));
                    }
                    let x_peer =
                        RingMat::from_data(rows, dpeer, theirs[..rows * dpeer].to_vec());
                    let t_peer =
                        RingMat::from_data(dpeer, h, theirs[rows * dpeer..].to_vec());

                    // 2) shares of the extra holders' blocks (j >= 2)
                    let mut x_parts: Vec<(usize, RingMat)> = vec![
                        (j, x_mine),
                        (if is_a { 1 } else { 0 }, x_peer),
                    ];
                    let mut t_parts: Vec<(usize, RingMat)> = vec![
                        (j, t_mine),
                        (if is_a { 1 } else { 0 }, t_peer),
                    ];
                    for extra in 2..n_holders {
                        let dx = split.width(extra);
                        let buf =
                            p.recv_tagged(ids::holder(extra), tag)?.into_u64s()?;
                        if buf.len() != rows * dx + dx * h {
                            return Err(Error::Protocol(
                                "holder: extra share size".into(),
                            ));
                        }
                        x_parts.push((
                            extra,
                            RingMat::from_data(rows, dx, buf[..rows * dx].to_vec()),
                        ));
                        t_parts.push((
                            extra,
                            RingMat::from_data(dx, h, buf[rows * dx..].to_vec()),
                        ));
                    }
                    // concat in holder order (theta rows stack the same)
                    x_parts.sort_by_key(|(i, _)| *i);
                    t_parts.sort_by_key(|(i, _)| *i);
                    let mut x_share = x_parts.remove(0).1;
                    for (_, m) in x_parts {
                        x_share = x_share.concat_cols(&m);
                    }
                    let mut t_share = t_parts.remove(0).1;
                    for (_, m) in t_parts {
                        t_share = t_share.concat_rows(&m);
                    }
                    debug_assert_eq!(x_share.shape(), (rows, total_d));
                    debug_assert_eq!(t_share.shape(), (total_d, h));

                    // 3) triple (requested at prefetch; A consumes its
                    // possibly pre-expanded feed material, B expands its
                    // seed at point of use) + Beaver matmul through the
                    // Pallas kernel
                    let triple = match feed.as_mut() {
                        Some(feed) => match feed.next(p, tag)? {
                            Material::Mat(t)
                                if t.u.shape() == (rows, total_d)
                                    && t.v.shape() == (total_d, h) =>
                            {
                                t
                            }
                            Material::Mat(t) => {
                                return Err(Error::Protocol(format!(
                                    "dealer feed shape drift: wanted \
                                     ({rows},{total_d})x({total_d},{h}), got {:?}x{:?}",
                                    t.u.shape(),
                                    t.v.shape()
                                )))
                            }
                            _ => {
                                return Err(Error::Protocol(
                                    "dealer feed kind drift: wanted Mat".into(),
                                ))
                            }
                        },
                        None => dealer::recv_mat_triple_b_tagged(
                            p, ids::DEALER, rows, total_d, h, tag,
                        )?,
                    };
                    let eng = engine.as_mut().unwrap();
                    // engine is behind &mut — wrap in RefCell for the closure
                    let eng_cell = std::cell::RefCell::new(eng);
                    let art = ring_art.clone();
                    // the AOT Pallas kernel is the default hot path; the
                    // §Perf pass measured a 3.5-5.5x interpret-mode CPU
                    // overhead vs the native ring matmul, selectable via
                    // SPNN_NATIVE_MM=1 (EXPERIMENTS.md §Perf)
                    let native = std::env::var("SPNN_NATIVE_MM").is_ok();
                    let mm = move |x: &RingMat, w: &RingMat| -> RingMat {
                        if native {
                            x.matmul(w)
                        } else {
                            eng_cell
                                .borrow_mut()
                                .ring_matmul(&art, x, w)
                                .expect("ring matmul artifact")
                        }
                    };
                    let mut z = beaver_matmul(
                        p, peer, role, &x_share, &t_share, &triple, &mm,
                    )?;
                    // 4) truncate my share, ship to the server
                    trunc_share_mat(&mut z, role);
                    p.send_tagged(ids::SERVER, tag, Payload::U64s(z.data))?;
                } else {
                    // extra holder: share my block to A and B
                    let (xa, xb) = share2_from_mask(&x_ring, r_x);
                    let (ta, tb) = share2_from_mask(&t_ring, r_t);
                    let mut buf_a = xa.data;
                    buf_a.extend_from_slice(&ta.data);
                    p.send_tagged(ids::holder(0), tag, Payload::U64s(buf_a))?;
                    let mut buf_b = xb.data;
                    buf_b.extend_from_slice(&tb.data);
                    p.send_tagged(ids::holder(1), tag, Payload::U64s(buf_b))?;
                }
                Ok(xblk)
            }
        }
    }
}

impl ForwardPass for SpnnHolderFwd {
    fn role(&self) -> &'static str {
        "spnn-holder"
    }

    fn stage_rows(&mut self, index: u64, ids: &[u32]) {
        self.src.stage(index, ids);
    }

    fn prefetch(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<()> {
        SpnnHolderFwd::prefetch(self, p, b)
    }

    fn forward(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<Option<Vec<f32>>> {
        self.submit(p, b)?;
        Ok(None)
    }
}

// ---------------------------------------------------------------------------
// SPNN server
// ---------------------------------------------------------------------------

/// The server's hidden-layer forward (paper §4.4): reconstruct `h1` from
/// the holders' contributions (decrypt the packed Paillier chain or sum
/// the truncated product shares), run the AOT `server_fwd` graph, and ship
/// `hL` to the label holder. Owns the server parameter stack (trained in
/// place) and — under HE — the Paillier secret key.
pub struct SpnnServerFwd {
    /// The server's hidden-stack parameters (trained in place).
    pub params: ModelParams,
    /// The AOT/native graph engine (training's backward uses it too).
    pub engine: Engine,
    sk: Option<SecretKey>,
    packing: Option<Packing>,
    n_holders: usize,
    cap: usize,
    h1_dim: usize,
    hl_dim: usize,
    cfg: ModelConfig,
    exec: ExecPool,
}

impl SpnnServerFwd {
    /// `sk` is the Paillier keypair's secret half under HE (`None` = SS);
    /// the packing geometry is derived from it exactly as the holders
    /// derive theirs from the broadcast public key.
    pub fn new(
        cfg: &ModelConfig,
        tc: &TrainConfig,
        params: ModelParams,
        sk: Option<SecretKey>,
        n_holders: usize,
    ) -> Result<Self> {
        let packing = match &sk {
            Some(sk) => Some(Packing::new(&sk.pk, tc.slot_bits, n_holders)?),
            None => None,
        };
        Ok(SpnnServerFwd {
            params,
            engine: Engine::load_default()?,
            sk,
            packing,
            n_holders,
            cap: ModelConfig::pick_batch(tc.batch),
            h1_dim: cfg.h1_dim,
            hl_dim: cfg.hl_dim(),
            cfg: cfg.clone(),
            exec: exec::pool(),
        })
    }

    /// The server's per-batch forward: receive/reconstruct `h1`, run the
    /// hidden stack, send `hL` (real rows only) to the label holder.
    /// Returns the padded `h1` block — training's backward needs it.
    pub fn run(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<Vec<f32>> {
        let rows = b.rows;
        let tag = b.tag();
        p.set_stage("server-fwd");
        if rows > self.cap {
            // a ragged/oversized batch must fail loudly, not panic mid-copy
            return Err(Error::Protocol(format!(
                "server: batch of {rows} rows exceeds the artifact cap {}",
                self.cap
            )));
        }
        let a = ids::holder(0);
        // ---- receive h1 (reconstruct from shares or decrypt) ----
        let h1_f32: Vec<f32> = if let Some(sk) = self.sk.as_ref() {
            let packing = self.packing.as_ref().unwrap();
            let last_holder = ids::holder(self.n_holders - 1);
            let (data, ct_bytes, count) =
                p.recv_tagged(last_holder, tag)?.into_cipher_block()?;
            let expect = packing.ct_count(rows * self.h1_dim);
            if count != expect {
                return Err(Error::Protocol(format!(
                    "server: expected {expect} packed ciphertexts, got {count}"
                )));
            }
            let cts = pack::block_to_cts(&data, ct_bytes, count)?;
            // parallel CRT decryptions, then per-slot k-holder sums
            let sums = pack::decrypt_batch(
                sk,
                packing,
                &cts,
                rows * self.h1_dim,
                self.n_holders,
                &self.exec,
            )?;
            sums.iter().map(|&s| crate::fixed::decode(s as u64) as f32).collect()
        } else {
            let sa = p.recv_tagged(a, tag)?.into_u64s()?;
            let sb = p.recv_tagged(ids::holder(1), tag)?.into_u64s()?;
            if sa.len() != rows * self.h1_dim || sb.len() != sa.len() {
                return Err(Error::Protocol("server: h1 share size".into()));
            }
            sa.iter()
                .zip(&sb)
                .map(|(x, y)| crate::fixed::decode(x.wrapping_add(*y)) as f32)
                .collect()
        };

        // ---- forward through the hidden stack (AOT graph) ----
        let mut h1_pad = vec![0.0f32; self.cap * self.h1_dim];
        h1_pad[..rows * self.h1_dim].copy_from_slice(&h1_f32);
        let server_f32 = self.params.server_f32();
        let mut inputs: Vec<TensorIn> = vec![TensorIn::F32(&h1_pad)];
        for sp in &server_f32 {
            inputs.push(TensorIn::F32(sp));
        }
        let hl = self
            .engine
            .execute(&self.cfg.artifact("server_fwd", self.cap), &inputs)?
            .remove(0)
            .f32()?;
        // send hL (only the real rows) to the label holder
        p.send_tagged(a, tag, Payload::F32s(hl[..rows * self.hl_dim].to_vec()))?;
        Ok(h1_pad)
    }
}

impl ForwardPass for SpnnServerFwd {
    fn role(&self) -> &'static str {
        "spnn-server"
    }

    fn prefetch(&mut self, _p: &mut dyn Channel, _b: &BatchCtx) -> Result<()> {
        // the server has no value-independent lookahead work: its entire
        // per-batch load depends on the holders' h1
        Ok(())
    }

    fn forward(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<Option<Vec<f32>>> {
        self.run(p, b)?;
        Ok(None)
    }
}

// ---------------------------------------------------------------------------
// Label-layer scoring (shared by every scoring role + the direct forwards)
// ---------------------------------------------------------------------------

/// Run the forward-only `label_fwd(hL, wy, by)` graph at `cap` padding and
/// slice the `rows` real scores.
fn label_scores(
    engine: &mut Engine,
    cfg: &ModelConfig,
    cap: usize,
    hl_pad: &[f32],
    wy: &[f32],
    by: &[f32],
    rows: usize,
) -> Result<Vec<f32>> {
    let outs = engine.execute(
        &cfg.artifact("label_fwd", cap),
        &[TensorIn::F32(hl_pad), TensorIn::F32(wy), TensorIn::F32(by)],
    )?;
    let p = outs
        .into_iter()
        .next()
        .ok_or_else(|| Error::Protocol("label_fwd: missing output".into()))?
        .f32()?;
    Ok(p[..rows].to_vec())
}

// ---------------------------------------------------------------------------
// SPNN label head (holder A)
// ---------------------------------------------------------------------------

/// Holder A's label layer (paper §4.5). Training receives `hL` through
/// [`SpnnHeadFwd::recv_hidden`] and runs the `label_grad` graph (loss +
/// gradients); serving runs the forward-only `label_fwd` graph via
/// [`SpnnHeadFwd::score`]. Owns the label-layer parameters (trained in
/// place).
pub struct SpnnHeadFwd {
    /// Label-layer weights (trained in place).
    pub wy: MatF64,
    /// Label-layer bias (trained in place).
    pub by: MatF64,
    /// Graph engine for `label_grad` / `label_fwd`.
    pub engine: Engine,
    cap: usize,
    hl_dim: usize,
    cfg: ModelConfig,
}

impl SpnnHeadFwd {
    /// Paper-style label-layer initialization from the shared seed.
    /// `d_in` is the first layer's input width (`cfg.n_features`, or the
    /// compressed `k_total` when a feature transform is active) — the
    /// `theta0` draw count shifts every later draw, so all parties must
    /// agree on it.
    pub fn new(cfg: &ModelConfig, tc: &TrainConfig, d_in: usize) -> Result<Self> {
        let init = ModelParams::init_with_input(cfg, tc.seed, d_in);
        Ok(SpnnHeadFwd {
            wy: init.wy,
            by: init.by,
            engine: Engine::load_default()?,
            cap: ModelConfig::pick_batch(tc.batch),
            hl_dim: cfg.hl_dim(),
            cfg: cfg.clone(),
        })
    }

    /// The artifact batch cap (padding width).
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Receive batch `b`'s `hL` rows from the server, zero-padded to the
    /// artifact cap (the receive both training and serving start from).
    pub fn recv_hidden(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<Vec<f32>> {
        let hl = p.recv_tagged(ids::SERVER, b.tag())?.into_f32s()?;
        if b.rows > self.cap || hl.len() != b.rows * self.hl_dim {
            return Err(Error::Protocol(format!(
                "holder: hL block of {} values for {} rows (cap {})",
                hl.len(),
                b.rows,
                self.cap
            )));
        }
        let mut hl_pad = vec![0.0f32; self.cap * self.hl_dim];
        hl_pad[..b.rows * self.hl_dim].copy_from_slice(&hl);
        Ok(hl_pad)
    }

    /// Score a padded `hL` block: `label_fwd(hL, wy, by)` — one
    /// probability per real row.
    pub fn score(&mut self, hl_pad: &[f32], rows: usize) -> Result<Vec<f32>> {
        let wy = self.wy.to_f32();
        let by = self.by.to_f32();
        label_scores(&mut self.engine, &self.cfg, self.cap, hl_pad, &wy, &by, rows)
    }
}

/// Holder A's serving role: the Algorithm 2/3 holder forward composed with
/// the label head — the party that turns `hL` into client-visible scores.
pub struct SpnnLabelFwd<'a> {
    /// A's private-feature forward.
    pub holder: &'a mut SpnnHolderFwd,
    /// A's label layer.
    pub head: &'a mut SpnnHeadFwd,
}

impl ForwardPass for SpnnLabelFwd<'_> {
    fn role(&self) -> &'static str {
        "spnn-label-holder"
    }

    fn stage_rows(&mut self, index: u64, ids: &[u32]) {
        self.holder.src.stage(index, ids);
    }

    fn prefetch(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<()> {
        self.holder.prefetch(p, b)
    }

    fn forward(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<Option<Vec<f32>>> {
        self.holder.submit(p, b)?;
        let hl_pad = self.head.recv_hidden(p, b)?;
        Ok(Some(self.head.score(&hl_pad, b.rows)?))
    }
}

// ---------------------------------------------------------------------------
// SecureML (whole-network 2-party MPC)
// ---------------------------------------------------------------------------

/// One shared layer: weight / optional bias shares.
#[derive(Clone)]
pub struct LayerShare {
    /// Weight-matrix share.
    pub w: RingMat,
    /// Bias-vector share (layers with a bias).
    pub b: Option<Vec<u64>>,
}

/// Fixed-point encode of a public constant.
pub(crate) fn enc_const(v: f64) -> u64 {
    crate::fixed::encode(v)
}

/// Add a public constant to a share vector (role 0 only).
pub(crate) fn add_const(share: &mut [u64], c: u64, role: u8) {
    if role == 0 {
        for v in share.iter_mut() {
            *v = v.wrapping_add(c);
        }
    }
}

/// The dealer-material sequence one mini-batch's **forward** pass
/// consumes, in consumption order: one matrix triple per layer plus the
/// activation material (two comparisons + a Hadamard for the piecewise
/// sigmoid, one comparison + a Hadamard for relu).
pub fn mpc_fwd_script(dims: &[usize], acts: &[Act], rows: usize) -> Vec<Req> {
    let n_layers = dims.len() - 1;
    let mut script = Vec::new();
    for l in 0..n_layers {
        let lanes = rows * dims[l + 1];
        script.push(Req::Mat(rows, dims[l], dims[l + 1]));
        match acts[l] {
            Act::Sigmoid => {
                script.push(Req::Bool(lanes));
                script.push(Req::Bool(lanes));
                script.push(Req::Elem(lanes));
            }
            Act::Relu => {
                script.push(Req::Bool(lanes));
                script.push(Req::Elem(lanes));
            }
            Act::Identity => {}
        }
    }
    script
}

/// The full forward + backward dealer script one training mini-batch
/// consumes ([`mpc_fwd_script`] followed by the backward material, in
/// reverse layer order). `Prefetch` fires these as tagged requests; the
/// forward/backward code pulls the replies in the same order, so the two
/// MUST stay in sync (guarded by `secureml_depths_are_transcript_equal`
/// and the tiny end-to-end test).
pub fn mpc_batch_script(dims: &[usize], acts: &[Act], rows: usize) -> Vec<Req> {
    let mut script = mpc_fwd_script(dims, acts, rows);
    let n_layers = dims.len() - 1;
    for l in (0..n_layers).rev() {
        let lanes = rows * dims[l + 1];
        if acts[l] != Act::Identity {
            script.push(Req::Elem(lanes));
        }
        script.push(Req::Mat(dims[l], rows, dims[l + 1]));
        if l > 0 {
            script.push(Req::Mat(rows, dims[l + 1], dims[l]));
        }
    }
    script
}

/// The layer activations a SecureML forward pass hands to the backward
/// stage (or to score opening, when serving).
pub struct MpcActs {
    /// Per-layer activation shares; `[0]` is the input share, the last
    /// entry is the output-probability share.
    pub act_shares: Vec<RingMat>,
    /// Per-layer activation-derivative shares (empty vec = identity).
    pub deriv_shares: Vec<Vec<u64>>,
}

/// A SecureML compute party's (A or B) forward state: the shared layer
/// stack (trained in place), the A-side dealer feed, the input-mask RNG
/// and the feature source. `train` selects the dealer script (forward +
/// backward vs forward-only) and whether labels are shared.
pub struct MlpMpcFwd {
    /// 0 = A (fires dealer requests, owns labels), 1 = B.
    pub role: u8,
    /// Shared layer stack (trained in place by the backward pass).
    pub layers: Vec<LayerShare>,
    /// Where per-batch feature blocks come from.
    pub src: FeatureSource,
    /// A's labels (train mode only).
    pub y: Option<Vec<f32>>,
    a_id: usize,
    b_id: usize,
    dealer: usize,
    extra_ids: Vec<usize>,
    split: VerticalSplit,
    dims: Vec<usize>,
    acts: Vec<Act>,
    feed: Option<DealerFeed>,
    rng: ChaChaRng,
    train: bool,
    masks: VecDeque<(RingMat, Option<RingMat>)>,
}

impl MlpMpcFwd {
    /// Build a compute party's forward state. `rng` must be the party's
    /// input-mask RNG positioned after weight-initialization sharing (the
    /// draws continue in schedule order). `extra_ids` are the party ids of
    /// holders 2.. in holder order.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        role: u8,
        a_id: usize,
        b_id: usize,
        dealer: usize,
        extra_ids: Vec<usize>,
        split: VerticalSplit,
        dims: Vec<usize>,
        acts: Vec<Act>,
        layers: Vec<LayerShare>,
        src: FeatureSource,
        y: Option<Vec<f32>>,
        rng: ChaChaRng,
        train: bool,
    ) -> Self {
        let feed = if role == 0 { Some(DealerFeed::new(dealer)) } else { None };
        MlpMpcFwd {
            role,
            layers,
            src,
            y,
            a_id,
            b_id,
            dealer,
            extra_ids,
            split,
            dims,
            acts,
            feed,
            rng,
            train,
            masks: VecDeque::new(),
        }
    }

    /// Switch between the training script (fwd + bwd dealer material,
    /// label sharing) and the serving script (forward-only).
    pub fn set_train(&mut self, train: bool) {
        self.train = train;
    }

    /// Position of the party's private mask RNG, for checkpointing at the
    /// training→serving boundary (see [`crate::ckpt`]).
    pub fn rng_cursor(&self) -> (u64, u64) {
        self.rng.cursor()
    }

    /// Restore the mask RNG to a checkpointed cursor so a warm-started
    /// replica draws the same serving-phase masks the continuous session
    /// would have.
    pub fn rng_seek(&mut self, cursor: (u64, u64)) -> Result<()> {
        self.rng.seek(cursor)
    }

    fn peer(&self) -> usize {
        if self.role == 0 {
            self.b_id
        } else {
            self.a_id
        }
    }

    /// `Step::Prefetch`: A streams the batch's whole dealer script ahead
    /// of demand and pumps already-landed replies (expansion inside the
    /// prefetch window); both parties pre-draw their input-share masks in
    /// schedule order.
    pub fn prefetch(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<()> {
        p.set_stage("prefetch");
        if let Some(feed) = self.feed.as_mut() {
            let script = if self.train {
                mpc_batch_script(&self.dims, &self.acts, b.rows)
            } else {
                mpc_fwd_script(&self.dims, &self.acts, b.rows)
            };
            for req in script {
                feed.request(p, req, b.tag())?;
            }
            feed.pump(p)?;
        }
        // input-share masks, drawn in schedule order
        let dj = self.src.width();
        let r_x = RingMat::random(&mut self.rng, b.rows, dj);
        let r_y = if self.train && self.role == 0 {
            Some(RingMat::random(&mut self.rng, b.rows, 1))
        } else {
            None
        };
        self.masks.push_back((r_x, r_y));
        Ok(())
    }

    /// Input sharing: exchange feature-block shares with the peer, absorb
    /// the extra holders' shares, and (train mode) share the labels.
    /// Returns the full input share `(rows x D)` and A/B's label share.
    pub fn share_inputs(
        &mut self,
        p: &mut dyn Channel,
        b: &BatchCtx,
    ) -> Result<(RingMat, Option<Vec<u64>>)> {
        let rows = b.rows;
        let tag = b.tag();
        let me_is_a = self.role == 0;
        let peer = self.peer();
        let (r_x, r_y) = self.masks.pop_front().expect("prefetch before submit");
        let xblk = self.src.block(b)?;
        let xr = RingMat::encode_f64(rows, xblk.cols, &xblk.data);
        let (mine, theirs) = share2_from_mask(&xr, r_x);
        p.send_tagged(peer, tag, Payload::U64s(theirs.data))?;
        let peer_share = p.recv_tagged(peer, tag)?.into_u64s()?;
        let dpeer = self.split.width(if me_is_a { 1 } else { 0 });
        if peer_share.len() != rows * dpeer {
            return Err(Error::Protocol("secureml: peer share size".into()));
        }
        let peer_mat = RingMat::from_data(rows, dpeer, peer_share);
        // column order: holder 0 block, holder 1 block, extras...
        let mut x_share = if me_is_a {
            mine.concat_cols(&peer_mat)
        } else {
            peer_mat.concat_cols(&mine)
        };
        for (i, &id) in self.extra_ids.iter().enumerate() {
            let blk = p.recv_tagged(id, tag)?.into_u64s()?;
            let w = self.split.width(2 + i);
            if blk.len() != rows * w {
                return Err(Error::Protocol("secureml: extra block size".into()));
            }
            x_share = x_share.concat_cols(&RingMat::from_data(rows, w, blk));
        }
        // labels: A shares y (train mode only; serving has no labels)
        let y_share = if self.train {
            Some(if me_is_a {
                let yv: Vec<f64> = self.y.as_ref().expect("A holds labels")
                    [b.start..b.start + rows]
                    .iter()
                    .map(|&v| v as f64)
                    .collect();
                let yr = RingMat::encode_f64(rows, 1, &yv);
                let (ya, yb) = share2_from_mask(&yr, r_y.expect("A drew a label mask"));
                p.send_tagged(peer, tag, Payload::U64s(yb.data))?;
                ya.data
            } else {
                p.recv_tagged(peer, tag)?.into_u64s()?
            })
        } else {
            None
        };
        Ok((x_share, y_share))
    }

    /// The shared-network forward: per layer, Beaver matmul + truncation +
    /// shared bias, then the MPC-friendly piecewise activation. Returns
    /// every activation/derivative share (backward or score opening).
    pub fn forward_layers(
        &mut self,
        p: &mut dyn Channel,
        b: &BatchCtx,
        x_share: RingMat,
    ) -> Result<MpcActs> {
        use crate::fixed::SCALE;
        let rows = b.rows;
        let tag = b.tag();
        let n_layers = self.dims.len() - 1;
        let peer = self.peer();
        let role = self.role;
        let mut act_shares: Vec<RingMat> = vec![x_share];
        let mut deriv_shares: Vec<Vec<u64>> = Vec::new(); // per layer
        for l in 0..n_layers {
            let a_in = act_shares.last().unwrap().clone();
            let (m, k, n) = (rows, self.dims[l], self.dims[l + 1]);
            let triple = self.mat_triple(p, m, k, n, tag)?;
            let mut z =
                beaver_matmul(p, peer, role, &a_in, &self.layers[l].w, &triple, &native_mm)?;
            trunc_share_mat(&mut z, role);
            if let Some(bv) = &self.layers[l].b {
                for r in 0..m {
                    for c in 0..n {
                        let v = &mut z.data[r * n + c];
                        *v = v.wrapping_add(bv[c]);
                    }
                }
            }
            // activation
            let lanes = m * n;
            match self.acts[l] {
                Act::Sigmoid => {
                    // piecewise: f = (b1-b2)(z+1/2) + b2
                    let mut u = z.data.clone();
                    add_const(&mut u, enc_const(0.5), role);
                    let b1 = self.drelu(p, &u, tag)?;
                    let mut v = z.data.clone();
                    add_const(&mut v, enc_const(-0.5), role);
                    let b2 = self.drelu(p, &v, tag)?;
                    let d: Vec<u64> = b1
                        .iter()
                        .zip(&b2)
                        .map(|(x, yv)| x.wrapping_sub(*yv))
                        .collect();
                    let et = self.elem_triple(p, lanes, tag)?;
                    let prod = beaver_mul_elem(p, peer, role, &d, &u, &et)?;
                    let f: Vec<u64> = prod
                        .iter()
                        .zip(&b2)
                        .map(|(x, yv)| x.wrapping_add(yv.wrapping_mul(SCALE as u64)))
                        .collect();
                    deriv_shares.push(d);
                    act_shares.push(RingMat::from_data(m, n, f));
                }
                Act::Relu => {
                    let bb = self.drelu(p, &z.data, tag)?;
                    let et = self.elem_triple(p, lanes, tag)?;
                    let f = beaver_mul_elem(p, peer, role, &bb, &z.data, &et)?;
                    deriv_shares.push(bb);
                    act_shares.push(RingMat::from_data(m, n, f));
                }
                Act::Identity => {
                    deriv_shares.push(vec![]);
                    act_shares.push(z);
                }
            }
        }
        Ok(MpcActs { act_shares, deriv_shares })
    }

    /// Pull a matrix triple requested at prefetch under `tag`: A consumes
    /// its (possibly pre-expanded) feed material, B expands its seed at
    /// point of use.
    pub fn mat_triple(
        &mut self,
        p: &mut dyn Channel,
        m: usize,
        k: usize,
        n: usize,
        tag: u64,
    ) -> Result<MatTriple> {
        match self.feed.as_mut() {
            Some(feed) => match feed.next(p, tag)? {
                Material::Mat(t) if t.u.shape() == (m, k) && t.v.shape() == (k, n) => Ok(t),
                Material::Mat(t) => Err(Error::Protocol(format!(
                    "dealer feed shape drift: wanted ({m},{k})x({k},{n}), got {:?}x{:?}",
                    t.u.shape(),
                    t.v.shape()
                ))),
                _ => Err(Error::Protocol("dealer feed kind drift: wanted Mat".into())),
            },
            None => {
                debug_assert_ne!(self.role, 0);
                dealer::recv_mat_triple_b_tagged(p, self.dealer, m, k, n, tag)
            }
        }
    }

    /// Pull an elementwise triple requested at prefetch under `tag`.
    pub fn elem_triple(
        &mut self,
        p: &mut dyn Channel,
        len: usize,
        tag: u64,
    ) -> Result<ElemTriple> {
        match self.feed.as_mut() {
            Some(feed) => match feed.next(p, tag)? {
                Material::Elem(t) if t.u.len() == len => Ok(t),
                Material::Elem(t) => Err(Error::Protocol(format!(
                    "dealer feed shape drift: wanted {len} lanes, got {}",
                    t.u.len()
                ))),
                _ => Err(Error::Protocol("dealer feed kind drift: wanted Elem".into())),
            },
            None => {
                debug_assert_ne!(self.role, 0);
                dealer::recv_elem_triple_b_tagged(p, self.dealer, len, tag)
            }
        }
    }

    /// DReLU over a share vector via a prefetched dealer bundle.
    pub fn drelu(&mut self, p: &mut dyn Channel, x: &[u64], tag: u64) -> Result<Vec<u64>> {
        use crate::smpc::boolean::drelu_arith;
        let lanes = x.len();
        let mut bundle = match self.feed.as_mut() {
            Some(feed) => match feed.next(p, tag)? {
                Material::Bool(b) if b.eda.r_arith.len() == lanes => b,
                Material::Bool(b) => {
                    return Err(Error::Protocol(format!(
                        "dealer feed shape drift: wanted {lanes} lanes, got {}",
                        b.eda.r_arith.len()
                    )))
                }
                _ => return Err(Error::Protocol("dealer feed kind drift: wanted Bool".into())),
            },
            None => dealer::recv_bool_bundle_b_tagged(p, self.dealer, lanes, tag)?,
        };
        let peer = self.peer();
        drelu_arith(p, peer, self.role, x, &bundle.eda, &mut bundle.bank, &bundle.dab)
    }

    /// Open the output-probability shares toward A: B contributes its
    /// share, A reconstructs and decodes the client-visible scores.
    pub fn open_scores(
        &mut self,
        p: &mut dyn Channel,
        b: &BatchCtx,
        p_share: &RingMat,
    ) -> Result<Option<Vec<f32>>> {
        let tag = b.tag();
        if self.role == 0 {
            let p_peer = p.recv_tagged(self.b_id, tag)?.into_u64s()?;
            if p_peer.len() != p_share.data.len() {
                return Err(Error::Protocol("secureml: score share size".into()));
            }
            Ok(Some(
                p_share
                    .data
                    .iter()
                    .zip(&p_peer)
                    .map(|(a, q)| {
                        crate::fixed::decode(a.wrapping_add(*q)).clamp(0.0, 1.0) as f32
                    })
                    .collect(),
            ))
        } else {
            p.send_tagged(self.a_id, tag, Payload::U64s(p_share.data.clone()))?;
            Ok(None)
        }
    }
}

impl ForwardPass for MlpMpcFwd {
    fn role(&self) -> &'static str {
        if self.role == 0 {
            "secureml-A"
        } else {
            "secureml-B"
        }
    }

    fn stage_rows(&mut self, index: u64, ids: &[u32]) {
        self.src.stage(index, ids);
    }

    fn prefetch(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<()> {
        MlpMpcFwd::prefetch(self, p, b)
    }

    fn forward(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<Option<Vec<f32>>> {
        p.set_stage("fwd");
        let (x_share, _) = self.share_inputs(p, b)?;
        let acts = self.forward_layers(p, b, x_share)?;
        let p_share = acts.act_shares.last().unwrap().clone();
        self.open_scores(p, b, &p_share)
    }
}

/// A SecureML extra data holder (holder 2..): shares its feature block
/// into the two compute parties each batch. The block encode and the mask
/// draw are value-independent, so both stage in the prefetch window.
pub struct MlpExtraFwd {
    /// Where per-batch feature blocks come from.
    pub src: FeatureSource,
    a_id: usize,
    b_id: usize,
    rng: ChaChaRng,
    staged: VecDeque<(RingMat, RingMat)>,
}

impl MlpExtraFwd {
    /// `rng` is the holder's mask RNG (seeded per the deployment).
    pub fn new(a_id: usize, b_id: usize, src: FeatureSource, rng: ChaChaRng) -> Self {
        MlpExtraFwd { src, a_id, b_id, rng, staged: VecDeque::new() }
    }

    /// Position of the holder's private mask RNG, for checkpointing at the
    /// training→serving boundary (see [`crate::ckpt`]).
    pub fn rng_cursor(&self) -> (u64, u64) {
        self.rng.cursor()
    }

    /// Restore the mask RNG to a checkpointed cursor (warm start).
    pub fn rng_seek(&mut self, cursor: (u64, u64)) -> Result<()> {
        self.rng.seek(cursor)
    }

    /// Encode the block and pre-draw the mask (schedule order).
    pub fn prefetch(&mut self, b: &BatchCtx) -> Result<()> {
        let xblk = self.src.block(b)?;
        let xr = RingMat::encode_f64(b.rows, xblk.cols, &xblk.data);
        let r = RingMat::random(&mut self.rng, b.rows, xblk.cols);
        self.staged.push_back((xr, r));
        Ok(())
    }

    /// Ship the two shares to A and B.
    pub fn submit(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<()> {
        let (xr, r) = self.staged.pop_front().expect("prefetch before submit");
        let (sa, sb) = share2_from_mask(&xr, r);
        p.send_tagged(self.a_id, b.tag(), Payload::U64s(sa.data))?;
        p.send_tagged(self.b_id, b.tag(), Payload::U64s(sb.data))?;
        Ok(())
    }
}

impl ForwardPass for MlpExtraFwd {
    fn role(&self) -> &'static str {
        "secureml-holder"
    }

    fn stage_rows(&mut self, index: u64, ids: &[u32]) {
        self.src.stage(index, ids);
    }

    fn prefetch(&mut self, _p: &mut dyn Channel, b: &BatchCtx) -> Result<()> {
        MlpExtraFwd::prefetch(self, b)
    }

    fn forward(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<Option<Vec<f32>>> {
        self.submit(p, b)?;
        Ok(None)
    }
}

// ---------------------------------------------------------------------------
// SplitNN
// ---------------------------------------------------------------------------

/// A SplitNN data holder's bottom encoder: `z = X_j · enc`, sent to the
/// server as this holder's cut-layer block (plaintext — the baseline's
/// privacy weakness is the point of comparison).
pub struct SplitHolderFwd {
    /// The private bottom encoder (trained in place).
    pub enc: MatF64,
    /// Where per-batch feature blocks come from.
    pub src: FeatureSource,
    staged: VecDeque<MatF64>,
}

impl SplitHolderFwd {
    /// Holder with encoder `enc` over feature source `src`.
    pub fn new(enc: MatF64, src: FeatureSource) -> Self {
        SplitHolderFwd { enc, src, staged: VecDeque::new() }
    }

    /// Stage the decoded feature block (value-independent).
    pub fn prefetch(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<()> {
        p.set_stage("prefetch");
        self.staged.push_back(self.src.block(b)?);
        Ok(())
    }

    /// Encoder forward: send the pre-activation cut-layer units (the
    /// server applies the activation). Returns the feature block for the
    /// training backward.
    pub fn submit(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<MatF64> {
        p.set_stage("cut-fwd");
        let x = self.staged.pop_front().expect("prefetch before submit");
        let z = x.matmul(&self.enc);
        p.send_tagged(ids::SERVER, b.tag(), Payload::F32s(z.to_f32()))?;
        Ok(x)
    }
}

impl ForwardPass for SplitHolderFwd {
    fn role(&self) -> &'static str {
        "splitnn-holder"
    }

    fn stage_rows(&mut self, index: u64, ids: &[u32]) {
        self.src.stage(index, ids);
    }

    fn prefetch(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<()> {
        SplitHolderFwd::prefetch(self, p, b)
    }

    fn forward(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<Option<Vec<f32>>> {
        self.submit(p, b)?;
        Ok(None)
    }
}

/// The SplitNN server: concatenates the holders' cut-layer blocks, runs
/// the hidden stack, and — since SplitNN's server owns the labels — also
/// the label head. While serving, the server is the scoring role.
pub struct SplitServerFwd {
    /// Server stack + label layer (trained in place; `theta0` unused —
    /// SplitNN never trains it).
    pub params: ModelParams,
    /// Graph engine (training's backward uses it too).
    pub engine: Engine,
    n_holders: usize,
    usplit: VerticalSplit,
    cap: usize,
    h1_dim: usize,
    hl_dim: usize,
    cfg: ModelConfig,
}

impl SplitServerFwd {
    /// `usplit` is the cut-layer unit split across holders.
    pub fn new(
        cfg: &ModelConfig,
        tc: &TrainConfig,
        params: ModelParams,
        n_holders: usize,
        usplit: VerticalSplit,
    ) -> Result<Self> {
        Ok(SplitServerFwd {
            params,
            engine: Engine::load_default()?,
            n_holders,
            usplit,
            cap: ModelConfig::pick_batch(tc.batch),
            h1_dim: cfg.h1_dim,
            hl_dim: cfg.hl_dim(),
            cfg: cfg.clone(),
        })
    }

    /// The artifact batch cap (padding width).
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Gather the holders' cut-layer blocks and run the hidden stack.
    /// Returns `(h1_pad, hL)` — training continues with `label_grad` and
    /// the backward; serving continues with [`SplitServerFwd::score`].
    pub fn hidden(
        &mut self,
        p: &mut dyn Channel,
        b: &BatchCtx,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let rows = b.rows;
        let tag = b.tag();
        p.set_stage("server");
        if rows > self.cap {
            return Err(Error::Protocol(format!(
                "server: batch of {rows} rows exceeds the artifact cap {}",
                self.cap
            )));
        }
        // gather cut-layer blocks from every holder, concat by unit range
        let h1 = self.h1_dim;
        let mut h1_pad = vec![0.0f32; self.cap * h1];
        for j in 0..self.n_holders {
            let blk = p.recv_tagged(ids::holder(j), tag)?.into_f32s()?;
            let (us, ue) = self.usplit.ranges[j];
            let w = ue - us;
            if blk.len() != rows * w {
                return Err(Error::Protocol("splitnn: cut block size".into()));
            }
            for r in 0..rows {
                h1_pad[r * h1 + us..r * h1 + ue]
                    .copy_from_slice(&blk[r * w..(r + 1) * w]);
            }
        }
        let server_f32 = self.params.server_f32();
        let mut inputs: Vec<TensorIn> = vec![TensorIn::F32(&h1_pad)];
        for sp in &server_f32 {
            inputs.push(TensorIn::F32(sp));
        }
        let hl_act = self
            .engine
            .execute(&self.cfg.artifact("server_fwd", self.cap), &inputs)?
            .remove(0)
            .f32()?;
        Ok((h1_pad, hl_act))
    }

    /// Score a padded `hL` block through the server-held label layer
    /// (`label_fwd`): one probability per real row.
    pub fn score(&mut self, hl_pad: &[f32], rows: usize) -> Result<Vec<f32>> {
        let wy = self.params.wy_f32();
        let by = self.params.by_f32();
        label_scores(&mut self.engine, &self.cfg, self.cap, hl_pad, &wy, &by, rows)
    }
}

impl ForwardPass for SplitServerFwd {
    fn role(&self) -> &'static str {
        "splitnn-server"
    }

    fn prefetch(&mut self, _p: &mut dyn Channel, _b: &BatchCtx) -> Result<()> {
        Ok(())
    }

    fn forward(&mut self, p: &mut dyn Channel, b: &BatchCtx) -> Result<Option<Vec<f32>>> {
        let (_, hl) = self.hidden(p, b)?;
        Ok(Some(self.score(&hl, b.rows)?))
    }
}

// ---------------------------------------------------------------------------
// Direct (channel-free) reference forward passes
// ---------------------------------------------------------------------------

/// Copy one named block of a report into a parameter buffer (validated).
fn copy_block(rep: &TrainReport, name: &str, dst: &mut [f64]) -> Result<()> {
    let blk = rep
        .param(name)
        .ok_or_else(|| Error::Protocol(format!("report missing param block {name:?}")))?;
    if blk.len() != dst.len() {
        return Err(Error::Protocol(format!(
            "report param {name:?}: {} values, wanted {}",
            blk.len(),
            dst.len()
        )));
    }
    dst.copy_from_slice(blk);
    Ok(())
}

/// Copy a report's `server{i}` / `wy` / `by` blocks into `mp` (the pieces
/// every protocol's report carries; SPNN additionally has `theta0`).
fn copy_server_head(rep: &TrainReport, mp: &mut ModelParams) -> Result<()> {
    for i in 0..mp.server.len() {
        let name = format!("server{i}");
        copy_block(rep, &name, &mut mp.server[i].data)?;
    }
    copy_block(rep, "wy", &mut mp.wy.data)?;
    copy_block(rep, "by", &mut mp.by.data)
}

/// Rebuild a full [`ModelParams`] from a [`TrainReport`]'s assembled
/// parameter blocks (`theta0`, `server{i}`, `wy`, `by`). The first
/// layer's input width is inferred from the `theta0` block, so reports
/// from compressed runs (`theta0` is `k_total x h1`) round-trip too.
pub fn params_from_report(cfg: &ModelConfig, rep: &TrainReport) -> Result<ModelParams> {
    let t0 = rep
        .param("theta0")
        .ok_or_else(|| Error::Protocol("report missing param block \"theta0\"".into()))?;
    let h = cfg.h1_dim;
    if t0.is_empty() || t0.len() % h != 0 {
        return Err(Error::Protocol(format!(
            "report param \"theta0\": {} values is not a multiple of h1_dim {h}",
            t0.len()
        )));
    }
    let mut mp = ModelParams::init_with_input(cfg, 0, t0.len() / h);
    copy_block(rep, "theta0", &mut mp.theta0.data)?;
    copy_server_head(rep, &mut mp)?;
    Ok(mp)
}

/// Direct single-process SPNN forward on trained weights, replicating the
/// **fixed-point pipeline** of the private protocols: per holder
/// `encode(X_j · theta_j)`, wrapping-sum across holders, decode, then the
/// `server_fwd` + `label_fwd` graphs.
///
/// For SPNN-**HE** this is bit-exact against the served predictions
/// (Paillier decryption of a packed sum is exactly the slot-wise sum of
/// encodes). For SPNN-**SS** the served path additionally carries the
/// SecureML truncation's probabilistic low-order-bit error, so agreement
/// is within fixed-point tolerance rather than bit-exact.
pub fn spnn_direct_scores(
    cfg: &ModelConfig,
    params: &ModelParams,
    n_holders: usize,
    table: &Dataset,
    rows: &[u32],
    compress: Option<&CompressPlan>,
) -> Result<Vec<f32>> {
    // raw split gathers the private columns; the weight split follows the
    // post-transform widths (identical when no transform is active)
    let raw_split = match compress {
        Some(plan) => plan.raw.clone(),
        None => VerticalSplit::even(cfg.n_features, n_holders),
    };
    let wsplit = match compress {
        Some(plan) => plan.csplit.clone(),
        None => raw_split.clone(),
    };
    let n = rows.len();
    let h1_dim = cfg.h1_dim;
    let mut h1_fix = vec![0u64; n * h1_dim];
    for j in 0..n_holders {
        let (s, e) = raw_split.ranges[j];
        let dj = e - s;
        let mut xb = Vec::with_capacity(n * dj);
        for &r in rows {
            let row = &table.x[r as usize * cfg.n_features..(r as usize + 1) * cfg.n_features];
            for c in s..e {
                xb.push(row[c]);
            }
        }
        let mut xm = MatF64::from_f32(n, dj, &xb);
        if let Some(plan) = compress {
            xm = plan.tfs[j].apply(&xm);
        }
        let (ws, we) = wsplit.ranges[j];
        let theta_j = MatF64::from_data(
            we - ws,
            h1_dim,
            params.theta0.data[ws * h1_dim..we * h1_dim].to_vec(),
        );
        let prod = xm.matmul(&theta_j);
        for (cell, &v) in h1_fix.iter_mut().zip(prod.data.iter()) {
            *cell = cell.wrapping_add(crate::fixed::encode(v));
        }
    }
    let h1: Vec<f32> = h1_fix.iter().map(|&u| crate::fixed::decode(u) as f32).collect();

    let cap = ModelConfig::pick_batch(n);
    let mut engine = Engine::load_default()?;
    let mut h1_pad = vec![0.0f32; cap * h1_dim];
    h1_pad[..n * h1_dim].copy_from_slice(&h1);
    let server_f32 = params.server_f32();
    let mut inputs: Vec<TensorIn> = vec![TensorIn::F32(&h1_pad)];
    for sp in &server_f32 {
        inputs.push(TensorIn::F32(sp));
    }
    let hl = engine
        .execute(&cfg.artifact("server_fwd", cap), &inputs)?
        .remove(0)
        .f32()?;
    let wy = params.wy_f32();
    let by = params.by_f32();
    label_scores(&mut engine, cfg, cap, &hl, &wy, &by, n)
}

/// Direct single-process SplitNN forward on trained weights (encoders from
/// the report's `enc{j}` blocks + server stack + label layer) — bit-exact
/// against the served predictions: the cut-layer traffic is plaintext f32
/// and every graph runs row-independently.
pub fn splitnn_direct_scores(
    cfg: &ModelConfig,
    rep: &TrainReport,
    n_holders: usize,
    table: &Dataset,
    rows: &[u32],
    compress: Option<&CompressPlan>,
) -> Result<Vec<f32>> {
    let fsplit = match compress {
        Some(plan) => plan.raw.clone(),
        None => VerticalSplit::even(cfg.n_features, n_holders),
    };
    let usplit = VerticalSplit::even(cfg.h1_dim, n_holders);
    // theta0 is untrained in SplitNN; only server/wy/by blocks exist
    let mut params = ModelParams::init(cfg, 0);
    copy_server_head(rep, &mut params)?;
    let n = rows.len();
    let h1 = cfg.h1_dim;
    let cap = ModelConfig::pick_batch(n);
    let mut h1_pad = vec![0.0f32; cap * h1];
    for j in 0..n_holders {
        let name = format!("enc{j}");
        let blk = rep
            .param(&name)
            .ok_or_else(|| Error::Protocol(format!("report missing param block {name:?}")))?;
        let (fs, fe) = fsplit.ranges[j];
        let dj = fe - fs;
        // the encoder consumes post-transform columns when compression is on
        let kj = match compress {
            Some(plan) => {
                let (cs, ce) = plan.csplit.ranges[j];
                ce - cs
            }
            None => dj,
        };
        let (us, ue) = usplit.ranges[j];
        let uj = ue - us;
        if blk.len() != kj * uj {
            return Err(Error::Protocol(format!("report param {name:?}: size mismatch")));
        }
        let enc = MatF64::from_data(kj, uj, blk.to_vec());
        let mut xb = Vec::with_capacity(n * dj);
        for &r in rows {
            let row = &table.x[r as usize * cfg.n_features..(r as usize + 1) * cfg.n_features];
            for c in fs..fe {
                xb.push(row[c]);
            }
        }
        let mut xm = MatF64::from_f32(n, dj, &xb);
        if let Some(plan) = compress {
            xm = plan.tfs[j].apply(&xm);
        }
        // the holder sends z as f32 — replicate the f64->f32 boundary
        let z = xm.matmul(&enc).to_f32();
        for r in 0..n {
            h1_pad[r * h1 + us..r * h1 + ue].copy_from_slice(&z[r * uj..(r + 1) * uj]);
        }
    }
    let mut engine = Engine::load_default()?;
    let server_f32 = params.server_f32();
    let mut inputs: Vec<TensorIn> = vec![TensorIn::F32(&h1_pad)];
    for sp in &server_f32 {
        inputs.push(TensorIn::F32(sp));
    }
    let hl = engine
        .execute(&cfg.artifact("server_fwd", cap), &inputs)?
        .remove(0)
        .f32()?;
    let wy = params.wy_f32();
    let by = params.by_f32();
    label_scores(&mut engine, cfg, cap, &hl, &wy, &by, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FRAUD;

    #[test]
    fn feature_source_slice_cuts_contiguous_batches() {
        let x: Vec<f32> = (0..12).map(|v| v as f32).collect(); // 6 rows x 2 cols
        let mut src = FeatureSource::slice(x, 2);
        assert_eq!(src.width(), 2);
        let b = BatchCtx::new(0, 2, 3);
        let m = src.block(&b).unwrap();
        assert_eq!(m.shape(), (3, 2));
        assert_eq!(m.data, vec![4.0, 5.0, 6.0, 7.0, 8.0, 9.0]);
        // beyond the table errors instead of panicking
        let bad = BatchCtx::new(1, 5, 3);
        assert!(src.block(&bad).is_err());
    }

    #[test]
    fn feature_source_gather_resolves_staged_rows_once() {
        let x: Vec<f32> = (0..8).map(|v| v as f32).collect(); // 4 rows x 2 cols
        let mut src = FeatureSource::gather(x, 2);
        src.stage(7, &[3, 0, 3]);
        let b = BatchCtx::new(7, 0, 3);
        let m = src.block(&b).unwrap();
        assert_eq!(m.data, vec![6.0, 7.0, 0.0, 1.0, 6.0, 7.0]);
        // consumed: a second block() for the same batch fails
        assert!(src.block(&b).is_err());
        // row count mismatch and out-of-range ids are protocol errors
        src.stage(8, &[1]);
        let wrong = BatchCtx::new(8, 0, 2);
        assert!(src.block(&wrong).is_err());
        src.stage(9, &[99]);
        let oob = BatchCtx::new(9, 0, 1);
        assert!(src.block(&oob).is_err());
    }

    #[test]
    fn feature_source_applies_attached_transform() {
        use crate::config::CompressBasis;
        let x: Vec<f32> = (0..12).map(|v| v as f32).collect(); // 3 rows x 4 cols
        let tf = FeatureTransform::build(CompressBasis::Dct, 4, 2, 123);
        let mut src = FeatureSource::slice(x.clone(), 4).with_transform(Some(tf.clone()));
        assert_eq!(src.raw_width(), 4);
        assert_eq!(src.width(), 2);
        let b = BatchCtx::new(0, 0, 3);
        let m = src.block(&b).unwrap();
        assert_eq!(m.shape(), (3, 2));
        // bit-identical to applying the transform to the raw block directly
        let want = tf.apply(&MatF64::from_f32(3, 4, &x));
        assert_eq!(m.data, want.data);
        // gather mode transforms too
        let mut g = FeatureSource::gather(x.clone(), 4).with_transform(Some(tf.clone()));
        g.stage(0, &[2, 0]);
        let gb = BatchCtx::new(0, 0, 2);
        let gm = g.block(&gb).unwrap();
        assert_eq!(gm.shape(), (2, 2));
        let mut picked = Vec::new();
        picked.extend_from_slice(&x[8..12]);
        picked.extend_from_slice(&x[0..4]);
        let gwant = tf.apply(&MatF64::from_f32(2, 4, &picked));
        assert_eq!(gm.data, gwant.data);
    }

    #[test]
    fn fwd_script_is_a_prefix_of_the_batch_script() {
        let dims = vec![28usize, 8, 8, 1];
        let acts = vec![Act::Sigmoid, Act::Sigmoid, Act::Sigmoid];
        let fwd = mpc_fwd_script(&dims, &acts, 64);
        let full = mpc_batch_script(&dims, &acts, 64);
        assert!(fwd.len() < full.len());
        assert_eq!(&full[..fwd.len()], &fwd[..], "forward script must prefix training's");
        // per sigmoid layer: Mat + Bool + Bool + Elem
        assert_eq!(fwd.len(), 3 * 4);
    }

    #[test]
    fn params_from_report_roundtrips() {
        let mp = ModelParams::init(&FRAUD, 9);
        let mut rep = TrainReport::default();
        rep.params.push(("theta0".into(), mp.theta0.data.clone()));
        for (i, m) in mp.server.iter().enumerate() {
            rep.params.push((format!("server{i}"), m.data.clone()));
        }
        rep.params.push(("wy".into(), mp.wy.data.clone()));
        rep.params.push(("by".into(), mp.by.data.clone()));
        let got = params_from_report(&FRAUD, &rep).unwrap();
        assert_eq!(got.digest(), mp.digest());
        // a missing block is an error, not a silent default
        rep.params.retain(|(n, _)| n != "wy");
        assert!(params_from_report(&FRAUD, &rep).is_err());
    }
}
