//! The five training protocols the paper evaluates (§6.1):
//!
//! | protocol | module | first layer | heavy layers | labels |
//! |---|---|---|---|---|
//! | NN (plaintext)  | [`plaintext`] | local | local | local |
//! | SplitNN         | [`splitnn`]   | per-holder encoders (plaintext) | server | **on server** (leaked) |
//! | SecureML        | [`secureml`]  | 2-party MPC | 2-party MPC (piecewise act.) | shared |
//! | SPNN-SS         | [`spnn`]      | arithmetic sharing (Alg. 2) | server (plaintext) | holder A |
//! | SPNN-HE         | [`spnn`]      | Paillier HE (Alg. 3) | server (plaintext) | holder A |
//!
//! All implement [`Trainer`] and produce a [`TrainReport`] with accuracy,
//! loss curves, simulated epoch times, traffic accounting, and a bit-exact
//! weight digest — the raw material for every table/figure in `exp/`.
//!
//! Every trainer's party loops run on the shared pipelined session
//! framework ([`common::run_pipeline`]): `TrainConfig::pipeline_depth`
//! mini-batches of value-independent crypto stay in flight per party,
//! while the weight-update schedule (and therefore the trained model) is
//! identical at any depth.

pub mod common;
pub mod plaintext;
pub mod secureml;
pub mod splitnn;
pub mod spnn;

pub use common::{run_pipeline, BatchCtx, ModelParams, Step, TrainReport};

use crate::config::{ModelConfig, TrainConfig};
use crate::data::Dataset;
use crate::netsim::LinkSpec;
use crate::Result;

/// A privacy-preserving (or baseline) training protocol.
pub trait Trainer {
    /// Human-readable protocol name (report rows).
    fn name(&self) -> &'static str;

    /// Train on `train`, evaluate AUC on `test`, under the given network.
    fn train(
        &self,
        cfg: &ModelConfig,
        tc: &TrainConfig,
        spec: LinkSpec,
        train: &Dataset,
        test: &Dataset,
        n_holders: usize,
    ) -> Result<TrainReport>;
}

/// Instantiate a trainer by CLI name.
pub fn by_name(name: &str) -> Option<Box<dyn Trainer>> {
    match name {
        "nn" => Some(Box::new(plaintext::PlainNn)),
        "splitnn" => Some(Box::new(splitnn::SplitNn)),
        "secureml" => Some(Box::new(secureml::SecureMl)),
        "spnn-ss" => Some(Box::new(spnn::Spnn { he: false })),
        "spnn-he" => Some(Box::new(spnn::Spnn { he: true })),
        _ => None,
    }
}
